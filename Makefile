# Repo-level entry points.
#
# `make artifacts` exports the AOT HLO artifacts + manifest that the
# PJRT-backed runtime loads (python + jax required; the stages land in
# artifacts/<config>/ — see python/compile/aot.py for the contract).

.PHONY: artifacts test bench

artifacts:
	cd python && python -m compile.aot --config smoke --out-dir ../artifacts

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo build --release --benches
