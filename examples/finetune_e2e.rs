//! End-to-end validation (DESIGN.md §6): fine-tune the ~100M-parameter
//! model for a few hundred steps on the synthetic corpus through the
//! FULL offload stack, in both ZeRO-Infinity-baseline and MemAscend
//! modes, and record loss curves + throughput + peak memory.
//!
//!     make artifacts
//!     cargo run --release --example finetune_e2e -- [model] [steps]
//!
//! model: tiny100m (default) | tiny25m | smoke; steps default 150.
//! Results land in bench_out/e2e_<model>_<mode>.csv; the headline run
//! recorded in EXPERIMENTS.md used `tiny100m 150` and `tiny25m 250`
//! (Fig. 19 analog).

use std::path::{Path, PathBuf};

use memascend::config::{MemAscendFlags, TrainSpec};
use memascend::runtime::Manifest;
use memascend::train::{TrainOpts, Trainer};
use memascend::util::human;

fn run(
    model: &str,
    steps: usize,
    flags: MemAscendFlags,
) -> anyhow::Result<memascend::metrics::RunReport> {
    let artifacts = PathBuf::from("artifacts").join(model);
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts/{model} missing — run `make artifacts`"
    );
    let manifest = Manifest::load(&artifacts.join("manifest.json"))?;
    let storage = std::env::temp_dir().join(format!(
        "ma-e2e-{model}-{}-{}",
        flags.label(),
        std::process::id()
    ));
    std::fs::create_dir_all(&storage)?;
    let spec = TrainSpec {
        batch: manifest.config.batch,
        seq: manifest.config.seq,
        flags,
        ..Default::default()
    };
    let opts = TrainOpts {
        steps,
        seed: 42,
        log_every: 10,
        loss_csv: Some(format!("bench_out/e2e_{model}_{}.csv", flags.label())),
    };
    let mut trainer = Trainer::new(&artifacts, &storage, spec, &opts)?;
    let report = trainer.run(&opts)?;
    std::fs::remove_dir_all(&storage).ok();
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("tiny100m");
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let both = args.iter().any(|a| a == "--both");

    println!("== end-to-end fine-tuning: {model}, {steps} steps ==");
    let ma = run(model, steps, MemAscendFlags::memascend())?;
    summarize("memascend", &ma);

    if both {
        let zi = run(model, steps, MemAscendFlags::baseline())?;
        summarize("zero-infinity", &zi);
        let identical = zi
            .steps
            .iter()
            .zip(&ma.steps)
            .all(|(a, b)| a.loss.to_bits() == b.loss.to_bits());
        println!("\nconvergence parity (Fig. 19): bit-identical = {identical}");
        println!(
            "throughput: MA {:.1} vs ZI {:.1} tokens/s ({:+.1}%)",
            ma.tokens_per_sec(),
            zi.tokens_per_sec(),
            (ma.tokens_per_sec() / zi.tokens_per_sec() - 1.0) * 100.0
        );
        println!(
            "peak host memory: MA {} vs ZI {}",
            human::bytes(ma.peak_sysmem_bytes),
            human::bytes(zi.peak_sysmem_bytes)
        );
    }
    Ok(())
}

fn summarize(label: &str, r: &memascend::metrics::RunReport) {
    let t_io: f64 = r.steps.iter().map(|s| s.io_secs).sum();
    let t_all: f64 = r.steps.iter().map(|s| s.step_secs).sum();
    let t_ovf: f64 = r.steps.iter().map(|s| s.overflow_check_secs).sum();
    let t_opt: f64 = r.steps.iter().map(|s| s.optim_secs).sum();
    println!("\n--- {label} ---");
    println!("loss {:.4} -> {:.4}", r.steps[0].loss, r.mean_tail_loss(10));
    println!("throughput {:.1} tokens/s", r.tokens_per_sec());
    println!("peak host memory {}", human::bytes(r.peak_sysmem_bytes));
    println!("SSD traffic/step {}", human::bytes(r.io_bytes_per_step));
    println!(
        "time split: io {:.1}% overflow {:.1}% optim {:.1}% compute {:.1}%",
        t_io / t_all * 100.0,
        t_ovf / t_all * 100.0,
        t_opt / t_all * 100.0,
        (t_all - t_io - t_ovf - t_opt) / t_all * 100.0
    );
    let _ = Path::new(".");
}
