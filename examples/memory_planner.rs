//! Memory planner: given a hardware budget, what can you train?
//!
//!     cargo run --release --example memory_planner -- [dram_gib] [hw]
//!
//! For every model preset, finds the maximum context length (batch 1)
//! and the maximum batch size (ctx 4096) that fit the system-memory
//! budget under ZeRO-Infinity vs MemAscend — the paper's §V-B/§V-C
//! claims ("16,384 -> 131,072 tokens, batch 4 -> 32 under 128 GiB")
//! as a planning tool.

use memascend::accounting::sysmem::peak_sysmem;
use memascend::config::hardware::HardwareSpec;
use memascend::config::presets::PAPER_DENSE;
use memascend::config::{MemAscendFlags, TrainSpec};
use memascend::util::bench::Table;

fn fits(model: &memascend::config::ModelSpec, spec: &TrainSpec, hw: &HardwareSpec, cap: f64) -> bool {
    peak_sysmem(model, spec, hw).gib() <= cap
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cap: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(128.0);
    let hw = HardwareSpec::by_name(args.get(1).map(String::as_str).unwrap_or("config1"))?;

    println!("== memory planner: {cap} GiB system-memory budget on {} ==\n", hw.name);
    let ctxs = [4096usize, 8192, 16384, 32768, 65536, 131072, 262144];
    let batches = [1usize, 2, 4, 8, 16, 32, 64, 96];

    let mut t = Table::new(vec![
        "model",
        "max ctx ZI",
        "max ctx MA",
        "max batch ZI",
        "max batch MA",
    ]);
    for m in PAPER_DENSE {
        let max_ctx = |flags: MemAscendFlags| {
            ctxs.iter()
                .rev()
                .find(|&&c| {
                    let s = TrainSpec {
                        batch: 1,
                        seq: c,
                        ranks: 2,
                        prefetch_depth: 1,
                        flags,
                        ..Default::default()
                    };
                    fits(m, &s, hw, cap)
                })
                .copied()
        };
        let max_batch = |flags: MemAscendFlags| {
            batches
                .iter()
                .rev()
                .find(|&&b| {
                    let s = TrainSpec {
                        batch: b,
                        seq: 4096,
                        ranks: 2,
                        prefetch_depth: 1,
                        flags,
                        ..Default::default()
                    };
                    fits(m, &s, hw, cap)
                })
                .copied()
        };
        let fmt = |v: Option<usize>| v.map(|x| x.to_string()).unwrap_or_else(|| "none".into());
        t.row(vec![
            m.name.to_string(),
            fmt(max_ctx(MemAscendFlags::baseline())),
            fmt(max_ctx(MemAscendFlags::memascend())),
            fmt(max_batch(MemAscendFlags::baseline())),
            fmt(max_batch(MemAscendFlags::memascend())),
        ]);
    }
    println!("{}", t.render());
    println!("paper §V-B/§V-C (Qwen2.5-7B @128 GiB): ctx 16,384 -> 131,072; batch 4 -> 32");
    Ok(())
}
