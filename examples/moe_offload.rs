//! MoE offloading demo (paper §VI-B-2e, Fig. 18): why the monolithic
//! buffer pool collapses on sparse models, shown with the real pool
//! constructors over Qwen3-30B-A3B's actual tensor inventory.
//!
//!     cargo run --release --example moe_offload

use std::sync::Arc;

use memascend::bufpool::{AdaptivePool, MonolithicPool, ParamBufferPool};
use memascend::config::presets::QWEN3_30B_A3B;
use memascend::dtype::DType;
use memascend::pinned::{AlignedAllocator, MemoryTracker, Mode};
use memascend::tensors;
use memascend::util::human;

fn main() {
    let m = &QWEN3_30B_A3B;
    println!(
        "== {} — {:.1}B params, {} experts/layer, {} active ==\n",
        m.name,
        m.param_count() as f64 / 1e9,
        m.n_experts,
        m.experts_per_token
    );

    let inv = tensors::inventory(m);
    let expert_elems = m.hidden * m.expert_intermediate;
    let embed_elems = m.vocab * m.hidden;
    println!(
        "largest tensor (embedding): {} | one expert projection: {} ({}x smaller)",
        human::bytes((embed_elems * 2) as u64),
        human::bytes((expert_elems * 2) as u64),
        embed_elems / expert_elems
    );
    println!(
        "offloadable tensors per block: {} (dense models have ~7)\n",
        inv.iter().filter(|t| t.layer == 0 && t.offloadable()).count()
    );

    let alloc = AlignedAllocator::new(Mode::Virtual, Arc::new(MemoryTracker::new()));
    let mono = MonolithicPool::new(m, 1, DType::F16, &alloc);
    let adap = AdaptivePool::new(m, 1, DType::F16, &alloc);
    println!(
        "monolithic pool (every slot embedding-sized): {}",
        human::bytes(mono.stats().pool_bytes as u64)
    );
    println!(
        "adaptive pool   (per-shape-class slots):      {}",
        human::bytes(adap.stats().pool_bytes as u64)
    );
    println!(
        "reduction: {:.1}% (paper Fig. 18: ~71.9% end-to-end)\n",
        (1.0 - adap.stats().pool_bytes as f64 / mono.stats().pool_bytes as f64) * 100.0
    );

    println!("adaptive subpool layout:");
    for (class, slot, n) in adap.layout() {
        println!(
            "  {class:?}: {n:>4} slots x {}",
            human::bytes(slot as u64)
        );
    }
}
