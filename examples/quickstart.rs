//! Quickstart: train the smoke model through the full SSD-offload
//! stack and print a run report + memory ledger.
//!
//!     make artifacts
//!     cargo run --release --example quickstart
//!
//! Everything a real run does happens here: fp16 weights + fp32
//! optimizer states on the simulated SSD, layer-streamed PJRT forward/
//! backward, fused overflow check, dynamic loss scaling, CPU AdamW.

use std::path::Path;

use memascend::config::{MemAscendFlags, TrainSpec};
use memascend::train::{TrainOpts, Trainer};
use memascend::util::human;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts/smoke");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let storage = std::env::temp_dir().join(format!("ma-quickstart-{}", std::process::id()));
    std::fs::create_dir_all(&storage)?;

    let spec = TrainSpec {
        batch: 2,
        seq: 16,
        flags: MemAscendFlags::memascend(),
        init_loss_scale: 1024.0,
        ..Default::default()
    };
    let opts = TrainOpts { steps: 30, seed: 42, log_every: 5, loss_csv: None };
    let mut trainer = Trainer::new(artifacts, &storage, spec, &opts)?;
    let report = trainer.run(&opts)?;

    println!("\n=== quickstart report ===");
    println!("loss: {:.4} -> {:.4}", report.steps[0].loss, report.final_loss());
    println!("throughput: {:.0} tokens/s", report.tokens_per_sec());
    println!("peak host memory: {}", human::bytes(report.peak_sysmem_bytes));
    println!("SSD traffic/step: {}", human::bytes(report.io_bytes_per_step));
    println!("\nmemory ledger:\n{}", trainer.engine.tracker.report());
    std::fs::remove_dir_all(&storage).ok();
    Ok(())
}
