"""AOT export: lower every model stage + standalone kernels to HLO text.

Interchange is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla_extension
0.5.1 under the Rust `xla` crate rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --config smoke --out-dir ../artifacts
    python -m compile.aot --all --out-dir ../artifacts

Outputs, per config C:
    artifacts/C/<stage>.hlo.txt     one module per stage
    artifacts/C/manifest.json       shapes/dtypes/arg-order contract
                                    consumed by rust `runtime::manifest`
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, ModelConfig
from .kernels.adam import fused_adam_step
from .kernels.overflow import fused_overflow_check


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def stage_signatures(cfg: ModelConfig):
    """Argument/result signatures for every stage, in PJRT call order."""
    b, s, h, v = cfg.batch, cfg.seq, cfg.hidden, cfg.vocab
    bw = model.block_weight_shapes(cfg)
    block_args = [("h", _spec((b, s, h)))] + [
        (n, _spec(bw[n])) for n in model.BLOCK_WEIGHT_NAMES
    ]
    c = cfg.chunk
    return {
        "embed_fwd": {
            "args": [("tokens", _spec((b, s), "i32")), ("table", _spec((v, h)))],
            "results": [("h", _spec((b, s, h)))],
        },
        "block_fwd": {
            "args": block_args,
            "results": [("h_out", _spec((b, s, h)))],
        },
        "block_bwd": {
            "args": block_args + [("d_out", _spec((b, s, h)))],
            "results": [("d_h", _spec((b, s, h)))]
            + [("d_" + n, _spec(bw[n])) for n in model.BLOCK_WEIGHT_NAMES],
        },
        "head_fwd_bwd": {
            "args": [
                ("h", _spec((b, s, h))),
                ("final_norm", _spec((h,))),
                ("w_head", _spec((h, v))),
                ("labels", _spec((b, s), "i32")),
                ("scale", _spec((1,))),
            ],
            "results": [
                ("loss", _spec((1,))),
                ("d_h", _spec((b, s, h))),
                ("d_final_norm", _spec((h,))),
                ("d_w_head", _spec((h, v))),
            ],
        },
        "embed_bwd": {
            "args": [("tokens", _spec((b, s), "i32")), ("d_h", _spec((b, s, h)))],
            "results": [("d_table", _spec((v, h)))],
        },
        "adam_step": {
            "args": [
                ("bias_corr", _spec((2,))),
                ("p", _spec((c,))),
                ("g", _spec((c,))),
                ("m", _spec((c,))),
                ("v", _spec((c,))),
            ],
            "results": [("p", _spec((c,))), ("m", _spec((c,))), ("v", _spec((c,)))],
        },
        "overflow_check": {
            "args": [("x", _spec((c,)))],
            "results": [("flag", _spec((1,), "i32"))],
        },
    }


def _as_shape(spec):
    dt = {"f32": jnp.float32, "i32": jnp.int32}[spec["dtype"]]
    return jax.ShapeDtypeStruct(tuple(spec["shape"]), dt)


def stage_fns(cfg: ModelConfig):
    """The callables behind each stage, matching stage_signatures order."""

    def adam(bc, p, g, m, v):
        return fused_adam_step(
            p, g, m, v, bc,
            lr=1.0e-3, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0,
            block=min(cfg.chunk, 1 << 16),
        )

    # NOTE: adam hyper-params are baked trace-time; the Rust coordinator's
    # native optimizer is the default path, and the HLO artifact is the
    # parity/demo path (tests assert both agree for these constants).
    return {
        "embed_fwd": model.embed_fwd,
        "block_fwd": functools.partial(model.block_fwd, cfg),
        "block_bwd": functools.partial(model.block_bwd, cfg),
        "head_fwd_bwd": functools.partial(model.head_fwd_bwd, cfg),
        "embed_bwd": functools.partial(model.embed_bwd, cfg),
        "adam_step": adam,
        "overflow_check": lambda x: fused_overflow_check(
            x, block=min(cfg.chunk, 1 << 16)
        ),
    }


def export_config(cfg: ModelConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    sigs = stage_signatures(cfg)
    fns = stage_fns(cfg)
    manifest = {
        "config": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "hidden": cfg.hidden,
            "intermediate": cfg.intermediate,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "kv_heads": cfg.kv_heads,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "chunk": cfg.chunk,
            "param_count": cfg.param_count(),
            "norm_eps": cfg.norm_eps,
            "rope_theta": cfg.rope_theta,
        },
        "block_weight_names": list(model.BLOCK_WEIGHT_NAMES),
        "adam": {"lr": 1.0e-3, "beta1": 0.9, "beta2": 0.999,
                 "eps": 1e-8, "weight_decay": 0.0},
        "stages": {},
    }
    for name, sig in sigs.items():
        example = [_as_shape(s) for _, s in sig["args"]]
        lowered = jax.jit(fns[name]).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["stages"][name] = {
            "file": fname,
            "args": [{"name": n, **s} for n, s in sig["args"]],
            "results": [{"name": n, **s} for n, s in sig["results"]],
        }
        print(f"  [{cfg.name}] {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", choices=sorted(CONFIGS), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    names = sorted(CONFIGS) if args.all or not args.config else [args.config]
    for name in names:
        cfg = CONFIGS[name]
        print(f"exporting {name} ...")
        export_config(cfg, os.path.join(args.out_dir, name))
    print("AOT export complete.")


if __name__ == "__main__":
    main()
