"""Model/export configurations shared by the AOT pipeline and tests.

Three runnable configs (executed on the CPU PJRT client by the Rust
coordinator) plus the full-scale *inventory-only* architectures used by
the accounting engine live on the Rust side (`config/presets.rs`); the
two lists are kept consistent by `tests/test_aot.py`.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    hidden: int
    intermediate: int
    layers: int
    heads: int
    kv_heads: int
    seq: int          # export-time context length
    batch: int        # export-time micro-batch per rank
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    # flat-buffer chunk sizes for the standalone adam/overflow artifacts
    chunk: int = 1 << 16

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameters (matches tensors::inventory on the Rust side)."""
        h, f, v = self.hidden, self.intermediate, self.vocab
        per_block = (
            h * h + h * self.kv_dim + h * self.kv_dim + h * h  # q k v o
            + 3 * h * f                                         # gate/up/down
            + 2 * h                                             # two norms
        )
        return v * h + self.layers * per_block + h + h * v      # embed+final norm+head


# smoke: integration-test scale — compiles in ms, runs anywhere.
SMOKE = ModelConfig(
    name="smoke", vocab=64, hidden=32, intermediate=64, layers=2,
    heads=2, kv_heads=2, seq=16, batch=2, chunk=1 << 10,
)

# tiny-25m: convergence-curve scale (Fig. 19 reproduction).
TINY25M = ModelConfig(
    name="tiny25m", vocab=4096, hidden=384, intermediate=1024, layers=8,
    heads=6, kv_heads=6, seq=128, batch=1, chunk=1 << 16,
)

# tiny-100m: the end-to-end validation model (~100M params).
TINY100M = ModelConfig(
    name="tiny100m", vocab=8192, hidden=768, intermediate=2048, layers=12,
    heads=12, kv_heads=12, seq=128, batch=1, chunk=1 << 16,
)

CONFIGS = {c.name: c for c in (SMOKE, TINY25M, TINY100M)}
