"""L1 Pallas kernels for MemAscend's fused hot paths.

Each kernel expresses one of the paper's fusion opportunities as a
single-pass Pallas kernel (interpret=True so the AOT-lowered HLO runs
on the CPU PJRT client):

- ``overflow``       — fused IEEE-754 exponent-mask overflow check
                       (paper Algorithm 1, replaces the isinf/isnan chain)
- ``adam``           — fused AdamW step (DeepSpeed CPU-optimizer analog)
- ``cross_entropy``  — fused softmax-CE loss + logit-gradient (Liger analog)
- ``rmsnorm``        — fused RMSNorm forward (Liger analog)
- ``ref``            — pure-jnp oracles for all of the above
"""

from .adam import fused_adam_step
from .cross_entropy import cross_entropy_loss, fused_cross_entropy
from .overflow import fused_overflow_check
from .rmsnorm import fused_rmsnorm, rmsnorm

__all__ = [
    "fused_adam_step",
    "cross_entropy_loss",
    "fused_cross_entropy",
    "fused_overflow_check",
    "fused_rmsnorm",
    "rmsnorm",
]
