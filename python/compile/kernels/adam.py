"""L1 Pallas kernel: fused Adam/AdamW step (DeepSpeed CPU-optimizer analog).

ZeRO-Infinity runs the optimizer on the host: a fused C++/AVX kernel
updates contiguous fp32 master parameters + momentum/variance against
fp16 gradients.  This kernel is the same fusion expressed in Pallas:
one pass reads (p, g, m, v) blocks from HBM into VMEM, applies the full
AdamW update (bias-corrected, decoupled weight decay), and writes
(p', m', v') back — no intermediate tensors ever materialize.

Hyper-parameters ``lr/beta1/beta2/eps/weight_decay`` are trace-time
constants (they are fixed for a training run); the *step-dependent*
bias corrections are passed as a (2,)-element array so one compiled
artifact serves every step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1 << 16


def _adam_kernel(bc_ref, p_ref, g_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref,
                 *, lr, beta1, beta2, eps, weight_decay):
    p = p_ref[...]
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * (g * g)
    # bc_ref = [1 - beta1^t, 1 - beta2^t]
    m_hat = m / bc_ref[0]
    v_hat = v / bc_ref[1]
    update = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p
    po_ref[...] = p - lr * update
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(
    jax.jit,
    static_argnames=("lr", "beta1", "beta2", "eps", "weight_decay", "block"),
)
def fused_adam_step(
    p: jax.Array,
    g: jax.Array,
    m: jax.Array,
    v: jax.Array,
    bias_corrections: jax.Array,
    *,
    lr: float = 1e-4,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    block: int = DEFAULT_BLOCK,
):
    """Fused AdamW over flat fp32 buffers. Returns (p', m', v').

    ``bias_corrections`` is f32[2] = [1-beta1^t, 1-beta2^t] for step t.
    Lengths must be a multiple of ``block`` (tail chunks are padded with
    g=m=v=p=0, which the update maps to 0 — padding stays inert).
    """
    (n,) = p.shape
    if n % block != 0:
        raise ValueError(f"length {n} not a multiple of block {block}")
    kernel = functools.partial(
        _adam_kernel, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
        weight_decay=weight_decay,
    )
    grid = (n // block,)
    blk = pl.BlockSpec((block,), lambda i: (i,))
    scalar = pl.BlockSpec((2,), lambda i: (0,))
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[scalar, blk, blk, blk, blk],
        out_specs=[blk, blk, blk],
        out_shape=[out, out, out],
        interpret=True,
    )(bias_corrections, p, g, m, v)
