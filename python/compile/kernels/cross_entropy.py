"""L1 Pallas kernel: fused softmax-cross-entropy (Liger-Kernel analog).

The paper's baseline integrates Liger-Kernel precisely because a naive
cross-entropy materializes the full logit tensor again for the softmax
and once more for the gradient.  This kernel computes, in a single
row-wise pass with the row resident in VMEM: the numerically-stable
log-sum-exp, the per-row loss, and the logit gradient
``softmax(row) - onehot(label)`` — nothing but the inputs and outputs
ever exist in memory.

A ``jax.custom_vjp`` wrapper makes the fused kernel differentiable so
the L2 model's LM head can call it inside ``jax.vjp``: the forward pass
stashes the fused gradient as the residual and the backward pass is a
broadcast multiply.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ce_kernel(x_ref, l_ref, loss_ref, dx_ref):
    row = x_ref[...].astype(jnp.float32)  # (1, V)
    label = l_ref[0]
    v = row.shape[-1]
    m = jnp.max(row, axis=-1, keepdims=True)
    e = jnp.exp(row - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    lse = jnp.log(s) + m  # (1, 1)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, row.shape, 1) == label
    ).astype(jnp.float32)
    picked = jnp.sum(row * onehot, axis=-1, keepdims=True)
    loss_ref[...] = (lse - picked)[:, 0]
    dx_ref[...] = e / s - onehot
    del v


def fused_cross_entropy(logits: jax.Array, labels: jax.Array):
    """Row-fused CE. logits f32[T, V], labels i32[T] -> (loss f32[T], dlogits f32[T, V])."""
    t, v = logits.shape
    return pl.pallas_call(
        _ce_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, v), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, v), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t, v), jnp.float32),
        ],
        interpret=True,
    )(logits, labels)


@jax.custom_vjp
def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE loss over rows, differentiable via the fused kernel."""
    loss, _ = fused_cross_entropy(logits, labels)
    return jnp.mean(loss)


def _ce_fwd(logits, labels):
    loss, dlogits = fused_cross_entropy(logits, labels)
    return jnp.mean(loss), (dlogits, logits.shape[0])


def _ce_bwd(res, g):
    dlogits, t = res
    return (g * dlogits / t, None)


cross_entropy_loss.defvjp(_ce_fwd, _ce_bwd)
