"""L1 Pallas kernel: fused gradient-overflow check (paper Algorithm 1).

The ZeRO-Infinity baseline detects fp16-range overflow in the fp32
gradient flat buffer with a chain of framework ops —
``abs -> isinf -> any -> isnan -> any`` — which materializes a full-size
temporary plus two boolean tensors (a 2.25x peak-memory spike) and makes
five passes over the data.

MemAscend's fused check exploits IEEE-754 directly: a float is Inf or
NaN iff *all exponent bits are ones*.  One bitcast, one mask-compare,
one reduction — a single pass, zero temporaries.  This kernel is the
Pallas expression of that insight: each grid step stages one block of
the flat buffer into VMEM, reduces it to a single flag, and ORs the
flag into a (1,)-shaped accumulator that lives across grid steps.

On a real TPU this is pure VPU work on (8,128)-aligned tiles; here it is
lowered with ``interpret=True`` so the CPU PJRT client can execute the
resulting HLO (real-TPU lowering emits a Mosaic custom-call the CPU
plugin cannot run).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# All-ones exponent field for each supported storage format.
_EXP_MASK = {
    jnp.dtype(jnp.float32): (jnp.uint32, 0x7F80_0000),
    jnp.dtype(jnp.float16): (jnp.uint16, 0x7C00),
    jnp.dtype(jnp.bfloat16): (jnp.uint16, 0x7F80),
}

# Default block: 64Ki elements = 256 KiB of f32, a comfortable VMEM tile
# (VMEM is ~16 MiB/core; double-buffered staging of 256 KiB blocks keeps
# the VPU busy while HBM->VMEM copies stream).
DEFAULT_BLOCK = 1 << 16


def _overflow_kernel(x_ref, o_ref, *, uint_dtype, mask):
    """One grid step: reduce one block to a 0/1 flag and OR-accumulate."""
    bits = jax.lax.bitcast_convert_type(x_ref[...], uint_dtype)
    m = jnp.asarray(mask, dtype=uint_dtype)
    hit = jnp.any((bits & m) == m).astype(jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] = jnp.maximum(o_ref[...], hit)


@functools.partial(jax.jit, static_argnames=("block",))
def fused_overflow_check(x: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Return int32[1]: 1 if any element of ``x`` is Inf/NaN, else 0.

    ``x`` must be a flat (1-D) array whose length is a multiple of
    ``block`` — the coordinator pads the tail chunk with zeros, which
    can never flag (zero exponent field).
    """
    (n,) = x.shape
    if n % block != 0:
        raise ValueError(f"length {n} not a multiple of block {block}")
    uint_dtype, mask = _EXP_MASK[jnp.dtype(x.dtype)]
    kernel = functools.partial(_overflow_kernel, uint_dtype=uint_dtype, mask=mask)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        interpret=True,
    )(x)
