"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: pytest + hypothesis sweep
shapes/dtypes and assert the Pallas kernels match these references
(`test_kernels.py`).  They intentionally mirror the *baseline*
formulations the paper describes (multi-op overflow chain, unfused
Adam, unfused CE/RMSNorm) so the parity tests double as proof that
fusion changes nothing numerically.
"""

from __future__ import annotations

import jax.numpy as jnp


def overflow_check_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Baseline isinf/isnan chain (paper Fig. 3, steps 2-6)."""
    a = jnp.abs(x)                     # step 2: abs temporary
    inf_any = jnp.any(jnp.isinf(a))    # steps 2-3: bool tensor + reduce
    nan_any = jnp.any(jnp.isnan(x))    # steps 4-5: bool tensor + reduce
    return (inf_any | nan_any).astype(jnp.int32).reshape(1)


def adam_step_ref(p, g, m, v, step, *, lr=1e-4, beta1=0.9, beta2=0.999,
                  eps=1e-8, weight_decay=0.0):
    """Textbook AdamW with decoupled weight decay (DeepSpeed semantics)."""
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32)
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m / (1.0 - beta1**step)
    v_hat = v / (1.0 - beta2**step)
    p = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p)
    return p, m, v


def cross_entropy_ref(logits, labels):
    """Unfused CE: materializes log-softmax and softmax separately."""
    logits = logits.astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(logits - logits.max(-1, keepdims=True)), -1))
    lse = lse + logits.max(-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = lse - picked
    soft = jnp.exp(logits - logits.max(-1, keepdims=True))
    soft = soft / soft.sum(-1, keepdims=True)
    onehot = jnp.zeros_like(logits).at[jnp.arange(logits.shape[0]), labels].set(1.0)
    return loss, soft - onehot


def rmsnorm_ref(x, w, eps=1e-6):
    x = x.astype(jnp.float32)
    r = 1.0 / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x * r * w
