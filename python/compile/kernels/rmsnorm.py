"""L1 Pallas kernel: fused RMSNorm (Liger-Kernel analog).

Baseline frameworks compute RMSNorm as square -> mean -> rsqrt ->
multiply -> scale, materializing intermediates between kernel launches.
The fused kernel keeps one row block in VMEM and emits the normalized,
scaled output in a single pass.  A ``custom_vjp`` wrapper provides the
analytic backward pass so L2 transformer blocks can use the fused
forward inside ``jax.vjp`` (gradient-checkpoint recomputation included).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # (1, H)
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = x * r * w_ref[...]


def fused_rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Fused RMSNorm. x f32[T, H], w f32[H] -> f32[T, H]."""
    t, h = x.shape
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h), jnp.float32),
        interpret=True,
    )(x, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    return fused_rmsnorm(x, w, eps)


def _rms_fwd(x, w, eps):
    return fused_rmsnorm(x, w, eps), (x, w)


def _rms_bwd(eps, res, dy):
    x, w = res
    h = x.shape[-1]
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    dyw = dy * w
    # d/dx [x * r(x) * w]: product rule through r = (mean(x^2)+eps)^-1/2
    dx = r * dyw - (r**3 / h) * x * jnp.sum(dyw * x, axis=-1, keepdims=True)
    dw = jnp.sum(dy * x * r, axis=tuple(range(x.ndim - 1)))
    return dx, dw


rmsnorm.defvjp(_rms_fwd, _rms_bwd)
