"""L2: Llama-style decoder fwd/bwd in JAX, staged for layer-wise offload.

The Rust coordinator streams one transformer block's weights at a time
from the (simulated) SSD, exactly as ZeRO-Infinity does — so the model
is exported **per stage** rather than as one monolithic module:

    embed_fwd     tokens, embedding table          -> hidden states
    block_fwd     hidden, block weights            -> hidden' (also used
                  for gradient-checkpoint recomputation)
    block_bwd     hidden, block weights, d_hidden' -> d_hidden, d_weights
    head_fwd_bwd  hidden, final-norm w, head w,
                  labels, loss-scale               -> loss, d_hidden,
                                                      d_norm, d_head
    embed_bwd     tokens, d_hidden                 -> d_table

Each stage is jit-lowered once and serialized as HLO *text*
(`aot.py`); the runtime executes stages through PJRT with no Python.

Fused L1 kernels on the path: the LM head uses the Pallas fused
softmax-CE (`kernels.cross_entropy`) through its custom_vjp, and block
norms use the Pallas fused RMSNorm (`kernels.rmsnorm`), whose analytic
backward is traced into `block_bwd`.

Canonical per-block weight order (must match rust `tensors::BLOCK_ORDER`):
    [attn_norm, wq, wk, wv, wo, ffn_norm, w_gate, w_up, w_down]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.cross_entropy import cross_entropy_loss
from .kernels.rmsnorm import rmsnorm

BLOCK_WEIGHT_NAMES = (
    "attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down",
)


def block_weight_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    h, f, kv = cfg.hidden, cfg.intermediate, cfg.kv_dim
    return {
        "attn_norm": (h,),
        "wq": (h, h),
        "wk": (h, kv),
        "wv": (h, kv),
        "wo": (h, h),
        "ffn_norm": (h,),
        "w_gate": (h, f),
        "w_up": (h, f),
        "w_down": (f, h),
    }


def _rope(x: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding over [B, S, n, head_dim]."""
    b, s, n, d = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def block_fwd(cfg: ModelConfig, h, attn_norm, wq, wk, wv, wo,
              ffn_norm, w_gate, w_up, w_down):
    """One pre-norm decoder block: GQA causal attention + SwiGLU MLP."""
    b, s, hd = h.shape
    nh, nkv, dh = cfg.heads, cfg.kv_heads, cfg.head_dim

    # --- attention ---
    x = rmsnorm(h.reshape(-1, hd), attn_norm, cfg.norm_eps).reshape(b, s, hd)
    q = (x @ wq).reshape(b, s, nh, dh)
    k = (x @ wk).reshape(b, s, nkv, dh)
    v = (x @ wv).reshape(b, s, nkv, dh)
    q = _rope(q, cfg.rope_theta)
    k = _rope(k, cfg.rope_theta)
    if nkv != nh:  # grouped-query attention: broadcast kv heads
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqnd,bknd->bnqk", q, k) / jnp.sqrt(float(dh))
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(causal[None, None, :, :], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bnqk,bknd->bqnd", attn, v).reshape(b, s, hd)
    h = h + ctx @ wo

    # --- SwiGLU MLP ---
    x = rmsnorm(h.reshape(-1, hd), ffn_norm, cfg.norm_eps).reshape(b, s, hd)
    gated = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h + gated @ w_down


def block_bwd(cfg: ModelConfig, h, *ws_and_dout):
    """VJP of block_fwd: (h, ws..., d_out) -> (d_h, d_ws...)."""
    *ws, dout = ws_and_dout
    _, pullback = jax.vjp(lambda hh, *ww: block_fwd(cfg, hh, *ww), h, *ws)
    return pullback(dout)


def embed_fwd(tokens, table):
    return table[tokens]


def embed_bwd(cfg: ModelConfig, tokens, dh):
    table_shape = (cfg.vocab, cfg.hidden)
    flat_tok = tokens.reshape(-1)
    flat_dh = dh.reshape(-1, cfg.hidden)
    return jnp.zeros(table_shape, jnp.float32).at[flat_tok].add(flat_dh)


def head_fwd_bwd(cfg: ModelConfig, h, norm_w, w_head, labels, scale):
    """Final norm + LM head + fused CE, forward and backward in one stage.

    Returns (mean unscaled loss[1], d_h, d_norm_w, d_w_head) where the
    gradients carry the dynamic loss scale (``scale`` f32[1]) so fp16
    gradient casts on the Rust side land in representable range.
    """
    def loss_fn(hh, nw, wh):
        hn = rmsnorm(hh.reshape(-1, cfg.hidden), nw, cfg.norm_eps)
        logits = hn @ wh                      # [B*S, V]
        return cross_entropy_loss(logits, labels.reshape(-1))

    loss, pullback = jax.vjp(loss_fn, h, norm_w, w_head)
    dh, dnorm, dhead = pullback(scale[0])
    return loss.reshape(1), dh, dnorm, dhead


def full_forward_loss(cfg: ModelConfig, tokens, labels, params):
    """Reference whole-model loss (used by python tests only).

    ``params`` = (table, [block weight tuples...], final_norm, w_head).
    """
    table, blocks, final_norm, w_head = params
    h = embed_fwd(tokens, table)
    for ws in blocks:
        h = block_fwd(cfg, h, *ws)
    loss, *_ = head_fwd_bwd(
        cfg, h, final_norm, w_head, labels, jnp.ones((1,), jnp.float32)
    )
    return loss[0]
