"""AOT export contract tests: manifest integrity + HLO text format."""

from __future__ import annotations

import json
import os

import pytest

from compile import aot, model
from compile.configs import CONFIGS, SMOKE

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "smoke")


@pytest.fixture(scope="module")
def smoke_artifacts(tmp_path_factory):
    """Use the checked-out artifacts if present, else export fresh."""
    if os.path.exists(os.path.join(ART, "manifest.json")):
        return ART
    out = str(tmp_path_factory.mktemp("art") / "smoke")
    aot.export_config(SMOKE, out)
    return out


def test_signatures_cover_all_stages():
    sigs = aot.stage_signatures(SMOKE)
    fns = aot.stage_fns(SMOKE)
    assert set(sigs) == set(fns) == {
        "embed_fwd", "block_fwd", "block_bwd", "head_fwd_bwd",
        "embed_bwd", "adam_step", "overflow_check",
    }


def test_block_bwd_signature_is_fwd_plus_cotangent():
    sigs = aot.stage_signatures(SMOKE)
    fwd = sigs["block_fwd"]["args"]
    bwd = sigs["block_bwd"]["args"]
    assert bwd[:-1] == fwd
    assert bwd[-1][0] == "d_out"
    assert [r["shape"] for r in
            [dict(name=n, **s) for n, s in sigs["block_bwd"]["results"]]] == [
        s["shape"] for _, s in fwd
    ]


def test_manifest_matches_signatures(smoke_artifacts):
    with open(os.path.join(smoke_artifacts, "manifest.json")) as f:
        man = json.load(f)
    sigs = aot.stage_signatures(SMOKE)
    assert set(man["stages"]) == set(sigs)
    for name, st in man["stages"].items():
        assert [a["name"] for a in st["args"]] == [n for n, _ in sigs[name]["args"]]
        assert [a["shape"] for a in st["args"]] == [
            s["shape"] for _, s in sigs[name]["args"]]
        path = os.path.join(smoke_artifacts, st["file"])
        assert os.path.exists(path)
    assert man["config"]["param_count"] == SMOKE.param_count()
    assert man["block_weight_names"] == list(model.BLOCK_WEIGHT_NAMES)


def test_hlo_text_is_parseable_format(smoke_artifacts):
    """HLO text (not proto): must start with 'HloModule' for the rust parser."""
    with open(os.path.join(smoke_artifacts, "manifest.json")) as f:
        man = json.load(f)
    for st in man["stages"].values():
        with open(os.path.join(smoke_artifacts, st["file"])) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), st["file"]


def test_all_configs_have_consistent_chunking():
    for cfg in CONFIGS.values():
        assert cfg.chunk % min(cfg.chunk, 1 << 16) == 0
        assert cfg.hidden % cfg.heads == 0
        assert cfg.heads % cfg.kv_heads == 0
        assert (cfg.hidden // cfg.heads) % 2 == 0  # RoPE needs even head_dim
