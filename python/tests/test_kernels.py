"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes/special-value placements; every test
asserts allclose (or exact equality for the boolean overflow verdict)
against `compile.kernels.ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    cross_entropy_loss,
    fused_adam_step,
    fused_cross_entropy,
    fused_overflow_check,
    fused_rmsnorm,
    rmsnorm,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------- overflow

@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 8),
    block=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
    special=st.sampled_from([None, "inf", "-inf", "nan"]),
)
def test_overflow_matches_ref_f32(blocks, block, seed, special):
    n = blocks * block
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    if special is not None:
        pos = rng.integers(0, n)
        x[pos] = {"inf": np.inf, "-inf": -np.inf, "nan": np.nan}[special]
    xj = jnp.asarray(x)
    got = int(fused_overflow_check(xj, block=block)[0])
    want = int(ref.overflow_check_ref(xj)[0])
    assert got == want == (0 if special is None else 1)


@pytest.mark.parametrize("dtype,make", [
    (jnp.float16, np.float16),
    (jnp.bfloat16, None),
])
def test_overflow_half_precision(dtype, make):
    x = jnp.zeros((256,), dtype).at[17].set(jnp.inf)
    assert int(fused_overflow_check(x, block=64)[0]) == 1
    x = jnp.zeros((256,), dtype).at[200].set(jnp.nan)
    assert int(fused_overflow_check(x, block=64)[0]) == 1
    x = jnp.full((256,), 2.5, dtype)
    assert int(fused_overflow_check(x, block=64)[0]) == 0


def test_overflow_rejects_misaligned_length():
    with pytest.raises(ValueError):
        fused_overflow_check(jnp.zeros((100,)), block=64)


def test_overflow_extreme_finite_values_not_flagged():
    # Largest finite f32: exponent is all-ones minus one — must NOT flag.
    x = jnp.full((128,), np.finfo(np.float32).max, jnp.float32)
    assert int(fused_overflow_check(x, block=64)[0]) == 0
    x = jnp.full((128,), np.finfo(np.float32).tiny, jnp.float32)
    assert int(fused_overflow_check(x, block=64)[0]) == 0


# ---------------------------------------------------------------- adam

@settings(**SETTINGS)
@given(
    n_blocks=st.integers(1, 4),
    step=st.integers(1, 500),
    seed=st.integers(0, 1000),
    wd=st.sampled_from([0.0, 0.01, 0.1]),
    lr=st.sampled_from([1e-4, 1e-3]),
)
def test_adam_matches_ref(n_blocks, step, seed, wd, lr):
    n = n_blocks * 128
    p, g, m = (_rand(seed + i, (n,)) for i in range(3))
    v = jnp.abs(_rand(seed + 3, (n,)))
    bc = jnp.array([1 - 0.9**step, 1 - 0.999**step], jnp.float32)
    po, mo, vo = fused_adam_step(
        p, g, m, v, bc, lr=lr, weight_decay=wd, block=128)
    pr, mr, vr = ref.adam_step_ref(p, g, m, v, step, lr=lr, weight_decay=wd)
    np.testing.assert_allclose(po, pr, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(mo, mr, rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(vo, vr, rtol=1e-6, atol=1e-8)


def test_adam_zero_padding_is_inert():
    """Tail-chunk padding contract: p=g=m=v=0 stays exactly 0."""
    z = jnp.zeros((128,), jnp.float32)
    bc = jnp.array([1 - 0.9, 1 - 0.999], jnp.float32)
    po, mo, vo = fused_adam_step(z, z, z, z, bc, lr=1e-3,
                                 weight_decay=0.01, block=128)
    assert not po.any() and not mo.any() and not vo.any()


# ---------------------------------------------------------------- cross entropy

@settings(**SETTINGS)
@given(
    t=st.integers(1, 16),
    v=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 1000),
    scale=st.sampled_from([1.0, 10.0]),
)
def test_cross_entropy_matches_ref(t, v, seed, scale):
    logits = _rand(seed, (t, v), scale=scale)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (t,), 0, v)
    lo, dl = fused_cross_entropy(logits, labels)
    lr_, dr = ref.cross_entropy_ref(logits, labels)
    np.testing.assert_allclose(lo, lr_, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dl, dr, rtol=1e-4, atol=1e-5)


def test_cross_entropy_vjp_grad():
    logits = _rand(0, (8, 64))
    labels = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, 64)
    gk = jax.grad(lambda x: cross_entropy_loss(x, labels))(logits)
    gr = jax.grad(
        lambda x: jnp.mean(ref.cross_entropy_ref(x, labels)[0]))(logits)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-6)


def test_cross_entropy_perfect_prediction_near_zero_loss():
    v = 32
    labels = jnp.arange(4) % v
    logits = jnp.zeros((4, v)).at[jnp.arange(4), labels].set(50.0)
    lo, _ = fused_cross_entropy(logits, labels)
    assert float(jnp.max(lo)) < 1e-4


# ---------------------------------------------------------------- rmsnorm

@settings(**SETTINGS)
@given(
    t=st.integers(1, 16),
    h=st.sampled_from([16, 32, 96]),
    seed=st.integers(0, 1000),
)
def test_rmsnorm_matches_ref(t, h, seed):
    x = _rand(seed, (t, h))
    w = _rand(seed + 1, (h,))
    np.testing.assert_allclose(
        fused_rmsnorm(x, w), ref.rmsnorm_ref(x, w), rtol=1e-5, atol=1e-6)


def test_rmsnorm_custom_vjp_matches_autodiff():
    x = _rand(3, (6, 32))
    w = _rand(4, (32,))
    f_fused = lambda x, w: jnp.sum(jnp.sin(rmsnorm(x, w)))
    f_ref = lambda x, w: jnp.sum(jnp.sin(ref.rmsnorm_ref(x, w)))
    g1 = jax.grad(f_fused, argnums=(0, 1))(x, w)
    g2 = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-4, atol=1e-5)


def test_rmsnorm_scale_invariance_property():
    """RMSNorm(c*x) == RMSNorm(x) for c>0 (up to eps effects)."""
    x = _rand(5, (4, 64), scale=3.0)
    w = jnp.ones((64,))
    a = fused_rmsnorm(x, w)
    b = fused_rmsnorm(100.0 * x, w)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
