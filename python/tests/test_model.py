"""L2 model correctness: shapes, gradients, invariants on the smoke config."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import SMOKE


def _init_block(cfg, seed=0):
    shapes = model.block_weight_shapes(cfg)
    ks = jax.random.split(jax.random.PRNGKey(seed), len(model.BLOCK_WEIGHT_NAMES))
    ws = []
    for k, name in zip(ks, model.BLOCK_WEIGHT_NAMES):
        s = shapes[name]
        if len(s) == 1:
            ws.append(jnp.ones(s, jnp.float32))
        else:
            ws.append(jax.random.normal(k, s) * (0.4 / np.sqrt(s[0])))
    return ws


def _tokens(cfg, seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (cfg.batch, cfg.seq), 0, cfg.vocab)


class TestBlock:
    def test_fwd_shape(self):
        cfg = SMOKE
        h = jax.random.normal(jax.random.PRNGKey(1),
                              (cfg.batch, cfg.seq, cfg.hidden))
        out = model.block_fwd(cfg, h, *_init_block(cfg))
        assert out.shape == h.shape
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_causality(self):
        """Changing token t must not affect outputs at positions < t."""
        cfg = SMOKE
        h = jax.random.normal(jax.random.PRNGKey(1),
                              (1, cfg.seq, cfg.hidden))
        ws = _init_block(cfg)
        base = model.block_fwd(cfg, h, *ws)
        t = cfg.seq // 2
        h2 = h.at[0, t:].set(jax.random.normal(jax.random.PRNGKey(2),
                                               (cfg.seq - t, cfg.hidden)))
        pert = model.block_fwd(cfg, h2, *ws)
        np.testing.assert_allclose(base[0, :t], pert[0, :t], rtol=1e-5, atol=1e-6)
        assert not np.allclose(base[0, t:], pert[0, t:])

    def test_bwd_matches_autodiff(self):
        cfg = SMOKE
        h = jax.random.normal(jax.random.PRNGKey(1),
                              (cfg.batch, cfg.seq, cfg.hidden))
        ws = _init_block(cfg)
        dout = jax.random.normal(jax.random.PRNGKey(2), h.shape)
        grads = model.block_bwd(cfg, h, *ws, dout)
        assert len(grads) == 1 + len(ws)
        # finite-difference check on a scalar projection wrt h
        f = lambda hh: jnp.vdot(model.block_fwd(cfg, hh, *ws), dout)
        eps = 1e-3
        d = jax.random.normal(jax.random.PRNGKey(3), h.shape)
        fd = (f(h + eps * d) - f(h - eps * d)) / (2 * eps)
        an = jnp.vdot(grads[0], d)
        np.testing.assert_allclose(fd, an, rtol=2e-2, atol=1e-2)

    def test_gqa_heads(self):
        cfg = SMOKE
        import dataclasses
        gqa = dataclasses.replace(cfg, kv_heads=1)
        h = jax.random.normal(jax.random.PRNGKey(1),
                              (gqa.batch, gqa.seq, gqa.hidden))
        out = model.block_fwd(gqa, h, *_init_block(gqa))
        assert out.shape == h.shape


class TestEmbedHead:
    def test_embed_roundtrip_grad(self):
        cfg = SMOKE
        tok = _tokens(cfg)
        table = jax.random.normal(jax.random.PRNGKey(0),
                                  (cfg.vocab, cfg.hidden))
        h = model.embed_fwd(tok, table)
        assert h.shape == (cfg.batch, cfg.seq, cfg.hidden)
        dh = jnp.ones_like(h)
        dtable = model.embed_bwd(cfg, tok, dh)
        # each token occurrence contributes its upstream gradient row
        counts = np.zeros(cfg.vocab)
        for t in np.asarray(tok).flatten():
            counts[t] += 1
        np.testing.assert_allclose(np.asarray(dtable)[:, 0], counts, atol=1e-5)

    def test_head_loss_scale_propagates_to_grads_not_loss(self):
        cfg = SMOKE
        h = jax.random.normal(jax.random.PRNGKey(0),
                              (cfg.batch, cfg.seq, cfg.hidden))
        nw = jnp.ones((cfg.hidden,))
        wh = jax.random.normal(jax.random.PRNGKey(1),
                               (cfg.hidden, cfg.vocab)) * 0.05
        lbl = _tokens(cfg, 7)
        one = jnp.ones((1,), jnp.float32)
        k = jnp.full((1,), 1024.0, jnp.float32)
        l1, dh1, dn1, dw1 = model.head_fwd_bwd(cfg, h, nw, wh, lbl, one)
        l2, dh2, dn2, dw2 = model.head_fwd_bwd(cfg, h, nw, wh, lbl, k)
        np.testing.assert_allclose(l1, l2, rtol=1e-6)
        np.testing.assert_allclose(dh2, 1024.0 * dh1, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(dw2, 1024.0 * dw1, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(dn2, 1024.0 * dn1, rtol=1e-4, atol=1e-6)

    def test_uniform_logits_loss_is_log_vocab(self):
        cfg = SMOKE
        h = jnp.zeros((cfg.batch, cfg.seq, cfg.hidden))
        nw = jnp.ones((cfg.hidden,))
        wh = jnp.zeros((cfg.hidden, cfg.vocab))
        lbl = _tokens(cfg, 3)
        loss, *_ = model.head_fwd_bwd(
            cfg, h, nw, wh, lbl, jnp.ones((1,), jnp.float32))
        np.testing.assert_allclose(loss[0], np.log(cfg.vocab), rtol=1e-5)


class TestFullModel:
    def test_staged_equals_full_forward(self):
        """Layer-streamed staging must equal the monolithic forward."""
        cfg = SMOKE
        tok = _tokens(cfg)
        lbl = _tokens(cfg, 1)
        table = jax.random.normal(jax.random.PRNGKey(0),
                                  (cfg.vocab, cfg.hidden)) * 0.1
        blocks = [_init_block(cfg, seed=i) for i in range(cfg.layers)]
        nw = jnp.ones((cfg.hidden,))
        wh = jax.random.normal(jax.random.PRNGKey(99),
                               (cfg.hidden, cfg.vocab)) * 0.05
        # staged (what the rust coordinator does)
        h = model.embed_fwd(tok, table)
        for ws in blocks:
            h = model.block_fwd(cfg, h, *ws)
        staged_loss, *_ = model.head_fwd_bwd(
            cfg, h, nw, wh, lbl, jnp.ones((1,), jnp.float32))
        # monolithic
        full = model.full_forward_loss(cfg, tok, lbl, (table, blocks, nw, wh))
        np.testing.assert_allclose(staged_loss[0], full, rtol=1e-5)

    def test_param_count_formula(self):
        cfg = SMOKE
        shapes = model.block_weight_shapes(cfg)
        per_block = sum(int(np.prod(s)) for s in shapes.values())
        total = (cfg.vocab * cfg.hidden + cfg.layers * per_block
                 + cfg.hidden + cfg.hidden * cfg.vocab)
        assert total == cfg.param_count()
