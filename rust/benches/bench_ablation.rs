//! Ablation sweep (DESIGN.md §5): every combination of the four
//! MemAscend components → peak sysmem + projected step time for
//! Qwen2.5-7B, isolating each component's contribution (Fig. 8's
//! narrative, quantified per flag).

mod common;

use memascend::accounting::perfmodel::{step_time, Calib};
use memascend::accounting::sysmem::peak_sysmem;
use memascend::config::hardware::CONFIG2;
use memascend::config::presets::QWEN25_7B;
use memascend::config::MemAscendFlags;
use memascend::util::bench::Table;

fn main() {
    let calib = Calib::default();
    let mut t = Table::new(vec![
        "pool", "align", "fused", "nvme", "peak sysmem (GiB)", "step time (s)", "label",
    ]);
    let mut rows: Vec<(f64, f64, MemAscendFlags)> = MemAscendFlags::all_combinations()
        .into_iter()
        .map(|f| {
            let s = common::eval_spec(f);
            let mem = peak_sysmem(&QWEN25_7B, &s, &CONFIG2).peak_total as f64
                / (1u64 << 30) as f64;
            let st = step_time(&QWEN25_7B, &s, &CONFIG2, &calib).total();
            (mem, st, f)
        })
        .collect();
    rows.sort_by(|a, b| a.0.total_cmp(&b.0));
    for (mem, st, f) in &rows {
        t.row(vec![
            u8::from(f.adaptive_pool).to_string(),
            u8::from(f.alignment_free).to_string(),
            u8::from(f.fused_overflow).to_string(),
            u8::from(f.direct_nvme).to_string(),
            format!("{mem:.2}"),
            format!("{st:.2}"),
            f.label(),
        ]);
    }
    common::emit("ablation", "all 16 component combinations (Qwen2.5-7B, C2)", &t);

    // single-component deltas vs baseline
    let base_mem = rows
        .iter()
        .find(|(_, _, f)| *f == MemAscendFlags::baseline())
        .unwrap()
        .0;
    println!("single-component memory savings vs baseline ({base_mem:.1} GiB):");
    for (name, f) in [
        ("adaptive_pool", MemAscendFlags { adaptive_pool: true, ..MemAscendFlags::baseline() }),
        ("alignment_free", MemAscendFlags { alignment_free: true, ..MemAscendFlags::baseline() }),
        ("fused_overflow", MemAscendFlags { fused_overflow: true, ..MemAscendFlags::baseline() }),
        ("direct_nvme", MemAscendFlags { direct_nvme: true, ..MemAscendFlags::baseline() }),
    ] {
        let mem = rows.iter().find(|(_, _, g)| *g == f).unwrap().0;
        println!("  {name:<16} -{:.1} GiB", base_mem - mem);
    }
}
