//! §Perf micro-bench: CPU AdamW per-element cost (feeds perfmodel's
//! c_adam calibration).
fn main() {
    let n = 1 << 22;
    let mut rng = memascend::util::rng::Xoshiro256::new(1);
    let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut m = vec![0f32; n];
    let mut v = vec![0f32; n];
    let hp = memascend::optimizer::AdamParams::default();
    let mut t = 0u64;
    let s = memascend::util::bench::bench_n(2, 10, || {
        t += 1;
        memascend::optimizer::adam_step_f32(&mut p, &g, &mut m, &mut v, t, 1024.0, &hp, 1);
    });
    println!("adam 4Mi elems: {} ({:.2} ns/elem)", s, s.mean_secs() / n as f64 * 1e9);
}
