//! Arena fragmentation + budget bench: the memory-trajectory tracker
//! behind the unified pinned-memory arena.
//!
//! Emits `bench_out/BENCH_arena.json` with, per paper model, the
//! monolithic-vs-adaptive pool demand (peak_requested vs pool_bytes,
//! Fig. 11's axis) and the arena's own reserved/requested watermarks
//! and fragmentation, plus two behavioural proofs future PRs can
//! regress against:
//!
//! - budget enforcement: a cap below pool demand yields a structured
//!   `ArenaError::BudgetExceeded`, never an abort;
//! - shape-class recycling: rebuilding the same pool on a warm arena
//!   pins zero fresh segments — every class region is recycled.

mod common;

use std::sync::Arc;

use memascend::bufpool::{AdaptivePool, MonolithicPool, ParamBufferPool};
use memascend::config::presets::{PAPER_DENSE, QWEN3_30B_A3B};
use memascend::dtype::DType;
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, Cat, MemoryTracker, Mode, PinnedArena,
};
use memascend::util::bench::Table;
use memascend::util::json::Json;

fn arena(budget: Option<usize>) -> Arc<PinnedArena> {
    let alloc = AlignedAllocator::new(Mode::Virtual, Arc::new(MemoryTracker::new()));
    PinnedArena::new(
        Arc::new(alloc),
        ArenaConfig { budget_bytes: budget, ..Default::default() },
    )
}

fn main() {
    let mut table = Table::new(vec![
        "model",
        "mono pool (GiB)",
        "adaptive pool (GiB)",
        "arena reserved (GiB)",
        "peak requested (GiB)",
        "peak frag %",
    ]);
    let mut models = Vec::new();
    let all: Vec<_> = PAPER_DENSE.iter().copied().chain([&QWEN3_30B_A3B]).collect();
    for m in &all {
        // separate arenas so each pool's backing is measured clean
        let mono = MonolithicPool::new(m, 1, DType::F16, &arena(None)).unwrap();
        let mono_bytes = mono.stats().pool_bytes;
        let a = arena(None);
        let adap = AdaptivePool::new(m, 1, DType::F16, &a).unwrap();
        let adap_bytes = adap.stats().pool_bytes;
        let st = a.stats();
        table.row(vec![
            m.name.to_string(),
            common::gib(mono_bytes as u64),
            common::gib(adap_bytes as u64),
            common::gib(st.reserved_bytes as u64),
            common::gib(st.peak_requested as u64),
            format!("{:.1}", st.peak_fragmentation() * 100.0),
        ]);
        models.push(Json::obj(vec![
            ("model", Json::from(m.name)),
            ("mono_pool_bytes", Json::from(mono_bytes)),
            ("adaptive_pool_bytes", Json::from(adap_bytes)),
            ("pool_reduction", Json::from(1.0 - adap_bytes as f64 / mono_bytes as f64)),
            ("arena_reserved_bytes", Json::from(st.reserved_bytes)),
            ("arena_peak_requested_bytes", Json::from(st.peak_requested)),
            ("arena_peak_fragmentation", Json::from(st.peak_fragmentation())),
        ]));
    }

    // --- budget enforcement: cap below demand → structured error ---
    let q7 = PAPER_DENSE[0];
    let need = {
        let a = arena(None);
        let p = AdaptivePool::new(q7, 1, DType::F16, &a).unwrap();
        p.stats().pool_bytes
    };
    let capped = arena(Some(need / 2));
    let refusal = AdaptivePool::new(q7, 1, DType::F16, &capped);
    let budget_enforced = match &refusal {
        Err(e) => e.to_string().contains("pinned budget exceeded"),
        Ok(_) => false,
    };
    println!(
        "budget: cap {} below demand {} -> structured refusal: {budget_enforced}",
        need / 2,
        need
    );

    // --- shape-class recycling on a warm arena ---
    let warm = arena(None);
    let p1 = AdaptivePool::new(q7, 1, DType::F16, &warm).unwrap();
    drop(p1);
    let fresh_before = warm.stats().fresh_segments;
    let _p2 = AdaptivePool::new(q7, 1, DType::F16, &warm).unwrap();
    let st = warm.stats();
    let recycled_all = st.fresh_segments == fresh_before && st.recycled > 0;
    println!(
        "recycle: rebuild on warm arena pinned {} fresh segments ({} recycled leases)",
        st.fresh_segments - fresh_before,
        st.recycled
    );
    let param_wm = warm.watermark(Cat::ParamPool);
    println!(
        "warm-arena ParamPool watermark: charged {} B for requested {} B",
        param_wm.charged, param_wm.requested
    );

    common::emit("arena", "unified pinned-memory arena: demand vs backing", &table);
    std::fs::create_dir_all(common::OUT_DIR).ok();
    let out = Json::obj(vec![
        ("models", Json::Arr(models)),
        ("budget_enforced", Json::from(budget_enforced)),
        ("warm_rebuild_recycles_all", Json::from(recycled_all)),
    ]);
    let path = format!("{}/BENCH_arena.json", common::OUT_DIR);
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("[json] {path}"),
        Err(e) => eprintln!("warn: could not write {path}: {e}"),
    }

    let pass = budget_enforced && recycled_all;
    println!("ACCEPTANCE: {}", if pass { "PASS" } else { "FAIL" });
    if !pass {
        std::process::exit(1);
    }
}
