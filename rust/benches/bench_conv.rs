fn main() {
    let mut rng = memascend::util::rng::Xoshiro256::new(1);
    let src: Vec<f32> = (0..(1<<22)).map(|_| rng.normal() as f32).collect();
    let mut bytes = vec![0u8; src.len()*2];
    memascend::dtype::f32s_to_f16_bytes(&src, &mut bytes);
    let mut dst = vec![0f32; src.len()];
    let s = memascend::util::bench::bench_n(2, 10, || {
        memascend::dtype::f16_bytes_to_f32s(std::hint::black_box(&bytes), &mut dst);
    });
    println!("f16->f32 4Mi elems: {}", s);
    let s2 = memascend::util::bench::bench_n(2, 10, || {
        memascend::dtype::f32s_to_f16_bytes(std::hint::black_box(&src), &mut bytes);
    });
    println!("f32->f16 4Mi elems: {}", s2);
}
