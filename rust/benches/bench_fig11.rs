//! Fig. 11 — parameter-buffer-pool memory: monolithic (ZeRO-Infinity)
//! vs adaptive (MemAscend) across models, built with the *real* pool
//! constructors (paper: avg 72.71% reduction; Qwen14B == Qwen32B under
//! the baseline because both share the embedding size).

mod common;

use std::sync::Arc;

use memascend::bufpool::{AdaptivePool, MonolithicPool, ParamBufferPool};
use memascend::config::presets::{PAPER_DENSE, QWEN3_30B_A3B};
use memascend::dtype::DType;
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, MemoryTracker, Mode, PinnedArena,
};
use memascend::util::bench::Table;

fn arena() -> Arc<PinnedArena> {
    let alloc = AlignedAllocator::new(Mode::Virtual, Arc::new(MemoryTracker::new()));
    PinnedArena::new(Arc::new(alloc), ArenaConfig::default())
}

fn main() {
    let mut t = Table::new(vec![
        "model",
        "monolithic (GiB)",
        "adaptive (GiB)",
        "reduction %",
    ]);
    let mut reds = Vec::new();
    let all: Vec<_> = PAPER_DENSE.iter().copied().chain([&QWEN3_30B_A3B]).collect();
    for m in all {
        let a = arena();
        let mono = MonolithicPool::new(m, 1, DType::F16, &a).unwrap();
        let adap = AdaptivePool::new(m, 1, DType::F16, &a).unwrap();
        let mb = mono.stats().pool_bytes as u64;
        let ab = adap.stats().pool_bytes as u64;
        let red = (1.0 - ab as f64 / mb as f64) * 100.0;
        if !m.is_moe() {
            reds.push(red);
        }
        t.row(vec![
            m.name.to_string(),
            common::gib(mb),
            common::gib(ab),
            format!("{red:.1}"),
        ]);
    }
    common::emit("fig11", "parameter buffer pool memory", &t);
    println!(
        "avg dense reduction: {:.1}% (paper: 72.71%)",
        reds.iter().sum::<f64>() / reds.len() as f64
    );

    // paper's anomaly: Qwen14B and Qwen32B identical under baseline
    let a = arena();
    let p14 = MonolithicPool::new(
        memascend::config::ModelSpec::by_name("qwen2.5-14b").unwrap(),
        1,
        DType::F16,
        &a,
    )
    .unwrap();
    let p32 = MonolithicPool::new(
        memascend::config::ModelSpec::by_name("qwen2.5-32b").unwrap(),
        1,
        DType::F16,
        &a,
    )
    .unwrap();
    println!(
        "qwen14b monolithic == qwen32b monolithic: {} (paper: identical, both bounded by the embedding)",
        p14.stats().pool_bytes == p32.stats().pool_bytes
    );
}
