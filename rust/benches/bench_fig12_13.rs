//! Fig. 12 — overflow-check latency: baseline chain vs fused, measured
//! for real on this machine across buffer sizes, then projected to the
//! paper's model sizes and both CPU configs (paper: avg 97% reduction).
//! Fig. 13 — overflow-check memory overhead (2.25x spike vs none).
//! Fig. 3  — tensor-lifetime timeline CSV during the baseline check.

mod common;

use std::sync::Arc;
use std::time::Duration;

use memascend::config::hardware::{CONFIG1, CONFIG2};
use memascend::overflow::{baseline_overflow_check, fused_overflow_check};
use memascend::pinned::{Cat, MemoryTracker};
use memascend::util::bench::{bench_n, black_box, Table};
use memascend::util::human;

fn main() {
    // ---------- real measurement across sizes (this machine) ----------
    let sizes: &[usize] = &[1 << 20, 1 << 22, 1 << 24, 1 << 26];
    let mut t = Table::new(vec![
        "elements",
        "baseline mean",
        "fused mean",
        "reduction %",
    ]);
    // per-element costs from the largest size (steady state)
    let mut c_base = 0.0f64;
    let mut c_fused = 0.0f64;
    for &n in sizes {
        let grads = vec![0.5f32; n];
        let tracker = Arc::new(MemoryTracker::new());
        let iters = if n >= 1 << 26 { 3 } else { 6 };
        let sb = bench_n(1, iters, || {
            black_box(baseline_overflow_check(black_box(&grads), &tracker));
        });
        let sf = bench_n(1, iters, || {
            black_box(fused_overflow_check(black_box(&grads), 1));
        });
        let red = (1.0 - sf.mean_secs() / sb.mean_secs()) * 100.0;
        c_base = sb.mean_secs() / n as f64;
        c_fused = sf.mean_secs() / n as f64;
        t.row(vec![
            n.to_string(),
            human::secs(sb.mean_secs()),
            human::secs(sf.mean_secs()),
            format!("{red:.1}"),
        ]);
    }
    common::emit("fig12_local", "overflow check latency (measured, this CPU)", &t);

    // ---------- projection to paper scale (Fig. 12a/b) ----------
    // local single core ~= cpu_rel 0.5 of the paper's C1 reference core;
    // the baseline torch chain is single-threaded, the fused check is
    // OpenMP-parallel (~97% efficiency, paper §IV-D).
    let mut tp = Table::new(vec![
        "config",
        "model params",
        "baseline (ms)",
        "fused (ms)",
        "reduction %",
        "paper",
    ]);
    let paper_c1_8b = "5507 ms baseline, ~97% cut";
    for (hw, label) in [(&CONFIG1, "config1"), (&CONFIG2, "config2")] {
        for p in [1.0e9, 8.0e9, 14.0e9, 32.0e9] {
            let threads = (hw.cpu_threads as f64 * 0.25).max(1.0);
            let base_ms = p * c_base / (hw.cpu_rel / 0.5) * 1e3;
            let fused_ms =
                p * c_fused / (hw.cpu_rel / 0.5) / (threads * 0.97) * 1e3;
            tp.row(vec![
                label.to_string(),
                format!("{:.0}B", p / 1e9),
                format!("{base_ms:.0}"),
                format!("{fused_ms:.2}"),
                format!("{:.1}", (1.0 - fused_ms / base_ms) * 100.0),
                if p == 8.0e9 && label == "config1" {
                    paper_c1_8b.to_string()
                } else {
                    String::new()
                },
            ]);
        }
    }
    common::emit("fig12_projected", "overflow check latency (projected)", &tp);

    // ---------- Fig. 13: memory overhead ----------
    let n = 1 << 24; // 64 MiB flat buffer
    let grads = vec![0.5f32; n];
    let tracker = Arc::new(MemoryTracker::new());
    tracker.alloc(Cat::GradFlat, (n * 4) as u64);
    baseline_overflow_check(&grads, &tracker);
    let base_overhead = tracker.peak(Cat::OverflowTemp);
    let tracker2 = Arc::new(MemoryTracker::new());
    tracker2.alloc(Cat::GradFlat, (n * 4) as u64);
    fused_overflow_check(&grads, 1);
    let fused_overhead = tracker2.peak(Cat::OverflowTemp);
    let mut tm = Table::new(vec!["method", "flat buffer", "check overhead", "peak ratio"]);
    tm.row(vec![
        "zero-infinity".to_string(),
        human::bytes((n * 4) as u64),
        human::bytes(base_overhead),
        format!("{:.2}x (paper: 2.25x)", tracker.peak_total() as f64 / (n as f64 * 4.0)),
    ]);
    tm.row(vec![
        "memascend".to_string(),
        human::bytes((n * 4) as u64),
        human::bytes(fused_overhead),
        "1.00x (paper: 1.0x)".to_string(),
    ]);
    common::emit("fig13", "overflow check memory overhead", &tm);

    // ---------- Fig. 3: lifetime timeline ----------
    let tl_tracker = Arc::new(MemoryTracker::with_timeline());
    let small = vec![0.5f32; 1 << 16];
    tl_tracker.alloc(Cat::GradFlat, (small.len() * 4) as u64);
    baseline_overflow_check(&small, &tl_tracker);
    let mut t3 = Table::new(vec!["event", "category", "delta (B)", "total after (B)"]);
    for e in tl_tracker.timeline() {
        t3.row(vec![
            e.t.to_string(),
            e.cat.name().to_string(),
            e.delta.to_string(),
            e.total_after.to_string(),
        ]);
    }
    common::emit("fig3_timeline", "tensor lifetimes during the baseline check", &t3);
    let _ = Duration::ZERO;
}
