//! Fig. 14 — NVMe read/write latency & bandwidth vs tensor size,
//! filesystem baseline vs direct engine.
//!
//! Two parts: (1) a *real* head-to-head of the two engines on this
//! container's storage (ordering + small-transfer overhead gap are
//! real); (2) the analytic device model at the paper's Configuration-2
//! scale, which supplies the device physics (SLC-cache destaging, 4.5x
//! write-bandwidth gap) that container storage cannot show.

mod common;

use memascend::config::hardware::CONFIG2;
use memascend::ssd::{DeviceModel, DirectEngine, FsEngine, NvmeEngine};
use memascend::util::bench::Table;
use memascend::util::human;

fn measure(eng: &dyn NvmeEngine, key: &str, data: &[u8], iters: usize) -> (f64, f64) {
    // returns (write_secs, read_secs) means
    let mut w = 0.0;
    let mut r = 0.0;
    let mut out = vec![0u8; data.len()];
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        eng.write(key, data).unwrap();
        w += t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        eng.read(key, &mut out).unwrap();
        r += t1.elapsed().as_secs_f64();
    }
    (w / iters as f64, r / iters as f64)
}

fn main() {
    // ---------- real engines on this container ----------
    let root = std::env::temp_dir().join(format!("ma-fig14-{}", std::process::id()));
    std::fs::create_dir_all(&root).unwrap();
    let fs = FsEngine::new(&root.join("fs"), 2, 512 << 10).unwrap();
    let direct = DirectEngine::new(&root.join("d"), 2, 1 << 30, 1).unwrap();
    let sizes: &[usize] = &[1 << 21, 1 << 23, 1 << 25, 1 << 27];
    let mut t = Table::new(vec![
        "bytes",
        "fs write",
        "direct write",
        "fs read",
        "direct read",
        "write speedup",
    ]);
    for &n in sizes {
        let data: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
        let iters = if n >= 1 << 25 { 3 } else { 8 };
        let (fw, fr) = measure(&fs, &format!("t{n}"), &data, iters);
        let (dw, dr) = measure(&direct, &format!("t{n}"), &data, iters);
        t.row(vec![
            n.to_string(),
            human::secs(fw),
            human::secs(dw),
            human::secs(fr),
            human::secs(dr),
            format!("{:.2}x", fw / dw),
        ]);
    }
    common::emit("fig14_local", "engine head-to-head (real, container storage)", &t);

    // ---------- device-model projection at paper scale ----------
    let m = DeviceModel::new(&CONFIG2);
    let paper_sizes: &[u64] = &[
        2_097_152,       // the paper's small write example
        16 << 20,
        128 << 20,
        1 << 30,
        3_114_270_720,   // the paper's large write example
    ];
    let mut tp = Table::new(vec![
        "bytes",
        "fs write lat",
        "direct write lat",
        "fs write BW",
        "direct write BW",
        "paper",
    ]);
    for &n in paper_sizes {
        let fl = m.fs_write_lat(n, false);
        let dl = m.direct_write_lat(n);
        let note = match n {
            2_097_152 => "988us vs 219us",
            3_114_270_720 => "304.6ms vs 266.2ms",
            _ => "",
        };
        tp.row(vec![
            n.to_string(),
            human::secs(fl),
            human::secs(dl),
            human::rate(n as f64 / fl),
            human::rate(n as f64 / dl),
            note.to_string(),
        ]);
    }
    common::emit("fig14_model", "write path at Configuration-2 scale (device model)", &tp);

    let mut tr = Table::new(vec!["bytes", "fs read BW", "direct read BW"]);
    for &n in paper_sizes {
        tr.row(vec![
            n.to_string(),
            human::rate(n as f64 / m.fs_read_lat(n)),
            human::rate(n as f64 / m.direct_read_lat(n)),
        ]);
    }
    common::emit("fig14_model_read", "read path (device model; paper: comparable means, lower variance for direct)", &tr);

    // paper's headline: avg write-BW gain
    let gains: Vec<f64> = paper_sizes
        .iter()
        .map(|&n| (n as f64 / m.direct_write_lat(n)) / (n as f64 / m.fs_write_lat(n, false)))
        .collect();
    println!(
        "write BW gain range {:.2}x..{:.2}x (paper: up to 4.5x, avg +72.04%)",
        gains.iter().cloned().fold(f64::MAX, f64::min),
        gains.iter().cloned().fold(0.0, f64::max)
    );
    std::fs::remove_dir_all(&root).ok();
}
