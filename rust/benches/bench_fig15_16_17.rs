//! Fig. 15 — end-to-end peak system memory across the four dense models
//! (paper: avg −55.7%).
//! Fig. 16 — peak memory vs context length, 4k→131k, 2 ranks
//! (paper: −41.65% Llama8B … −49.48% Qwen32B; 128 GiB cap ⇒ 16k vs 131k).
//! Fig. 17 — memory + projected throughput vs batch size at ctx 4096
//! (paper: avg −42.8% memory; near-linear throughput scaling).
//! Fig. 9/10 are the Qwen2.5-7B rows/columns of the same sweeps.

mod common;

use memascend::accounting::perfmodel::{step_time, Calib};
use memascend::accounting::sysmem::peak_sysmem;
use memascend::config::hardware::CONFIG1;
use memascend::config::presets::PAPER_DENSE;
use memascend::config::MemAscendFlags;
use memascend::util::bench::Table;

fn main() {
    let paper_fig15: &[(&str, f64, f64)] = &[
        ("llama3.1-8b", 91.06, 44.71),
        ("qwen2.5-7b", 109.06, 43.67),
        ("qwen2.5-14b", 174.5, 76.1),
        ("qwen2.5-32b", 322.3, 143.6),
    ];

    // ---------- Fig. 15 ----------
    let mut t = Table::new(vec![
        "model",
        "ZI paper",
        "ZI measured",
        "MA paper",
        "MA measured",
        "cut %",
    ]);
    let mut cuts = Vec::new();
    for (name, zp, mp) in paper_fig15 {
        let m = memascend::config::ModelSpec::by_name(name).unwrap();
        let z = peak_sysmem(m, &common::eval_spec(MemAscendFlags::baseline()), &CONFIG1);
        let a = peak_sysmem(m, &common::eval_spec(MemAscendFlags::memascend()), &CONFIG1);
        let cut = (1.0 - a.peak_total as f64 / z.peak_total as f64) * 100.0;
        cuts.push(cut);
        t.row(vec![
            name.to_string(),
            format!("{zp:.1}"),
            common::gib(z.peak_total),
            format!("{mp:.1}"),
            common::gib(a.peak_total),
            format!("{cut:.1}"),
        ]);
    }
    common::emit("fig15", "end-to-end peak system memory (GiB)", &t);
    println!(
        "avg cut {:.1}% (paper: 55.7%)",
        cuts.iter().sum::<f64>() / cuts.len() as f64
    );

    // ---------- Fig. 16 (and Fig. 9 = qwen2.5-7b row) ----------
    let ctxs: &[usize] = &[4096, 8192, 16384, 32768, 65536, 131072];
    let mut t16 = Table::new(vec![
        "model", "ctx", "ZI (GiB)", "MA (GiB)", "cut %", "fits 128GiB (ZI/MA)",
    ]);
    for m in PAPER_DENSE {
        let mut reds = Vec::new();
        for &c in ctxs {
            let mut zi = common::eval_spec(MemAscendFlags::baseline());
            zi.seq = c;
            zi.batch = 1;
            let mut ma = common::eval_spec(MemAscendFlags::memascend());
            ma.seq = c;
            ma.batch = 1;
            let z = peak_sysmem(m, &zi, &CONFIG1);
            let a = peak_sysmem(m, &ma, &CONFIG1);
            let cut = (1.0 - a.peak_total as f64 / z.peak_total as f64) * 100.0;
            reds.push(cut);
            t16.row(vec![
                m.name.to_string(),
                c.to_string(),
                common::gib(z.peak_total),
                common::gib(a.peak_total),
                format!("{cut:.1}"),
                format!(
                    "{}/{}",
                    if z.gib() <= 128.0 { "y" } else { "n" },
                    if a.gib() <= 128.0 { "y" } else { "n" }
                ),
            ]);
        }
        println!(
            "{}: avg ctx-sweep cut {:.1}%",
            m.name,
            reds.iter().sum::<f64>() / reds.len() as f64
        );
    }
    common::emit("fig16", "peak sysmem vs context (paper: -41.65%..-49.48%)", &t16);

    // ---------- Fig. 17 (and Fig. 10 = qwen2.5-7b row) ----------
    let batches: &[usize] = &[1, 2, 4, 8, 16, 32, 48];
    let calib = Calib::default();
    let mut t17 = Table::new(vec![
        "model", "batch", "ZI (GiB)", "MA (GiB)", "MA tokens/s (proj)",
    ]);
    for m in PAPER_DENSE {
        for &b in batches {
            let mut zi = common::eval_spec(MemAscendFlags::baseline());
            zi.batch = b;
            let mut ma = common::eval_spec(MemAscendFlags::memascend());
            ma.batch = b;
            let z = peak_sysmem(m, &zi, &CONFIG1);
            let a = peak_sysmem(m, &ma, &CONFIG1);
            let st = step_time(m, &ma, &CONFIG1, &calib);
            t17.row(vec![
                m.name.to_string(),
                b.to_string(),
                common::gib(z.peak_total),
                common::gib(a.peak_total),
                format!("{:.0}", st.tokens_per_sec(&ma)),
            ]);
        }
    }
    common::emit(
        "fig17",
        "memory + throughput vs batch (paper: -42.8% avg memory, near-linear tput)",
        &t17,
    );

    // paper Fig. 10 headline: under 128 GiB, ZI tops out at batch 4 vs
    // MA at 32 for Qwen2.5-7B
    let q7 = memascend::config::ModelSpec::by_name("qwen2.5-7b").unwrap();
    let max_batch = |flags: MemAscendFlags| {
        batches
            .iter()
            .rev()
            .find(|&&b| {
                let mut s = common::eval_spec(flags);
                s.batch = b;
                peak_sysmem(q7, &s, &CONFIG1).gib() <= 128.0
            })
            .copied()
            .unwrap_or(0)
    };
    println!(
        "max batch under 128 GiB: ZI={} MA={} (paper: 4 vs 32)",
        max_batch(MemAscendFlags::baseline()),
        max_batch(MemAscendFlags::memascend())
    );
}
