//! Fig. 18 — sparse MoE (Qwen3-30B-A3B, Configuration 3): context and
//! batch sweeps (paper: baseline 756.73→818.74 GiB vs MemAscend
//! 202.24→248.75 GiB; avg reductions 71.87% / 71.40% — the adaptive
//! pool's biggest win, because the baseline sizes every one of the
//! 3×128 expert buffers per block to the embedding).

mod common;

use memascend::accounting::perfmodel::{step_time, Calib};
use memascend::accounting::sysmem::peak_sysmem;
use memascend::config::hardware::CONFIG3;
use memascend::config::presets::QWEN3_30B_A3B;
use memascend::config::{MemAscendFlags, TrainSpec};
use memascend::util::bench::Table;

fn spec(flags: MemAscendFlags, batch: usize, seq: usize) -> TrainSpec {
    // untiled optimizer staging: paper-parity memory model
    TrainSpec {
        batch,
        seq,
        ranks: 2,
        prefetch_depth: 1,
        optim_tile_bytes: 0,
        flags,
        ..Default::default()
    }
}

fn main() {
    let m = &QWEN3_30B_A3B;

    // ---------- (a) context sweep at batch 1 ----------
    let mut ta = Table::new(vec!["ctx", "ZI (GiB)", "MA (GiB)", "cut %"]);
    let mut cuts = Vec::new();
    for &c in &[4096usize, 16384, 65536, 131072] {
        let z = peak_sysmem(m, &spec(MemAscendFlags::baseline(), 1, c), &CONFIG3);
        let a = peak_sysmem(m, &spec(MemAscendFlags::memascend(), 1, c), &CONFIG3);
        let cut = (1.0 - a.peak_total as f64 / z.peak_total as f64) * 100.0;
        cuts.push(cut);
        ta.row(vec![
            c.to_string(),
            common::gib(z.peak_total),
            common::gib(a.peak_total),
            format!("{cut:.1}"),
        ]);
    }
    common::emit(
        "fig18a",
        "MoE context sweep (paper: 756.73->818.74 vs 202.24->248.75 GiB, avg -71.87%)",
        &ta,
    );
    println!("avg ctx cut {:.1}% (paper 71.87%)", cuts.iter().sum::<f64>() / cuts.len() as f64);

    // ---------- (b) batch sweep at ctx 4096 ----------
    let calib = Calib::default();
    let mut tb = Table::new(vec!["batch", "ZI (GiB)", "MA (GiB)", "cut %", "MA tokens/s (proj)"]);
    let mut cuts_b = Vec::new();
    for &b in &[1usize, 2, 4, 8, 16] {
        let zi = spec(MemAscendFlags::baseline(), b, 4096);
        let ma = spec(MemAscendFlags::memascend(), b, 4096);
        let z = peak_sysmem(m, &zi, &CONFIG3);
        let a = peak_sysmem(m, &ma, &CONFIG3);
        let cut = (1.0 - a.peak_total as f64 / z.peak_total as f64) * 100.0;
        cuts_b.push(cut);
        let st = step_time(m, &ma, &CONFIG3, &calib);
        tb.row(vec![
            b.to_string(),
            common::gib(z.peak_total),
            common::gib(a.peak_total),
            format!("{cut:.1}"),
            format!("{:.0}", st.tokens_per_sec(&ma)),
        ]);
    }
    common::emit("fig18b", "MoE batch sweep (paper avg -71.40%)", &tb);
    println!("avg batch cut {:.1}% (paper 71.40%)", cuts_b.iter().sum::<f64>() / cuts_b.len() as f64);
}
