//! Fig. 19 — convergence parity: ZeRO-Infinity vs MemAscend loss
//! curves on a *real* training run through the full offload stack
//! (paper: identical trajectories on Qwen2.5-0.5B/OpenWebText; here:
//! bit-identical trajectories on the tiny model / synthetic corpus —
//! a strictly stronger check).
//!
//! The bench runs the smoke config for speed; `examples/finetune_e2e`
//! records the longer tiny-25M/100M curves for EXPERIMENTS.md.

mod common;

use std::path::Path;

use memascend::config::{MemAscendFlags, TrainSpec};
use memascend::train::{TrainOpts, Trainer};
use memascend::util::bench::Table;

fn run(flags: MemAscendFlags, steps: usize, tag: &str) -> memascend::metrics::RunReport {
    let artifacts = Path::new("artifacts/smoke");
    assert!(
        artifacts.join("manifest.json").exists(),
        "run `make artifacts` first"
    );
    let dir = std::env::temp_dir().join(format!("ma-f19-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spec = TrainSpec {
        batch: 2,
        seq: 16,
        flags,
        init_loss_scale: 1024.0,
        ..Default::default()
    };
    let opts = TrainOpts { steps, seed: 42, log_every: 0, loss_csv: None };
    let mut t = Trainer::new(artifacts, &dir, spec, &opts).unwrap();
    let r = t.run(&opts).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    r
}

fn main() {
    let steps = 30;
    let zi = run(MemAscendFlags::baseline(), steps, "zi");
    let ma = run(MemAscendFlags::memascend(), steps, "ma");
    let mut t = Table::new(vec!["step", "ZI loss", "MA loss", "bit-identical"]);
    let mut all_identical = true;
    for (a, b) in zi.steps.iter().zip(&ma.steps) {
        let ident = a.loss.to_bits() == b.loss.to_bits();
        all_identical &= ident;
        if a.step % 5 == 0 || !ident {
            t.row(vec![
                a.step.to_string(),
                format!("{:.6}", a.loss),
                format!("{:.6}", b.loss),
                ident.to_string(),
            ]);
        }
    }
    common::emit("fig19", "convergence parity (real training, full offload stack)", &t);
    println!(
        "loss decreased: {:.4} -> {:.4}; trajectories bit-identical: {all_identical} (paper: identical convergence)",
        zi.steps[0].loss,
        zi.mean_tail_loss(3)
    );
    assert!(all_identical, "parity violated!");
    assert!(zi.mean_tail_loss(3) < zi.steps[0].loss);
}
