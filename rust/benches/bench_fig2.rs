//! Fig. 2 — GPU memory vs residual-memory optimizations, 8B model,
//! batch 4, ctx 512 and 32768 (paper: log-scale bars; each added
//! optimization — GC, Liger/Flash, Offloaded-GC — cuts GPU memory, and
//! at 32k the unoptimized variants OOM any real GPU).

mod common;

use memascend::accounting::gpumem::{gpu_memory, GpuMemOpts, Placement};
use memascend::config::presets::LLAMA31_8B;
use memascend::config::TrainSpec;
use memascend::util::bench::Table;

fn main() {
    let variants: &[(&str, GpuMemOpts)] = &[
        (
            "none",
            GpuMemOpts {
                placement: Placement::ZeroInfinity,
                grad_ckpt: false,
                liger: false,
                flash: false,
                offloaded_gc: false,
            },
        ),
        (
            "GC",
            GpuMemOpts {
                placement: Placement::ZeroInfinity,
                grad_ckpt: true,
                liger: false,
                flash: false,
                offloaded_gc: false,
            },
        ),
        (
            "GC+Liger/Flash",
            GpuMemOpts {
                placement: Placement::ZeroInfinity,
                grad_ckpt: true,
                liger: true,
                flash: true,
                offloaded_gc: false,
            },
        ),
        (
            "GC+Liger/Flash+Offloaded-GC",
            GpuMemOpts {
                placement: Placement::ZeroInfinity,
                grad_ckpt: true,
                liger: true,
                flash: true,
                offloaded_gc: true,
            },
        ),
    ];
    let mut t = Table::new(vec![
        "optimizations",
        "ctx 512 (GiB)",
        "ctx 32768 (GiB)",
        "fits 80 GiB @32k",
    ]);
    for (name, opts) in variants {
        let short = TrainSpec { batch: 4, seq: 512, ..Default::default() };
        let long = TrainSpec { batch: 4, seq: 32768, ..Default::default() };
        let g_s = gpu_memory(&LLAMA31_8B, &short, opts);
        let g_l = gpu_memory(&LLAMA31_8B, &long, opts);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", g_s.gib()),
            format!("{:.2}", g_l.gib()),
            if g_l.gib() <= 80.0 { "y" } else { "n (OOM)" }.to_string(),
        ]);
    }
    common::emit(
        "fig2",
        "GPU memory vs optimizations, 8B model (paper: monotone reduction; unoptimized OOMs at 32k)",
        &t,
    );
}
