//! Fig. 20 — I/O volume per iteration, fp32 vs bf16 optimizer states
//! (paper: −58%).  Table VI — bf16-optimizer throughput gains on C1/C2
//! (paper: C1 avg +27.25%, C2 avg +17.08%, larger at small batch).
//! Fig. 21 — peak sysmem under bf16 *mixed precision* (paper: −25.19%
//! avg — smaller than fp16's −55.7% because bf16 needs no overflow
//! check, so there is no spike to eliminate).

mod common;

use memascend::accounting::perfmodel::{io_volume_per_step, step_time, Calib};
use memascend::accounting::sysmem::peak_sysmem;
use memascend::config::hardware::{CONFIG1, CONFIG2};
use memascend::config::presets::PAPER_DENSE;
use memascend::config::{MemAscendFlags, Precision, TrainSpec};
use memascend::dtype::DType;
use memascend::optimizer::StateDtype;
use memascend::util::bench::Table;
use memascend::util::human;

fn main() {
    // ---------- Fig. 20 ----------
    let mut t20 = Table::new(vec![
        "model", "fp32 optim I/O", "bf16 optim I/O", "cut %", "paper",
    ]);
    for m in PAPER_DENSE {
        let f = io_volume_per_step(m, StateDtype::F32);
        let b = io_volume_per_step(m, StateDtype::BF16);
        t20.row(vec![
            m.name.to_string(),
            human::bytes(f),
            human::bytes(b),
            format!("{:.1}", (1.0 - b as f64 / f as f64) * 100.0),
            "-58%".to_string(),
        ]);
    }
    common::emit("fig20", "I/O volume per iteration", &t20);

    // ---------- Table VI ----------
    let rows: &[(&str, usize, usize, f64, f64)] = &[
        ("llama3.1-8b", 8, 8, 28.63, 19.39),
        ("llama3.1-8b", 80, 20, 13.24, 11.99),
        ("qwen2.5-7b", 8, 8, 56.80, 18.26),
        ("qwen2.5-7b", 64, 20, 22.55, 9.99),
        ("qwen2.5-14b", 8, 4, 28.84, 22.11),
        ("qwen2.5-14b", 64, 16, 16.73, 11.80),
        ("qwen2.5-32b", 8, 4, 33.26, 24.21),
        ("qwen2.5-32b", 48, 8, 17.92, 18.87),
    ];
    let calib = Calib::default();
    let gain = |model: &str, batch: usize, hw| {
        let m = memascend::config::ModelSpec::by_name(model).unwrap();
        let mk = |dtype| TrainSpec {
            batch,
            seq: 4096,
            ranks: 2,
            prefetch_depth: 1,
            flags: MemAscendFlags::memascend(),
            optim_dtype: dtype,
            ..Default::default()
        };
        let f = step_time(m, &mk(DType::F32), hw, &calib).total();
        let b = step_time(m, &mk(DType::BF16), hw, &calib).total();
        (f / b - 1.0) * 100.0
    };
    let mut t6 = Table::new(vec![
        "model",
        "batch (C1/C2)",
        "C1 paper %",
        "C1 measured %",
        "C2 paper %",
        "C2 measured %",
    ]);
    for (model, b1, b2, p1, p2) in rows {
        t6.row(vec![
            model.to_string(),
            format!("{b1} / {b2}"),
            format!("{p1:.2}"),
            format!("{:.2}", gain(model, *b1, &CONFIG1)),
            format!("{p2:.2}"),
            format!("{:.2}", gain(model, *b2, &CONFIG2)),
        ]);
    }
    common::emit("table6", "bf16 optimizer throughput improvement", &t6);

    // ---------- Fig. 21 ----------
    let mut t21 = Table::new(vec!["model", "ZI bf16 (GiB)", "MA bf16 (GiB)", "cut %", "paper avg"]);
    let mut cuts = Vec::new();
    for m in PAPER_DENSE {
        let mk = |flags| {
            let mut s = common::eval_spec(flags);
            s.precision = Precision::MixedBF16;
            s
        };
        let z = peak_sysmem(m, &mk(MemAscendFlags::baseline()), &CONFIG1);
        let a = peak_sysmem(m, &mk(MemAscendFlags::memascend()), &CONFIG1);
        let cut = (1.0 - a.peak_total as f64 / z.peak_total as f64) * 100.0;
        cuts.push(cut);
        t21.row(vec![
            m.name.to_string(),
            common::gib(z.peak_total),
            common::gib(a.peak_total),
            format!("{cut:.1}"),
            "25.19%".to_string(),
        ]);
    }
    common::emit("fig21", "bf16 mixed-precision peak sysmem", &t21);
    let avg = cuts.iter().sum::<f64>() / cuts.len() as f64;
    println!("avg bf16 cut {avg:.1}% (paper: 25.19%; must be < the fp16 55.7%)");
}
