//! Fig. 8 — Qwen2.5-7B peak system-memory breakdown: ZeRO-Infinity vs
//! MemAscend vs theoretical minimum (paper: 109.04 / 43.64 / 30.83 GiB).
//! Also Fig. 4 — required vs wasted system memory across all models.

mod common;

use memascend::accounting::sysmem::peak_sysmem;
use memascend::config::hardware::CONFIG1;
use memascend::config::presets::{PAPER_DENSE, QWEN25_7B};
use memascend::config::MemAscendFlags;
use memascend::util::bench::Table;

fn main() {
    // ---------- Fig. 8 ----------
    let zi = peak_sysmem(&QWEN25_7B, &common::eval_spec(MemAscendFlags::baseline()), &CONFIG1);
    let ma = peak_sysmem(&QWEN25_7B, &common::eval_spec(MemAscendFlags::memascend()), &CONFIG1);
    let mut t = Table::new(vec!["component", "zero-infinity (GiB)", "memascend (GiB)"]);
    let row = |t: &mut Table, n: &str, a: u64, b: u64| {
        t.row(vec![n.to_string(), common::gib(a), common::gib(b)]);
    };
    row(&mut t, "param_pool", zi.param_pool, ma.param_pool);
    row(&mut t, "pinned_overhead", zi.pinned_overhead, ma.pinned_overhead);
    row(&mut t, "grad_flat", zi.grad_flat, ma.grad_flat);
    row(&mut t, "overflow_spike", zi.overflow_spike, ma.overflow_spike);
    row(&mut t, "optim_buf", zi.optim_buf, ma.optim_buf);
    row(&mut t, "swap_buf", zi.swap_buf, ma.swap_buf);
    row(&mut t, "act_ckpt", zi.act_ckpt, ma.act_ckpt);
    row(&mut t, "resident", zi.resident, ma.resident);
    row(&mut t, "PEAK TOTAL", zi.peak_total, ma.peak_total);
    t.row(vec![
        "paper PEAK".to_string(),
        "109.04".to_string(),
        "43.64".to_string(),
    ]);
    t.row(vec![
        "theoretical min".to_string(),
        common::gib(zi.theoretical_min()),
        common::gib(ma.theoretical_min()),
    ]);
    common::emit("fig8", "Qwen2.5-7B peak sysmem breakdown", &t);
    println!(
        "reduction: {:.1}% (paper: 60.0%)",
        (1.0 - ma.peak_total as f64 / zi.peak_total as f64) * 100.0
    );

    // ---------- Fig. 4 ----------
    let mut t4 = Table::new(vec![
        "model",
        "required (GiB)",
        "ZI peak (GiB)",
        "wasted (GiB)",
        "waste %",
        "paper avg waste %",
    ]);
    let mut waste_sum = 0.0;
    for m in PAPER_DENSE {
        let z = peak_sysmem(m, &common::eval_spec(MemAscendFlags::baseline()), &CONFIG1);
        let a = peak_sysmem(m, &common::eval_spec(MemAscendFlags::memascend()), &CONFIG1);
        // "required" = what a waste-free system (MemAscend) needs;
        // "wasted" = the ZI excess over that
        let wasted = z.peak_total - a.peak_total;
        let pct = wasted as f64 / z.peak_total as f64 * 100.0;
        waste_sum += pct;
        t4.row(vec![
            m.name.to_string(),
            common::gib(a.peak_total),
            common::gib(z.peak_total),
            common::gib(wasted),
            format!("{pct:.1}"),
            "55.7".to_string(),
        ]);
    }
    common::emit("fig4", "required vs wasted system memory (ZeRO-Infinity)", &t4);
    println!(
        "measured avg waste: {:.1}% (paper: 55.7%)",
        waste_sum / PAPER_DENSE.len() as f64
    );
}
