//! Pressure-adaptive governor + super-group coalescing bench.
//!
//! Two experiments, mirroring the two halves of the governor PR:
//!
//! 1. **Coalescing / submission count** — the SMOKE model's offloadable
//!    tensor inventory (many sub-tile tensors) driven through one
//!    optimizer step by (a) the per-tensor-group tiled driver and (b)
//!    the coalesced super-group driver, counting NVMe submissions
//!    (`IoSnapshot::ops` delta).  Acceptance bar (deterministic,
//!    CI-gated): coalescing cuts per-step submissions by ≥ 2× and every
//!    stored artifact stays byte-identical to the sequential
//!    `OptimState::step` reference.
//! 2. **Governor convergence under a fixed pinned budget** — one group
//!    whose static tile window cannot fit the budget next to the
//!    boundary's delivery views.  Static config degrades tiles every
//!    step, forever; the governed run shrinks the windows until
//!    `degraded_tiles == 0` and `host_copy_bytes == 0`, and stays
//!    there.  Acceptance bars
//!    (deterministic, CI-gated): the static run shows pressure, the
//!    governed run converges within the step budget, peak pinned
//!    reservation stays within the arena budget, and both runs remain
//!    byte-identical to the sequential reference.  Wall-clock stall
//!    seconds are printed and stored in the JSON but are report-only
//!    (timing-sensitive on shared runners).
//!
//! Emits `bench_out/BENCH_governor.json`.

mod common;

use std::sync::Arc;

use memascend::config::presets::SMOKE;
use memascend::metrics::HostCopyMeter;
use memascend::optimizer::{
    step_groups_tiled, AdamParams, CoalescedOptim, OptimState, StateDtype,
};
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, Cat, MemoryTracker, Mode, PinnedArena,
};
use memascend::runtime::F32Staging;
use memascend::ssd::{AsyncEngine, DirectEngine, NvmeEngine};
use memascend::tensors::inventory;
use memascend::train::{GovernorConfig, GovernorSample, PipelineGovernor, PipelineTuning};
use memascend::util::bench::Table;
use memascend::util::json::Json;
use memascend::util::rng::Xoshiro256;
use memascend::util::stage::StageExecutor;

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ma-gov-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn arena() -> Arc<PinnedArena> {
    PinnedArena::new(
        Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
        ArenaConfig::default(),
    )
}

/// Seed one engine with per-tensor optimizer groups + fp16 keys for
/// the SMOKE inventory, deterministically.
fn seed_groups(eng: &dyn NvmeEngine, sizes: &[usize], seed: u64) -> Vec<OptimState> {
    let mut rng = Xoshiro256::new(seed);
    let mut states = Vec::new();
    for (g, n) in sizes.iter().enumerate() {
        let p0: Vec<f32> = (0..*n).map(|_| rng.normal() as f32).collect();
        states.push(OptimState::init(eng, &format!("g{g}"), &p0, StateDtype::F32).unwrap());
        let mut fp16 = vec![0u8; n * 2];
        memascend::dtype::f32s_to_f16_bytes(&p0, &mut fp16);
        eng.write(&format!("g{g}/fp16"), &fp16).unwrap();
    }
    states
}

struct CoalesceResult {
    members: usize,
    per_group_ops: u64,
    coalesced_ops: u64,
    identical: bool,
}

/// Experiment 1: submission counts, per-tensor groups vs super-groups,
/// on the SMOKE model's many-small-tensor inventory.
fn run_coalesce() -> CoalesceResult {
    // the trainer's real group shapes: every offloadable SMOKE tensor
    let sizes: Vec<usize> = inventory(&SMOKE)
        .into_iter()
        .filter(|t| t.offloadable())
        .map(|t| t.numel)
        .collect();
    let steps = 2u64;
    let tile = 64 << 10;
    let hp = AdamParams { weight_decay: 0.01, ..Default::default() };

    let dir_seq = tmp("co-seq");
    let dir_grp = tmp("co-grp");
    let dir_coa = tmp("co-coa");
    let eng_seq = DirectEngine::new(&dir_seq, 2, 1 << 26, 1).unwrap();
    let eng_grp: Arc<dyn NvmeEngine> =
        Arc::new(DirectEngine::new(&dir_grp, 2, 1 << 26, 1).unwrap());
    let eng_coa: Arc<dyn NvmeEngine> =
        Arc::new(DirectEngine::new(&dir_coa, 2, 1 << 26, 1).unwrap());
    let states_seq = seed_groups(&eng_seq, &sizes, 42);
    let states_grp = seed_groups(eng_grp.as_ref(), &sizes, 42);
    let states_coa = seed_groups(eng_coa.as_ref(), &sizes, 42);
    let aio_grp = AsyncEngine::new(Arc::clone(&eng_grp), 3);
    let aio_coa = AsyncEngine::new(Arc::clone(&eng_coa), 3);
    let stage = StageExecutor::new(2);
    let co = CoalescedOptim::build(eng_coa.as_ref(), &states_coa, 1 << 20).unwrap();
    let keys: Vec<String> = (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
    let arena_grp = arena();
    let arena_coa = arena();

    let mut rng = Xoshiro256::new(7);
    let mut per_group_ops = 0u64;
    let mut coalesced_ops = 0u64;
    for t in 1..=steps {
        let grads: Vec<Vec<f32>> = sizes
            .iter()
            .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
            .collect();
        let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        for (g, st) in states_seq.iter().enumerate() {
            st.step(&eng_seq, &grads[g], t, 2.0, &hp, 1, &keys[g]).unwrap();
        }
        let before = eng_grp.stats().ops();
        step_groups_tiled(
            &aio_grp, &stage, &arena_grp, &states_grp, &grad_refs, &keys, t, 2.0, &hp,
            1, tile, 2,
        )
        .unwrap();
        per_group_ops += eng_grp.stats().ops() - before;
        let before = eng_coa.stats().ops();
        co.step_tiled(
            &aio_coa, &stage, &arena_coa, &grad_refs, &keys, t, 2.0, &hp, 1, tile, 2,
        )
        .unwrap();
        coalesced_ops += eng_coa.stats().ops() - before;
    }

    // byte-identity of every member artifact against the sequential
    // reference, for both drivers
    let mut identical = true;
    for (g, n) in sizes.iter().enumerate() {
        for suffix in ["master", "adam_m", "adam_v"] {
            let key = format!("g{g}/{suffix}");
            let mut a = vec![0u8; n * 4];
            let mut b = vec![0u8; n * 4];
            let mut c = vec![0u8; n * 4];
            eng_seq.read(&key, &mut a).unwrap();
            eng_grp.read(&key, &mut b).unwrap();
            co.read_member_state(eng_coa.as_ref(), g, suffix, &mut c).unwrap();
            if a != b || a != c {
                identical = false;
                eprintln!("MISMATCH at {key}");
            }
        }
        let key = format!("g{g}/fp16");
        let mut a = vec![0u8; n * 2];
        let mut c = vec![0u8; n * 2];
        eng_seq.read(&key, &mut a).unwrap();
        eng_coa.read(&key, &mut c).unwrap();
        if a != c {
            identical = false;
            eprintln!("MISMATCH at {key}");
        }
    }
    std::fs::remove_dir_all(&dir_seq).ok();
    std::fs::remove_dir_all(&dir_grp).ok();
    std::fs::remove_dir_all(&dir_coa).ok();
    CoalesceResult {
        members: sizes.len(),
        per_group_ops: per_group_ops / steps,
        coalesced_ops: coalesced_ops / steps,
        identical,
    }
}

struct BudgetRun {
    pressured_steps: usize,
    /// First step after which no pressure ever returned (`None` =
    /// pressured through the end).
    clean_at: Option<usize>,
    final_tuning: PipelineTuning,
    peak_reserved: usize,
    wait_secs: f64,
}

const GOV_STEPS: u64 = 24;
const BUDGET: usize = 1 << 20; // 1 MiB pinned for optimizer + delivery
const GROUP_ELEMS: usize = 200_000; // 800 KiB per f32 stream
const VIEW_ELEMS: usize = 24 << 10; // one 96 KiB delivery view per slot

/// One run of experiment 2: `governed = false` pins the static tuning
/// forever (today's behavior), `true` lets the governor retune.
fn run_budget(tag: &str, governed: bool) -> (BudgetRun, Vec<u8>, Vec<u8>) {
    let dir = tmp(tag);
    let eng: Arc<dyn NvmeEngine> =
        Arc::new(DirectEngine::new(&dir, 1, 1 << 26, 1).unwrap());
    let mut rng = Xoshiro256::new(3);
    let p0: Vec<f32> = (0..GROUP_ELEMS).map(|_| rng.normal() as f32).collect();
    let st = OptimState::init(eng.as_ref(), "g0", &p0, StateDtype::F32).unwrap();
    let aio = AsyncEngine::new(Arc::clone(&eng), 2);
    let stage = StageExecutor::new(1);
    let hp = AdamParams::default();
    let arena = PinnedArena::new(
        Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
        ArenaConfig { budget_bytes: Some(BUDGET), ..Default::default() },
    );
    let meter = HostCopyMeter::new();
    let cfg = GovernorConfig {
        min_tile_bytes: 8 << 10,
        max_tile_bytes: 1 << 20,
        ..Default::default()
    };
    // the static operating point: 512 KiB tiles × depth 2 needs up to
    // 7 MiB of pinned window next to a 1 MiB budget
    let start = PipelineTuning {
        optim_tile_bytes: 512 << 10,
        tile_depth: 2,
        prefetch_depth: 4,
        sched_lead_us: 200,
        act_host_budget: usize::MAX,
    };
    let mut gov = PipelineGovernor::new(cfg, start);
    let mut tuning = gov.tuning();
    let mut pressured_steps = 0usize;
    let mut steps_to_clean: Option<usize> = None;
    let mut clean_streak = 0usize;
    let mut wait_secs = 0.0f64;
    for t in 1..=GOV_STEPS {
        // the boundary's concurrent delivery views, one per prefetch
        // slot, held across the optimizer phase
        let copies_before = meter.bytes();
        let views: Vec<F32Staging> = (0..tuning.prefetch_depth)
            .map(|_| F32Staging::take(&arena, Cat::SwapBuf, VIEW_ELEMS, &meter))
            .collect();
        let g: Vec<f32> = (0..GROUP_ELEMS).map(|_| rng.normal() as f32).collect();
        let stats = step_groups_tiled(
            &aio,
            &stage,
            &arena,
            std::slice::from_ref(&st),
            &[g.as_slice()],
            &["g0/fp16".to_string()],
            t,
            1.0,
            &hp,
            1,
            tuning.optim_tile_bytes,
            tuning.tile_depth,
        )
        .unwrap();
        drop(views);
        wait_secs += stats.wait_secs;
        let host_copy = meter.bytes() - copies_before;
        if host_copy > 0 || stats.degraded_tiles > 0 {
            pressured_steps += 1;
            clean_streak = 0;
        } else {
            clean_streak += 1;
            if clean_streak == 1 && steps_to_clean.is_none() {
                steps_to_clean = Some(t as usize);
            }
        }
        if clean_streak == 0 {
            steps_to_clean = None; // pressure returned: not converged yet
        }
        if governed {
            let a = arena.stats();
            tuning = gov.observe(&GovernorSample {
                host_copy_bytes: host_copy,
                degraded_tiles: stats.degraded_tiles,
                prefetch_late: 0,
                prefetch_hits: 0,
                io_wait_secs: stats.wait_secs,
                io_busy_secs: 0.0,
                step_secs: 1.0,
                arena_reserved: a.reserved_bytes,
                arena_budget: Some(BUDGET),
            });
        }
    }
    // final states for the cross-run identity check
    let mut master = vec![0u8; GROUP_ELEMS * 4];
    eng.read("g0/master", &mut master).unwrap();
    let mut fp16 = vec![0u8; GROUP_ELEMS * 2];
    eng.read("g0/fp16", &mut fp16).unwrap();
    let peak = arena.stats().peak_reserved;
    std::fs::remove_dir_all(&dir).ok();
    (
        BudgetRun {
            pressured_steps,
            clean_at: steps_to_clean,
            final_tuning: tuning,
            peak_reserved: peak,
            wait_secs,
        },
        master,
        fp16,
    )
}

fn main() {
    // ---- experiment 1: coalescing vs per-tensor submissions ----
    let co = run_coalesce();
    let reduction = co.per_group_ops as f64 / co.coalesced_ops.max(1) as f64;
    let mut t1 = Table::new(vec![
        "members",
        "per-group subs/step",
        "coalesced subs/step",
        "reduction",
        "byte-identical",
    ]);
    t1.row(vec![
        co.members.to_string(),
        co.per_group_ops.to_string(),
        co.coalesced_ops.to_string(),
        format!("{reduction:.2}x"),
        co.identical.to_string(),
    ]);
    common::emit(
        "bench_governor_coalesce",
        "super-group coalescing: NVMe submissions per optimizer step (SMOKE inventory)",
        &t1,
    );

    // ---- experiment 2: static vs governed under a 1 MiB budget ----
    let (stat, stat_master, stat_fp16) = run_budget("static", false);
    let (gov, gov_master, gov_fp16) = run_budget("governed", true);
    // sequential reference for identity: same grads, same seed
    let dir = tmp("ref");
    let eng = DirectEngine::new(&dir, 1, 1 << 26, 1).unwrap();
    let mut rng = Xoshiro256::new(3);
    let p0: Vec<f32> = (0..GROUP_ELEMS).map(|_| rng.normal() as f32).collect();
    let st = OptimState::init(&eng, "g0", &p0, StateDtype::F32).unwrap();
    let hp = AdamParams::default();
    for t in 1..=GOV_STEPS {
        let g: Vec<f32> = (0..GROUP_ELEMS).map(|_| rng.normal() as f32).collect();
        st.step(&eng, &g, t, 1.0, &hp, 1, "g0/fp16").unwrap();
    }
    let mut ref_master = vec![0u8; GROUP_ELEMS * 4];
    eng.read("g0/master", &mut ref_master).unwrap();
    let mut ref_fp16 = vec![0u8; GROUP_ELEMS * 2];
    eng.read("g0/fp16", &mut ref_fp16).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let identical = stat_master == ref_master
        && gov_master == ref_master
        && stat_fp16 == ref_fp16
        && gov_fp16 == ref_fp16;

    let mut t2 = Table::new(vec![
        "run",
        "pressured steps",
        "converged at step",
        "final tile (KiB)",
        "final depth",
        "final prefetch",
        "peak reserved (KiB)",
        "stall secs (report-only)",
    ]);
    t2.row(vec![
        "static".into(),
        format!("{}/{GOV_STEPS}", stat.pressured_steps),
        "-".into(),
        (stat.final_tuning.optim_tile_bytes >> 10).to_string(),
        stat.final_tuning.tile_depth.to_string(),
        stat.final_tuning.prefetch_depth.to_string(),
        (stat.peak_reserved >> 10).to_string(),
        format!("{:.3}", stat.wait_secs),
    ]);
    t2.row(vec![
        "governed".into(),
        format!("{}/{GOV_STEPS}", gov.pressured_steps),
        gov.clean_at
            .map(|s| s.to_string())
            .unwrap_or_else(|| "never".into()),
        (gov.final_tuning.optim_tile_bytes >> 10).to_string(),
        gov.final_tuning.tile_depth.to_string(),
        gov.final_tuning.prefetch_depth.to_string(),
        (gov.peak_reserved >> 10).to_string(),
        format!("{:.3}", gov.wait_secs),
    ]);
    common::emit(
        "bench_governor_budget",
        "pipeline governor under a fixed 1 MiB pinned budget",
        &t2,
    );

    // ---- acceptance ----
    let submissions_halved = reduction >= 2.0;
    let static_pressured = stat.pressured_steps == GOV_STEPS as usize;
    let governed_converged = gov.clean_at.is_some();
    let budget_held = gov.peak_reserved <= BUDGET;
    println!(
        "submissions: {} -> {} per step ({reduction:.2}x, target >= 2x): {}",
        co.per_group_ops, co.coalesced_ops, submissions_halved
    );
    println!(
        "static run pressured every step: {static_pressured}; governed converged: \
         {governed_converged} (at step {:?}, final tuning {:?})",
        gov.clean_at, gov.final_tuning
    );
    println!("governed peak reserved {} <= budget {}: {budget_held}", gov.peak_reserved, BUDGET);
    println!("byte-identity (static & governed & coalesced vs sequential): {}", co.identical && identical);
    println!(
        "LATENCY (report-only): static stall {:.3}s vs governed stall {:.3}s",
        stat.wait_secs, gov.wait_secs
    );

    std::fs::create_dir_all(common::OUT_DIR).ok();
    let out = Json::obj(vec![
        ("members", Json::from(co.members)),
        ("per_group_submissions_per_step", Json::from(co.per_group_ops)),
        ("coalesced_submissions_per_step", Json::from(co.coalesced_ops)),
        ("submission_reduction", Json::from(reduction)),
        ("coalesced_byte_identical", Json::from(co.identical)),
        ("budget_bytes", Json::from(BUDGET)),
        ("static_pressured_steps", Json::from(stat.pressured_steps)),
        ("governed_pressured_steps", Json::from(gov.pressured_steps)),
        (
            "governed_converged_at_step",
            Json::from(gov.clean_at.unwrap_or(0)),
        ),
        ("governed_final_tile_bytes", Json::from(gov.final_tuning.optim_tile_bytes)),
        ("governed_final_tile_depth", Json::from(gov.final_tuning.tile_depth)),
        ("governed_final_prefetch_depth", Json::from(gov.final_tuning.prefetch_depth)),
        ("static_peak_reserved", Json::from(stat.peak_reserved)),
        ("governed_peak_reserved", Json::from(gov.peak_reserved)),
        ("static_stall_secs", Json::from(stat.wait_secs)),
        ("governed_stall_secs", Json::from(gov.wait_secs)),
        ("runs_byte_identical", Json::from(identical)),
    ]);
    let path = format!("{}/BENCH_governor.json", common::OUT_DIR);
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("[json] {path}"),
        Err(e) => eprintln!("warn: could not write {path}: {e}"),
    }

    let pass = submissions_halved
        && co.identical
        && static_pressured
        && governed_converged
        && budget_held
        && identical;
    println!("ACCEPTANCE: {}", if pass { "PASS" } else { "FAIL" });
    if !pass {
        std::process::exit(1);
    }
}
