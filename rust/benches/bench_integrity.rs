//! I/O integrity and straggler-tolerance bench: three CI-gated bars
//! over the robustness stack (checksummed streams, bounded retry,
//! per-op deadlines with hedged reads), all at the optimizer level so
//! the full bench runs on plain CI runners:
//!
//! 1. **Corruption detection and healing (CI-gated)** — the same
//!    deterministic step sequence runs once on a clean engine and once
//!    over `Retry(Integrity(Faulty))` with seeded read-side bit flips
//!    (~10% of whole-key reads corrupt one bit in flight — of stream
//!    bytes or of the sidecar sums the verify path fetches).  Every
//!    injected flip must be detected by the checksum layer and healed
//!    by a re-read: the final training state must be bit-identical to
//!    the clean run, with zero retry exhaustions.  A second engine
//!    with *write-side* flips (durable rot) must refuse the rotten
//!    bytes with the typed `integrity mismatch` after exhausting the
//!    retry budget — training never sees corrupt data on either path.
//! 2. **Hedged reads under latency spikes (CI-gated)** — a straggler
//!    device (seeded ~16% of data ops stall ~50 ms) serves the same
//!    serial read sequence unhedged and hedged (10 ms per-op
//!    deadline).  The hedged pass must record timeouts and fired
//!    hedges and finish faster than the unhedged baseline.
//! 3. **Clean-path checksum overhead (reported)** — the step sequence
//!    timed over a clean engine with and without the integrity layer;
//!    the delta is the price of verify-on-read + sum-on-write.  Gated
//!    only on transparency: both runs must produce identical bytes
//!    (integrity off ≡ integrity on, data-wise).
//!
//! Emits `bench_out/BENCH_integrity.json`.

mod common;

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use memascend::optimizer::{step_groups_tiled, AdamParams, OptimState, StateDtype};
use memascend::pinned::{AlignedAllocator, ArenaConfig, MemoryTracker, Mode, PinnedArena};
use memascend::ssd::{
    AsyncEngine, DirectEngine, FaultyEngine, IntegrityEngine, NvmeEngine, OpKind,
    OpMask, RetryEngine, RetryPolicy,
};
use memascend::util::bench::Table;
use memascend::util::json::Json;
use memascend::util::rng::Xoshiro256;
use memascend::util::stage::StageExecutor;

/// Every stream stays under one integrity block (256 KiB), so each
/// key's sidecar is a single sum and *any* in-flight flip — of data or
/// of a fetched sidecar — lands in the verified span.  That turns
/// "every injected bit-flip detected" into a countable gate:
/// `integrity_failures >= corrupted`.
const SIZES: [usize; 3] = [60_000, 30_000, 14_000];
const TILE_BYTES: usize = 32 << 10;
const DEPTH: usize = 2;
const STEPS: u64 = 4;
const SEED: u64 = 7;

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ma-bint-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn arena() -> Arc<PinnedArena> {
    PinnedArena::new(
        Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
        ArenaConfig::default(),
    )
}

fn direct(dir: &std::path::Path) -> Arc<DirectEngine> {
    Arc::new(DirectEngine::new(dir, 2, 1 << 27, 1).unwrap())
}

/// Deterministic per-step gradients: the clean and chaotic runs see
/// the same data stream.
fn grads_for(step: u64) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256::new(step ^ 0xB0B);
    SIZES
        .iter()
        .map(|&n| (0..n).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn init_states(engine: &dyn NvmeEngine) -> Vec<OptimState> {
    let mut rng = Xoshiro256::new(1009);
    SIZES
        .iter()
        .enumerate()
        .map(|(g, &n)| {
            let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            OptimState::init(engine, &format!("g{g}"), &vals, StateDtype::F32).unwrap()
        })
        .collect()
}

fn fp16_keys(states: &[OptimState]) -> Vec<String> {
    states.iter().map(|s| format!("{}/fp16", s.group)).collect()
}

/// All stored streams of every group, read through `engine` — through
/// the verified stack this re-checks (and, under transient flips,
/// heals) every byte it returns.
fn all_bytes(engine: &dyn NvmeEngine) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    for (g, &n) in SIZES.iter().enumerate() {
        for (key, width) in [
            (format!("g{g}/master"), 4usize),
            (format!("g{g}/adam_m"), 4),
            (format!("g{g}/adam_v"), 4),
            (format!("g{g}/fp16"), 2),
        ] {
            let mut buf = vec![0u8; n * width];
            engine.read(&key, &mut buf).unwrap();
            out.push(buf);
        }
    }
    out
}

/// Init + `STEPS` optimizer steps over `eng`; returns the timed step
/// loop duration and the final stored bytes.
fn run_pipeline(eng: Arc<dyn NvmeEngine>) -> (Duration, Vec<Vec<u8>>) {
    let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
    let states = init_states(eng.as_ref());
    let aio = AsyncEngine::new(eng.clone(), 2);
    let stage = StageExecutor::new(2);
    let arena = arena();
    let t0 = Instant::now();
    for t in 1..=STEPS {
        let grads = grads_for(t);
        let gr: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        step_groups_tiled(
            &aio,
            &stage,
            &arena,
            &states,
            &gr,
            &fp16_keys(&states),
            t,
            1.0,
            &hp,
            1,
            TILE_BYTES,
            DEPTH,
        )
        .unwrap();
    }
    let dt = t0.elapsed();
    let bytes = all_bytes(eng.as_ref());
    (dt, bytes)
}

struct CorruptionResult {
    corrupted: u64,
    integrity_failures: u64,
    retries: u64,
    retry_exhaustions: u64,
    identical: bool,
    rot_typed_abort: bool,
    rot_exhaustions: u64,
}

/// Experiment 1: transient read flips heal to bit-identity; durable
/// write rot aborts typed.
fn run_corruption(clean: &[Vec<u8>]) -> CorruptionResult {
    let dir = tmp("chaos");
    // ~10% of whole-key reads corrupt one bit in the out buffer.
    // Ranged reads are spared: the sum-maintenance path re-reads
    // partially-covered edge blocks through this engine, and a flip
    // there would *durably* rot the sidecar — that contract is the
    // write-side half below.
    let faulty = Arc::new(
        FaultyEngine::new(direct(&dir), 0, SEED)
            .with_bit_flips(100, SEED)
            .with_flip_mask(OpMask::NONE.with(OpKind::Read)),
    );
    let integrity = Arc::new(IntegrityEngine::new(faulty.clone()));
    let eng: Arc<dyn NvmeEngine> =
        Arc::new(RetryEngine::new(integrity, RetryPolicy::attempts(12)));
    let (_, bytes) = run_pipeline(eng.clone());
    let snap = eng.stats();
    let corrupted = faulty.corrupted.load(Ordering::Relaxed);
    let identical = bytes == clean;
    std::fs::remove_dir_all(&dir).ok();

    // durable rot: every write flips one bit after the sums were
    // computed, so stored data and stored sums can never agree; the
    // verified read must exhaust its budget and refuse the bytes typed
    let dir2 = tmp("rot");
    let rotter = Arc::new(
        FaultyEngine::new(direct(&dir2), 0, SEED)
            .with_bit_flips(1024, SEED)
            .with_flip_mask(OpMask::NONE.with(OpKind::Write)),
    );
    let verified: Arc<dyn NvmeEngine> = Arc::new(RetryEngine::new(
        Arc::new(IntegrityEngine::new(rotter.clone())),
        RetryPolicy::attempts(3),
    ));
    verified.write("rotten", &[0x5Au8; 4096]).unwrap();
    let mut out = vec![0u8; 4096];
    let rot_typed_abort = match verified.read("rotten", &mut out) {
        Ok(()) => false,
        Err(e) => {
            let msg = e.to_string();
            msg.contains("integrity mismatch") && msg.contains("retry exhausted")
        }
    };
    let rot_exhaustions = verified.stats().retry_exhaustions;
    std::fs::remove_dir_all(&dir2).ok();

    CorruptionResult {
        corrupted,
        integrity_failures: snap.integrity_failures,
        retries: snap.retries,
        retry_exhaustions: snap.retry_exhaustions,
        identical,
        rot_typed_abort,
        rot_exhaustions,
    }
}

const READ_KEYS: usize = 96;
const KEY_BYTES: usize = 128 << 10;

struct StragglerResult {
    secs: f64,
    hedges: u64,
    timeouts: u64,
}

/// One serial read pass over a straggler device (seeded latency
/// spikes), hedged or not.  Serial submit-then-wait keeps the second
/// queue worker free, so a fired hedge runs immediately instead of
/// queuing behind a backlog — the shape a deadline is meant for.
fn run_straggler(base: Arc<DirectEngine>, hedged: bool) -> StragglerResult {
    let faulty = Arc::new(FaultyEngine::new(base, 0, SEED).with_latency(
        160,
        Duration::from_millis(50),
        Duration::from_millis(5),
        SEED,
    ));
    let deadline = hedged.then(|| Duration::from_millis(10));
    let aio = AsyncEngine::new(faulty, 2).with_deadline(deadline);
    let t0 = Instant::now();
    for i in 0..READ_KEYS {
        let got = aio
            .submit_read(format!("k{i}"), vec![0u8; KEY_BYTES])
            .wait()
            .unwrap();
        assert!(
            got.iter().all(|&b| b == (i % 251) as u8),
            "k{i} returned wrong bytes (hedged={hedged})"
        );
    }
    let secs = t0.elapsed().as_secs_f64();
    let health = aio.executor().health();
    let out = StragglerResult { secs, hedges: health.hedges(), timeouts: health.timeouts() };
    // let stale spiked primaries drain before the engine (and its temp
    // dir) goes away under them
    std::thread::sleep(Duration::from_millis(120));
    out
}

fn main() {
    // --- experiment 1: corruption detection and healing
    let dir_clean = tmp("clean");
    let (clean_secs, clean_bytes) = run_pipeline(direct(&dir_clean) as Arc<dyn NvmeEngine>);
    std::fs::remove_dir_all(&dir_clean).ok();
    let cor = run_corruption(&clean_bytes);
    let mut t1 = Table::new(vec!["metric", "value"]);
    t1.row(vec!["bit flips injected (read path)".into(), cor.corrupted.to_string()]);
    t1.row(vec!["integrity failures detected".into(), cor.integrity_failures.to_string()]);
    t1.row(vec!["retries (healing re-reads)".into(), cor.retries.to_string()]);
    t1.row(vec!["retry exhaustions".into(), cor.retry_exhaustions.to_string()]);
    t1.row(vec!["final state bit-identical".into(), cor.identical.to_string()]);
    t1.row(vec!["durable rot -> typed abort".into(), cor.rot_typed_abort.to_string()]);
    common::emit(
        "bench_integrity_corruption",
        "flip detection + healing (CI-gated)",
        &t1,
    );

    // --- experiment 2: hedged reads under latency spikes
    let dir_io = tmp("spikes");
    let base = direct(&dir_io);
    for i in 0..READ_KEYS {
        base.write(&format!("k{i}"), &vec![(i % 251) as u8; KEY_BYTES]).unwrap();
    }
    let unhedged = run_straggler(base.clone(), false);
    let hedged = run_straggler(base.clone(), true);
    std::fs::remove_dir_all(&dir_io).ok();
    let mut t2 = Table::new(vec!["pass", "wall s", "hedges", "timeouts"]);
    t2.row(vec![
        "unhedged".into(),
        format!("{:.3}", unhedged.secs),
        unhedged.hedges.to_string(),
        unhedged.timeouts.to_string(),
    ]);
    t2.row(vec![
        "hedged (10 ms deadline)".into(),
        format!("{:.3}", hedged.secs),
        hedged.hedges.to_string(),
        hedged.timeouts.to_string(),
    ]);
    common::emit(
        "bench_integrity_straggler",
        "hedged reads vs latency spikes (CI-gated)",
        &t2,
    );

    // --- experiment 3: clean-path checksum overhead
    let dir_ver = tmp("verified");
    let (verified_secs, verified_bytes) = run_pipeline(Arc::new(IntegrityEngine::new(
        direct(&dir_ver) as Arc<dyn NvmeEngine>,
    )));
    std::fs::remove_dir_all(&dir_ver).ok();
    let transparent = verified_bytes == clean_bytes;
    let clean_s = clean_secs.as_secs_f64();
    let overhead_pct = if clean_s > 0.0 {
        (verified_secs.as_secs_f64() / clean_s - 1.0) * 100.0
    } else {
        0.0
    };
    let mut t3 = Table::new(vec!["pass", "step-loop s", "bytes identical"]);
    t3.row(vec!["integrity off".into(), format!("{clean_s:.3}"), "-".into()]);
    t3.row(vec![
        "integrity on".into(),
        format!("{:.3}", verified_secs.as_secs_f64()),
        transparent.to_string(),
    ]);
    common::emit(
        "bench_integrity_overhead",
        "clean-path checksum overhead (reported)",
        &t3,
    );

    std::fs::create_dir_all(common::OUT_DIR).ok();
    let out = Json::obj(vec![
        ("steps", Json::from(STEPS)),
        ("flips_injected", Json::from(cor.corrupted)),
        ("integrity_failures", Json::from(cor.integrity_failures)),
        ("healing_retries", Json::from(cor.retries)),
        ("retry_exhaustions", Json::from(cor.retry_exhaustions)),
        ("chaos_bit_identical", Json::from(cor.identical)),
        ("durable_rot_typed_abort", Json::from(cor.rot_typed_abort)),
        ("unhedged_secs", Json::from(unhedged.secs)),
        ("hedged_secs", Json::from(hedged.secs)),
        ("hedges", Json::from(hedged.hedges)),
        ("timeouts", Json::from(hedged.timeouts)),
        ("clean_secs", Json::from(clean_s)),
        ("verified_secs", Json::from(verified_secs.as_secs_f64())),
        ("checksum_overhead_pct", Json::from(overhead_pct)),
        ("integrity_transparent", Json::from(transparent)),
    ]);
    let path = format!("{}/BENCH_integrity.json", common::OUT_DIR);
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("[json] {path}"),
        Err(e) => eprintln!("warn: could not write {path}: {e}"),
    }

    println!(
        "corruption: {} flips -> {} detected, {} retries, {} exhaustions, identical {}",
        cor.corrupted, cor.integrity_failures, cor.retries, cor.retry_exhaustions,
        cor.identical
    );
    println!(
        "straggler: unhedged {:.3}s vs hedged {:.3}s ({} hedges, {} timeouts)",
        unhedged.secs, hedged.secs, hedged.hedges, hedged.timeouts
    );
    println!(
        "overhead: integrity off {clean_s:.3}s vs on {:.3}s ({overhead_pct:+.1}%), transparent {transparent}",
        verified_secs.as_secs_f64()
    );

    // CI gates
    assert!(cor.corrupted > 0, "chaos engine injected no flips");
    assert!(
        cor.integrity_failures >= cor.corrupted,
        "{} of {} flips detected — a flip slipped past the checksum layer",
        cor.integrity_failures,
        cor.corrupted
    );
    assert!(cor.retries >= cor.integrity_failures, "detected flips were not re-read");
    assert_eq!(cor.retry_exhaustions, 0, "transient flips must heal within budget");
    assert!(cor.identical, "training state diverged under read-side bit flips");
    assert!(cor.rot_typed_abort, "durable rot not refused with the typed mismatch");
    assert!(cor.rot_exhaustions > 0, "durable rot never exhausted the retry budget");
    assert_eq!(unhedged.hedges, 0, "hedges fired without a deadline");
    assert!(hedged.hedges > 0, "no hedge fired under latency spikes");
    assert!(hedged.timeouts > 0, "no deadline timeout recorded under spikes");
    assert!(
        hedged.secs < unhedged.secs,
        "hedging did not beat the straggler baseline: {:.3}s vs {:.3}s",
        hedged.secs,
        unhedged.secs
    );
    assert!(transparent, "integrity layer changed stored bytes");
    println!("ACCEPTANCE: PASS");
}
