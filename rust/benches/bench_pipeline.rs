//! §IV-A/§IV-E overlap bench: does the async multi-queue pipeline
//! actually hide SSD time behind compute?
//!
//! Two experiments on the SMOKE preset, no PJRT artifacts needed (a
//! calibrated spin stands in for kernel time so the I/O:compute ratio
//! matches a balanced training step):
//!
//! 1. **Swapper**: sequential fetch→convert→compute per tensor vs the
//!    windowed pipeline (depth in flight, out-of-order completion,
//!    in-order delivery).
//! 2. **Optimizer**: sequential read→Adam→write per group vs the
//!    double-buffered swap — and a byte-for-byte comparison of every
//!    stored state tensor proving the two paths are bit-identical.
//!
//! Results are reported through `StepMetrics::io_overlap_frac` — the
//! same overlap accounting the trainer emits — and the acceptance bar
//! is ≥ 30% of engine-busy I/O time hidden behind compute.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use memascend::bufpool::{AdaptivePool, ParamBufferPool};
use memascend::config::presets::SMOKE;
use memascend::dtype::{f16_bytes_to_f32s, f32s_to_f16_bytes, DType};
use memascend::metrics::StepMetrics;
use memascend::offload::{F32Scratch, FetchOpts, Swapper};
use memascend::optimizer::{
    step_groups_pipelined, AdamParams, OptimState, StateDtype,
};
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, MemoryTracker, Mode, PinnedArena,
};
use memascend::ssd::{AsyncEngine, DirectEngine, IoExecutor, NvmeEngine};
use memascend::tensors::{inventory, TensorDesc};
use memascend::util::bench::{black_box, Table};
use memascend::util::rng::Xoshiro256;
use memascend::util::stage::StageExecutor;

fn arena() -> Arc<PinnedArena> {
    let alloc = AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()));
    PinnedArena::new(Arc::new(alloc), ArenaConfig::default())
}

fn spin(d: Duration) {
    let t0 = Instant::now();
    let mut x = 0u64;
    while t0.elapsed() < d {
        x = black_box(x.wrapping_mul(6364136223846793005).wrapping_add(1));
    }
    black_box(x);
}

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ma-pipe-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn io_busy_delta(
    eng: &dyn NvmeEngine,
    before: memascend::ssd::IoSnapshot,
) -> f64 {
    // union-of-busy-intervals: concurrent transfers are counted once,
    // so "hidden" time below is strictly compute overlap
    let after = eng.stats();
    (after.busy_ns - before.busy_ns) as f64 / 1e9
}

/// Overlap report row from measured stall/busy time, phrased as the
/// trainer's own `StepMetrics`.
fn metrics(io_secs: f64, io_wait_secs: f64, step_secs: f64) -> StepMetrics {
    StepMetrics {
        step: 1,
        loss: 0.0,
        loss_scale: 1.0,
        overflowed: false,
        tokens: 0,
        step_secs,
        compute_secs: (step_secs - io_secs).max(0.0),
        io_secs,
        overflow_check_secs: 0.0,
        optim_secs: 0.0,
        io_wait_secs,
        optim_tiles: 0,
        degraded_tiles: 0,
        nvme_submissions: 0,
        optim_tile_bytes: 0,
        tile_depth: 0,
        prefetch_depth: 0,
        host_copy_bytes: 0,
        ckpt_secs: 0.0,
        io_retries: 0,
        journal_epoch: 0,
        fetch_submissions: 0,
        prefetch_hits: 0,
        prefetch_late: 0,
        prefetch_fallbacks: 0,
    }
}

/// Per-queue attribution: how much of the window's engine-busy time
/// each NVMe device queue carried (union-of-intervals per queue).
fn print_queue_busy(label: &str, eng: &dyn NvmeEngine, before: memascend::ssd::IoSnapshot) {
    let after = eng.stats();
    let mut parts = Vec::new();
    for q in 0..after.queue_count.max(before.queue_count) {
        let d = (after.queue_busy_ns[q] - before.queue_busy_ns[q]) as f64 / 1e9;
        parts.push(format!("q{q} {d:.3}s"));
    }
    println!("  per-queue busy [{label}]: {}", parts.join("  "));
}

fn seed_engine(tag: &str) -> (Arc<DirectEngine>, Vec<TensorDesc>, std::path::PathBuf) {
    let dir = tmp(tag);
    let eng = Arc::new(DirectEngine::new(&dir, 2, 1 << 26, 2).unwrap());
    let plan: Vec<TensorDesc> =
        inventory(&SMOKE).into_iter().filter(|t| t.offloadable()).collect();
    for (i, t) in plan.iter().enumerate() {
        let vals = vec![i as f32 * 0.25 + 0.5; t.numel];
        let mut bytes = vec![0u8; t.numel * 2];
        f32s_to_f16_bytes(&vals, &mut bytes);
        eng.write(&format!("{}/fp16", t.name), &bytes).unwrap();
    }
    (eng, plan, dir)
}

/// Per-tensor simulated kernel time: proportional to tensor size, at a
/// rate calibrated so compute is the same order as SSD time.
fn compute_time(t: &TensorDesc, ns_per_elem: f64) -> Duration {
    Duration::from_nanos((t.numel as f64 * ns_per_elem) as u64)
}

fn swapper_experiment(table: &mut Table) -> (StepMetrics, f64) {
    let (eng, plan, dir) = seed_engine("swap");
    let passes = 6;

    // calibrate spin rate off one sync sweep so compute ≈ I/O
    let t0 = Instant::now();
    let mut staging = vec![0u8; plan.iter().map(|t| t.numel).max().unwrap() * 2];
    let mut scratch = vec![0f32; plan.iter().map(|t| t.numel).max().unwrap()];
    for t in &plan {
        let n = t.numel;
        eng.read(&format!("{}/fp16", t.name), &mut staging[..n * 2]).unwrap();
        f16_bytes_to_f32s(&staging[..n * 2], &mut scratch[..n]);
    }
    let sweep_io = t0.elapsed().as_secs_f64();
    let total_elems: usize = plan.iter().map(|t| t.numel).sum();
    let ns_per_elem = sweep_io * 1e9 / total_elems as f64;

    // --- sequential: fetch, convert, compute, one tensor at a time ---
    let io_before = eng.stats();
    let t0 = Instant::now();
    for _ in 0..passes {
        for t in &plan {
            let n = t.numel;
            eng.read(&format!("{}/fp16", t.name), &mut staging[..n * 2]).unwrap();
            f16_bytes_to_f32s(&staging[..n * 2], &mut scratch[..n]);
            spin(compute_time(t, ns_per_elem));
        }
    }
    let sync_wall = t0.elapsed().as_secs_f64();
    let sync_io = io_busy_delta(eng.as_ref(), io_before);
    let m_sync = metrics(sync_io, sync_io, sync_wall); // all I/O is stall

    // --- pipelined: window of 4, shared executor, arena-pooled scratch,
    // --- upconvert chained onto the compute-side stage pool ---
    let a = arena();
    let pool: Arc<dyn ParamBufferPool> =
        Arc::new(AdaptivePool::new(&SMOKE, 4, DType::F16, &a).unwrap());
    let exec = Arc::new(IoExecutor::new(4));
    let stage = Arc::new(StageExecutor::new(2));
    let f32_pool = Arc::new(F32Scratch::new(Arc::clone(&a)));
    let io_before = eng.stats();
    let t0 = Instant::now();
    let mut wait = 0.0;
    let mut fetch_submissions = 0u64;
    let mut prefetch_hits = 0u64;
    let mut prefetch_late = 0u64;
    for _ in 0..passes {
        let mut sw = Swapper::start(
            eng.clone(),
            pool.clone(),
            exec.clone(),
            stage.clone(),
            f32_pool.clone(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(4),
        );
        for t in &plan {
            let f = sw.next().unwrap();
            assert_eq!(f.desc.name, t.name, "plan order violated");
            spin(compute_time(t, ns_per_elem));
            f32_pool.put_buf(f.data); // consumer recycles, like the trainer
        }
        wait += sw.wait_secs();
        let swm = sw.metrics();
        fetch_submissions += swm.fetch_submissions;
        prefetch_hits += swm.prefetch_hits;
        prefetch_late += swm.prefetch_late;
    }
    let async_wall = t0.elapsed().as_secs_f64();
    let async_io = io_busy_delta(eng.as_ref(), io_before);
    let mut m_async = metrics(async_io, wait, async_wall);
    m_async.fetch_submissions = fetch_submissions;
    m_async.prefetch_hits = prefetch_hits;
    m_async.prefetch_late = prefetch_late;
    print_queue_busy("swapper/pipelined", eng.as_ref(), io_before);
    println!(
        "  fetch submissions {} / prefetch hits {} / late {} over {passes} passes",
        m_async.fetch_submissions, m_async.prefetch_hits, m_async.prefetch_late
    );

    for (mode, m, wall) in
        [("sequential", &m_sync, sync_wall), ("pipelined", &m_async, async_wall)]
    {
        table.row(vec![
            format!("swapper/{mode}"),
            format!("{wall:.3}"),
            format!("{:.3}", m.io_secs),
            format!("{:.3}", m.io_wait_secs),
            format!("{:.3}", m.io_overlap_secs()),
            format!("{:.1}%", m.io_overlap_frac() * 100.0),
        ]);
    }
    std::fs::remove_dir_all(&dir).ok();
    (m_async, sync_wall / async_wall)
}

fn optimizer_experiment(table: &mut Table) -> (StepMetrics, bool) {
    let n_groups = 6usize;
    let n = 120_000usize;
    let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
    let dir_a = tmp("opt-seq");
    let dir_b = tmp("opt-pipe");
    let eng_a = DirectEngine::new(&dir_a, 2, 1 << 28, 2).unwrap();
    let eng_b: Arc<dyn NvmeEngine> =
        Arc::new(DirectEngine::new(&dir_b, 2, 1 << 28, 2).unwrap());
    let mut rng = Xoshiro256::new(7);
    let mut states_a = Vec::new();
    let mut states_b = Vec::new();
    for g in 0..n_groups {
        let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        states_a
            .push(OptimState::init(&eng_a, &format!("g{g}"), &p0, StateDtype::F32).unwrap());
        states_b.push(
            OptimState::init(eng_b.as_ref(), &format!("g{g}"), &p0, StateDtype::F32)
                .unwrap(),
        );
    }
    let grads: Vec<Vec<f32>> = (0..n_groups)
        .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
        .collect();
    let steps = 5u64;

    // --- sequential reference ---
    let io_before = eng_a.stats();
    let t0 = Instant::now();
    for t in 1..=steps {
        for (g, st) in states_a.iter().enumerate() {
            st.step(&eng_a, &grads[g], t, 1.0, &hp, 1, &format!("g{g}/fp16")).unwrap();
        }
    }
    let seq_wall = t0.elapsed().as_secs_f64();
    let seq_io = io_busy_delta(&eng_a, io_before);
    let m_seq = metrics(seq_io, seq_io, seq_wall);

    // --- double-buffered pipeline (staging recycled via the arena) ---
    let aio = AsyncEngine::new(Arc::clone(&eng_b), 3);
    let opt_arena = arena();
    let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let keys: Vec<String> = (0..n_groups).map(|g| format!("g{g}/fp16")).collect();
    let io_before = eng_b.stats();
    let t0 = Instant::now();
    let mut wait = 0.0;
    for t in 1..=steps {
        let stats = step_groups_pipelined(
            &aio, &opt_arena, &states_b, &grad_refs, &keys, t, 1.0, &hp, 1,
        )
        .unwrap();
        wait += stats.wait_secs;
    }
    let pipe_wall = t0.elapsed().as_secs_f64();
    let pipe_io = io_busy_delta(eng_b.as_ref(), io_before);
    let m_pipe = metrics(pipe_io, wait, pipe_wall);
    print_queue_busy("optimizer/double-buffered", eng_b.as_ref(), io_before);

    // --- bit-identity across every stored artifact ---
    let mut identical = true;
    for g in 0..n_groups {
        for suffix in ["master", "adam_m", "adam_v", "fp16"] {
            let key = format!("g{g}/{suffix}");
            let len = eng_a.len_of(&key).unwrap();
            let mut a = vec![0u8; len];
            let mut b = vec![0u8; len];
            eng_a.read(&key, &mut a).unwrap();
            eng_b.read(&key, &mut b).unwrap();
            if a != b {
                identical = false;
                eprintln!("MISMATCH at {key}");
            }
        }
    }

    for (mode, m, wall) in [
        ("optimizer/sequential", &m_seq, seq_wall),
        ("optimizer/double-buffered", &m_pipe, pipe_wall),
    ] {
        table.row(vec![
            mode.to_string(),
            format!("{wall:.3}"),
            format!("{:.3}", m.io_secs),
            format!("{:.3}", m.io_wait_secs),
            format!("{:.3}", m.io_overlap_secs()),
            format!("{:.1}%", m.io_overlap_frac() * 100.0),
        ]);
    }
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
    (m_pipe, identical)
}

fn main() {
    let mut table = Table::new(vec![
        "stage",
        "wall (s)",
        "engine io (s)",
        "fg stall (s)",
        "hidden (s)",
        "hidden %",
    ]);
    let (m_swap, speedup) = swapper_experiment(&mut table);
    let (m_opt, identical) = optimizer_experiment(&mut table);
    common::emit(
        "bench_pipeline",
        "async multi-queue pipeline: I/O hidden behind compute",
        &table,
    );
    // the acceptance bar is combined: swapper + optimizer together
    // must hide ≥ 30% of all engine-busy I/O behind compute
    let total_io = m_swap.io_secs + m_opt.io_secs;
    let total_hidden = m_swap.io_overlap_secs() + m_opt.io_overlap_secs();
    let combined = if total_io > 0.0 { total_hidden / total_io } else { 0.0 };
    println!("swapper pipeline speedup over sequential: {speedup:.2}x");
    println!(
        "overlap: swapper {:.1}% / optimizer {:.1}% / combined {:.1}% of engine I/O hidden (target: combined ≥ 30%)",
        m_swap.io_overlap_frac() * 100.0,
        m_opt.io_overlap_frac() * 100.0,
        combined * 100.0
    );
    println!("optimizer state bit-identity (sync vs async): {identical}");
    let pass = combined >= 0.30 && identical;
    println!("ACCEPTANCE: {}", if pass { "PASS" } else { "FAIL" });
    if !pass {
        std::process::exit(1);
    }
}
