//! Coalesced + profile-replay fetch path bench: does the read side
//! really cut submissions, and does the recorded just-in-time schedule
//! hold the pinned staging watermark at or below the greedy window's?
//!
//! Streams the full SMOKE offloadable plan through the swapper four
//! ways over identical on-SSD bytes (per-tensor `{name}/fp16` keys AND
//! the packed `optim/sg{i}/fp16` super-group streams, seeded with the
//! same values):
//!
//! 1. **window** — per-tensor depth-window greedy fetch (the seed
//!    path and the submission-count baseline);
//! 2. **grouped** — coalesced ranged reads, still window-greedy (the
//!    `Cat::SwapBuf` depth-window baseline for replay: same fetch
//!    units, greedy discipline);
//! 3. **record** — coalesced + profile store, first step: traces the
//!    (key, offset, len, timing) schedule;
//! 4. **replay** — same store, later steps: rate-matched just-in-time
//!    issue against the recorded schedule, on a fresh arena so its
//!    peak watermark is measured in replay mode alone.
//!
//! Gates (deterministic, they set the exit code):
//!
//! 1. ≥2× fewer read submissions/step on the replayed coalesced path
//!    than the per-tensor window path;
//! 2. byte-identical delivery across all four runs (checksum over the
//!    exact f32 slices compute would upload, every pass);
//! 3. `Cat::SwapBuf` peak in replay mode ≤ the grouped depth-window
//!    baseline (just-in-time issue can only defer staging, never hold
//!    more in flight than the greedy window);
//! 4. every post-record pass actually replays (digest hit, no
//!    fallback).
//!
//! Stall (`wait_secs`) and prefetch hit/late distributions are
//! report-only — timing is nondeterministic on shared runners.  Emits
//! `bench_out/BENCH_prefetch.json`.

mod common;

use std::sync::Arc;

use memascend::bufpool::{AdaptivePool, ParamBufferPool};
use memascend::config::presets::SMOKE;
use memascend::dtype::{f32s_to_f16_bytes, DType};
use memascend::offload::{F32Scratch, FetchGroups, FetchOpts, ProfileStore, Swapper};
use memascend::optimizer::coalesce::fp16_stream_name;
use memascend::optimizer::{CoalescedLayout, StateDtype};
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, Cat, MemoryTracker, Mode, PinnedArena,
};
use memascend::ssd::{DirectEngine, IoExecutor, NvmeEngine};
use memascend::tensors::{inventory, TensorDesc};
use memascend::util::bench::Table;
use memascend::util::json::Json;
use memascend::util::stage::StageExecutor;

/// Window depth shared by every run — only the fetch discipline varies.
const DEPTH: usize = 4;
/// Replay safety lead (µs) subtracted from each recorded deadline.
const LEAD_US: u64 = 500;
const PASSES: usize = 3;

fn arena() -> Arc<PinnedArena> {
    let alloc = AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()));
    PinnedArena::new(Arc::new(alloc), ArenaConfig::default())
}

fn checksum(acc: u64, s: &[f32]) -> u64 {
    s.iter().fold(acc, |h, x| {
        h.wrapping_mul(0x100000001b3).wrapping_add(x.to_bits() as u64)
    })
}

struct RunStats {
    passes: usize,
    /// Engine-side reads across all passes (must match submissions).
    reads: u64,
    submissions: u64,
    hits: u64,
    late: u64,
    replays: u64,
    fallbacks: u64,
    /// Per-pass delivery checksum (asserted identical across passes).
    sum: u64,
    /// `Cat::SwapBuf` high-water mark on this run's private arena.
    peak: u64,
    wait_secs: f64,
}

/// Stream the plan `passes` times with a private scratch arena, so the
/// `Cat::SwapBuf` watermark reflects this run's discipline alone.
fn run_passes(
    engine: &Arc<DirectEngine>,
    plan: &[TensorDesc],
    passes: usize,
    groups: Option<&Arc<FetchGroups>>,
    profile: Option<&Arc<ProfileStore>>,
) -> RunStats {
    let pool_arena = arena();
    let pool: Arc<dyn ParamBufferPool> =
        Arc::new(AdaptivePool::new(&SMOKE, DEPTH, DType::F16, &pool_arena).unwrap());
    let scratch = Arc::new(F32Scratch::new(arena()));
    let exec = Arc::new(IoExecutor::new(4));
    let stage = Arc::new(StageExecutor::new(2));

    let reads0 = engine.stats().reads;
    let mut r = RunStats {
        passes,
        reads: 0,
        submissions: 0,
        hits: 0,
        late: 0,
        replays: 0,
        fallbacks: 0,
        sum: 0,
        peak: 0,
        wait_secs: 0.0,
    };
    for pass in 0..passes {
        let mut opts = FetchOpts::window(DEPTH);
        if let Some(g) = groups {
            opts = opts.with_groups(Arc::clone(g));
        }
        if let Some(p) = profile {
            opts = opts.with_profile(Arc::clone(p), LEAD_US);
        }
        let eng: Arc<dyn NvmeEngine> = Arc::clone(engine);
        let mut sw = Swapper::start(
            eng,
            pool.clone(),
            exec.clone(),
            stage.clone(),
            scratch.clone(),
            plan.to_vec(),
            |t| format!("{}/fp16", t.name),
            opts,
        );
        let mut pass_sum = 0u64;
        for want in plan {
            let got = sw.next().unwrap();
            assert_eq!(got.desc.name, want.name, "plan order violated");
            pass_sum = checksum(pass_sum, got.data.as_f32());
            scratch.put_buf(got.data);
        }
        if pass == 0 {
            r.sum = pass_sum;
        } else {
            assert_eq!(r.sum, pass_sum, "delivery diverged between passes");
        }
        let m = sw.metrics();
        r.submissions += m.fetch_submissions;
        r.hits += m.prefetch_hits;
        r.late += m.prefetch_late;
        r.replays += u64::from(m.replayed);
        r.fallbacks += u64::from(m.profile_fallback);
        r.wait_secs += sw.wait_secs();
    }
    r.reads = engine.stats().reads - reads0;
    r.peak = scratch.arena().tracker().peak(Cat::SwapBuf);
    r
}

fn main() {
    // seed: identical values on both the per-tensor fp16 keys and the
    // packed super-group streams, so every run reads the same bytes
    let dir = std::env::temp_dir().join(format!("ma-prefbench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let engine = Arc::new(DirectEngine::new(&dir, 2, 1 << 24, 2).unwrap());
    let plan: Vec<TensorDesc> =
        inventory(&SMOKE).into_iter().filter(|t| t.offloadable()).collect();
    for (i, t) in plan.iter().enumerate() {
        let vals = vec![i as f32 + 0.5; t.numel];
        let mut bytes = vec![0u8; t.numel * 2];
        f32s_to_f16_bytes(&vals, &mut bytes);
        engine.write(&format!("{}/fp16", t.name), &bytes).unwrap();
    }
    let members: Vec<(String, usize)> =
        plan.iter().map(|t| (t.name.clone(), t.numel)).collect();
    let layout = CoalescedLayout::plan(&members, StateDtype::F32, 1 << 22);
    let mut streams: Vec<Vec<u8>> =
        layout.super_numels.iter().map(|&n| vec![0u8; n * 2]).collect();
    for (i, t) in plan.iter().enumerate() {
        let (sg, off, numel) = layout.span_of(&t.name).unwrap();
        let vals = vec![i as f32 + 0.5; numel];
        f32s_to_f16_bytes(&vals, &mut streams[sg][off * 2..(off + numel) * 2]);
    }
    for (sg, bytes) in streams.iter().enumerate() {
        engine.write(&fp16_stream_name(sg), bytes).unwrap();
    }
    let groups = Arc::new(FetchGroups::from_layout(&layout));

    let window = run_passes(&engine, &plan, PASSES, None, None);
    let grouped = run_passes(&engine, &plan, PASSES, Some(&groups), None);
    let store = Arc::new(ProfileStore::new());
    let record = run_passes(&engine, &plan, 1, Some(&groups), Some(&store));
    let replay = run_passes(&engine, &plan, PASSES, Some(&groups), Some(&store));
    std::fs::remove_dir_all(&dir).ok();

    let per_pass = |r: &RunStats| r.reads as f64 / r.passes as f64;
    let cut = per_pass(&window) / per_pass(&replay);

    let mut table = Table::new(vec![
        "path",
        "passes",
        "reads/pass",
        "hits",
        "late",
        "peak SwapBuf B",
        "stall s",
    ]);
    for (name, r) in [
        ("window (per-tensor)", &window),
        ("grouped window", &grouped),
        ("record", &record),
        ("replay", &replay),
    ] {
        table.row(vec![
            name.to_string(),
            format!("{}", r.passes),
            format!("{:.1}", per_pass(r)),
            format!("{}", r.hits),
            format!("{}", r.late),
            format!("{}", r.peak),
            format!("{:.4}", r.wait_secs),
        ]);
    }
    common::emit("prefetch", "coalesced reads + profile replay vs depth window", &table);

    let submission_cut = cut >= 2.0;
    let identical = window.sum == grouped.sum
        && window.sum == record.sum
        && window.sum == replay.sum;
    let peak_ok = replay.peak <= grouped.peak;
    let replay_engaged = record.replays == 0
        && record.fallbacks == 0
        && replay.replays == replay.passes as u64
        && replay.fallbacks == 0;
    let accounting_ok =
        window.reads == window.submissions && replay.reads == replay.submissions;

    println!(
        "{} tensors/pass: {:.1} reads/pass windowed vs {:.1} replayed ({cut:.1}x cut), \
         replay peak {} B vs grouped-window {} B",
        plan.len(),
        per_pass(&window),
        per_pass(&replay),
        replay.peak,
        grouped.peak,
    );
    println!("byte-identity across all paths: {identical}");
    println!(
        "replay engaged on every post-record pass: {replay_engaged} \
         (hits {} / late {} over {} passes)",
        replay.hits, replay.late, replay.passes,
    );

    std::fs::create_dir_all(common::OUT_DIR).ok();
    let out = Json::obj(vec![
        ("tensors_per_pass", Json::from(plan.len())),
        ("window_reads_per_pass", Json::from(per_pass(&window))),
        ("grouped_reads_per_pass", Json::from(per_pass(&grouped))),
        ("replay_reads_per_pass", Json::from(per_pass(&replay))),
        ("submission_cut", Json::from(cut)),
        ("byte_identical", Json::from(identical)),
        ("swapbuf_peak_window", Json::from(window.peak)),
        ("swapbuf_peak_grouped_window", Json::from(grouped.peak)),
        ("swapbuf_peak_replay", Json::from(replay.peak)),
        ("replay_peak_ok", Json::from(peak_ok)),
        ("replay_hits", Json::from(replay.hits)),
        ("replay_late", Json::from(replay.late)),
        ("lead_us", Json::from(LEAD_US)),
        ("window_stall_secs", Json::from(window.wait_secs)),
        ("replay_stall_secs", Json::from(replay.wait_secs)),
    ]);
    let path = format!("{}/BENCH_prefetch.json", common::OUT_DIR);
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("[json] {path}"),
        Err(e) => eprintln!("warn: could not write {path}: {e}"),
    }

    let pass = submission_cut && identical && peak_ok && replay_engaged && accounting_ok;
    println!("ACCEPTANCE: {}", if pass { "PASS" } else { "FAIL" });
    if !pass {
        std::process::exit(1);
    }
}
