//! Checkpoint/recovery bench: cadence overhead + crash recovery.
//!
//! Three experiments over the journaled checkpoint subsystem, all at
//! the optimizer + journal level (no AOT artifacts needed, so the full
//! bench runs on plain CI runners):
//!
//! 1. **Cadence overhead (report-only)** — the same step sequence run
//!    with checkpointing off and with a commit every k steps.  A
//!    checkpoint is flush barriers + one journal record, not a data
//!    copy, so the tax should be a small fraction of step time; the
//!    fraction is printed and stored in the JSON but not gated
//!    (wall-clock on shared runners is noisy).
//! 2. **Recovery bit-identity (CI-gated)** — run to step N/2 under
//!    injected transient faults (absorbed by the bounded retry layer),
//!    flush, commit, drop every handle, reopen the storage root cold,
//!    replay the journal, rebuild the optimizer handles from metadata
//!    alone, and continue to step N.  Every stored stream
//!    (master/m/v/fp16) must be byte-identical to an uninterrupted
//!    fault-free run.
//! 3. **Torn-commit rollback (CI-gated)** — tear the newest journal
//!    slot with same-length garbage; a cold reload must fall back to
//!    the previous epoch and its key set must still validate.
//! 4. **Shadow-paged crash points (CI-gated)** — the full epoch cycle
//!    over a [`memascend::ckpt::ShadowEngine`]: commit every k steps
//!    with the flush → slot → flip sequence, then (a) rot the newest
//!    slot after the final commit — recovery must walk back one epoch
//!    and rerun bit-identically — and (b) kill between the slot write
//!    and the flip — the slot record must resume bit-identically.
//!    Reports the space cost of shadow paging: the peak bytes of live
//!    shadow extents (`shadow_overhead_peak_bytes`), sampled per step.
//!
//! Emits `bench_out/BENCH_recovery.json`.

mod common;

use std::sync::Arc;
use std::time::Instant;

use memascend::ckpt::{CkptState, Journal, ShadowEngine};
use memascend::optimizer::states::state_keys;
use memascend::optimizer::{
    flush_groups, step_groups_tiled, AdamParams, OptimState, StateDtype,
};
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, MemoryTracker, Mode, PinnedArena,
};
use memascend::ssd::{
    AsyncEngine, DirectEngine, FaultyEngine, NvmeEngine, OpMask, RetryEngine,
    RetryPolicy,
};
use memascend::util::bench::Table;
use memascend::util::json::Json;
use memascend::util::rng::Xoshiro256;
use memascend::util::stage::StageExecutor;

const SIZES: [usize; 3] = [200_000, 120_000, 60_000];
const TILE_BYTES: usize = 64 << 10;
const DEPTH: usize = 2;
const STEPS: u64 = 12;
const CKPT_EVERY: u64 = 2;

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ma-brec-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn arena() -> Arc<PinnedArena> {
    PinnedArena::new(
        Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
        ArenaConfig::default(),
    )
}

fn direct(dir: &std::path::Path) -> Arc<DirectEngine> {
    Arc::new(DirectEngine::new(dir, 2, 1 << 26, 1).unwrap())
}

/// Deterministic per-step gradients so every leg sees the same data.
fn grads_for(step: u64) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256::new(0xB0B ^ step);
    SIZES
        .iter()
        .map(|&n| (0..n).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn init_states(engine: &dyn NvmeEngine) -> Vec<OptimState> {
    let mut rng = Xoshiro256::new(17);
    SIZES
        .iter()
        .enumerate()
        .map(|(g, &n)| {
            let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            OptimState::init(engine, &format!("g{g}"), &vals, StateDtype::F32).unwrap()
        })
        .collect()
}

fn fp16_keys(states: &[OptimState]) -> Vec<String> {
    states.iter().map(|s| format!("{}/fp16", s.group)).collect()
}

fn one_step(
    aio: &AsyncEngine,
    stage: &StageExecutor,
    arena: &Arc<PinnedArena>,
    states: &[OptimState],
    t: u64,
) {
    let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
    let grads = grads_for(t);
    let gr: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    step_groups_tiled(
        aio,
        stage,
        arena,
        states,
        &gr,
        &fp16_keys(states),
        t,
        1.0,
        &hp,
        1,
        TILE_BYTES,
        DEPTH,
    )
    .unwrap();
}

/// Every logical key one epoch of `states` covers.
fn all_keys(states: &[OptimState]) -> Vec<String> {
    let mut keys = Vec::new();
    for st in states {
        keys.extend(state_keys(&st.group));
        keys.push(format!("{}/fp16", st.group));
    }
    keys
}

/// Journal record with the given key triples.
fn ckpt_with_keys(epoch: u64, steps_done: u64, keys: Vec<(String, usize, u8)>) -> CkptState {
    CkptState {
        epoch,
        steps_done,
        applied_steps: steps_done,
        seed: 17,
        model: "bench-recovery".into(),
        dtype: "f32".into(),
        corpus_rng: [1, 2, 3, 4],
        scale: 1.0,
        good_steps: 0,
        overflows: 0,
        growths: 0,
        tile_bytes: TILE_BYTES,
        tile_depth: DEPTH,
        prefetch_depth: 1,
        sched_lead_us: 2_000,
        act_host_budget: usize::MAX,
        keys,
        layout_digest: None,
        profile_digest: None,
    }
}

/// Record over a raw (un-shadowed) engine — everything at extent 0.
fn ckpt_state(
    epoch: u64,
    steps_done: u64,
    engine: &dyn NvmeEngine,
    states: &[OptimState],
) -> CkptState {
    let keys = all_keys(states)
        .into_iter()
        .map(|k| {
            let len = engine.len_of(&k).unwrap();
            (k, len, 0u8)
        })
        .collect();
    ckpt_with_keys(epoch, steps_done, keys)
}

/// The trainer's commit sequence over a shadow-paged stack: flush the
/// newest extents, write the slot record carrying the extent map, then
/// flip (`flip_after: false` = crash between slot write and flip).
fn commit_epoch(
    journal: &Journal,
    shadow: &Arc<ShadowEngine>,
    states: &[OptimState],
    epoch: u64,
    steps_done: u64,
    flip_after: bool,
) {
    flush_groups(shadow.as_ref(), states, &fp16_keys(states)).unwrap();
    let keys = all_keys(states)
        .into_iter()
        .map(|k| {
            let ext = shadow.newest_ext(&k);
            let len = shadow.len_of(&k).unwrap();
            (k, len, ext)
        })
        .collect();
    journal.commit(&ckpt_with_keys(epoch, steps_done, keys)).unwrap();
    if flip_after {
        shadow.flip();
    }
}

/// All stored streams of every group, for identity checks.
fn all_bytes(engine: &dyn NvmeEngine) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    for (g, &n) in SIZES.iter().enumerate() {
        for (key, width) in [
            (format!("g{g}/master"), 4usize),
            (format!("g{g}/adam_m"), 4),
            (format!("g{g}/adam_v"), 4),
            (format!("g{g}/fp16"), 2),
        ] {
            let mut buf = vec![0u8; n * width];
            engine.read(&key, &mut buf).unwrap();
            out.push(buf);
        }
    }
    out
}

struct CadenceRun {
    step_secs: f64,
    ckpt_secs: f64,
    epochs: u64,
}

/// Experiment 1: N steps, checkpointing every `interval` steps
/// (0 = off), timed.
fn run_cadence(tag: &str, interval: u64) -> CadenceRun {
    let dir = tmp(tag);
    let eng: Arc<dyn NvmeEngine> = direct(&dir);
    let states = init_states(eng.as_ref());
    let aio = AsyncEngine::new(eng.clone(), 2);
    let stage = StageExecutor::new(2);
    let arena = arena();
    let journal = Journal::new(eng.clone());
    let mut step_secs = 0.0;
    let mut ckpt_secs = 0.0;
    let mut epochs = 0u64;
    for t in 1..=STEPS {
        let t0 = Instant::now();
        one_step(&aio, &stage, &arena, &states, t);
        step_secs += t0.elapsed().as_secs_f64();
        if interval > 0 && t % interval == 0 {
            let t0 = Instant::now();
            flush_groups(eng.as_ref(), &states, &fp16_keys(&states)).unwrap();
            epochs += 1;
            journal.commit(&ckpt_state(epochs, t, eng.as_ref(), &states)).unwrap();
            ckpt_secs += t0.elapsed().as_secs_f64();
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    CadenceRun { step_secs, ckpt_secs, epochs }
}

struct RecoveryResult {
    identical: bool,
    injected: u64,
    retries: u64,
    resumed_epoch: u64,
}

/// Experiment 2: kill-and-restart under transient faults vs an
/// uninterrupted fault-free reference.
fn run_recovery() -> RecoveryResult {
    // uninterrupted reference
    let dir_ref = tmp("rec-ref");
    let eng_ref: Arc<dyn NvmeEngine> = direct(&dir_ref);
    let st_ref = init_states(eng_ref.as_ref());
    {
        let aio = AsyncEngine::new(eng_ref.clone(), 2);
        let stage = StageExecutor::new(2);
        let arena = arena();
        for t in 1..=STEPS {
            one_step(&aio, &stage, &arena, &st_ref, t);
        }
    }
    flush_groups(eng_ref.as_ref(), &st_ref, &fp16_keys(&st_ref)).unwrap();

    // interrupted run, first half under transient faults absorbed by
    // the retry layer (every distinct op fails once)
    let half = STEPS / 2;
    let dir = tmp("rec-live");
    let (injected, retries) = {
        let inner = direct(&dir);
        let faulty = Arc::new(FaultyEngine::transient(inner, 1, OpMask::ALL));
        let eng: Arc<dyn NvmeEngine> =
            Arc::new(RetryEngine::new(faulty.clone(), RetryPolicy::attempts(3)));
        let states = init_states(eng.as_ref());
        let aio = AsyncEngine::new(eng.clone(), 2);
        let stage = StageExecutor::new(2);
        let arena = arena();
        for t in 1..=half {
            one_step(&aio, &stage, &arena, &states, t);
        }
        flush_groups(eng.as_ref(), &states, &fp16_keys(&states)).unwrap();
        Journal::new(eng.clone())
            .commit(&ckpt_state(1, half, eng.as_ref(), &states))
            .unwrap();
        (
            faulty.injected.load(std::sync::atomic::Ordering::Relaxed),
            eng.stats().retries,
        )
        // every handle drops here: kill -9 right after the commit
    };

    // cold restart: replay the journal, rebuild handles from metadata
    // alone (no gather, no re-init), continue to STEPS
    let eng2: Arc<dyn NvmeEngine> = direct(&dir);
    let ck = Journal::new(eng2.clone()).load().expect("journal survives restart");
    ck.validate_keys(eng2.as_ref()).unwrap();
    let resumed: Vec<OptimState> = SIZES
        .iter()
        .enumerate()
        .map(|(g, &n)| OptimState {
            group: format!("g{g}"),
            numel: n,
            dtype: StateDtype::F32,
        })
        .collect();
    {
        let aio = AsyncEngine::new(eng2.clone(), 2);
        let stage = StageExecutor::new(2);
        let arena = arena();
        for t in (ck.steps_done + 1)..=STEPS {
            one_step(&aio, &stage, &arena, &resumed, t);
        }
    }
    flush_groups(eng2.as_ref(), &resumed, &fp16_keys(&resumed)).unwrap();

    let identical = all_bytes(eng_ref.as_ref()) == all_bytes(eng2.as_ref());
    std::fs::remove_dir_all(&dir_ref).ok();
    std::fs::remove_dir_all(&dir).ok();
    RecoveryResult { identical, injected, retries, resumed_epoch: ck.epoch }
}

/// Experiment 3: torn newest slot rolls back to the previous epoch.
fn run_torn() -> bool {
    let dir = tmp("torn");
    {
        let eng: Arc<dyn NvmeEngine> = direct(&dir);
        let states = init_states(eng.as_ref());
        let aio = AsyncEngine::new(eng.clone(), 2);
        let stage = StageExecutor::new(2);
        let arena = arena();
        one_step(&aio, &stage, &arena, &states, 1);
        flush_groups(eng.as_ref(), &states, &fp16_keys(&states)).unwrap();
        let journal = Journal::new(eng.clone());
        journal.commit(&ckpt_state(1, 1, eng.as_ref(), &states)).unwrap();
        journal.commit(&ckpt_state(2, 2, eng.as_ref(), &states)).unwrap();
        // epoch 2 is even -> slot A holds the newest record
        let slot = memascend::ckpt::journal::SLOT_A;
        let len = eng.len_of(slot).unwrap();
        eng.write(slot, &vec![0xA5u8; len]).unwrap();
    }
    let eng2: Arc<dyn NvmeEngine> = direct(&dir);
    let ck = Journal::new(eng2.clone()).load();
    let ok = match ck {
        Some(ck) => ck.epoch == 1 && ck.validate_keys(eng2.as_ref()).is_ok(),
        None => false,
    };
    std::fs::remove_dir_all(&dir).ok();
    ok
}

struct ShadowCrashResult {
    walkback_identical: bool,
    preflip_identical: bool,
    walkback_epoch: u64,
    overhead_peak_bytes: u64,
    /// Total bytes of the committed streams, for the overhead ratio.
    live_bytes: u64,
}

/// Experiment 4: shadow-paged epoch cycle with crash points between
/// epochs and between slot write and flip, plus the peak space cost.
fn run_shadow_crash() -> ShadowCrashResult {
    // uninterrupted reference
    let dir_ref = tmp("sh-ref");
    let eng_ref: Arc<dyn NvmeEngine> = direct(&dir_ref);
    let st_ref = init_states(eng_ref.as_ref());
    {
        let aio = AsyncEngine::new(eng_ref.clone(), 2);
        let stage = StageExecutor::new(2);
        let arena = arena();
        for t in 1..=STEPS {
            one_step(&aio, &stage, &arena, &st_ref, t);
        }
    }
    flush_groups(eng_ref.as_ref(), &st_ref, &fp16_keys(&st_ref)).unwrap();
    let ref_bytes = all_bytes(eng_ref.as_ref());
    let live_bytes: u64 = ref_bytes.iter().map(|b| b.len() as u64).sum();

    // crash point (a): full run with a commit/flip every CKPT_EVERY
    // steps, newest slot rots after the final commit — walk back one
    // epoch and rerun the lost window
    let dir = tmp("sh-live");
    let mut overhead_peak = 0u64;
    let mut epochs = 0u64;
    {
        let shadow = Arc::new(ShadowEngine::new(direct(&dir)));
        let states = init_states(shadow.as_ref());
        shadow.register(all_keys(&states));
        let journal = Journal::new(shadow.clone());
        let eng: Arc<dyn NvmeEngine> = shadow.clone();
        let aio = AsyncEngine::new(eng, 2);
        let stage = StageExecutor::new(2);
        let arena = arena();
        for t in 1..=STEPS {
            one_step(&aio, &stage, &arena, &states, t);
            shadow.advance();
            overhead_peak = overhead_peak.max(shadow.shadow_overhead_bytes());
            if t % CKPT_EVERY == 0 {
                epochs += 1;
                commit_epoch(&journal, &shadow, &states, epochs, t, true);
            }
        }
        // rot the newest slot (final epoch is even -> slot A)
        let slot = if epochs % 2 == 0 {
            memascend::ckpt::journal::SLOT_A
        } else {
            memascend::ckpt::journal::SLOT_B
        };
        let len = shadow.len_of(slot).unwrap();
        let mut buf = vec![0u8; len];
        shadow.read(slot, &mut buf).unwrap();
        buf[40] ^= 0xFF;
        shadow.write(slot, &buf).unwrap();
    }
    let shadow2 = Arc::new(ShadowEngine::new(direct(&dir)));
    let candidates = Journal::new(shadow2.clone()).load_all();
    let ck = candidates.into_iter().next().expect("previous epoch survives");
    let walkback_epoch = ck.epoch;
    ck.validate_keys(shadow2.inner().as_ref()).unwrap();
    shadow2.install(ck.extent_map());
    let resumed: Vec<OptimState> = SIZES
        .iter()
        .enumerate()
        .map(|(g, &n)| OptimState {
            group: format!("g{g}"),
            numel: n,
            dtype: StateDtype::F32,
        })
        .collect();
    {
        let eng: Arc<dyn NvmeEngine> = shadow2.clone();
        let aio = AsyncEngine::new(eng, 2);
        let stage = StageExecutor::new(2);
        let arena = arena();
        for t in (ck.steps_done + 1)..=STEPS {
            one_step(&aio, &stage, &arena, &resumed, t);
            shadow2.advance();
        }
    }
    flush_groups(shadow2.as_ref(), &resumed, &fp16_keys(&resumed)).unwrap();
    let walkback_identical = ref_bytes == all_bytes(shadow2.as_ref());

    // crash point (b): slot written, flip never happens — the durable
    // record must resume the just-committed state bit-identically
    let dir_b = tmp("sh-preflip");
    {
        let shadow = Arc::new(ShadowEngine::new(direct(&dir_b)));
        let states = init_states(shadow.as_ref());
        shadow.register(all_keys(&states));
        let journal = Journal::new(shadow.clone());
        let eng: Arc<dyn NvmeEngine> = shadow.clone();
        let aio = AsyncEngine::new(eng, 2);
        let stage = StageExecutor::new(2);
        let arena = arena();
        for t in 1..=STEPS {
            one_step(&aio, &stage, &arena, &states, t);
            shadow.advance();
            if t % CKPT_EVERY == 0 {
                // the final commit loses its flip (kill -9 in the gap)
                let flip = t != STEPS;
                commit_epoch(&journal, &shadow, &states, t / CKPT_EVERY, t, flip);
            }
        }
    }
    let shadow3 = Arc::new(ShadowEngine::new(direct(&dir_b)));
    let ck = Journal::new(shadow3.clone()).load().expect("final epoch is durable");
    ck.validate_keys(shadow3.inner().as_ref()).unwrap();
    shadow3.install(ck.extent_map());
    let preflip_identical =
        ck.steps_done == STEPS && ref_bytes == all_bytes(shadow3.as_ref());

    std::fs::remove_dir_all(&dir_ref).ok();
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir_b).ok();
    ShadowCrashResult {
        walkback_identical,
        preflip_identical,
        walkback_epoch,
        overhead_peak_bytes: overhead_peak,
        live_bytes,
    }
}

fn main() {
    // ---- experiment 1: cadence overhead (report-only) ----
    let off = run_cadence("cad-off", 0);
    let on = run_cadence("cad-on", CKPT_EVERY);
    let frac = on.ckpt_secs / (on.step_secs + on.ckpt_secs).max(1e-12);
    let mut t1 = Table::new(vec![
        "run",
        "steps",
        "epochs",
        "step secs",
        "ckpt secs",
        "ckpt fraction",
    ]);
    t1.row(vec![
        "interval 0".into(),
        STEPS.to_string(),
        off.epochs.to_string(),
        format!("{:.3}", off.step_secs),
        format!("{:.3}", off.ckpt_secs),
        "-".into(),
    ]);
    t1.row(vec![
        format!("interval {CKPT_EVERY}"),
        STEPS.to_string(),
        on.epochs.to_string(),
        format!("{:.3}", on.step_secs),
        format!("{:.3}", on.ckpt_secs),
        format!("{:.1}%", frac * 100.0),
    ]);
    common::emit(
        "bench_recovery_cadence",
        "checkpoint cadence overhead (flush barriers + journal commit, report-only)",
        &t1,
    );

    // ---- experiments 2-4: recovery + torn commit + shadow crash
    // points (CI-gated) ----
    let rec = run_recovery();
    let torn_ok = run_torn();
    let sh = run_shadow_crash();
    let overhead_pct = sh.overhead_peak_bytes as f64 / sh.live_bytes.max(1) as f64 * 100.0;
    let mut t2 = Table::new(vec![
        "check",
        "result",
        "detail",
    ]);
    t2.row(vec![
        "kill-and-restart bit-identity".into(),
        rec.identical.to_string(),
        format!(
            "resumed at epoch {}, {} faults injected, {} retries absorbed",
            rec.resumed_epoch, rec.injected, rec.retries
        ),
    ]);
    t2.row(vec![
        "torn-commit rollback".into(),
        torn_ok.to_string(),
        "newest slot torn -> previous epoch loads and validates".into(),
    ]);
    t2.row(vec![
        "between-epoch walk-back bit-identity".into(),
        sh.walkback_identical.to_string(),
        format!("newest slot rotted -> recovered epoch {}", sh.walkback_epoch),
    ]);
    t2.row(vec![
        "pre-flip crash bit-identity".into(),
        sh.preflip_identical.to_string(),
        "slot written, flip lost -> newest record resumes".into(),
    ]);
    t2.row(vec![
        "shadow space overhead".into(),
        format!("{} B", sh.overhead_peak_bytes),
        format!("peak live shadow extents = {overhead_pct:.0}% of stream bytes"),
    ]);
    common::emit(
        "bench_recovery_crash",
        "crash recovery under transient faults + shadow-paged crash points (CI-gated)",
        &t2,
    );

    std::fs::create_dir_all(common::OUT_DIR).ok();
    let out = Json::obj(vec![
        ("steps", Json::from(STEPS)),
        ("ckpt_interval", Json::from(CKPT_EVERY)),
        ("epochs_committed", Json::from(on.epochs)),
        ("step_secs_interval0", Json::from(off.step_secs)),
        ("step_secs_interval_k", Json::from(on.step_secs)),
        ("ckpt_secs", Json::from(on.ckpt_secs)),
        ("ckpt_fraction", Json::from(frac)),
        ("faults_injected", Json::from(rec.injected)),
        ("retries_absorbed", Json::from(rec.retries)),
        ("recovery_bit_identical", Json::from(rec.identical)),
        ("torn_commit_rolls_back", Json::from(torn_ok)),
        ("walkback_bit_identical", Json::from(sh.walkback_identical)),
        ("preflip_bit_identical", Json::from(sh.preflip_identical)),
        ("shadow_overhead_peak_bytes", Json::from(sh.overhead_peak_bytes)),
        ("shadow_overhead_pct_of_stream_bytes", Json::from(overhead_pct)),
    ]);
    let path = format!("{}/BENCH_recovery.json", common::OUT_DIR);
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("[json] {path}"),
        Err(e) => eprintln!("warn: could not write {path}: {e}"),
    }

    println!(
        "LATENCY (report-only): checkpoint tax {:.1}% of wall clock at interval {CKPT_EVERY}",
        frac * 100.0
    );
    println!(
        "recovery bit-identical: {} ({} faults injected, {} retries)",
        rec.identical, rec.injected, rec.retries
    );
    println!("torn-commit rollback: {torn_ok}");
    println!(
        "shadow walk-back bit-identical: {} (recovered epoch {})",
        sh.walkback_identical, sh.walkback_epoch
    );
    println!("pre-flip crash bit-identical: {}", sh.preflip_identical);
    println!(
        "shadow space overhead: peak {} bytes ({overhead_pct:.0}% of stream bytes)",
        sh.overhead_peak_bytes
    );
    let pass = rec.identical
        && rec.injected > 0
        && torn_ok
        && sh.walkback_identical
        && sh.preflip_identical
        && sh.overhead_peak_bytes > 0;
    println!("ACCEPTANCE: {}", if pass { "PASS" } else { "FAIL" });
    if !pass {
        std::process::exit(1);
    }
}
