//! Zero-copy PJRT boundary bench: does the weight path really upload
//! straight out of pinned lease memory, and is the lease-backed path
//! bit-identical to the owned-Vec path?
//!
//! Streams the full SMOKE offloadable plan (embed, 7 weights × layers,
//! lm_head) through the swapper pipeline twice — once with a healthy
//! scratch arena (fetches arrive as lease views) and once with a
//! starved one (every fetch degrades to an owned vector, the seed's
//! copy chain) — building per-tensor stage argument lists exactly the
//! way the trainer does and folding a checksum over the *exact slices
//! the PJRT client would upload* (`ValueRef::as_f32`, validated by
//! `check_args`).  Gates (all deterministic, they set the exit code):
//!
//! 1. `host_copy_bytes == 0` on the lease-backed weight path;
//! 2. the degraded path meters exactly the bytes it staged (the
//!    savings bar: what the seed copied per pass);
//! 3. the two paths' upload bytes are bit-identical;
//! 4. resident-norm arguments borrow storage in place (pointer
//!    equality — the old per-block `.to_vec()` is gone).
//!
//! Emits `bench_out/BENCH_runtime.json`.  Wall-clock per pass is
//! report-only (timing is nondeterministic on shared runners).

mod common;

use std::sync::Arc;
use std::time::Instant;

use memascend::bufpool::{AdaptivePool, ParamBufferPool};
use memascend::config::presets::SMOKE;
use memascend::dtype::{f32s_to_f16_bytes, DType};
use memascend::metrics::HostCopyMeter;
use memascend::offload::{F32Scratch, FetchOpts, Swapper};
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, MemoryTracker, Mode, PinnedArena,
};
use memascend::runtime::{check_args, ArgSpec, StageSpec, ValueRef};
use memascend::ssd::{DirectEngine, IoExecutor, NvmeEngine};
use memascend::tensors::{inventory, TensorDesc};
use memascend::train::weights::ResidentTensor;
use memascend::util::bench::Table;
use memascend::util::json::Json;
use memascend::util::stage::StageExecutor;

const PASSES: usize = 2;

fn arena(budget: Option<usize>) -> Arc<PinnedArena> {
    let alloc = AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()));
    PinnedArena::new(Arc::new(alloc), ArenaConfig { budget_bytes: budget, ..Default::default() })
}

fn checksum(acc: u64, s: &[f32]) -> u64 {
    s.iter().fold(acc, |h, x| {
        h.wrapping_mul(0x100000001b3).wrapping_add(x.to_bits() as u64)
    })
}

struct PassResult {
    copies: u64,
    sum: u64,
    bytes: u64,
    views: usize,
    secs: f64,
}

/// One full plan stream: fetch every tensor, validate it against its
/// stage spec, and checksum the exact upload slice.
fn stream_pass(
    engine: &Arc<DirectEngine>,
    plan: &[TensorDesc],
    starve_scratch: bool,
) -> PassResult {
    let pool_arena = arena(None);
    let pool: Arc<dyn ParamBufferPool> =
        Arc::new(AdaptivePool::new(&SMOKE, 4, DType::F16, &pool_arena).unwrap());
    // a 1 KiB budget refuses every lease: the pre-redesign copy chain
    let scratch_arena = arena(starve_scratch.then_some(1024));
    let scratch = Arc::new(F32Scratch::with_meter(scratch_arena, HostCopyMeter::new()));
    let exec = Arc::new(IoExecutor::new(4));
    let stage = Arc::new(StageExecutor::new(2));

    let mut sum = 0u64;
    let mut bytes = 0u64;
    let mut views = 0usize;
    let t0 = Instant::now();
    for _ in 0..PASSES {
        let eng: Arc<dyn NvmeEngine> = Arc::clone(engine);
        let mut sw = Swapper::start(
            eng,
            pool.clone(),
            exec.clone(),
            stage.clone(),
            scratch.clone(),
            plan.to_vec(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(4),
        );
        for t in plan {
            let f = sw.next().unwrap();
            assert_eq!(f.desc.name, t.name, "plan order violated");
            // the trainer's argument-building step, per tensor
            let spec = StageSpec {
                name: "upload".into(),
                file: String::new(),
                args: vec![ArgSpec {
                    name: t.name.clone(),
                    shape: t.shape.clone(),
                    dtype: "f32".into(),
                }],
                results: vec![],
            };
            let args = [f.data.as_value()];
            check_args("upload", &spec, &args).unwrap();
            // the exact slice buffer_from_host_buffer would consume
            let slice = args[0].as_f32().unwrap();
            sum = checksum(sum, slice);
            bytes += slice.len() as u64 * 4;
            views += usize::from(f.data.is_view());
            scratch.put_buf(f.data);
        }
    }
    PassResult {
        copies: scratch.meter().bytes(),
        sum,
        bytes,
        views,
        secs: t0.elapsed().as_secs_f64(),
    }
}

fn main() {
    // seeded engine shared by both passes: identical bytes on disk
    let dir = std::env::temp_dir().join(format!("ma-rtbench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let engine = Arc::new(DirectEngine::new(&dir, 2, 1 << 24, 2).unwrap());
    let plan: Vec<TensorDesc> =
        inventory(&SMOKE).into_iter().filter(|t| t.offloadable()).collect();
    let mut rng = memascend::util::rng::Xoshiro256::new(29);
    for t in &plan {
        let vals: Vec<f32> = (0..t.numel).map(|_| rng.normal() as f32).collect();
        let mut bytes = vec![0u8; t.numel * 2];
        f32s_to_f16_bytes(&vals, &mut bytes);
        engine.write(&format!("{}/fp16", t.name), &bytes).unwrap();
    }

    let lease = stream_pass(&engine, &plan, false);
    let degraded = stream_pass(&engine, &plan, true);
    std::fs::remove_dir_all(&dir).ok();

    // resident-norm arguments: ResidentTensor::value (the trainer's
    // resident_arg path) must alias the resident storage itself — the
    // seed staged a .to_vec() copy per block pass
    let norm_desc = inventory(&SMOKE)
        .into_iter()
        .find(|t| !t.offloadable())
        .expect("SMOKE has resident norms");
    let resident = ResidentTensor {
        data: vec![1.0f32; norm_desc.numel],
        m: vec![0.0; norm_desc.numel],
        v: vec![0.0; norm_desc.numel],
        desc: norm_desc,
    };
    let arg: ValueRef = resident.value();
    let resident_zero_copy =
        std::ptr::eq(arg.as_f32().unwrap().as_ptr(), resident.data.as_ptr());
    let resident_legacy_bytes =
        (SMOKE.layers * 2 + 1) * SMOKE.hidden * 4 * PASSES; // norms per pass

    let mut table = Table::new(vec![
        "path",
        "fetches",
        "lease views",
        "upload bytes",
        "host_copy_bytes",
        "secs",
    ]);
    for (name, r) in [("lease-backed", &lease), ("degraded (seed chain)", &degraded)] {
        table.row(vec![
            name.to_string(),
            format!("{}", plan.len() * PASSES),
            format!("{}", r.views),
            format!("{}", r.bytes),
            format!("{}", r.copies),
            format!("{:.4}", r.secs),
        ]);
    }
    common::emit("runtime", "zero-copy PJRT boundary: staging copies per path", &table);

    let identical = lease.sum == degraded.sum;
    let zero_copy = lease.copies == 0 && lease.views == plan.len() * PASSES;
    let degraded_metered = degraded.copies == degraded.bytes && degraded.views == 0;
    println!(
        "weight path: {} upload bytes/pass, lease path copies {} B, \
         degraded path copies {} B (the per-pass saving)",
        lease.bytes / PASSES as u64,
        lease.copies,
        degraded.copies / PASSES as u64,
    );
    println!("byte-identity lease vs owned: {identical}");
    println!("resident-norm borrow is zero-copy: {resident_zero_copy}");

    std::fs::create_dir_all(common::OUT_DIR).ok();
    let out = Json::obj(vec![
        ("tensors_per_pass", Json::from(plan.len())),
        ("passes", Json::from(PASSES)),
        ("upload_bytes", Json::from(lease.bytes)),
        ("host_copy_bytes_lease", Json::from(lease.copies)),
        ("host_copy_bytes_degraded", Json::from(degraded.copies)),
        ("byte_identical", Json::from(identical)),
        ("resident_borrow_zero_copy", Json::from(resident_zero_copy)),
        ("resident_legacy_bytes", Json::from(resident_legacy_bytes)),
        ("lease_secs", Json::from(lease.secs)),
        ("degraded_secs", Json::from(degraded.secs)),
    ]);
    let path = format!("{}/BENCH_runtime.json", common::OUT_DIR);
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("[json] {path}"),
        Err(e) => eprintln!("warn: could not write {path}: {e}"),
    }

    let pass = zero_copy && degraded_metered && identical && resident_zero_copy;
    println!("ACCEPTANCE: {}", if pass { "PASS" } else { "FAIL" });
    if !pass {
        std::process::exit(1);
    }
}
