//! Table II — peak system memory across training approaches.
//!
//! Paper setup: 24 GiB GPU, 128 GiB system memory; All-in-GPU vs
//! ZeRO-Offload vs ZeRO-Infinity on 1B/3B/8B models (ctx 4096, b 8).
//! OOM verdicts must match the paper exactly; absolute GiB are
//! accounting-model outputs.

mod common;

use memascend::accounting::gpumem::{gpu_memory, GpuMemOpts, Placement};
use memascend::accounting::sysmem::peak_sysmem;
use memascend::config::hardware::COMMODITY128;
use memascend::config::presets::{DENSE_1B, DENSE_3B, LLAMA31_8B};
use memascend::config::{MemAscendFlags, ModelSpec, TrainSpec};
use memascend::util::bench::Table;
use memascend::util::human::GIB;

/// System memory used by non-offloaded approaches (closed-form):
/// All-in-GPU keeps only the data path on the host; ZeRO-Offload pins
/// fp16 grads + fp32 master/m/v in host DRAM (pow2-rounded, as its
/// allocator does).
fn sysmem_non_infinity(spec: &ModelSpec, placement: Placement) -> f64 {
    let p = spec.param_count() as f64;
    let framework = 3.0; // loader + tokenizer + CUDA host structs, GiB
    match placement {
        Placement::AllInGpu => framework / 2.0 + p * 2.0 / GIB as f64 * 0.25,
        Placement::ZeroOffload => {
            // pinned: grads fp32 + master fp32 + m + v (pow2 each)
            let pinned: f64 = [4.0, 4.0, 4.0, 4.0]
                .iter()
                .map(|bpe| {
                    let bytes = (p * bpe) as u64;
                    bytes.next_power_of_two() as f64 / GIB as f64
                })
                .sum();
            framework + pinned
        }
        Placement::ZeroInfinity => unreachable!(),
    }
}

fn main() {
    let paper: &[(&str, &str, &str)] = &[
        ("All in GPU", "1B", "4.48"),
        ("ZeRO-Offload", "1B", "42.99"),
        ("ZeRO-Infinity", "1B", "39.04"),
        ("All in GPU", "3B", "VRAM OOM"),
        ("ZeRO-Offload", "3B", "104.17"),
        ("ZeRO-Infinity", "3B", "62.97"),
        ("All in GPU", "8B", "VRAM OOM"),
        ("ZeRO-Offload", "8B", "DRAM OOM"),
        ("ZeRO-Infinity", "8B", "91.76"),
    ];
    let models: &[(&str, &ModelSpec)] =
        &[("1B", &DENSE_1B), ("3B", &DENSE_3B), ("8B", &LLAMA31_8B)];
    let hw = &COMMODITY128;
    let gpu_opts = |pl| GpuMemOpts {
        placement: pl,
        grad_ckpt: true,
        liger: true,
        flash: true,
        offloaded_gc: false,
    };
    // motivational-experiment scale (the paper's Table II machine is a
    // single 24 GiB GPU; its workload is smaller than the H100 runs)
    let train = TrainSpec {
        batch: 4,
        seq: 2048,
        ranks: 1,
        prefetch_depth: 1,
        offloaded_gc: false,
        optim_tile_bytes: 0, // paper-parity (untiled) memory model
        flags: MemAscendFlags::baseline(),
        ..Default::default()
    };

    let mut t = Table::new(vec!["type", "model", "paper sysmem (GiB)", "measured (GiB)"]);
    for (ty, msize, paper_v) in paper {
        let (_, spec) = models.iter().find(|(n, _)| n == msize).unwrap();
        let measured = match *ty {
            "All in GPU" => {
                let g = gpu_memory(spec, &train, &gpu_opts(Placement::AllInGpu));
                if g.gib() > hw.vram_gib {
                    "VRAM OOM".to_string()
                } else {
                    format!("{:.2}", sysmem_non_infinity(spec, Placement::AllInGpu))
                }
            }
            "ZeRO-Offload" => {
                let g = gpu_memory(spec, &train, &gpu_opts(Placement::ZeroOffload));
                let s = sysmem_non_infinity(spec, Placement::ZeroOffload);
                if g.gib() > hw.vram_gib {
                    "VRAM OOM".to_string()
                } else if s > hw.dram_gib {
                    "DRAM OOM".to_string()
                } else {
                    format!("{s:.2}")
                }
            }
            _ => {
                let b = peak_sysmem(spec, &train, hw);
                let g = gpu_memory(spec, &train, &gpu_opts(Placement::ZeroInfinity));
                if g.gib() > hw.vram_gib {
                    "VRAM OOM".to_string()
                } else if b.gib() > hw.dram_gib {
                    format!("{:.2} (DRAM OOM)", b.gib())
                } else {
                    format!("{:.2}", b.gib())
                }
            }
        };
        t.row(vec![ty.to_string(), msize.to_string(), paper_v.to_string(), measured]);
    }
    common::emit("table2", "peak system memory by training approach", &t);
}
