//! Table IV — end-to-end throughput improvement, ZeRO-Infinity →
//! MemAscend, Configurations 1 & 2 (both with the direct engine, as in
//! the paper — the fs baseline "is unstable and prone to hanging").
//! Projection from the calibrated step-time model; the structure to
//! match: gains positive everywhere, larger on the slower CPU (C2),
//! larger at smaller batch.

mod common;

use memascend::accounting::perfmodel::{step_time, Calib};
use memascend::config::hardware::{CONFIG1, CONFIG2};
use memascend::config::{MemAscendFlags, TrainSpec};
use memascend::util::bench::Table;

fn main() {
    // (model, batch C1, batch C2, paper C1 %, paper C2 %)
    let rows: &[(&str, usize, usize, f64, f64)] = &[
        ("llama3.1-8b", 8, 8, 6.97, 12.91),
        ("llama3.1-8b", 80, 20, 2.72, 7.52),
        ("qwen2.5-7b", 8, 8, 5.53, 14.02),
        ("qwen2.5-7b", 64, 20, 3.73, 8.36),
        ("qwen2.5-14b", 8, 4, 6.45, 18.86),
        ("qwen2.5-14b", 64, 16, 3.28, 6.77),
        ("qwen2.5-32b", 8, 4, 5.64, 18.43),
        ("qwen2.5-32b", 48, 8, 2.89, 16.42),
    ];
    let calib = Calib::default();
    let imp = |model: &str, batch: usize, hw| {
        let m = memascend::config::ModelSpec::by_name(model).unwrap();
        let mut zi_flags = MemAscendFlags::baseline();
        zi_flags.direct_nvme = true; // both sides use the direct engine
        let mk = |flags| TrainSpec {
            batch,
            seq: 4096,
            ranks: 2,
            prefetch_depth: 1,
            flags,
            ..Default::default()
        };
        let zi = step_time(m, &mk(zi_flags), hw, &calib).total();
        let ma = step_time(m, &mk(MemAscendFlags::memascend()), hw, &calib).total();
        (zi / ma - 1.0) * 100.0
    };
    let mut t = Table::new(vec![
        "model",
        "batch (C1/C2)",
        "C1 paper %",
        "C1 measured %",
        "C2 paper %",
        "C2 measured %",
    ]);
    for (model, b1, b2, p1, p2) in rows {
        t.row(vec![
            model.to_string(),
            format!("{b1} / {b2}"),
            format!("{p1:.2}"),
            format!("{:.2}", imp(model, *b1, &CONFIG1)),
            format!("{p2:.2}"),
            format!("{:.2}", imp(model, *b2, &CONFIG2)),
        ]);
    }
    common::emit("table4", "end-to-end throughput improvement ZI -> MA", &t);
}
