//! Multi-job tenancy bench: three CI-gated bars over the shared
//! substrate (one device, one submission queue, one pinned arena), all
//! at the optimizer level so the full bench runs on plain CI runners
//! (no AOT artifacts needed):
//!
//! 1. **Solo-vs-shared byte identity (CI-gated)** — each of three jobs
//!    runs the same deterministic step sequence once alone on its own
//!    stack and once as a co-tenant ([`memascend::jobs::ScopedEngine`]
//!    key prefixes, namespaced arena, weighted lanes, concurrent
//!    threads under a [`memascend::jobs::JobRegistry`]).  Every stored
//!    stream (master/m/v/fp16) must be byte-identical between the two
//!    runs, and the per-namespace charged bytes must sum to the shared
//!    arena's global ledger exactly.
//! 2. **Weighted-fair service share (CI-gated)** — a single-worker
//!    executor with a held-back backlog: two jobs at weights 3:1
//!    enqueue equal-cost tasks while the worker is blocked, then the
//!    DWRR drain order is recorded.  In the contended prefix the
//!    served-task ratio must track the weight ratio within 20%
//!    (deterministic: all arrivals precede the first dispatch), and
//!    every task must complete (work conservation).
//! 3. **Fault isolation (CI-gated)** — two co-tenants; one gets a
//!    persistent injected NVMe fault under the bounded retry layer.
//!    Only that job may fail: the registry must report it `Failed`
//!    with exactly one `JobFailed` event, while the clean co-tenant
//!    finishes and stays byte-identical to its solo reference.
//!
//! Emits `bench_out/BENCH_tenancy.json`.

mod common;

use std::sync::{mpsc, Arc, Mutex};

use memascend::jobs::{JobRegistry, JobState, ScopedEngine};
use memascend::metrics::StepMetrics;
use memascend::optimizer::{step_groups_tiled, AdamParams, OptimState, StateDtype};
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, MemoryTracker, Mode, PinnedArena, MAX_NAMESPACES,
};
use memascend::ssd::{
    AsyncEngine, DirectEngine, FaultyEngine, IoExecutor, IoSnapshot, JobId,
    NvmeEngine, OpMask, RetryEngine, RetryPolicy,
};
use memascend::util::bench::Table;
use memascend::util::events::{EventKind, EventSink, MemorySink};
use memascend::util::json::Json;
use memascend::util::rng::Xoshiro256;
use memascend::util::stage::StageExecutor;

const SIZES: [usize; 3] = [150_000, 90_000, 45_000];
const TILE_BYTES: usize = 64 << 10;
const DEPTH: usize = 2;
const STEPS: u64 = 6;
/// Co-tenants in the identity experiment (device lanes 1..=TENANTS).
const TENANTS: u16 = 3;

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ma-bten-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn arena() -> Arc<PinnedArena> {
    PinnedArena::new(
        Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
        ArenaConfig::default(),
    )
}

fn direct(dir: &std::path::Path) -> Arc<DirectEngine> {
    Arc::new(DirectEngine::new(dir, 2, 1 << 27, 1).unwrap())
}

/// Deterministic per-job, per-step gradients: a job's data stream is
/// identical whether it runs solo or co-tenant.
fn grads_for(job: u16, step: u64) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256::new(((job as u64) << 32) ^ step ^ 0xB0B);
    SIZES
        .iter()
        .map(|&n| (0..n).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn init_states(engine: &dyn NvmeEngine, job: u16) -> Vec<OptimState> {
    let mut rng = Xoshiro256::new(1000 + job as u64);
    SIZES
        .iter()
        .enumerate()
        .map(|(g, &n)| {
            let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            OptimState::init(engine, &format!("g{g}"), &vals, StateDtype::F32).unwrap()
        })
        .collect()
}

fn fp16_keys(states: &[OptimState]) -> Vec<String> {
    states.iter().map(|s| format!("{}/fp16", s.group)).collect()
}

fn one_step(
    aio: &AsyncEngine,
    stage: &StageExecutor,
    arena: &Arc<PinnedArena>,
    states: &[OptimState],
    t: u64,
    job: u16,
) -> anyhow::Result<()> {
    let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
    let grads = grads_for(job, t);
    let gr: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    step_groups_tiled(
        aio,
        stage,
        arena,
        states,
        &gr,
        &fp16_keys(states),
        t,
        1.0,
        &hp,
        1,
        TILE_BYTES,
        DEPTH,
    )?;
    Ok(())
}

/// All stored streams of every group, read through `engine` — through
/// a job's [`ScopedEngine`] these are its private key-prefixed copies.
fn all_bytes(engine: &dyn NvmeEngine) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    for (g, &n) in SIZES.iter().enumerate() {
        for (key, width) in [
            (format!("g{g}/master"), 4usize),
            (format!("g{g}/adam_m"), 4),
            (format!("g{g}/adam_v"), 4),
            (format!("g{g}/fp16"), 2),
        ] {
            let mut buf = vec![0u8; n * width];
            engine.read(&key, &mut buf).unwrap();
            out.push(buf);
        }
    }
    out
}

/// One job alone on its own full stack: the byte-identity reference.
fn run_solo(job: u16) -> Vec<Vec<u8>> {
    let dir = tmp(&format!("solo{job}"));
    let eng: Arc<dyn NvmeEngine> = direct(&dir);
    let states = init_states(eng.as_ref(), job);
    let aio = AsyncEngine::new(eng.clone(), 2);
    let stage = StageExecutor::new(2);
    let arena = arena();
    for t in 1..=STEPS {
        one_step(&aio, &stage, &arena, &states, t, job).unwrap();
    }
    let bytes = all_bytes(eng.as_ref());
    std::fs::remove_dir_all(&dir).ok();
    bytes
}

struct SharedRun {
    per_job_bytes: Vec<Vec<Vec<u8>>>,
    all_finished: bool,
    ns_sum_matches_ledger: bool,
}

/// All jobs concurrently on ONE device + executor + arena, each through
/// its scoped view, step loops driven by the registry's threads.
fn run_shared() -> SharedRun {
    let dir = tmp("shared");
    let base: Arc<dyn NvmeEngine> = direct(&dir);
    let ioq = Arc::new(IoExecutor::new(2));
    let shared_arena = arena();
    let stage = Arc::new(StageExecutor::new(2));
    let sink = MemorySink::new();
    let reg = JobRegistry::new(sink.clone() as Arc<dyn EventSink>);
    for j in 1..=TENANTS {
        let job = JobId(j);
        // distinct weights: shares differ, bytes must not
        ioq.set_weight(job, j as u32);
        let scoped: Arc<dyn NvmeEngine> = Arc::new(ScopedEngine::new(base.clone(), job));
        let states = init_states(scoped.as_ref(), j);
        let aio = AsyncEngine::with_executor(scoped, ioq.clone()).for_job(job);
        let ns = shared_arena.namespace(job.lane() as u32);
        let stage = stage.clone();
        reg.spawn(&format!("tenant{j}"), job, STEPS, move |t| {
            one_step(&aio, &stage, &ns, &states, t + 1, j)?;
            Ok(StepMetrics { step: t + 1, ..Default::default() })
        });
    }
    reg.join_all();
    let all_finished =
        (1..=TENANTS).all(|j| reg.state(JobId(j)) == Some(JobState::Finished));
    let per_job_bytes = (1..=TENANTS)
        .map(|j| {
            let scoped = ScopedEngine::new(base.clone(), JobId(j));
            all_bytes(&scoped)
        })
        .collect();
    let ns_sum: usize = (0..MAX_NAMESPACES)
        .map(|ns| shared_arena.ns_stats(ns).charged)
        .sum();
    let ns_sum_matches_ledger = ns_sum == shared_arena.stats().reserved_bytes;
    std::fs::remove_dir_all(&dir).ok();
    SharedRun { per_job_bytes, all_finished, ns_sum_matches_ledger }
}

struct FairResult {
    served_heavy: usize,
    served_light: usize,
    ratio: f64,
    conserved: bool,
    snap: IoSnapshot,
}

/// Deterministic DWRR drain: enqueue the whole contended backlog while
/// a single worker is parked on a blocker task, then record the order.
fn run_fairshare() -> FairResult {
    const PER_JOB: usize = 40;
    const COST: u64 = 32 * 1024; // half a quantum unit
    let exec = Arc::new(IoExecutor::new(1));
    let (heavy, light) = (JobId(1), JobId(2));
    exec.set_weight(heavy, 3);
    exec.set_weight(light, 1);
    let (started_tx, started_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    exec.submit(move || {
        started_tx.send(()).unwrap();
        release_rx.recv().unwrap();
    });
    started_rx.recv().unwrap(); // the worker is parked; arrivals below all precede dispatch
    let order: Arc<Mutex<Vec<u16>>> = Arc::new(Mutex::new(Vec::new()));
    let (done_tx, done_rx) = mpsc::channel();
    for _ in 0..PER_JOB {
        for job in [heavy, light] {
            let order = order.clone();
            let done = done_tx.clone();
            exec.submit_for(job, COST, move || {
                order.lock().unwrap().push(job.0);
                done.send(()).unwrap();
            });
        }
    }
    release_tx.send(()).unwrap();
    for _ in 0..PER_JOB * 2 {
        done_rx.recv().unwrap();
    }
    let order = order.lock().unwrap().clone();
    // contended prefix: both lanes still backlogged for the first
    // PER_JOB dispatches (5 full DWRR rounds at these costs/weights)
    let served_heavy = order[..PER_JOB].iter().filter(|&&j| j == heavy.0).count();
    let served_light = PER_JOB - served_heavy;
    let ratio = served_heavy as f64 / served_light.max(1) as f64;
    let mut snap = IoSnapshot::default();
    exec.fill_job_lanes(&mut snap);
    let conserved = order.len() == PER_JOB * 2
        && snap.job_ops[heavy.lane()] == PER_JOB as u64
        && snap.job_ops[light.lane()] == PER_JOB as u64;
    FairResult { served_heavy, served_light, ratio, conserved, snap }
}

struct IsoResult {
    clean_finished: bool,
    faulted_failed: bool,
    one_failure_event_on_faulted_job: bool,
    co_tenant_identical: bool,
}

/// One clean tenant + one tenant whose every data op fails persistently
/// under the bounded retry layer; only the faulted job may abort.
fn run_isolation(clean_solo_ref: &[Vec<u8>]) -> IsoResult {
    let dir = tmp("iso");
    let base: Arc<dyn NvmeEngine> = direct(&dir);
    let ioq = Arc::new(IoExecutor::new(2));
    let shared_arena = arena();
    let stage = Arc::new(StageExecutor::new(2));
    let sink = MemorySink::new();
    let reg = JobRegistry::new(sink.clone() as Arc<dyn EventSink>);
    {
        let job = JobId(1);
        let scoped: Arc<dyn NvmeEngine> = Arc::new(ScopedEngine::new(base.clone(), job));
        let states = init_states(scoped.as_ref(), 1);
        let aio = AsyncEngine::with_executor(scoped, ioq.clone()).for_job(job);
        let ns = shared_arena.namespace(job.lane() as u32);
        let stage = stage.clone();
        reg.spawn("clean", job, STEPS, move |t| {
            one_step(&aio, &stage, &ns, &states, t + 1, 1)?;
            Ok(StepMetrics { step: t + 1, ..Default::default() })
        });
    }
    {
        let job = JobId(2);
        let scoped: Arc<dyn NvmeEngine> = Arc::new(ScopedEngine::new(base.clone(), job));
        let faulty: Arc<dyn NvmeEngine> =
            Arc::new(FaultyEngine::transient(scoped, u32::MAX, OpMask::DATA));
        let retried: Arc<dyn NvmeEngine> =
            Arc::new(RetryEngine::new(faulty, RetryPolicy::attempts(3)));
        reg.spawn("faulted", job, STEPS, move |_| {
            // the job's first unit of work: initialize its states
            // through its (broken) storage view — retry exhausts, the
            // error fails this job and nothing else
            let mut rng = Xoshiro256::new(7);
            let vals: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
            OptimState::init(retried.as_ref(), "g0", &vals, StateDtype::F32)?;
            Ok(StepMetrics::default())
        });
    }
    reg.join_all();
    let failures: Vec<_> = sink
        .events()
        .into_iter()
        .filter(|e| e.kind == EventKind::JobFailed)
        .collect();
    let scoped1 = ScopedEngine::new(base.clone(), JobId(1));
    let out = IsoResult {
        clean_finished: reg.state(JobId(1)) == Some(JobState::Finished),
        faulted_failed: reg.state(JobId(2)) == Some(JobState::Failed),
        one_failure_event_on_faulted_job: failures.len() == 1
            && failures[0].job == JobId(2),
        co_tenant_identical: all_bytes(&scoped1) == clean_solo_ref,
    };
    std::fs::remove_dir_all(&dir).ok();
    out
}

fn main() {
    // --- experiment 1: solo references, then the shared co-tenant run
    let solo: Vec<Vec<Vec<u8>>> = (1..=TENANTS).map(run_solo).collect();
    let shared = run_shared();
    let identical: Vec<bool> = solo
        .iter()
        .zip(&shared.per_job_bytes)
        .map(|(a, b)| a == b)
        .collect();
    let mut t = Table::new(vec!["job", "weight", "solo==shared", "state finished"]);
    for j in 0..TENANTS as usize {
        t.row(vec![
            format!("tenant{}", j + 1),
            (j + 1).to_string(),
            identical[j].to_string(),
            shared.all_finished.to_string(),
        ]);
    }
    common::emit("bench_tenancy_identity", "co-tenant byte identity (CI-gated)", &t);

    // --- experiment 2: weighted-fair drain order
    let fair = run_fairshare();
    let mut t2 = Table::new(vec!["lane", "weight", "served in contended prefix", "bytes total"]);
    t2.row(vec![
        "heavy".into(),
        "3".into(),
        fair.served_heavy.to_string(),
        fair.snap.job_bytes[JobId(1).lane()].to_string(),
    ]);
    t2.row(vec![
        "light".into(),
        "1".into(),
        fair.served_light.to_string(),
        fair.snap.job_bytes[JobId(2).lane()].to_string(),
    ]);
    common::emit("bench_tenancy_fairshare", "DWRR service shares (CI-gated)", &t2);

    // --- experiment 3: fault isolation
    let iso = run_isolation(&solo[0]);

    std::fs::create_dir_all(common::OUT_DIR).ok();
    let out = Json::obj(vec![
        ("tenants", Json::from(TENANTS as u64)),
        ("steps", Json::from(STEPS)),
        (
            "identity_per_job",
            Json::Arr(identical.iter().map(|&b| Json::from(b)).collect()),
        ),
        ("all_jobs_finished", Json::from(shared.all_finished)),
        ("ns_charges_sum_to_ledger", Json::from(shared.ns_sum_matches_ledger)),
        ("fair_weight_ratio", Json::from(3.0)),
        ("fair_served_ratio", Json::from(fair.ratio)),
        ("fair_work_conserving", Json::from(fair.conserved)),
        ("isolation_clean_finished", Json::from(iso.clean_finished)),
        ("isolation_faulted_failed", Json::from(iso.faulted_failed)),
        (
            "isolation_single_failure_event",
            Json::from(iso.one_failure_event_on_faulted_job),
        ),
        ("isolation_co_tenant_identical", Json::from(iso.co_tenant_identical)),
    ]);
    let path = format!("{}/BENCH_tenancy.json", common::OUT_DIR);
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("[json] {path}"),
        Err(e) => eprintln!("warn: could not write {path}: {e}"),
    }

    println!(
        "byte identity solo vs shared: {identical:?}; ns charges sum to ledger: {}",
        shared.ns_sum_matches_ledger
    );
    println!(
        "weighted-fair contended share: {}:{} (ratio {:.2}, target 3.00 +/- 20%); conserved: {}",
        fair.served_heavy, fair.served_light, fair.ratio, fair.conserved
    );
    println!(
        "fault isolation: clean finished {} / faulted failed {} / single event {} / co-tenant identical {}",
        iso.clean_finished, iso.faulted_failed, iso.one_failure_event_on_faulted_job,
        iso.co_tenant_identical
    );

    // CI gates
    assert!(identical.iter().all(|&b| b), "solo-vs-shared byte identity violated");
    assert!(shared.all_finished, "a co-tenant did not finish");
    assert!(shared.ns_sum_matches_ledger, "namespace charges diverged from the ledger");
    assert!(
        (fair.ratio - 3.0).abs() / 3.0 <= 0.20,
        "served ratio {:.2} off the 3:1 weights by more than 20%",
        fair.ratio
    );
    assert!(fair.conserved, "DWRR dropped or duplicated work");
    assert!(iso.clean_finished, "clean co-tenant was dragged down");
    assert!(iso.faulted_failed, "persistently faulted job did not fail");
    assert!(iso.one_failure_event_on_faulted_job, "failure events misattributed");
    assert!(iso.co_tenant_identical, "co-tenant bytes diverged under a neighbor's fault");
    println!("ACCEPTANCE: PASS");
}
