//! Staged-tile optimizer pipeline bench: does fixed-byte tiling cap
//! peak pinned DRAM independent of group size, at no step-time cost?
//!
//! For one parameter group grown 1× → 8× at a fixed tile size, this
//! measures:
//!
//! 1. **peak pinned optimizer staging** (arena `charged_peak` under
//!    `Cat::OptimBuf` + `Cat::SwapBuf`) of the tiled driver — the
//!    acceptance bar is *flat within one tile* across the 8× growth,
//!    while the whole-group working set (3 × group bytes) grows 8×;
//! 2. **step latency** of the tiled driver vs the untiled
//!    double-buffered pipeline on identical data (target: within 10%,
//!    or faster — within one group the tiled driver overlaps fetch,
//!    Adam, downconvert, and write-back where the whole-group path is
//!    serial);
//! 3. **byte-identity** of every stored artifact (master/m/v/fp16)
//!    against the sequential `OptimState::step` reference.
//!
//! Emits `bench_out/BENCH_tiling.json`.  The memory and identity bars
//! are deterministic and gate the exit code; the latency ratio is a
//! sub-second wall-clock sample, nondeterministic on shared CI
//! runners, so it is report-only (target ≤ 1.10×, printed and stored
//! in the JSON, never fed to the exit code).

mod common;

use std::sync::Arc;
use std::time::Instant;

use memascend::optimizer::{
    step_groups_pipelined, step_groups_tiled, AdamParams, OptimState, StateDtype,
    TILE_PIPELINE_DEPTH,
};
use memascend::pinned::{
    AlignedAllocator, ArenaConfig, Cat, MemoryTracker, Mode, PinnedArena,
};
use memascend::ssd::{AsyncEngine, DirectEngine, NvmeEngine};
use memascend::util::bench::Table;
use memascend::util::json::Json;
use memascend::util::rng::Xoshiro256;
use memascend::util::stage::StageExecutor;

/// Fixed tile size the sweep holds constant.  Small enough that even
/// the 1x group runs a *saturated* pipeline window (8 tiles >> depth):
/// peak staging then depends only on the window, never the group.
const TILE_BYTES: usize = 128 << 10;
/// Smallest group: 1 MiB per f32 stream (8 tiles), grown up to 8x.
const BASE_ELEMS: usize = 256 * 1024;
const WARMUP_STEPS: u64 = 1;
const TIMED_STEPS: u64 = 2;

fn arena() -> Arc<PinnedArena> {
    let alloc = AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()));
    PinnedArena::new(Arc::new(alloc), ArenaConfig::default())
}

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ma-tile-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

struct SizeResult {
    elems: usize,
    peak_pinned: usize,
    tiled_secs: f64,
    untiled_secs: f64,
    identical: bool,
}

fn run_size(mult: usize) -> SizeResult {
    let n = BASE_ELEMS * mult;
    let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
    let mut rng = Xoshiro256::new(17 + mult as u64);
    let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let grads: Vec<Vec<f32>> = (0..(WARMUP_STEPS + TIMED_STEPS))
        .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
        .collect();

    let dir_seq = tmp(&format!("seq-{mult}"));
    let dir_unt = tmp(&format!("unt-{mult}"));
    let dir_til = tmp(&format!("til-{mult}"));
    let eng_seq = DirectEngine::new(&dir_seq, 2, (n as u64 * 16).max(1 << 24), 1).unwrap();
    let eng_unt: Arc<dyn NvmeEngine> =
        Arc::new(DirectEngine::new(&dir_unt, 2, (n as u64 * 16).max(1 << 24), 1).unwrap());
    let eng_til: Arc<dyn NvmeEngine> =
        Arc::new(DirectEngine::new(&dir_til, 2, (n as u64 * 16).max(1 << 24), 1).unwrap());
    let st_seq = OptimState::init(&eng_seq, "g0", &p0, StateDtype::F32).unwrap();
    let st_unt =
        OptimState::init(eng_unt.as_ref(), "g0", &p0, StateDtype::F32).unwrap();
    let st_til =
        OptimState::init(eng_til.as_ref(), "g0", &p0, StateDtype::F32).unwrap();
    let aio_unt = AsyncEngine::new(Arc::clone(&eng_unt), 3);
    let aio_til = AsyncEngine::new(Arc::clone(&eng_til), 3);
    let stage = StageExecutor::new(2);
    let arena_unt = arena();
    let arena_til = arena();
    let keys = ["g0/fp16".to_string()];

    let mut tiled_secs = 0.0;
    let mut untiled_secs = 0.0;
    for (i, g) in grads.iter().enumerate() {
        let t = i as u64 + 1;
        let gr = [g.as_slice()];
        st_seq.step(&eng_seq, g, t, 1.0, &hp, 1, "g0/fp16").unwrap();
        let t0 = Instant::now();
        step_groups_pipelined(
            &aio_unt,
            &arena_unt,
            std::slice::from_ref(&st_unt),
            &gr,
            &keys,
            t,
            1.0,
            &hp,
            1,
        )
        .unwrap();
        if t > WARMUP_STEPS {
            untiled_secs += t0.elapsed().as_secs_f64();
        }
        let t0 = Instant::now();
        step_groups_tiled(
            &aio_til,
            &stage,
            &arena_til,
            std::slice::from_ref(&st_til),
            &gr,
            &keys,
            t,
            1.0,
            &hp,
            1,
            TILE_BYTES,
            TILE_PIPELINE_DEPTH,
        )
        .unwrap();
        if t > WARMUP_STEPS {
            tiled_secs += t0.elapsed().as_secs_f64();
        }
    }

    // peak pinned optimizer staging of the tiled driver (this arena
    // carries nothing but the tile leases)
    let peak_pinned = arena_til.watermark(Cat::OptimBuf).charged_peak
        + arena_til.watermark(Cat::SwapBuf).charged_peak;

    // byte-identity: tiled and untiled against the sequential reference
    let mut identical = true;
    for (suffix, width) in [("master", 4), ("adam_m", 4), ("adam_v", 4), ("fp16", 2)] {
        let key = format!("g0/{suffix}");
        let mut a = vec![0u8; n * width];
        let mut b = vec![0u8; n * width];
        let mut c = vec![0u8; n * width];
        eng_seq.read(&key, &mut a).unwrap();
        eng_unt.read(&key, &mut b).unwrap();
        eng_til.read(&key, &mut c).unwrap();
        if a != b || a != c {
            identical = false;
            eprintln!("MISMATCH at {key} (mult {mult})");
        }
    }

    std::fs::remove_dir_all(&dir_seq).ok();
    std::fs::remove_dir_all(&dir_unt).ok();
    std::fs::remove_dir_all(&dir_til).ok();
    SizeResult {
        elems: n,
        peak_pinned,
        tiled_secs: tiled_secs / TIMED_STEPS as f64,
        untiled_secs: untiled_secs / TIMED_STEPS as f64,
        identical,
    }
}

fn main() {
    let mut table = Table::new(vec![
        "group (MiB/stream)",
        "working set (MiB)",
        "peak pinned (MiB)",
        "tiled step (s)",
        "untiled step (s)",
        "ratio",
    ]);
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for mult in [1usize, 2, 4, 8] {
        let r = run_size(mult);
        let mib = |b: usize| b as f64 / (1 << 20) as f64;
        let ratio = if r.untiled_secs > 0.0 { r.tiled_secs / r.untiled_secs } else { 0.0 };
        table.row(vec![
            format!("{:.1}", mib(r.elems * 4)),
            format!("{:.1}", mib(r.elems * 4 * 3)),
            format!("{:.2}", mib(r.peak_pinned)),
            format!("{:.3}", r.tiled_secs),
            format!("{:.3}", r.untiled_secs),
            format!("{ratio:.2}"),
        ]);
        rows.push(Json::obj(vec![
            ("elems", Json::from(r.elems)),
            ("group_bytes_per_stream", Json::from(r.elems * 4)),
            ("whole_group_working_set_bytes", Json::from(r.elems * 4 * 3)),
            ("peak_pinned_optim_bytes", Json::from(r.peak_pinned)),
            ("tiled_step_secs", Json::from(r.tiled_secs)),
            ("untiled_step_secs", Json::from(r.untiled_secs)),
            ("latency_ratio", Json::from(ratio)),
            ("byte_identical", Json::from(r.identical)),
        ]));
        results.push(r);
    }
    common::emit(
        "bench_tiling",
        "staged-tile optimizer pipeline: peak pinned DRAM vs group size",
        &table,
    );

    let peak_min = results.iter().map(|r| r.peak_pinned).min().unwrap();
    let peak_max = results.iter().map(|r| r.peak_pinned).max().unwrap();
    let peak_flat = peak_max - peak_min <= TILE_BYTES;
    let identical = results.iter().all(|r| r.identical);
    let worst_ratio = results
        .iter()
        .map(|r| if r.untiled_secs > 0.0 { r.tiled_secs / r.untiled_secs } else { 0.0 })
        .fold(0.0f64, f64::max);
    let latency_within_10pct = worst_ratio <= 1.10;

    println!(
        "peak pinned staging: {peak_min}..{peak_max} B across 8x group growth \
         (spread {} B vs one {TILE_BYTES} B tile) -> flat: {peak_flat}",
        peak_max - peak_min
    );
    println!(
        "LATENCY (report-only, timing-sensitive): worst tiled/untiled ratio \
         {worst_ratio:.3} (target <= 1.10): within target: {latency_within_10pct}"
    );
    println!("byte-identity (tiled & untiled vs sequential): {identical}");

    std::fs::create_dir_all(common::OUT_DIR).ok();
    let out = Json::obj(vec![
        ("tile_bytes", Json::from(TILE_BYTES)),
        ("pipeline_depth", Json::from(TILE_PIPELINE_DEPTH)),
        ("sizes", Json::Arr(rows)),
        ("peak_spread_bytes", Json::from(peak_max - peak_min)),
        ("peak_flat_within_one_tile", Json::from(peak_flat)),
        ("worst_latency_ratio", Json::from(worst_ratio)),
        ("latency_within_10pct", Json::from(latency_within_10pct)),
        ("byte_identical", Json::from(identical)),
    ]);
    let path = format!("{}/BENCH_tiling.json", common::OUT_DIR);
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("[json] {path}"),
        Err(e) => eprintln!("warn: could not write {path}: {e}"),
    }

    // only the deterministic bars gate: memory flatness + identity
    let pass = peak_flat && identical;
    println!("ACCEPTANCE: {}", if pass { "PASS" } else { "FAIL" });
    if !pass {
        std::process::exit(1);
    }
}
