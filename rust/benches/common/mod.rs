//! Shared bench scaffolding: paper-vs-measured table output + CSV dump.

// each bench binary compiles its own copy; not every bench uses
// every helper
#![allow(dead_code)]

use memascend::util::bench::Table;

pub const OUT_DIR: &str = "bench_out";

pub fn emit(name: &str, title: &str, table: &Table) {
    println!("\n=== {name}: {title} ===\n");
    println!("{}", table.render());
    let path = format!("{OUT_DIR}/{name}.csv");
    if let Err(e) = table.write_csv(&path) {
        eprintln!("warn: could not write {path}: {e}");
    } else {
        println!("[csv] {path}");
    }
}

pub fn gib(bytes: u64) -> String {
    format!("{:.2}", memascend::util::human::gib(bytes))
}

/// Standard Fig-8-style training spec (ctx 4096, batch 4/rank, 2 ranks).
/// Paper parity: optimizer staging stays whole-subgroup (untiled) so
/// the figure-replay numbers match the paper's memory model; the tiled
/// pipeline's savings are measured separately by `bench_tiling`.
pub fn eval_spec(flags: memascend::config::MemAscendFlags) -> memascend::config::TrainSpec {
    memascend::config::TrainSpec {
        batch: 4,
        seq: 4096,
        ranks: 2,
        prefetch_depth: 1,
        optim_tile_bytes: 0,
        flags,
        ..Default::default()
    }
}
