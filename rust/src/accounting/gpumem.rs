//! GPU-memory model (Fig. 2, Table II's VRAM-OOM column).
//!
//! A closed-form working-set model of the residual memory the paper's
//! Fig. 2 charts: weights/grads/optimizer (placement depends on the
//! offload mode), activations (with/without gradient checkpointing and
//! host offload), attention intermediates (with/without
//! Flash-Attention), and head logits (with/without Liger's fused CE).
//! Coefficients follow the standard transformer activation-memory
//! derivation (Korthikanti et al.) specialized to SwiGLU blocks.

use crate::config::{ModelSpec, TrainSpec};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    AllInGpu,
    ZeroOffload,
    ZeroInfinity,
}

#[derive(Debug, Clone, Copy)]
pub struct GpuMemOpts {
    pub placement: Placement,
    /// Gradient checkpointing enabled.
    pub grad_ckpt: bool,
    /// Liger-Kernel (fused CE — no materialized logits) + fused ops.
    pub liger: bool,
    /// Flash-Attention (no S×S score matrix).
    pub flash: bool,
    /// Offload checkpointed activations to host memory.
    pub offloaded_gc: bool,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct GpuMemBreakdown {
    pub weights: u64,
    pub grads: u64,
    pub optimizer: u64,
    pub activations: u64,
    pub attn_intermediate: u64,
    pub logits: u64,
    pub workspace: u64,
}

impl GpuMemBreakdown {
    pub fn total(&self) -> u64 {
        self.weights
            + self.grads
            + self.optimizer
            + self.activations
            + self.attn_intermediate
            + self.logits
            + self.workspace
    }

    pub fn gib(&self) -> f64 {
        crate::util::human::gib(self.total())
    }
}

/// Per-GPU memory for one configuration.
pub fn gpu_memory(spec: &ModelSpec, train: &TrainSpec, opts: &GpuMemOpts) -> GpuMemBreakdown {
    let p = spec.param_count();
    let (b, c) = (train.batch as u64, train.seq as u64);
    let (l, h, v) = (spec.layers as u64, spec.hidden as u64, spec.vocab as u64);
    let heads = spec.heads as u64;

    let mut out = GpuMemBreakdown::default();

    match opts.placement {
        Placement::AllInGpu => {
            out.weights = p * 2; // fp16 compute copy
            out.grads = p * 2;
            out.optimizer = p * 12; // fp32 master + m + v
        }
        Placement::ZeroOffload => {
            out.weights = p * 2;
            out.grads = p * 2;
            out.optimizer = 0; // states live in host DRAM
        }
        Placement::ZeroInfinity => {
            // streamed: only the working set of ~2 blocks + embeddings
            let per_block: u64 = crate::tensors::inventory(spec)
                .iter()
                .filter(|t| t.layer == 0)
                .map(|t| t.numel as u64 * 2)
                .sum();
            let embed = (spec.vocab * spec.hidden) as u64 * 2;
            out.weights = 2 * per_block + 2 * embed;
            out.grads = per_block; // one block's grads before offload
            out.optimizer = 0;
        }
    }

    // --- activations (fp16) ---
    // Full storage per layer for a SwiGLU block ≈ (18h + 4f) per token
    // (inputs of every matmul + norms + silu products), f = FFN width.
    let f = if spec.is_moe() {
        (spec.expert_intermediate * spec.experts_per_token) as u64
    } else {
        spec.intermediate as u64
    };
    let act_per_layer_token = 18 * h + 4 * f;
    if opts.grad_ckpt {
        // checkpoints: one h-vector per token per layer...
        let ckpt = b * c * l * h * 2;
        out.activations = if opts.offloaded_gc { 0 } else { ckpt };
        // ...plus the recompute working set of a single layer
        out.activations += b * c * act_per_layer_token * 2;
    } else {
        out.activations = b * c * l * act_per_layer_token * 2;
    }

    // --- attention intermediates ---
    if !opts.flash {
        // S×S score + softmax matrices per head (fp16, fwd+bwd copies)
        let layers_holding = if opts.grad_ckpt { 1 } else { l };
        out.attn_intermediate = 2 * b * heads * c * c * 2 * layers_holding;
    }

    // --- LM head logits ---
    if !opts.liger {
        // logits + softmax grad in fp32 (the tensor Liger never builds)
        out.logits = 2 * b * c * v * 4;
    }

    // cuBLAS/cudnn workspace + allocator slack
    out.workspace = 1 << 30;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{DENSE_1B, LLAMA31_8B};

    fn train(b: usize, c: usize) -> TrainSpec {
        TrainSpec { batch: b, seq: c, ..Default::default() }
    }

    fn opts(placement: Placement) -> GpuMemOpts {
        GpuMemOpts {
            placement,
            grad_ckpt: true,
            liger: true,
            flash: true,
            offloaded_gc: true,
        }
    }

    #[test]
    fn table2_oom_pattern_on_24gib_gpu() {
        // All-in-GPU: 1B fits, 3B+ OOM (Table II)
        let cap = 24.0;
        let one_b = gpu_memory(&DENSE_1B, &train(4, 2048), &opts(Placement::AllInGpu));
        assert!(one_b.gib() < cap, "1B all-in-gpu {} GiB", one_b.gib());
        let eight_b =
            gpu_memory(&LLAMA31_8B, &train(8, 4096), &opts(Placement::AllInGpu));
        assert!(eight_b.gib() > cap, "8B all-in-gpu {} GiB", eight_b.gib());
        // ZeRO-Infinity: 8B fits in VRAM (system memory is the limit)
        let zi = gpu_memory(&LLAMA31_8B, &train(8, 4096), &opts(Placement::ZeroInfinity));
        assert!(zi.gib() < cap, "8B zero-infinity {} GiB", zi.gib());
    }

    #[test]
    fn fig2_each_optimization_reduces_memory() {
        // ctx 32768: without flash the S^2 term dominates; without
        // liger the logits dominate; without GC activations dominate.
        let t = train(4, 32768);
        let full = GpuMemOpts {
            placement: Placement::ZeroInfinity,
            grad_ckpt: false,
            liger: false,
            flash: false,
            offloaded_gc: false,
        };
        let base = gpu_memory(&LLAMA31_8B, &t, &full).total();
        let with_flash = gpu_memory(
            &LLAMA31_8B,
            &t,
            &GpuMemOpts { flash: true, ..full },
        )
        .total();
        let with_gc = gpu_memory(
            &LLAMA31_8B,
            &t,
            &GpuMemOpts { flash: true, grad_ckpt: true, ..full },
        )
        .total();
        let with_liger = gpu_memory(
            &LLAMA31_8B,
            &t,
            &GpuMemOpts { flash: true, grad_ckpt: true, liger: true, ..full },
        )
        .total();
        let with_ogc = gpu_memory(&LLAMA31_8B, &t, &opts(Placement::ZeroInfinity))
            .total();
        assert!(base > with_flash);
        assert!(with_flash > with_gc);
        assert!(with_gc > with_liger);
        assert!(with_liger > with_ogc);
    }

    #[test]
    fn long_context_without_flash_explodes() {
        let t = train(4, 32768);
        let no_flash = GpuMemOpts {
            placement: Placement::ZeroInfinity,
            grad_ckpt: true,
            liger: true,
            flash: false,
            offloaded_gc: true,
        };
        let g = gpu_memory(&LLAMA31_8B, &t, &no_flash);
        assert!(g.gib() > 80.0, "S^2 term should OOM any GPU: {}", g.gib());
    }
}
