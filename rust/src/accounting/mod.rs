//! Full-scale memory & performance accounting.
//!
//! Peak system memory for a 32B-parameter run is determined by
//! *allocator and pool decisions over the tensor inventory*, not by the
//! bytes themselves — so this engine executes the real pool
//! constructors and the real pinned-allocation policies in Virtual
//! mode (same logic, no backing pages) and reads the resulting ledger.
//! That is how the paper's Tables II and Figures 2/4/8/9/10/15/16/17/
//! 18/21 are regenerated inside a 35 GiB container.

pub mod gpumem;
pub mod perfmodel;
pub mod sysmem;

pub use gpumem::{gpu_memory, GpuMemOpts};
pub use perfmodel::{step_time, StepTime};
pub use sysmem::{peak_sysmem, SysMemBreakdown};
