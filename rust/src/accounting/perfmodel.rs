//! Step-time / throughput model (Tables IV & VI, Figs. 10/17/18 curves).
//!
//! Projects one training-iteration latency at paper scale from a
//! component decomposition:
//!
//! `t_step = max(t_compute, t_param_io·(1-overlap)) + t_engine_tax
//!           + t_overflow + max(t_optim_io, t_optim_cpu)`
//!
//! - compute follows the 8·P·T FLOP rule (fwd 2PT + bwd 4PT +
//!   checkpoint recompute 2PT) over the hardware's GPU throughput;
//! - parameter I/O streams fp16 weights twice per step (fwd + bwd),
//!   overlap-centric execution hides most of it behind compute;
//! - the engine tax charges per-tensor fixed costs (filesystem
//!   metadata vs raw submission — the Fig. 14 constants);
//! - overflow-check and CPU-Adam costs are per-element constants
//!   *calibrated from this repo's measured benches* and scaled by the
//!   target CPU's relative speed.

use crate::config::{HardwareSpec, ModelSpec, TrainSpec};
use crate::optimizer::StateDtype;
use crate::ssd::DeviceModel;
use crate::tensors;

/// Calibration constants (seconds). Defaults reflect this container's
/// measured values scaled to a Xeon-6780E-class core; benches may
/// override with live measurements.
#[derive(Debug, Clone)]
pub struct Calib {
    /// Baseline overflow chain, s/element at cpu_rel=1.
    pub c_overflow_base: f64,
    /// Fused overflow check, s/element at cpu_rel=1.
    pub c_overflow_fused: f64,
    /// CPU AdamW, s/element/thread at cpu_rel=1.
    pub c_adam: f64,
    /// H100 FLOP/s *achieved in SSD-offloaded fine-tuning* (not peak:
    /// layer streaming, host round-trips, and checkpoint recompute keep
    /// MFU low; calibrated so an 8B/ctx-4096/b-8 step on C1 lands near
    /// the paper's ~41 s iteration, per its §III-C 13.36% claim).
    pub gpu_flops: f64,
    /// Fraction of parameter I/O hidden behind compute.
    pub overlap: f64,
}

impl Default for Calib {
    fn default() -> Self {
        Self {
            c_overflow_base: 0.69e-9, // paper: 5507 ms @ 8B params on C1
            c_overflow_fused: 0.02e-9, // ~97% lower, parallel
            c_adam: 1.2e-9,
            gpu_flops: 120e12,
            overlap: 0.85,
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct StepTime {
    pub compute: f64,
    pub param_io_exposed: f64,
    pub engine_tax: f64,
    pub overflow: f64,
    pub optim: f64,
}

impl StepTime {
    pub fn total(&self) -> f64 {
        self.compute + self.param_io_exposed + self.engine_tax + self.overflow + self.optim
    }

    pub fn tokens_per_sec(&self, train: &TrainSpec) -> f64 {
        train.tokens_per_step() as f64 / self.total()
    }
}

/// Project one training step on `hw`.
pub fn step_time(
    spec: &ModelSpec,
    train: &TrainSpec,
    hw: &HardwareSpec,
    calib: &Calib,
) -> StepTime {
    let p = spec.param_count() as f64;
    // MoE: only active experts compute, but ALL weights stream from SSD
    let p_active = if spec.is_moe() {
        let inv = tensors::inventory(spec);
        let expert: f64 = inv
            .iter()
            .filter(|t| t.name.contains("experts"))
            .map(|t| t.numel as f64)
            .sum();
        (p - expert)
            + expert * spec.experts_per_token as f64 / spec.n_experts as f64
    } else {
        p
    };
    let tokens_per_gpu = (train.batch * train.seq) as f64;
    let gpus = hw.gpus.max(1) as f64;

    // --- compute ---
    let flops = 8.0 * p_active * tokens_per_gpu;
    let compute = flops / (calib.gpu_flops * hw.gpu_rel_flops.max(1e-3));

    // --- parameter streaming I/O (fp16, read twice/step) ---
    let param_bytes = 2.0 * p * 2.0;
    let read_bw = hw.ssd_agg_read_gibs() * (1u64 << 30) as f64;
    let param_io = param_bytes / read_bw / gpus.max(1.0);
    let param_io_exposed = (param_io - compute * calib.overlap).max(0.0);

    // --- per-tensor engine tax ---
    let dm = DeviceModel::new(hw);
    let n_offloadable = tensors::inventory(spec)
        .iter()
        .filter(|t| t.offloadable())
        .count() as f64;
    let sub = super::sysmem::subgroup_elems(spec);
    let n_groups = (spec.param_count() as f64 / sub as f64).ceil();
    let ops = n_offloadable * 2.0 + n_groups * 7.0;
    let per_op = if train.flags.direct_nvme {
        // submission cost only — data time is in param_io/optim_io
        8e-6 * hw.ssds as f64
    } else {
        // filesystem metadata path (matches DeviceModel constants)
        dm.fs_write_lat(0, false)
    };
    let engine_tax = ops * per_op / gpus;

    // --- overflow check (CPU, once per step over the flat buffer) ---
    let overflow = if train.precision.needs_overflow_check() {
        let c = if train.flags.fused_overflow {
            calib.c_overflow_fused
        } else {
            calib.c_overflow_base
        };
        p * c / hw.cpu_rel
    } else {
        0.0
    };

    // --- optimizer: state I/O overlapped with CPU update ---
    let sb = match train.optim_dtype {
        crate::dtype::DType::BF16 => StateDtype::BF16.bytes_per_elem() as f64,
        _ => StateDtype::F32.bytes_per_elem() as f64,
    };
    let optim_read = p * 3.0 * sb;
    let optim_write = p * (3.0 * sb + 2.0);
    let write_bw = hw.ssd_agg_write_gibs() * (1u64 << 30) as f64;
    let optim_io = optim_read / read_bw + optim_write / write_bw;
    let threads = (hw.cpu_threads as f64 * 0.25).max(1.0); // OMP share
    let optim_cpu = p * calib.c_adam / (hw.cpu_rel * threads);
    let optim = optim_io.max(optim_cpu);

    StepTime { compute, param_io_exposed, engine_tax, overflow, optim }
}

/// Total SSD I/O volume per iteration (Fig. 20), bytes.
pub fn io_volume_per_step(spec: &ModelSpec, optim: StateDtype) -> u64 {
    let p = spec.param_count();
    let sb = optim.bytes_per_elem() as u64;
    // fp16 weights read fwd+bwd, states read+write, fp16 writeback
    p * 2 * 2 + p * 3 * sb * 2 + p * 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{CONFIG1, CONFIG2};
    use crate::config::presets::{QWEN25_14B, QWEN25_7B};
    use crate::config::MemAscendFlags;

    fn spec(batch: usize, flags: MemAscendFlags) -> TrainSpec {
        TrainSpec { batch, seq: 4096, ranks: 2, flags, ..Default::default() }
    }

    /// Table IV shape: MemAscend wins, more on the slower CPU, more at
    /// small batch.
    #[test]
    fn table4_improvement_structure() {
        let calib = Calib::default();
        let imp = |hw: &HardwareSpec, batch: usize| {
            // Table IV: both sides run the direct engine (fs baseline
            // "is unstable and prone to hanging"); the delta is the
            // fused overflow check + allocator effects
            let mut zi_flags = MemAscendFlags::baseline();
            zi_flags.direct_nvme = true;
            let zi = step_time(&QWEN25_7B, &spec(batch, zi_flags), hw, &calib);
            let ma =
                step_time(&QWEN25_7B, &spec(batch, MemAscendFlags::memascend()), hw, &calib);
            zi.total() / ma.total() - 1.0
        };
        let c1_small = imp(&CONFIG1, 8);
        let c1_large = imp(&CONFIG1, 64);
        let c2_small = imp(&CONFIG2, 8);
        let c2_large = imp(&CONFIG2, 20);
        assert!(c1_small > 0.0 && c2_small > 0.0);
        assert!(c2_small > c1_small, "slower CPU gains more: {c2_small} vs {c1_small}");
        assert!(c1_small > c1_large, "small batch gains more");
        assert!(c2_small > c2_large);
        // paper band: C1 2.7-7%, C2 6.8-18.9%
        assert!((0.005..0.30).contains(&c1_small), "c1 {c1_small}");
        assert!((0.02..0.60).contains(&c2_small), "c2 {c2_small}");
    }

    /// Table VI shape: bf16 optimizer helps everywhere, most at small
    /// batch (I/O-bound regime).
    #[test]
    fn table6_bf16_optimizer_gains() {
        let calib = Calib::default();
        let imp = |hw: &HardwareSpec, batch: usize| {
            let f32_t = step_time(
                &QWEN25_14B,
                &spec(batch, MemAscendFlags::memascend()),
                hw,
                &calib,
            );
            let mut tr = spec(batch, MemAscendFlags::memascend());
            tr.optim_dtype = crate::dtype::DType::BF16;
            let bf16_t = step_time(&QWEN25_14B, &tr, hw, &calib);
            f32_t.total() / bf16_t.total() - 1.0
        };
        let small = imp(&CONFIG1, 8);
        let large = imp(&CONFIG1, 64);
        assert!(small > 0.05, "small-batch gain {small}");
        assert!(small > large, "gain shrinks with batch: {small} vs {large}");
    }

    /// Fig. 10/17: throughput scales near-linearly with batch until
    /// compute dominates.
    #[test]
    fn throughput_scales_with_batch() {
        let calib = Calib::default();
        let tp = |b: usize| {
            let t = spec(b, MemAscendFlags::memascend());
            step_time(&QWEN25_7B, &t, &CONFIG1, &calib).tokens_per_sec(&t)
        };
        let t1 = tp(1);
        let t8 = tp(8);
        let t32 = tp(32);
        assert!(t8 > 4.0 * t1, "batch 8 speedup {}", t8 / t1);
        assert!(t32 > t8);
    }

    /// Fig. 20: bf16 optimizer cuts I/O volume by >40%.
    #[test]
    fn io_volume_cut() {
        let f = io_volume_per_step(&QWEN25_7B, StateDtype::F32) as f64;
        let b = io_volume_per_step(&QWEN25_7B, StateDtype::BF16) as f64;
        let cut = 1.0 - b / f;
        assert!((0.35..0.55).contains(&cut), "cut {cut} (paper: 0.58 incl. metadata)");
    }
}
