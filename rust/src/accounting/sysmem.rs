//! Peak system-memory model (paper §V-A, Fig. 8's component breakdown).
//!
//! Replays the full allocation sequence of one training iteration
//! against the *real* [`PinnedArena`] (over the configured policy
//! allocator — caching-pow2 for ZeRO-Infinity, alignment-free for
//! MemAscend — in Virtual mode), not a parallel virtual model: the same
//! lease calls the trainer makes, so the ledger peaks reported here are
//! the arena's own watermarks, bit for bit:
//!
//! 1. gradient partition flat buffers (fp32, pinned, one per rank)
//! 2. the parameter buffer pool (monolithic vs adaptive, leased
//!    through the arena exactly as `OffloadEngine` builds it)
//! 3. optimizer-state fetch buffers + swap-out buffer (pinned,
//!    subgroup-sized, double-buffered)
//! 4. offloaded activation-checkpoint buffers (pinned, per rank ×
//!    layer, Eq. 1)
//! 5. the zero-copy boundary's f32 *delivery* views (`Cat::SwapBuf`):
//!    the swapper's prefetch window plus the in-kernel live weight
//!    set, leased exactly as PR 4's lease-backed fetches stage them —
//!    and, on the whole-group optimizer path, the fp16 compute window
//!    (`Cat::OptimBuf`, two generations × subgroup × 2 B)
//! 6. resident small tensors + framework base
//! 7. the overflow-check transient (baseline chain: 1.25× of the flat
//!    buffer materialized and freed — the 2.25× total peak; fused: 0)
//!
//! With (5) charged, a `pinned_budget_bytes` sized from this model
//! covers every consumer the trainer actually leases — the PR-4
//! modeling gap that silently degraded the zero-copy path
//! (`StepMetrics::host_copy_bytes` > 0) under model-derived budgets is
//! closed.  The paper's own figures predate these terms, but they add
//! the same absolute bytes to ZeRO-Infinity and MemAscend alike, so
//! every figure-level *ratio* assertion still holds (tested below).

use std::sync::Arc;

use crate::bufpool::{AdaptivePool, MonolithicPool, ParamBufferPool};
use crate::config::{HardwareSpec, ModelSpec, TrainSpec};
use crate::pinned::{
    AlignedAllocator, ArenaConfig, CachingAllocator, Cat, CatWatermark,
    HostAllocator, MemoryTracker, Mode, PinnedArena,
};
use crate::tensors;

/// DeepSpeed-style optimizer subgroup: elements fetched per swap.
pub fn subgroup_elems(spec: &ModelSpec) -> usize {
    ((spec.param_count() as usize) / 8).clamp(50_000_000, 250_000_000)
}

#[derive(Debug, Clone, Default)]
pub struct SysMemBreakdown {
    pub label: String,
    /// All in bytes.
    pub grad_flat: u64,
    pub param_pool: u64,
    pub pinned_overhead: u64,
    pub optim_buf: u64,
    pub swap_buf: u64,
    pub act_ckpt: u64,
    pub overflow_spike: u64,
    pub resident: u64,
    pub peak_total: u64,
    /// The arena's own per-category watermarks for the replay — must
    /// agree with the tracker-peak fields above bit for bit (tested).
    pub arena_watermarks: Vec<(Cat, CatWatermark)>,
}

impl SysMemBreakdown {
    pub fn gib(&self) -> f64 {
        crate::util::human::gib(self.peak_total)
    }

    /// The theoretical minimum of Fig. 8: pool + grad flat only.
    pub fn theoretical_min(&self) -> u64 {
        self.param_pool + self.grad_flat
    }
}

/// Compute the peak system-memory breakdown for one configuration by
/// replaying the iteration's leases against a Virtual-mode arena.
pub fn peak_sysmem(
    spec: &ModelSpec,
    train: &TrainSpec,
    _hw: &HardwareSpec,
) -> SysMemBreakdown {
    let (tracker, arena) = replay_arena(train);
    replay_into(&arena, &tracker, spec, train)
}

/// Build the Virtual-mode replay arena (policy allocator selected by
/// the flags) shared by one or more namespaced replays.
pub fn replay_arena(train: &TrainSpec) -> (Arc<MemoryTracker>, Arc<PinnedArena>) {
    let tracker = Arc::new(MemoryTracker::new());
    let memascend_alloc = train.flags.alignment_free;
    let alloc: Arc<dyn HostAllocator> = if memascend_alloc {
        let a = AlignedAllocator::new(Mode::Virtual, tracker.clone());
        Arc::new(a) as Arc<dyn HostAllocator>
    } else {
        let a = CachingAllocator::new(Mode::Virtual, tracker.clone());
        Arc::new(a) as Arc<dyn HostAllocator>
    };
    // unbudgeted: this is the measurement of what a run *would* need
    (tracker.clone(), PinnedArena::new(alloc, ArenaConfig::default()))
}

/// Replay one job's iteration leases through `arena` — pass the root
/// arena for the classic single-job model, or a
/// [`PinnedArena::namespace`] view to simulate one tenant of a shared
/// arena (its charged bytes are then attributed to that namespace,
/// and per-namespace mirrors keep summing to the ledger bit for bit).
pub fn replay_into(
    arena: &Arc<PinnedArena>,
    tracker: &Arc<MemoryTracker>,
    spec: &ModelSpec,
    train: &TrainSpec,
) -> SysMemBreakdown {
    let uncapped = |r: Result<crate::pinned::Lease, crate::pinned::ArenaError>| {
        r.expect("unbudgeted arena cannot refuse")
    };

    let p_total = spec.param_count() as usize;
    let ranks = train.ranks.max(1);
    let mut held = Vec::new();

    // 1. gradient partition flat buffers: fp32, one partition per rank
    let per_rank = p_total.div_ceil(ranks);
    for _ in 0..ranks {
        held.push(uncapped(arena.lease(per_rank * 4, Cat::GradFlat)));
    }

    // 2. parameter buffer pool (full tensor sizes — partitioned reads
    // shrink per-rank buffers but the node hosts all ranks, so totals
    // match the unpartitioned pool; see §IV-B "per-process buffers
    // shrink proportionally with the number of partitions")
    let dtype = train.precision.compute_dtype();
    let pool: Box<dyn ParamBufferPool> = if train.flags.adaptive_pool {
        Box::new(
            AdaptivePool::new(spec, train.prefetch_depth, dtype, arena)
                .expect("unbudgeted arena cannot refuse"),
        )
    } else {
        Box::new(
            MonolithicPool::new(spec, train.prefetch_depth, dtype, arena)
                .expect("unbudgeted arena cannot refuse"),
        )
    };
    let pool_bytes = pool.stats().pool_bytes as u64;

    // 3. optimizer staging.  Untiled (`optim_tile_bytes = 0`, the
    // paper-parity baseline): double-buffered whole-subgroup
    // {master, m, v} fetches + fp32 swap-out staging — the largest
    // subgroup sets the peak.  Tiled: at any instant the staged-tile
    // pipeline holds at most `depth` fetch generations (the tile under
    // Adam counts against the refill window) plus `depth` write-back
    // generations of 3 state tiles each, and `depth` fp16 windows —
    // peak staging is O(tile_bytes × depth) regardless of subgroup
    // size.
    let sub = subgroup_elems(spec);
    let state_bytes = train.optim_dtype.size();
    // the tiled path only engages with async I/O workers (the trainer's
    // sequential io_workers = 0 path swaps whole subgroups regardless)
    if train.optim_tile_bytes > 0 && train.io_workers > 0 {
        let tile_elems = (train.optim_tile_bytes / state_bytes).max(1).min(sub);
        let depth = train.optim_tile_depth.max(1);
        for _ in 0..(2 * depth) {
            for _ in 0..3 {
                held.push(uncapped(
                    arena.lease(tile_elems * state_bytes, Cat::OptimBuf),
                ));
            }
        }
        for _ in 0..depth {
            held.push(uncapped(arena.lease(tile_elems * 2, Cat::SwapBuf)));
        }
    } else {
        for _ in 0..2 {
            for _ in 0..3 {
                held.push(uncapped(arena.lease(sub * state_bytes, Cat::OptimBuf)));
            }
        }
        for _ in 0..2 {
            held.push(uncapped(arena.lease(sub * 4, Cat::SwapBuf)));
        }
        // the whole-group drivers' fp16 compute window: two
        // generations in flight, leased under Cat::OptimBuf
        // (`Fp16Staging::take`) — a PR-4 consumer this replay now
        // charges
        for _ in 0..2 {
            held.push(uncapped(arena.lease(sub * 2, Cat::OptimBuf)));
        }
    }

    // 3b. zero-copy delivery views (PR 4): every swapper fetch decodes
    // into a pinned `Cat::SwapBuf` lease and is consumed as a borrowed
    // view — at the peak moment up to `prefetch_depth` decoded tensors
    // wait ahead of compute (bounded by the largest offloadable
    // tensor) while the kernel in flight borrows one full layer's
    // weight set, leased per tensor exactly as the swapper stages them
    let inv = tensors::inventory(spec);
    let max_view_elems = inv
        .iter()
        .filter(|t| t.offloadable())
        .map(|t| t.numel)
        .max()
        .unwrap_or(0);
    for _ in 0..train.prefetch_depth.max(1) {
        held.push(uncapped(arena.lease(max_view_elems * 4, Cat::SwapBuf)));
    }
    for t in inv.iter().filter(|t| t.offloadable() && t.layer == 0) {
        held.push(uncapped(arena.lease(t.numel * 4, Cat::SwapBuf)));
    }

    // 4. offloaded activation checkpoints (Eq. 1): Ng × B × C × L × H ×
    // 2 bytes, pinned per rank per layer
    if train.offloaded_gc {
        let per_layer = train.batch * train.seq * spec.hidden * 2;
        for _ in 0..ranks {
            for _ in 0..spec.layers {
                held.push(uncapped(arena.lease(per_layer, Cat::ActCkpt)));
            }
        }
    }

    // 5. resident small tensors (norms/router master copies, fp32) +
    // framework base — unpinned framework memory, charged straight to
    // the ledger (not arena business)
    let resident_small: usize = inv
        .iter()
        .filter(|t| !t.offloadable())
        .map(|t| t.numel * 4)
        .sum();
    let framework_base = 512 << 20; // interpreter + CUDA ctx + loader
    tracker.alloc(Cat::Resident, (resident_small + framework_base) as u64);

    // 6. overflow-check transient at its worst moment (everything else
    // live): baseline materializes abs copy (1.0x) + bool (0.25x)
    let grad_flat_total = (per_rank * 4 * ranks) as u64;
    if train.precision.needs_overflow_check() && !train.flags.fused_overflow {
        let spike = grad_flat_total + grad_flat_total / 4;
        tracker.alloc(Cat::OverflowTemp, spike);
        tracker.free(Cat::OverflowTemp, spike);
    }

    let bd = SysMemBreakdown {
        label: train.flags.label(),
        grad_flat: tracker.peak(Cat::GradFlat),
        param_pool: pool_bytes,
        pinned_overhead: tracker.peak(Cat::PinnedOverhead),
        optim_buf: tracker.peak(Cat::OptimBuf),
        swap_buf: tracker.peak(Cat::SwapBuf),
        act_ckpt: tracker.peak(Cat::ActCkpt),
        overflow_spike: tracker.peak(Cat::OverflowTemp),
        resident: tracker.peak(Cat::Resident),
        peak_total: tracker.peak_total(),
        arena_watermarks: arena.watermarks(),
    };
    drop(held);
    drop(pool);
    bd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::CONFIG1;
    use crate::config::presets::{PAPER_DENSE, QWEN25_7B, QWEN3_30B_A3B};
    use crate::config::MemAscendFlags;
    use crate::util::human::GIB;

    fn spec_fig8() -> TrainSpec {
        TrainSpec {
            batch: 4,
            seq: 4096,
            ranks: 2,
            prefetch_depth: 1,
            // paper parity: the figures model whole-subgroup optimizer
            // staging; the tiled pipeline is measured separately below
            optim_tile_bytes: 0,
            ..Default::default()
        }
    }

    #[test]
    fn fig8_qwen7b_zero_infinity_vs_memascend() {
        let mut zi = spec_fig8();
        zi.flags = MemAscendFlags::baseline();
        let mut ma = spec_fig8();
        ma.flags = MemAscendFlags::memascend();
        let b_zi = peak_sysmem(&QWEN25_7B, &zi, &CONFIG1);
        let b_ma = peak_sysmem(&QWEN25_7B, &ma, &CONFIG1);
        // paper: 109.04 -> 43.64 GiB (60% cut). Accept the shape:
        // large cut, MA in the low-40s..50s, ZI ~90-120.
        let zi_gib = b_zi.gib();
        let ma_gib = b_ma.gib();
        assert!((80.0..130.0).contains(&zi_gib), "ZI {zi_gib} GiB");
        assert!((38.0..55.0).contains(&ma_gib), "MA {ma_gib} GiB");
        let cut = 1.0 - ma_gib / zi_gib;
        assert!(cut > 0.45, "cut {cut}");
        // component sanity: grad flat identical across modes
        assert_eq!(b_zi.grad_flat, b_ma.grad_flat);
        // MA pinned overhead negligible vs ZI's
        assert!(b_ma.pinned_overhead * 10 < b_zi.pinned_overhead);
        // overflow spike only in ZI
        assert!(b_zi.overflow_spike > b_zi.grad_flat);
        assert_eq!(b_ma.overflow_spike, 0);
    }

    #[test]
    fn average_cut_across_models_matches_paper() {
        // paper Fig. 15: average 55.7% across the four dense models
        let mut cuts = Vec::new();
        for m in PAPER_DENSE {
            let mut zi = spec_fig8();
            zi.flags = MemAscendFlags::baseline();
            let mut ma = spec_fig8();
            ma.flags = MemAscendFlags::memascend();
            let z = peak_sysmem(m, &zi, &CONFIG1).peak_total as f64;
            let a = peak_sysmem(m, &ma, &CONFIG1).peak_total as f64;
            cuts.push(1.0 - a / z);
        }
        let avg = cuts.iter().sum::<f64>() / cuts.len() as f64;
        assert!(
            (0.45..0.70).contains(&avg),
            "avg cut {avg} vs paper 0.557 (cuts {cuts:?})"
        );
    }

    #[test]
    fn context_scaling_is_linear_for_memascend() {
        // Fig. 9: MA scales ~ linearly in C; ZI scales faster (pow2)
        let mut ma = spec_fig8();
        ma.flags = MemAscendFlags::memascend();
        ma.batch = 1;
        let at = |c: usize| {
            let mut t = ma.clone();
            t.seq = c;
            peak_sysmem(&QWEN25_7B, &t, &CONFIG1).peak_total as f64
        };
        let (a, b, c) = (at(4096), at(8192), at(16384));
        let d1 = b - a;
        let d2 = c - b;
        // second difference ~= d1 doubling (act term linear in C)
        assert!((d2 / d1 - 2.0).abs() < 0.2, "d1 {d1} d2 {d2}");
    }

    #[test]
    fn moe_cut_is_larger_than_dense() {
        // Fig. 18: ~71.9% cut for Qwen3-30B-A3B (embedding-sized slots
        // for tiny expert tensors are maximally wasteful)
        let mut zi = spec_fig8();
        zi.flags = MemAscendFlags::baseline();
        zi.batch = 1;
        let mut ma = zi.clone();
        ma.flags = MemAscendFlags::memascend();
        let z = peak_sysmem(&QWEN3_30B_A3B, &zi, &CONFIG1).peak_total as f64;
        let a = peak_sysmem(&QWEN3_30B_A3B, &ma, &CONFIG1).peak_total as f64;
        let cut = 1.0 - a / z;
        assert!(cut > 0.55, "MoE cut {cut}");
    }

    #[test]
    fn bf16_mixed_precision_cut_is_smaller() {
        // Fig. 21: bf16 has no overflow spike, so the MA advantage
        // shrinks (paper: 25.19% vs 55.7%)
        use crate::config::Precision;
        let mk = |flags, prec| {
            let mut t = spec_fig8();
            t.flags = flags;
            t.precision = prec;
            peak_sysmem(&QWEN25_7B, &t, &CONFIG1).peak_total as f64
        };
        let cut_f16 = 1.0
            - mk(MemAscendFlags::memascend(), Precision::MixedF16)
                / mk(MemAscendFlags::baseline(), Precision::MixedF16);
        let cut_bf16 = 1.0
            - mk(MemAscendFlags::memascend(), Precision::MixedBF16)
                / mk(MemAscendFlags::baseline(), Precision::MixedBF16);
        assert!(cut_bf16 < cut_f16, "bf16 {cut_bf16} vs f16 {cut_f16}");
        assert!(cut_bf16 > 0.10, "bf16 cut {cut_bf16}");
    }

    #[test]
    fn theoretical_min_close_to_memascend() {
        // Fig. 8: MA is within ~30-40% of pool+gradflat; ZI needs -72%
        let mut ma = spec_fig8();
        ma.flags = MemAscendFlags::memascend();
        let b = peak_sysmem(&QWEN25_7B, &ma, &CONFIG1);
        let margin = (b.peak_total - b.theoretical_min()) as f64
            / b.peak_total as f64;
        assert!(margin < 0.45, "margin {margin}");
        let _ = GIB;
    }

    #[test]
    fn ledger_peaks_match_arena_watermarks_bit_for_bit() {
        // the acceptance invariant of the arena refactor: the replay
        // charges nothing behind the arena's back, so the tracker peaks
        // reported per pinned category ARE the arena's watermarks
        for flags in [MemAscendFlags::baseline(), MemAscendFlags::memascend()] {
            let mut t = spec_fig8();
            t.flags = flags;
            let b = peak_sysmem(&QWEN25_7B, &t, &CONFIG1);
            let by_cat: std::collections::BTreeMap<Cat, CatWatermark> =
                b.arena_watermarks.iter().copied().collect();
            for (cat, field) in [
                (Cat::GradFlat, b.grad_flat),
                (Cat::OptimBuf, b.optim_buf),
                (Cat::SwapBuf, b.swap_buf),
                (Cat::ActCkpt, b.act_ckpt),
            ] {
                assert_eq!(
                    by_cat[&cat].charged_peak as u64, field,
                    "{cat:?}: tracker peak diverged from arena watermark"
                );
            }
            // the pool's own stats agree with the arena's leased demand
            assert_eq!(
                by_cat[&Cat::ParamPool].requested_peak as u64, b.param_pool,
                "PoolStats.pool_bytes diverged from arena ParamPool demand"
            );
        }
    }

    #[test]
    fn two_namespaced_replays_sum_to_the_shared_ledger_bit_for_bit() {
        // tenancy version of the watermark invariant: two jobs replay
        // their iterations through namespaced views of ONE shared
        // arena; every byte each job pins is attributed to its
        // namespace, and the per-namespace charges always sum to the
        // global ledger exactly — nothing double-counted, nothing lost
        let mut t = spec_fig8();
        t.flags = MemAscendFlags::memascend();
        let (tracker, arena) = replay_arena(&t);
        let j1 = arena.namespace(1);
        let j2 = arena.namespace(2);
        let check_sum = |arena: &std::sync::Arc<crate::pinned::PinnedArena>, when: &str| {
            let total: usize = (0..crate::pinned::MAX_NAMESPACES)
                .map(|ns| arena.ns_stats(ns).charged)
                .sum();
            assert_eq!(
                total,
                arena.stats().reserved_bytes,
                "namespace charges diverged from the ledger {when}"
            );
        };
        let b1 = replay_into(&j1, &tracker, &QWEN25_7B, &t);
        check_sum(&arena, "after job 1's replay");
        let b2 = replay_into(&j2, &tracker, &QWEN25_7B, &t);
        check_sum(&arena, "after job 2's replay");
        // both tenants' demand is attributed, host namespace untouched.
        // j1 pins every segment fresh; j2 replays the same shapes and
        // recycles j1's released extents — the *charge* stays with the
        // pinning namespace (ns 1), while j2's live demand is metered
        // under its own (requested/leases)
        let (ns1, ns2) = (arena.ns_stats(1), arena.ns_stats(2));
        assert!(ns1.charged_peak > 0, "job 1 pinned nothing?");
        assert!(ns2.requested_peak > 0 && ns2.leases > 0, "job 2 unmetered");
        assert!(ns2.recycled > 0, "job 2 should recycle job 1's extents");
        assert_eq!(arena.ns_stats(0).charged, 0, "no bytes may leak to the host ns");
        assert!(b1.peak_total > 0 && b2.peak_total > 0);
    }

    #[test]
    fn delivery_views_and_fp16_window_are_replayed() {
        // the two PR-4 consumers the replay now charges: swapper f32
        // delivery views scale with the prefetch window…
        let mut base = spec_fig8();
        base.flags = MemAscendFlags::memascend();
        let shallow = peak_sysmem(&QWEN25_7B, &base, &CONFIG1);
        let mut deep = base.clone();
        deep.prefetch_depth = 3;
        let deep = peak_sysmem(&QWEN25_7B, &deep, &CONFIG1);
        assert!(
            deep.swap_buf > shallow.swap_buf,
            "prefetch window not charged: {} vs {}",
            deep.swap_buf,
            shallow.swap_buf
        );
        // …and the whole-group fp16 compute window rides Cat::OptimBuf
        // (two generations × subgroup × 2 B on top of the 2 × 3 state
        // fetches)
        let sub = subgroup_elems(&QWEN25_7B);
        let state = base.optim_dtype.size();
        assert!(
            shallow.optim_buf as usize >= 2 * sub * (3 * state + 2),
            "fp16 window missing from the whole-group replay: {} < {}",
            shallow.optim_buf,
            2 * sub * (3 * state + 2)
        );
        // the delivery terms are cut-neutral: both modes pay them, so
        // the ZI-vs-MA ratio assertions elsewhere keep holding — but a
        // budget sized from this model now covers the boundary views
        assert!(shallow.swap_buf > 0);
    }

    #[test]
    fn tiled_optimizer_staging_is_flat_in_model_size() {
        // the staged-tile pipeline's replay: optimizer staging is
        // O(tile_bytes x depth), so it neither grows with the model
        // nor depends on subgroup size — and the total peak drops
        // below the untiled MemAscend baseline
        let tile = 4 << 20;
        let mk = |m: &'static ModelSpec, tile_bytes: usize| {
            let mut t = spec_fig8();
            t.flags = MemAscendFlags::memascend();
            t.optim_tile_bytes = tile_bytes;
            peak_sysmem(m, &t, &CONFIG1)
        };
        let small = mk(PAPER_DENSE[0], tile);
        let large = mk(PAPER_DENSE[PAPER_DENSE.len() - 1], tile);
        assert_eq!(
            small.optim_buf, large.optim_buf,
            "tiled staging must not scale with the model"
        );
        let depth = crate::optimizer::TILE_PIPELINE_DEPTH as u64;
        assert!(
            small.optim_buf <= 2 * depth * 3 * tile as u64,
            "tiled staging {} exceeds the pipeline window",
            small.optim_buf
        );
        // vs whole-subgroup double-buffering: strictly smaller peak
        let untiled = mk(PAPER_DENSE[0], 0);
        assert!(small.optim_buf < untiled.optim_buf / 4);
        assert!(small.peak_total < untiled.peak_total);
    }

    #[test]
    fn ablation_single_components_each_help() {
        let base = {
            let mut t = spec_fig8();
            t.flags = MemAscendFlags::baseline();
            peak_sysmem(&QWEN25_7B, &t, &CONFIG1).peak_total
        };
        for i in 0..4 {
            let mut f = MemAscendFlags::baseline();
            match i {
                0 => f.adaptive_pool = true,
                1 => f.alignment_free = true,
                2 => f.fused_overflow = true,
                _ => f.direct_nvme = true,
            }
            let mut t = spec_fig8();
            t.flags = f;
            let v = peak_sysmem(&QWEN25_7B, &t, &CONFIG1).peak_total;
            // direct_nvme does not change memory; others strictly help
            if i == 3 {
                assert_eq!(v, base);
            } else {
                assert!(v < base, "component {i} did not reduce memory");
            }
        }
    }
}
