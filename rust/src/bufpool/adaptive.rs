//! MemAscend's adaptive buffer pool (§IV-B).
//!
//! One subpool per tensor shape class, each with exactly-sized slots:
//! embedding-class slots hold vocab×hidden, ffn-class slots hold
//! intermediate×hidden, kv/qo slots their projection sizes, expert
//! slots the per-expert FFN size.  Subgroup counts follow the paper:
//! {embed: 2, ffn: 3N, kv: 2N, qo: 2N} (+ MoE: 3·E·N expert slots),
//! with N = prefetch depth.  Each subpool's backing is its own
//! exactly-sized [`PinnedArena`] lease — the "few shape-class regions
//! per category" the arena is built around — so releasing the pool
//! returns every class region for same-shape recycling, and buffer
//! access only serializes within one class.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

use crate::config::ModelSpec;
use crate::dtype::DType;
use crate::pinned::{Cat, Lease, PinnedArena};
use crate::tensors::{self, ShapeClass, TensorDesc};

use super::{ParamBufferPool, PoolBuf, PoolStats};

struct SubPool {
    class: ShapeClass,
    slot_bytes: usize,
    /// Free slot offsets into this class's own lease.
    free: Vec<usize>,
    total_slots: usize,
}

struct State {
    subpools: Vec<SubPool>,
    /// lease key -> (subpool idx, offset, requested bytes)
    in_use: HashMap<u64, (usize, usize, usize)>,
    next_key: u64,
    cur_requested: usize,
    cur_capacity: usize,
    stats: PoolStats,
}

pub struct AdaptivePool {
    /// One lease per subpool, parallel to `State::subpools`.
    regions: Vec<Mutex<Lease>>,
    state: Mutex<State>,
    available: Condvar,
}

impl AdaptivePool {
    pub fn new(
        spec: &ModelSpec,
        prefetch_depth: usize,
        dtype: DType,
        arena: &PinnedArena,
    ) -> anyhow::Result<Self> {
        let n = prefetch_depth.max(1);
        let class_sizes = tensors::class_max_elems(spec);
        let class_counts: HashMap<ShapeClass, usize> =
            tensors::class_counts_per_block(spec).into_iter().collect();

        let mut subpools = Vec::new();
        let mut regions = Vec::new();
        let mut total = 0usize;
        for (class, max_elems) in class_sizes {
            let slot_bytes = max_elems * dtype.size();
            let slots = match class {
                // embedding + lm head are needed once each
                ShapeClass::Embed => 2,
                // per-block tensor count × blocks in flight
                _ => class_counts.get(&class).copied().unwrap_or(0) * n,
            };
            if slots == 0 {
                continue;
            }
            let class_bytes = slot_bytes * slots;
            regions.push(Mutex::new(arena.lease(class_bytes, Cat::ParamPool)?));
            let free = (0..slots).rev().map(|i| i * slot_bytes).collect();
            subpools.push(SubPool { class, slot_bytes, free, total_slots: slots });
            total += class_bytes;
        }
        Ok(Self {
            regions,
            state: Mutex::new(State {
                subpools,
                in_use: HashMap::new(),
                next_key: 0,
                cur_requested: 0,
                cur_capacity: 0,
                stats: PoolStats { pool_bytes: total, ..Default::default() },
            }),
            available: Condvar::new(),
        })
    }

    /// Subpool layout summary: (class, slot_bytes, slots).
    pub fn layout(&self) -> Vec<(ShapeClass, usize, usize)> {
        self.state
            .lock()
            .unwrap()
            .subpools
            .iter()
            .map(|s| (s.class, s.slot_bytes, s.total_slots))
            .collect()
    }

    fn subpool_for(st: &State, t: &TensorDesc) -> anyhow::Result<usize> {
        let class = t.shape_class();
        st.subpools
            .iter()
            .position(|s| s.class == class)
            .ok_or_else(|| {
                anyhow::anyhow!("no subpool for class {:?} (tensor {})", class, t.name)
            })
    }

    fn grab(&self, st: &mut State, idx: usize, requested: usize) -> PoolBuf {
        let sp = &mut st.subpools[idx];
        let offset = sp.free.pop().expect("checked non-empty");
        let capacity = sp.slot_bytes;
        let key = st.next_key;
        st.next_key += 1;
        st.in_use.insert(key, (idx, offset, requested));
        st.cur_requested += requested;
        st.cur_capacity += capacity;
        st.stats.acquires += 1;
        st.stats.peak_requested = st.stats.peak_requested.max(st.cur_requested);
        st.stats.peak_capacity = st.stats.peak_capacity.max(st.cur_capacity);
        PoolBuf { key, class: idx, offset, capacity, requested }
    }
}

impl ParamBufferPool for AdaptivePool {
    fn acquire(&self, t: &TensorDesc, dtype: DType) -> anyhow::Result<PoolBuf> {
        let requested = t.bytes(dtype);
        let mut st = self.state.lock().unwrap();
        let idx = Self::subpool_for(&st, t)?;
        anyhow::ensure!(
            requested <= st.subpools[idx].slot_bytes,
            "tensor {} exceeds its class slot",
            t.name
        );
        while st.subpools[idx].free.is_empty() {
            st = self.available.wait(st).unwrap();
        }
        Ok(self.grab(&mut st, idx, requested))
    }

    fn try_acquire(
        &self,
        t: &TensorDesc,
        dtype: DType,
    ) -> anyhow::Result<Option<PoolBuf>> {
        let requested = t.bytes(dtype);
        let mut st = self.state.lock().unwrap();
        let idx = Self::subpool_for(&st, t)?;
        if st.subpools[idx].free.is_empty() {
            return Ok(None);
        }
        Ok(Some(self.grab(&mut st, idx, requested)))
    }

    fn release(&self, buf: PoolBuf) {
        let mut st = self.state.lock().unwrap();
        let (idx, offset, requested) = st
            .in_use
            .remove(&buf.key)
            .expect("release of unknown or double-released buffer");
        let cap = st.subpools[idx].slot_bytes;
        st.subpools[idx].free.push(offset);
        st.cur_requested -= requested;
        st.cur_capacity -= cap;
        st.stats.releases += 1;
        drop(st);
        self.available.notify_all();
    }

    fn with_buf(&self, buf: &PoolBuf, f: &mut dyn FnMut(&mut [u8])) {
        // lock only to read the class region's base — NOT for the
        // closure: slots are disjoint carves handed out exactly once
        // until release, so a device read into slot A and an upconvert
        // out of slot B of the same class run concurrently (the whole
        // point of the queue→stage fetch split)
        let base = self.regions[buf.class].lock().unwrap().span_base();
        if base.is_null() {
            f(&mut []);
            return;
        }
        // SAFETY: [offset, offset+requested) lies inside the slot this
        // PoolBuf exclusively owns between acquire and release; slots
        // within a class never overlap and the class lease outlives
        // the pool, so this view aliases nothing live.
        let slice = unsafe {
            std::slice::from_raw_parts_mut(base.add(buf.offset), buf.requested)
        };
        f(slice);
    }

    fn stats(&self) -> PoolStats {
        self.state.lock().unwrap().stats
    }

    fn label(&self) -> &'static str {
        "adaptive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::{sample_tensors, test_arena};
    use crate::bufpool::MonolithicPool;
    use crate::config::presets;
    use crate::pinned::Mode;
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};
    use crate::util::rng::Xoshiro256;
    use std::sync::Arc;

    fn mk(spec: &ModelSpec, depth: usize) -> AdaptivePool {
        AdaptivePool::new(spec, depth, DType::F16, &test_arena(Mode::Virtual)).unwrap()
    }

    #[test]
    fn subgroup_counts_match_paper() {
        // paper §IV-B: counts {2, 3N, 2N, 2N} for embed/ffn/kv/qo
        let pool = mk(&presets::QWEN25_7B, 2);
        let layout: HashMap<ShapeClass, usize> = pool
            .layout()
            .into_iter()
            .map(|(c, _, slots)| (c, slots))
            .collect();
        assert_eq!(layout[&ShapeClass::Embed], 2);
        assert_eq!(layout[&ShapeClass::Ffn], 3 * 2);
        assert_eq!(layout[&ShapeClass::Kv], 2 * 2);
        assert_eq!(layout[&ShapeClass::Qo], 2 * 2);
    }

    #[test]
    fn pool_is_dramatically_smaller_than_monolithic() {
        // Fig. 11: avg 72.71% reduction
        for spec in presets::PAPER_DENSE {
            let mono =
                MonolithicPool::new(spec, 2, DType::F16, &test_arena(Mode::Virtual))
                    .unwrap();
            let adap = mk(spec, 2);
            let m = mono.stats().pool_bytes as f64;
            let a = adap.stats().pool_bytes as f64;
            let reduction = 1.0 - a / m;
            assert!(
                reduction > 0.5,
                "{}: only {:.1}% reduction",
                spec.name,
                reduction * 100.0
            );
        }
    }

    #[test]
    fn acquire_gets_exact_class_slot() {
        let spec = &presets::QWEN25_7B;
        let pool = mk(spec, 2);
        let ts = sample_tensors(spec);
        let ffn = ts.iter().find(|t| t.name.contains("w_gate")).unwrap();
        let b = pool.acquire(ffn, DType::F16).unwrap();
        assert_eq!(b.capacity, 18_944 * 3584 * 2);
        assert_eq!(b.requested, b.capacity); // exact fit: zero waste
        pool.release(b);
    }

    #[test]
    fn moe_expert_class_exists() {
        let spec = &presets::QWEN3_30B_A3B;
        let pool = mk(spec, 1);
        let layout: HashMap<ShapeClass, usize> = pool
            .layout()
            .into_iter()
            .map(|(c, _, slots)| (c, slots))
            .collect();
        assert_eq!(layout[&ShapeClass::Expert], 3 * 128);
        // expert slots are small — the pool must not size them to the
        // embedding (the baseline's failure on MoE, Fig. 18)
        let expert_slot = pool
            .layout()
            .iter()
            .find(|(c, _, _)| *c == ShapeClass::Expert)
            .unwrap()
            .1;
        assert_eq!(expert_slot, 2048 * 768 * 2);
    }

    #[test]
    fn pool_bytes_equal_arena_leased_demand() {
        // the "policy over the arena" invariant: every pool byte is an
        // arena-leased byte, nothing more
        let arena = test_arena(Mode::Virtual);
        let pool =
            AdaptivePool::new(&presets::QWEN25_7B, 2, DType::F16, &arena).unwrap();
        assert_eq!(arena.stats().requested_bytes, pool.stats().pool_bytes);
        assert_eq!(
            arena.watermark(Cat::ParamPool).requested,
            pool.stats().pool_bytes
        );
        drop(pool);
        assert_eq!(arena.stats().requested_bytes, 0);
    }

    #[test]
    fn prop_no_overlap_and_exact_free() {
        check("adaptive-pool", Config { cases: 32, ..Default::default() }, |rng, _| {
            let spec = &presets::TINY100M;
            let pool = mk(spec, 2);
            let ts = sample_tensors(spec);
            let mut held: Vec<PoolBuf> = Vec::new();
            for _ in 0..200 {
                if !held.is_empty() && rng.next_f64() < 0.5 {
                    let i = rng.below(held.len());
                    pool.release(held.swap_remove(i));
                } else {
                    let t = &ts[rng.below(ts.len())];
                    if let Some(b) = pool.try_acquire(t, DType::F16).unwrap() {
                        // overlap check against everything held in the
                        // same class lease
                        for o in held.iter().filter(|o| o.class == b.class) {
                            let disjoint = b.offset + b.capacity <= o.offset
                                || o.offset + o.capacity <= b.offset;
                            prop_assert!(
                                disjoint,
                                "class {} lease [{},{}) overlaps [{},{})",
                                b.class,
                                b.offset,
                                b.offset + b.capacity,
                                o.offset,
                                o.offset + o.capacity
                            );
                        }
                        held.push(b);
                    }
                }
            }
            let st = pool.stats();
            prop_assert!(
                st.acquires == st.releases + held.len() as u64,
                "lease ledger drift"
            );
            Ok(())
        });
    }

    #[test]
    fn real_mode_data_roundtrip() {
        let arena = test_arena(Mode::Real);
        let spec = &presets::SMOKE;
        let pool = AdaptivePool::new(spec, 1, DType::F32, &arena).unwrap();
        let ts = sample_tensors(spec);
        let b = pool.acquire(&ts[0], DType::F32).unwrap();
        pool.with_buf(&b, &mut |s| {
            for (i, x) in s.iter_mut().enumerate() {
                *x = (i % 251) as u8;
            }
        });
        pool.with_buf(&b, &mut |s| {
            assert!(s.iter().enumerate().all(|(i, &x)| x == (i % 251) as u8));
        });
        pool.release(b);
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let spec = &presets::SMOKE;
        let pool = Arc::new(mk(spec, 1));
        let ts = sample_tensors(spec);
        let embed = ts.iter().find(|t| t.name == "embed").unwrap().clone();
        let b1 = pool.acquire(&embed, DType::F16).unwrap();
        let b2 = pool.acquire(&embed, DType::F16).unwrap(); // 2 embed slots
        let p2 = pool.clone();
        let e2 = embed.clone();
        let h = std::thread::spawn(move || p2.acquire(&e2, DType::F16).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(30));
        pool.release(b1);
        let b3 = h.join().unwrap();
        pool.release(b2);
        pool.release(b3);
        let _ = Xoshiro256::new(0); // keep import used in cfg permutations
    }
}
