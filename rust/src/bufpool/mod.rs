//! Parameter buffer pools: prefetch staging between SSD and "GPU".
//!
//! The pool is where §III-A's fragmentation lives.  Since the arena
//! refactor, neither pool owns pinned memory: both are *sizing
//! policies* over [`crate::pinned::PinnedArena`] leases, differing only
//! in how slots are shaped:
//!
//! - [`monolithic::MonolithicPool`] (baseline): one lease, every slot
//!   sized to the *largest* offloadable tensor (the embedding), so a kv
//!   projection occupies an embedding-sized slot → ~70%+ internal
//!   fragmentation.  This is ZeRO-Infinity's scheme: a monolithic
//!   region plus a hashtable of sub-buffer metadata.
//! - [`adaptive::AdaptivePool`] (MemAscend §IV-B): one exactly-sized
//!   lease per shape class (embed / ffn / kv / qo / expert) with
//!   subgroup counts {2, 3N, 2N, 2N} for N blocks in flight.  Because
//!   each class is its own lease, releasing the pool hands each class
//!   region back to the arena for same-shape recycling, and `with_buf`
//!   only serializes within a class.
//!
//! Slot bookkeeping (free lists, blocking acquire, the lease-key
//! hashtable) stays here; the bytes, the budget, and the
//! overlap-freedom invariant live in the arena.

pub mod adaptive;
pub mod monolithic;

pub use adaptive::AdaptivePool;
pub use monolithic::MonolithicPool;

use crate::dtype::DType;
use crate::tensors::TensorDesc;

/// A leased sub-buffer: logical offset/len into one of the pool's
/// arena leases plus the hashtable key that tracks it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolBuf {
    pub key: u64,
    /// Which pool lease the buffer lives in (shape-class index for the
    /// adaptive pool; always 0 for the monolithic pool).
    pub class: usize,
    /// Offset within that lease.
    pub offset: usize,
    /// Capacity of the slot (the fragmentation source when > requested).
    pub capacity: usize,
    /// Bytes actually requested for the tensor.
    pub requested: usize,
}

/// Utilization snapshot for Fig. 11.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Total bytes of the backing leases (what the pool keeps pinned).
    pub pool_bytes: usize,
    /// Peak simultaneously-requested bytes (the "actual need").
    pub peak_requested: usize,
    /// Peak simultaneously-occupied slot capacity.
    pub peak_capacity: usize,
    pub acquires: u64,
    pub releases: u64,
}

impl PoolStats {
    /// Internal fragmentation = 1 - actual-need / pool-size
    /// (paper §III-A: 13.05 GiB pool, 3.81 GiB needed -> 70.82%).
    pub fn fragmentation(&self) -> f64 {
        if self.pool_bytes == 0 {
            return 0.0;
        }
        1.0 - self.peak_requested as f64 / self.pool_bytes as f64
    }
}

/// Common interface the swapper drives.
pub trait ParamBufferPool: Send + Sync {
    /// Lease a staging buffer for tensor `t` at transfer dtype `dtype`.
    /// Blocks until a slot frees up (backpressure on the prefetcher).
    fn acquire(&self, t: &TensorDesc, dtype: DType) -> anyhow::Result<PoolBuf>;

    /// Non-blocking acquire (returns None when the class is exhausted).
    fn try_acquire(&self, t: &TensorDesc, dtype: DType)
        -> anyhow::Result<Option<PoolBuf>>;

    fn release(&self, buf: PoolBuf);

    /// Run `f` over the buffer's backing bytes (requested span).
    /// Virtual-mode pools call `f` with an empty slice.
    fn with_buf(&self, buf: &PoolBuf, f: &mut dyn FnMut(&mut [u8]));

    fn stats(&self) -> PoolStats;

    fn label(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_util {
    use std::sync::Arc;

    use crate::config::ModelSpec;
    use crate::pinned::{
        AlignedAllocator, ArenaConfig, MemoryTracker, Mode, PinnedArena,
    };
    use crate::tensors::{inventory, TensorDesc};

    /// The offloadable tensors of one block plus embed/head.
    pub fn sample_tensors(spec: &ModelSpec) -> Vec<TensorDesc> {
        inventory(spec)
            .into_iter()
            .filter(|t| t.offloadable())
            .collect()
    }

    pub fn test_arena(mode: Mode) -> Arc<PinnedArena> {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(mode, tracker);
        PinnedArena::new(Arc::new(alloc), ArenaConfig::default())
    }
}
