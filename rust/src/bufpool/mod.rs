//! Parameter buffer pools: prefetch staging between SSD and "GPU".
//!
//! The pool is where §III-A's fragmentation lives.  Both designs follow
//! ZeRO-Infinity's underlying scheme — allocate **one monolithic pinned
//! region** up front, then hand out logical sub-buffers tracked by a
//! hashtable of metadata — but differ in how sub-buffers are sized:
//!
//! - [`monolithic::MonolithicPool`] (baseline): every buffer is sized
//!   to the *largest* offloadable tensor (the embedding), so a kv
//!   projection occupies an embedding-sized slot → ~70%+ internal
//!   fragmentation.
//! - [`adaptive::AdaptivePool`] (MemAscend §IV-B): one subpool per
//!   shape class (embed / ffn / kv / qo / expert), each sized exactly,
//!   with subgroup counts {2, 3N, 2N, 2N} for N blocks in flight.

pub mod adaptive;
pub mod monolithic;

pub use adaptive::AdaptivePool;
pub use monolithic::MonolithicPool;

use crate::dtype::DType;
use crate::tensors::TensorDesc;

/// A leased sub-buffer: logical offset/len into the pool's monolithic
/// backing region plus the hashtable key that tracks it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolBuf {
    pub key: u64,
    pub offset: usize,
    /// Capacity of the slot (the fragmentation source when > requested).
    pub capacity: usize,
    /// Bytes actually requested for the tensor.
    pub requested: usize,
}

/// Utilization snapshot for Fig. 11.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Total bytes of the backing region (what the pool pins forever).
    pub pool_bytes: usize,
    /// Peak simultaneously-requested bytes (the "actual need").
    pub peak_requested: usize,
    /// Peak simultaneously-occupied slot capacity.
    pub peak_capacity: usize,
    pub acquires: u64,
    pub releases: u64,
}

impl PoolStats {
    /// Internal fragmentation = 1 - actual-need / pool-size
    /// (paper §III-A: 13.05 GiB pool, 3.81 GiB needed -> 70.82%).
    pub fn fragmentation(&self) -> f64 {
        if self.pool_bytes == 0 {
            return 0.0;
        }
        1.0 - self.peak_requested as f64 / self.pool_bytes as f64
    }
}

/// Common interface the swapper drives.
pub trait ParamBufferPool: Send + Sync {
    /// Lease a staging buffer for tensor `t` at transfer dtype `dtype`.
    /// Blocks until a slot frees up (backpressure on the prefetcher).
    fn acquire(&self, t: &TensorDesc, dtype: DType) -> anyhow::Result<PoolBuf>;

    /// Non-blocking acquire (returns None when the class is exhausted).
    fn try_acquire(&self, t: &TensorDesc, dtype: DType)
        -> anyhow::Result<Option<PoolBuf>>;

    fn release(&self, buf: PoolBuf);

    /// Run `f` over the buffer's backing bytes (requested span).
    /// Virtual-mode pools call `f` with an empty slice.
    fn with_buf(&self, buf: &PoolBuf, f: &mut dyn FnMut(&mut [u8]));

    fn stats(&self) -> PoolStats;

    fn label(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_util {
    use crate::config::ModelSpec;
    use crate::tensors::{inventory, TensorDesc};

    /// The offloadable tensors of one block plus embed/head.
    pub fn sample_tensors(spec: &ModelSpec) -> Vec<TensorDesc> {
        inventory(spec)
            .into_iter()
            .filter(|t| t.offloadable())
            .collect()
    }
}
