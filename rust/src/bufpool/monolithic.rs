//! Baseline buffer pool: uniform slots sized to the largest tensor.
//!
//! Reproduces ZeRO-Infinity's parameter-swap buffer management: the
//! pool holds `count` identical slots of `slot_bytes` each, where
//! `slot_bytes` is the largest offloadable tensor's transfer size and
//! `count` covers the embedding + N in-flight blocks' tensors.  Every
//! acquire occupies a full slot regardless of the tensor's real size —
//! the internal fragmentation of §III-A.  The backing bytes are one
//! [`PinnedArena`] lease; the pool only does slot policy.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

use crate::config::ModelSpec;
use crate::dtype::DType;
use crate::pinned::{Cat, Lease, PinnedArena};
use crate::tensors::{self, TensorDesc};

use super::{ParamBufferPool, PoolBuf, PoolStats};

struct State {
    free_slots: Vec<usize>,
    in_use: HashMap<u64, (usize, usize)>, // key -> (slot, requested)
    next_key: u64,
    cur_requested: usize,
    cur_capacity: usize,
    stats: PoolStats,
}

pub struct MonolithicPool {
    slot_bytes: usize,
    region: Mutex<Lease>,
    state: Mutex<State>,
    available: Condvar,
}

impl MonolithicPool {
    /// `prefetch_depth` = N blocks in flight (paper's buffer-count
    /// driver). Transfer dtype sizes the slots.  Fails only if the
    /// arena refuses the backing lease (budget).
    pub fn new(
        spec: &ModelSpec,
        prefetch_depth: usize,
        dtype: DType,
        arena: &PinnedArena,
    ) -> anyhow::Result<Self> {
        let slot_bytes = tensors::largest_offloadable_elems(spec) * dtype.size();
        let per_block: usize = tensors::class_counts_per_block(spec)
            .iter()
            .map(|(_, n)| n)
            .sum();
        // embedding + lm head + N blocks' offloadable tensors
        let count = 2 + per_block * prefetch_depth.max(1);
        let total = slot_bytes * count;
        let region = arena.lease(total.max(1), Cat::ParamPool)?;
        Ok(Self {
            slot_bytes,
            region: Mutex::new(region),
            state: Mutex::new(State {
                free_slots: (0..count).rev().collect(),
                in_use: HashMap::new(),
                next_key: 0,
                cur_requested: 0,
                cur_capacity: 0,
                stats: PoolStats { pool_bytes: total, ..Default::default() },
            }),
            available: Condvar::new(),
        })
    }

    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    fn grab(&self, st: &mut State, requested: usize) -> PoolBuf {
        let slot = st.free_slots.pop().expect("checked non-empty");
        let key = st.next_key;
        st.next_key += 1;
        st.in_use.insert(key, (slot, requested));
        st.cur_requested += requested;
        st.cur_capacity += self.slot_bytes;
        st.stats.acquires += 1;
        st.stats.peak_requested = st.stats.peak_requested.max(st.cur_requested);
        st.stats.peak_capacity = st.stats.peak_capacity.max(st.cur_capacity);
        PoolBuf {
            key,
            class: 0,
            offset: slot * self.slot_bytes,
            capacity: self.slot_bytes,
            requested,
        }
    }
}

impl ParamBufferPool for MonolithicPool {
    fn acquire(&self, t: &TensorDesc, dtype: DType) -> anyhow::Result<PoolBuf> {
        let requested = t.bytes(dtype);
        anyhow::ensure!(
            requested <= self.slot_bytes,
            "tensor {} ({} B) exceeds slot size {} B",
            t.name,
            requested,
            self.slot_bytes
        );
        let mut st = self.state.lock().unwrap();
        while st.free_slots.is_empty() {
            st = self.available.wait(st).unwrap();
        }
        Ok(self.grab(&mut st, requested))
    }

    fn try_acquire(
        &self,
        t: &TensorDesc,
        dtype: DType,
    ) -> anyhow::Result<Option<PoolBuf>> {
        let requested = t.bytes(dtype);
        anyhow::ensure!(requested <= self.slot_bytes, "tensor too large for slot");
        let mut st = self.state.lock().unwrap();
        if st.free_slots.is_empty() {
            return Ok(None);
        }
        Ok(Some(self.grab(&mut st, requested)))
    }

    fn release(&self, buf: PoolBuf) {
        let mut st = self.state.lock().unwrap();
        let (slot, requested) = st
            .in_use
            .remove(&buf.key)
            .expect("release of unknown or double-released buffer");
        st.free_slots.push(slot);
        st.cur_requested -= requested;
        st.cur_capacity -= self.slot_bytes;
        st.stats.releases += 1;
        drop(st);
        self.available.notify_one();
    }

    fn with_buf(&self, buf: &PoolBuf, f: &mut dyn FnMut(&mut [u8])) {
        // lock only to read the region base — slots are disjoint
        // carves, so concurrent with_buf calls on different slots
        // (device read vs upconvert) proceed in parallel
        let base = self.region.lock().unwrap().span_base();
        if base.is_null() {
            f(&mut []);
            return;
        }
        // SAFETY: [offset, offset+requested) lies inside the slot this
        // PoolBuf exclusively owns between acquire and release; slots
        // never overlap and the pool lease outlives the pool.
        let slice = unsafe {
            std::slice::from_raw_parts_mut(base.add(buf.offset), buf.requested)
        };
        f(slice);
    }

    fn stats(&self) -> PoolStats {
        self.state.lock().unwrap().stats
    }

    fn label(&self) -> &'static str {
        "monolithic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::{sample_tensors, test_arena};
    use crate::config::presets;
    use crate::pinned::Mode;

    fn mk(spec: &ModelSpec, depth: usize) -> MonolithicPool {
        MonolithicPool::new(spec, depth, DType::F16, &test_arena(Mode::Virtual)).unwrap()
    }

    #[test]
    fn slots_sized_to_embedding() {
        let pool = mk(&presets::QWEN25_7B, 2);
        assert_eq!(pool.slot_bytes(), 152_064 * 3584 * 2);
    }

    #[test]
    fn small_tensor_occupies_full_slot() {
        let pool = mk(&presets::QWEN25_7B, 2);
        let ts = sample_tensors(&presets::QWEN25_7B);
        let kv = ts.iter().find(|t| t.name.contains("wk")).unwrap();
        let buf = pool.acquire(kv, DType::F16).unwrap();
        assert_eq!(buf.capacity, pool.slot_bytes());
        assert!(buf.requested < buf.capacity / 10); // >90% slot waste
        pool.release(buf);
    }

    #[test]
    fn fragmentation_matches_paper_ballpark() {
        // Walk one full forward pass's acquires with depth-2 prefetch;
        // fragmentation should land in the paper's 70%+ range.
        let spec = &presets::QWEN25_7B;
        let pool = mk(spec, 2);
        let ts = sample_tensors(spec);
        // hold embedding + 2 blocks, then stream remaining blocks
        let mut held: Vec<PoolBuf> = Vec::new();
        for t in ts.iter().take(1 + 14) {
            held.push(pool.acquire(t, DType::F16).unwrap());
        }
        for t in ts.iter().skip(15) {
            let b = pool.acquire(t, DType::F16).unwrap();
            pool.release(held.remove(1.min(held.len() - 1)));
            held.push(b);
        }
        let frag = pool.stats().fragmentation();
        assert!(frag > 0.55, "fragmentation {frag} unexpectedly low");
    }

    #[test]
    fn exhaustion_blocks_try_acquire() {
        let spec = &presets::SMOKE;
        let pool = mk(spec, 1);
        let ts = sample_tensors(spec);
        let mut held = Vec::new();
        while let Some(b) = pool.try_acquire(&ts[0], DType::F16).unwrap() {
            held.push(b);
        }
        assert!(pool.try_acquire(&ts[0], DType::F16).unwrap().is_none());
        pool.release(held.pop().unwrap());
        assert!(pool.try_acquire(&ts[0], DType::F16).unwrap().is_some());
    }

    #[test]
    #[should_panic(expected = "double-released")]
    fn double_release_panics() {
        let spec = &presets::SMOKE;
        let pool = mk(spec, 1);
        let ts = sample_tensors(spec);
        let b = pool.acquire(&ts[0], DType::F16).unwrap();
        pool.release(b);
        pool.release(b);
    }

    #[test]
    fn dropping_the_pool_returns_its_lease() {
        let arena = test_arena(Mode::Virtual);
        let pool = MonolithicPool::new(&presets::SMOKE, 1, DType::F16, &arena).unwrap();
        let bytes = pool.stats().pool_bytes;
        assert_eq!(arena.stats().requested_bytes, bytes);
        drop(pool);
        assert_eq!(arena.stats().requested_bytes, 0);
    }
}
