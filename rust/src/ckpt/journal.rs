//! The epoch journal: a dual-slot, checksummed superblock over two
//! engine keys that makes checkpoint commits atomic.
//!
//! A checkpoint epoch is *committed* by writing one [`CkptState`]
//! record into the slot for that epoch ([`SLOT_A`] for even epochs,
//! [`SLOT_B`] for odd) and flushing it.  The previous epoch's record
//! lives in the *other* slot and is never touched by the commit, so a
//! torn or lost slot write can only invalidate the epoch being
//! committed — [`Journal::load`] parses both slots, discards any whose
//! magic/length/checksum fail, and returns the highest valid epoch.
//! Rollback on a torn commit is therefore not a recovery procedure;
//! it is what load does anyway.
//!
//! Records are fixed-capacity (first write rounds up to the next
//! 4 KiB; later commits reuse the stored length) because engine keys
//! are fixed-length once written, and zero-padded past the payload.
//! All multi-byte header fields are little-endian; `u64` values inside
//! the JSON payload that can exceed 2^53 (RNG state, seeds, digests)
//! are hex strings, since the JSON number type is an `f64`.
//!
//! Each record's key list carries the **per-key extent map** of the
//! shadow-paged state layer ([`crate::ckpt::shadow`]): `(key, len,
//! ext)` triples naming which of a key's two physical extents the
//! epoch owns.  Post-commit write-backs land in the *other* extent,
//! so every valid record in either slot describes extents no later
//! window has touched — [`Journal::load_all`] returns them all
//! (newest first) and resume walks back until one validates.  The old
//! dirty-marker refusal contract is gone; its only survivor is
//! [`Journal::invalidate`], which a fresh run uses to retire stale
//! records before re-initializing weights under the same keys.

use std::sync::Arc;

use crate::ssd::NvmeEngine;
use crate::util::json::Json;

use super::shadow::phys_key;

/// Slot key for even-numbered epochs.
pub const SLOT_A: &str = "ckpt/journal/a";
/// Slot key for odd-numbered epochs.
pub const SLOT_B: &str = "ckpt/journal/b";

/// Record magic ("MACKPTJ1" as little-endian bytes).
const MAGIC: u64 = u64::from_le_bytes(*b"MACKPTJ1");
/// magic + epoch + payload_len + checksum, all u64 LE.
const HEADER: usize = 32;
/// Slot capacity granularity.
const SLOT_ALIGN: usize = 4096;
/// Headroom over the first payload, so later epochs whose numbers grow
/// a few digits still fit the fixed-capacity slot.
const SLOT_SLACK: usize = 2048;

/// FNV-1a 64-bit — the journal's payload checksum and the layout
/// digest hash.  Not cryptographic; it detects torn writes and stale
/// blobs, which is all the journal needs.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn hex(v: u64) -> Json {
    Json::from(format!("{v:016x}"))
}

fn req_hex(j: &Json, key: &str) -> anyhow::Result<u64> {
    let s = j
        .req(key)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("journal: field '{key}' not a hex string"))?;
    u64::from_str_radix(s, 16)
        .map_err(|e| anyhow::anyhow!("journal: field '{key}' bad hex: {e}"))
}

fn req_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("journal: field '{key}' not a number"))
}

/// Everything one committed epoch pins down: which step the on-SSD key
/// set is consistent at, plus the host-side cursors (data-loader RNG,
/// loss scaler, step counters, pipeline tuning) needed to continue the
/// run bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptState {
    /// Commit sequence number, starting at 1.
    pub epoch: u64,
    /// Steps completed when this epoch was committed.
    pub steps_done: u64,
    /// Optimizer steps actually applied (`<= steps_done`: overflow
    /// steps are skipped).
    pub applied_steps: u64,
    /// The run's weight-init / data seed (resume must match it).
    pub seed: u64,
    /// Model spec name, to refuse resuming against foreign storage.
    pub model: String,
    /// Optimizer state dtype label ("f32" | "bf16").
    pub dtype: String,
    /// Data-loader cursor: the corpus RNG state.
    pub corpus_rng: [u64; 4],
    /// Loss-scaler dynamic state ([`crate::offload::LossScaler`]).
    pub scale: f64,
    pub good_steps: usize,
    pub overflows: u64,
    pub growths: u64,
    /// Pipeline tuning in effect at commit (the governed knobs).
    pub tile_bytes: usize,
    pub tile_depth: usize,
    pub prefetch_depth: usize,
    /// Replay-schedule lead-time (µs) in effect at commit; absent in
    /// pre-prefetch records, which decode to the spec default.
    pub sched_lead_us: u64,
    /// Activation-store host budget in effect at commit (hex-encoded:
    /// `usize::MAX` = unbudgeted exceeds the JSON f64 range).
    pub act_host_budget: usize,
    /// Every on-SSD key this epoch is consistent over: `(logical key,
    /// stored length, owning extent)`.  The extent (0 or 1) names
    /// which physical copy of a shadow-paged key holds this epoch's
    /// bytes ([`crate::ckpt::shadow::phys_key`]); resume validates
    /// each resolved key against `len_of` and installs the map into
    /// the shadow layer.  Records from pre-shadow epochs decode with
    /// extent 0 throughout.
    pub keys: Vec<(String, usize, u8)>,
    /// FNV-1a digest of the persisted coalesce-layout blob
    /// ([`crate::optimizer::coalesce::LAYOUT_KEY`]); `None` for
    /// uncoalesced runs.
    pub layout_digest: Option<u64>,
    /// FNV-1a digest of the persisted step-profile blob
    /// ([`crate::offload::prefetch::PROFILE_KEY`]); `None` when the
    /// run keeps no recorded prefetch schedule.  Resume revalidates it
    /// and *degrades* on mismatch (re-record) instead of erroring —
    /// the profile is a performance hint, not state.
    pub profile_digest: Option<u64>,
}

impl CkptState {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", hex(self.epoch)),
            ("steps_done", hex(self.steps_done)),
            ("applied_steps", hex(self.applied_steps)),
            ("seed", hex(self.seed)),
            ("model", Json::from(self.model.clone())),
            ("dtype", Json::from(self.dtype.clone())),
            (
                "corpus_rng",
                Json::Arr(self.corpus_rng.iter().map(|&v| hex(v)).collect()),
            ),
            ("scale", Json::from(self.scale)),
            ("good_steps", Json::from(self.good_steps)),
            ("overflows", hex(self.overflows)),
            ("growths", hex(self.growths)),
            ("tile_bytes", Json::from(self.tile_bytes)),
            ("tile_depth", Json::from(self.tile_depth)),
            ("prefetch_depth", Json::from(self.prefetch_depth)),
            ("sched_lead_us", hex(self.sched_lead_us)),
            ("act_host_budget", hex(self.act_host_budget as u64)),
            (
                "keys",
                Json::Arr(
                    self.keys
                        .iter()
                        .map(|(k, l, ext)| {
                            Json::obj(vec![
                                ("key", Json::from(k.clone())),
                                ("len", Json::from(*l)),
                                ("ext", Json::from(*ext as usize)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "layout_digest",
                match self.layout_digest {
                    Some(d) => hex(d),
                    None => Json::Null,
                },
            ),
            (
                "profile_digest",
                match self.profile_digest {
                    Some(d) => hex(d),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let rng_arr = j
            .req("corpus_rng")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("journal: corpus_rng not an array"))?;
        anyhow::ensure!(rng_arr.len() == 4, "journal: corpus_rng must have 4 words");
        let mut corpus_rng = [0u64; 4];
        for (i, v) in rng_arr.iter().enumerate() {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("journal: corpus_rng[{i}] not hex"))?;
            corpus_rng[i] = u64::from_str_radix(s, 16)
                .map_err(|e| anyhow::anyhow!("journal: corpus_rng[{i}] bad hex: {e}"))?;
        }
        let keys = j
            .req("keys")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("journal: keys not an array"))?
            .iter()
            .map(|e| {
                let k = e
                    .req("key")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("journal: bad key name"))?
                    .to_string();
                let l = req_usize(e, "len")?;
                // pre-shadow records have no extent field: extent 0
                let ext = match e.get("ext") {
                    None | Some(Json::Null) => 0u8,
                    Some(_) => {
                        let v = req_usize(e, "ext")?;
                        anyhow::ensure!(v <= 1, "journal: extent {v} out of range");
                        v as u8
                    }
                };
                Ok((k, l, ext))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let layout_digest = match j.get("layout_digest") {
            None | Some(Json::Null) => None,
            Some(_) => Some(req_hex(j, "layout_digest")?),
        };
        let profile_digest = match j.get("profile_digest") {
            None | Some(Json::Null) => None,
            Some(_) => Some(req_hex(j, "profile_digest")?),
        };
        // absent in records committed before the prefetch knobs
        // existed: decode to the spec defaults
        let sched_lead_us = match j.get("sched_lead_us") {
            None | Some(Json::Null) => 2_000,
            Some(_) => req_hex(j, "sched_lead_us")?,
        };
        let act_host_budget = match j.get("act_host_budget") {
            None | Some(Json::Null) => usize::MAX,
            Some(_) => req_hex(j, "act_host_budget")? as usize,
        };
        Ok(Self {
            epoch: req_hex(j, "epoch")?,
            steps_done: req_hex(j, "steps_done")?,
            applied_steps: req_hex(j, "applied_steps")?,
            seed: req_hex(j, "seed")?,
            model: j
                .req("model")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("journal: bad model"))?
                .to_string(),
            dtype: j
                .req("dtype")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("journal: bad dtype"))?
                .to_string(),
            corpus_rng,
            scale: j
                .req("scale")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("journal: bad scale"))?,
            good_steps: req_usize(j, "good_steps")?,
            overflows: req_hex(j, "overflows")?,
            growths: req_hex(j, "growths")?,
            tile_bytes: req_usize(j, "tile_bytes")?,
            tile_depth: req_usize(j, "tile_depth")?,
            prefetch_depth: req_usize(j, "prefetch_depth")?,
            sched_lead_us,
            act_host_budget,
            keys,
            layout_digest,
            profile_digest,
        })
    }

    /// Validate every journaled key against the engine's current
    /// inventory — the first line of defence against resuming over
    /// foreign or truncated storage.  Keys resolve through the
    /// record's extent map, so this works on the raw (un-shadowed)
    /// engine; a failure sends resume walking back one epoch.
    pub fn validate_keys(&self, engine: &dyn NvmeEngine) -> anyhow::Result<()> {
        for (key, len, ext) in &self.keys {
            let phys = phys_key(key, *ext);
            match engine.len_of(&phys) {
                Some(stored) => anyhow::ensure!(
                    stored == *len,
                    "checkpoint epoch {} expects '{phys}' at {len} bytes, storage \
                     has {stored}",
                    self.epoch
                ),
                None => anyhow::bail!(
                    "checkpoint epoch {} references '{phys}' which is missing from \
                     storage",
                    self.epoch
                ),
            }
        }
        Ok(())
    }

    /// The record's `(logical key, extent)` map, ready for
    /// [`crate::ckpt::shadow::ShadowEngine::install`].
    pub fn extent_map(&self) -> Vec<(String, u8)> {
        self.keys.iter().map(|(k, _, ext)| (k.clone(), *ext)).collect()
    }
}

/// Handle on the dual-slot journal of one storage root.
pub struct Journal {
    engine: Arc<dyn NvmeEngine>,
}

impl Journal {
    pub fn new(engine: Arc<dyn NvmeEngine>) -> Self {
        Self { engine }
    }

    fn slot_key(epoch: u64) -> &'static str {
        if epoch % 2 == 0 {
            SLOT_A
        } else {
            SLOT_B
        }
    }

    /// Commit `state` as the newest epoch: one checksummed record into
    /// this epoch's slot, then a flush barrier on the slot.  The
    /// caller must have flushed every data key listed in `state.keys`
    /// *before* calling — a visible journal record always describes
    /// already-durable data.  On error the previous epoch's slot is
    /// untouched and [`Self::load`] still returns it.
    pub fn commit(&self, state: &CkptState) -> anyhow::Result<()> {
        let payload = state.to_json().to_string().into_bytes();
        let mut rec = Vec::with_capacity(HEADER + payload.len());
        rec.extend_from_slice(&MAGIC.to_le_bytes());
        rec.extend_from_slice(&state.epoch.to_le_bytes());
        rec.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        rec.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        rec.extend_from_slice(&payload);
        let key = Self::slot_key(state.epoch);
        let cap = match self.engine.len_of(key) {
            Some(cap) => {
                anyhow::ensure!(
                    rec.len() <= cap,
                    "journal record ({} bytes) outgrew slot '{key}' ({cap} bytes)",
                    rec.len()
                );
                cap
            }
            None => (rec.len() + SLOT_SLACK).div_ceil(SLOT_ALIGN) * SLOT_ALIGN,
        };
        rec.resize(cap, 0);
        self.engine.write(key, &rec)?;
        self.engine.flush(key)
    }

    fn decode(buf: &[u8]) -> Option<CkptState> {
        if buf.len() < HEADER {
            return None;
        }
        let word = |i: usize| u64::from_le_bytes(buf[i * 8..i * 8 + 8].try_into().unwrap());
        if word(0) != MAGIC {
            return None;
        }
        let epoch = word(1);
        let plen = word(2) as usize;
        let sum = word(3);
        if plen > buf.len() - HEADER {
            return None;
        }
        let payload = &buf[HEADER..HEADER + plen];
        if fnv1a64(payload) != sum {
            return None;
        }
        let json = Json::parse(std::str::from_utf8(payload).ok()?).ok()?;
        let state = CkptState::from_json(&json).ok()?;
        if state.epoch != epoch {
            return None;
        }
        Some(state)
    }

    fn read_slot(&self, key: &str) -> Option<CkptState> {
        let len = self.engine.len_of(key)?;
        let mut buf = vec![0u8; len];
        self.engine.read(key, &mut buf).ok()?;
        Self::decode(&buf)
    }

    /// Newest valid committed epoch, or `None` for unjournaled
    /// storage.  A slot that fails magic/length/checksum validation is
    /// treated as absent — which is exactly how a torn commit rolls
    /// back to the previous epoch.
    pub fn load(&self) -> Option<CkptState> {
        self.load_all().into_iter().next()
    }

    /// Every valid committed epoch, newest first (at most two: one per
    /// slot).  Resume walks this list — a candidate whose extents fail
    /// validation (bit-rot, foreign storage) falls back to the next.
    pub fn load_all(&self) -> Vec<CkptState> {
        let mut out: Vec<CkptState> = [self.read_slot(SLOT_A), self.read_slot(SLOT_B)]
            .into_iter()
            .flatten()
            .collect();
        out.sort_by(|a, b| b.epoch.cmp(&a.epoch));
        out
    }

    /// Durably retire every committed record: both slots are
    /// zero-overwritten (at their stored capacity) and flushed.  A
    /// fresh run calls this *before* re-initializing weights under the
    /// same keys — otherwise a stale record over freshly-written
    /// extent-0 data could validate by length alone and resume into
    /// silently divergent state.
    pub fn invalidate(&self) -> anyhow::Result<()> {
        for slot in [SLOT_A, SLOT_B] {
            if let Some(cap) = self.engine.len_of(slot) {
                self.engine.write(slot, &vec![0u8; cap])?;
                self.engine.flush(slot)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::{DirectEngine, FaultyEngine, OpMask, RetryEngine, RetryPolicy};

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ma-jrnl-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn state(epoch: u64, steps: u64) -> CkptState {
        CkptState {
            epoch,
            steps_done: steps,
            applied_steps: steps.saturating_sub(1),
            seed: 0xDEAD_BEEF_CAFE_F00D, // deliberately > 2^53
            model: "smoke".into(),
            dtype: "f32".into(),
            corpus_rng: [u64::MAX, 1, 0x8000_0000_0000_0000, 42],
            scale: 65536.0,
            good_steps: 17,
            overflows: 2,
            growths: 1,
            tile_bytes: 4 << 20,
            tile_depth: 2,
            prefetch_depth: 2,
            sched_lead_us: 1_500,
            act_host_budget: usize::MAX - 1, // deliberately > 2^53
            keys: vec![("w0/master".into(), 4096, 0), ("w0/fp16".into(), 2048, 1)],
            layout_digest: Some(0xFFFF_FFFF_FFFF_FFFE),
            profile_digest: Some(0x0123_4567_89AB_CDEF),
        }
    }

    #[test]
    fn state_json_roundtrip_preserves_full_u64_range() {
        let s = state(3, 120);
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        let back = CkptState::from_json(&j).unwrap();
        assert_eq!(back, s, "hex round-trip must be exact past 2^53");
        // uncoalesced / unprofiled: digests absent
        let s2 = CkptState { layout_digest: None, profile_digest: None, ..s };
        let j2 = Json::parse(&s2.to_json().to_string()).unwrap();
        let back2 = CkptState::from_json(&j2).unwrap();
        assert_eq!(back2.layout_digest, None);
        assert_eq!(back2.profile_digest, None);
    }

    #[test]
    fn commit_then_load_returns_newest_epoch() {
        let dir = tmp("roundtrip");
        let eng = std::sync::Arc::new(DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap());
        let j = Journal::new(eng);
        assert!(j.load().is_none(), "fresh storage has no journal");
        j.commit(&state(1, 10)).unwrap();
        assert_eq!(j.load().unwrap().epoch, 1);
        j.commit(&state(2, 20)).unwrap();
        let loaded = j.load().unwrap();
        assert_eq!(loaded.epoch, 2);
        assert_eq!(loaded, state(2, 20));
        // both slots now populated; epoch 3 overwrites the older one
        j.commit(&state(3, 30)).unwrap();
        assert_eq!(j.load().unwrap().steps_done, 30);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_commit_rolls_back_to_previous_epoch() {
        let dir = tmp("torn");
        let eng = std::sync::Arc::new(DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap());
        let j = Journal::new(eng.clone());
        j.commit(&state(1, 10)).unwrap();
        j.commit(&state(2, 20)).unwrap();
        // epoch 3 would land in slot B (odd): simulate the torn write
        // by replacing the slot with garbage of the same stored length
        let slot = Journal::slot_key(3);
        let cap = eng.len_of(slot).unwrap();
        eng.write(slot, &vec![0xA5u8; cap]).unwrap();
        let loaded = j.load().unwrap();
        assert_eq!(loaded.epoch, 2, "torn slot must not win");
        assert_eq!(loaded, state(2, 20));
        // a later successful commit of epoch 3 recovers the slot
        j.commit(&state(3, 30)).unwrap();
        assert_eq!(j.load().unwrap().epoch, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_payload_fails_checksum() {
        let dir = tmp("sum");
        let eng = std::sync::Arc::new(DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap());
        let j = Journal::new(eng.clone());
        j.commit(&state(1, 10)).unwrap();
        let slot = Journal::slot_key(1);
        let cap = eng.len_of(slot).unwrap();
        let mut buf = vec![0u8; cap];
        eng.read(slot, &mut buf).unwrap();
        buf[HEADER + 5] ^= 0x40; // one bit inside the payload
        eng.write(slot, &buf).unwrap();
        assert!(j.load().is_none(), "checksum must reject the flipped bit");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_commit_leaves_journal_unchanged() {
        let dir = tmp("fail");
        let inner = std::sync::Arc::new(DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap());
        let j_ok = Journal::new(inner.clone());
        j_ok.commit(&state(1, 10)).unwrap();
        // persistent write faults: the slot write itself dies, even
        // through a retry layer
        let faulty = std::sync::Arc::new(FaultyEngine::transient(
            inner.clone(),
            u32::MAX,
            OpMask::DATA,
        ));
        let retrying =
            std::sync::Arc::new(RetryEngine::new(faulty, RetryPolicy::attempts(2)));
        let j_bad = Journal::new(retrying);
        let err = j_bad.commit(&state(2, 20)).unwrap_err();
        assert!(err.to_string().contains("injected"), "unexpected error: {err}");
        // no partial commit: the journal still reads epoch 1, intact
        assert_eq!(j_ok.load().unwrap(), state(1, 10));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn invalidate_retires_both_slots() {
        let dir = tmp("inval");
        let eng = std::sync::Arc::new(DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap());
        let j = Journal::new(eng.clone());
        j.invalidate().unwrap(); // no slots yet: a no-op
        j.commit(&state(1, 10)).unwrap();
        j.commit(&state(2, 20)).unwrap();
        assert_eq!(j.load_all().len(), 2);
        j.invalidate().unwrap();
        assert!(j.load().is_none(), "invalidated journal must read empty");
        // slot capacity survives, so re-committing after a fresh init
        // reuses the extents
        j.commit(&state(1, 5)).unwrap();
        assert_eq!(j.load().unwrap().steps_done, 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_all_returns_epochs_newest_first() {
        let dir = tmp("all");
        let eng = std::sync::Arc::new(DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap());
        let j = Journal::new(eng.clone());
        j.commit(&state(1, 10)).unwrap();
        j.commit(&state(2, 20)).unwrap();
        let all = j.load_all();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].epoch, 2);
        assert_eq!(all[1].epoch, 1);
        // torn newest slot: load_all degrades to the single survivor
        let slot = Journal::slot_key(2);
        let cap = eng.len_of(slot).unwrap();
        eng.write(slot, &vec![0x5Au8; cap]).unwrap();
        let all = j.load_all();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].epoch, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn key_validation_resolves_extents_and_names_the_divergence() {
        let dir = tmp("keys");
        let eng = DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap();
        eng.write("w0/master", &vec![0u8; 4096]).unwrap();
        let mut s = state(1, 10);
        s.keys = vec![("w0/master".into(), 4096, 0)];
        s.validate_keys(&eng).unwrap();
        s.keys[0].1 = 4097;
        let err = s.validate_keys(&eng).unwrap_err();
        assert!(err.to_string().contains("4097"), "unexpected error: {err}");
        s.keys = vec![("w1/master".into(), 8, 0)];
        let err = s.validate_keys(&eng).unwrap_err();
        assert!(err.to_string().contains("missing"), "unexpected error: {err}");
        // extent-1 keys validate against the shadow extent, not the
        // bare key
        s.keys = vec![("w0/master".into(), 4096, 1)];
        let err = s.validate_keys(&eng).unwrap_err();
        assert!(err.to_string().contains("@s1"), "unexpected error: {err}");
        eng.write("w0/master@s1", &vec![0u8; 4096]).unwrap();
        s.validate_keys(&eng).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prop_corrupted_records_never_decode_and_never_panic() {
        use crate::prop_assert;
        use crate::util::proptest::{check, Config};
        check("journal-fuzz", Config { cases: 48, ..Default::default() }, |rng, _| {
            let dir = tmp(&format!("fuzz-{}", rng.next_u64()));
            let eng =
                std::sync::Arc::new(DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap());
            let j = Journal::new(eng.clone());
            let s1 = state(1, 10);
            let s2 = state(2, 20);
            j.commit(&s1).unwrap();
            j.commit(&s2).unwrap();
            // corrupt one slot: random byte flips, or a zero tail (the
            // fixed-length analog of a truncated record)
            let victim = if rng.next_u64() % 2 == 0 { SLOT_A } else { SLOT_B };
            let cap = eng.len_of(victim).unwrap();
            let mut buf = vec![0u8; cap];
            eng.read(victim, &mut buf).unwrap();
            if rng.next_u64() % 3 == 0 {
                let keep = rng.range(0, cap);
                for b in &mut buf[keep..] {
                    *b = 0;
                }
            } else {
                for _ in 0..rng.range(1, 64) {
                    let i = rng.range(0, cap - 1);
                    buf[i] ^= (rng.next_u64() % 255 + 1) as u8;
                }
            }
            eng.write(victim, &buf).unwrap();
            // must never panic; anything returned must be one of the
            // exact committed records (mutations confined to the zero
            // padding legitimately leave a record valid)
            for got in j.load_all() {
                prop_assert!(
                    got == s1 || got == s2,
                    "decoded a record that was never committed: epoch {}",
                    got.epoch
                );
            }
            std::fs::remove_dir_all(&dir).ok();
            Ok(())
        });
    }
}
