//! Checkpoint & resume: shadow-paged, crash-consistent epochs over
//! the live SSD key set.
//!
//! MemAscend's training state already lives on the SSD — fp32 masters,
//! Adam moments, fp16 compute weights, the coalesced layout — kept
//! current by the tiled/coalesced write-back every step.  A checkpoint
//! therefore does not *copy* anything: it is a **barrier plus a
//! journal record over shadow-paged extents**.  Every checkpointed key
//! resolves through [`shadow::ShadowEngine`] to one of two physical
//! extents; the window after a commit writes the extent the committed
//! epoch does *not* own.  The trainer's commit path is
//!
//! 1. drain and [`crate::ssd::NvmeEngine::flush`] every state/fp16
//!    key (the flush routes to the freshly-written shadow extent),
//! 2. persist the host-resident remainder — norm tensors
//!    ([`write_resident`], checksummed) — under `ckpt/resident/*`,
//!    also shadow-paged,
//! 3. atomically commit a [`journal::CkptState`] record naming the
//!    step, every `(key, len, extent)` triple, the data-loader RNG
//!    cursor, the loss scaler, and the layout digest, via the
//!    dual-slot [`journal::Journal`],
//! 4. flip the in-memory extent map ([`shadow::ShadowEngine::flip`])
//!    so the next window targets the now-reusable older extents.
//!
//! **What an epoch owns:** the extents its journal record names — a
//! closed, immutable set; nothing the next window does touches them.
//! **When extents are reusable:** an extent not named by either
//! slot's record is dead and becomes the next window's shadow at the
//! flip.  **Why dirty-marker refusal is gone:** post-commit writes
//! can no longer destroy a committed epoch, so a crash at *any*
//! instant — mid-step, mid-commit flush, after the slot write but
//! before the flip, between epochs — leaves at least one journal slot
//! whose extents are bit-intact.  [`crate::train::Trainer::resume`]
//! walks the valid records newest-first, validates each candidate's
//! extents and resident checksums, and recovers the first that holds
//! up; only config mismatch (seed/model/dtype/layout) still refuses.

pub mod journal;
pub mod shadow;

pub use journal::{fnv1a64, CkptState, Journal};
pub use shadow::{phys_key, ShadowEngine, SHADOW_SUFFIX};

use crate::ssd::NvmeEngine;

/// Structured failure reading a resident-tensor blob back at resume.
/// Carries the key so the trainer's walk-back loop can report which
/// tensor sent it to the previous epoch.
#[derive(Debug)]
pub struct ResidentError {
    pub key: String,
    pub kind: ResidentErrorKind,
}

#[derive(Debug)]
pub enum ResidentErrorKind {
    /// No blob stored under the key at all.
    Missing,
    /// Blob present but not the expected byte count (foreign storage
    /// or a different model spec).
    Length { stored: usize, expected: usize },
    /// Payload bytes fail the stored FNV-1a checksum: bit-rot or a
    /// short/torn write.
    Checksum { stored: u64, computed: u64 },
}

impl std::fmt::Display for ResidentError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.kind {
            ResidentErrorKind::Missing => {
                write!(f, "checkpoint has no resident tensor '{}'", self.key)
            }
            ResidentErrorKind::Length { stored, expected } => write!(
                f,
                "resident tensor '{}': stored {stored} bytes, expected {expected}",
                self.key
            ),
            ResidentErrorKind::Checksum { stored, computed } => write!(
                f,
                "resident tensor '{}': checksum mismatch (stored {stored:016x}, \
                 computed {computed:016x})",
                self.key
            ),
        }
    }
}

impl std::error::Error for ResidentError {}

/// Engine key a host-resident tensor checkpoints under.
pub fn resident_key(name: &str) -> String {
    format!("ckpt/resident/{name}")
}

/// Persist one resident (host-only) tensor's full optimizer state —
/// parameters, Adam m, Adam v — as one little-endian f32 blob behind
/// an 8-byte FNV-1a payload checksum, flushed through the engine's
/// durability barrier.  Resident tensors are the only training state
/// not already on the SSD, so this is the only byte-moving part of a
/// checkpoint; the checksum turns bit-rot or a short read into a
/// structured [`ResidentError`] at resume instead of silent
/// divergence.
pub fn write_resident(
    engine: &dyn NvmeEngine,
    name: &str,
    data: &[f32],
    m: &[f32],
    v: &[f32],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        data.len() == m.len() && data.len() == v.len(),
        "resident tensor '{name}': data/m/v length mismatch"
    );
    let mut payload = Vec::with_capacity(data.len() * 12);
    for part in [data, m, v] {
        for &x in part {
            payload.extend_from_slice(&x.to_le_bytes());
        }
    }
    let mut buf = Vec::with_capacity(8 + payload.len());
    buf.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    buf.extend_from_slice(&payload);
    let key = resident_key(name);
    engine.write(&key, &buf)?;
    engine.flush(&key)
}

/// Read back and verify a [`write_resident`] blob: `(data, m, v)`,
/// each `numel` f32s.  Absence, length divergence, and checksum
/// failure all surface as a typed [`ResidentError`] (downcastable
/// from the `anyhow::Error`) so the resume walk-back can fall to the
/// prior epoch — never a partial or silently-corrupt read.
pub fn read_resident(
    engine: &dyn NvmeEngine,
    name: &str,
    numel: usize,
) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    let key = resident_key(name);
    let fail = |kind| -> anyhow::Error {
        ResidentError { key: resident_key(name), kind }.into()
    };
    let want = 8 + numel * 12;
    let stored = engine
        .len_of(&key)
        .ok_or_else(|| fail(ResidentErrorKind::Missing))?;
    if stored != want {
        return Err(fail(ResidentErrorKind::Length { stored, expected: want }));
    }
    let mut buf = vec![0u8; want];
    engine.read(&key, &mut buf)?;
    let sum = u64::from_le_bytes(buf[..8].try_into().unwrap());
    let payload = &buf[8..];
    let computed = fnv1a64(payload);
    if computed != sum {
        return Err(fail(ResidentErrorKind::Checksum { stored: sum, computed }));
    }
    let decode = |chunk: &[u8]| -> Vec<f32> {
        chunk
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    };
    Ok((
        decode(&payload[..numel * 4]),
        decode(&payload[numel * 4..numel * 8]),
        decode(&payload[numel * 8..]),
    ))
}

/// FNV-1a digest of a stored key's bytes (`None` if absent) — how the
/// journal fingerprints the coalesce-layout blob so resume can detect
/// a re-laid storage root.
pub fn stored_digest(engine: &dyn NvmeEngine, key: &str) -> anyhow::Result<Option<u64>> {
    let Some(len) = engine.len_of(key) else {
        return Ok(None);
    };
    let mut buf = vec![0u8; len];
    engine.read(key, &mut buf)?;
    Ok(Some(fnv1a64(&buf)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::DirectEngine;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ma-ckpt-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn resident_blob_round_trips_bit_exactly() {
        let dir = tmp("resident");
        let eng = DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap();
        let data: Vec<f32> = (0..300).map(|i| (i as f32).sin()).collect();
        let m: Vec<f32> = (0..300).map(|i| i as f32 * 1e-4).collect();
        let v: Vec<f32> = (0..300).map(|i| i as f32 * -2e-6).collect();
        write_resident(&eng, "final_norm", &data, &m, &v).unwrap();
        let (d2, m2, v2) = read_resident(&eng, "final_norm", 300).unwrap();
        assert_eq!(d2, data);
        assert_eq!(m2, m);
        assert_eq!(v2, v);
        // overwrite at the same length is the per-epoch update path
        write_resident(&eng, "final_norm", &m, &v, &data).unwrap();
        let (d3, _, v3) = read_resident(&eng, "final_norm", 300).unwrap();
        assert_eq!(d3, m);
        assert_eq!(v3, data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resident_read_validates_presence_and_length() {
        let dir = tmp("resident-err");
        let eng = DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap();
        let err = read_resident(&eng, "absent", 8).unwrap_err();
        assert!(err.to_string().contains("no resident tensor"));
        assert!(matches!(
            err.downcast_ref::<ResidentError>(),
            Some(ResidentError { kind: ResidentErrorKind::Missing, .. })
        ));
        write_resident(&eng, "t", &[1.0; 8], &[0.0; 8], &[0.0; 8]).unwrap();
        // 8-byte checksum header + 9 * 12 payload bytes
        let err = read_resident(&eng, "t", 9).unwrap_err();
        assert!(err.to_string().contains("expected 116"), "got: {err}");
        assert!(matches!(
            err.downcast_ref::<ResidentError>(),
            Some(ResidentError { kind: ResidentErrorKind::Length { .. }, .. })
        ));
        let err = write_resident(&eng, "t", &[1.0; 8], &[0.0; 7], &[0.0; 8]).unwrap_err();
        assert!(err.to_string().contains("length mismatch"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resident_read_detects_bit_rot() {
        let dir = tmp("resident-rot");
        let eng = DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap();
        write_resident(&eng, "t", &[1.0; 16], &[2.0; 16], &[3.0; 16]).unwrap();
        let key = resident_key("t");
        let len = eng.len_of(&key).unwrap();
        let mut buf = vec![0u8; len];
        eng.read(&key, &mut buf).unwrap();
        buf[8 + 21] ^= 0x04; // one payload bit
        eng.write(&key, &buf).unwrap();
        let err = read_resident(&eng, "t", 16).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "got: {err}");
        let rot = err.downcast_ref::<ResidentError>().unwrap();
        assert!(matches!(rot.kind, ResidentErrorKind::Checksum { .. }));
        assert_eq!(rot.key, key);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stored_digest_fingerprints_content() {
        let dir = tmp("digest");
        let eng = DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap();
        assert_eq!(stored_digest(&eng, "absent").unwrap(), None);
        eng.write("blob", b"layout-v1").unwrap();
        let d1 = stored_digest(&eng, "blob").unwrap().unwrap();
        assert_eq!(d1, fnv1a64(b"layout-v1"));
        eng.write("blob", b"layout-v2").unwrap();
        assert_ne!(stored_digest(&eng, "blob").unwrap().unwrap(), d1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
