//! Checkpoint & resume: crash-consistent epochs over the live SSD
//! key set.
//!
//! MemAscend's training state already lives on the SSD — fp32 masters,
//! Adam moments, fp16 compute weights, the coalesced layout — kept
//! current by the tiled/coalesced write-back every step.  A checkpoint
//! therefore does not *copy* anything: it is a **barrier plus a
//! journal record**.  The trainer
//!
//! 1. drains and [`crate::ssd::NvmeEngine::flush`]es every state/fp16
//!    key (the per-key durability barriers of the ssd layer),
//! 2. persists the host-resident remainder — norm tensors
//!    ([`write_resident`]) — under `ckpt/resident/*` keys,
//! 3. atomically commits a [`journal::CkptState`] record naming the
//!    step, every key + length, the data-loader RNG cursor, the loss
//!    scaler, and the layout digest, via the dual-slot
//!    [`journal::Journal`].
//!
//! [`crate::train::Trainer::resume`] replays the newest valid epoch:
//! it validates the journal against the storage inventory (key
//! lengths, layout digest, seed, dtype, model), rebuilds the optimizer
//! handles from metadata alone — no DRAM re-staging of state, the
//! tensors stay on the SSD — reads back the small resident tensors,
//! restores the RNG/scaler/step cursors, and continues bit-identically
//! with the run the checkpoint interrupted.
//!
//! Because commits are in place, a committed epoch stays recoverable
//! only until the next optimizer write-back dirties the keys; the
//! journal's dirty marker turns a mid-epoch crash into a structured
//! "cannot resume" error rather than silent divergence, and a torn
//! commit simply loses the newest epoch (the dual-slot load falls back
//! to the previous one).

pub mod journal;

pub use journal::{fnv1a64, CkptState, Journal};

use crate::ssd::NvmeEngine;

/// Engine key a host-resident tensor checkpoints under.
pub fn resident_key(name: &str) -> String {
    format!("ckpt/resident/{name}")
}

/// Persist one resident (host-only) tensor's full optimizer state —
/// parameters, Adam m, Adam v — as one little-endian f32 blob, flushed
/// through the engine's durability barrier.  Resident tensors are the
/// only training state not already on the SSD, so this is the only
/// byte-moving part of a checkpoint.
pub fn write_resident(
    engine: &dyn NvmeEngine,
    name: &str,
    data: &[f32],
    m: &[f32],
    v: &[f32],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        data.len() == m.len() && data.len() == v.len(),
        "resident tensor '{name}': data/m/v length mismatch"
    );
    let mut buf = Vec::with_capacity(data.len() * 12);
    for part in [data, m, v] {
        for &x in part {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    let key = resident_key(name);
    engine.write(&key, &buf)?;
    engine.flush(&key)
}

/// Read back a [`write_resident`] blob: `(data, m, v)`, each `numel`
/// f32s.  Length divergence is a structured error (foreign storage or
/// a different model spec), never a partial read.
pub fn read_resident(
    engine: &dyn NvmeEngine,
    name: &str,
    numel: usize,
) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    let key = resident_key(name);
    let want = numel * 12;
    let stored = engine
        .len_of(&key)
        .ok_or_else(|| anyhow::anyhow!("checkpoint has no resident tensor '{key}'"))?;
    anyhow::ensure!(
        stored == want,
        "resident tensor '{key}': stored {stored} bytes, expected {want}"
    );
    let mut buf = vec![0u8; want];
    engine.read(&key, &mut buf)?;
    let decode = |chunk: &[u8]| -> Vec<f32> {
        chunk
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    };
    Ok((
        decode(&buf[..numel * 4]),
        decode(&buf[numel * 4..numel * 8]),
        decode(&buf[numel * 8..]),
    ))
}

/// FNV-1a digest of a stored key's bytes (`None` if absent) — how the
/// journal fingerprints the coalesce-layout blob so resume can detect
/// a re-laid storage root.
pub fn stored_digest(engine: &dyn NvmeEngine, key: &str) -> anyhow::Result<Option<u64>> {
    let Some(len) = engine.len_of(key) else {
        return Ok(None);
    };
    let mut buf = vec![0u8; len];
    engine.read(key, &mut buf)?;
    Ok(Some(fnv1a64(&buf)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::DirectEngine;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ma-ckpt-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn resident_blob_round_trips_bit_exactly() {
        let dir = tmp("resident");
        let eng = DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap();
        let data: Vec<f32> = (0..300).map(|i| (i as f32).sin()).collect();
        let m: Vec<f32> = (0..300).map(|i| i as f32 * 1e-4).collect();
        let v: Vec<f32> = (0..300).map(|i| i as f32 * -2e-6).collect();
        write_resident(&eng, "final_norm", &data, &m, &v).unwrap();
        let (d2, m2, v2) = read_resident(&eng, "final_norm", 300).unwrap();
        assert_eq!(d2, data);
        assert_eq!(m2, m);
        assert_eq!(v2, v);
        // overwrite at the same length is the per-epoch update path
        write_resident(&eng, "final_norm", &m, &v, &data).unwrap();
        let (d3, _, v3) = read_resident(&eng, "final_norm", 300).unwrap();
        assert_eq!(d3, m);
        assert_eq!(v3, data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resident_read_validates_presence_and_length() {
        let dir = tmp("resident-err");
        let eng = DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap();
        let err = read_resident(&eng, "absent", 8).unwrap_err();
        assert!(err.to_string().contains("no resident tensor"));
        write_resident(&eng, "t", &[1.0; 8], &[0.0; 8], &[0.0; 8]).unwrap();
        let err = read_resident(&eng, "t", 9).unwrap_err();
        assert!(err.to_string().contains("expected 108"), "got: {err}");
        let err = write_resident(&eng, "t", &[1.0; 8], &[0.0; 7], &[0.0; 8]).unwrap_err();
        assert!(err.to_string().contains("length mismatch"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stored_digest_fingerprints_content() {
        let dir = tmp("digest");
        let eng = DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap();
        assert_eq!(stored_digest(&eng, "absent").unwrap(), None);
        eng.write("blob", b"layout-v1").unwrap();
        let d1 = stored_digest(&eng, "blob").unwrap().unwrap();
        assert_eq!(d1, fnv1a64(b"layout-v1"));
        eng.write("blob", b"layout-v2").unwrap();
        assert_ne!(stored_digest(&eng, "blob").unwrap().unwrap(), d1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
