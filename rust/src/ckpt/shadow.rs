//! Shadow-paged extents: the epoch-mapped key layer that makes every
//! journaled epoch restorable.
//!
//! Each *registered* logical key (the optimizer streams — fp32
//! masters, Adam moments, fp16 compute weights, packed super-group
//! streams, resident blobs) resolves to one of two physical extents:
//!
//! * extent 0 — the bare key (`optim/sg0/master`),
//! * extent 1 — the key with [`SHADOW_SUFFIX`] (`optim/sg0/master@s1`).
//!
//! The committed epoch owns one extent per key (the journal record
//! carries the per-key map); the *other* extent is the shadow the next
//! epoch's write-backs land in.  Commit therefore never overwrites
//! committed bytes — it flushes the shadow extents, writes the journal
//! slot, and **flips** the in-memory map.  A crash at any instant
//! leaves the newest durable journal record pointing at extents the
//! interrupted window never touched, so resume is recovery, not
//! refusal (the old dirty-marker contract is gone).
//!
//! Routing rules (see [`ShadowEngine`]'s `NvmeEngine` impl):
//!
//! * reads go to the key's **read extent**;
//! * writes (`write`/`write_at`) go to the **write extent** and mark
//!   the key dirty; an absent write extent is materialized (reserved
//!   at the read extent's length) on first ranged write;
//! * `reserve` targets the write extent without dirtying;
//! * `flush` targets the **newest** extent (write if dirty, else
//!   read) — the one a subsequent commit will name;
//! * unregistered keys (journal slots, layout/profile blobs, member
//!   state streams kept out of the checkpoint set) pass through
//!   untouched.
//!
//! Within a window the first applied step reads epoch N's extent and
//! writes the shadow; [`ShadowEngine::advance`] then folds the read
//! side onto the shadow (dirty keys only), so later steps of the same
//! window run in place *on the shadow* while the committed extent
//! stays bit-intact until the flip.  Skipped (overflow) steps dirty
//! nothing, so `advance` is a no-op for them by construction.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use crate::ssd::{IoSnapshot, NvmeEngine};

/// Suffix naming a key's second physical extent.
pub const SHADOW_SUFFIX: &str = "@s1";

/// Physical engine key of logical `key`'s extent `ext` (0 or 1).
pub fn phys_key(key: &str, ext: u8) -> String {
    if ext == 0 {
        key.to_string()
    } else {
        format!("{key}{SHADOW_SUFFIX}")
    }
}

#[derive(Clone, Copy, Debug)]
struct KeyState {
    /// Extent reads resolve to (the committed / advanced side).
    read: u8,
    /// Extent writes resolve to.
    write: u8,
    /// Whether the write extent holds bytes newer than `read`'s.
    dirty: bool,
}

impl KeyState {
    fn newest(&self) -> u8 {
        if self.dirty {
            self.write
        } else {
            self.read
        }
    }
}

/// Engine decorator implementing the per-key extent map.  Sits
/// directly above the retry/storage stack and below the async queue,
/// so the swapper, prefetcher, and tiled optimizer all read *logical*
/// keys and never see a flip.
pub struct ShadowEngine {
    inner: Arc<dyn NvmeEngine>,
    map: RwLock<HashMap<String, KeyState>>,
    /// Serializes write-extent materialization (concurrent tile writes
    /// to one freshly-flipped key must reserve its extent exactly
    /// once).
    materialize: Mutex<()>,
}

impl ShadowEngine {
    pub fn new(inner: Arc<dyn NvmeEngine>) -> Self {
        Self { inner, map: RwLock::new(HashMap::new()), materialize: Mutex::new(()) }
    }

    /// The wrapped engine (journal slots and layout blobs are reached
    /// through the shadow layer too — they pass through unregistered).
    pub fn inner(&self) -> &Arc<dyn NvmeEngine> {
        &self.inner
    }

    /// Register `keys` for shadow paging on a fresh run: both sides
    /// point at extent 0, so the first window is pass-through
    /// equivalent and the first commit maps every key to extent 0.
    /// Already-registered keys are left untouched.
    pub fn register<I, S>(&self, keys: I)
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut map = self.map.write().unwrap();
        for k in keys {
            map.entry(k.into())
                .or_insert(KeyState { read: 0, write: 0, dirty: false });
        }
    }

    /// Install the committed per-key map a journal record carries:
    /// reads resolve to the committed extent, writes to the other one.
    /// Replaces any prior registration (resume walks epochs; each
    /// candidate re-installs).
    pub fn install<I, S>(&self, committed: I)
    where
        I: IntoIterator<Item = (S, u8)>,
        S: Into<String>,
    {
        let mut map = self.map.write().unwrap();
        for (k, ext) in committed {
            let ext = ext & 1;
            map.insert(k.into(), KeyState { read: ext, write: 1 - ext, dirty: false });
        }
    }

    pub fn is_registered(&self, key: &str) -> bool {
        self.map.read().unwrap().contains_key(key)
    }

    /// Extent a commit of the current state would record for `key`
    /// (0 for unregistered keys, which live outside the map).
    pub fn newest_ext(&self, key: &str) -> u8 {
        self.map.read().unwrap().get(key).map_or(0, |s| s.newest())
    }

    /// Fold the read side of every dirty key onto its freshly-written
    /// extent.  Called after each *applied* optimizer step: the next
    /// step of the same window then reads what this one wrote, while
    /// the committed extent stays untouched.  Keys nothing wrote
    /// (skipped steps, resident blobs between commits) keep reading
    /// the committed side.  Callers must have drained in-flight I/O.
    pub fn advance(&self) {
        let mut map = self.map.write().unwrap();
        for st in map.values_mut() {
            if st.dirty {
                st.read = st.write;
                st.dirty = false;
            }
        }
    }

    /// Commit-time flip: every key's read side moves to its newest
    /// extent and the *other* extent becomes the next window's shadow.
    /// Pure in-memory state — the journal record written just before
    /// is the durable authority, so a crash between slot write and
    /// flip loses nothing.
    pub fn flip(&self) {
        let mut map = self.map.write().unwrap();
        for st in map.values_mut() {
            let n = st.newest();
            st.read = n;
            st.write = 1 - n;
            st.dirty = false;
        }
    }

    /// Bytes currently duplicated across extent pairs: for every
    /// registered key whose shadow extent (`@s1`) has been
    /// materialized alongside extent 0, both copies are live on the
    /// SSD.  This is the space cost of shadow paging —
    /// `bench_recovery` reports its peak.
    pub fn shadow_overhead_bytes(&self) -> u64 {
        let map = self.map.read().unwrap();
        let mut total = 0u64;
        for key in map.keys() {
            if self.inner.len_of(&phys_key(key, 0)).is_some() {
                if let Some(l) = self.inner.len_of(&phys_key(key, 1)) {
                    total += l as u64;
                }
            }
        }
        total
    }

    /// Resolve `key` for a read-side op.
    fn read_key(&self, key: &str) -> String {
        let ext = self.map.read().unwrap().get(key).map_or(0, |s| s.read);
        phys_key(key, ext)
    }

    /// Resolve `key` for a write-side op, marking it dirty when
    /// `dirties` and the key is registered.
    fn write_key(&self, key: &str, dirties: bool) -> String {
        let mut map = self.map.write().unwrap();
        match map.get_mut(key) {
            Some(st) => {
                if dirties {
                    st.dirty = true;
                }
                phys_key(key, st.write)
            }
            None => key.to_string(),
        }
    }

    /// Ensure the physical write extent exists before a ranged write
    /// lands in it: a freshly-flipped shadow extent has no storage
    /// yet, so reserve it at the peer extent's length.
    fn ensure_extent(&self, key: &str, phys: &str) -> anyhow::Result<()> {
        if self.inner.len_of(phys).is_none() {
            let _guard = self.materialize.lock().unwrap();
            if self.inner.len_of(phys).is_none() {
                let peer = {
                    let map = self.map.read().unwrap();
                    let st = map.get(key).copied();
                    st.map(|s| phys_key(key, 1 - s.write))
                };
                if let Some(peer) = peer {
                    if let Some(len) = self.inner.len_of(&peer) {
                        self.inner.reserve(phys, len)?;
                    }
                }
            }
        }
        Ok(())
    }
}

impl NvmeEngine for ShadowEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        let phys = self.write_key(key, true);
        self.inner.write(&phys, data)
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        self.inner.read(&self.read_key(key), out)
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        self.inner.read_at(&self.read_key(key), offset, out)
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        let phys = self.write_key(key, true);
        self.ensure_extent(key, &phys)?;
        self.inner.write_at(&phys, offset, data)
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        let ext = self.map.read().unwrap().get(key).map_or(0, |s| s.newest());
        self.inner.flush(&phys_key(key, ext))
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        let phys = self.write_key(key, false);
        self.inner.reserve(&phys, len)
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        match self.map.read().unwrap().get(key) {
            Some(st) => self
                .inner
                .len_of(&phys_key(key, st.newest()))
                .or_else(|| self.inner.len_of(&phys_key(key, 1 - st.newest()))),
            None => self.inner.len_of(key),
        }
    }

    fn stats(&self) -> IoSnapshot {
        self.inner.stats()
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::DirectEngine;

    fn direct(tag: &str) -> (Arc<dyn NvmeEngine>, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("ma-shadow-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        (Arc::new(DirectEngine::new(&dir, 2, 1 << 22, 1).unwrap()), dir)
    }

    fn read_all(eng: &dyn NvmeEngine, key: &str) -> Vec<u8> {
        let len = eng.len_of(key).unwrap();
        let mut buf = vec![0u8; len];
        eng.read(key, &mut buf).unwrap();
        buf
    }

    #[test]
    fn unregistered_keys_pass_through() {
        let (inner, dir) = direct("pass");
        let sh = ShadowEngine::new(inner.clone());
        sh.write("plain", &[7u8; 64]).unwrap();
        assert_eq!(inner.len_of("plain"), Some(64));
        assert_eq!(inner.len_of(&phys_key("plain", 1)), None);
        assert_eq!(read_all(&sh, "plain"), vec![7u8; 64]);
        assert_eq!(sh.newest_ext("plain"), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fresh_registration_is_extent_zero_until_flip() {
        let (inner, dir) = direct("fresh");
        let sh = ShadowEngine::new(inner.clone());
        sh.register(["k"]);
        sh.write("k", &[1u8; 32]).unwrap();
        // fresh run: both sides extent 0, write lands on the bare key
        assert_eq!(inner.len_of("k"), Some(32));
        assert_eq!(sh.newest_ext("k"), 0);
        sh.flip(); // commit epoch 1 at extent 0
        // next window's writes land in the shadow; reads still see
        // epoch 1 until advance
        sh.write("k", &[2u8; 32]).unwrap();
        assert_eq!(read_all(&sh, "k"), vec![1u8; 32]);
        assert_eq!(read_all(inner.as_ref(), &phys_key("k", 1)), vec![2u8; 32]);
        assert_eq!(sh.newest_ext("k"), 1);
        sh.advance();
        assert_eq!(read_all(&sh, "k"), vec![2u8; 32]);
        // epoch 1's extent is bit-intact the whole window
        assert_eq!(read_all(inner.as_ref(), "k"), vec![1u8; 32]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ranged_write_materializes_the_shadow_extent() {
        let (inner, dir) = direct("ranged");
        let sh = ShadowEngine::new(inner.clone());
        sh.register(["k"]);
        sh.write("k", &[9u8; 4096]).unwrap();
        sh.flip();
        // no reserve call: the first tile write must materialize @s1
        sh.write_at("k", 1024, &[5u8; 512]).unwrap();
        assert_eq!(inner.len_of(&phys_key("k", 1)), Some(4096));
        sh.advance();
        let buf = read_all(&sh, "k");
        assert_eq!(&buf[1024..1536], &[5u8; 512][..]);
        // unwritten shadow bytes read back as reserve zeros, the
        // committed extent still has the epoch-1 bytes
        assert_eq!(buf[0], 0);
        assert_eq!(read_all(inner.as_ref(), "k"), vec![9u8; 4096]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flip_alternates_extents_and_skipped_windows_hold_position() {
        let (inner, dir) = direct("alt");
        let sh = ShadowEngine::new(inner.clone());
        sh.register(["k"]);
        sh.write("k", &[1u8; 16]).unwrap();
        sh.flip(); // epoch 1 @ ext 0
        sh.write("k", &[2u8; 16]).unwrap();
        sh.advance();
        sh.flip(); // epoch 2 @ ext 1
        assert_eq!(sh.newest_ext("k"), 1);
        // a window with no writes (all steps skipped): commit maps the
        // same extent again
        sh.flip();
        assert_eq!(sh.newest_ext("k"), 1);
        sh.write("k", &[3u8; 16]).unwrap();
        sh.advance();
        sh.flip(); // epoch 3 back @ ext 0
        assert_eq!(sh.newest_ext("k"), 0);
        assert_eq!(read_all(inner.as_ref(), "k"), vec![3u8; 16]);
        assert_eq!(read_all(inner.as_ref(), &phys_key("k", 1)), vec![2u8; 16]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn install_routes_reads_to_committed_extent() {
        let (inner, dir) = direct("install");
        inner.write(&phys_key("k", 1), &[4u8; 8]).unwrap();
        inner.write("k", &[9u8; 8]).unwrap();
        let sh = ShadowEngine::new(inner.clone());
        sh.install([("k", 1u8)]);
        assert_eq!(read_all(&sh, "k"), vec![4u8; 8]);
        // next window overwrites the stale extent 0
        sh.write("k", &[6u8; 8]).unwrap();
        assert_eq!(read_all(inner.as_ref(), "k"), vec![6u8; 8]);
        assert_eq!(read_all(inner.as_ref(), &phys_key("k", 1)), vec![4u8; 8]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn overhead_counts_only_materialized_pairs() {
        let (inner, dir) = direct("cost");
        let sh = ShadowEngine::new(inner);
        sh.register(["a", "b"]);
        sh.write("a", &[1u8; 100]).unwrap();
        sh.write("b", &[1u8; 50]).unwrap();
        assert_eq!(sh.shadow_overhead_bytes(), 0, "no shadow extents yet");
        sh.flip();
        sh.write("a", &[2u8; 100]).unwrap();
        assert_eq!(sh.shadow_overhead_bytes(), 100, "only 'a' duplicated");
        sh.write("b", &[2u8; 50]).unwrap();
        assert_eq!(sh.shadow_overhead_bytes(), 150);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reserve_targets_write_extent_without_dirtying() {
        let (inner, dir) = direct("rsv");
        let sh = ShadowEngine::new(inner.clone());
        sh.register(["k"]);
        sh.write("k", &[1u8; 64]).unwrap();
        sh.flip();
        sh.reserve("k", 64).unwrap();
        // reserve alone must not move the commit map off epoch 1
        assert_eq!(sh.newest_ext("k"), 0);
        assert_eq!(inner.len_of(&phys_key("k", 1)), Some(64));
        sh.write_at("k", 0, &[2u8; 8]).unwrap();
        assert_eq!(sh.newest_ext("k"), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
