//! In-process collectives — the "network" of Fig. 1.
//!
//! ZeRO-3 data parallelism needs exactly two primitives: **allgather**
//! (assemble full parameters from per-rank partitions before compute)
//! and **reduce-scatter** (sum gradients, leave each rank its own
//! partition).  Ranks here are threads in one process, so the wire is a
//! memcpy through a rendezvous slot; the partitioning math is identical
//! to NCCL's.

use std::sync::{Arc, Condvar, Mutex};

struct Slot {
    deposits: Vec<Option<Vec<f32>>>,
    result: Option<Arc<Vec<f32>>>,
    arrived: usize,
    departed: usize,
    generation: u64,
}

/// A rendezvous-based collective group of `n` ranks.
pub struct Collective {
    n: usize,
    slot: Mutex<Slot>,
    cv: Condvar,
}

impl Collective {
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(Self {
            n,
            slot: Mutex::new(Slot {
                deposits: (0..n).map(|_| None).collect(),
                result: None,
                arrived: 0,
                departed: 0,
                generation: 0,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn ranks(&self) -> usize {
        self.n
    }

    /// Generic rendezvous: every rank deposits its vector; the last
    /// arrival computes `combine` over all deposits; everyone receives
    /// the shared result.
    fn rendezvous<F>(&self, rank: usize, data: Vec<f32>, combine: F) -> Arc<Vec<f32>>
    where
        F: FnOnce(Vec<Vec<f32>>) -> Vec<f32>,
    {
        let mut slot = self.slot.lock().unwrap();
        let my_gen = slot.generation;
        // wait for the previous round to fully drain
        while slot.departed > 0 && slot.departed < self.n {
            slot = self.cv.wait(slot).unwrap();
        }
        debug_assert!(slot.deposits[rank].is_none(), "rank {rank} double deposit");
        slot.deposits[rank] = Some(data);
        slot.arrived += 1;
        if slot.arrived == self.n {
            let deposits: Vec<Vec<f32>> =
                slot.deposits.iter_mut().map(|d| d.take().unwrap()).collect();
            slot.result = Some(Arc::new(combine(deposits)));
            slot.arrived = 0;
            slot.departed = 0;
            slot.generation += 1;
            self.cv.notify_all();
        } else {
            while slot.generation == my_gen {
                slot = self.cv.wait(slot).unwrap();
            }
        }
        let out = slot.result.as_ref().unwrap().clone();
        slot.departed += 1;
        if slot.departed == self.n {
            slot.result = None;
            slot.departed = 0;
            self.cv.notify_all();
        }
        out
    }

    /// Allgather: concatenate per-rank partitions in rank order.
    /// Partitions may have unequal length (last rank's remainder).
    pub fn allgather(&self, rank: usize, partition: Vec<f32>) -> Vec<f32> {
        if self.n == 1 {
            return partition;
        }
        self.rendezvous(rank, partition, |parts| {
            let mut out = Vec::with_capacity(parts.iter().map(Vec::len).sum());
            for p in parts {
                out.extend_from_slice(&p);
            }
            out
        })
        .to_vec()
    }

    /// Reduce-scatter with mean: sums full-length gradient vectors
    /// element-wise, divides by rank count, returns this rank's
    /// partition `[rank*chunk, min((rank+1)*chunk, n))`.
    pub fn reduce_scatter_mean(&self, rank: usize, full: Vec<f32>) -> Vec<f32> {
        let len = full.len();
        let chunk = len.div_ceil(self.n);
        if self.n == 1 {
            return full;
        }
        let n_ranks = self.n as f32;
        let summed = self.rendezvous(rank, full, move |parts| {
            let mut acc = parts[0].clone();
            for p in &parts[1..] {
                for (a, b) in acc.iter_mut().zip(p) {
                    *a += *b;
                }
            }
            for a in acc.iter_mut() {
                *a /= n_ranks;
            }
            acc
        });
        let lo = (rank * chunk).min(len);
        let hi = ((rank + 1) * chunk).min(len);
        summed[lo..hi].to_vec()
    }

    /// Barrier + scalar OR-reduce (used for the global overflow flag:
    /// any rank overflowing skips the step on all ranks).
    pub fn any_flag(&self, rank: usize, flag: bool) -> bool {
        if self.n == 1 {
            return flag;
        }
        let r = self.rendezvous(rank, vec![f32::from(u8::from(flag))], |parts| {
            vec![parts.iter().map(|p| p[0]).sum::<f32>()]
        });
        r[0] > 0.0
    }
}

/// Partition bounds for ZeRO-3: rank r owns [lo, hi) of a flat buffer.
pub fn partition_bounds(len: usize, ranks: usize, rank: usize) -> (usize, usize) {
    let chunk = len.div_ceil(ranks);
    ((rank * chunk).min(len), ((rank + 1) * chunk).min(len))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_bounds_cover() {
        for len in [0usize, 1, 10, 101] {
            for ranks in [1usize, 2, 3, 4] {
                let mut total = 0;
                for r in 0..ranks {
                    let (lo, hi) = partition_bounds(len, ranks, r);
                    assert!(lo <= hi);
                    total += hi - lo;
                }
                assert_eq!(total, len);
            }
        }
    }

    #[test]
    fn allgather_concatenates_in_rank_order() {
        let c = Collective::new(3);
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let hs: Vec<_> = (0..3)
                .map(|r| {
                    let c = c.clone();
                    s.spawn(move || c.allgather(r, vec![r as f32; 2]))
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for o in outs {
            assert_eq!(o, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn reduce_scatter_means_and_partitions() {
        let c = Collective::new(2);
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let hs: Vec<_> = (0..2)
                .map(|r| {
                    let c = c.clone();
                    s.spawn(move || {
                        let full = vec![(r + 1) as f32; 5]; // rank0: 1s, rank1: 2s
                        c.reduce_scatter_mean(r, full)
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // mean = 1.5 everywhere; chunk = 3 -> rank0 gets 3, rank1 gets 2
        assert_eq!(outs[0], vec![1.5; 3]);
        assert_eq!(outs[1], vec![1.5; 2]);
    }

    #[test]
    fn any_flag_ors_across_ranks() {
        let c = Collective::new(3);
        let outs: Vec<bool> = std::thread::scope(|s| {
            let hs: Vec<_> = (0..3)
                .map(|r| {
                    let c = c.clone();
                    s.spawn(move || c.any_flag(r, r == 1))
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(outs.iter().all(|&b| b));
    }

    #[test]
    fn repeated_rounds_do_not_deadlock() {
        let c = Collective::new(2);
        std::thread::scope(|s| {
            for r in 0..2 {
                let c = c.clone();
                s.spawn(move || {
                    for round in 0..50 {
                        let v = c.allgather(r, vec![round as f32]);
                        assert_eq!(v.len(), 2);
                    }
                });
            }
        });
    }
}
