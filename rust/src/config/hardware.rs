//! Hardware profiles (paper Table III + Table V) and derived bandwidths.
//!
//! The accounting + performance model scales component costs by these
//! parameters; the *local* profile describes this container and is what
//! real benches run under.

/// One machine configuration.
#[derive(Debug, Clone)]
pub struct HardwareSpec {
    pub name: &'static str,
    pub cpu: &'static str,
    /// DRAM capacity in GiB.
    pub dram_gib: f64,
    /// Peak DRAM bandwidth, GiB/s (from MT/s × channels × 8B).
    pub dram_gibs: f64,
    /// PCIe generation of the GPU/SSD links.
    pub pcie_gen: u8,
    pub gpus: usize,
    pub vram_gib: f64,
    /// Relative *achieved* GPU throughput in SSD-offloaded training
    /// (C1's H100 = 1.0). Offloaded steps are far from peak MFU, so
    /// slower cards lose less than their spec-sheet ratio suggests.
    pub gpu_rel_flops: f64,
    pub ssds: usize,
    /// Per-SSD sustained sequential read/write, GiB/s.
    pub ssd_read_gibs: f64,
    pub ssd_write_gibs: f64,
    /// Device-level 4KiB random access latency, microseconds.
    pub ssd_lat_us: f64,
    /// SLC/DRAM write-cache size per SSD, GiB (burst absorption).
    pub ssd_cache_gib: f64,
    /// Relative single-core CPU speed (Xeon 6780E core = 1.0) — scales
    /// overflow-check/optimizer latency in projections.
    pub cpu_rel: f64,
    pub cpu_threads: usize,
}

impl HardwareSpec {
    /// PCIe x16 practical bandwidth, GiB/s.
    pub fn pcie_gibs(&self) -> f64 {
        match self.pcie_gen {
            3 => 12.0,
            4 => 24.0,
            5 => 48.0,
            g => 6.0 * f64::from(g),
        }
    }

    /// Aggregate SSD bandwidths across the array.
    pub fn ssd_agg_read_gibs(&self) -> f64 {
        self.ssd_read_gibs * self.ssds as f64
    }

    pub fn ssd_agg_write_gibs(&self) -> f64 {
        self.ssd_write_gibs * self.ssds as f64
    }

    pub fn by_name(name: &str) -> anyhow::Result<&'static HardwareSpec> {
        ALL.iter().find(|h| h.name == name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown hardware profile '{name}' (available: {})",
                ALL.iter().map(|h| h.name).collect::<Vec<_>>().join(", ")
            )
        }).copied()
    }
}

/// Configuration 1 (Table III): Xeon 6780E, 1 TB DDR5-6400, PCIe5,
/// 2×H100 PCIe, 1× DapuStor H5100 7.5 TB.
pub static CONFIG1: HardwareSpec = HardwareSpec {
    name: "config1",
    cpu: "Intel Xeon 6780E",
    dram_gib: 1024.0,
    dram_gibs: 409.6, // 8ch × 6400 MT/s × 8 B
    pcie_gen: 5,
    gpus: 2,
    vram_gib: 80.0,
    gpu_rel_flops: 1.0,
    ssds: 1,
    ssd_read_gibs: 13.0,
    ssd_write_gibs: 9.0,
    ssd_lat_us: 60.0,
    ssd_cache_gib: 24.0,
    cpu_rel: 1.0,
    cpu_threads: 288,
};

/// Configuration 2 (Table III): 2× EPYC 7282, 1 TB DDR4-3200, PCIe4,
/// 1× A5000, 2× Phison AI100E.
pub static CONFIG2: HardwareSpec = HardwareSpec {
    name: "config2",
    cpu: "2x AMD EPYC 7282",
    dram_gib: 1024.0,
    dram_gibs: 204.8,
    pcie_gen: 4,
    gpus: 1,
    vram_gib: 24.0,
    gpu_rel_flops: 0.5, // A5000, offload-achieved (not the ~0.11 peak ratio)
    ssds: 2,
    ssd_read_gibs: 6.8,
    ssd_write_gibs: 5.2,
    ssd_lat_us: 80.0,
    ssd_cache_gib: 8.0,
    cpu_rel: 0.45, // Zen2 2.8 GHz, AVX2-only vs AVX512 — paper: overflow
    // check ~2.2x slower on C2 (Fig. 12)
    cpu_threads: 64,
};

/// Configuration 3 (Table V, MoE): Xeon 8480+, 1 TB DDR5-4800, PCIe5,
/// 2×H100 SXM5, 2× Samsung 980 Pro.
pub static CONFIG3: HardwareSpec = HardwareSpec {
    name: "config3",
    cpu: "Intel Xeon 8480+",
    dram_gib: 1024.0,
    dram_gibs: 307.2,
    pcie_gen: 5,
    gpus: 2,
    vram_gib: 80.0,
    gpu_rel_flops: 1.1, // SXM5 w/ NVL
    ssds: 2,
    ssd_read_gibs: 6.5,
    ssd_write_gibs: 4.6,
    ssd_lat_us: 70.0,
    ssd_cache_gib: 6.0,
    cpu_rel: 0.9,
    cpu_threads: 112,
};

/// The motivational-experiment machine (§III-E, Table II):
/// 24 GiB GPU, 128 GiB system memory cap.
pub static COMMODITY128: HardwareSpec = HardwareSpec {
    name: "commodity128",
    cpu: "commodity",
    dram_gib: 128.0,
    dram_gibs: 76.8,
    pcie_gen: 4,
    gpus: 1,
    vram_gib: 24.0,
    gpu_rel_flops: 0.4,
    ssds: 1,
    ssd_read_gibs: 7.0,
    ssd_write_gibs: 5.0,
    ssd_lat_us: 80.0,
    ssd_cache_gib: 8.0,
    cpu_rel: 0.5,
    cpu_threads: 16,
};

/// This container (single core, tmpfs-backed storage): the profile real
/// benches run under.
pub static LOCAL: HardwareSpec = HardwareSpec {
    name: "local",
    cpu: "container (1 core)",
    dram_gib: 35.0,
    dram_gibs: 10.0,
    pcie_gen: 3,
    gpus: 0,
    vram_gib: 0.0,
    gpu_rel_flops: 0.0,
    ssds: 1,
    ssd_read_gibs: 1.5,
    ssd_write_gibs: 1.0,
    ssd_lat_us: 100.0,
    ssd_cache_gib: 0.5,
    cpu_rel: 0.5,
    cpu_threads: 1,
};

pub static ALL: &[&HardwareSpec] =
    &[&CONFIG1, &CONFIG2, &CONFIG3, &COMMODITY128, &LOCAL];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcie_bandwidth_by_gen() {
        assert_eq!(CONFIG1.pcie_gibs(), 48.0);
        assert_eq!(CONFIG2.pcie_gibs(), 24.0);
    }

    #[test]
    fn config2_is_slower_cpu() {
        assert!(CONFIG2.cpu_rel < CONFIG1.cpu_rel);
    }

    #[test]
    fn aggregate_ssd_bandwidth() {
        assert!(CONFIG2.ssd_agg_read_gibs() > CONFIG2.ssd_read_gibs);
        assert_eq!(CONFIG1.ssd_agg_read_gibs(), CONFIG1.ssd_read_gibs);
    }

    #[test]
    fn lookup() {
        assert!(HardwareSpec::by_name("config1").is_ok());
        assert!(HardwareSpec::by_name("cray").is_err());
    }
}
