//! Configuration: model architectures, hardware profiles, training specs.

pub mod hardware;
pub mod presets;
pub mod train;

pub use hardware::HardwareSpec;
pub use presets::ModelSpec;
pub use train::{MemAscendFlags, Precision, TrainSpec};
