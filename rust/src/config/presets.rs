//! Model architecture specs.
//!
//! Full-scale architectures (used by the accounting engine to reproduce
//! the paper's 7B–32B peak-memory numbers from their *exact* tensor
//! shapes) plus the runnable tiny configs that mirror
//! `python/compile/configs.py` (kept consistent by integration tests
//! against the AOT manifest).

/// Dense or MoE decoder architecture, enough to enumerate every
/// parameter tensor with its exact shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub vocab: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    /// MoE: experts per layer (0 = dense).
    pub n_experts: usize,
    /// MoE: per-expert FFN intermediate size.
    pub expert_intermediate: usize,
    /// MoE: experts activated per token (throughput model only).
    pub experts_per_token: usize,
    /// Whether embedding and lm_head share one tensor.
    pub tie_embeddings: bool,
}

impl ModelSpec {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim()
    }

    pub fn is_moe(&self) -> bool {
        self.n_experts > 0
    }

    /// Total parameter count (validated against known model sizes).
    pub fn param_count(&self) -> u64 {
        crate::tensors::inventory(self)
            .iter()
            .map(|t| t.numel as u64)
            .sum()
    }

    pub fn by_name(name: &str) -> anyhow::Result<&'static ModelSpec> {
        ALL.iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown model '{name}' (available: {})",
                    ALL.iter().map(|m| m.name).collect::<Vec<_>>().join(", ")
                )
            }).copied()
    }
}

const fn dense(
    name: &'static str,
    vocab: usize,
    hidden: usize,
    intermediate: usize,
    layers: usize,
    heads: usize,
    kv_heads: usize,
) -> ModelSpec {
    ModelSpec {
        name,
        vocab,
        hidden,
        intermediate,
        layers,
        heads,
        kv_heads,
        n_experts: 0,
        expert_intermediate: 0,
        experts_per_token: 0,
        tie_embeddings: false,
    }
}

/// Llama 3.1 8B (HF config: 128256 vocab, 4096 h, 14336 ffn, 32 L, GQA 8).
pub static LLAMA31_8B: ModelSpec =
    dense("llama3.1-8b", 128_256, 4096, 14_336, 32, 32, 8);

/// Qwen2.5-7B (152064 vocab, 3584 h, 18944 ffn, 28 L, GQA 4).
pub static QWEN25_7B: ModelSpec =
    dense("qwen2.5-7b", 152_064, 3584, 18_944, 28, 28, 4);

/// Qwen2.5-14B (152064 vocab, 5120 h, 13824 ffn, 48 L, GQA 8).
pub static QWEN25_14B: ModelSpec =
    dense("qwen2.5-14b", 152_064, 5120, 13_824, 48, 40, 8);

/// Qwen2.5-32B (152064 vocab, 5120 h, 27648 ffn, 64 L, GQA 8).
pub static QWEN25_32B: ModelSpec =
    dense("qwen2.5-32b", 152_064, 5120, 27_648, 64, 40, 8);

/// Qwen2.5-0.5B (used by the paper's convergence experiment, Fig. 19).
pub static QWEN25_05B: ModelSpec =
    dense("qwen2.5-0.5b", 151_936, 896, 4864, 24, 14, 2);

/// Llama-3.2-1B-class model for the Table II motivational experiment
/// (tied embeddings, like the real 1B checkpoint).
pub static DENSE_1B: ModelSpec = ModelSpec {
    name: "dense-1b",
    vocab: 128_256,
    hidden: 2048,
    intermediate: 8192,
    layers: 16,
    heads: 32,
    kv_heads: 8,
    n_experts: 0,
    expert_intermediate: 0,
    experts_per_token: 0,
    tie_embeddings: true,
};

/// Llama-3.2-3B-class model for the Table II motivational experiment.
pub static DENSE_3B: ModelSpec = dense("dense-3b", 128_256, 3072, 8192, 28, 24, 8);

/// Qwen3-30B-A3B: sparse MoE, 128 experts, 8 active, expert ffn 768.
pub static QWEN3_30B_A3B: ModelSpec = ModelSpec {
    name: "qwen3-30b-a3b",
    vocab: 151_936,
    hidden: 2048,
    intermediate: 0, // MoE layers have no dense FFN
    layers: 48,
    heads: 32,
    kv_heads: 4,
    n_experts: 128,
    expert_intermediate: 768,
    experts_per_token: 8,
    tie_embeddings: false,
};

// ---- runnable configs (must mirror python/compile/configs.py) ----

pub static SMOKE: ModelSpec = dense("smoke", 64, 32, 64, 2, 2, 2);
pub static TINY25M: ModelSpec = dense("tiny25m", 4096, 384, 1024, 8, 6, 6);
pub static TINY100M: ModelSpec = dense("tiny100m", 8192, 768, 2048, 12, 12, 12);

pub static ALL: &[&ModelSpec] = &[
    &LLAMA31_8B,
    &QWEN25_7B,
    &QWEN25_14B,
    &QWEN25_32B,
    &QWEN25_05B,
    &DENSE_1B,
    &DENSE_3B,
    &QWEN3_30B_A3B,
    &SMOKE,
    &TINY25M,
    &TINY100M,
];

/// The four dense evaluation models of the paper's §VI.
pub static PAPER_DENSE: &[&ModelSpec] =
    &[&LLAMA31_8B, &QWEN25_7B, &QWEN25_14B, &QWEN25_32B];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        // within 5% of the nominal size (nominal names round down)
        let cases: &[(&ModelSpec, f64)] = &[
            (&LLAMA31_8B, 8.0e9),
            (&QWEN25_7B, 7.6e9),
            (&QWEN25_14B, 14.8e9),
            (&QWEN25_32B, 32.8e9),
            (&QWEN3_30B_A3B, 30.5e9),
        ];
        for (m, nominal) in cases {
            let p = m.param_count() as f64;
            let ratio = p / nominal;
            assert!(
                (0.90..1.10).contains(&ratio),
                "{}: {:.2}B vs nominal {:.2}B",
                m.name,
                p / 1e9,
                nominal / 1e9
            );
        }
    }

    #[test]
    fn tiny100m_is_about_100m() {
        let p = TINY100M.param_count() as f64;
        assert!((8.0e7..1.3e8).contains(&p), "{p}");
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(ModelSpec::by_name("qwen2.5-7b").unwrap().hidden, 3584);
        assert!(ModelSpec::by_name("gpt-5").is_err());
    }

    #[test]
    fn gqa_dims_divide() {
        for m in ALL {
            assert_eq!(m.hidden % m.heads, 0, "{}", m.name);
            assert_eq!(m.heads % m.kv_heads, 0, "{}", m.name);
        }
    }
}
