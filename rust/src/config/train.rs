//! Training specification + the MemAscend component ablation flags.

use crate::dtype::DType;

/// Mixed-precision mode (paper §VI-B-3b: fp16 needs overflow checks,
/// bf16 does not — which is exactly why fp16 shows the larger savings).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// fp16 compute + fp32 master + dynamic loss scaling + overflow check.
    MixedF16,
    /// bf16 compute + fp32 master, no overflow check required.
    MixedBF16,
}

impl Precision {
    pub fn compute_dtype(self) -> DType {
        match self {
            Precision::MixedF16 => DType::F16,
            Precision::MixedBF16 => DType::BF16,
        }
    }

    pub fn needs_overflow_check(self) -> bool {
        matches!(self, Precision::MixedF16)
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "fp16" | "f16" => Precision::MixedF16,
            "bf16" => Precision::MixedBF16,
            other => anyhow::bail!("unknown precision '{other}' (fp16|bf16)"),
        })
    }
}

/// The four MemAscend optimizations as independent toggles, enabling
/// the ablation benches DESIGN.md calls out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemAscendFlags {
    /// §IV-B adaptive buffer pool (vs largest-tensor monolithic pool).
    pub adaptive_pool: bool,
    /// §IV-C alignment-free pinned allocation (vs pow2 caching policy).
    pub alignment_free: bool,
    /// §IV-D fused overflow check (vs isinf/isnan chain).
    pub fused_overflow: bool,
    /// §IV-E direct NVMe engine (vs filesystem engine).
    pub direct_nvme: bool,
}

impl MemAscendFlags {
    pub const fn baseline() -> Self {
        Self {
            adaptive_pool: false,
            alignment_free: false,
            fused_overflow: false,
            direct_nvme: false,
        }
    }

    pub const fn memascend() -> Self {
        Self {
            adaptive_pool: true,
            alignment_free: true,
            fused_overflow: true,
            direct_nvme: true,
        }
    }

    pub fn label(&self) -> String {
        if *self == Self::baseline() {
            return "zero-infinity".into();
        }
        if *self == Self::memascend() {
            return "memascend".into();
        }
        let mut parts = vec![];
        if self.adaptive_pool {
            parts.push("pool");
        }
        if self.alignment_free {
            parts.push("align");
        }
        if self.fused_overflow {
            parts.push("fused");
        }
        if self.direct_nvme {
            parts.push("nvme");
        }
        format!("ablation[{}]", parts.join("+"))
    }

    /// All 16 combinations, for the ablation sweep.
    pub fn all_combinations() -> Vec<Self> {
        (0..16u8)
            .map(|m| Self {
                adaptive_pool: m & 1 != 0,
                alignment_free: m & 2 != 0,
                fused_overflow: m & 4 != 0,
                direct_nvme: m & 8 != 0,
            })
            .collect()
    }
}

/// Everything that defines one training run.
#[derive(Debug, Clone)]
pub struct TrainSpec {
    /// Micro-batch per rank.
    pub batch: usize,
    /// Context length in tokens.
    pub seq: usize,
    /// Data-parallel rank count (ZeRO-3 partitions).
    pub ranks: usize,
    pub precision: Precision,
    /// Optimizer state dtype: F32 (baseline) or BF16 (§VI-B-3a).
    pub optim_dtype: DType,
    /// Transformer blocks kept in flight by the prefetcher (paper's N).
    pub prefetch_depth: usize,
    /// Worker threads of the shared async I/O queue (swapper fetch
    /// window + double-buffered optimizer swap). `0` = fully
    /// synchronous: single-worker fetches and the sequential
    /// read→Adam→write optimizer loop (the overlap-ablation baseline —
    /// numerically identical either way).
    pub io_workers: usize,
    /// Fixed-byte tile size for the optimizer-state swap: each group's
    /// (master, m, v) streams are split into tiles of this many state
    /// bytes and streamed through the four-stage fetch → upconvert →
    /// Adam → downconvert/write-back pipeline, capping peak pinned
    /// optimizer staging at `O(tile_bytes × depth)` *independent of
    /// group size* (one embedding or MoE-expert group no longer sets
    /// the high-water mark).  `0` = whole-group double-buffering — the
    /// paper-parity baseline the Fig. 8/15 replays use.  All settings
    /// are bit-identical in result.  Default ≈ one arena segment's
    /// worth of staging.
    pub optim_tile_bytes: usize,
    /// Tile-pipeline window: fetch and write-back generations the
    /// staged-tile optimizer keeps in flight (the former
    /// `TILE_PIPELINE_DEPTH` constant, now a spec knob the governor
    /// may retune).  Clamped to ≥ 1.
    pub optim_tile_depth: usize,
    /// Coalesce the per-tensor optimizer groups into super-group
    /// streams of at most this many state bytes each before tiling
    /// (`optimizer::CoalescedOptim`): one long contiguous ranged
    /// submission per tile instead of ≥ 7 submissions per tensor.
    /// Only engages on the tiled path (`io_workers > 0` and
    /// `optim_tile_bytes > 0`).  `0` = off (per-tensor groups, today's
    /// layout).  Bit-identical either way.
    pub optim_coalesce_bytes: usize,
    /// Coalesce the *weight fetch* path too: mirror each fp16 weight
    /// into packed per-super-group read streams
    /// (`CoalescedOptim::enable_fp16_streams`) and let the swapper
    /// gather a whole super-group of tensors with one ranged read,
    /// delivering per-member lease views off a single upconvert.
    /// Requires `optim_coalesce_bytes > 0` (the streams live on the
    /// coalesced layout); ignored otherwise.  Bit-identical either
    /// way — only the submission count changes.
    pub fetch_coalesce: bool,
    /// Record the first step's fetch timing profile and replay later
    /// steps against a rate-matched just-in-time issue schedule
    /// (`offload::ProfileStore`), instead of the fixed depth window.
    /// The profile persists on-engine (`swap/profile`) and across
    /// checkpoint resume; a plan-digest mismatch degrades to the depth
    /// window and re-records (`StepMetrics::prefetch_fallbacks`).
    pub prefetch_profile: bool,
    /// Safety lead subtracted from each replayed fetch deadline, in
    /// microseconds.  The governor retunes it between
    /// `min_lead_us`/`max_lead_us` when enabled; static otherwise.
    pub prefetch_lead_us: u64,
    /// Enable the pressure-adaptive pipeline governor
    /// (`train::PipelineGovernor`): retunes `optim_tile_bytes`,
    /// `optim_tile_depth`, `prefetch_depth`, the replay schedule's
    /// lead-time, and `act_host_budget` each step from observed arena
    /// pressure (`host_copy_bytes`, `degraded_tiles`), prefetch
    /// hit/late counts, and stall/busy ratios.  `false` = the static knobs above are
    /// used verbatim forever — today's behavior, byte for byte (the
    /// paper-parity figure specs keep it off).
    pub governor: bool,
    /// Offload activation checkpoints to host memory (Eq. 1).
    pub offloaded_gc: bool,
    /// Host byte budget for activation checkpoints; checkpoints beyond
    /// it spill to the SSD (the SSDTrain integration, §II-B1).
    /// `usize::MAX` = everything stays in host memory.
    pub act_host_budget: usize,
    /// Global pinned-memory budget enforced by the `PinnedArena` all
    /// host buffers lease from; `None` = unbounded.  Exceeding it is a
    /// structured error (or a graceful spill), never an abort.
    pub pinned_budget_bytes: Option<usize>,
    /// Cache FsEngine member fds (§III-D ablation: isolates the
    /// path-resolution tax from the journal tax).  No effect with
    /// `direct_nvme`.
    pub fs_cached_fds: bool,
    /// Commit a crash-consistent checkpoint epoch every this many
    /// steps (`ckpt::Journal`): flush every on-SSD state/fp16 key,
    /// persist resident tensors + RNG/scaler/step cursors, then
    /// atomically advance the journal epoch.  `0` = off (no journal,
    /// no resume).  The flushes ride the bytes the tiled write-back
    /// already pushed — a checkpoint is a barrier, not a copy.
    pub ckpt_interval_steps: usize,
    /// Total attempts per NVMe op under the transient-fault retry
    /// layer (`ssd::RetryEngine`); `<= 1` = no retry layer.  Retries
    /// are metered in `IoSnapshot::retries` / `StepMetrics::io_retries`
    /// and exhaustion still surfaces the error.
    pub io_retry_attempts: usize,
    /// Per-op I/O deadline in milliseconds.  Non-zero arms hedged
    /// reads on the async queue (`AsyncEngine::with_deadline`): an
    /// owned-buffer read whose primary submission stalls past the
    /// health tracker's hedge delay (rolling p99, capped by this
    /// deadline) is recorded as a timeout and re-submitted; first
    /// completion wins.  `0` = off (no hedging, today's behavior).
    pub io_deadline_ms: u64,
    /// Verify every read against per-block FNV-1a checksums
    /// (`ssd::IntegrityEngine`): writes maintain a `sums/{key}`
    /// sidecar, reads verify it, mismatches surface as typed
    /// `IntegrityError`s the retry layer re-reads through.  `false` =
    /// no integrity layer — byte-identical to the pre-integrity stack.
    pub verify_reads: bool,
    /// Walk persisted keys between steps, re-reading (and thereby
    /// verifying, when `verify_reads` is on) a couple per step so cold
    /// rot is found before a restore needs the bytes.  Metered in
    /// `StepMetrics::scrubbed_bytes` / `scrub_failures`.
    pub scrub: bool,
    pub flags: MemAscendFlags,
    // optimizer hyper-parameters (must match artifacts' adam constants
    // when the HLO adam path is used — see manifest "adam")
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    /// Initial dynamic loss scale (power of two).
    pub init_loss_scale: f64,
    /// Good steps before the scale doubles.
    pub scale_growth_interval: usize,
}

impl Default for TrainSpec {
    fn default() -> Self {
        Self {
            batch: 1,
            seq: 128,
            ranks: 1,
            precision: Precision::MixedF16,
            optim_dtype: DType::F32,
            prefetch_depth: 2,
            io_workers: 2,
            optim_tile_bytes: 4 << 20,
            optim_tile_depth: 2,
            optim_coalesce_bytes: 0,
            fetch_coalesce: false,
            prefetch_profile: false,
            prefetch_lead_us: 2_000,
            governor: false,
            offloaded_gc: true,
            act_host_budget: usize::MAX,
            pinned_budget_bytes: None,
            fs_cached_fds: false,
            ckpt_interval_steps: 0,
            io_retry_attempts: 3,
            io_deadline_ms: 0,
            verify_reads: false,
            scrub: false,
            flags: MemAscendFlags::memascend(),
            lr: 1.0e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            init_loss_scale: 65536.0,
            scale_growth_interval: 100,
        }
    }
}

impl TrainSpec {
    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.seq * self.ranks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_labels() {
        assert_eq!(MemAscendFlags::baseline().label(), "zero-infinity");
        assert_eq!(MemAscendFlags::memascend().label(), "memascend");
        let mut f = MemAscendFlags::baseline();
        f.fused_overflow = true;
        assert_eq!(f.label(), "ablation[fused]");
    }

    #[test]
    fn all_combinations_unique() {
        let all = MemAscendFlags::all_combinations();
        assert_eq!(all.len(), 16);
        for (i, a) in all.iter().enumerate() {
            for b in &all[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn precision_rules() {
        assert!(Precision::MixedF16.needs_overflow_check());
        assert!(!Precision::MixedBF16.needs_overflow_check());
        assert_eq!(Precision::parse("bf16").unwrap(), Precision::MixedBF16);
    }
}
