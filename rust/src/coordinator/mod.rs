//! CLI command implementations — the leader process's surface.
//!
//! Each subcommand is a thin orchestration over the library: parse
//! flags, build specs, run, render tables.  `main.rs` dispatches here.

use std::path::PathBuf;
use std::sync::Arc;

use crate::accounting::{self, sysmem};
use crate::config::{HardwareSpec, MemAscendFlags, ModelSpec, Precision, TrainSpec};
use crate::jobs::{FleetConfig, FleetGovernor, JobCtx, JobRegistry, JobState};
use crate::offload::{JobFault, OffloadEngine};
use crate::ssd::{JobId, MAX_JOB_LANES};
use crate::train::{TrainOpts, Trainer};
use crate::util::bench::Table;
use crate::util::cli::{Args, Command};
use crate::util::events::{EventSink, StderrSink};
use crate::util::human;
use crate::util::json::Json;

pub fn commands() -> Vec<Command> {
    vec![
        Command::new("train", "run SSD-offloaded fine-tuning on a tiny model")
            .opt("model", "smoke", "artifact config (smoke|tiny25m|tiny100m)")
            .opt("steps", "20", "training steps")
            .opt("mode", "memascend", "memascend|zero-infinity")
            .opt("ranks", "1", "simulated data-parallel ranks")
            .opt("optim", "f32", "optimizer state dtype (f32|bf16)")
            .opt(
                "optim-tile-bytes",
                "4194304",
                "optimizer tile size in state bytes (0 = whole-group swap)",
            )
            .opt(
                "optim-tile-depth",
                "2",
                "tile-pipeline window: fetch/write-back generations in flight",
            )
            .opt(
                "optim-coalesce-bytes",
                "0",
                "coalesce per-tensor optimizer groups into super-groups of this many state bytes (0 = off)",
            )
            .flag(
                "fetch-coalesce",
                "coalesce the weight fetch path over packed fp16 super-group streams: one ranged read per super-group instead of per-tensor reads (needs --optim-coalesce-bytes > 0)",
            )
            .flag(
                "prefetch-profile",
                "record the first step's fetch timing profile and replay later steps on a rate-matched just-in-time schedule (persists across checkpoint resume)",
            )
            .opt(
                "prefetch-lead-us",
                "2000",
                "safety lead subtracted from each replayed fetch deadline, in microseconds",
            )
            .flag(
                "governor",
                "enable the pressure-adaptive pipeline governor (retunes tile size/depth, prefetch depth, schedule lead-time, and the activation host budget per step)",
            )
            .opt(
                "ckpt-interval",
                "0",
                "commit a crash-consistent checkpoint epoch every N steps (0 = off); a checkpoint is a flush barrier + journal record, not a copy — resume with --resume",
            )
            .opt(
                "io-retry",
                "3",
                "attempts per NVMe op under the transient-fault retry layer (<=1 = no retries)",
            )
            .opt(
                "io-deadline-ms",
                "0",
                "per-op I/O deadline in ms: a read outliving the health tracker's hedge delay is re-submitted and the first completion wins (0 = off)",
            )
            .flag(
                "verify-reads",
                "checksum every stream per 256 KiB block on write and verify on read (detected corruption retries under --io-retry)",
            )
            .flag(
                "scrub",
                "idle-time integrity scrub: re-read and re-verify a couple of streams between steps (needs --verify-reads)",
            )
            .flag(
                "resume",
                "resume from the newest checkpoint epoch on --storage instead of re-initializing (requires a --ckpt-interval run and the original seed)",
            )
            .opt("precision", "fp16", "mixed precision (fp16|bf16)")
            .opt("seed", "42", "init/data seed")
            .opt("artifacts", "artifacts", "AOT artifacts root")
            .opt("storage", "", "SSD-sim directory (default: temp)")
            .opt("loss-csv", "", "write the loss curve CSV here")
            .opt("log-every", "10", "progress cadence"),
        Command::new("multitrain", "run N co-tenant fine-tuning jobs on one shared offload stack")
            .opt(
                "jobs",
                "",
                "job-spec JSON path: {\"jobs\":[{\"name\",\"weight\",\"steps\",\"seed\",\"fault\"},..]} \
                 or a bare array; empty = two unit-weight jobs",
            )
            .opt("model", "smoke", "artifact config (smoke|tiny25m|tiny100m)")
            .opt("steps", "20", "default steps per job (a job spec entry overrides)")
            .opt("mode", "memascend", "memascend|zero-infinity")
            .opt("ranks", "1", "simulated data-parallel ranks (per job)")
            .opt("precision", "fp16", "mixed precision (fp16|bf16)")
            .opt("optim", "f32", "optimizer state dtype (f32|bf16)")
            .opt(
                "optim-tile-bytes",
                "4194304",
                "optimizer tile size in state bytes (0 = whole-group swap)",
            )
            .opt(
                "optim-tile-depth",
                "2",
                "tile-pipeline window: fetch/write-back generations in flight",
            )
            .flag(
                "governor",
                "per-job pipeline governors (the fleet governor overlays its caps either way)",
            )
            .opt("ckpt-interval", "0", "per-job checkpoint cadence in steps (0 = off)")
            .opt("io-retry", "3", "attempts per NVMe op under the retry layer (<=1 = no retries)")
            .opt(
                "io-deadline-ms",
                "0",
                "per-op I/O deadline in ms for hedged reads (0 = off)",
            )
            .flag(
                "verify-reads",
                "per-block checksums on every job's streams, verified on read",
            )
            .flag("scrub", "per-job idle-time integrity scrub (needs --verify-reads)")
            .opt(
                "events-jsonl",
                "",
                "append structured events (job failures, device health, integrity violations) as JSON lines to this file instead of stderr",
            )
            .opt("seed", "42", "base seed (job i defaults to seed + i)")
            .opt("artifacts", "artifacts", "AOT artifacts root")
            .opt("storage", "", "shared SSD-sim directory (default: temp)")
            .opt("log-every", "10", "per-job progress cadence (0 = quiet)"),
        Command::new("report-memory", "full-scale peak system-memory breakdown")
            .opt("model", "qwen2.5-7b", "model preset")
            .opt("ctx", "4096", "context length")
            .opt("batch", "4", "micro-batch per rank")
            .opt("ranks", "2", "data-parallel ranks")
            .opt("hw", "config1", "hardware profile")
            .opt("precision", "fp16", "fp16|bf16"),
        Command::new("inventory", "print a model's parameter tensor inventory")
            .opt("model", "qwen2.5-7b", "model preset"),
        Command::new("perf-model", "projected step time / throughput at paper scale")
            .opt("model", "qwen2.5-7b", "model preset")
            .opt("ctx", "4096", "context length")
            .opt("batch", "8", "micro-batch per rank")
            .opt("ranks", "2", "ranks")
            .opt("hw", "config1", "hardware profile")
            .opt("mode", "memascend", "memascend|zero-infinity")
            .opt("optim", "f32", "f32|bf16"),
        Command::new("sweep-context", "peak-memory sweep over context lengths")
            .opt("model", "qwen2.5-7b", "model preset")
            .opt("batch", "1", "micro-batch per rank")
            .opt("ranks", "2", "ranks")
            .opt("hw", "config1", "hardware profile")
            .opt("cap", "128", "system-memory cap in GiB"),
        Command::new("sweep-batch", "peak-memory + throughput sweep over batch sizes")
            .opt("model", "qwen2.5-7b", "model preset")
            .opt("ctx", "4096", "context length")
            .opt("ranks", "2", "ranks")
            .opt("hw", "config1", "hardware profile")
            .opt("cap", "128", "system-memory cap in GiB"),
        Command::new("help", "list commands"),
    ]
}

pub fn parse_mode(mode: &str) -> anyhow::Result<MemAscendFlags> {
    Ok(match mode {
        "memascend" | "ma" => MemAscendFlags::memascend(),
        "zero-infinity" | "zi" | "baseline" => MemAscendFlags::baseline(),
        other => anyhow::bail!("unknown mode '{other}' (memascend|zero-infinity)"),
    })
}

pub fn train_spec_from_args(args: &Args, batch: usize, seq: usize) -> anyhow::Result<TrainSpec> {
    let defaults = TrainSpec::default();
    Ok(TrainSpec {
        batch,
        seq,
        ranks: args.get_usize("ranks", 1)?,
        precision: Precision::parse(args.get_or("precision", "fp16"))?,
        optim_dtype: crate::dtype::DType::parse(args.get_or("optim", "f32"))?,
        optim_tile_bytes: args
            .get_usize("optim-tile-bytes", defaults.optim_tile_bytes)?,
        optim_tile_depth: args
            .get_usize("optim-tile-depth", defaults.optim_tile_depth)?,
        optim_coalesce_bytes: args
            .get_usize("optim-coalesce-bytes", defaults.optim_coalesce_bytes)?,
        fetch_coalesce: args.get_bool("fetch-coalesce"),
        prefetch_profile: args.get_bool("prefetch-profile"),
        prefetch_lead_us: args
            .get_usize("prefetch-lead-us", defaults.prefetch_lead_us as usize)?
            as u64,
        governor: args.get_bool("governor"),
        ckpt_interval_steps: args
            .get_usize("ckpt-interval", defaults.ckpt_interval_steps)?,
        io_retry_attempts: args.get_usize("io-retry", defaults.io_retry_attempts)?,
        io_deadline_ms: args.get_usize("io-deadline-ms", defaults.io_deadline_ms as usize)?
            as u64,
        verify_reads: args.get_bool("verify-reads"),
        scrub: args.get_bool("scrub"),
        flags: parse_mode(args.get_or("mode", "memascend"))?,
        ..defaults
    })
}

pub fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let model = args.get_or("model", "smoke").to_string();
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts")).join(&model);
    let resume = args.get_bool("resume");
    let storage = match args.get_or("storage", "") {
        "" if resume => anyhow::bail!(
            "--resume needs --storage pointing at the checkpointed run's \
             directory (the default storage is a fresh per-process temp dir)"
        ),
        "" => std::env::temp_dir().join(format!("memascend-{}", std::process::id())),
        s => PathBuf::from(s),
    };
    std::fs::create_dir_all(&storage)?;
    // batch/seq come from the artifact manifest
    let manifest =
        crate::runtime::Manifest::load(&artifacts.join("manifest.json"))?;
    let mut spec = train_spec_from_args(args, manifest.config.batch, manifest.config.seq)?;
    if spec.precision == Precision::MixedBF16 {
        spec.init_loss_scale = 1.0;
    }
    let opts = TrainOpts {
        steps: args.get_usize("steps", 20)?,
        seed: args.get_usize("seed", 42)? as u64,
        log_every: args.get_usize("log-every", 10)?,
        loss_csv: match args.get_or("loss-csv", "") {
            "" => None,
            p => Some(p.to_string()),
        },
    };
    eprintln!(
        "{} {model} [{}] for {} steps (ranks={} precision={:?})",
        if resume { "resuming" } else { "training" },
        spec.flags.label(),
        opts.steps,
        spec.ranks,
        spec.precision
    );
    let mut trainer = if resume {
        Trainer::resume(&artifacts, &storage, spec, &opts)?
    } else {
        Trainer::new(&artifacts, &storage, spec, &opts)?
    };
    if resume {
        eprintln!(
            "resumed at epoch {} (step {})",
            trainer.journal_epoch(),
            trainer.steps_done()
        );
    }
    let report = trainer.run(&opts)?;
    println!("=== run report ===");
    println!("label            {}", report.label);
    println!("final loss       {:.4}", report.final_loss());
    println!("tokens/sec       {:.1}", report.tokens_per_sec());
    println!("peak sysmem      {}", human::bytes(report.peak_sysmem_bytes));
    println!("io bytes/step    {}", human::bytes(report.io_bytes_per_step));
    println!("--- memory ledger ---\n{}", trainer.engine.tracker.report());
    Ok(())
}

/// One tenant of a `multitrain` run, as parsed from the `--jobs` spec.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub name: String,
    /// Weighted-fair scheduling weight and fair-share quota weight.
    pub weight: u32,
    pub steps: u64,
    pub seed: u64,
    /// Optional per-job NVMe fault injection (chaos drills).
    pub fault: Option<JobFault>,
}

/// Parse a `--jobs` spec: `{"jobs": [ {..}, .. ]}` or a bare array.
/// Per entry: `name` (default `job<i>`), `weight` (default 1),
/// `steps` (default `default_steps`), `seed` (default `base_seed + i`),
/// `fault` (`"none"` | `"persistent"` | `"probabilistic"`, with
/// `fault_per_1024` / `fault_seed` refining the probabilistic case).
pub fn parse_job_specs(
    src: &str,
    default_steps: u64,
    base_seed: u64,
) -> anyhow::Result<Vec<JobSpec>> {
    let root = Json::parse(src).map_err(|e| anyhow::anyhow!("--jobs spec: {e}"))?;
    let arr = root
        .get("jobs")
        .unwrap_or(&root)
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("--jobs spec: expected an array or {{\"jobs\": [..]}}"))?;
    anyhow::ensure!(!arr.is_empty(), "--jobs spec: no jobs listed");
    anyhow::ensure!(
        arr.len() < MAX_JOB_LANES,
        "--jobs spec: {} jobs, but only {} tenant lanes (lane 0 is the host)",
        arr.len(),
        MAX_JOB_LANES - 1
    );
    let mut out = Vec::with_capacity(arr.len());
    for (i, o) in arr.iter().enumerate() {
        let seed = o.get("seed").and_then(Json::as_u64).unwrap_or(base_seed + i as u64);
        let fault = match o.get("fault").and_then(Json::as_str).unwrap_or("none") {
            "none" => None,
            "persistent" => Some(JobFault::Persistent),
            "probabilistic" | "transient" => Some(JobFault::Probabilistic {
                per_1024: o.get("fault_per_1024").and_then(Json::as_u64).unwrap_or(8),
                seed: o.get("fault_seed").and_then(Json::as_u64).unwrap_or(seed),
            }),
            other => anyhow::bail!(
                "--jobs spec: unknown fault '{other}' (none|persistent|probabilistic)"
            ),
        };
        out.push(JobSpec {
            name: o
                .get("name")
                .and_then(Json::as_str)
                .map(str::to_string)
                .unwrap_or_else(|| format!("job{}", i + 1)),
            weight: o.get("weight").and_then(Json::as_u64).unwrap_or(1).max(1) as u32,
            steps: o.get("steps").and_then(Json::as_u64).unwrap_or(default_steps),
            seed,
            fault,
        });
    }
    Ok(out)
}

pub fn cmd_multitrain(args: &Args) -> anyhow::Result<()> {
    let model = args.get_or("model", "smoke").to_string();
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts")).join(&model);
    let storage = match args.get_or("storage", "") {
        "" => std::env::temp_dir().join(format!("memascend-mt-{}", std::process::id())),
        s => PathBuf::from(s),
    };
    std::fs::create_dir_all(&storage)?;
    let manifest = crate::runtime::Manifest::load(&artifacts.join("manifest.json"))?;
    let mut train = train_spec_from_args(args, manifest.config.batch, manifest.config.seq)?;
    if train.precision == Precision::MixedBF16 {
        train.init_loss_scale = 1.0;
    }
    let default_steps = args.get_usize("steps", 20)? as u64;
    let base_seed = args.get_usize("seed", 42)? as u64;
    let log_every = args.get_usize("log-every", 10)? as u64;
    let jobs = match args.get_or("jobs", "") {
        "" => parse_job_specs(r#"[{}, {}]"#, default_steps, base_seed)?,
        p => parse_job_specs(
            &std::fs::read_to_string(p)
                .map_err(|e| anyhow::anyhow!("--jobs {p}: {e}"))?,
            default_steps,
            base_seed,
        )?,
    };
    let rt = Trainer::load_runtime(&artifacts, &train)?;
    let spec = rt.manifest().model_spec()?;
    // one shared substrate: arena + device + submission queue + stage
    let engine = OffloadEngine::new_shared(spec, &train, &storage, jobs.len())?;
    let sink: Arc<dyn EventSink> = match args.get_or("events-jsonl", "") {
        "" => Arc::new(StderrSink),
        p => crate::util::events::FileSink::create(p)
            .map_err(|e| anyhow::anyhow!("--events-jsonl {p}: {e}"))?,
    };
    let fleet = FleetGovernor::new(engine.arena.clone(), engine.ioq.clone(), FleetConfig::default());
    let registry = JobRegistry::new(sink.clone());
    eprintln!(
        "multitrain {model} [{}]: {} jobs on one engine (weights {:?})",
        train.flags.label(),
        jobs.len(),
        jobs.iter().map(|j| j.weight).collect::<Vec<_>>()
    );
    let interval = train.ckpt_interval_steps as u64;
    for (i, js) in jobs.iter().enumerate() {
        let job = JobId((i + 1) as u16);
        fleet.register(job, js.weight);
        let view = engine.job_view(spec, &train, job, js.fault)?;
        let opts = TrainOpts {
            steps: js.steps as usize,
            seed: js.seed,
            log_every: 0,
            loss_csv: None,
        };
        let ctx = JobCtx::new(job, sink.clone()).with_fleet(fleet.clone());
        let (rt, train, name) = (rt.clone(), train.clone(), js.name.clone());
        // the trainer is built lazily on the job's own thread, so a
        // tenant whose storage is broken (e.g. an injected persistent
        // fault) fails *its* job at step 0 instead of aborting the fleet
        let mut view = Some(view);
        let mut tr: Option<Trainer> = None;
        registry.spawn(&js.name, job, js.steps, move |_| {
            if tr.is_none() {
                let v = view.take().expect("trainer already failed to build");
                tr = Some(Trainer::with_engine(rt.clone(), v, train.clone(), &opts, ctx.clone())?);
            }
            let t = tr.as_mut().expect("just built");
            let idx = t.steps_done() + 1;
            let mut m = t.step(idx)?;
            if interval > 0 && idx % interval == 0 {
                m.ckpt_secs = t
                    .checkpoint()
                    .map_err(|e| e.context(format!("checkpoint commit failed after step {idx}")))?;
            }
            if log_every > 0 && idx % log_every == 0 {
                eprintln!("[{name}] step {idx:>4}  loss {:.4}  {:.2}s", m.loss, m.step_secs);
            }
            Ok(m)
        });
    }
    registry.join_all();
    let mut snap = engine.base.stats();
    engine.ioq.fill_job_lanes(&mut snap);
    let mut t = Table::new(vec![
        "job", "weight", "state", "steps", "mean loss", "io share", "io busy",
    ]);
    let mut failed_unexpectedly = Vec::new();
    for (i, js) in jobs.iter().enumerate() {
        let job = JobId((i + 1) as u16);
        let state = registry.state(job).unwrap_or(JobState::Stopped);
        let rollup = registry.rollup(job).unwrap_or_default();
        if state == JobState::Failed && js.fault.is_none() {
            failed_unexpectedly.push(js.name.clone());
        }
        t.row(vec![
            js.name.clone(),
            js.weight.to_string(),
            format!("{state:?}"),
            rollup.steps.to_string(),
            format!("{:.4}", rollup.mean_loss()),
            format!("{:.2}", snap.job_share(job)),
            human::secs(snap.job_busy_secs(job)),
        ]);
    }
    println!("=== multitrain report ===");
    println!("{}", t.render());
    println!("--- shared memory ledger ---\n{}", engine.tracker.report());
    anyhow::ensure!(
        failed_unexpectedly.is_empty(),
        "jobs failed without injected faults: {failed_unexpectedly:?}"
    );
    Ok(())
}

pub fn cmd_report_memory(args: &Args) -> anyhow::Result<()> {
    let model = ModelSpec::by_name(args.get_or("model", "qwen2.5-7b"))?;
    let hw = HardwareSpec::by_name(args.get_or("hw", "config1"))?;
    let base = TrainSpec {
        batch: args.get_usize("batch", 4)?,
        seq: args.get_usize("ctx", 4096)?,
        ranks: args.get_usize("ranks", 2)?,
        precision: Precision::parse(args.get_or("precision", "fp16"))?,
        prefetch_depth: 1,
        ..Default::default()
    };
    let mut t = Table::new(vec![
        "component", "zero-infinity", "memascend", "delta",
    ]);
    let mut zi = base.clone();
    zi.flags = MemAscendFlags::baseline();
    let mut ma = base;
    ma.flags = MemAscendFlags::memascend();
    let bz = sysmem::peak_sysmem(model, &zi, hw);
    let bm = sysmem::peak_sysmem(model, &ma, hw);
    let row = |t: &mut Table, name: &str, a: u64, b: u64| {
        t.row(vec![
            name.to_string(),
            human::bytes(a),
            human::bytes(b),
            human::pct_delta(a as f64, b as f64),
        ]);
    };
    row(&mut t, "param_pool", bz.param_pool, bm.param_pool);
    row(&mut t, "pinned_overhead", bz.pinned_overhead, bm.pinned_overhead);
    row(&mut t, "grad_flat", bz.grad_flat, bm.grad_flat);
    row(&mut t, "overflow_spike", bz.overflow_spike, bm.overflow_spike);
    row(&mut t, "optim+swap_buf", bz.optim_buf + bz.swap_buf, bm.optim_buf + bm.swap_buf);
    row(&mut t, "act_ckpt", bz.act_ckpt, bm.act_ckpt);
    row(&mut t, "resident", bz.resident, bm.resident);
    row(&mut t, "PEAK TOTAL", bz.peak_total, bm.peak_total);
    println!("peak system memory — {} on {}\n", model.name, hw.name);
    println!("{}", t.render());
    println!(
        "theoretical minimum (pool + grad flat): {}",
        human::bytes(bm.theoretical_min())
    );
    Ok(())
}

pub fn cmd_inventory(args: &Args) -> anyhow::Result<()> {
    let model = ModelSpec::by_name(args.get_or("model", "qwen2.5-7b"))?;
    let inv = crate::tensors::inventory(model);
    let mut t = Table::new(vec!["tensor", "shape", "class", "fp16 bytes"]);
    // layer 0 + non-layer tensors only (layers repeat)
    for d in inv.iter().filter(|t| t.layer == 0 || t.layer == usize::MAX) {
        t.row(vec![
            d.name.clone(),
            format!("{:?}", d.shape),
            format!("{:?}", d.shape_class()),
            human::bytes(d.bytes(crate::dtype::DType::F16) as u64),
        ]);
    }
    println!(
        "{} — {} tensors, {:.2}B params ({} layers; showing layer 0)\n",
        model.name,
        inv.len(),
        model.param_count() as f64 / 1e9,
        model.layers
    );
    println!("{}", t.render());
    Ok(())
}

pub fn cmd_perf_model(args: &Args) -> anyhow::Result<()> {
    let model = ModelSpec::by_name(args.get_or("model", "qwen2.5-7b"))?;
    let hw = HardwareSpec::by_name(args.get_or("hw", "config1"))?;
    let spec = TrainSpec {
        batch: args.get_usize("batch", 8)?,
        seq: args.get_usize("ctx", 4096)?,
        ranks: args.get_usize("ranks", 2)?,
        flags: parse_mode(args.get_or("mode", "memascend"))?,
        optim_dtype: crate::dtype::DType::parse(args.get_or("optim", "f32"))?,
        ..Default::default()
    };
    let calib = accounting::perfmodel::Calib::default();
    let st = accounting::step_time(model, &spec, hw, &calib);
    println!("projected step time — {} on {} [{}]", model.name, hw.name, spec.flags.label());
    println!("  compute        {}", human::secs(st.compute));
    println!("  exposed I/O    {}", human::secs(st.param_io_exposed));
    println!("  engine tax     {}", human::secs(st.engine_tax));
    println!("  overflow check {}", human::secs(st.overflow));
    println!("  optimizer      {}", human::secs(st.optim));
    println!("  TOTAL          {}", human::secs(st.total()));
    println!("  throughput     {:.1} tokens/s", st.tokens_per_sec(&spec));
    Ok(())
}

/// Context or batch sweep, ZI vs MA, with fit verdicts under a cap.
pub fn cmd_sweep(args: &Args, over_context: bool) -> anyhow::Result<()> {
    let model = ModelSpec::by_name(args.get_or("model", "qwen2.5-7b"))?;
    let hw = HardwareSpec::by_name(args.get_or("hw", "config1"))?;
    let cap = args.get_f64("cap", 128.0)?;
    let calib = accounting::perfmodel::Calib::default();
    let points: Vec<usize> = if over_context {
        vec![4096, 8192, 16384, 32768, 65536, 131072]
    } else {
        vec![1, 2, 4, 8, 16, 32, 48, 64, 96]
    };
    let mut t = Table::new(vec![
        if over_context { "ctx" } else { "batch" },
        "ZI (GiB)",
        "MA (GiB)",
        "cut %",
        "MA tokens/s (proj)",
        "fits cap (ZI/MA)",
    ]);
    for p in points {
        let mk = |flags| TrainSpec {
            batch: if over_context { args.get_usize("batch", 1).unwrap_or(1) } else { p },
            seq: if over_context { p } else { args.get_usize("ctx", 4096).unwrap_or(4096) },
            ranks: args.get_usize("ranks", 2).unwrap_or(2),
            prefetch_depth: 1,
            flags,
            ..Default::default()
        };
        let zi = sysmem::peak_sysmem(model, &mk(MemAscendFlags::baseline()), hw);
        let ma_spec = mk(MemAscendFlags::memascend());
        let ma = sysmem::peak_sysmem(model, &ma_spec, hw);
        let st = accounting::step_time(model, &ma_spec, hw, &calib);
        t.row(vec![
            p.to_string(),
            format!("{:.2}", zi.gib()),
            format!("{:.2}", ma.gib()),
            format!("{:.1}", (1.0 - ma.peak_total as f64 / zi.peak_total as f64) * 100.0),
            format!("{:.0}", st.tokens_per_sec(&ma_spec)),
            format!(
                "{}/{}",
                if zi.gib() <= cap { "y" } else { "n" },
                if ma.gib() <= cap { "y" } else { "n" }
            ),
        ]);
    }
    println!(
        "{} sweep — {} on {} (cap {cap} GiB)\n",
        if over_context { "context" } else { "batch" },
        model.name,
        hw.name
    );
    println!("{}", t.render());
    Ok(())
}

pub fn dispatch(cmd: &str, argv: &[String]) -> anyhow::Result<()> {
    let cmds = commands();
    let spec = cmds.iter().find(|c| c.name == cmd);
    match (cmd, spec) {
        ("help", _) | (_, None) => {
            println!("memascend — SSD-offloaded LLM fine-tuning (paper reproduction)\n");
            for c in &cmds {
                println!("  {:<16} {}", c.name, c.about);
            }
            if spec.is_none() && cmd != "help" {
                anyhow::bail!("unknown command '{cmd}'");
            }
            Ok(())
        }
        (_, Some(spec)) => {
            let args = spec.parse(argv)?;
            match cmd {
                "train" => cmd_train(&args),
                "multitrain" => cmd_multitrain(&args),
                "report-memory" => cmd_report_memory(&args),
                "inventory" => cmd_inventory(&args),
                "perf-model" => cmd_perf_model(&args),
                "sweep-context" => cmd_sweep(&args, true),
                "sweep-batch" => cmd_sweep(&args, false),
                _ => unreachable!(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing() {
        assert_eq!(parse_mode("memascend").unwrap(), MemAscendFlags::memascend());
        assert_eq!(parse_mode("zi").unwrap(), MemAscendFlags::baseline());
        assert!(parse_mode("fast").is_err());
    }

    #[test]
    fn job_spec_parsing_defaults_and_faults() {
        let js = parse_job_specs(
            r#"{"jobs": [
                {"name": "big", "weight": 3, "steps": 12},
                {"seed": 7, "fault": "persistent"},
                {"fault": "probabilistic", "fault_per_1024": 16}
            ]}"#,
            20,
            100,
        )
        .unwrap();
        assert_eq!(js.len(), 3);
        assert_eq!(js[0].name, "big");
        assert_eq!((js[0].weight, js[0].steps, js[0].seed), (3, 12, 100));
        assert!(js[0].fault.is_none());
        assert_eq!(js[1].name, "job2");
        assert_eq!(js[1].seed, 7);
        assert!(matches!(js[1].fault, Some(JobFault::Persistent)));
        assert!(matches!(
            js[2].fault,
            Some(JobFault::Probabilistic { per_1024: 16, seed: 102 })
        ));
        // bare-array form, all defaults
        let js = parse_job_specs("[{}, {}]", 5, 1).unwrap();
        assert_eq!(js[1], JobSpec {
            name: "job2".into(),
            weight: 1,
            steps: 5,
            seed: 2,
            fault: None,
        });
        // rejects: garbage, empty, too many lanes, unknown fault kinds
        assert!(parse_job_specs("{", 1, 1).is_err());
        assert!(parse_job_specs("[]", 1, 1).is_err());
        assert!(parse_job_specs(&format!("[{}]", vec!["{}"; 99].join(",")), 1, 1).is_err());
        assert!(parse_job_specs(r#"[{"fault": "meteor"}]"#, 1, 1).is_err());
    }

    #[test]
    fn multitrain_command_is_registered() {
        let cmds = commands();
        let spec = cmds.iter().find(|c| c.name == "multitrain").unwrap();
        let args = spec
            .parse(&["--steps".to_string(), "3".to_string()])
            .unwrap();
        assert_eq!(args.get_usize("steps", 0).unwrap(), 3);
        assert_eq!(args.get_or("jobs", "x"), "");
    }

    #[test]
    fn inventory_command_runs() {
        let cmds = commands();
        let spec = cmds.iter().find(|c| c.name == "inventory").unwrap();
        let args = spec.parse(&["--model".to_string(), "smoke".to_string()]).unwrap();
        cmd_inventory(&args).unwrap();
    }

    #[test]
    fn report_memory_command_runs() {
        let cmds = commands();
        let spec = cmds.iter().find(|c| c.name == "report-memory").unwrap();
        let args = spec.parse(&[]).unwrap();
        cmd_report_memory(&args).unwrap();
    }

    #[test]
    fn perf_model_command_runs() {
        let cmds = commands();
        let spec = cmds.iter().find(|c| c.name == "perf-model").unwrap();
        let args = spec.parse(&[]).unwrap();
        cmd_perf_model(&args).unwrap();
    }
}
