//! Bit-exact IEEE 754 binary16 and bfloat16 conversions.
//!
//! Round-to-nearest-even on narrowing, exact on widening — matching
//! hardware semantics so the Rust-side casts agree with what jax/XLA
//! produce, and so fp16 overflow manifests as real ±inf for the
//! overflow-check path.

/// f32 -> IEEE binary16, branch-light round-to-nearest-even
/// (Giesen's float_to_half_fast3_rtne — §Perf: 6.8 -> ~2 ns/elem on
/// the fp16 gradient/weight writeback path; the reference
/// implementation below is kept for differential testing).
pub fn f32_to_f16(x: f32) -> u16 {
    const F32_INFTY: u32 = 255 << 23;
    const F16_MAX: u32 = (127 + 16) << 23;
    const DENORM_MAGIC_BITS: u32 = ((127 - 15) + (23 - 10) + 1) << 23;
    let denorm_magic = f32::from_bits(DENORM_MAGIC_BITS);
    let bits = x.to_bits();
    let sign = (bits >> 16) as u16 & 0x8000;
    let mut f = bits & 0x7fff_ffff;
    let o: u16 = if f >= F16_MAX {
        // overflow -> inf; nan -> quiet nan
        if f > F32_INFTY { 0x7e00 } else { 0x7c00 }
    } else if f < (113 << 23) {
        // subnormal-f16 range (incl. zero): float-add renormalizes and
        // rounds RTNE in one step
        let fv = f32::from_bits(f) + denorm_magic;
        (fv.to_bits() - DENORM_MAGIC_BITS) as u16
    } else {
        // normal: rebias exponent, round mantissa to nearest-even
        let mant_odd = (f >> 13) & 1;
        f = f.wrapping_add(0xC800_0FFFu32); // ((15-127)<<23) + 0xfff
        f = f.wrapping_add(mant_odd);
        (f >> 13) as u16
    };
    sign | o
}

/// Reference f32 -> f16 (explicit-case version; differential oracle).
pub fn f32_to_f16_ref(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // inf stays inf; any nan becomes a quiet nan with payload msb set
        return if mant == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }

    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e >= -14 {
        // normal f16
        let mut m = (mant >> 13) as u16;
        let mut he = (e + 15) as u16;
        // round to nearest even on the 13 truncated bits
        let rem = mant & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
            if m == 0x400 {
                m = 0;
                he += 1;
                if he >= 0x1f {
                    return sign | 0x7c00;
                }
            }
        }
        return sign | (he << 10) | m;
    }
    if e >= -25 {
        // subnormal f16 (e == -25 values can still round up to 1 ulp)
        let full = mant | 0x0080_0000; // implicit leading 1
        let shift = (-14 - e) as u32 + 13;
        let m = (full >> shift) as u16;
        let rem_mask = (1u32 << shift) - 1;
        let rem = full & rem_mask;
        let half = 1u32 << (shift - 1);
        let mut m = m;
        if rem > half || (rem == half && (m & 1) == 1) {
            m += 1; // may carry into normal range — that is correct
        }
        return sign | m;
    }
    sign // underflow to ±0
}

/// IEEE binary16 -> f32 (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        // inf / nan
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: value = mant * 2^-24 (exact in f32)
            let v = mant as f32 * 2.0f32.powi(-24);
            return if sign != 0 { -v } else { v };
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// f32 -> bfloat16 (round-to-nearest-even; NaN preserved).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet the NaN, keep payload msb set
        return ((bits >> 16) as u16) | 0x0040;
    }
    let rem = bits & 0xffff;
    let mut top = (bits >> 16) as u16;
    if rem > 0x8000 || (rem == 0x8000 && (top & 1) == 1) {
        top = top.wrapping_add(1);
    }
    top
}

/// bfloat16 -> f32 (exact: just restore the low mantissa bits as zero).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

use once_cell::sync::Lazy;

/// f16 -> f32 lookup table (256 KiB): bulk decode of swapped-in fp16
/// weights is the hottest conversion in the trainer (§Perf).
static F16_LUT: Lazy<Vec<f32>> =
    Lazy::new(|| (0..=u16::MAX).map(f16_to_f32).collect());

/// LUT-accelerated scalar decode for bulk paths.
#[inline]
pub fn f16_to_f32_lut(h: u16) -> f32 {
    F16_LUT[h as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3c00);
        assert_eq!(f32_to_f16(-2.0), 0xc000);
        assert_eq!(f32_to_f16(65504.0), 0x7bff); // f16 max
        assert_eq!(f32_to_f16(65536.0), 0x7c00); // overflow -> inf
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
        assert!(f16_to_f32(0x7e00).is_nan());
        // smallest positive subnormal: 2^-24
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001);
    }

    #[test]
    fn fast_encoder_matches_reference_exhaustively() {
        // differential test over a dense sweep of interesting floats
        let mut cases: Vec<f32> = vec![
            0.0, -0.0, 1.0, -1.0, 65504.0, 65536.0, 1e-8, -1e-8,
            f32::INFINITY, f32::NEG_INFINITY, f32::MAX, f32::MIN_POSITIVE,
            2.0f32.powi(-24), 2.0f32.powi(-25), 1.0 + 2.0f32.powi(-11),
        ];
        let mut rng = crate::util::rng::Xoshiro256::new(99);
        for _ in 0..200_000 {
            cases.push(f32::from_bits(rng.next_u64() as u32));
        }
        for x in cases {
            let fast = f32_to_f16(x);
            let slow = f32_to_f16_ref(x);
            if x.is_nan() {
                assert_eq!(fast & 0x7c00, 0x7c00);
                assert_ne!(fast & 0x03ff, 0);
            } else {
                assert_eq!(fast, slow, "x={x} ({:#010x})", x.to_bits());
            }
        }
    }

    #[test]
    fn lut_matches_bitwise_decode() {
        for h in (0u16..=u16::MAX).step_by(7) {
            let a = f16_to_f32_lut(h);
            let b = f16_to_f32(h);
            assert!(a == b || (a.is_nan() && b.is_nan()), "h={h:#06x}");
        }
    }

    #[test]
    fn f16_nan_propagates() {
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_roundtrip_exact_for_representables() {
        // every finite f16 value must round-trip bit-exactly
        for h in 0u16..=0xffff {
            let exp = (h >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/nan handled elsewhere
            }
            let f = f16_to_f32(h);
            assert_eq!(f32_to_f16(f), h, "h={h:#06x} f={f}");
        }
    }

    #[test]
    fn f16_round_nearest_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and 1.0+2^-10:
        // must round to even mantissa (1.0).
        let halfway = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(halfway), 0x3c00);
        // slightly above halfway rounds up
        let above = 1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20);
        assert_eq!(f32_to_f16(above), 0x3c01);
    }

    #[test]
    fn bf16_known_values() {
        assert_eq!(f32_to_bf16(1.0), 0x3f80);
        assert_eq!(f32_to_bf16(-1.0), 0xbf80);
        assert_eq!(bf16_to_f32(0x3f80), 1.0);
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7f80);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // 1e30 fits in bf16 range
        assert!(bf16_to_f32(f32_to_bf16(1e30)).is_finite());
    }

    #[test]
    fn bf16_round_nearest_even() {
        // halfway cases on the truncated 16 bits
        let x = f32::from_bits(0x3f80_8000); // exactly halfway
        assert_eq!(f32_to_bf16(x), 0x3f80); // ties to even (low bit 0)
        let y = f32::from_bits(0x3f81_8000);
        assert_eq!(f32_to_bf16(y), 0x3f82); // ties to even (rounds up)
    }

    #[test]
    fn bf16_roundtrip_exact_for_representables() {
        for b in 0u16..=0xffff {
            let exp = (b >> 7) & 0xff;
            if exp == 0xff {
                continue;
            }
            let f = bf16_to_f32(b);
            assert_eq!(f32_to_bf16(f), b, "b={b:#06x}");
        }
    }
}
