//! Data types and precision conversion (the `half` crate analog).
//!
//! SSD offloading is precision-plumbing: fp16 compute weights + fp32
//! masters on disk, fp16 gradients accumulated into an fp32 flat
//! buffer, optionally bf16 optimizer states (paper §VI-B-3a).  This
//! module owns the bit-exact conversions and the per-dtype byte math.

pub mod f16;

pub use f16::{bf16_to_f32, f16_to_f32, f16_to_f32_lut, f32_to_bf16, f32_to_f16};

/// Storage dtypes that flow through the offload pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    BF16,
    I32,
    U8,
}

impl DType {
    pub const fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 | DType::BF16 => 2,
            DType::U8 => 1,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "f32" | "fp32" => DType::F32,
            "f16" | "fp16" => DType::F16,
            "bf16" => DType::BF16,
            "i32" => DType::I32,
            "u8" => DType::U8,
            other => anyhow::bail!("unknown dtype '{other}'"),
        })
    }

    pub const fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::I32 => "i32",
            DType::U8 => "u8",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Convert an f32 slice to packed f16 bytes (the "cast to fp16 gradient"
/// step of mixed-precision training). Values outside fp16 range become
/// ±inf — exactly the overflow the loss scaler must then detect.
pub fn f32s_to_f16_bytes(src: &[f32], dst: &mut [u8]) {
    assert_eq!(dst.len(), src.len() * 2);
    for (i, &x) in src.iter().enumerate() {
        let b = f32_to_f16(x).to_le_bytes();
        dst[i * 2] = b[0];
        dst[i * 2 + 1] = b[1];
    }
}

/// [`f32s_to_f16_bytes`] over raw little-endian f32 bytes — the
/// alignment-free view a byte buffer provides.  Same [`f32_to_f16`]
/// per element, so outputs are bit-identical to the slice variant.
pub fn f32_le_bytes_to_f16_bytes(src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len() % 4, 0);
    assert_eq!(dst.len() * 2, src.len());
    for i in 0..src.len() / 4 {
        let x = f32::from_le_bytes([
            src[4 * i],
            src[4 * i + 1],
            src[4 * i + 2],
            src[4 * i + 3],
        ]);
        let b = f32_to_f16(x).to_le_bytes();
        dst[2 * i] = b[0];
        dst[2 * i + 1] = b[1];
    }
}

pub fn f16_bytes_to_f32s(src: &[u8], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len() * 2);
    // LUT decode (§Perf): the swap-in H2D-analog path runs this over
    // every streamed weight, twice per step
    for (i, x) in dst.iter_mut().enumerate() {
        *x = f16_to_f32_lut(u16::from_le_bytes([src[i * 2], src[i * 2 + 1]]));
    }
}

pub fn f32s_to_bf16_bytes(src: &[f32], dst: &mut [u8]) {
    assert_eq!(dst.len(), src.len() * 2);
    for (i, &x) in src.iter().enumerate() {
        let b = f32_to_bf16(x).to_le_bytes();
        dst[i * 2] = b[0];
        dst[i * 2 + 1] = b[1];
    }
}

pub fn bf16_bytes_to_f32s(src: &[u8], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len() * 2);
    for (i, x) in dst.iter_mut().enumerate() {
        *x = bf16_to_f32(u16::from_le_bytes([src[i * 2], src[i * 2 + 1]]));
    }
}

/// View a f32 slice as raw little-endian bytes (zero-copy).
pub fn f32s_as_bytes(src: &[f32]) -> &[u8] {
    // SAFETY: f32 has no invalid bit patterns and alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(src.as_ptr().cast::<u8>(), src.len() * 4) }
}

pub fn f32s_as_bytes_mut(src: &mut [f32]) -> &mut [u8] {
    // SAFETY: as above; exclusive borrow guarantees aliasing rules.
    unsafe {
        std::slice::from_raw_parts_mut(src.as_mut_ptr().cast::<u8>(), src.len() * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::F16.size(), 2);
        assert_eq!(DType::parse("bf16").unwrap(), DType::BF16);
        assert!(DType::parse("f64").is_err());
    }

    #[test]
    fn le_bytes_f16_conversion_matches_slice_variant() {
        // the alignment-free variant must be bit-identical — the tiled
        // optimizer's downconvert rides it
        let vals = [0.0f32, 1.5, -2.25, 65504.0, 1e-8, f32::INFINITY, -0.0];
        let mut a = vec![0u8; vals.len() * 2];
        let mut b = vec![0u8; vals.len() * 2];
        f32s_to_f16_bytes(&vals, &mut a);
        f32_le_bytes_to_f16_bytes(f32s_as_bytes(&vals), &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn f16_bulk_roundtrip() {
        let src = vec![0.0f32, 1.0, -2.5, 0.333251953125, 65504.0];
        let mut bytes = vec![0u8; src.len() * 2];
        f32s_to_f16_bytes(&src, &mut bytes);
        let mut back = vec![0f32; src.len()];
        f16_bytes_to_f32s(&bytes, &mut back);
        // all values above are exactly representable in f16
        assert_eq!(src, back);
    }

    #[test]
    fn f16_overflow_becomes_inf() {
        let src = vec![1e30f32, -1e30];
        let mut bytes = vec![0u8; 4];
        f32s_to_f16_bytes(&src, &mut bytes);
        let mut back = vec![0f32; 2];
        f16_bytes_to_f32s(&bytes, &mut back);
        assert!(back[0].is_infinite() && back[0] > 0.0);
        assert!(back[1].is_infinite() && back[1] < 0.0);
    }

    #[test]
    fn bf16_preserves_range_loses_precision() {
        let src = vec![1e30f32, 3.14159265f32];
        let mut bytes = vec![0u8; 4];
        f32s_to_bf16_bytes(&src, &mut bytes);
        let mut back = vec![0f32; 2];
        bf16_bytes_to_f32s(&bytes, &mut back);
        assert!(back[0].is_finite(), "bf16 has f32 range");
        assert!((back[1] - 3.14159265).abs() < 0.01);
        assert_ne!(back[1], 3.14159265f32);
    }

    #[test]
    fn byte_view_roundtrip() {
        let mut v = vec![1.5f32, -2.25, 1e-7];
        let orig = v.clone();
        let bytes = f32s_as_bytes(&v).to_vec();
        f32s_as_bytes_mut(&mut v).copy_from_slice(&bytes);
        assert_eq!(v, orig);
    }
}
