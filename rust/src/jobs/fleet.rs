//! The fleet governor: cross-job arbitration over shared resources.
//!
//! Each trainer already has a [`PipelineGovernor`] tuning its own
//! windows against *global* pressure signals — but a per-job governor
//! cannot tell "I am the problem" from "my co-tenant is".  Left alone,
//! N per-job governors all see the same saturated arena and all shrink
//! (convoy collapse), or the greediest keeps growing while the others
//! starve.  The [`FleetGovernor`] sits above them:
//!
//! - **Registration** splits the arena budget into weighted fair-share
//!   namespace quotas (minus a shared-headroom slice any job may
//!   borrow) and programs the job's weight into the NVMe scheduler.
//! - **Pressure arbitration**: each job reports its
//!   [`GovernorSample`] once per step.  When global arena pressure
//!   crosses the threshold, the governor throttles the *heaviest*
//!   tenant only — capping its pipeline windows via [`FleetCaps`] and
//!   revoking its right to new headroom borrows — instead of letting
//!   every job shrink.
//! - **Recovery**: a throttled job that stays calm for
//!   [`FleetConfig::calm_steps`] reports gets its caps doubled back
//!   toward unlimited, then fully released (borrow right restored).
//!
//! Caps are an overlay ([`PipelineGovernor::set_caps`]): the per-job
//! governor's converged state is never corrupted, so releasing a cap
//! restores the tuning the job had earned.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::pinned::PinnedArena;
use crate::ssd::{IoExecutor, JobId};
use crate::train::{FleetCaps, GovernorSample};

/// Fleet arbitration knobs.
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Fraction of the arena budget kept as borrowable shared headroom
    /// (the rest splits into weighted fair-share quotas).
    pub headroom_frac: f64,
    /// Global reserved/budget fraction above which the heaviest tenant
    /// is throttled.
    pub pressure_frac: f64,
    /// Calm (unpressured) reports before a throttled job's caps relax
    /// one notch.
    pub calm_steps: u32,
    /// Depth cap applied on the first throttle notch (halved on each
    /// further pressure event, floored at 1).
    pub first_notch_depth: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            headroom_frac: 0.25,
            pressure_frac: 0.85,
            calm_steps: 4,
            first_notch_depth: 8,
        }
    }
}

struct JobEntry {
    weight: u32,
    caps: FleetCaps,
    throttled: bool,
    calm: u32,
}

/// Arbitrates per-job [`FleetCaps`] and arena quotas over one shared
/// [`PinnedArena`] + [`IoExecutor`] pair.
pub struct FleetGovernor {
    arena: Arc<PinnedArena>,
    exec: Arc<IoExecutor>,
    cfg: FleetConfig,
    jobs: Mutex<HashMap<JobId, JobEntry>>,
}

impl FleetGovernor {
    pub fn new(arena: Arc<PinnedArena>, exec: Arc<IoExecutor>, cfg: FleetConfig) -> Arc<Self> {
        Arc::new(Self {
            arena,
            exec,
            cfg,
            jobs: Mutex::new(HashMap::new()),
        })
    }

    /// Admit a job with a scheduling/memory weight.  Reprograms the
    /// NVMe scheduler lane weight and re-splits the arena budget into
    /// fair-share quotas across every registered job (no-op on an
    /// unbudgeted arena — nothing to ration).
    pub fn register(&self, job: JobId, weight: u32) {
        let weight = weight.max(1);
        self.exec.set_weight(job, weight);
        let mut jobs = self.jobs.lock().unwrap();
        jobs.insert(
            job,
            JobEntry {
                weight,
                caps: FleetCaps::unlimited(),
                throttled: false,
                calm: 0,
            },
        );
        self.resplit(&jobs);
    }

    /// Remove a job (its quota share redistributes to the others).
    pub fn deregister(&self, job: JobId) {
        let mut jobs = self.jobs.lock().unwrap();
        if jobs.remove(&job).is_some() {
            self.arena.set_ns_quota(job.lane(), None);
            self.arena.set_ns_revoked(job.lane(), false);
            self.resplit(&jobs);
        }
    }

    fn resplit(&self, jobs: &HashMap<JobId, JobEntry>) {
        let Some(budget) = self.arena.budget_bytes() else {
            return;
        };
        let headroom = (budget as f64 * self.cfg.headroom_frac) as usize;
        self.arena.set_shared_headroom(headroom);
        let pool = budget - headroom;
        let total_w: u64 = jobs.values().map(|e| u64::from(e.weight)).sum();
        if total_w == 0 {
            return;
        }
        for (job, e) in jobs {
            let share = (pool as u128 * u128::from(e.weight) / u128::from(total_w)) as usize;
            self.arena.set_ns_quota(job.lane(), Some(share));
        }
    }

    /// Per-step report from one job's trainer.  Returns the caps the
    /// job must overlay on its governor (`None` = unlimited).
    ///
    /// A quarantined device ([`GovernorSample::device_degraded`]) is
    /// fleet-level pressure too: the shared queue is sick, so the
    /// heaviest tenant's windows shrink rather than every job piling
    /// deeper submissions onto a struggling device.
    pub fn report(&self, job: JobId, sample: &GovernorSample) -> Option<FleetCaps> {
        let pressured = sample.device_degraded
            || sample.arena_budget.is_some_and(|b| {
                sample.arena_reserved as f64 > self.cfg.pressure_frac * b as f64
            });
        let mut jobs = self.jobs.lock().unwrap();
        if pressured {
            // Throttle the heaviest tenant only — by charged arena
            // attribution — so co-tenants keep their earned windows.
            let heaviest = jobs
                .keys()
                .copied()
                .max_by_key(|j| self.arena.ns_stats(j.lane()).charged)
                .unwrap_or(job);
            if let Some(e) = jobs.get_mut(&heaviest) {
                if e.throttled {
                    e.caps.max_tile_depth = (e.caps.max_tile_depth / 2).max(1);
                    e.caps.max_prefetch_depth = (e.caps.max_prefetch_depth / 2).max(1);
                } else {
                    e.throttled = true;
                    e.caps = FleetCaps {
                        max_tile_depth: self.cfg.first_notch_depth,
                        max_prefetch_depth: self.cfg.first_notch_depth,
                        max_act_budget: usize::MAX,
                    };
                }
                e.calm = 0;
                self.arena.set_ns_revoked(heaviest.lane(), true);
            }
        } else if let Some(e) = jobs.get_mut(&job) {
            if e.throttled {
                e.calm += 1;
                if e.calm >= self.cfg.calm_steps {
                    e.calm = 0;
                    let relaxed = e.caps.max_tile_depth.saturating_mul(2);
                    if relaxed >= self.cfg.first_notch_depth {
                        e.throttled = false;
                        e.caps = FleetCaps::unlimited();
                        self.arena.set_ns_revoked(job.lane(), false);
                    } else {
                        e.caps.max_tile_depth = relaxed;
                        e.caps.max_prefetch_depth =
                            e.caps.max_prefetch_depth.saturating_mul(2);
                    }
                }
            }
        }
        let e = jobs.get(&job)?;
        e.throttled.then_some(e.caps)
    }

    /// Current caps for a job without reporting a sample.
    pub fn caps(&self, job: JobId) -> Option<FleetCaps> {
        let jobs = self.jobs.lock().unwrap();
        let e = jobs.get(&job)?;
        e.throttled.then_some(e.caps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pinned::{AlignedAllocator, ArenaConfig, Cat, MemoryTracker, Mode};

    fn arena(budget: Option<usize>) -> Arc<PinnedArena> {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(Mode::Virtual, tracker);
        PinnedArena::new(
            Arc::new(alloc),
            ArenaConfig { budget_bytes: budget, ..Default::default() },
        )
    }

    fn rig(budget: usize) -> (Arc<PinnedArena>, Arc<IoExecutor>) {
        (arena(Some(budget)), Arc::new(IoExecutor::new(1)))
    }

    fn sample(reserved: usize, budget: Option<usize>) -> GovernorSample {
        GovernorSample {
            arena_reserved: reserved,
            arena_budget: budget,
            ..Default::default()
        }
    }

    #[test]
    fn registration_splits_budget_by_weight_minus_headroom() {
        let budget = 1 << 20;
        let (arena, exec) = rig(budget);
        let fleet = FleetGovernor::new(Arc::clone(&arena), exec, FleetConfig::default());
        fleet.register(JobId(1), 3);
        fleet.register(JobId(2), 1);
        let pool = budget - (budget as f64 * 0.25) as usize;
        assert_eq!(arena.ns_stats(1).quota, Some(pool * 3 / 4));
        assert_eq!(arena.ns_stats(2).quota, Some(pool / 4));
        // host namespace keeps its unlimited default
        assert_eq!(arena.ns_stats(0).quota, None);
    }

    #[test]
    fn pressure_throttles_only_the_heaviest_tenant() {
        let budget = 1 << 20;
        let (arena, exec) = rig(budget);
        let fleet = FleetGovernor::new(Arc::clone(&arena), exec, FleetConfig::default());
        fleet.register(JobId(1), 1);
        fleet.register(JobId(2), 1);
        // make j1 the heavy tenant by holding a live lease in ns 1
        let j1_arena = arena.namespace(1);
        let _lease = j1_arena.lease(512 * 1024, Cat::Other).unwrap();
        let hot = sample((0.9 * budget as f64) as usize, Some(budget));
        // j2 reports pressure: the *heaviest* (j1) gets capped, not j2
        assert_eq!(fleet.report(JobId(2), &hot), None);
        let caps = fleet.caps(JobId(1)).expect("heaviest job must be capped");
        assert_eq!(caps.max_tile_depth, 8);
        assert!(arena.ns_stats(1).revoked, "throttled job loses borrow right");
        assert!(!arena.ns_stats(2).revoked);
        // repeated pressure halves the notch, floored at 1
        for _ in 0..5 {
            fleet.report(JobId(2), &hot);
        }
        assert_eq!(fleet.caps(JobId(1)).unwrap().max_tile_depth, 1);
    }

    #[test]
    fn calm_streak_relaxes_back_to_unlimited() {
        let budget = 1 << 20;
        let (arena, exec) = rig(budget);
        let cfg = FleetConfig {
            calm_steps: 2,
            ..Default::default()
        };
        let fleet = FleetGovernor::new(Arc::clone(&arena), exec, cfg);
        fleet.register(JobId(1), 1);
        let hot = sample((0.9 * budget as f64) as usize, Some(budget));
        let cool = sample(0, Some(budget));
        assert!(fleet.report(JobId(1), &hot).is_some());
        assert!(arena.ns_stats(1).revoked);
        // one calm report is not enough; the second relaxes fully
        // (8 * 2 >= first_notch_depth releases the throttle)
        assert!(fleet.report(JobId(1), &cool).is_some());
        assert_eq!(fleet.report(JobId(1), &cool), None);
        assert!(!arena.ns_stats(1).revoked, "borrow right restored");
        assert_eq!(fleet.caps(JobId(1)), None);
    }

    #[test]
    fn degraded_device_throttles_the_heaviest_tenant() {
        let budget = 1 << 20;
        let (arena, exec) = rig(budget);
        let fleet = FleetGovernor::new(Arc::clone(&arena), exec, FleetConfig::default());
        fleet.register(JobId(1), 1);
        fleet.register(JobId(2), 1);
        let j1_arena = arena.namespace(1);
        let _lease = j1_arena.lease(512 * 1024, Cat::Other).unwrap();
        // arena is calm; the device is not
        let sick = GovernorSample { device_degraded: true, ..Default::default() };
        assert_eq!(fleet.report(JobId(2), &sick), None);
        let caps = fleet.caps(JobId(1)).expect("heaviest tenant capped");
        assert_eq!(caps.max_tile_depth, 8);
    }

    #[test]
    fn unbudgeted_arena_registers_without_quotas() {
        let arena = arena(None);
        let fleet = FleetGovernor::new(
            Arc::clone(&arena),
            Arc::new(IoExecutor::new(1)),
            FleetConfig::default(),
        );
        fleet.register(JobId(1), 2);
        assert_eq!(arena.ns_stats(1).quota, None);
        // and pressure can never trigger (no budget in the sample)
        assert_eq!(fleet.report(JobId(1), &sample(usize::MAX >> 1, None)), None);
    }
}
