//! Multi-job tenancy: N fine-tuning jobs sharing one offload stack.
//!
//! The single-trainer stack owns three scarce resources — the pinned
//! arena, the NVMe engine, and the I/O submission queue.  This module
//! makes all three multi-tenant without changing their solo-run
//! behavior by one byte.  The tenancy contract has four clauses:
//!
//! 1. **Fair share.**  Each job leases pinned memory through a
//!    namespaced arena view ([`crate::pinned::PinnedArena::namespace`])
//!    holding a weighted fair-share byte quota, and its NVMe
//!    submissions ride a deficit-weighted-round-robin scheduler
//!    ([`crate::ssd::DwrrQueue`]) under the same weight — sustained
//!    device time converges to the weight ratio.
//! 2. **Borrowable headroom.**  A slice of the arena budget is held
//!    back as shared headroom any job may borrow past its quota when
//!    co-tenants are idle — work-conserving, like the scheduler.
//! 3. **Revocation degrades, never aborts.**  Under global pressure
//!    the [`FleetGovernor`] revokes the heaviest job's right to *new*
//!    borrows and caps its pipeline windows ([`FleetCaps`] overlay on
//!    its [`crate::train::PipelineGovernor`]).  A refused lease
//!    surfaces as the same `BudgetExceeded` error the budget always
//!    produced, so every existing degradation path (smaller tiles,
//!    synchronous fallback) applies; in-flight borrows drain
//!    naturally.  No co-tenant is ever aborted to reclaim memory.
//! 4. **Fault isolation.**  Each job sees the shared SSD through a
//!    key-prefixed [`ScopedEngine`] view (no key collisions) and runs
//!    under the [`JobRegistry`], which converts a job's error into a
//!    `Failed` state plus a [`crate::util::events::EventKind::JobFailed`]
//!    event — its siblings keep their engines, leases, and schedules.
//!
//! [`JobCtx`] is the identity a trainer carries through all of this:
//! which job it is, where its diagnostics go, and (optionally) which
//! fleet governor arbitrates its windows.

pub mod fleet;
pub mod registry;
pub mod scoped;

pub use fleet::{FleetConfig, FleetGovernor};
pub use registry::{JobRegistry, JobRollup, JobState};
pub use scoped::ScopedEngine;

use std::sync::Arc;

use crate::util::events::{EventSink, JobId, StderrSink};

/// A trainer's tenancy identity: job id, event sink, and (for
/// fleet-managed jobs) the governor arbitrating its pipeline caps.
/// `JobCtx::default()` is the host identity — solo trainers behave
/// exactly as before tenancy existed.
#[derive(Clone)]
pub struct JobCtx {
    pub job: JobId,
    pub events: Arc<dyn EventSink>,
    pub fleet: Option<Arc<FleetGovernor>>,
}

impl Default for JobCtx {
    fn default() -> Self {
        Self { job: JobId::HOST, events: Arc::new(StderrSink), fleet: None }
    }
}

impl JobCtx {
    /// Identity for tenant `job`, reporting to `events`, unmanaged.
    pub fn new(job: JobId, events: Arc<dyn EventSink>) -> Self {
        Self { job, events, fleet: None }
    }

    pub fn with_fleet(mut self, fleet: Arc<FleetGovernor>) -> Self {
        self.fleet = Some(fleet);
        self
    }
}
