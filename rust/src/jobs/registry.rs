//! The job control plane: lifecycle, rollups, and fault isolation.
//!
//! A [`JobRegistry`] runs each tenant's step loop on its own thread
//! and owns the only mutable lifecycle state
//! ([`JobState`]: running → paused/resumed → stopped/failed/finished).
//! The isolation contract is structural: a job body's error marks
//! *that job* `Failed` and emits [`EventKind::JobFailed`] — the
//! registry never propagates the panic/err to siblings, and co-tenant
//! threads keep stepping.  Per-job [`JobRollup`]s aggregate the
//! [`StepMetrics`] stream so a fleet operator can read progress
//! without touching trainer internals.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::metrics::StepMetrics;
use crate::util::events::{Event, EventKind, EventSink, JobId};

/// Lifecycle of a registry-managed job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Running,
    Paused,
    /// Stopped by request; the step loop exited at the next boundary.
    Stopped,
    /// The job body returned an error; co-tenants are unaffected.
    Failed,
    /// All requested steps completed.
    Finished,
}

impl JobState {
    fn name(self) -> &'static str {
        match self {
            JobState::Running => "running",
            JobState::Paused => "paused",
            JobState::Stopped => "stopped",
            JobState::Failed => "failed",
            JobState::Finished => "finished",
        }
    }
}

/// Aggregate progress of one job, updated after every successful step.
#[derive(Debug, Clone, Copy, Default)]
pub struct JobRollup {
    pub steps: u64,
    pub loss_sum: f64,
    pub last_loss: f64,
    pub io_wait_secs: f64,
    pub step_secs: f64,
}

impl JobRollup {
    pub fn mean_loss(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.loss_sum / self.steps as f64
    }
}

struct JobShared {
    state: Mutex<JobState>,
    cv: Condvar,
    rollup: Mutex<JobRollup>,
}

struct JobHandle {
    name: String,
    shared: Arc<JobShared>,
    thread: Option<JoinHandle<()>>,
}

/// Spawns, observes, and controls a fleet of step loops.
pub struct JobRegistry {
    jobs: Mutex<HashMap<JobId, JobHandle>>,
    events: Arc<dyn EventSink>,
}

impl JobRegistry {
    pub fn new(events: Arc<dyn EventSink>) -> Self {
        Self { jobs: Mutex::new(HashMap::new()), events }
    }

    /// Run `body(step)` for `steps` iterations on a dedicated thread.
    /// The body is the whole per-step unit of work (typically
    /// `Trainer::step` plus logging); its `Err` fails only this job.
    pub fn spawn<F>(&self, name: &str, job: JobId, steps: u64, mut body: F)
    where
        F: FnMut(u64) -> anyhow::Result<StepMetrics> + Send + 'static,
    {
        let shared = Arc::new(JobShared {
            state: Mutex::new(JobState::Running),
            cv: Condvar::new(),
            rollup: Mutex::new(JobRollup::default()),
        });
        let worker_shared = Arc::clone(&shared);
        let events = Arc::clone(&self.events);
        let thread = std::thread::Builder::new()
            .name(format!("ma-job-{}", job.0))
            .spawn(move || {
                for step in 0..steps {
                    {
                        let mut st = worker_shared.state.lock().unwrap();
                        while *st == JobState::Paused {
                            st = worker_shared.cv.wait(st).unwrap();
                        }
                        if *st != JobState::Running {
                            return;
                        }
                    }
                    match body(step) {
                        Ok(m) => {
                            let mut r = worker_shared.rollup.lock().unwrap();
                            r.steps += 1;
                            r.loss_sum += m.loss;
                            r.last_loss = m.loss;
                            r.io_wait_secs += m.io_wait_secs;
                            r.step_secs += m.step_secs;
                        }
                        Err(e) => {
                            *worker_shared.state.lock().unwrap() = JobState::Failed;
                            worker_shared.cv.notify_all();
                            events.emit(Event {
                                job,
                                kind: EventKind::JobFailed,
                                detail: format!("step {step}: {e:#}"),
                            });
                            return;
                        }
                    }
                }
                let mut st = worker_shared.state.lock().unwrap();
                if *st == JobState::Running {
                    *st = JobState::Finished;
                }
            })
            .expect("spawn job thread");
        self.jobs.lock().unwrap().insert(
            job,
            JobHandle { name: name.to_string(), shared, thread: Some(thread) },
        );
    }

    fn transition(&self, job: JobId, from: &[JobState], to: JobState) -> bool {
        let jobs = self.jobs.lock().unwrap();
        let Some(h) = jobs.get(&job) else { return false };
        let mut st = h.shared.state.lock().unwrap();
        if !from.contains(&st) {
            return false;
        }
        *st = to;
        h.shared.cv.notify_all();
        drop(st);
        self.events.emit(Event {
            job,
            kind: EventKind::JobStateChanged { state: to.name() },
            detail: h.name.clone(),
        });
        true
    }

    /// Hold the job at its next step boundary (in-flight step finishes).
    pub fn pause(&self, job: JobId) -> bool {
        self.transition(job, &[JobState::Running], JobState::Paused)
    }

    pub fn resume(&self, job: JobId) -> bool {
        self.transition(job, &[JobState::Paused], JobState::Running)
    }

    /// Stop at the next step boundary.  Also wakes a paused job so it
    /// can observe the stop.
    pub fn stop(&self, job: JobId) -> bool {
        self.transition(job, &[JobState::Running, JobState::Paused], JobState::Stopped)
    }

    pub fn state(&self, job: JobId) -> Option<JobState> {
        let jobs = self.jobs.lock().unwrap();
        jobs.get(&job).map(|h| *h.shared.state.lock().unwrap())
    }

    pub fn rollup(&self, job: JobId) -> Option<JobRollup> {
        let jobs = self.jobs.lock().unwrap();
        jobs.get(&job).map(|h| *h.shared.rollup.lock().unwrap())
    }

    pub fn name(&self, job: JobId) -> Option<String> {
        self.jobs.lock().unwrap().get(&job).map(|h| h.name.clone())
    }

    /// Block until the job's thread exits (its state is terminal
    /// afterwards).  Idempotent.
    pub fn join(&self, job: JobId) {
        let thread = {
            let mut jobs = self.jobs.lock().unwrap();
            jobs.get_mut(&job).and_then(|h| h.thread.take())
        };
        if let Some(t) = thread {
            let _ = t.join();
        }
    }

    /// Join every spawned job.
    pub fn join_all(&self) {
        let ids: Vec<JobId> = self.jobs.lock().unwrap().keys().copied().collect();
        for job in ids {
            self.join(job);
        }
    }

    /// Jobs in registration order is not guaranteed; sorted by id.
    pub fn job_ids(&self) -> Vec<JobId> {
        let mut ids: Vec<JobId> = self.jobs.lock().unwrap().keys().copied().collect();
        ids.sort();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::events::MemorySink;

    #[test]
    fn one_job_failing_never_touches_its_co_tenant() {
        let sink = MemorySink::new();
        let reg = JobRegistry::new(sink.clone() as Arc<dyn EventSink>);
        reg.spawn("flaky", JobId(1), 8, |step| {
            if step == 3 {
                anyhow::bail!("injected persistent I/O fault");
            }
            Ok(StepMetrics { step, loss: 1.0, ..Default::default() })
        });
        reg.spawn("steady", JobId(2), 8, |step| {
            Ok(StepMetrics { step, loss: 0.5, ..Default::default() })
        });
        reg.join_all();
        assert_eq!(reg.state(JobId(1)), Some(JobState::Failed));
        assert_eq!(reg.state(JobId(2)), Some(JobState::Finished));
        // the co-tenant completed every step despite j1's abort
        let r2 = reg.rollup(JobId(2)).unwrap();
        assert_eq!(r2.steps, 8);
        assert!((r2.mean_loss() - 0.5).abs() < 1e-12);
        // j1 stopped exactly at the failing step, and said so
        assert_eq!(reg.rollup(JobId(1)).unwrap().steps, 3);
        let failures = sink.for_job(JobId(1));
        assert!(failures
            .iter()
            .any(|e| e.kind == EventKind::JobFailed && e.detail.contains("step 3")));
        assert!(sink
            .for_job(JobId(2))
            .iter()
            .all(|e| e.kind != EventKind::JobFailed));
    }

    #[test]
    fn pause_holds_the_step_loop_and_resume_releases_it() {
        let sink = MemorySink::new();
        let reg = JobRegistry::new(sink.clone() as Arc<dyn EventSink>);
        reg.spawn("pausable", JobId(1), u64::MAX, |step| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            Ok(StepMetrics { step, ..Default::default() })
        });
        // let it take a few steps, then pause
        while reg.rollup(JobId(1)).unwrap().steps < 3 {
            std::thread::yield_now();
        }
        assert!(reg.pause(JobId(1)));
        // at most the in-flight step can land after the pause
        let s1 = reg.rollup(JobId(1)).unwrap().steps;
        std::thread::sleep(std::time::Duration::from_millis(30));
        let s2 = reg.rollup(JobId(1)).unwrap().steps;
        assert!(s2 <= s1 + 1, "paused job kept stepping: {s1} -> {s2}");
        assert_eq!(reg.state(JobId(1)), Some(JobState::Paused));
        // resume makes progress again, stop terminates from paused too
        assert!(reg.resume(JobId(1)));
        while reg.rollup(JobId(1)).unwrap().steps <= s2 {
            std::thread::yield_now();
        }
        assert!(reg.pause(JobId(1)));
        assert!(reg.stop(JobId(1)));
        reg.join(JobId(1));
        assert_eq!(reg.state(JobId(1)), Some(JobState::Stopped));
        // lifecycle transitions were all announced
        let states: Vec<&'static str> = sink
            .for_job(JobId(1))
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::JobStateChanged { state } => Some(state),
                _ => None,
            })
            .collect();
        assert_eq!(states, vec!["paused", "running", "paused", "stopped"]);
    }

    #[test]
    fn invalid_transitions_are_rejected() {
        let reg = JobRegistry::new(Arc::new(crate::util::events::StderrSink));
        reg.spawn("quick", JobId(1), 1, |step| {
            Ok(StepMetrics { step, ..Default::default() })
        });
        reg.join(JobId(1));
        assert_eq!(reg.state(JobId(1)), Some(JobState::Finished));
        assert!(!reg.pause(JobId(1)), "cannot pause a finished job");
        assert!(!reg.stop(JobId(1)), "cannot stop a finished job");
        assert!(!reg.resume(JobId(42)), "unknown job");
    }
}
