//! Key-prefix isolation: one shared [`NvmeEngine`] presented to each
//! tenant as a private namespace.
//!
//! Every key a job reads or writes is rewritten to `j{N}.{key}` before
//! it reaches the shared engine, so two jobs initializing the same
//! model (identical key sets: `layers.0.wq/fp16`, `optim/sg0/m`, …)
//! can share one device without clobbering each other — and a job's
//! bytes are attributable on inspection.  The host job (`j0`) is NOT
//! rewritten: a solo run's on-SSD layout stays byte-identical to the
//! pre-tenancy stack, which is what the checkpoint/recovery tests pin.

use std::sync::Arc;

use crate::ssd::{IoSnapshot, JobId, NvmeEngine};

/// An [`NvmeEngine`] view that prefixes every key with its job's
/// namespace.  Pure delegation otherwise — stats, flush semantics, and
/// the disjoint-range `write_at` contract all pass through.
pub struct ScopedEngine {
    inner: Arc<dyn NvmeEngine>,
    job: JobId,
    prefix: String,
}

impl ScopedEngine {
    pub fn new(inner: Arc<dyn NvmeEngine>, job: JobId) -> Self {
        let prefix = if job == JobId::HOST {
            String::new()
        } else {
            format!("{job}.")
        };
        Self { inner, job, prefix }
    }

    pub fn job(&self) -> JobId {
        self.job
    }

    fn key(&self, key: &str) -> String {
        if self.prefix.is_empty() {
            key.to_string()
        } else {
            format!("{}{key}", self.prefix)
        }
    }
}

impl NvmeEngine for ScopedEngine {
    fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
        self.inner.write(&self.key(key), data)
    }

    fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
        self.inner.read(&self.key(key), out)
    }

    fn read_at(&self, key: &str, offset: usize, out: &mut [u8]) -> anyhow::Result<()> {
        self.inner.read_at(&self.key(key), offset, out)
    }

    fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
        self.inner.write_at(&self.key(key), offset, data)
    }

    fn flush(&self, key: &str) -> anyhow::Result<()> {
        self.inner.flush(&self.key(key))
    }

    fn reserve(&self, key: &str, len: usize) -> anyhow::Result<()> {
        self.inner.reserve(&self.key(key), len)
    }

    fn len_of(&self, key: &str) -> Option<usize> {
        self.inner.len_of(&self.key(key))
    }

    fn stats(&self) -> IoSnapshot {
        self.inner.stats()
    }

    fn label(&self) -> &'static str {
        "job-scoped"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::FsEngine;

    fn shared() -> Arc<dyn NvmeEngine> {
        let dir = std::env::temp_dir().join(format!("ma-scoped-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        Arc::new(FsEngine::new(&dir, 1, 1 << 20).unwrap())
    }

    #[test]
    fn same_key_different_jobs_never_collide() {
        let base = shared();
        let j1 = ScopedEngine::new(Arc::clone(&base), JobId(1));
        let j2 = ScopedEngine::new(Arc::clone(&base), JobId(2));
        j1.write("layers.0.wq/fp16", &[1u8; 64]).unwrap();
        j2.write("layers.0.wq/fp16", &[2u8; 64]).unwrap();
        let mut out = [0u8; 64];
        j1.read("layers.0.wq/fp16", &mut out).unwrap();
        assert!(out.iter().all(|&b| b == 1), "j1 saw j2's bytes");
        j2.read("layers.0.wq/fp16", &mut out).unwrap();
        assert!(out.iter().all(|&b| b == 2), "j2 saw j1's bytes");
        // the shared engine really holds both, under distinct keys
        assert_eq!(base.len_of("j1.layers.0.wq/fp16"), Some(64));
        assert_eq!(base.len_of("j2.layers.0.wq/fp16"), Some(64));
        assert_eq!(base.len_of("layers.0.wq/fp16"), None);
    }

    #[test]
    fn host_job_is_the_identity_prefix() {
        let base = shared();
        let host = ScopedEngine::new(Arc::clone(&base), JobId::HOST);
        host.write("probe", &[7u8; 8]).unwrap();
        assert_eq!(base.len_of("probe"), Some(8), "host keys must not be rewritten");
    }

    #[test]
    fn ranged_surface_passes_through() {
        let base = shared();
        let j = ScopedEngine::new(base, JobId(3));
        j.reserve("t", 16).unwrap();
        j.write_at("t", 4, &[9u8; 4]).unwrap();
        let mut out = [0u8; 4];
        j.read_at("t", 4, &mut out).unwrap();
        assert_eq!(out, [9u8; 4]);
        j.flush("t").unwrap();
        assert_eq!(j.len_of("t"), Some(16));
    }
}
