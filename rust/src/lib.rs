//! # MemAscend — system-memory-optimized SSD-offloaded LLM fine-tuning
//!
//! Reproduction of *MemAscend: System Memory Optimization for
//! SSD-Offloaded LLM Fine-Tuning* (Liaw & Chen, 2025) as a three-layer
//! Rust + JAX + Pallas stack: Pallas kernels (L1) and a staged JAX
//! transformer (L2) are AOT-lowered to HLO text at build time; the Rust
//! coordinator (L3) — this crate — owns the training runtime: the
//! ZeRO-Infinity-style offload engine, the four MemAscend
//! optimizations, the PJRT executor, and the full benchmark suite.
//!
//! See DESIGN.md for the system inventory and the experiment index
//! mapping every paper table/figure to a bench target.
//!
//! ## Checkpoint & resume: shadow-paged epochs
//!
//! Because the training state already lives on the SSD, a checkpoint
//! is a *barrier*, not a copy — and with shadow paging
//! ([`ckpt::ShadowEngine`]) it is also never an overwrite.  Every
//! checkpointed stream resolves to one of two physical extents; the
//! window after a commit writes the *other* extent, so the committed
//! epoch's bytes stay bit-intact no matter where the next window
//! crashes.  Every `--ckpt-interval` steps the trainer flushes the
//! shadow extents, persists the small host-resident tensors
//! (checksummed blobs) and RNG/scaler/step cursors, atomically
//! advances a dual-slot epoch journal ([`ckpt::Journal`]) whose record
//! carries the per-key extent map, and flips the routing.
//!
//! `memascend train --resume` (or [`train::Trainer::resume`]) walks
//! the journaled epochs newest-first and recovers the first that fully
//! verifies (key lengths at the journaled extents, resident-blob
//! checksums, layout digest), continuing bit-identically.  A torn
//! slot, bit-rot, or a crash at *any* phase — mid window, mid commit
//! flush, between slot write and flip, between epochs — lands on an
//! older intact epoch instead of an error; only configuration
//! mismatches (model/seed/dtype/coalesce mode) refuse.  Transient NVMe
//! faults are absorbed by a bounded-backoff retry layer with jittered
//! delays ([`ssd::RetryEngine`], `--io-retry`), metered in
//! `StepMetrics::io_retries`; a retry budget that runs dry surfaces
//! the typed [`ssd::RetryExhausted`] error and is metered separately.
//!
//! ## Architecture: shared substrate, per-job views
//!
//! The crate is layered so every scarce resource has exactly one owner
//! and everything above it holds a *view*:
//!
//! - **Host memory** — [`pinned::PinnedArena`]: one budget-enforced
//!   lease tier over the allocator policies of §III-B.  Tenancy view:
//!   [`pinned::PinnedArena::namespace`] — same arena, per-namespace
//!   quota + charged-byte attribution ([`pinned::NsStats`]), refusals
//!   surfacing as ordinary `BudgetExceeded`.
//! - **SSD** — [`ssd::NvmeEngine`] implementations (direct I/O, fs,
//!   retry, fault-injection) under the shadow-paging checkpoint layer.
//!   Tenancy view: [`jobs::ScopedEngine`] key-prefixes a job's streams
//!   onto the shared device.
//! - **I/O submission** — [`ssd::IoExecutor`]: the async queue all
//!   engines submit through, scheduled deficit-weighted-round-robin
//!   ([`ssd::DwrrQueue`]) with per-job lanes metered in
//!   [`ssd::IoSnapshot`].
//! - **Pipeline control** — each trainer's [`train::PipelineGovernor`]
//!   tunes its own windows; the [`jobs::FleetGovernor`] arbitrates
//!   *across* trainers with [`train::FleetCaps`] overlays and quota
//!   splits; the [`jobs::JobRegistry`] owns lifecycle + fault
//!   isolation.  Diagnostics flow through [`util::events`] tagged with
//!   a [`util::events::JobId`].
//!
//! A solo run is the degenerate case throughout: host namespace 0, no
//! quota, unit weight, host job id — bit-identical to the
//! pre-tenancy stack.
//!
//! ## Robustness layers: integrity, deadlines, device health
//!
//! Training state that lives on a commodity SSD for hours inherits the
//! device's failure modes, so the engine stack carries an end-to-end
//! robustness tier (opt-in, off by default — disabled it is
//! byte-identical to the plain stack):
//!
//! - **Checksummed streams** — [`ssd::IntegrityEngine`]
//!   (`--verify-reads`): per-256-KiB-block FNV-1a sums in a `sums/`
//!   sidecar, verified on every read.  A mismatch is the typed
//!   [`ssd::IntegrityError`], which the [`ssd::RetryEngine`] above
//!   treats like any transient fault: in-flight flips heal by re-read,
//!   durable rot exhausts the budget and aborts typed — training never
//!   consumes corrupt bytes.  An idle-time scrubber (`--scrub`) walks
//!   the checkpointed keys between steps, metered in
//!   `StepMetrics::scrubbed_bytes`.
//! - **Op deadlines and hedged reads** — every submission through the
//!   [`ssd::IoExecutor`] feeds a [`ssd::HealthTracker`] (service-
//!   latency EWMA/p99, error and timeout meters).  With
//!   `--io-deadline-ms` set, a blocked read that outlives
//!   [`ssd::HealthTracker::hedge_delay`] records a timeout and races a
//!   re-submission — first completion wins, stragglers stop stalling
//!   the pipeline.
//! - **Device-health quarantine** — sustained error/timeout bursts trip
//!   the tracker into a degraded state (emitting `DeviceDegraded`
//!   events); the [`train::PipelineGovernor`] and
//!   [`jobs::FleetGovernor`] treat a degraded device as backpressure
//!   and shrink in-flight windows until a clean streak recovers it.
//!
//! The decorator order is fixed:
//! `Shadow(Retry(Integrity(Faulty?(Scoped(base)))))` — integrity sits
//! below retry so mismatches are retryable, above the (test-only)
//! fault injector so injected corruption is caught, and above the job
//! scope so each tenant's sidecars ride its own key prefix; see
//! [`ssd`]'s module docs for the full contract.

pub mod accounting;
pub mod bufpool;
pub mod ckpt;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod dtype;
pub mod jobs;
pub mod metrics;
pub mod optimizer;
pub mod overflow;
pub mod pinned;
pub mod ssd;
pub mod tensors;
pub mod offload;
pub mod runtime;
pub mod train;
pub mod util;
