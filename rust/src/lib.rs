//! # MemAscend — system-memory-optimized SSD-offloaded LLM fine-tuning
//!
//! Reproduction of *MemAscend: System Memory Optimization for
//! SSD-Offloaded LLM Fine-Tuning* (Liaw & Chen, 2025) as a three-layer
//! Rust + JAX + Pallas stack: Pallas kernels (L1) and a staged JAX
//! transformer (L2) are AOT-lowered to HLO text at build time; the Rust
//! coordinator (L3) — this crate — owns the training runtime: the
//! ZeRO-Infinity-style offload engine, the four MemAscend
//! optimizations, the PJRT executor, and the full benchmark suite.
//!
//! See DESIGN.md for the system inventory and the experiment index
//! mapping every paper table/figure to a bench target.
//!
//! ## Checkpoint & resume
//!
//! Because the training state already lives on the SSD, a checkpoint
//! is a *barrier*, not a copy: every `--ckpt-interval` steps the
//! trainer flushes the state/fp16 keys the tiled write-back has been
//! updating in place, persists the small host-resident tensors and
//! RNG/scaler/step cursors, and atomically advances a dual-slot epoch
//! journal ([`ckpt::Journal`]).  `memascend train --resume` (or
//! [`train::Trainer::resume`]) replays the newest valid epoch and
//! continues bit-identically; a torn commit rolls back to the previous
//! epoch, and state dirtied after the last commit is a structured
//! error, never silent divergence.  Transient NVMe faults are absorbed
//! by a bounded-backoff retry layer ([`ssd::RetryEngine`],
//! `--io-retry`), metered in `StepMetrics::io_retries`.

pub mod accounting;
pub mod bufpool;
pub mod ckpt;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod dtype;
pub mod metrics;
pub mod optimizer;
pub mod overflow;
pub mod pinned;
pub mod ssd;
pub mod tensors;
pub mod offload;
pub mod runtime;
pub mod train;
pub mod util;
