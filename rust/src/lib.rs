//! # MemAscend — system-memory-optimized SSD-offloaded LLM fine-tuning
//!
//! Reproduction of *MemAscend: System Memory Optimization for
//! SSD-Offloaded LLM Fine-Tuning* (Liaw & Chen, 2025) as a three-layer
//! Rust + JAX + Pallas stack: Pallas kernels (L1) and a staged JAX
//! transformer (L2) are AOT-lowered to HLO text at build time; the Rust
//! coordinator (L3) — this crate — owns the training runtime: the
//! ZeRO-Infinity-style offload engine, the four MemAscend
//! optimizations, the PJRT executor, and the full benchmark suite.
//!
//! See DESIGN.md for the system inventory and the experiment index
//! mapping every paper table/figure to a bench target.
//!
//! ## Checkpoint & resume: shadow-paged epochs
//!
//! Because the training state already lives on the SSD, a checkpoint
//! is a *barrier*, not a copy — and with shadow paging
//! ([`ckpt::ShadowEngine`]) it is also never an overwrite.  Every
//! checkpointed stream resolves to one of two physical extents; the
//! window after a commit writes the *other* extent, so the committed
//! epoch's bytes stay bit-intact no matter where the next window
//! crashes.  Every `--ckpt-interval` steps the trainer flushes the
//! shadow extents, persists the small host-resident tensors
//! (checksummed blobs) and RNG/scaler/step cursors, atomically
//! advances a dual-slot epoch journal ([`ckpt::Journal`]) whose record
//! carries the per-key extent map, and flips the routing.
//!
//! `memascend train --resume` (or [`train::Trainer::resume`]) walks
//! the journaled epochs newest-first and recovers the first that fully
//! verifies (key lengths at the journaled extents, resident-blob
//! checksums, layout digest), continuing bit-identically.  A torn
//! slot, bit-rot, or a crash at *any* phase — mid window, mid commit
//! flush, between slot write and flip, between epochs — lands on an
//! older intact epoch instead of an error; only configuration
//! mismatches (model/seed/dtype/coalesce mode) refuse.  Transient NVMe
//! faults are absorbed by a bounded-backoff retry layer with jittered
//! delays ([`ssd::RetryEngine`], `--io-retry`), metered in
//! `StepMetrics::io_retries`; a retry budget that runs dry surfaces
//! the typed [`ssd::RetryExhausted`] error and is metered separately.

pub mod accounting;
pub mod bufpool;
pub mod ckpt;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod dtype;
pub mod metrics;
pub mod optimizer;
pub mod overflow;
pub mod pinned;
pub mod ssd;
pub mod tensors;
pub mod offload;
pub mod runtime;
pub mod train;
pub mod util;
