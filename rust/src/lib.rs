//! # MemAscend — system-memory-optimized SSD-offloaded LLM fine-tuning
//!
//! Reproduction of *MemAscend: System Memory Optimization for
//! SSD-Offloaded LLM Fine-Tuning* (Liaw & Chen, 2025) as a three-layer
//! Rust + JAX + Pallas stack: Pallas kernels (L1) and a staged JAX
//! transformer (L2) are AOT-lowered to HLO text at build time; the Rust
//! coordinator (L3) — this crate — owns the training runtime: the
//! ZeRO-Infinity-style offload engine, the four MemAscend
//! optimizations, the PJRT executor, and the full benchmark suite.
//!
//! See DESIGN.md for the system inventory and the experiment index
//! mapping every paper table/figure to a bench target.

pub mod accounting;
pub mod bufpool;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod dtype;
pub mod metrics;
pub mod optimizer;
pub mod overflow;
pub mod pinned;
pub mod ssd;
pub mod tensors;
pub mod offload;
pub mod runtime;
pub mod train;
pub mod util;
