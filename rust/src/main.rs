//! memascend — leader entrypoint.
//!
//! `memascend <command> [flags]`; `memascend help` lists commands.

fn main() {
    memascend::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help").to_string();
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    if let Err(e) = memascend::coordinator::dispatch(&cmd, rest) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
