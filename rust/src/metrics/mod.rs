//! Run metrics: loss curves, throughput, and structured result dumps.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::json::Json;

/// Shared counter of fp32 bytes staged through *owned heap buffers* on
/// the way to the PJRT boundary — the copy chain the lease-backed
/// [`crate::runtime::TensorBuf`] views exist to eliminate.
///
/// Producers (the swapper's upconvert, the activation stores' fetch
/// decode, any `.to_vec()` staging) charge it whenever a tensor is
/// staged outside a pinned lease; the trainer snapshots it per step
/// into [`StepMetrics::host_copy_bytes`].  Cloning shares the counter,
/// so one meter can span the swapper, the spill store, and the trainer.
#[derive(Clone, Debug, Default)]
pub struct HostCopyMeter(Arc<AtomicU64>);

impl HostCopyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `bytes` of heap-staged tensor data.
    pub fn add(&self, bytes: usize) {
        self.0.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Monotone total since construction.
    pub fn bytes(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Append-oriented CSV logger (loss curves, sweep outputs).
pub struct CsvLog {
    file: std::fs::File,
    cols: usize,
}

impl CsvLog {
    pub fn create(path: &str, headers: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", headers.join(","))?;
        Ok(Self { file, cols: headers.len() })
    }

    pub fn row(&mut self, values: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(values.len() == self.cols, "column count mismatch");
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> anyhow::Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Per-step training record.
#[derive(Debug, Clone, Default)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f64,
    pub loss_scale: f64,
    pub overflowed: bool,
    pub tokens: usize,
    pub step_secs: f64,
    /// Step-time decomposition for the perf model calibration.
    pub compute_secs: f64,
    pub io_secs: f64,
    pub overflow_check_secs: f64,
    pub optim_secs: f64,
    /// Seconds the compute thread actually stalled on I/O completions
    /// (swapper `next()`, activation-spill fetches, and optimizer
    /// fetch/write-back waits). The gap to `io_secs` is transfer time
    /// hidden behind compute.
    pub io_wait_secs: f64,
    /// Optimizer-state tiles streamed by the staged-tile pipeline this
    /// step (0 when the whole-group or sequential path ran).
    pub optim_tiles: u64,
    /// Tiles the staged pipeline degraded to the synchronous unpinned
    /// path under budget pressure this step.  Non-zero is the
    /// governor's primary shrink signal: the pinned budget is too
    /// tight for the current tile window.
    pub degraded_tiles: u64,
    /// NVMe submissions (read + write calls) issued this step — the
    /// counter the optimizer's group-coalescing pass drives down:
    /// same bytes, far fewer per-tensor submissions.
    pub nvme_submissions: u64,
    /// Optimizer tile size actually used this step (the governed
    /// value; equals `TrainSpec::optim_tile_bytes` with the governor
    /// off).
    pub optim_tile_bytes: usize,
    /// Tile-pipeline depth actually used this step (fetch and
    /// write-back generations in flight).
    pub tile_depth: usize,
    /// Swapper prefetch window actually used this step.
    pub prefetch_depth: usize,
    /// fp32 bytes staged through owned heap buffers at the PJRT
    /// boundary this step (see [`HostCopyMeter`]).  0 means every
    /// weight/activation argument uploaded straight from pinned lease
    /// memory — the zero-copy invariant `bench_runtime` gates on; a
    /// non-zero count means the arena budget forced owned-vector
    /// degradation somewhere.
    pub host_copy_bytes: u64,
    /// Seconds spent committing a checkpoint epoch after this step
    /// (flush barriers + resident persistence + journal commit).
    /// Accounted separately from `io_wait_secs`: checkpoint flushes
    /// are a durability tax, not pipeline stall, and must not skew
    /// the overlap metrics.  0 on steps with no checkpoint.
    pub ckpt_secs: f64,
    /// Transient-fault I/O retries absorbed by the retry layer during
    /// this step (delta of `IoSnapshot::retries`).  0 without a
    /// `RetryEngine` or on a fault-free step.
    pub io_retries: u64,
    /// Newest checkpoint epoch committed on this storage when the step
    /// finished (after a checkpointed step, the epoch that step was
    /// committed as).  0 = no commit yet; numbering continues across
    /// resumes and storage reuse, so epochs are monotone per storage
    /// root, not per process.
    pub journal_epoch: u64,
    /// Weight-fetch submissions the swapper issued this step (forward
    /// + backward).  With coalesced fetch groups one ranged read
    /// covers a whole super-group of tensors, so this is the counter
    /// `bench_prefetch` gates its ≥2× submission cut on.
    pub fetch_submissions: u64,
    /// Fetch units already upconverted when compute asked for them
    /// this step (`SwapMetrics::prefetch_hits`, forward + backward).
    pub prefetch_hits: u64,
    /// Fetch units compute had to block on this step
    /// (`SwapMetrics::prefetch_late`) — fed to the governor, which
    /// answers by growing the replay schedule's lead-time.
    pub prefetch_late: u64,
    /// Swapper passes this step that wanted to replay a recorded
    /// profile but fell back to the depth-window schedule (plan digest
    /// mismatch after a plan change or profile loss).  Structured
    /// fallback signal, not an error: the pass re-records.
    pub prefetch_fallbacks: u64,
    /// Hedged backup reads the async layer fired this step (delta of
    /// `HealthTracker::hedges`).  0 with `io_deadline_ms` off or when
    /// every primary read beat its deadline.
    pub io_hedges: u64,
    /// Primary reads that blew their per-op deadline this step (delta
    /// of `HealthTracker::timeouts`); every timeout also fires a hedge.
    pub io_timeouts: u64,
    /// Checksum mismatches the integrity layer detected this step
    /// (delta of `IoSnapshot::integrity_failures`).  Transient
    /// corruption heals through the retry layer and still counts here.
    pub integrity_failures: u64,
    /// Bytes re-read and re-verified by the idle-time scrub walk after
    /// this step (delta of `IoSnapshot::scrubbed_bytes`).  0 with
    /// `--scrub` off.
    pub scrubbed_bytes: u64,
    /// Scrub passes whose re-verify found durable rot this step (delta
    /// of `IoSnapshot::scrub_failures`).
    pub scrub_failures: u64,
}

impl StepMetrics {
    /// Engine-busy I/O time that the async pipeline hid behind
    /// compute: `io_secs - io_wait_secs` (clamped at 0).
    ///
    /// `io_secs` is the engine's union-of-busy-intervals time
    /// (`IoSnapshot::busy_ns`), so concurrent transfers are counted
    /// once and the overlap metric is exact — overlapping I/O can
    /// never be mistaken for compute overlap.
    pub fn io_overlap_secs(&self) -> f64 {
        (self.io_secs - self.io_wait_secs).max(0.0)
    }

    /// Fraction of engine I/O time hidden behind compute (0 when the
    /// step did no I/O).
    pub fn io_overlap_frac(&self) -> f64 {
        if self.io_secs <= 0.0 {
            return 0.0;
        }
        self.io_overlap_secs() / self.io_secs
    }
}

/// Whole-run summary, dumped as JSON for EXPERIMENTS.md.
#[derive(Debug, Default, Clone)]
pub struct RunReport {
    pub label: String,
    pub model: String,
    pub steps: Vec<StepMetrics>,
    pub peak_sysmem_bytes: u64,
    pub io_bytes_per_step: u64,
}

impl RunReport {
    pub fn tokens_per_sec(&self) -> f64 {
        let toks: usize = self.steps.iter().map(|s| s.tokens).sum();
        let secs: f64 = self.steps.iter().map(|s| s.step_secs).sum();
        if secs == 0.0 {
            0.0
        } else {
            toks as f64 / secs
        }
    }

    pub fn final_loss(&self) -> f64 {
        self.steps.last().map(|s| s.loss).unwrap_or(f64::NAN)
    }

    /// Mean loss over the last k effective (non-overflow) steps.
    pub fn mean_tail_loss(&self, k: usize) -> f64 {
        let tail: Vec<f64> = self
            .steps
            .iter()
            .rev()
            .filter(|s| !s.overflowed)
            .take(k)
            .map(|s| s.loss)
            .collect();
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::from(self.label.clone())),
            ("model", Json::from(self.model.clone())),
            ("steps", Json::from(self.steps.len())),
            ("final_loss", Json::from(self.final_loss())),
            ("tokens_per_sec", Json::from(self.tokens_per_sec())),
            ("peak_sysmem_bytes", Json::from(self.peak_sysmem_bytes)),
            ("io_bytes_per_step", Json::from(self.io_bytes_per_step)),
            (
                "loss_curve",
                Json::Arr(self.steps.iter().map(|s| Json::from(s.loss)).collect()),
            ),
        ])
    }

    pub fn write_json(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn write_loss_csv(&self, path: &str) -> anyhow::Result<()> {
        let mut log = CsvLog::create(
            path,
            &["step", "loss", "loss_scale", "overflowed", "step_secs"],
        )?;
        for s in &self.steps {
            log.row(&[
                s.step.to_string(),
                format!("{}", s.loss),
                format!("{}", s.loss_scale),
                u8::from(s.overflowed).to_string(),
                format!("{}", s.step_secs),
            ])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(i: u64, loss: f64) -> StepMetrics {
        StepMetrics {
            step: i,
            loss,
            loss_scale: 1024.0,
            overflowed: false,
            tokens: 128,
            step_secs: 0.5,
            compute_secs: 0.3,
            io_secs: 0.1,
            overflow_check_secs: 0.05,
            optim_secs: 0.05,
            io_wait_secs: 0.04,
            optim_tiles: 0,
            degraded_tiles: 0,
            nvme_submissions: 0,
            optim_tile_bytes: 0,
            tile_depth: 0,
            prefetch_depth: 0,
            host_copy_bytes: 0,
            ckpt_secs: 0.0,
            io_retries: 0,
            journal_epoch: 0,
            fetch_submissions: 0,
            prefetch_hits: 0,
            prefetch_late: 0,
            prefetch_fallbacks: 0,
            io_hedges: 0,
            io_timeouts: 0,
            integrity_failures: 0,
            scrubbed_bytes: 0,
            scrub_failures: 0,
        }
    }

    #[test]
    fn host_copy_meter_is_shared_by_clones() {
        let m = HostCopyMeter::new();
        let m2 = m.clone();
        m.add(100);
        m2.add(28);
        assert_eq!(m.bytes(), 128);
        assert_eq!(m2.bytes(), 128);
    }

    #[test]
    fn overlap_accounting() {
        let s = step(1, 1.0);
        assert!((s.io_overlap_secs() - 0.06).abs() < 1e-12);
        assert!((s.io_overlap_frac() - 0.6).abs() < 1e-9);
        let idle = StepMetrics { io_secs: 0.0, io_wait_secs: 0.0, ..step(1, 1.0) };
        assert_eq!(idle.io_overlap_frac(), 0.0);
    }

    #[test]
    fn throughput_math() {
        let mut r = RunReport { label: "t".into(), ..Default::default() };
        r.steps = vec![step(1, 5.0), step(2, 4.0)];
        assert!((r.tokens_per_sec() - 256.0).abs() < 1e-9);
        assert_eq!(r.final_loss(), 4.0);
        assert!((r.mean_tail_loss(2) - 4.5).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let mut r = RunReport { label: "x".into(), model: "smoke".into(), ..Default::default() };
        r.steps = vec![step(1, 3.0)];
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("model").unwrap().as_str(), Some("smoke"));
        assert_eq!(j.get("loss_curve").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn csv_log_writes() {
        let p = std::env::temp_dir().join(format!("ma-csv-{}.csv", std::process::id()));
        let mut log = CsvLog::create(p.to_str().unwrap(), &["a", "b"]).unwrap();
        log.rowf(&[1.0, 2.0]).unwrap();
        assert!(log.row(&["only-one".into()]).is_err());
        drop(log);
        let content = std::fs::read_to_string(&p).unwrap();
        assert!(content.starts_with("a,b\n1,2\n"));
        std::fs::remove_file(&p).ok();
    }
}
