//! Offloaded activation-checkpoint store (§V-B, Eq. 1).
//!
//! With gradient checkpointing, each transformer layer's *input* hidden
//! state is the checkpoint; offloaded-GC moves it from GPU to pinned
//! host memory (fp16) right after the layer runs and fetches it back
//! just in time for recomputation in the backward pass.  Total host
//! bytes = Ng·B·C·L·H·2 + pinned overhead — exactly Eq. 1, and exactly
//! what limits context length once system memory is the bottleneck.
//! Slots are [`PinnedArena`] leases under `Cat::ActCkpt`, so they show
//! up on the shared ledger and inside the global budget; see
//! [`super::spill::SpillingActivationStore`] for the budget-capped
//! variant that spills past-budget checkpoints to the SSD.

use crate::dtype::{f16_bytes_to_f32s, f32s_to_f16_bytes};
use crate::pinned::{Cat, Lease, PinnedArena};

/// Host-side checkpoint slots for one rank's L layers.
pub struct ActivationStore {
    slots: Vec<Lease>,
    elems_per_slot: usize,
    /// Which slots currently hold a checkpoint (fwd sets, bwd takes).
    occupied: Vec<bool>,
}

impl ActivationStore {
    /// `elems` = B × C × H per checkpoint; one slot per layer.
    pub fn new(layers: usize, elems: usize, arena: &PinnedArena) -> anyhow::Result<Self> {
        let slots = (0..layers)
            .map(|_| arena.lease(elems * 2, Cat::ActCkpt).map_err(Into::into))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self { slots, elems_per_slot: elems, occupied: vec![false; layers] })
    }

    /// Offload a checkpoint (f32 "GPU" tensor -> fp16 pinned host slot).
    pub fn offload(&mut self, layer: usize, h: &[f32]) {
        assert_eq!(h.len(), self.elems_per_slot);
        assert!(!self.occupied[layer], "layer {layer} checkpoint overwritten");
        f32s_to_f16_bytes(h, self.slots[layer].as_mut_slice());
        self.occupied[layer] = true;
    }

    /// Fetch a checkpoint back for recomputation (host fp16 -> f32).
    pub fn fetch(&mut self, layer: usize) -> Vec<f32> {
        let mut out = vec![0f32; self.elems_per_slot];
        self.fetch_into(layer, &mut out);
        out
    }

    /// [`Self::fetch`] decoding into a caller-provided destination —
    /// typically a pinned lease's f32 view, so the recomputation
    /// argument is staged once, in upload-ready memory, with no owned
    /// intermediate (the zero-copy boundary's consumption pattern; see
    /// [`super::spill::SpillingActivationStore::fetch`] for the
    /// budget-elastic store the trainer uses).
    pub fn fetch_into(&mut self, layer: usize, out: &mut [f32]) {
        assert!(self.occupied[layer], "layer {layer} checkpoint missing");
        assert_eq!(out.len(), self.elems_per_slot);
        f16_bytes_to_f32s(self.slots[layer].as_slice(), out);
        self.occupied[layer] = false;
    }

    pub fn host_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.bytes_padded()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::pinned::{
        AlignedAllocator, ArenaConfig, CachingAllocator, MemoryTracker, Mode,
        PinnedArena,
    };
    use std::sync::Arc;

    #[test]
    fn offload_fetch_roundtrip() {
        let mut store = ActivationStore::new(4, 256, &test_arena(Mode::Real)).unwrap();
        let h: Vec<f32> = (0..256).map(|i| (i as f32) / 16.0).collect();
        store.offload(2, &h);
        let back = store.fetch(2);
        // all values here are f16-exact
        assert_eq!(back, h);
    }

    #[test]
    fn fetch_into_decodes_into_a_lease_view() {
        // the zero-copy consumption pattern: decode straight into a
        // pinned lease, freeze, upload the view
        let arena = test_arena(Mode::Real);
        let mut store = ActivationStore::new(2, 128, &arena).unwrap();
        let h: Vec<f32> = (0..128).map(|i| i as f32).collect();
        store.offload(1, &h);
        let mut dst = arena.lease(128 * 4, crate::pinned::Cat::SwapBuf).unwrap();
        store.fetch_into(1, dst.as_f32_mut());
        let view = crate::runtime::TensorBuf::from_lease(dst).unwrap();
        assert_eq!(view.as_f32(), h.as_slice());
    }

    #[test]
    #[should_panic(expected = "checkpoint missing")]
    fn double_fetch_panics() {
        let mut store = ActivationStore::new(2, 16, &test_arena(Mode::Real)).unwrap();
        store.offload(0, &[0.0; 16]);
        store.fetch(0);
        store.fetch(0);
    }

    #[test]
    fn eq1_accounting_difference_between_allocators() {
        // Eq. 1's P_m term: pow2 rounding on non-pow2 checkpoint sizes
        let elems = 5000; // 10'000 B -> pow2 16384
        let tr1 = Arc::new(MemoryTracker::new());
        let a1 = PinnedArena::new(
            Arc::new(CachingAllocator::new(Mode::Virtual, tr1.clone())),
            ArenaConfig::default(),
        );
        let _s1 = ActivationStore::new(8, elems, &a1).unwrap();
        let tr2 = Arc::new(MemoryTracker::new());
        let a2 = PinnedArena::new(
            Arc::new(AlignedAllocator::new(Mode::Virtual, tr2.clone())),
            ArenaConfig::default(),
        );
        let _s2 = ActivationStore::new(8, elems, &a2).unwrap();
        assert!(tr1.peak_total() > tr2.peak_total());
        // the arena pads each slot to the page, charged under ActCkpt
        assert_eq!(tr2.current(Cat::ActCkpt), 8 * 12_288);
    }
}
