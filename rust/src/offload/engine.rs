//! Component assembly: `MemAscendFlags` → concrete allocator policy,
//! pinned arena, pool, NVMe engine, and overflow checker.
//!
//! This is the ablation axis: every flag combination yields a working
//! engine, so benches can toggle one optimization at a time (DESIGN.md
//! §ablations) and the trainer can run as pure ZeRO-Infinity, pure
//! MemAscend, or anything between.  All host memory flows through one
//! [`PinnedArena`] built over the flag-selected allocator policy —
//! `TrainSpec::pinned_budget_bytes` makes its budget a run-level knob.

use std::path::Path;
use std::sync::Arc;

use crate::bufpool::{AdaptivePool, MonolithicPool, ParamBufferPool};
use crate::config::{ModelSpec, TrainSpec};
use crate::metrics::HostCopyMeter;
use crate::overflow::{baseline_overflow_check, fused_overflow_check, Checker};
use crate::pinned::{
    AlignedAllocator, ArenaConfig, CachingAllocator, HostAllocator, MemoryTracker,
    Mode, PinnedArena,
};
use crate::ckpt::ShadowEngine;
use crate::jobs::ScopedEngine;
use crate::ssd::{
    AsyncEngine, DirectEngine, FaultyEngine, FsEngine, IntegrityEngine, IoExecutor,
    JobId, NvmeEngine, OpMask, RetryEngine, RetryPolicy,
};
use crate::util::stage::StageExecutor;

/// Fault-injection mode for a tenant's engine view ([`OffloadEngine::
/// job_view`]): probabilistic faults sit *below* the retry layer (they
/// are absorbed like real transient faults), persistent ones exhaust
/// it and abort only that job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobFault {
    Probabilistic { per_1024: u64, seed: u64 },
    Persistent,
}

pub struct OffloadEngine {
    pub tracker: Arc<MemoryTracker>,
    /// The one lease tier every host-memory consumer allocates from.
    pub arena: Arc<PinnedArena>,
    pub pool: Arc<dyn ParamBufferPool>,
    pub nvme: Arc<dyn NvmeEngine>,
    /// Typed handle on the shadow-paging layer `nvme` points at: the
    /// trainer registers/advances/flips the per-key extent map here
    /// while every I/O consumer keeps reading logical keys through
    /// `nvme`.
    pub shadow: Arc<ShadowEngine>,
    /// The raw storage engine (pre-retry, pre-shadow) — the substrate
    /// tenant views stack their own retry/fault/shadow layers over.
    pub base: Arc<dyn NvmeEngine>,
    /// Typed handle on this view's checksum layer when
    /// `TrainSpec::verify_reads` is on (`None` otherwise): the trainer
    /// drives the between-steps scrubber and reads the meters here
    /// while every I/O consumer keeps going through `nvme`.
    pub integrity: Option<Arc<IntegrityEngine>>,
    /// Which tenant this engine (view) belongs to.  `JobId::HOST` for
    /// the root engine built by [`Self::new`]/[`Self::new_shared`].
    pub job: JobId,
    /// Per-op deadline from `TrainSpec::io_deadline_ms` (`None` = 0 =
    /// off); [`Self::async_io`] arms hedged reads with it.
    pub deadline: Option<std::time::Duration>,
    /// Shared async submission queue: swapper fetch window, activation
    /// spill, and the optimizer swap ride this one executor (the
    /// engines keep their own per-device queues underneath).
    pub ioq: Arc<IoExecutor>,
    /// Compute-side stage pool: f16↔f32 conversions of the swapper and
    /// the tiled optimizer run here, never on the NVMe queue workers.
    pub stage: Arc<StageExecutor>,
    pub checker: Checker,
    pub threads: usize,
    /// Engine-wide boundary copy counter: every component that stages
    /// fp32 tensors in owned heap memory on the way to PJRT (swapper
    /// fallback, spill-store fallback) charges this one meter, so the
    /// trainer's per-step `host_copy_bytes` covers the whole engine.
    pub copy_meter: HostCopyMeter,
}

impl OffloadEngine {
    /// Build a real (byte-moving) engine rooted at `storage_dir`.
    pub fn new(
        spec: &ModelSpec,
        train: &TrainSpec,
        storage_dir: &Path,
    ) -> anyhow::Result<Self> {
        Self::new_shared(spec, train, storage_dir, 1)
    }

    /// [`Self::new`] scaled for `tenants` co-resident jobs: device
    /// capacity multiplies so every tenant's key-prefixed streams fit,
    /// while arena budget stays as configured (tenancy *shares* the
    /// pinned budget — that is the point).
    pub fn new_shared(
        spec: &ModelSpec,
        train: &TrainSpec,
        storage_dir: &Path,
        tenants: usize,
    ) -> anyhow::Result<Self> {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc: Arc<dyn HostAllocator> = if train.flags.alignment_free {
            Arc::new(AlignedAllocator::new(Mode::Real, tracker.clone()))
        } else {
            Arc::new(CachingAllocator::new(Mode::Real, tracker.clone()))
        };
        let arena = PinnedArena::new(
            alloc,
            ArenaConfig {
                budget_bytes: train.pinned_budget_bytes,
                ..Default::default()
            },
        );
        let dtype = train.precision.compute_dtype();
        let pool: Arc<dyn ParamBufferPool> = if train.flags.adaptive_pool {
            Arc::new(AdaptivePool::new(spec, train.prefetch_depth, dtype, &arena)?)
        } else {
            Arc::new(MonolithicPool::new(spec, train.prefetch_depth, dtype, &arena)?)
        };
        // capacity: fp16 + fp32 master + m + v + slack, per device —
        // doubled, because shadow paging keeps two physical extents
        // per checkpointed stream (epoch N plus the N+1 shadow)
        let cap_bytes = ((spec.param_count() as u64)
            .saturating_mul(32)
            .max(1 << 24)
            + (128 << 20))
            .saturating_mul(tenants.max(1) as u64);
        let devices = 2;
        let base: Arc<dyn NvmeEngine> = if train.flags.direct_nvme {
            Arc::new(DirectEngine::new(
                &storage_dir.join("direct"),
                devices,
                cap_bytes / devices as u64,
                1,
            )?)
        } else {
            Arc::new(FsEngine::with_fd_cache(
                &storage_dir.join("fs"),
                devices,
                512 << 10,
                train.fs_cached_fds,
            )?)
        };
        // checksums sit directly above the storage engine so anything
        // the device (or an injected fault) corrupts is caught on read
        let integrity = if train.verify_reads {
            Some(Arc::new(IntegrityEngine::new(base.clone())))
        } else {
            None
        };
        let verified: Arc<dyn NvmeEngine> = match &integrity {
            Some(i) => i.clone(),
            None => base.clone(),
        };
        // transient-fault retry sits above the checksum layer and below
        // the async queue, so queued submit closures and synchronous
        // calls retry identically (label passes through) and a checksum
        // mismatch is retried as a re-read before it aborts anything
        let nvme: Arc<dyn NvmeEngine> = if train.io_retry_attempts > 1 {
            Arc::new(RetryEngine::new(
                verified,
                RetryPolicy::attempts(train.io_retry_attempts as u32),
            ))
        } else {
            verified
        };
        // shadow paging tops the stack: logical checkpoint keys route
        // to per-epoch physical extents; everything unregistered
        // passes through (label/stats delegate)
        let shadow = Arc::new(ShadowEngine::new(nvme));
        let nvme: Arc<dyn NvmeEngine> = shadow.clone();
        let checker = if train.flags.fused_overflow {
            Checker::Fused
        } else {
            Checker::Baseline
        };
        let ioq = Arc::new(IoExecutor::new(train.io_workers.max(1)));
        let threads = crate::util::par::default_threads();
        let stage = Arc::new(StageExecutor::new((threads / 2).clamp(1, 4)));
        Ok(Self {
            tracker,
            arena,
            pool,
            nvme,
            shadow,
            base,
            integrity,
            job: JobId::HOST,
            deadline: (train.io_deadline_ms > 0)
                .then(|| std::time::Duration::from_millis(train.io_deadline_ms)),
            ioq,
            stage,
            checker,
            threads,
            copy_meter: HostCopyMeter::new(),
        })
    }

    /// A tenant's view of this engine: same tracker, submission queue,
    /// stage pool, and raw storage — but a namespaced arena (quota'd
    /// leases, attributed bytes), its own buffer pool leased from that
    /// namespace, a key-prefixed [`ScopedEngine`] over the shared
    /// device with optional per-job fault injection, and a private
    /// shadow-paging layer (each job checkpoints independently).
    ///
    /// Layer order per job:
    /// `Shadow(Retry?(Integrity?(Faulty?(Scoped(base)))))` — retry
    /// sits *above* injection so probabilistic faults are absorbed
    /// exactly like real transient faults, while persistent ones
    /// exhaust the budget and abort only this job; the checksum layer
    /// (`TrainSpec::verify_reads`) sits above injection too, so
    /// injected bit-flips are caught, and above the key scoping, so
    /// each tenant's `sums/` sidecars ride its own prefix.
    pub fn job_view(
        &self,
        spec: &ModelSpec,
        train: &TrainSpec,
        job: JobId,
        fault: Option<JobFault>,
    ) -> anyhow::Result<OffloadEngine> {
        let arena = self.arena.namespace(job.lane() as u32);
        let dtype = train.precision.compute_dtype();
        let pool: Arc<dyn ParamBufferPool> = if train.flags.adaptive_pool {
            Arc::new(AdaptivePool::new(spec, train.prefetch_depth, dtype, &arena)?)
        } else {
            Arc::new(MonolithicPool::new(spec, train.prefetch_depth, dtype, &arena)?)
        };
        let scoped: Arc<dyn NvmeEngine> =
            Arc::new(ScopedEngine::new(self.base.clone(), job));
        let faulted: Arc<dyn NvmeEngine> = match fault {
            None => scoped,
            Some(JobFault::Probabilistic { per_1024, seed }) => {
                Arc::new(FaultyEngine::new(scoped, per_1024, seed))
            }
            Some(JobFault::Persistent) => {
                Arc::new(FaultyEngine::transient(scoped, u32::MAX, OpMask::DATA))
            }
        };
        let integrity = if train.verify_reads {
            Some(Arc::new(IntegrityEngine::new(faulted.clone()).for_job(job)))
        } else {
            None
        };
        let verified: Arc<dyn NvmeEngine> = match &integrity {
            Some(i) => i.clone(),
            None => faulted,
        };
        let retried: Arc<dyn NvmeEngine> = if train.io_retry_attempts > 1 {
            Arc::new(
                RetryEngine::new(
                    verified,
                    RetryPolicy::attempts(train.io_retry_attempts as u32),
                )
                .for_job(job),
            )
        } else {
            verified
        };
        let shadow = Arc::new(ShadowEngine::new(retried));
        let nvme: Arc<dyn NvmeEngine> = shadow.clone();
        Ok(OffloadEngine {
            tracker: self.tracker.clone(),
            arena,
            pool,
            nvme,
            shadow,
            base: self.base.clone(),
            integrity,
            job,
            deadline: (train.io_deadline_ms > 0)
                .then(|| std::time::Duration::from_millis(train.io_deadline_ms)),
            ioq: self.ioq.clone(),
            stage: self.stage.clone(),
            checker: self.checker,
            threads: self.threads,
            copy_meter: HostCopyMeter::new(),
        })
    }

    /// Async surface over the configured NVMe engine, sharing the
    /// engine-wide submission queue.  Submissions carry this engine
    /// view's job id into the weighted-fair scheduler; a configured
    /// `TrainSpec::io_deadline_ms` arms hedged reads.
    pub fn async_io(&self) -> AsyncEngine {
        AsyncEngine::with_executor(self.nvme.clone(), self.ioq.clone())
            .for_job(self.job)
            .with_deadline(self.deadline)
    }

    /// Run the configured overflow check over a flat fp32 buffer.
    pub fn check_overflow(&self, grads: &[f32]) -> bool {
        match self.checker {
            Checker::Fused => fused_overflow_check(grads, self.threads),
            Checker::Baseline => baseline_overflow_check(grads, &self.tracker),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::SMOKE;
    use crate::config::MemAscendFlags;

    fn storage(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ma-eng-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn all_sixteen_combinations_construct_and_roundtrip() {
        for (i, flags) in MemAscendFlags::all_combinations().into_iter().enumerate() {
            let train = TrainSpec { flags, ..Default::default() };
            let dir = storage(&format!("c{i}"));
            let eng = OffloadEngine::new(&SMOKE, &train, &dir).unwrap();
            eng.nvme.write("probe", &[1, 2, 3, 4]).unwrap();
            let mut out = [0u8; 4];
            eng.nvme.read("probe", &mut out).unwrap();
            assert_eq!(out, [1, 2, 3, 4]);
            assert!(!eng.check_overflow(&[0.0, 1.0]));
            assert!(eng.check_overflow(&[f32::NAN]));
            // the pool's bytes are arena-leased, on the shared ledger
            assert_eq!(
                eng.arena.stats().requested_bytes,
                eng.pool.stats().pool_bytes
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn labels_reflect_flags() {
        let d = storage("lbl");
        let zi = OffloadEngine::new(
            &SMOKE,
            &TrainSpec { flags: MemAscendFlags::baseline(), ..Default::default() },
            &d,
        )
        .unwrap();
        assert_eq!(zi.pool.label(), "monolithic");
        assert_eq!(zi.nvme.label(), "fs-raid0");
        let ma = OffloadEngine::new(
            &SMOKE,
            &TrainSpec { flags: MemAscendFlags::memascend(), ..Default::default() },
            &d,
        )
        .unwrap();
        assert_eq!(ma.pool.label(), "adaptive");
        assert_eq!(ma.nvme.label(), "direct-nvme");
        let cfd = OffloadEngine::new(
            &SMOKE,
            &TrainSpec {
                flags: MemAscendFlags::baseline(),
                fs_cached_fds: true,
                ..Default::default()
            },
            &d,
        )
        .unwrap();
        assert_eq!(cfd.nvme.label(), "fs-raid0-cachedfd");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn job_views_share_substrate_but_isolate_keys_and_faults() {
        let train = TrainSpec::default();
        let dir = storage("jv");
        let eng = OffloadEngine::new_shared(&SMOKE, &train, &dir, 3).unwrap();
        let j1 = eng.job_view(&SMOKE, &train, crate::ssd::JobId(1), None).unwrap();
        let j2 = eng
            .job_view(&SMOKE, &train, crate::ssd::JobId(2), Some(JobFault::Persistent))
            .unwrap();
        // shared substrate: one queue, one stage pool, one ledger
        assert!(Arc::ptr_eq(&eng.ioq, &j1.ioq));
        assert!(Arc::ptr_eq(&eng.tracker, &j2.tracker));
        // same logical key, no collision across views
        eng.nvme.write("probe", &[0u8; 8]).unwrap();
        j1.nvme.write("probe", &[1u8; 8]).unwrap();
        let mut out = [9u8; 8];
        eng.nvme.read("probe", &mut out).unwrap();
        assert_eq!(out, [0u8; 8]);
        j1.nvme.read("probe", &mut out).unwrap();
        assert_eq!(out, [1u8; 8]);
        // a persistent fault aborts only j2's I/O; co-tenants unaffected
        assert!(j2.nvme.write("probe", &[2u8; 8]).is_err());
        j1.nvme.read("probe", &mut out).unwrap();
        assert_eq!(out, [1u8; 8]);
        // arena namespaces attribute to the shared ledger
        let ns1 = eng.arena.ns_stats(1);
        assert!(ns1.charged > 0, "j1's pool bytes must be attributed to ns 1");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn verify_reads_layers_checksums_under_retry_and_over_scoping() {
        let train = TrainSpec { verify_reads: true, ..Default::default() };
        let dir = storage("vr");
        let eng = OffloadEngine::new_shared(&SMOKE, &train, &dir, 2).unwrap();
        let integ = eng.integrity.as_ref().expect("verify_reads builds the layer");
        // writes through the stack maintain sidecar sums on the base
        eng.nvme.write("probe", &[5u8; 4096]).unwrap();
        let mut out = [0u8; 4096];
        eng.nvme.read("probe", &mut out).unwrap();
        assert_eq!(out, [5u8; 4096]);
        assert!(
            eng.base.len_of(&crate::ssd::integrity::sums_key("probe")).is_some(),
            "sidecar must land on the base engine"
        );
        assert_eq!(integ.failures(), 0);
        // label still passes through the whole stack
        assert_eq!(eng.nvme.label(), "direct-nvme");
        // a tenant view gets its own layer, sidecars under its prefix
        let j1 = eng.job_view(&SMOKE, &train, crate::ssd::JobId(1), None).unwrap();
        assert!(j1.integrity.is_some());
        j1.nvme.write("probe", &[9u8; 512]).unwrap();
        j1.nvme.read("probe", &mut out[..512]).unwrap();
        assert!(out[..512].iter().all(|&b| b == 9));
        // a flip on the base (under the checksums) is detected and
        // metered once the retry budget exhausts
        let scoped_probe = "j1.probe";
        let mut raw = vec![0u8; 512];
        eng.base.read(scoped_probe, &mut raw).unwrap();
        raw[17] ^= 0x10;
        eng.base.write(scoped_probe, &raw).unwrap();
        let err = j1.nvme.read("probe", &mut out[..512]).unwrap_err();
        assert!(
            err.to_string().contains("integrity mismatch"),
            "unexpected error: {err}"
        );
        assert!(j1.nvme.stats().integrity_failures > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pinned_budget_below_pool_demand_is_a_structured_error() {
        let train = TrainSpec {
            pinned_budget_bytes: Some(4096), // far below the pool's need
            ..Default::default()
        };
        let dir = storage("budget");
        let err = OffloadEngine::new(&SMOKE, &train, &dir).unwrap_err();
        assert!(
            err.to_string().contains("pinned budget exceeded"),
            "unexpected error: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
