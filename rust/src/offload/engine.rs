//! Component assembly: `MemAscendFlags` → concrete allocator policy,
//! pinned arena, pool, NVMe engine, and overflow checker.
//!
//! This is the ablation axis: every flag combination yields a working
//! engine, so benches can toggle one optimization at a time (DESIGN.md
//! §ablations) and the trainer can run as pure ZeRO-Infinity, pure
//! MemAscend, or anything between.  All host memory flows through one
//! [`PinnedArena`] built over the flag-selected allocator policy —
//! `TrainSpec::pinned_budget_bytes` makes its budget a run-level knob.

use std::path::Path;
use std::sync::Arc;

use crate::bufpool::{AdaptivePool, MonolithicPool, ParamBufferPool};
use crate::config::{ModelSpec, TrainSpec};
use crate::metrics::HostCopyMeter;
use crate::overflow::{baseline_overflow_check, fused_overflow_check, Checker};
use crate::pinned::{
    AlignedAllocator, ArenaConfig, CachingAllocator, HostAllocator, MemoryTracker,
    Mode, PinnedArena,
};
use crate::ckpt::ShadowEngine;
use crate::ssd::{
    AsyncEngine, DirectEngine, FsEngine, IoExecutor, NvmeEngine, RetryEngine,
    RetryPolicy,
};
use crate::util::stage::StageExecutor;

pub struct OffloadEngine {
    pub tracker: Arc<MemoryTracker>,
    /// The one lease tier every host-memory consumer allocates from.
    pub arena: Arc<PinnedArena>,
    pub pool: Arc<dyn ParamBufferPool>,
    pub nvme: Arc<dyn NvmeEngine>,
    /// Typed handle on the shadow-paging layer `nvme` points at: the
    /// trainer registers/advances/flips the per-key extent map here
    /// while every I/O consumer keeps reading logical keys through
    /// `nvme`.
    pub shadow: Arc<ShadowEngine>,
    /// Shared async submission queue: swapper fetch window, activation
    /// spill, and the optimizer swap ride this one executor (the
    /// engines keep their own per-device queues underneath).
    pub ioq: Arc<IoExecutor>,
    /// Compute-side stage pool: f16↔f32 conversions of the swapper and
    /// the tiled optimizer run here, never on the NVMe queue workers.
    pub stage: Arc<StageExecutor>,
    pub checker: Checker,
    pub threads: usize,
    /// Engine-wide boundary copy counter: every component that stages
    /// fp32 tensors in owned heap memory on the way to PJRT (swapper
    /// fallback, spill-store fallback) charges this one meter, so the
    /// trainer's per-step `host_copy_bytes` covers the whole engine.
    pub copy_meter: HostCopyMeter,
}

impl OffloadEngine {
    /// Build a real (byte-moving) engine rooted at `storage_dir`.
    pub fn new(
        spec: &ModelSpec,
        train: &TrainSpec,
        storage_dir: &Path,
    ) -> anyhow::Result<Self> {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc: Arc<dyn HostAllocator> = if train.flags.alignment_free {
            Arc::new(AlignedAllocator::new(Mode::Real, tracker.clone()))
        } else {
            Arc::new(CachingAllocator::new(Mode::Real, tracker.clone()))
        };
        let arena = PinnedArena::new(
            alloc,
            ArenaConfig {
                budget_bytes: train.pinned_budget_bytes,
                ..Default::default()
            },
        );
        let dtype = train.precision.compute_dtype();
        let pool: Arc<dyn ParamBufferPool> = if train.flags.adaptive_pool {
            Arc::new(AdaptivePool::new(spec, train.prefetch_depth, dtype, &arena)?)
        } else {
            Arc::new(MonolithicPool::new(spec, train.prefetch_depth, dtype, &arena)?)
        };
        // capacity: fp16 + fp32 master + m + v + slack, per device —
        // doubled, because shadow paging keeps two physical extents
        // per checkpointed stream (epoch N plus the N+1 shadow)
        let cap_bytes = (spec.param_count() as u64)
            .saturating_mul(32)
            .max(1 << 24)
            + (128 << 20);
        let devices = 2;
        let nvme: Arc<dyn NvmeEngine> = if train.flags.direct_nvme {
            Arc::new(DirectEngine::new(
                &storage_dir.join("direct"),
                devices,
                cap_bytes / devices as u64,
                1,
            )?)
        } else {
            Arc::new(FsEngine::with_fd_cache(
                &storage_dir.join("fs"),
                devices,
                512 << 10,
                train.fs_cached_fds,
            )?)
        };
        // transient-fault retry sits directly above the storage engine
        // and below the async queue, so queued submit closures and
        // synchronous calls retry identically (label passes through)
        let nvme: Arc<dyn NvmeEngine> = if train.io_retry_attempts > 1 {
            Arc::new(RetryEngine::new(
                nvme,
                RetryPolicy::attempts(train.io_retry_attempts as u32),
            ))
        } else {
            nvme
        };
        // shadow paging tops the stack: logical checkpoint keys route
        // to per-epoch physical extents; everything unregistered
        // passes through (label/stats delegate)
        let shadow = Arc::new(ShadowEngine::new(nvme));
        let nvme: Arc<dyn NvmeEngine> = shadow.clone();
        let checker = if train.flags.fused_overflow {
            Checker::Fused
        } else {
            Checker::Baseline
        };
        let ioq = Arc::new(IoExecutor::new(train.io_workers.max(1)));
        let threads = crate::util::par::default_threads();
        let stage = Arc::new(StageExecutor::new((threads / 2).clamp(1, 4)));
        Ok(Self {
            tracker,
            arena,
            pool,
            nvme,
            shadow,
            ioq,
            stage,
            checker,
            threads,
            copy_meter: HostCopyMeter::new(),
        })
    }

    /// Async surface over the configured NVMe engine, sharing the
    /// engine-wide submission queue.
    pub fn async_io(&self) -> AsyncEngine {
        AsyncEngine::with_executor(self.nvme.clone(), self.ioq.clone())
    }

    /// Run the configured overflow check over a flat fp32 buffer.
    pub fn check_overflow(&self, grads: &[f32]) -> bool {
        match self.checker {
            Checker::Fused => fused_overflow_check(grads, self.threads),
            Checker::Baseline => baseline_overflow_check(grads, &self.tracker),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::SMOKE;
    use crate::config::MemAscendFlags;

    fn storage(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ma-eng-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn all_sixteen_combinations_construct_and_roundtrip() {
        for (i, flags) in MemAscendFlags::all_combinations().into_iter().enumerate() {
            let train = TrainSpec { flags, ..Default::default() };
            let dir = storage(&format!("c{i}"));
            let eng = OffloadEngine::new(&SMOKE, &train, &dir).unwrap();
            eng.nvme.write("probe", &[1, 2, 3, 4]).unwrap();
            let mut out = [0u8; 4];
            eng.nvme.read("probe", &mut out).unwrap();
            assert_eq!(out, [1, 2, 3, 4]);
            assert!(!eng.check_overflow(&[0.0, 1.0]));
            assert!(eng.check_overflow(&[f32::NAN]));
            // the pool's bytes are arena-leased, on the shared ledger
            assert_eq!(
                eng.arena.stats().requested_bytes,
                eng.pool.stats().pool_bytes
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn labels_reflect_flags() {
        let d = storage("lbl");
        let zi = OffloadEngine::new(
            &SMOKE,
            &TrainSpec { flags: MemAscendFlags::baseline(), ..Default::default() },
            &d,
        )
        .unwrap();
        assert_eq!(zi.pool.label(), "monolithic");
        assert_eq!(zi.nvme.label(), "fs-raid0");
        let ma = OffloadEngine::new(
            &SMOKE,
            &TrainSpec { flags: MemAscendFlags::memascend(), ..Default::default() },
            &d,
        )
        .unwrap();
        assert_eq!(ma.pool.label(), "adaptive");
        assert_eq!(ma.nvme.label(), "direct-nvme");
        let cfd = OffloadEngine::new(
            &SMOKE,
            &TrainSpec {
                flags: MemAscendFlags::baseline(),
                fs_cached_fds: true,
                ..Default::default()
            },
            &d,
        )
        .unwrap();
        assert_eq!(cfd.nvme.label(), "fs-raid0-cachedfd");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn pinned_budget_below_pool_demand_is_a_structured_error() {
        let train = TrainSpec {
            pinned_budget_bytes: Some(4096), // far below the pool's need
            ..Default::default()
        };
        let dir = storage("budget");
        let err = OffloadEngine::new(&SMOKE, &train, &dir).unwrap_err();
        assert!(
            err.to_string().contains("pinned budget exceeded"),
            "unexpected error: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
