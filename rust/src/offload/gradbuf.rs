//! The fp32 gradient partition flat buffer (§III-C).
//!
//! One contiguous fp32 block sized to the full partition, laid out in
//! canonical tensor order; gradients arrive as fp16 (the GPU transport
//! format — the cast is where overflow becomes ±inf) and are
//! accumulated in fp32.  The buffer is pinned through the configured
//! allocator, so its pow2-vs-exact overhead shows up in the ledger.

use std::collections::HashMap;

use crate::dtype::{f16_to_f32, f32_to_f16};
use crate::pinned::{Cat, HostAllocator, HostRegion};
use crate::tensors::TensorDesc;

pub struct GradFlatBuffer {
    /// Backing pinned region (kept alive for ledger correctness).
    _region: HostRegion,
    /// The fp32 accumulator (owned separately: HostRegion byte access
    /// is awkward for f32 math; the region charges the ledger, this
    /// holds the data — both are the same size).
    data: Vec<f32>,
    /// tensor name -> (offset, len) in elements.
    layout: HashMap<String, (usize, usize)>,
    len: usize,
}

impl GradFlatBuffer {
    /// Build the layout from the canonical inventory order.
    pub fn new(tensors: &[TensorDesc], alloc: &dyn HostAllocator) -> Self {
        let mut layout = HashMap::new();
        let mut off = 0usize;
        for t in tensors {
            layout.insert(t.name.clone(), (off, t.numel));
            off += t.numel;
        }
        let region = alloc.alloc(off * 4, Cat::GradFlat);
        Self { _region: region, data: vec![0f32; off], layout, len: off }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn span_of(&self, tensor: &str) -> Option<(usize, usize)> {
        self.layout.get(tensor).copied()
    }

    pub fn grads_of(&self, tensor: &str) -> &[f32] {
        let (off, len) = self.layout[tensor];
        &self.data[off..off + len]
    }

    /// Accumulate a gradient that traveled as fp16 (values round-trip
    /// f32→f16→f32: overflow becomes ±inf here, exactly as on a real
    /// PCIe path).
    pub fn accumulate_f16_transport(&mut self, tensor: &str, grads_f32: &[f32]) {
        let (off, len) = self.layout[tensor];
        assert_eq!(len, grads_f32.len(), "grad size mismatch for {tensor}");
        for (dst, &g) in self.data[off..off + len].iter_mut().zip(grads_f32) {
            *dst += f16_to_f32(f32_to_f16(g));
        }
    }

    /// Accumulate at full fp32 (bf16 runs skip the f16 bottleneck; the
    /// bf16 cast itself loses only mantissa, applied here).
    pub fn accumulate_bf16_transport(&mut self, tensor: &str, grads_f32: &[f32]) {
        use crate::dtype::{bf16_to_f32, f32_to_bf16};
        let (off, len) = self.layout[tensor];
        assert_eq!(len, grads_f32.len(), "grad size mismatch for {tensor}");
        for (dst, &g) in self.data[off..off + len].iter_mut().zip(grads_f32) {
            *dst += bf16_to_f32(f32_to_bf16(g));
        }
    }

    pub fn zero(&mut self) {
        self.data.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::SMOKE;
    use crate::pinned::{AlignedAllocator, MemoryTracker, Mode};
    use crate::tensors::inventory;
    use std::sync::Arc;

    fn mk() -> GradFlatBuffer {
        let alloc = AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()));
        let inv = inventory(&SMOKE);
        GradFlatBuffer::new(&inv, &Arc::clone(&alloc))
    }

    #[test]
    fn layout_covers_all_params() {
        let buf = mk();
        let total: usize = inventory(&SMOKE).iter().map(|t| t.numel).sum();
        assert_eq!(buf.len(), total);
        assert_eq!(total as u64, SMOKE.param_count());
    }

    #[test]
    fn spans_are_disjoint_and_ordered() {
        let buf = mk();
        let inv = inventory(&SMOKE);
        let mut expect = 0usize;
        for t in &inv {
            let (off, len) = buf.span_of(&t.name).unwrap();
            assert_eq!(off, expect);
            assert_eq!(len, t.numel);
            expect += len;
        }
    }

    #[test]
    fn f16_transport_creates_inf_on_overflow() {
        let mut buf = mk();
        let inv = inventory(&SMOKE);
        let t = &inv[1]; // first block tensor
        let mut grads = vec![0.5f32; t.numel];
        grads[3] = 1e30; // beyond f16 range
        buf.accumulate_f16_transport(&t.name, &grads);
        let got = buf.grads_of(&t.name);
        assert!(got[3].is_infinite());
        assert_eq!(got[0], 0.5);
    }

    #[test]
    fn accumulation_adds() {
        let mut buf = mk();
        let inv = inventory(&SMOKE);
        let t = &inv[2];
        let g = vec![1.0f32; t.numel];
        buf.accumulate_f16_transport(&t.name, &g);
        buf.accumulate_f16_transport(&t.name, &g);
        assert!(buf.grads_of(&t.name).iter().all(|&x| x == 2.0));
        buf.zero();
        assert!(buf.grads_of(&t.name).iter().all(|&x| x == 0.0));
    }
}
