//! The fp32 gradient partition flat buffer (§III-C).
//!
//! One contiguous fp32 block sized to the full partition, laid out in
//! canonical tensor order; gradients arrive as fp16 (the GPU transport
//! format — the cast is where overflow becomes ±inf) and are
//! accumulated in fp32 **directly in the pinned lease**.  The seed
//! implementation paired the pinned region with a same-sized `Vec<f32>`
//! (region for the ledger, vector for the math) — 2× the partition in
//! host memory; the arena lease's aligned f32 view removes the
//! duplicate, so the buffer's footprint is exactly one partition.

use std::collections::HashMap;

use crate::dtype::{f16_to_f32, f32_to_f16};
use crate::pinned::{Cat, Lease, PinnedArena};
use crate::runtime::ValueRef;
use crate::tensors::TensorDesc;

pub struct GradFlatBuffer {
    /// The fp32 accumulator: one arena lease, page-aligned, viewed as
    /// `[f32]` in place.
    lease: Lease,
    /// tensor name -> (offset, len) in elements.
    layout: HashMap<String, (usize, usize)>,
    len: usize,
}

impl GradFlatBuffer {
    /// Build the layout from the canonical inventory order.
    pub fn new(tensors: &[TensorDesc], arena: &PinnedArena) -> anyhow::Result<Self> {
        let mut layout = HashMap::new();
        let mut off = 0usize;
        for t in tensors {
            layout.insert(t.name.clone(), (off, t.numel));
            off += t.numel;
        }
        let lease = arena.lease((off * 4).max(4), Cat::GradFlat)?;
        anyhow::ensure!(
            !lease.is_virtual() || off == 0,
            "GradFlatBuffer needs a real-mode arena (virtual runs use \
             accounting::sysmem instead)"
        );
        Ok(Self { lease, layout, len: off })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.lease.as_f32()[..self.len]
    }

    pub fn span_of(&self, tensor: &str) -> Option<(usize, usize)> {
        self.layout.get(tensor).copied()
    }

    pub fn grads_of(&self, tensor: &str) -> &[f32] {
        let (off, len) = self.layout[tensor];
        &self.lease.as_f32()[off..off + len]
    }

    /// One tensor's grad span as a PJRT argument — borrows the pinned
    /// lease region itself, so uploading a gradient (e.g. to an
    /// HLO-side optimizer kernel) stages zero copies.
    pub fn value_of(&self, tensor: &str) -> ValueRef<'_> {
        ValueRef::F32(self.grads_of(tensor))
    }

    /// The whole fp32 partition as one argument (same lease bytes the
    /// overflow check scans).
    pub fn as_value(&self) -> ValueRef<'_> {
        ValueRef::F32(self.as_slice())
    }

    /// Accumulate a gradient that traveled as fp16 (values round-trip
    /// f32→f16→f32: overflow becomes ±inf here, exactly as on a real
    /// PCIe path).
    pub fn accumulate_f16_transport(&mut self, tensor: &str, grads_f32: &[f32]) {
        let (off, len) = self.layout[tensor];
        assert_eq!(len, grads_f32.len(), "grad size mismatch for {tensor}");
        let data = self.lease.as_f32_mut();
        for (dst, &g) in data[off..off + len].iter_mut().zip(grads_f32) {
            *dst += f16_to_f32(f32_to_f16(g));
        }
    }

    /// Accumulate at full fp32 (bf16 runs skip the f16 bottleneck; the
    /// bf16 cast itself loses only mantissa, applied here).
    pub fn accumulate_bf16_transport(&mut self, tensor: &str, grads_f32: &[f32]) {
        use crate::dtype::{bf16_to_f32, f32_to_bf16};
        let (off, len) = self.layout[tensor];
        assert_eq!(len, grads_f32.len(), "grad size mismatch for {tensor}");
        let data = self.lease.as_f32_mut();
        for (dst, &g) in data[off..off + len].iter_mut().zip(grads_f32) {
            *dst += bf16_to_f32(f32_to_bf16(g));
        }
    }

    pub fn zero(&mut self) {
        let len = self.len;
        self.lease.as_f32_mut()[..len].fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::config::presets::SMOKE;
    use crate::pinned::Mode;
    use crate::tensors::inventory;

    fn mk() -> GradFlatBuffer {
        GradFlatBuffer::new(&inventory(&SMOKE), &test_arena(Mode::Real)).unwrap()
    }

    #[test]
    fn layout_covers_all_params() {
        let buf = mk();
        let total: usize = inventory(&SMOKE).iter().map(|t| t.numel).sum();
        assert_eq!(buf.len(), total);
        assert_eq!(total as u64, SMOKE.param_count());
    }

    #[test]
    fn spans_are_disjoint_and_ordered() {
        let buf = mk();
        let inv = inventory(&SMOKE);
        let mut expect = 0usize;
        for t in &inv {
            let (off, len) = buf.span_of(&t.name).unwrap();
            assert_eq!(off, expect);
            assert_eq!(len, t.numel);
            expect += len;
        }
    }

    #[test]
    fn single_allocation_no_duplicate_partition() {
        // regression for the seed's 2× footprint: the whole buffer is
        // one GradFlat charge of exactly the partition size (page
        // rounded), with the math running in the leased bytes — the
        // slice's base address *is* the lease.
        let a = test_arena(Mode::Real);
        let total: usize = inventory(&SMOKE).iter().map(|t| t.numel).sum();
        let mut buf = GradFlatBuffer::new(&inventory(&SMOKE), &a).unwrap();
        let charged = a.tracker().current(Cat::GradFlat) as usize;
        assert!(charged >= total * 4, "lease smaller than the partition");
        assert!(
            charged < total * 4 + crate::pinned::arena::LEASE_ALIGN,
            "GradFlat charge {} is more than one partition (+1 page): \
             duplicate allocation?",
            charged
        );
        assert_eq!(a.watermark(Cat::GradFlat).requested, total * 4);
        // the accumulator writes land in the leased span itself
        let inv = inventory(&SMOKE);
        let t = &inv[0];
        buf.accumulate_f16_transport(&t.name, &vec![1.0f32; t.numel]);
        assert_eq!(buf.as_slice()[0], 1.0);
        assert_eq!(buf.as_slice().as_ptr() as usize % 4096, 0, "not lease-backed");
    }

    #[test]
    fn f16_transport_creates_inf_on_overflow() {
        let mut buf = mk();
        let inv = inventory(&SMOKE);
        let t = &inv[1]; // first block tensor
        let mut grads = vec![0.5f32; t.numel];
        grads[3] = 1e30; // beyond f16 range
        buf.accumulate_f16_transport(&t.name, &grads);
        let got = buf.grads_of(&t.name);
        assert!(got[3].is_infinite());
        assert_eq!(got[0], 0.5);
    }

    #[test]
    fn value_refs_borrow_the_lease_without_copying() {
        let mut buf = mk();
        let inv = inventory(&SMOKE);
        let t = &inv[1];
        buf.accumulate_f16_transport(&t.name, &vec![0.25f32; t.numel]);
        // zero-copy proof: the argument's base pointer IS the lease span
        let arg = buf.value_of(&t.name);
        let arg_slice = arg.as_f32().unwrap();
        assert_eq!(arg_slice.as_ptr(), buf.grads_of(&t.name).as_ptr());
        assert_eq!(arg_slice.len(), t.numel);
        assert!(arg_slice.iter().all(|&x| x == 0.25));
        let whole = buf.as_value();
        assert_eq!(whole.len(), buf.len());
        assert_eq!(whole.as_f32().unwrap().as_ptr(), buf.as_slice().as_ptr());
    }

    #[test]
    fn accumulation_adds() {
        let mut buf = mk();
        let inv = inventory(&SMOKE);
        let t = &inv[2];
        let g = vec![1.0f32; t.numel];
        buf.accumulate_f16_transport(&t.name, &g);
        buf.accumulate_f16_transport(&t.name, &g);
        assert!(buf.grads_of(&t.name).iter().all(|&x| x == 2.0));
        buf.zero();
        assert!(buf.grads_of(&t.name).iter().all(|&x| x == 0.0));
    }
}
