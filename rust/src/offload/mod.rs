//! The offload engine: ZeRO-Infinity's data flow with MemAscend's
//! four optimizations as switchable components (§IV).
//!
//! - [`partition`] — ZeRO-3 parameter partitioning across ranks
//! - [`swapper`] — SSD→host prefetch pipeline over the buffer pool
//! - [`gradbuf`] — the fp32 gradient partition flat buffer
//! - [`scaler`] — DeepSpeed-semantics dynamic loss scaler
//! - [`spill`] — the offloaded activation-checkpoint store (Eq. 1):
//!   pinned host slots up to a byte budget, SSD spill beyond it
//!   (`host_budget = ∞` is the fully-host degenerate case — the old
//!   separate non-spilling store is gone)
//! - [`prefetch`] — coalesced fetch groups over the optimizer layout
//!   plus the recorded step-profile store the swapper replays
//! - [`engine`] — assembles allocator + pool + NVMe engine + checker
//!   from `MemAscendFlags` (the ablation axis every bench sweeps)

pub mod engine;
pub mod gradbuf;
pub mod partition;
pub mod prefetch;
pub mod scaler;
pub mod spill;
pub mod swapper;

pub use engine::{JobFault, OffloadEngine};
pub use gradbuf::GradFlatBuffer;
pub use prefetch::{FetchGroups, ProfileStore, StepProfile};
pub use scaler::LossScaler;
pub use spill::SpillingActivationStore;
pub use swapper::{F32Scratch, FetchOpts, Fetched, SwapMetrics, Swapper};
