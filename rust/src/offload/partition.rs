//! ZeRO-3 parameter partitioning (Fig. 1's P0(i)/G0(i)/O0(i) split).
//!
//! Every tensor's flat data is divided into `ranks` near-equal spans;
//! rank r owns span r of every tensor, stores only that shard on its
//! SSD region, and allgathers the full tensor before compute.

use crate::collective::partition_bounds;
use crate::tensors::TensorDesc;

/// A rank's view of one tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    pub tensor: String,
    pub rank: usize,
    /// Element span [lo, hi) within the flat tensor.
    pub lo: usize,
    pub hi: usize,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    /// SSD key for this shard's fp16 copy.
    pub fn key_fp16(&self) -> String {
        format!("{}/r{}/fp16", self.tensor, self.rank)
    }

    /// SSD key prefix for optimizer states.
    pub fn key_group(&self) -> String {
        format!("{}/r{}", self.tensor, self.rank)
    }
}

/// Shards of one tensor across all ranks.
pub fn shards_of(t: &TensorDesc, ranks: usize) -> Vec<Shard> {
    (0..ranks.max(1))
        .map(|r| {
            let (lo, hi) = partition_bounds(t.numel, ranks.max(1), r);
            Shard { tensor: t.name.clone(), rank: r, lo, hi }
        })
        .collect()
}

/// Reassemble a full tensor from rank shards (the allgather result).
pub fn assemble(shards: &[(Shard, Vec<f32>)]) -> Vec<f32> {
    let mut parts: Vec<&(Shard, Vec<f32>)> = shards.iter().collect();
    parts.sort_by_key(|(s, _)| s.lo);
    let mut out = Vec::with_capacity(parts.iter().map(|(s, _)| s.len()).sum());
    for (s, data) in parts {
        assert_eq!(s.len(), data.len(), "shard data mismatch");
        out.extend_from_slice(data);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::SMOKE;
    use crate::tensors::inventory;

    #[test]
    fn shards_cover_exactly() {
        for t in inventory(&SMOKE) {
            for ranks in [1, 2, 3] {
                let ss = shards_of(&t, ranks);
                assert_eq!(ss.len(), ranks);
                let total: usize = ss.iter().map(Shard::len).sum();
                assert_eq!(total, t.numel, "{} ranks={ranks}", t.name);
                for w in ss.windows(2) {
                    assert_eq!(w[0].hi, w[1].lo);
                }
            }
        }
    }

    #[test]
    fn assemble_restores_order() {
        let t = &inventory(&SMOKE)[1];
        let data: Vec<f32> = (0..t.numel).map(|i| i as f32).collect();
        let ss = shards_of(t, 3);
        let mut pieces: Vec<(Shard, Vec<f32>)> = ss
            .iter()
            .map(|s| (s.clone(), data[s.lo..s.hi].to_vec()))
            .collect();
        pieces.reverse(); // out of order on purpose
        assert_eq!(assemble(&pieces), data);
    }

    #[test]
    fn keys_are_unique_per_rank() {
        let t = &inventory(&SMOKE)[1];
        let ss = shards_of(t, 2);
        assert_ne!(ss[0].key_fp16(), ss[1].key_fp16());
    }
}
