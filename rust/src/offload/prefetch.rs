//! Fetch coalescing groups and the recorded step-profile store — the
//! data plane behind the swapper's predictive prefetcher.
//!
//! Two independent pieces live here, both consumed by
//! [`crate::offload::Swapper`] through
//! [`crate::offload::swapper::FetchOpts`]:
//!
//! - [`FetchGroups`] projects the optimizer's [`CoalescedLayout`] onto
//!   the *read* path: consecutive plan tensors that share a super-group
//!   collapse into one ranged read of that super-group's packed fp16
//!   stream (`optim/sg{i}/fp16`, maintained by
//!   [`crate::optimizer::CoalescedOptim`]'s write-back scatter).  Many
//!   small `{name}/fp16` submissions become one `read_at` per group —
//!   the read-side twin of the coalesced state scatter.
//!
//! - [`ProfileStore`] holds recorded [`StepProfile`]s keyed by a
//!   [`plan_digest`] of the fetch-unit sequence `(key, offset, len)`.
//!   The swapper records one profile per distinct plan (forward and
//!   backward differ) on its first window-greedy pass, then replays
//!   later steps against a rate-matched just-in-time schedule.  A
//!   digest miss — new, renamed, or reordered keys — simply means "no
//!   profile": the swapper degrades to the depth-window path and
//!   re-records, never stalling.
//!
//! The store persists on-engine under [`PROFILE_KEY`] as a
//! fixed-capacity, checksummed slot (engines reject size changes, the
//! same constraint the checkpoint journal works under), and the
//! checkpoint journal fingerprints the slot so
//! [`crate::train::Trainer::resume`] can tell a profile recorded by
//! *this* run's plan from a stale or foreign blob.  Validation failure
//! degrades to an empty store — record mode — by design.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::ckpt::fnv1a64;
use crate::optimizer::coalesce::fp16_stream_name;
use crate::optimizer::CoalescedLayout;
use crate::ssd::NvmeEngine;
use crate::util::json::Json;

/// Engine key the profile store persists under.
pub const PROFILE_KEY: &str = "swap/profile";

/// Slot header: magic (8) + payload len (8) + payload checksum (8).
const MAGIC: &[u8; 8] = b"MASWPRF1";
const HEADER: usize = 24;
/// Slot capacity granularity and headroom for profile growth (new
/// digests appear only when the plan changes, so growth is rare).
const SLOT_ALIGN: usize = 4096;
const SLOT_SLACK: usize = 4096;

/// Read-path projection of the optimizer's coalesced layout: member
/// name → `(super-group, element offset, element count)` plus the
/// packed fp16 stream key of each super-group.
#[derive(Debug, Clone)]
pub struct FetchGroups {
    spans: HashMap<String, (usize, usize, usize)>,
    streams: Vec<String>,
    super_numels: Vec<usize>,
}

impl FetchGroups {
    /// Build from the persisted/planned layout.  Only meaningful once
    /// [`crate::optimizer::CoalescedOptim::enable_fp16_streams`] has
    /// populated the packed streams the spans point into.
    pub fn from_layout(layout: &CoalescedLayout) -> Self {
        let spans = layout
            .members
            .iter()
            .map(|m| (m.name.clone(), (m.super_idx, m.offset, m.numel)))
            .collect();
        let streams = (0..layout.super_numels.len()).map(fp16_stream_name).collect();
        Self { spans, streams, super_numels: layout.super_numels.clone() }
    }

    /// `(super-group, element offset, element count)` of a member, or
    /// `None` if the tensor is not coalesced (fetched per-tensor).
    pub fn span_of(&self, name: &str) -> Option<(usize, usize, usize)> {
        self.spans.get(name).copied()
    }

    /// Packed fp16 stream key of super-group `idx`.
    pub fn stream_key(&self, idx: usize) -> &str {
        &self.streams[idx]
    }

    /// Element count of super-group `idx`'s stream.
    pub fn stream_numel(&self, idx: usize) -> usize {
        self.super_numels[idx]
    }

    /// Number of super-groups.
    pub fn groups(&self) -> usize {
        self.streams.len()
    }
}

/// One fetch unit's recorded timings, both measured from the step's
/// first fetch submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProfileUnit {
    /// µs at which compute *asked* for this unit (the swapper's
    /// `next()` entry) — the deadline a replayed fetch must beat.
    pub consume_us: u64,
    /// µs the fetch itself took (submission → upconverted delivery),
    /// subtracted from the deadline to find the latest safe issue time.
    pub fetch_us: u64,
}

/// A full step's fetch trace for one plan (one digest).
#[derive(Debug, Clone, Default)]
pub struct StepProfile {
    pub units: Vec<ProfileUnit>,
}

/// Digest of a fetch-unit sequence `(key, byte offset, byte len)` —
/// the identity a recorded profile is valid for.  Any plan change
/// (tensor added/renamed/reordered, layout re-planned) changes the
/// digest and invalidates the profile.
pub fn plan_digest<'a>(units: impl Iterator<Item = (&'a str, usize, usize)>) -> u64 {
    let mut buf = Vec::new();
    for (key, off, len) in units {
        buf.extend_from_slice(key.as_bytes());
        buf.push(0xff);
        buf.extend_from_slice(&(off as u64).to_le_bytes());
        buf.extend_from_slice(&(len as u64).to_le_bytes());
    }
    fnv1a64(&buf)
}

/// Shared store of recorded step profiles, keyed by [`plan_digest`].
/// Clone-shared via `Arc` between the trainer (persistence) and the
/// per-step swappers (record/replay).
#[derive(Debug, Default)]
pub struct ProfileStore {
    profiles: Mutex<HashMap<u64, Arc<StepProfile>>>,
    dirty: AtomicBool,
}

impl ProfileStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// The profile recorded for `digest`, if any.
    pub fn get(&self, digest: u64) -> Option<Arc<StepProfile>> {
        self.profiles.lock().unwrap().get(&digest).cloned()
    }

    /// Commit a freshly recorded profile (replaces any prior one for
    /// the same plan) and mark the store dirty for persistence.
    pub fn record(&self, digest: u64, profile: StepProfile) {
        self.profiles.lock().unwrap().insert(digest, Arc::new(profile));
        self.dirty.store(true, Ordering::Release);
    }

    pub fn len(&self) -> usize {
        self.profiles.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether profiles were recorded since the last [`Self::persist`].
    pub fn dirty(&self) -> bool {
        self.dirty.load(Ordering::Acquire)
    }

    pub fn to_json(&self) -> Json {
        let profiles = self.profiles.lock().unwrap();
        let mut entries: Vec<(u64, Arc<StepProfile>)> =
            profiles.iter().map(|(d, p)| (*d, Arc::clone(p))).collect();
        entries.sort_by_key(|(d, _)| *d);
        Json::Arr(
            entries
                .iter()
                .map(|(digest, p)| {
                    Json::obj(vec![
                        // u64 digests can exceed 2^53: hex strings.
                        ("digest", Json::from(format!("{digest:016x}"))),
                        (
                            "units",
                            Json::Arr(
                                p.units
                                    .iter()
                                    .map(|u| {
                                        Json::obj(vec![
                                            ("consume_us", Json::from(u.consume_us)),
                                            ("fetch_us", Json::from(u.fetch_us)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("profile store: expected array"))?;
        let mut profiles = HashMap::new();
        for entry in arr {
            let digest_s = entry
                .req("digest")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("profile store: digest must be a hex string"))?;
            let digest = u64::from_str_radix(digest_s, 16)
                .map_err(|e| anyhow::anyhow!("profile store: bad digest '{digest_s}': {e}"))?;
            let units = entry
                .req("units")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("profile store: units must be an array"))?
                .iter()
                .map(|u| {
                    let consume_us = u
                        .req("consume_us")?
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("profile store: bad consume_us"))?;
                    let fetch_us = u
                        .req("fetch_us")?
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("profile store: bad fetch_us"))?;
                    Ok(ProfileUnit { consume_us, fetch_us })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            profiles.insert(digest, Arc::new(StepProfile { units }));
        }
        Ok(Self { profiles: Mutex::new(profiles), dirty: AtomicBool::new(false) })
    }

    /// Persist the store into its fixed-capacity on-engine slot and
    /// clear the dirty flag.  The slot is sized with headroom on first
    /// write; if the serialized store ever outgrows it (many distinct
    /// plans on one storage root) the error is structured and the
    /// caller may treat persistence as best-effort — the in-memory
    /// store keeps working.
    pub fn persist(&self, engine: &dyn NvmeEngine) -> anyhow::Result<()> {
        let payload = self.to_json().to_string().into_bytes();
        let need = HEADER + payload.len();
        let cap = match engine.len_of(PROFILE_KEY) {
            Some(cap) => {
                anyhow::ensure!(
                    cap >= need,
                    "profile store outgrew its {cap}-byte slot (need {need})"
                );
                cap
            }
            None => {
                let cap = (need + SLOT_SLACK).div_ceil(SLOT_ALIGN) * SLOT_ALIGN;
                engine.reserve(PROFILE_KEY, cap)?;
                cap
            }
        };
        let mut buf = vec![0u8; cap];
        buf[..8].copy_from_slice(MAGIC);
        buf[8..16].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        buf[16..24].copy_from_slice(&fnv1a64(&payload).to_le_bytes());
        buf[HEADER..HEADER + payload.len()].copy_from_slice(&payload);
        engine.write(PROFILE_KEY, &buf)?;
        engine.flush(PROFILE_KEY)?;
        self.dirty.store(false, Ordering::Release);
        Ok(())
    }

    /// Load a persisted store.  `Ok(None)` if no slot exists; any
    /// corruption (magic, checksum, parse) is a structured error the
    /// caller should degrade on, not crash on.
    pub fn load(engine: &dyn NvmeEngine) -> anyhow::Result<Option<Self>> {
        let Some(cap) = engine.len_of(PROFILE_KEY) else {
            return Ok(None);
        };
        anyhow::ensure!(cap >= HEADER, "profile slot truncated ({cap} B)");
        let mut buf = vec![0u8; cap];
        engine.read(PROFILE_KEY, &mut buf)?;
        anyhow::ensure!(&buf[..8] == MAGIC, "profile slot: bad magic");
        let len = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
        anyhow::ensure!(HEADER + len <= cap, "profile slot: payload overruns capacity");
        let want = u64::from_le_bytes(buf[16..24].try_into().unwrap());
        let payload = &buf[HEADER..HEADER + len];
        anyhow::ensure!(fnv1a64(payload) == want, "profile slot: checksum mismatch");
        let text = std::str::from_utf8(payload)?;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("profile slot: {e:?}"))?;
        Ok(Some(Self::from_json(&j)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::states::StateDtype;
    use crate::ssd::DirectEngine;

    fn store_with(entries: &[(u64, &[(u64, u64)])]) -> ProfileStore {
        let s = ProfileStore::new();
        for (digest, units) in entries {
            s.record(
                *digest,
                StepProfile {
                    units: units
                        .iter()
                        .map(|&(consume_us, fetch_us)| ProfileUnit { consume_us, fetch_us })
                        .collect(),
                },
            );
        }
        s
    }

    fn engine(tag: &str) -> (DirectEngine, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("ma-prefetch-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        (DirectEngine::new(&dir, 1, 1 << 22, 1).unwrap(), dir)
    }

    #[test]
    fn fetch_groups_project_the_layout() {
        let sizes = [100usize, 50, 700, 30];
        let items: Vec<(String, usize)> =
            sizes.iter().enumerate().map(|(i, &n)| (format!("t{i}"), n)).collect();
        let layout = CoalescedLayout::plan(&items, StateDtype::F32, 1024);
        let g = FetchGroups::from_layout(&layout);
        assert_eq!(g.groups(), layout.super_numels.len());
        for m in &layout.members {
            let (sg, off, numel) = g.span_of(&m.name).unwrap();
            assert_eq!((sg, off, numel), (m.super_idx, m.offset, m.numel));
            assert_eq!(g.stream_key(sg), fp16_stream_name(sg));
            assert!(off + numel <= g.stream_numel(sg));
        }
        assert!(g.span_of("not-a-member").is_none());
    }

    #[test]
    fn plan_digest_separates_key_offset_and_order_changes() {
        let base = || vec![("a", 0usize, 64usize), ("b", 64, 32)];
        let d = |v: &[(&str, usize, usize)]| plan_digest(v.iter().copied());
        let orig = d(&base());
        assert_eq!(orig, d(&base()), "digest must be deterministic");
        assert_ne!(orig, d(&[("a", 0, 64), ("c", 64, 32)]), "key change");
        assert_ne!(orig, d(&[("a", 0, 64), ("b", 96, 32)]), "offset change");
        assert_ne!(orig, d(&[("b", 64, 32), ("a", 0, 64)]), "order change");
        assert_ne!(orig, d(&[("a", 0, 64)]), "length change");
    }

    #[test]
    fn persist_load_round_trips_and_clears_dirty() {
        let (eng, dir) = engine("roundtrip");
        let s = store_with(&[
            (0xdead_beef_dead_beef, &[(1500, 300), (2800, 450)]),
            (42, &[(10, 5)]),
        ]);
        assert!(s.dirty());
        s.persist(&eng).unwrap();
        assert!(!s.dirty());

        let back = ProfileStore::load(&eng).unwrap().expect("slot exists");
        assert_eq!(back.len(), 2);
        let p = back.get(0xdead_beef_dead_beef).unwrap();
        assert_eq!(
            p.units,
            vec![
                ProfileUnit { consume_us: 1500, fetch_us: 300 },
                ProfileUnit { consume_us: 2800, fetch_us: 450 },
            ]
        );
        assert_eq!(back.get(42).unwrap().units.len(), 1);
        assert!(back.get(7).is_none());

        // Re-persisting into the existing slot (same capacity) works.
        back.record(7, StepProfile { units: vec![ProfileUnit { consume_us: 9, fetch_us: 1 }] });
        back.persist(&eng).unwrap();
        assert_eq!(ProfileStore::load(&eng).unwrap().unwrap().len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_slot_loads_none_and_corruption_is_structured() {
        let (eng, dir) = engine("corrupt");
        assert!(ProfileStore::load(&eng).unwrap().is_none());

        let s = store_with(&[(1, &[(100, 20)])]);
        s.persist(&eng).unwrap();
        // Flip a payload byte: checksum must catch it.
        let cap = eng.len_of(PROFILE_KEY).unwrap();
        let mut buf = vec![0u8; cap];
        eng.read(PROFILE_KEY, &mut buf).unwrap();
        buf[HEADER + 2] ^= 0x40;
        eng.write(PROFILE_KEY, &buf).unwrap();
        let err = ProfileStore::load(&eng).unwrap_err().to_string();
        assert!(err.contains("checksum"), "got: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
