//! Dynamic loss scaler — DeepSpeed/Apex semantics.
//!
//! fp16 gradients underflow without scaling and overflow with too much
//! of it, so the scale adapts: halve on overflow (and skip the step),
//! double after `growth_interval` consecutive clean steps.  The §III-C
//! overflow check is what feeds `update`.

#[derive(Debug, Clone)]
pub struct LossScaler {
    scale: f64,
    growth_interval: usize,
    good_steps: usize,
    min_scale: f64,
    max_scale: f64,
    /// Counters for reporting.
    pub overflows: u64,
    pub growths: u64,
}

impl LossScaler {
    pub fn new(init_scale: f64, growth_interval: usize) -> Self {
        Self {
            scale: init_scale,
            growth_interval: growth_interval.max(1),
            good_steps: 0,
            min_scale: 1.0,
            max_scale: 2f64.powi(24),
            overflows: 0,
            growths: 0,
        }
    }

    /// Scaler for bf16 runs: fixed at 1.0 (no overflow checks needed).
    pub fn disabled() -> Self {
        let mut s = Self::new(1.0, usize::MAX);
        s.max_scale = 1.0;
        s
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Full dynamic state for checkpointing: (scale, good_steps,
    /// overflows, growths).  Bounds/interval are config, not state.
    pub fn snapshot(&self) -> (f64, usize, u64, u64) {
        (self.scale, self.good_steps, self.overflows, self.growths)
    }

    /// Restore a [`LossScaler::snapshot`] onto a freshly-configured
    /// scaler — resume continues the exact growth/backoff sequence.
    pub fn restore(&mut self, snap: (f64, usize, u64, u64)) {
        self.scale = snap.0.clamp(self.min_scale, self.max_scale);
        self.good_steps = snap.1;
        self.overflows = snap.2;
        self.growths = snap.3;
    }

    /// Feed the overflow verdict for this step. Returns true if the
    /// optimizer step should be SKIPPED.
    pub fn update(&mut self, overflowed: bool) -> bool {
        if overflowed {
            self.overflows += 1;
            self.good_steps = 0;
            self.scale = (self.scale / 2.0).max(self.min_scale);
            true
        } else {
            self.good_steps += 1;
            if self.good_steps >= self.growth_interval && self.scale < self.max_scale {
                self.scale = (self.scale * 2.0).min(self.max_scale);
                self.good_steps = 0;
                self.growths += 1;
            }
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn halves_on_overflow_and_skips() {
        let mut s = LossScaler::new(65536.0, 100);
        assert!(s.update(true));
        assert_eq!(s.scale(), 32768.0);
        assert!(s.update(true));
        assert_eq!(s.scale(), 16384.0);
    }

    #[test]
    fn grows_after_interval() {
        let mut s = LossScaler::new(1024.0, 3);
        assert!(!s.update(false));
        assert!(!s.update(false));
        assert_eq!(s.scale(), 1024.0);
        assert!(!s.update(false));
        assert_eq!(s.scale(), 2048.0);
        assert_eq!(s.growths, 1);
    }

    #[test]
    fn overflow_resets_growth_counter() {
        let mut s = LossScaler::new(1024.0, 2);
        s.update(false);
        s.update(true); // reset
        s.update(false);
        assert_eq!(s.scale(), 512.0, "no growth yet");
        s.update(false);
        assert_eq!(s.scale(), 1024.0);
    }

    #[test]
    fn floor_at_one() {
        let mut s = LossScaler::new(2.0, 10);
        s.update(true);
        s.update(true);
        s.update(true);
        assert_eq!(s.scale(), 1.0);
    }

    #[test]
    fn disabled_is_inert() {
        let mut s = LossScaler::disabled();
        for _ in 0..1000 {
            s.update(false);
        }
        assert_eq!(s.scale(), 1.0);
    }
}
