//! SSD activation spill — the SSDTrain integration point (§II-B1).
//!
//! The paper positions activation offloading to SSD as complementary:
//! "activation offloading techniques, such as those in SSDTrain, can
//! potentially be integrated with model state offloading systems".
//! This store implements that integration: checkpoints go to pinned
//! host slots up to a byte budget; beyond it they *spill to the NVMe
//! engine* (fp16), extending trainable context past what Eq. 1 lets
//! host memory hold.  Fetch order is backward-pass order (LIFO-ish),
//! so the spilled tail streams back just in time.

use std::sync::Arc;

use crate::dtype::{f16_bytes_to_f32s, f32s_to_f16_bytes};
use crate::pinned::{Cat, HostAllocator, HostRegion};
use crate::ssd::NvmeEngine;

enum Slot {
    Host(HostRegion),
    Ssd { key: String },
}

pub struct SpillingActivationStore {
    slots: Vec<Slot>,
    occupied: Vec<bool>,
    elems: usize,
    engine: Arc<dyn NvmeEngine>,
    /// Bytes of host budget remaining at construction time.
    pub host_slots: usize,
    pub spilled_slots: usize,
}

impl SpillingActivationStore {
    /// `host_budget_bytes` caps pinned checkpoint memory; the rest of
    /// the `layers` checkpoints live on the SSD.
    pub fn new(
        layers: usize,
        elems: usize,
        host_budget_bytes: usize,
        alloc: &dyn HostAllocator,
        engine: Arc<dyn NvmeEngine>,
    ) -> Self {
        let bytes_per = elems * 2;
        let host_slots = (host_budget_bytes / bytes_per.max(1)).min(layers);
        let mut slots = Vec::with_capacity(layers);
        for i in 0..layers {
            if i < host_slots {
                slots.push(Slot::Host(alloc.alloc(bytes_per, Cat::ActCkpt)));
            } else {
                slots.push(Slot::Ssd { key: format!("actckpt/{i}") });
            }
        }
        Self {
            slots,
            occupied: vec![false; layers],
            elems,
            engine,
            host_slots,
            spilled_slots: layers - host_slots,
        }
    }

    pub fn offload(&mut self, layer: usize, h: &[f32]) -> anyhow::Result<()> {
        assert_eq!(h.len(), self.elems);
        anyhow::ensure!(!self.occupied[layer], "layer {layer} already checkpointed");
        match &mut self.slots[layer] {
            Slot::Host(region) => f32s_to_f16_bytes(h, region.as_mut_slice()),
            Slot::Ssd { key } => {
                let mut bytes = vec![0u8; h.len() * 2];
                f32s_to_f16_bytes(h, &mut bytes);
                self.engine.write(key, &bytes)?;
            }
        }
        self.occupied[layer] = true;
        Ok(())
    }

    pub fn fetch(&mut self, layer: usize) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.occupied[layer], "layer {layer} checkpoint missing");
        let mut out = vec![0f32; self.elems];
        match &self.slots[layer] {
            Slot::Host(region) => f16_bytes_to_f32s(region.as_slice(), &mut out),
            Slot::Ssd { key } => {
                let mut bytes = vec![0u8; self.elems * 2];
                self.engine.read(key, &mut bytes)?;
                f16_bytes_to_f32s(&bytes, &mut out);
            }
        }
        self.occupied[layer] = false;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pinned::{AlignedAllocator, MemoryTracker, Mode};
    use crate::ssd::DirectEngine;

    fn mk(budget: usize) -> (SpillingActivationStore, std::path::PathBuf, Arc<MemoryTracker>) {
        let dir =
            std::env::temp_dir().join(format!("ma-spill-{budget}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 24, 1).unwrap());
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(Mode::Real, tracker.clone());
        let store =
            SpillingActivationStore::new(8, 1024, budget, &Arc::clone(&alloc), engine);
        (store, dir, tracker)
    }

    #[test]
    fn splits_host_and_ssd_by_budget() {
        // 1024 elems * 2B = 2 KiB/slot; budget 3 slots' worth (rounded
        // up to pages by the allocator, budget math uses raw bytes)
        let (store, dir, tracker) = mk(3 * 2048);
        assert_eq!(store.host_slots, 3);
        assert_eq!(store.spilled_slots, 5);
        assert!(tracker.peak(crate::pinned::Cat::ActCkpt) >= 3 * 2048);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_through_both_tiers() {
        let (mut store, dir, _) = mk(2 * 2048);
        for layer in 0..8 {
            // f16-exact values: integers below 2048
            let h: Vec<f32> = (0..1024).map(|i| (layer + i) as f32).collect();
            store.offload(layer, &h).unwrap();
        }
        for layer in (0..8).rev() {
            let h = store.fetch(layer).unwrap();
            assert_eq!(h[0], layer as f32, "layer {layer}");
            assert_eq!(h[1023], (layer + 1023) as f32);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn zero_budget_spills_everything() {
        let (mut store, dir, tracker) = mk(0);
        assert_eq!(store.host_slots, 0);
        let h = vec![1.5f32; 1024];
        store.offload(0, &h).unwrap();
        assert_eq!(store.fetch(0).unwrap()[0], 1.5);
        assert_eq!(tracker.peak(crate::pinned::Cat::ActCkpt), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn double_offload_rejected() {
        let (mut store, dir, _) = mk(1 << 20);
        store.offload(2, &vec![0.0; 1024]).unwrap();
        assert!(store.offload(2, &vec![0.0; 1024]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
