//! SSD activation spill — the SSDTrain integration point (§II-B1).
//!
//! The paper positions activation offloading to SSD as complementary:
//! "activation offloading techniques, such as those in SSDTrain, can
//! potentially be integrated with model state offloading systems".
//! This store implements that integration: checkpoints lease pinned
//! host slots from the [`PinnedArena`] up to a byte budget; beyond it
//! (or when the arena's own global budget refuses) they *spill to the
//! NVMe engine* (fp16), extending trainable context past what Eq. 1
//! lets host memory hold.
//!
//! Arena leases make the host tier elastic: fetching a host checkpoint
//! drops its lease, so the slot is immediately reusable by a later
//! offload (and by the next step, recycled through the arena's free
//! extents) instead of being parked for the store's lifetime.
//!
//! Spill I/O rides the async queue:
//!
//! - offloads `submit_write` and return immediately — the forward pass
//!   never blocks on a spill write;
//! - fetches chain read-after-write on the executor and are *prefetched*
//!   one layer ahead in backward order, so the spilled tail streams
//!   back just in time;
//! - every second the compute thread still blocks in [`Self::fetch`]
//!   is recorded and surfaced via [`Self::wait_secs`], which the
//!   trainer folds into `StepMetrics::io_wait_secs` (previously these
//!   stalls were invisible to the metrics — a ROADMAP item).

use std::sync::Arc;
use std::time::Instant;

use crate::dtype::{f16_bytes_to_f32s, f32s_to_f16_bytes};
use crate::metrics::HostCopyMeter;
use crate::pinned::{Cat, Lease, PinnedArena};
use crate::runtime::{F32Staging, TensorBuf};
use crate::ssd::{AsyncEngine, IoHandle};

enum Slot {
    Empty,
    Host(Lease),
    /// Spilled; the write may still be in flight on the queue.
    Ssd { key: String, pending_write: Option<IoHandle<Vec<u8>>> },
}

pub struct SpillingActivationStore {
    slots: Vec<Slot>,
    elems: usize,
    bytes_per: usize,
    /// Byte budget for live host (pinned) checkpoints.
    host_budget: usize,
    host_bytes_live: usize,
    arena: Arc<PinnedArena>,
    aio: AsyncEngine,
    /// Checkpoints served from pinned host slots (cumulative).
    pub host_slots: usize,
    /// Checkpoints spilled to the SSD (cumulative).
    pub spilled_slots: usize,
    /// In-flight prefetched read for the next spilled fetch.
    prefetched: Option<(usize, IoHandle<Vec<u8>>)>,
    wait_ns: u64,
    /// Charged when a fetch decode has to stage in an owned vector
    /// instead of a pinned lease.
    meter: HostCopyMeter,
}

impl SpillingActivationStore {
    /// The `host_budget = ∞` degenerate case: every checkpoint stays
    /// in pinned host memory (modulo the arena's own global budget) —
    /// what the deleted non-spilling `ActivationStore` used to be.
    /// One store, one code path; the budget is the only difference.
    pub fn unbounded(
        layers: usize,
        elems: usize,
        arena: Arc<PinnedArena>,
        aio: AsyncEngine,
        meter: HostCopyMeter,
    ) -> Self {
        Self::new(layers, elems, usize::MAX, arena, aio, meter)
    }

    /// `host_budget_bytes` caps pinned checkpoint memory; checkpoints
    /// beyond it live on the SSD.  Nothing is pinned up front — slots
    /// lease on offload and release on fetch.
    pub fn new(
        layers: usize,
        elems: usize,
        host_budget_bytes: usize,
        arena: Arc<PinnedArena>,
        aio: AsyncEngine,
        meter: HostCopyMeter,
    ) -> Self {
        Self {
            slots: (0..layers).map(|_| Slot::Empty).collect(),
            elems,
            bytes_per: elems * 2,
            host_budget: host_budget_bytes,
            host_bytes_live: 0,
            arena,
            aio,
            host_slots: 0,
            spilled_slots: 0,
            prefetched: None,
            wait_ns: 0,
            meter,
        }
    }

    pub fn offload(&mut self, layer: usize, h: &[f32]) -> anyhow::Result<()> {
        assert_eq!(h.len(), self.elems);
        anyhow::ensure!(
            matches!(self.slots[layer], Slot::Empty),
            "layer {layer} already checkpointed"
        );
        if self.host_bytes_live + self.bytes_per <= self.host_budget {
            // within the store budget; the arena may still refuse under
            // its global cap — degrade to a spill, never abort
            if let Ok(mut lease) = self.arena.lease(self.bytes_per, Cat::ActCkpt) {
                f32s_to_f16_bytes(h, lease.as_mut_slice());
                self.host_bytes_live += self.bytes_per;
                self.host_slots += 1;
                self.slots[layer] = Slot::Host(lease);
                return Ok(());
            }
        }
        let key = format!("actckpt/{layer}");
        let mut bytes = self.arena.take_bytes(self.bytes_per, Cat::ActCkpt);
        f32s_to_f16_bytes(h, &mut bytes);
        let write = self.aio.submit_write(key.clone(), bytes);
        self.spilled_slots += 1;
        self.slots[layer] = Slot::Ssd { key, pending_write: Some(write) };
        Ok(())
    }

    /// Fetch a checkpoint back for recomputation.  The f16→f32 decode
    /// lands in a fresh pinned `SwapBuf` lease frozen into a read-only
    /// [`TensorBuf`] view — the recomputation kernel's `h` argument
    /// uploads those bytes verbatim, no further staging copy.  A
    /// refused lease degrades to an owned scratch vector (charged to
    /// the copy meter); data is bit-identical either way.
    pub fn fetch(&mut self, layer: usize) -> anyhow::Result<TensorBuf> {
        // the shared lease-else-owned policy, under `Cat::SwapBuf` —
        // the scratch tier the trainer reclaims spent buffers into, so
        // even the degraded path recycles instead of allocating
        let mut dst =
            F32Staging::take(&self.arena, Cat::SwapBuf, self.elems, &self.meter);
        self.fetch_into(layer, dst.as_mut_slice())?;
        Ok(dst.freeze())
    }

    /// [`Self::fetch`] decoding into a caller-provided destination —
    /// typically a pinned lease's f32 view, so the recomputation
    /// argument is staged once, in upload-ready memory, with no owned
    /// intermediate (the zero-copy boundary's consumption pattern).
    pub fn fetch_into(&mut self, layer: usize, out: &mut [f32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            out.len() == self.elems,
            "layer {layer} destination holds {} elems, expected {}",
            out.len(),
            self.elems
        );
        anyhow::ensure!(
            !matches!(self.slots[layer], Slot::Empty),
            "layer {layer} checkpoint missing"
        );
        let slot = std::mem::replace(&mut self.slots[layer], Slot::Empty);
        match slot {
            Slot::Empty => unreachable!("checked above"),
            Slot::Host(lease) => {
                f16_bytes_to_f32s(lease.as_slice(), out);
                self.host_bytes_live -= self.bytes_per;
                // lease drops here: the host slot returns to the arena
                // for reuse by a later offload
            }
            Slot::Ssd { key, pending_write } => {
                let handle = match self.prefetched.take() {
                    Some((l, h)) if l == layer => h,
                    other => {
                        self.prefetched = other;
                        self.spawn_read(key, pending_write)
                    }
                };
                let bytes = self.await_read(handle)?;
                f16_bytes_to_f32s(&bytes, out);
                self.arena.put_bytes(bytes, Cat::ActCkpt);
            }
        }
        self.maybe_prefetch(layer);
        Ok(())
    }

    /// Seconds the caller blocked inside [`Self::fetch`] waiting on
    /// spill I/O (the stall the prefetch could not hide).
    pub fn wait_secs(&self) -> f64 {
        self.wait_ns as f64 / 1e9
    }

    /// Queue a read of `key`, chained after its pending write when one
    /// is still in flight (read-after-write on the executor, off the
    /// compute thread).
    fn spawn_read(
        &self,
        key: String,
        pending_write: Option<IoHandle<Vec<u8>>>,
    ) -> IoHandle<Vec<u8>> {
        let mut buf = self.arena.take_bytes(self.bytes_per, Cat::ActCkpt);
        let (completer, handle) = IoHandle::pair();
        let eng = Arc::clone(self.aio.engine());
        let arena = Arc::clone(&self.arena);
        self.aio.executor().submit(move || {
            if let Some(w) = pending_write {
                match w.wait() {
                    Ok(spent) => arena.put_bytes(spent, Cat::ActCkpt),
                    Err(e) => {
                        completer.complete(Err(e));
                        return;
                    }
                }
            }
            let res = eng.read(&key, &mut buf).map(move |()| buf);
            completer.complete(res);
        });
        handle
    }

    fn await_read(&mut self, h: IoHandle<Vec<u8>>) -> anyhow::Result<Vec<u8>> {
        let t0 = Instant::now();
        let r = h.wait();
        self.wait_ns += t0.elapsed().as_nanos() as u64;
        r
    }

    /// Start streaming the next spilled checkpoint below `below` —
    /// backward-pass fetch order is descending, so that is the one the
    /// compute thread will want next.
    fn maybe_prefetch(&mut self, below: usize) {
        if self.prefetched.is_some() {
            return;
        }
        for l in (0..below).rev() {
            if !matches!(self.slots[l], Slot::Ssd { .. }) {
                continue;
            }
            let slot = std::mem::replace(&mut self.slots[l], Slot::Empty);
            let Slot::Ssd { key, pending_write } = slot else {
                unreachable!("checked above")
            };
            let h = self.spawn_read(key.clone(), pending_write);
            self.slots[l] = Slot::Ssd { key, pending_write: None };
            self.prefetched = Some((l, h));
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::pinned::{AlignedAllocator, ArenaConfig, MemoryTracker, Mode};
    use crate::ssd::{DirectEngine, NvmeEngine};

    fn mk(
        budget: usize,
    ) -> (SpillingActivationStore, std::path::PathBuf, Arc<MemoryTracker>, Arc<PinnedArena>)
    {
        let dir = std::env::temp_dir()
            .join(format!("ma-spill-{budget}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 24, 1).unwrap());
        let arena = test_arena(Mode::Real);
        let tracker = Arc::clone(arena.tracker());
        let aio = AsyncEngine::new(engine, 2);
        let store = SpillingActivationStore::new(
            8,
            1024,
            budget,
            Arc::clone(&arena),
            aio,
            HostCopyMeter::new(),
        );
        (store, dir, tracker, arena)
    }

    #[test]
    fn splits_host_and_ssd_by_budget() {
        // 1024 elems * 2B = 2 KiB/slot; budget 3 slots' worth (leases
        // are page-rounded by the arena, budget math uses raw bytes)
        let (mut store, dir, tracker, _arena) = mk(3 * 2048);
        for layer in 0..8 {
            store.offload(layer, &vec![0.5f32; 1024]).unwrap();
        }
        assert_eq!(store.host_slots, 3);
        assert_eq!(store.spilled_slots, 5);
        assert!(tracker.peak(Cat::ActCkpt) >= 3 * 2048);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_through_both_tiers() {
        let (mut store, dir, _, _) = mk(2 * 2048);
        for layer in 0..8 {
            // f16-exact values: integers below 2048
            let h: Vec<f32> = (0..1024).map(|i| (layer + i) as f32).collect();
            store.offload(layer, &h).unwrap();
        }
        for layer in (0..8).rev() {
            let h = store.fetch(layer).unwrap();
            assert!(h.is_view(), "layer {layer}: fetch not lease-backed");
            let h = h.as_f32();
            assert_eq!(h[0], layer as f32, "layer {layer}");
            assert_eq!(h[1023], (layer + 1023) as f32);
        }
        assert_eq!(store.meter.bytes(), 0, "zero-copy fetches charged the meter");
        // the prefetch window only ever held one in-flight read, and
        // every stall was attributed
        assert!(store.wait_secs() >= 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn zero_budget_spills_everything() {
        let (mut store, dir, tracker, arena) = mk(0);
        let h = vec![1.5f32; 1024];
        store.offload(0, &h).unwrap();
        assert_eq!(store.host_slots, 0);
        assert_eq!(store.spilled_slots, 1);
        assert_eq!(store.fetch(0).unwrap().as_f32()[0], 1.5);
        // no pinned checkpoint slot was ever leased; the only ActCkpt
        // charge is recycled spill staging (bounded by two buffers)
        assert_eq!(arena.watermark(Cat::ActCkpt).requested_peak, 0);
        assert!(tracker.peak(Cat::ActCkpt) <= 2 * 2048);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unbounded_store_is_the_old_activation_store() {
        // the host_budget = ∞ degenerate case: everything stays in
        // pinned host slots, nothing spills, f16-exact roundtrip
        let (_, dir, tracker, arena) = mk(0); // engine/arena plumbing only
        let engine: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir.join("unb"), 1, 1 << 24, 1).unwrap());
        let aio = AsyncEngine::new(engine, 1);
        let mut store = SpillingActivationStore::unbounded(
            4,
            256,
            Arc::clone(&arena),
            aio,
            HostCopyMeter::new(),
        );
        let h: Vec<f32> = (0..256).map(|i| (i as f32) / 16.0).collect();
        store.offload(2, &h).unwrap();
        assert_eq!(store.host_slots, 1);
        assert_eq!(store.spilled_slots, 0);
        let back = store.fetch(2).unwrap();
        assert_eq!(back.as_f32(), h.as_slice());
        // a second fetch of the same layer is a structured error
        assert!(store.fetch(2).is_err());
        let _ = tracker;
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fetch_into_decodes_into_a_lease_view() {
        // the zero-copy consumption pattern: decode straight into a
        // pinned lease, freeze, upload the view
        let (mut store, dir, _, arena) = mk(1 << 20);
        let h: Vec<f32> = (0..1024).map(|i| i as f32).collect();
        store.offload(1, &h).unwrap();
        let mut dst = arena.lease(1024 * 4, crate::pinned::Cat::SwapBuf).unwrap();
        store.fetch_into(1, dst.as_f32_mut()).unwrap();
        let view = crate::runtime::TensorBuf::from_lease(dst).unwrap();
        assert_eq!(view.as_f32(), h.as_slice());
        // wrong-size destinations error before touching the slot
        store.offload(2, &h).unwrap();
        let mut short = vec![0f32; 8];
        assert!(store.fetch_into(2, &mut short).is_err());
        assert!(store.fetch_into(2, &mut vec![0f32; 1024]).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eq1_accounting_difference_between_allocators() {
        // Eq. 1's P_m term: pow2 rounding on non-pow2 checkpoint
        // sizes — ported from the deleted non-spilling store; the
        // unbounded spilling store leases the same per-layer slots
        let elems = 5000; // 10'000 B -> pow2 16384
        let mk_arena = |caching: bool| {
            let tr = Arc::new(MemoryTracker::new());
            let alloc: Arc<dyn crate::pinned::HostAllocator> = if caching {
                Arc::new(crate::pinned::CachingAllocator::new(Mode::Real, tr.clone()))
            } else {
                Arc::new(AlignedAllocator::new(Mode::Real, tr.clone()))
            };
            (PinnedArena::new(alloc, ArenaConfig::default()), tr)
        };
        let mut peaks = Vec::new();
        for caching in [true, false] {
            let dir = std::env::temp_dir()
                .join(format!("ma-spill-eq1-{caching}-{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let engine: Arc<dyn NvmeEngine> =
                Arc::new(DirectEngine::new(&dir, 1, 1 << 24, 1).unwrap());
            let (arena, tracker) = mk_arena(caching);
            let aio = AsyncEngine::new(engine, 1);
            let mut store = SpillingActivationStore::unbounded(
                8,
                elems,
                Arc::clone(&arena),
                aio,
                HostCopyMeter::new(),
            );
            let h = vec![0.5f32; elems];
            for layer in 0..8 {
                store.offload(layer, &h).unwrap();
            }
            assert_eq!(store.host_slots, 8, "unbounded store must not spill");
            // the pow2 excess lands under Cat::PinnedOverhead, so the
            // policies differ in total, not in the ActCkpt charge
            peaks.push(tracker.peak_total());
            std::fs::remove_dir_all(&dir).ok();
        }
        // pow2 caching policy rounds each slot up; alignment-free does
        // not — the accounting difference Fig. 8 measures
        assert!(peaks[0] > peaks[1], "caching {} vs aligned {}", peaks[0], peaks[1]);
    }

    #[test]
    fn double_offload_rejected() {
        let (mut store, dir, _, _) = mk(1 << 20);
        store.offload(2, &vec![0.0; 1024]).unwrap();
        assert!(store.offload(2, &vec![0.0; 1024]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fetched_host_slot_is_reusable() {
        // budget of exactly one slot: offload → fetch → offload again
        // must land on the host both times, recycling the same lease
        // through the arena
        let (mut store, dir, _, arena) = mk(2048);
        store.offload(0, &vec![1.0f32; 1024]).unwrap();
        assert_eq!(store.host_slots, 1);
        assert_eq!(store.fetch(0).unwrap().as_f32()[0], 1.0);
        store.offload(1, &vec![2.0f32; 1024]).unwrap();
        assert_eq!(store.host_slots, 2, "freed budget not reused");
        assert_eq!(store.spilled_slots, 0);
        // one page of ActCkpt backing total: the second offload
        // recycled the first slot's extent
        assert_eq!(arena.watermark(Cat::ActCkpt).charged_peak, 4096);
        assert_eq!(store.fetch(1).unwrap().as_f32()[0], 2.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn arena_budget_refusal_degrades_to_spill() {
        // the arena cap (not the store budget) is the limiter here
        let dir = std::env::temp_dir().join(format!("ma-spill-cap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 24, 1).unwrap());
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(Mode::Real, tracker);
        let arena = PinnedArena::new(
            Arc::new(alloc),
            ArenaConfig { budget_bytes: Some(4096), ..Default::default() },
        );
        let aio = AsyncEngine::new(engine, 1);
        let meter = HostCopyMeter::new();
        let mut store = SpillingActivationStore::new(
            4,
            1024,
            usize::MAX,
            Arc::clone(&arena),
            aio,
            meter.clone(),
        );
        store.offload(0, &vec![1.0f32; 1024]).unwrap(); // fills the 4 KiB cap
        store.offload(1, &vec![2.0f32; 1024]).unwrap(); // must spill
        assert_eq!(store.host_slots, 1);
        assert_eq!(store.spilled_slots, 1);
        assert_eq!(store.fetch(1).unwrap().as_f32()[0], 2.0);
        assert_eq!(store.fetch(0).unwrap().as_f32()[0], 1.0);
        // the 4 KiB cap also refuses the f32 decode leases: both
        // fetches degraded to owned staging, and both were metered
        assert_eq!(meter.bytes(), 2 * 1024 * 4);
        std::fs::remove_dir_all(&dir).ok();
    }
}
