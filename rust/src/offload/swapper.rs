//! Parameter swapper: the SSD→host→"GPU" prefetch pipeline (§IV-A),
//! rebuilt as a windowed async pipeline over the multi-queue layer,
//! with the f16→f32 upconvert split onto the compute-side stage pool.
//!
//! The seed swapper was one worker thread fetching one tensor at a
//! time — the compute thread could overlap with at most a single
//! in-flight transfer.  Now the swapper keeps a *window* of `depth`
//! fetches in flight on the shared [`IoExecutor`] and reorders
//! completions back into plan order; each fetch is itself two chained
//! stages, so a queue worker is back on the device as soon as the
//! bytes are staged instead of decoding them first (the PR-1 ROADMAP
//! item, resolved):
//!
//! ```text
//!        plan (layer-order tensor schedule)
//!          │ submit (window: `depth` in flight)
//!          ▼
//!  [ IoExecutor submission queue ] ──► worker: lease pool buffer
//!          │   out-of-order execution          read fp16 from NVMe
//!          ▼                                   chain ↓
//!  [ StageExecutor (compute pool) ] ──► worker: upconvert → pinned
//!          │                                    SwapBuf lease, freeze;
//!          ▼                                    release pool buffer
//!  [ per-fetch completion handles ]
//!          │ FIFO wait  (in-order delivery)
//!          ▼
//!  compute thread: `next()` → Fetched { desc, data: TensorBuf }
//!          │ TensorBuf::as_value() uploads the lease bytes verbatim
//!          ▼
//!  [ PJRT `Runtime::run` ] — zero fp32 host-to-host copies; dropping
//!          the view recycles the lease extent in the arena
//! ```
//!
//! Delivery is **lease-backed**: the f16→f32 upconvert decodes
//! straight into a pinned [`PinnedArena`] lease, which freezes into a
//! shared read-only [`TensorBuf`] view — the very bytes
//! `Runtime::run` uploads.  Only when the arena refuses the lease
//! (budget pressure, Virtual mode) does the fetch degrade to an owned
//! scratch vector, charging the staged bytes to the shared
//! [`HostCopyMeter`] (surfaced as `StepMetrics::host_copy_bytes`);
//! data is bit-identical either way.
//!
//! Backpressure is two-layer, as before: the parameter pool bounds
//! bytes staged in pinned memory (workers block in `acquire`), and the
//! window bounds ready-but-unconsumed tensors.  A staged buffer now
//! crosses the queue→stage boundary, but stage workers never block on
//! the pool, so every held buffer is always on a path to release — a
//! full pool can stall queue workers in `acquire`, never deadlock
//! them.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::bufpool::{ParamBufferPool, PoolBuf};
use crate::dtype::f16_bytes_to_f32s;
use crate::metrics::HostCopyMeter;
use crate::pinned::{Cat, PinnedArena};
use crate::runtime::{F32Staging, TensorBuf};
use crate::ssd::{IoExecutor, IoHandle, NvmeEngine};
use crate::tensors::TensorDesc;
use crate::util::stage::StageExecutor;

/// The swapper's staging tier: vends pinned `Cat::SwapBuf` leases for
/// zero-copy delivery, and recycles owned f32 vectors for everything
/// that must stay heap-backed (PJRT result buffers, budget-degraded
/// fetches).  Both tiers ride the arena, so idle bytes sit on the
/// shared ledger and inside the pinned budget; the [`HostCopyMeter`]
/// records every byte the owned tier stages on the boundary path.
pub struct F32Scratch {
    arena: Arc<PinnedArena>,
    meter: HostCopyMeter,
}

impl F32Scratch {
    pub fn new(arena: Arc<PinnedArena>) -> Self {
        Self::with_meter(arena, HostCopyMeter::new())
    }

    /// Share an existing copy meter (the engine-wide one, so swapper,
    /// spill store, and trainer report into one counter).
    pub fn with_meter(arena: Arc<PinnedArena>, meter: HostCopyMeter) -> Self {
        Self { arena, meter }
    }

    /// Take an `n`-element staging destination: a pinned lease when
    /// the arena grants one (zero-copy tier), else an owned scratch
    /// vector charged to the meter — [`F32Staging::take`]'s shared
    /// degradation policy under `Cat::SwapBuf`.
    pub fn take_staging(&self, n: usize) -> F32Staging {
        F32Staging::take(&self.arena, Cat::SwapBuf, n, &self.meter)
    }

    /// Take a vector of exactly `n` elements (recycled best-fit when
    /// possible).
    pub fn take(&self, n: usize) -> Vec<f32> {
        self.arena.take_f32(n, Cat::SwapBuf)
    }

    /// Return a spent vector to the pool (dropped past the arena's
    /// bounds or budget).
    pub fn put(&self, v: Vec<f32>) {
        self.arena.put_f32(v, Cat::SwapBuf)
    }

    /// Recycle a spent tensor: owned vectors return to the pool; lease
    /// views simply drop, releasing their extent back to the arena's
    /// free list (same recycling, different tier).
    pub fn put_buf(&self, buf: TensorBuf) {
        if let TensorBuf::F32(v) = buf {
            self.put(v);
        }
    }

    /// The boundary copy counter this scratch charges on degraded
    /// (owned-tier) staging.
    pub fn meter(&self) -> &HostCopyMeter {
        &self.meter
    }

    /// Vectors currently pooled (test/introspection hook).
    pub fn pooled(&self) -> usize {
        self.arena.pooled_f32(Cat::SwapBuf)
    }

    pub fn arena(&self) -> &Arc<PinnedArena> {
        &self.arena
    }
}

/// One fetched tensor, ready for compute: a lease-backed view on the
/// zero-copy path, an owned vector when the arena degraded the fetch.
pub struct Fetched {
    pub desc: TensorDesc,
    pub data: TensorBuf,
}

/// Everything a fetch job needs; shared by value-cloned `Arc`.
struct FetchCtx {
    engine: Arc<dyn NvmeEngine>,
    pool: Arc<dyn ParamBufferPool>,
    exec: Arc<IoExecutor>,
    /// Compute-side pool the upconvert stage chains onto.
    stage: Arc<StageExecutor>,
    scratch: Arc<F32Scratch>,
    key_of: Box<dyn Fn(&TensorDesc) -> String + Send + Sync>,
}

pub struct Swapper {
    ctx: Arc<FetchCtx>,
    /// FIFO reorder window: front = next tensor in plan order.
    inflight: VecDeque<IoHandle<Fetched>>,
    /// Plan suffix not yet submitted.
    pending: std::vec::IntoIter<TensorDesc>,
    depth: usize,
    /// Nanoseconds `next()` spent blocked on completions — the I/O
    /// the pipeline could *not* hide behind compute.
    wait_ns: u64,
}

impl Swapper {
    /// Start prefetching `plan` in order on `exec`, chaining each
    /// fetch's f16→f32 upconvert onto `stage` (the compute-side pool).
    /// `key_of` maps a tensor to its SSD key (rank shards use
    /// partition keys). `depth` is the pipeline window: fetches kept
    /// in flight ahead of compute, on top of the pool's own in-flight
    /// bound.
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        engine: Arc<dyn NvmeEngine>,
        pool: Arc<dyn ParamBufferPool>,
        exec: Arc<IoExecutor>,
        stage: Arc<StageExecutor>,
        scratch: Arc<F32Scratch>,
        plan: Vec<TensorDesc>,
        key_of: impl Fn(&TensorDesc) -> String + Send + Sync + 'static,
        depth: usize,
    ) -> Self {
        let ctx = Arc::new(FetchCtx {
            engine,
            pool,
            exec,
            stage,
            scratch,
            key_of: Box::new(key_of),
        });
        let mut sw = Self {
            ctx,
            inflight: VecDeque::new(),
            pending: plan.into_iter(),
            depth: depth.max(1),
            wait_ns: 0,
        };
        sw.fill_window();
        sw
    }

    fn fill_window(&mut self) {
        while self.inflight.len() < self.depth {
            let Some(t) = self.pending.next() else { break };
            self.inflight.push_back(submit_fetch(&self.ctx, t));
        }
    }

    /// Blocking receive of the next tensor in plan order.  Completions
    /// arrive out of order on the executor; delivery is serialized by
    /// waiting the window FIFO.
    pub fn next(&mut self) -> anyhow::Result<Fetched> {
        let handle = self
            .inflight
            .pop_front()
            .ok_or_else(|| anyhow::anyhow!("swapper: plan exhausted"))?;
        // keep `depth` fetches in flight while we wait on this one
        self.fill_window();
        let t0 = Instant::now();
        let fetched = handle.wait();
        self.wait_ns += t0.elapsed().as_nanos() as u64;
        fetched
    }

    /// Tensors not yet delivered (in flight + unsubmitted).
    pub fn remaining(&self) -> usize {
        self.inflight.len() + self.pending.len()
    }

    /// Seconds the consumer spent stalled in [`Self::next`] — compare
    /// against engine-side busy time to get the overlap ratio.
    pub fn wait_secs(&self) -> f64 {
        self.wait_ns as f64 / 1e9
    }
}

// Dropping a `Swapper` mid-plan is safe without joining anything:
// in-flight jobs own `Arc`s to everything they touch, release their
// pool buffers themselves, and complete into slots nobody reads.

fn submit_fetch(ctx: &Arc<FetchCtx>, t: TensorDesc) -> IoHandle<Fetched> {
    let (completer, handle) = IoHandle::pair();
    let job_ctx = Arc::clone(ctx);
    ctx.exec.submit(move || {
        // stage 1 (NVMe queue): lease pinned staging + device read;
        // the queue worker is free again the moment the bytes landed
        let (buf, n) = match stage_read(&job_ctx, &t) {
            Ok(staged) => staged,
            Err(e) => {
                completer.complete(Err(e));
                return;
            }
        };
        // stage 2 (compute pool): decode off the I/O path, so this
        // upconvert overlaps the next tensor's device read
        let conv_ctx = Arc::clone(&job_ctx);
        job_ctx.stage.submit(move || {
            let result =
                upconvert(&conv_ctx, buf, n).map(|data| Fetched { desc: t, data });
            completer.complete(result);
        });
    });
    handle
}

/// Fetch stage 1: lease pinned staging from the pool and read the fp16
/// bytes into it.  On success the buffer stays held for the upconvert
/// stage; on error it is released here.
fn stage_read(ctx: &FetchCtx, t: &TensorDesc) -> anyhow::Result<(PoolBuf, usize)> {
    let key = (ctx.key_of)(t);
    let n = ctx
        .engine
        .len_of(&key)
        .ok_or_else(|| anyhow::anyhow!("missing tensor '{key}'"))?
        / 2;
    let buf = ctx.pool.acquire(t, crate::dtype::DType::F16)?;
    let mut staged_err = None;
    ctx.pool.with_buf(&buf, &mut |bytes| {
        if bytes.is_empty() {
            staged_err = Some(anyhow::anyhow!("virtual pool"));
            return;
        }
        if let Err(e) = ctx.engine.read(&key, &mut bytes[..n * 2]) {
            staged_err = Some(e);
        }
    });
    if let Some(e) = staged_err {
        ctx.pool.release(buf);
        return Err(e);
    }
    Ok((buf, n))
}

/// Fetch stage 2: f16→f32 upconvert from the staged pool buffer
/// straight into a pinned `SwapBuf` lease (frozen into a read-only
/// view — the upload source), then release the staging back to the
/// pool.  A refused lease degrades to an owned scratch vector, charged
/// to the copy meter: bit-identical data, one extra heap staging hop.
fn upconvert(ctx: &FetchCtx, buf: PoolBuf, n: usize) -> anyhow::Result<TensorBuf> {
    let mut dst = ctx.scratch.take_staging(n);
    ctx.pool.with_buf(&buf, &mut |bytes| {
        f16_bytes_to_f32s(&bytes[..n * 2], dst.as_mut_slice());
    });
    ctx.pool.release(buf);
    Ok(dst.freeze())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::bufpool::AdaptivePool;
    use crate::config::presets::SMOKE;
    use crate::dtype::f32s_to_f16_bytes;
    use crate::pinned::Mode;
    use crate::ssd::{DirectEngine, FaultyEngine};
    use crate::tensors::inventory;

    fn scratch() -> Arc<F32Scratch> {
        Arc::new(F32Scratch::new(test_arena(Mode::Real)))
    }

    fn stage() -> Arc<StageExecutor> {
        Arc::new(StageExecutor::new(2))
    }

    fn seeded_engine(tag: &str) -> (Arc<DirectEngine>, Vec<TensorDesc>, std::path::PathBuf)
    {
        let dir = std::env::temp_dir()
            .join(format!("ma-swap-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine = Arc::new(DirectEngine::new(&dir, 2, 1 << 24, 2).unwrap());
        let plan: Vec<_> = inventory(&SMOKE)
            .into_iter()
            .filter(|t| t.offloadable())
            .collect();
        for (i, t) in plan.iter().enumerate() {
            let vals = vec![i as f32 + 0.5; t.numel];
            let mut bytes = vec![0u8; t.numel * 2];
            f32s_to_f16_bytes(&vals, &mut bytes);
            engine.write(&format!("{}/fp16", t.name), &bytes).unwrap();
        }
        (engine, plan, dir)
    }

    fn pool(depth: usize) -> Arc<dyn ParamBufferPool> {
        Arc::new(
            AdaptivePool::new(&SMOKE, depth, crate::dtype::DType::F16, &test_arena(Mode::Real))
                .unwrap(),
        )
    }

    #[test]
    fn prefetch_delivers_in_order_with_correct_data() {
        let (engine, plan, dir) = seeded_engine("order");
        let mut sw = Swapper::start(
            engine,
            pool(2),
            Arc::new(IoExecutor::new(1)),
            stage(),
            scratch(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            2,
        );
        for (i, want) in plan.iter().enumerate() {
            let got = sw.next().unwrap();
            assert_eq!(got.desc.name, want.name, "order violated");
            assert!(got.data.is_view(), "fetch not lease-backed");
            assert!(got.data.as_f32().iter().all(|&x| x == i as f32 + 0.5));
        }
        assert_eq!(sw.remaining(), 0);
        assert!(sw.next().is_err(), "exhausted plan must error");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multiworker_window_preserves_plan_order() {
        // 4 executor workers, deep window: completions race, delivery
        // must still follow the plan with uncorrupted payloads.
        let (engine, plan, dir) = seeded_engine("mw");
        for depth in [1usize, 3, 8] {
            let mut sw = Swapper::start(
                engine.clone(),
                pool(depth.max(2)),
                Arc::new(IoExecutor::new(4)),
                stage(),
                scratch(),
                plan.clone(),
                |t| format!("{}/fp16", t.name),
                depth,
            );
            for (i, want) in plan.iter().enumerate() {
                let got = sw.next().unwrap();
                assert_eq!(got.desc.name, want.name, "depth {depth}: order violated");
                assert!(
                    got.data.as_f32().iter().all(|&x| x == i as f32 + 0.5),
                    "depth {depth}: tensor {i} corrupted"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_surfaces_error() {
        let dir = std::env::temp_dir().join(format!("ma-swap2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 20, 1).unwrap());
        let plan: Vec<_> = inventory(&SMOKE)
            .into_iter()
            .filter(|t| t.offloadable())
            .take(1)
            .collect();
        let mut sw = Swapper::start(
            engine,
            pool(1),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            plan,
            |t| format!("{}/fp16", t.name),
            1,
        );
        assert!(sw.next().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_injection_errors_surface_without_hanging() {
        // every read fails (writes already done) — each next() must
        // return Err promptly; dropping mid-plan must not deadlock.
        let (engine, plan, dir) = seeded_engine("faulty");
        let faulty: Arc<dyn NvmeEngine> = Arc::new(FaultyEngine::new(
            engine,
            1024, // fail every op
            11,
        ));
        let mut sw = Swapper::start(
            faulty,
            pool(2),
            Arc::new(IoExecutor::new(4)),
            stage(),
            scratch(),
            plan,
            |t| format!("{}/fp16", t.name),
            4,
        );
        assert!(sw.next().is_err());
        drop(sw); // window still has in-flight fetches
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partial_faults_deliver_good_prefix_then_error() {
        let (engine, plan, dir) = seeded_engine("pf");
        let faulty: Arc<dyn NvmeEngine> = Arc::new(FaultyEngine::new(engine, 200, 3));
        let mut sw = Swapper::start(
            faulty,
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            3,
        );
        // in-order delivery means results match the plan prefix until
        // the first injected fault; data before it must be correct
        for (i, want) in plan.iter().enumerate() {
            match sw.next() {
                Ok(got) => {
                    assert_eq!(got.desc.name, want.name);
                    assert!(got.data.as_f32().iter().all(|&x| x == i as f32 + 0.5));
                }
                Err(_) => break,
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lease_backed_fetches_count_zero_copies_and_recycle_extents() {
        let (engine, plan, dir) = seeded_engine("zc");
        let s = scratch();
        let mut sw = Swapper::start(
            engine,
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            Arc::clone(&s),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            2,
        );
        for _ in 0..plan.len() {
            let got = sw.next().unwrap();
            assert!(got.data.is_view());
            s.put_buf(got.data); // drops the view: extent recycles
        }
        assert_eq!(s.meter().bytes(), 0, "zero-copy path charged the meter");
        let st = s.arena().stats();
        assert_eq!(st.requested_bytes, 0, "fetch leases leaked");
        assert!(st.recycled > 0, "fetch leases never recycled");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn starved_arena_degrades_to_owned_vectors_and_meters_the_copies() {
        use crate::pinned::{AlignedAllocator, ArenaConfig, MemoryTracker, PinnedArena};
        let (engine, plan, dir) = seeded_engine("deg");
        // the *scratch* arena is starved (1 KiB budget refuses every
        // lease); the pool keeps its own unbounded arena so staging
        // still works
        let starved = PinnedArena::new(
            Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
            ArenaConfig { budget_bytes: Some(1024), ..Default::default() },
        );
        let s = Arc::new(F32Scratch::new(starved));
        let mut sw = Swapper::start(
            engine,
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            Arc::clone(&s),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            2,
        );
        let mut expect_bytes = 0u64;
        for (i, t) in plan.iter().enumerate() {
            let got = sw.next().unwrap();
            assert!(!got.data.is_view(), "starved arena still granted a lease");
            // bit-identical payload on the degraded path
            assert!(got.data.as_f32().iter().all(|&x| x == i as f32 + 0.5));
            expect_bytes += t.numel as u64 * 4;
            s.put_buf(got.data);
        }
        assert_eq!(s.meter().bytes(), expect_bytes, "copy accounting diverged");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scratch_recycles_vectors_through_the_arena() {
        // policy details (best-fit, size floor, byte bound, budget) are
        // proven in pinned::arena's tests; this covers the facade and
        // the ledger wiring
        let s = F32Scratch::new(test_arena(Mode::Real));
        let v = s.take(100);
        let cap = v.capacity();
        s.put(v);
        assert_eq!(s.pooled(), 1);
        assert_eq!(s.arena().tracker().current(Cat::SwapBuf) as usize, cap * 4);
        let v2 = s.take(80); // fits in the recycled allocation
        assert!(v2.capacity() >= cap.min(100));
        assert_eq!(v2.len(), 80);
        assert_eq!(s.pooled(), 0);
        assert_eq!(s.arena().tracker().current(Cat::SwapBuf), 0);
    }

}
