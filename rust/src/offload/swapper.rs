//! Parameter swapper: the SSD→host→"GPU" prefetch pipeline (§IV-A).
//!
//! A worker thread walks the fetch plan (the layer-order tensor
//! schedule): for each tensor it leases a staging buffer from the
//! parameter pool (blocking when the pool is exhausted — that is the
//! backpressure that bounds blocks in flight), reads the fp16 shard
//! from the NVMe engine into the pinned buffer, upconverts to f32 (the
//! H2D-transfer analog), releases the buffer, and hands the tensor to
//! the compute thread through a bounded channel.

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::bufpool::ParamBufferPool;
use crate::dtype::f16_bytes_to_f32s;
use crate::ssd::NvmeEngine;
use crate::tensors::TensorDesc;

/// One fetched tensor, ready for compute.
pub struct Fetched {
    pub desc: TensorDesc,
    pub data: Vec<f32>,
}

pub struct Swapper {
    rx: Receiver<anyhow::Result<Fetched>>,
    handle: Option<JoinHandle<()>>,
}

impl Swapper {
    /// Start prefetching `plan` in order. `key_of` maps a tensor to its
    /// SSD key (rank shards use partition keys). `depth` bounds
    /// ready-but-unconsumed tensors (channel) on top of the pool's own
    /// in-flight bound.
    pub fn start(
        engine: Arc<dyn NvmeEngine>,
        pool: Arc<dyn ParamBufferPool>,
        plan: Vec<TensorDesc>,
        key_of: impl Fn(&TensorDesc) -> String + Send + 'static,
        depth: usize,
    ) -> Self {
        let (tx, rx) = sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || {
            for t in plan {
                let result = (|| -> anyhow::Result<Fetched> {
                    let key = key_of(&t);
                    let n = engine
                        .len_of(&key)
                        .ok_or_else(|| anyhow::anyhow!("missing tensor '{key}'"))?
                        / 2;
                    let buf = pool.acquire(&t, crate::dtype::DType::F16)?;
                    let mut staged_err = None;
                    let mut data = vec![0f32; n];
                    pool.with_buf(&buf, &mut |bytes| {
                        if bytes.is_empty() {
                            staged_err = Some(anyhow::anyhow!("virtual pool"));
                            return;
                        }
                        if let Err(e) = engine.read(&key, &mut bytes[..n * 2]) {
                            staged_err = Some(e);
                            return;
                        }
                        f16_bytes_to_f32s(&bytes[..n * 2], &mut data);
                    });
                    pool.release(buf);
                    if let Some(e) = staged_err {
                        return Err(e);
                    }
                    Ok(Fetched { desc: t, data })
                })();
                let failed = result.is_err();
                if tx.send(result).is_err() || failed {
                    return; // consumer dropped or fetch failed
                }
            }
        });
        Self { rx, handle: Some(handle) }
    }

    /// Blocking receive of the next tensor in plan order.
    pub fn next(&self) -> anyhow::Result<Fetched> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("swapper thread terminated early"))?
    }
}

impl Drop for Swapper {
    fn drop(&mut self) {
        // drain so the worker unblocks, then join
        while self.rx.try_recv().is_ok() {}
        if let Some(h) = self.handle.take() {
            // if the worker is blocked on send, receiving above freed
            // it; if blocked on pool.acquire it will finish its plan
            // only if buffers free — consumers must drain fully before
            // dropping mid-plan (trainer always does).
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::AdaptivePool;
    use crate::config::presets::SMOKE;
    use crate::dtype::f32s_to_f16_bytes;
    use crate::pinned::{AlignedAllocator, MemoryTracker, Mode};
    use crate::ssd::DirectEngine;
    use crate::tensors::inventory;

    #[test]
    fn prefetch_delivers_in_order_with_correct_data() {
        let dir = std::env::temp_dir().join(format!("ma-swap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 2, 1 << 24, 1).unwrap());
        let alloc = AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()));
        let pool: Arc<dyn ParamBufferPool> =
            Arc::new(AdaptivePool::new(&SMOKE, 2, crate::dtype::DType::F16, &alloc));

        let plan: Vec<_> = inventory(&SMOKE)
            .into_iter()
            .filter(|t| t.offloadable())
            .collect();
        // seed the SSD with recognizable values per tensor
        for (i, t) in plan.iter().enumerate() {
            let vals = vec![i as f32 + 0.5; t.numel];
            let mut bytes = vec![0u8; t.numel * 2];
            f32s_to_f16_bytes(&vals, &mut bytes);
            engine.write(&format!("{}/fp16", t.name), &bytes).unwrap();
        }

        let sw = Swapper::start(
            engine,
            pool,
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            2,
        );
        for (i, want) in plan.iter().enumerate() {
            let got = sw.next().unwrap();
            assert_eq!(got.desc.name, want.name, "order violated");
            assert!(got.data.iter().all(|&x| x == i as f32 + 0.5));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_surfaces_error() {
        let dir = std::env::temp_dir().join(format!("ma-swap2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 20, 1).unwrap());
        let alloc = AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()));
        let pool: Arc<dyn ParamBufferPool> =
            Arc::new(AdaptivePool::new(&SMOKE, 1, crate::dtype::DType::F16, &alloc));
        let plan: Vec<_> = inventory(&SMOKE)
            .into_iter()
            .filter(|t| t.offloadable())
            .take(1)
            .collect();
        let sw = Swapper::start(engine, pool, plan, |t| format!("{}/fp16", t.name), 1);
        assert!(sw.next().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
