//! Parameter swapper: the SSD→host→"GPU" prefetch pipeline (§IV-A) —
//! a windowed async pipeline over the multi-queue layer that now
//! *coalesces* reads along the optimizer's super-group layout and
//! *replays* a recorded step profile instead of prefetching blindly.
//!
//! ## Fetch units
//!
//! The plan (layer-order tensor schedule) is compiled into **fetch
//! units** before anything is submitted:
//!
//! - Without [`FetchOpts::groups`], every tensor is its own unit: one
//!   `{name}/fp16` read, one upconvert — the historical path.
//! - With groups (a [`crate::offload::FetchGroups`] projection of the
//!   coalesced optimizer layout), consecutive plan tensors that live
//!   in the same super-group collapse into **one ranged `read_at`** of
//!   the packed `optim/sg{i}/fp16` stream.  The unit upconverts the
//!   whole range into one pinned `Cat::SwapBuf` lease and delivers
//!   each member as a [`TensorBuf`] *view* off that shared lease —
//!   many small submissions become one, mirroring the write-side
//!   scatter's ≥2× submission cut.  A tensor whose key is sharded or
//!   absent from the layout falls back to a single-tensor unit; data
//!   is bit-identical either way.
//!
//! Each unit is two chained stages, as before: an [`IoExecutor`]
//! worker stages the fp16 bytes (back on the device queue the moment
//! they land), then a [`StageExecutor`] worker decodes f16→f32 off the
//! I/O path.  Completions reorder back into plan order through the
//! FIFO window; a group's trailing members are served from a ready
//! queue with zero additional waits.
//!
//! ## Recorded-schedule contract (record → replay → fall back)
//!
//! With [`FetchOpts::profile`] set, the swapper keys the compiled unit
//! sequence `(key, offset, len)` by [`crate::offload::prefetch::plan_digest`]
//! and consults the shared [`ProfileStore`]:
//!
//! - **Record** (digest unknown): run the depth-window greedy path and
//!   trace, per unit, when compute asked for it (`consume_us`) and how
//!   long its fetch took (`fetch_us`).  The trace commits to the store
//!   only when the *entire* plan delivers — a faulted step never
//!   poisons the store.
//! - **Replay** (digest known): submit unit `i` no earlier than
//!   `consume_us − fetch_us − lead_us`, rate-matched to the observed
//!   consumption pace (SSDTrain's discipline).  Fetches land just
//!   before consumption instead of window-greedily, so the pinned
//!   `Cat::SwapBuf` watermark stays at or below the depth-window
//!   baseline while late arrivals stay rare.  At least one unit is
//!   always in flight and the window depth still caps the schedule, so
//!   a pathological profile degrades to the windowed path, never a
//!   stall-spiral.
//! - **Fall back** (store has profiles, none for this digest — new,
//!   renamed, or reordered keys): run the depth-window path, flag
//!   [`SwapMetrics::profile_fallback`], and re-record so the next step
//!   replays again.
//!
//! [`SwapMetrics`] reports submissions, per-unit prefetch hit/late
//! counts, and the mode taken; the trainer feeds them to the
//! [`crate::train::PipelineGovernor`], which arbitrates schedule
//! lead-time against arena pressure.
//!
//! Delivery remains **lease-backed**: upconverts decode straight into
//! pinned [`PinnedArena`] leases frozen into shared read-only
//! [`TensorBuf`] views — the very bytes `Runtime::run` uploads.  Only
//! when the arena refuses a lease does a unit degrade to owned scratch
//! vectors, charging the staged bytes to the shared [`HostCopyMeter`];
//! dropping a view recycles its extent.  Backpressure is unchanged:
//! the parameter pool bounds single-unit staging, the arena bounds
//! group staging, and the window bounds ready-but-unconsumed units.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::bufpool::{ParamBufferPool, PoolBuf};
use crate::dtype::f16_bytes_to_f32s;
use crate::metrics::HostCopyMeter;
use crate::offload::prefetch::{plan_digest, FetchGroups, ProfileStore, ProfileUnit, StepProfile};
use crate::pinned::{Cat, Lease, PinnedArena};
use crate::runtime::{F32Staging, TensorBuf};
use crate::ssd::{IoExecutor, IoHandle, JobId, NvmeEngine};
use crate::tensors::TensorDesc;
use crate::util::stage::StageExecutor;

/// The swapper's staging tier: vends pinned `Cat::SwapBuf` leases for
/// zero-copy delivery, and recycles owned f32 vectors for everything
/// that must stay heap-backed (PJRT result buffers, budget-degraded
/// fetches).  Both tiers ride the arena, so idle bytes sit on the
/// shared ledger and inside the pinned budget; the [`HostCopyMeter`]
/// records every byte the owned tier stages on the boundary path.
pub struct F32Scratch {
    arena: Arc<PinnedArena>,
    meter: HostCopyMeter,
}

impl F32Scratch {
    pub fn new(arena: Arc<PinnedArena>) -> Self {
        Self::with_meter(arena, HostCopyMeter::new())
    }

    /// Share an existing copy meter (the engine-wide one, so swapper,
    /// spill store, and trainer report into one counter).
    pub fn with_meter(arena: Arc<PinnedArena>, meter: HostCopyMeter) -> Self {
        Self { arena, meter }
    }

    /// Take an `n`-element staging destination: a pinned lease when
    /// the arena grants one (zero-copy tier), else an owned scratch
    /// vector charged to the meter — [`F32Staging::take`]'s shared
    /// degradation policy under `Cat::SwapBuf`.
    pub fn take_staging(&self, n: usize) -> F32Staging {
        F32Staging::take(&self.arena, Cat::SwapBuf, n, &self.meter)
    }

    /// Take a vector of exactly `n` elements (recycled best-fit when
    /// possible).
    pub fn take(&self, n: usize) -> Vec<f32> {
        self.arena.take_f32(n, Cat::SwapBuf)
    }

    /// Return a spent vector to the pool (dropped past the arena's
    /// bounds or budget).
    pub fn put(&self, v: Vec<f32>) {
        self.arena.put_f32(v, Cat::SwapBuf)
    }

    /// Recycle a spent tensor: owned vectors return to the pool; lease
    /// views simply drop, releasing their extent back to the arena's
    /// free list (same recycling, different tier).
    pub fn put_buf(&self, buf: TensorBuf) {
        if let TensorBuf::F32(v) = buf {
            self.put(v);
        }
    }

    /// The boundary copy counter this scratch charges on degraded
    /// (owned-tier) staging.
    pub fn meter(&self) -> &HostCopyMeter {
        &self.meter
    }

    /// Vectors currently pooled (test/introspection hook).
    pub fn pooled(&self) -> usize {
        self.arena.pooled_f32(Cat::SwapBuf)
    }

    pub fn arena(&self) -> &Arc<PinnedArena> {
        &self.arena
    }
}

/// One fetched tensor, ready for compute: a lease-backed view on the
/// zero-copy path, an owned vector when the arena degraded the fetch.
pub struct Fetched {
    pub desc: TensorDesc,
    pub data: TensorBuf,
}

/// How a [`Swapper`] fetches: window depth, optional coalescing
/// groups, optional recorded-profile replay.
#[derive(Clone)]
pub struct FetchOpts {
    /// Pipeline window: max fetch units in flight ahead of compute.
    pub depth: usize,
    /// Coalesce consecutive same-super-group tensors into ranged reads
    /// of the packed fp16 streams.
    pub groups: Option<Arc<FetchGroups>>,
    /// Record/replay step profiles through this shared store.
    pub profile: Option<Arc<ProfileStore>>,
    /// Safety margin subtracted from each replayed unit's deadline
    /// (its fetch is issued `fetch_us + lead_us` before consumption).
    pub lead_us: u64,
    /// Tenant whose scheduler lane the fetch submissions ride
    /// (weighted-fair dispatch + per-job accounting).
    pub job: JobId,
}

impl FetchOpts {
    /// The classic depth-window greedy prefetcher, no coalescing, no
    /// profile.
    pub fn window(depth: usize) -> Self {
        Self { depth, groups: None, profile: None, lead_us: 0, job: JobId::HOST }
    }

    pub fn with_groups(mut self, groups: Arc<FetchGroups>) -> Self {
        self.groups = Some(groups);
        self
    }

    pub fn with_profile(mut self, store: Arc<ProfileStore>, lead_us: u64) -> Self {
        self.profile = Some(store);
        self.lead_us = lead_us;
        self
    }

    pub fn for_job(mut self, job: JobId) -> Self {
        self.job = job;
        self
    }
}

/// Per-plan fetch accounting, snapshotted by the trainer into
/// [`crate::metrics::StepMetrics`] and the governor's samples.
#[derive(Debug, Clone, Copy, Default)]
pub struct SwapMetrics {
    /// NVMe read submissions issued (one per fetch unit) — the number
    /// coalescing drives down.
    pub fetch_submissions: u64,
    /// Units already upconverted when compute asked for them.
    pub prefetch_hits: u64,
    /// Units compute had to block on — the replayer's grow signal for
    /// schedule lead-time.
    pub prefetch_late: u64,
    /// Replay was requested and the store had profiles, but none for
    /// this plan's digest (new/reordered keys): the swapper ran the
    /// depth-window path and re-recorded.
    pub profile_fallback: bool,
    /// This plan ran against a recorded just-in-time schedule.
    pub replayed: bool,
}

/// Everything a fetch job needs; shared by value-cloned `Arc`.
struct FetchCtx {
    engine: Arc<dyn NvmeEngine>,
    pool: Arc<dyn ParamBufferPool>,
    exec: Arc<IoExecutor>,
    /// Compute-side pool the upconvert stage chains onto.
    stage: Arc<StageExecutor>,
    scratch: Arc<F32Scratch>,
    key_of: Box<dyn Fn(&TensorDesc) -> String + Send + Sync>,
    /// Scheduler lane for every fetch submission.
    job: JobId,
}

/// One compiled fetch unit: a lone tensor, or a contiguous run of
/// same-super-group tensors read as one range of the packed stream.
enum Unit {
    Single(TensorDesc),
    Group(GroupUnit),
}

struct GroupUnit {
    /// Packed fp16 stream key (`optim/sg{i}/fp16`).
    stream: String,
    /// First element covered in the stream.
    start: usize,
    /// Elements covered.
    len: usize,
    /// Members in delivery order; offsets are elements relative to
    /// `start`.
    members: Vec<(TensorDesc, usize)>,
}

enum UnitHandle {
    Single(IoHandle<Fetched>),
    Group(IoHandle<Vec<Fetched>>),
}

struct InflightUnit {
    handle: UnitHandle,
    /// Nanoseconds the fetch took (submission → upconverted), written
    /// by the stage worker right before completion.
    fetch_ns: Arc<AtomicU64>,
}

impl InflightUnit {
    fn is_ready(&self) -> bool {
        match &self.handle {
            UnitHandle::Single(h) => h.is_ready(),
            UnitHandle::Group(h) => h.is_ready(),
        }
    }
}

/// Replay state: per-unit latest-safe issue times from the recorded
/// profile, rate-matched to the pace compute actually consumes at.
struct Schedule {
    profile: Arc<StepProfile>,
    /// `consume_us − fetch_us − lead_us` per unit, unscaled.
    issue_us: Vec<u64>,
    /// Observed-vs-recorded pace ratio, updated at every delivery and
    /// clamped so a bad profile can only mistime fetches, not stall
    /// the pipeline.
    rate: f64,
    consumed: usize,
}

impl Schedule {
    fn new(profile: Arc<StepProfile>, lead_us: u64) -> Self {
        let issue_us = profile
            .units
            .iter()
            .map(|u| u.consume_us.saturating_sub(u.fetch_us.saturating_add(lead_us)))
            .collect();
        Self { profile, issue_us, rate: 1.0, consumed: 0 }
    }
}

/// The step's fetch trace being recorded (committed only on full
/// delivery).
struct Trace {
    units: Vec<ProfileUnit>,
}

pub struct Swapper {
    ctx: Arc<FetchCtx>,
    /// Trailing members of an already-delivered group unit, served
    /// ahead of the window with zero waits.
    ready: VecDeque<Fetched>,
    /// FIFO reorder window: front = next unit in plan order.
    inflight: VecDeque<InflightUnit>,
    /// Unit suffix not yet submitted.
    pending: VecDeque<Unit>,
    depth: usize,
    /// Nanoseconds `next()` spent blocked on completions — the I/O
    /// the pipeline could *not* hide behind compute.
    wait_ns: u64,
    /// Tensors not yet delivered.
    remaining: usize,
    unit_total: usize,
    submitted: usize,
    t0: Instant,
    sched: Option<Schedule>,
    trace: Option<Trace>,
    store: Option<Arc<ProfileStore>>,
    digest: u64,
    metrics: SwapMetrics,
}

impl Swapper {
    /// Start prefetching `plan` in order on `exec`, chaining each
    /// unit's f16→f32 upconvert onto `stage` (the compute-side pool).
    /// `key_of` maps a tensor to its SSD key (rank shards use
    /// partition keys); `opts` selects window depth, coalescing, and
    /// profile replay (see the module docs for the mode contract).
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        engine: Arc<dyn NvmeEngine>,
        pool: Arc<dyn ParamBufferPool>,
        exec: Arc<IoExecutor>,
        stage: Arc<StageExecutor>,
        scratch: Arc<F32Scratch>,
        plan: Vec<TensorDesc>,
        key_of: impl Fn(&TensorDesc) -> String + Send + Sync + 'static,
        opts: FetchOpts,
    ) -> Self {
        let ctx = Arc::new(FetchCtx {
            engine,
            pool,
            exec,
            stage,
            scratch,
            key_of: Box::new(key_of),
            job: opts.job,
        });
        let tensor_total = plan.len();
        let units = build_units(&ctx, plan, opts.groups.as_deref());

        let mut metrics = SwapMetrics::default();
        let mut digest = 0u64;
        let (sched, trace) = match &opts.profile {
            None => (None, None),
            Some(store) => {
                let id: Vec<(String, usize, usize)> = units
                    .iter()
                    .map(|u| match u {
                        Unit::Single(t) => ((ctx.key_of)(t), 0, t.numel * 2),
                        Unit::Group(g) => (g.stream.clone(), g.start * 2, g.len * 2),
                    })
                    .collect();
                digest = plan_digest(id.iter().map(|(k, o, l)| (k.as_str(), *o, *l)));
                match store.get(digest) {
                    Some(p) if p.units.len() == units.len() => {
                        metrics.replayed = true;
                        (Some(Schedule::new(p, opts.lead_us)), None)
                    }
                    _ => {
                        metrics.profile_fallback = !store.is_empty();
                        (None, Some(Trace { units: Vec::with_capacity(units.len()) }))
                    }
                }
            }
        };

        let mut sw = Self {
            ctx,
            ready: VecDeque::new(),
            inflight: VecDeque::new(),
            unit_total: units.len(),
            pending: units,
            depth: opts.depth.max(1),
            wait_ns: 0,
            remaining: tensor_total,
            submitted: 0,
            t0: Instant::now(),
            sched,
            trace,
            store: opts.profile,
            digest,
            metrics,
        };
        sw.fill_window();
        sw
    }

    /// µs since the plan started (the clock profiles are recorded and
    /// replayed against).
    fn elapsed_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    /// Submit due units, up to `depth` in flight.  Window/record mode
    /// is greedy; replay mode holds each unit until its rate-scaled
    /// issue time, while always keeping at least one in flight.
    fn fill_window(&mut self) {
        while self.inflight.len() < self.depth && !self.pending.is_empty() {
            if let Some(s) = &self.sched {
                let due = (s.issue_us[self.submitted] as f64 * s.rate) as u64;
                if !self.inflight.is_empty() && self.elapsed_us() < due {
                    break;
                }
            }
            let unit = self.pending.pop_front().expect("checked non-empty");
            self.submit(unit);
        }
    }

    fn submit(&mut self, unit: Unit) {
        let fetch_ns = Arc::new(AtomicU64::new(0));
        self.metrics.fetch_submissions += 1;
        self.submitted += 1;
        let handle = match unit {
            Unit::Single(t) => {
                UnitHandle::Single(submit_fetch(&self.ctx, t, Arc::clone(&fetch_ns)))
            }
            Unit::Group(g) => {
                UnitHandle::Group(submit_group(&self.ctx, g, Arc::clone(&fetch_ns)))
            }
        };
        self.inflight.push_back(InflightUnit { handle, fetch_ns });
    }

    /// Blocking receive of the next tensor in plan order.  Completions
    /// arrive out of order on the executor; delivery is serialized by
    /// waiting the window FIFO, and a group unit's trailing members
    /// are handed out without further waits.
    pub fn next(&mut self) -> anyhow::Result<Fetched> {
        if let Some(f) = self.ready.pop_front() {
            self.remaining -= 1;
            return Ok(f);
        }
        let asked_us = self.elapsed_us();
        let unit = self
            .inflight
            .pop_front()
            .ok_or_else(|| anyhow::anyhow!("swapper: plan exhausted"))?;
        if unit.is_ready() {
            self.metrics.prefetch_hits += 1;
        } else {
            self.metrics.prefetch_late += 1;
        }
        if let Some(s) = &mut self.sched {
            // rate-match: scale the remaining schedule by how fast
            // compute is actually consuming vs the recording
            let rec = s.profile.units[s.consumed].consume_us;
            if rec > 0 && asked_us > 0 {
                s.rate = (asked_us as f64 / rec as f64).clamp(0.25, 4.0);
            }
            s.consumed += 1;
        }
        // keep the window full (or the schedule on pace) while we wait
        self.fill_window();
        let t0 = Instant::now();
        let result = match unit.handle {
            UnitHandle::Single(h) => h.wait().map(|f| vec![f]),
            UnitHandle::Group(h) => h.wait(),
        };
        self.wait_ns += t0.elapsed().as_nanos() as u64;
        let items = match result {
            Ok(items) => items,
            Err(e) => {
                // a faulted unit poisons this step's trace: a profile
                // recorded across a fault must never reach the store
                self.trace = None;
                return Err(e);
            }
        };
        if let Some(tr) = &mut self.trace {
            tr.units.push(ProfileUnit {
                consume_us: asked_us,
                fetch_us: unit.fetch_ns.load(Ordering::Acquire) / 1_000,
            });
            if tr.units.len() == self.unit_total {
                if let Some(store) = &self.store {
                    store.record(
                        self.digest,
                        StepProfile { units: std::mem::take(&mut tr.units) },
                    );
                }
                self.trace = None;
            }
        }
        let mut it = items.into_iter();
        let first = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("swapper: fetch unit delivered no tensors"))?;
        self.ready.extend(it);
        self.remaining -= 1;
        Ok(first)
    }

    /// Tensors not yet delivered (in flight + unsubmitted + ready).
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// Seconds the consumer spent stalled in [`Self::next`] — compare
    /// against engine-side busy time to get the overlap ratio.
    pub fn wait_secs(&self) -> f64 {
        self.wait_ns as f64 / 1e9
    }

    /// Fetch accounting so far (final after the last delivery).
    pub fn metrics(&self) -> SwapMetrics {
        self.metrics
    }
}

// Dropping a `Swapper` mid-plan is safe without joining anything:
// in-flight jobs own `Arc`s to everything they touch, release their
// pool buffers themselves, and complete into slots nobody reads.

/// Compile the plan into fetch units: consecutive tensors sharing a
/// super-group (and using the canonical `{name}/fp16` key — sharded
/// key schemes must not read the shared stream) merge into one ranged
/// unit; everything else stays per-tensor.
fn build_units(
    ctx: &FetchCtx,
    plan: Vec<TensorDesc>,
    groups: Option<&FetchGroups>,
) -> VecDeque<Unit> {
    let Some(groups) = groups else {
        return plan.into_iter().map(Unit::Single).collect();
    };
    struct Open {
        sg: usize,
        lo: usize,
        hi: usize,
        /// Members with *absolute* stream offsets until sealed.
        members: Vec<(TensorDesc, usize)>,
    }
    fn seal(o: Open, groups: &FetchGroups) -> Unit {
        let start = o.lo;
        Unit::Group(GroupUnit {
            stream: groups.stream_key(o.sg).to_string(),
            start,
            len: o.hi - o.lo,
            members: o.members.into_iter().map(|(t, off)| (t, off - start)).collect(),
        })
    }
    let mut units = VecDeque::new();
    let mut open: Option<Open> = None;
    for t in plan {
        let span = groups
            .span_of(&t.name)
            .filter(|&(_, _, numel)| numel == t.numel)
            .filter(|_| (ctx.key_of)(&t) == format!("{}/fp16", t.name));
        match span {
            None => {
                if let Some(o) = open.take() {
                    units.push_back(seal(o, groups));
                }
                units.push_back(Unit::Single(t));
            }
            Some((sg, off, numel)) => match &mut open {
                Some(o) if o.sg == sg => {
                    o.lo = o.lo.min(off);
                    o.hi = o.hi.max(off + numel);
                    o.members.push((t, off));
                }
                _ => {
                    if let Some(o) = open.take() {
                        units.push_back(seal(o, groups));
                    }
                    open = Some(Open { sg, lo: off, hi: off + numel, members: vec![(t, off)] });
                }
            },
        }
    }
    if let Some(o) = open.take() {
        units.push_back(seal(o, groups));
    }
    units
}

fn submit_fetch(
    ctx: &Arc<FetchCtx>,
    t: TensorDesc,
    fetch_ns: Arc<AtomicU64>,
) -> IoHandle<Fetched> {
    let (completer, handle) = IoHandle::pair();
    let job_ctx = Arc::clone(ctx);
    let cost = t.bytes(crate::dtype::DType::F16) as u64;
    ctx.exec.submit_for(ctx.job, cost, move || {
        let t_job = Instant::now();
        // stage 1 (NVMe queue): lease pinned staging + device read;
        // the queue worker is free again the moment the bytes landed
        let (buf, n) = match stage_read(&job_ctx, &t) {
            Ok(staged) => staged,
            Err(e) => {
                completer.complete(Err(e));
                return;
            }
        };
        // stage 2 (compute pool): decode off the I/O path, so this
        // upconvert overlaps the next unit's device read
        let conv_ctx = Arc::clone(&job_ctx);
        job_ctx.stage.submit(move || {
            let result =
                upconvert(&conv_ctx, buf, n).map(|data| Fetched { desc: t, data });
            fetch_ns.store(t_job.elapsed().as_nanos() as u64, Ordering::Release);
            completer.complete(result);
        });
    });
    handle
}

fn submit_group(
    ctx: &Arc<FetchCtx>,
    g: GroupUnit,
    fetch_ns: Arc<AtomicU64>,
) -> IoHandle<Vec<Fetched>> {
    let (completer, handle) = IoHandle::pair();
    let job_ctx = Arc::clone(ctx);
    let cost = (g.len * 2) as u64;
    ctx.exec.submit_for(ctx.job, cost, move || {
        let t_job = Instant::now();
        // stage 1: one ranged read covers every member's fp16 bytes
        let staged = match stage_group_read(&job_ctx, &g) {
            Ok(staged) => staged,
            Err(e) => {
                completer.complete(Err(e));
                return;
            }
        };
        let conv_ctx = Arc::clone(&job_ctx);
        job_ctx.stage.submit(move || {
            let result = upconvert_group(&conv_ctx, &g, staged);
            fetch_ns.store(t_job.elapsed().as_nanos() as u64, Ordering::Release);
            completer.complete(result);
        });
    });
    handle
}

/// Fetch stage 1: lease pinned staging from the pool and read the fp16
/// bytes into it.  On success the buffer stays held for the upconvert
/// stage; on error it is released here.
fn stage_read(ctx: &FetchCtx, t: &TensorDesc) -> anyhow::Result<(PoolBuf, usize)> {
    let key = (ctx.key_of)(t);
    let n = ctx
        .engine
        .len_of(&key)
        .ok_or_else(|| anyhow::anyhow!("missing tensor '{key}'"))?
        / 2;
    let buf = ctx.pool.acquire(t, crate::dtype::DType::F16)?;
    let mut staged_err = None;
    ctx.pool.with_buf(&buf, &mut |bytes| {
        if bytes.is_empty() {
            staged_err = Some(anyhow::anyhow!("virtual pool"));
            return;
        }
        if let Err(e) = ctx.engine.read(&key, &mut bytes[..n * 2]) {
            staged_err = Some(e);
        }
    });
    if let Some(e) = staged_err {
        ctx.pool.release(buf);
        return Err(e);
    }
    Ok((buf, n))
}

/// Fetch stage 2: f16→f32 upconvert from the staged pool buffer
/// straight into a pinned `SwapBuf` lease (frozen into a read-only
/// view — the upload source), then release the staging back to the
/// pool.  A refused lease degrades to an owned scratch vector, charged
/// to the copy meter: bit-identical data, one extra heap staging hop.
fn upconvert(ctx: &FetchCtx, buf: PoolBuf, n: usize) -> anyhow::Result<TensorBuf> {
    let mut dst = ctx.scratch.take_staging(n);
    ctx.pool.with_buf(&buf, &mut |bytes| {
        f16_bytes_to_f32s(&bytes[..n * 2], dst.as_mut_slice());
    });
    ctx.pool.release(buf);
    Ok(dst.freeze())
}

/// A group unit's fp16 staging: pinned when the arena grants it, heap
/// otherwise.  Staging-only bytes (not the fp32 boundary path), so the
/// heap fallback is not metered — exactly like the single path's pool
/// staging.
enum GroupStaging {
    Lease(Lease),
    Owned(Vec<u8>),
}

impl GroupStaging {
    fn as_mut_slice(&mut self) -> &mut [u8] {
        match self {
            GroupStaging::Lease(l) => l.as_mut_slice(),
            GroupStaging::Owned(v) => v,
        }
    }

    fn bytes(&self) -> &[u8] {
        match self {
            GroupStaging::Lease(l) => l.as_slice(),
            GroupStaging::Owned(v) => v,
        }
    }
}

/// Group stage 1: one ranged read of the packed stream covering every
/// member.
fn stage_group_read(ctx: &FetchCtx, g: &GroupUnit) -> anyhow::Result<GroupStaging> {
    let byte_len = g.len * 2;
    let mut staged = match ctx.scratch.arena().lease(byte_len, Cat::SwapBuf) {
        Ok(l) if !l.is_virtual() => GroupStaging::Lease(l),
        _ => GroupStaging::Owned(vec![0u8; byte_len]),
    };
    ctx.engine.read_at(&g.stream, g.start * 2, staged.as_mut_slice())?;
    Ok(staged)
}

/// Group stage 2: upconvert the whole range into one shared f32 lease
/// and deliver each member as a view off it — one decode, zero copies.
/// A refused lease degrades member-by-member through the scratch's
/// shared staging policy (metered owned vectors); data is bit-identical
/// either way.
fn upconvert_group(
    ctx: &FetchCtx,
    g: &GroupUnit,
    staged: GroupStaging,
) -> anyhow::Result<Vec<Fetched>> {
    let src = staged.bytes();
    match ctx.scratch.arena().lease(g.len * 4, Cat::SwapBuf) {
        Ok(mut l) if !l.is_virtual() => {
            f16_bytes_to_f32s(&src[..g.len * 2], l.as_f32_mut());
            let shared = l.into_shared();
            g.members
                .iter()
                .map(|(t, off)| {
                    TensorBuf::view(&shared, *off, t.numel)
                        .map(|data| Fetched { desc: t.clone(), data })
                })
                .collect()
        }
        _ => g
            .members
            .iter()
            .map(|(t, off)| {
                let mut dst = ctx.scratch.take_staging(t.numel);
                f16_bytes_to_f32s(&src[off * 2..(off + t.numel) * 2], dst.as_mut_slice());
                Ok(Fetched { desc: t.clone(), data: dst.freeze() })
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::bufpool::AdaptivePool;
    use crate::config::presets::SMOKE;
    use crate::dtype::f32s_to_f16_bytes;
    use crate::optimizer::coalesce::fp16_stream_name;
    use crate::optimizer::states::StateDtype;
    use crate::optimizer::CoalescedLayout;
    use crate::pinned::Mode;
    use crate::ssd::{DirectEngine, FaultyEngine, OpKind, OpMask};
    use crate::tensors::inventory;

    fn scratch() -> Arc<F32Scratch> {
        Arc::new(F32Scratch::new(test_arena(Mode::Real)))
    }

    fn stage() -> Arc<StageExecutor> {
        Arc::new(StageExecutor::new(2))
    }

    fn seeded_engine(tag: &str) -> (Arc<DirectEngine>, Vec<TensorDesc>, std::path::PathBuf)
    {
        let dir = std::env::temp_dir()
            .join(format!("ma-swap-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine = Arc::new(DirectEngine::new(&dir, 2, 1 << 24, 2).unwrap());
        let plan: Vec<_> = inventory(&SMOKE)
            .into_iter()
            .filter(|t| t.offloadable())
            .collect();
        for (i, t) in plan.iter().enumerate() {
            let vals = vec![i as f32 + 0.5; t.numel];
            let mut bytes = vec![0u8; t.numel * 2];
            f32s_to_f16_bytes(&vals, &mut bytes);
            engine.write(&format!("{}/fp16", t.name), &bytes).unwrap();
        }
        (engine, plan, dir)
    }

    /// Pack the per-tensor fp16 values into super-group streams per a
    /// freshly planned layout, returning the read-side groups.
    fn seeded_groups(engine: &DirectEngine, plan: &[TensorDesc]) -> Arc<FetchGroups> {
        let members: Vec<(String, usize)> =
            plan.iter().map(|t| (t.name.clone(), t.numel)).collect();
        let layout = CoalescedLayout::plan(&members, StateDtype::F32, 1 << 22);
        let mut streams: Vec<Vec<u8>> =
            layout.super_numels.iter().map(|&n| vec![0u8; n * 2]).collect();
        for (i, t) in plan.iter().enumerate() {
            let (sg, off, numel) = layout.span_of(&t.name).unwrap();
            let vals = vec![i as f32 + 0.5; numel];
            f32s_to_f16_bytes(&vals, &mut streams[sg][off * 2..(off + numel) * 2]);
        }
        for (sg, bytes) in streams.iter().enumerate() {
            engine.write(&fp16_stream_name(sg), bytes).unwrap();
        }
        Arc::new(FetchGroups::from_layout(&layout))
    }

    fn pool(depth: usize) -> Arc<dyn ParamBufferPool> {
        Arc::new(
            AdaptivePool::new(&SMOKE, depth, crate::dtype::DType::F16, &test_arena(Mode::Real))
                .unwrap(),
        )
    }

    fn drain_and_check(sw: &mut Swapper, plan: &[TensorDesc], label: &str) {
        for (i, want) in plan.iter().enumerate() {
            let got = sw.next().unwrap();
            assert_eq!(got.desc.name, want.name, "{label}: order violated");
            assert!(
                got.data.as_f32().iter().all(|&x| x == i as f32 + 0.5),
                "{label}: tensor {i} corrupted"
            );
        }
        assert_eq!(sw.remaining(), 0, "{label}: remaining after drain");
    }

    #[test]
    fn prefetch_delivers_in_order_with_correct_data() {
        let (engine, plan, dir) = seeded_engine("order");
        let mut sw = Swapper::start(
            engine,
            pool(2),
            Arc::new(IoExecutor::new(1)),
            stage(),
            scratch(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(2),
        );
        for (i, want) in plan.iter().enumerate() {
            let got = sw.next().unwrap();
            assert_eq!(got.desc.name, want.name, "order violated");
            assert!(got.data.is_view(), "fetch not lease-backed");
            assert!(got.data.as_f32().iter().all(|&x| x == i as f32 + 0.5));
        }
        assert_eq!(sw.remaining(), 0);
        assert_eq!(sw.metrics().fetch_submissions, plan.len() as u64);
        assert!(sw.next().is_err(), "exhausted plan must error");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multiworker_window_preserves_plan_order() {
        // 4 executor workers, deep window: completions race, delivery
        // must still follow the plan with uncorrupted payloads.
        let (engine, plan, dir) = seeded_engine("mw");
        for depth in [1usize, 3, 8] {
            let mut sw = Swapper::start(
                engine.clone(),
                pool(depth.max(2)),
                Arc::new(IoExecutor::new(4)),
                stage(),
                scratch(),
                plan.clone(),
                |t| format!("{}/fp16", t.name),
                FetchOpts::window(depth),
            );
            drain_and_check(&mut sw, &plan, &format!("depth {depth}"));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_surfaces_error() {
        let dir = std::env::temp_dir().join(format!("ma-swap2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine: Arc<dyn NvmeEngine> =
            Arc::new(DirectEngine::new(&dir, 1, 1 << 20, 1).unwrap());
        let plan: Vec<_> = inventory(&SMOKE)
            .into_iter()
            .filter(|t| t.offloadable())
            .take(1)
            .collect();
        let mut sw = Swapper::start(
            engine,
            pool(1),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            plan,
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(1),
        );
        assert!(sw.next().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_injection_errors_surface_without_hanging() {
        // every read fails (writes already done) — each next() must
        // return Err promptly; dropping mid-plan must not deadlock.
        let (engine, plan, dir) = seeded_engine("faulty");
        let faulty: Arc<dyn NvmeEngine> = Arc::new(FaultyEngine::new(
            engine,
            1024, // fail every op
            11,
        ));
        let mut sw = Swapper::start(
            faulty,
            pool(2),
            Arc::new(IoExecutor::new(4)),
            stage(),
            scratch(),
            plan,
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(4),
        );
        assert!(sw.next().is_err());
        drop(sw); // window still has in-flight fetches
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partial_faults_deliver_good_prefix_then_error() {
        let (engine, plan, dir) = seeded_engine("pf");
        let faulty: Arc<dyn NvmeEngine> = Arc::new(FaultyEngine::new(engine, 200, 3));
        let mut sw = Swapper::start(
            faulty,
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(3),
        );
        // in-order delivery means results match the plan prefix until
        // the first injected fault; data before it must be correct
        for (i, want) in plan.iter().enumerate() {
            match sw.next() {
                Ok(got) => {
                    assert_eq!(got.desc.name, want.name);
                    assert!(got.data.as_f32().iter().all(|&x| x == i as f32 + 0.5));
                }
                Err(_) => break,
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lease_backed_fetches_count_zero_copies_and_recycle_extents() {
        let (engine, plan, dir) = seeded_engine("zc");
        let s = scratch();
        let mut sw = Swapper::start(
            engine,
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            Arc::clone(&s),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(2),
        );
        for _ in 0..plan.len() {
            let got = sw.next().unwrap();
            assert!(got.data.is_view());
            s.put_buf(got.data); // drops the view: extent recycles
        }
        assert_eq!(s.meter().bytes(), 0, "zero-copy path charged the meter");
        let st = s.arena().stats();
        assert_eq!(st.requested_bytes, 0, "fetch leases leaked");
        assert!(st.recycled > 0, "fetch leases never recycled");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn starved_arena_degrades_to_owned_vectors_and_meters_the_copies() {
        use crate::pinned::{AlignedAllocator, ArenaConfig, MemoryTracker, PinnedArena};
        let (engine, plan, dir) = seeded_engine("deg");
        // the *scratch* arena is starved (1 KiB budget refuses every
        // lease); the pool keeps its own unbounded arena so staging
        // still works
        let starved = PinnedArena::new(
            Arc::new(AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()))),
            ArenaConfig { budget_bytes: Some(1024), ..Default::default() },
        );
        let s = Arc::new(F32Scratch::new(starved));
        let mut sw = Swapper::start(
            engine,
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            Arc::clone(&s),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(2),
        );
        let mut expect_bytes = 0u64;
        for (i, t) in plan.iter().enumerate() {
            let got = sw.next().unwrap();
            assert!(!got.data.is_view(), "starved arena still granted a lease");
            // bit-identical payload on the degraded path
            assert!(got.data.as_f32().iter().all(|&x| x == i as f32 + 0.5));
            expect_bytes += t.numel as u64 * 4;
            s.put_buf(got.data);
        }
        assert_eq!(s.meter().bytes(), expect_bytes, "copy accounting diverged");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scratch_recycles_vectors_through_the_arena() {
        // policy details (best-fit, size floor, byte bound, budget) are
        // proven in pinned::arena's tests; this covers the facade and
        // the ledger wiring
        let s = F32Scratch::new(test_arena(Mode::Real));
        let v = s.take(100);
        let cap = v.capacity();
        s.put(v);
        assert_eq!(s.pooled(), 1);
        assert_eq!(s.arena().tracker().current(Cat::SwapBuf) as usize, cap * 4);
        let v2 = s.take(80); // fits in the recycled allocation
        assert!(v2.capacity() >= cap.min(100));
        assert_eq!(v2.len(), 80);
        assert_eq!(s.pooled(), 0);
        assert_eq!(s.arena().tracker().current(Cat::SwapBuf), 0);
    }

    #[test]
    fn coalesced_groups_cut_submissions_and_stay_bit_identical() {
        let (engine, plan, dir) = seeded_engine("coal");
        let groups = seeded_groups(&engine, &plan);

        let before = engine.stats();
        let mut sw = Swapper::start(
            engine.clone(),
            pool(4),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(4).with_groups(Arc::clone(&groups)),
        );
        for (i, want) in plan.iter().enumerate() {
            let got = sw.next().unwrap();
            assert_eq!(got.desc.name, want.name, "order violated");
            assert!(got.data.is_view(), "group member not lease-backed");
            assert!(
                got.data.as_f32().iter().all(|&x| x == i as f32 + 0.5),
                "tensor {i} corrupted on the coalesced path"
            );
        }
        let reads = engine.stats().reads - before.reads;
        let m = sw.metrics();
        assert_eq!(m.fetch_submissions, reads, "submission accounting diverged");
        assert!(
            m.fetch_submissions * 2 <= plan.len() as u64,
            "coalescing submitted {} reads for {} tensors (expected ≥2× cut)",
            m.fetch_submissions,
            plan.len()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn profile_records_then_replays_byte_identically() {
        let (engine, plan, dir) = seeded_engine("prof");
        let store = Arc::new(ProfileStore::new());
        let opts = || FetchOpts::window(2).with_profile(Arc::clone(&store), 500);

        // step 1: store empty → record mode (no fallback flag)
        let mut sw = Swapper::start(
            engine.clone(),
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            opts(),
        );
        drain_and_check(&mut sw, &plan, "record step");
        let m1 = sw.metrics();
        assert!(!m1.replayed && !m1.profile_fallback);
        assert_eq!(store.len(), 1, "full delivery must commit exactly one profile");

        // step 2: digest hits → replay, identical delivery, every unit
        // accounted as hit or late
        let mut sw = Swapper::start(
            engine.clone(),
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            opts(),
        );
        drain_and_check(&mut sw, &plan, "replay step");
        let m2 = sw.metrics();
        assert!(m2.replayed, "recorded digest must replay");
        assert!(!m2.profile_fallback);
        assert_eq!(m2.prefetch_hits + m2.prefetch_late, m2.fetch_submissions);
        assert_eq!(store.len(), 1, "replay must not re-record");

        // "restart": persist, reload, and replay from the loaded store
        store.persist(engine.as_ref()).unwrap();
        let reloaded = Arc::new(ProfileStore::load(engine.as_ref()).unwrap().unwrap());
        let mut sw = Swapper::start(
            engine,
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(2).with_profile(reloaded, 500),
        );
        drain_and_check(&mut sw, &plan, "post-restart replay");
        assert!(sw.metrics().replayed, "persisted profile must replay after reload");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn profile_mismatch_falls_back_to_window_and_rerecords() {
        let (engine, plan, dir) = seeded_engine("mismatch");
        let store = Arc::new(ProfileStore::new());
        let fwd = plan.clone();
        let mut bwd = plan.clone();
        bwd.reverse();

        let mut sw = Swapper::start(
            engine.clone(),
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            fwd.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(2).with_profile(Arc::clone(&store), 500),
        );
        for want in &fwd {
            assert_eq!(sw.next().unwrap().desc.name, want.name);
        }
        assert_eq!(store.len(), 1);

        // reordered plan: digest misses → structured fallback + re-record
        let mut sw = Swapper::start(
            engine,
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            bwd.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(2).with_profile(Arc::clone(&store), 500),
        );
        for want in &bwd {
            assert_eq!(sw.next().unwrap().desc.name, want.name);
        }
        let m = sw.metrics();
        assert!(m.profile_fallback, "digest miss must flag the fallback");
        assert!(!m.replayed);
        assert_eq!(store.len(), 2, "the reordered plan must record its own profile");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn faulted_ranged_reads_surface_and_never_commit_a_profile() {
        let (engine, plan, dir) = seeded_engine("rfault");
        let groups = seeded_groups(&engine, &plan);
        let store = Arc::new(ProfileStore::new());

        // only ranged reads fail: exactly the coalesced group path
        let faulty: Arc<dyn NvmeEngine> = Arc::new(
            FaultyEngine::new(engine.clone(), 1024, 7)
                .with_mask(OpMask::NONE.with(OpKind::ReadAt)),
        );
        let mut sw = Swapper::start(
            faulty,
            pool(2),
            Arc::new(IoExecutor::new(2)),
            stage(),
            scratch(),
            plan.clone(),
            |t| format!("{}/fp16", t.name),
            FetchOpts::window(2)
                .with_groups(Arc::clone(&groups))
                .with_profile(Arc::clone(&store), 500),
        );
        let mut saw_err = false;
        for _ in 0..plan.len() {
            if sw.next().is_err() {
                saw_err = true;
                break;
            }
        }
        assert!(saw_err, "injected ranged-read faults never surfaced");
        drop(sw);
        assert!(store.is_empty(), "a faulted step must not commit a profile");

        // the schedule stays consistent: a clean pass on the same store
        // records normally and the next one replays
        for expect_replay in [false, true] {
            let mut sw = Swapper::start(
                engine.clone(),
                pool(2),
                Arc::new(IoExecutor::new(2)),
                stage(),
                scratch(),
                plan.clone(),
                |t| format!("{}/fp16", t.name),
                FetchOpts::window(2)
                    .with_groups(Arc::clone(&groups))
                    .with_profile(Arc::clone(&store), 500),
            );
            drain_and_check(&mut sw, &plan, "post-fault pass");
            assert_eq!(sw.metrics().replayed, expect_replay);
        }
        assert_eq!(store.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
