//! Optimizer super-group coalescing: many small per-tensor state
//! streams, one long contiguous ranged-I/O stream each.
//!
//! The trainer's parameter groups are per-tensor, and most of them are
//! small — at SMOKE scale a group is a few KiB, at paper scale a
//! norm-adjacent projection is still far below one tile.  Driving
//! [`super::step_groups_tiled`] over per-tensor groups therefore pays
//! the *minimum* submission tax per tensor: at least 3 ranged reads,
//! 3 ranged writes, and 1 fp16 write, no matter how small the tensor
//! is.  SSDTrain-style pipelines win by keeping transfers long and
//! rate-matched; tiny tensors defeat that.
//!
//! The coalescer fixes the layout, not the math:
//!
//! - [`CoalescedLayout::plan`] concatenates the members *in inventory
//!   order* into a bounded number of logical **super-groups** of at
//!   most `target_bytes` state bytes each (a member larger than the
//!   target gets its own super-group).  The key → (super-group,
//!   element offset) mapping is a pure function of the member list and
//!   is **persisted** on the engine under [`LAYOUT_KEY`], so a restart
//!   against the same storage maps identically — and a diverging
//!   inventory is a structured error, never silent relocation.
//! - [`CoalescedOptim::build`] gathers each member's existing
//!   (master, m, v) streams into the super-group streams with ranged
//!   writes, once, at construction.
//! - [`CoalescedOptim::step_tiled`] then drives the same four-stage
//!   tile pipeline as `step_groups_tiled` over the super-group
//!   streams: tiles span member boundaries, so one 4 MiB tile that
//!   covers fifty small tensors costs 6 ranged submissions where the
//!   per-group driver paid 350.  Adam runs per member overlap inside
//!   the tile (the kernels are elementwise, so the trajectory is
//!   bit-identical to [`super::OptimState::step`] per member), and the
//!   fp16 compute window downconverts once per tile and *scatters* to
//!   the per-member `{name}/fp16` keys the swapper reads — one shared
//!   pinned lease backing many ranged view writes
//!   ([`AsyncEngine::submit_write_at_lease_view`]) — so the rest of
//!   the system (swapper plan, weight keys, benches) is untouched.
//!
//! Budget pressure degrades exactly like the per-group tile driver: a
//! refused fetch lease runs that one tile synchronously through
//! unpinned buffers, a refused fp16 window finishes the tile's
//! write-back synchronously from the leases already held — counted in
//! [`PipelineStats::degraded_tiles`], never an abort, and bit-identical
//! either way.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

use crate::pinned::{Cat, Lease, PinnedArena};
use crate::ssd::{AsyncEngine, IoHandle, NvmeEngine};
use crate::util::json::Json;
use crate::util::stage::StageExecutor;

use super::states::{master_to_fp16, state_keys};
use super::{AdamParams, OptimState, PipelineStats, StateDtype};

/// Engine key the coalesced layout is persisted under.
pub const LAYOUT_KEY: &str = "optim/coalesce/layout";

/// SSD stream namespace of one super-group.
pub fn super_group_name(idx: usize) -> String {
    format!("optim/sg{idx}")
}

/// Key of one super-group's *packed fp16 stream*: every member's fp16
/// compute weights concatenated at the layout's element offsets (×2
/// bytes).  Maintained by the write-back scatter when
/// [`CoalescedOptim::enable_fp16_streams`] is on, and read back as one
/// ranged submission per super-group by the swapper's coalesced fetch
/// path — the read-side twin of the coalesced state streams.
pub fn fp16_stream_name(idx: usize) -> String {
    format!("{}/fp16", super_group_name(idx))
}

/// One member tensor's place in the coalesced layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemberSpan {
    /// The member's original group name (its fp16 key stays
    /// `{name}/fp16`).
    pub name: String,
    pub numel: usize,
    /// Which super-group the member lives in.
    pub super_idx: usize,
    /// Element offset of the member inside its super-group.
    pub offset: usize,
}

/// The stable key → (super-group, offset) mapping: a pure function of
/// the member list, persisted per run under [`LAYOUT_KEY`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoalescedLayout {
    pub dtype: StateDtype,
    /// Members in input (inventory) order; offsets ascend within each
    /// super-group.
    pub members: Vec<MemberSpan>,
    /// Element count of each super-group.
    pub super_numels: Vec<usize>,
}

impl CoalescedLayout {
    /// Deterministic first-fit-in-order packing: walk the members in
    /// the given order, close the current super-group when adding the
    /// next member would push it past `target_bytes` of state bytes.
    /// A member larger than the target gets a super-group of its own;
    /// order is never permuted, so the mapping is reproducible from
    /// the member list alone.
    pub fn plan(
        members: &[(String, usize)],
        dtype: StateDtype,
        target_bytes: usize,
    ) -> Self {
        let es = dtype.bytes_per_elem();
        let target = target_bytes.max(1);
        let mut super_numels = Vec::new();
        let mut spans = Vec::new();
        let mut cur = 0usize;
        for (name, numel) in members {
            if cur > 0 && (cur + numel) * es > target {
                super_numels.push(cur);
                cur = 0;
            }
            spans.push(MemberSpan {
                name: name.clone(),
                numel: *numel,
                super_idx: super_numels.len(),
                offset: cur,
            });
            cur += numel;
        }
        if cur > 0 {
            super_numels.push(cur);
        }
        Self { dtype, members: spans, super_numels }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "dtype",
                Json::from(match self.dtype {
                    StateDtype::F32 => "f32".to_string(),
                    StateDtype::BF16 => "bf16".to_string(),
                }),
            ),
            (
                "supers",
                Json::Arr(self.super_numels.iter().map(|&n| Json::from(n)).collect()),
            ),
            (
                "members",
                Json::Arr(
                    self.members
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("name", Json::from(m.name.clone())),
                                ("numel", Json::from(m.numel)),
                                ("super", Json::from(m.super_idx)),
                                ("offset", Json::from(m.offset)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let dtype = match j.req("dtype")?.as_str() {
            Some("f32") => StateDtype::F32,
            Some("bf16") => StateDtype::BF16,
            other => anyhow::bail!("coalesce layout: bad dtype {other:?}"),
        };
        let supers = j
            .req("supers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("coalesce layout: supers not an array"))?
            .iter()
            .map(|n| {
                n.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("coalesce layout: bad super numel"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let members = j
            .req("members")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("coalesce layout: members not an array"))?
            .iter()
            .map(|m| {
                let field = |k: &str| -> anyhow::Result<usize> {
                    m.req(k)?
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("coalesce layout: bad member {k}"))
                };
                Ok(MemberSpan {
                    name: m
                        .req("name")?
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("coalesce layout: bad member name"))?
                        .to_string(),
                    numel: field("numel")?,
                    super_idx: field("super")?,
                    offset: field("offset")?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self { dtype, members, super_numels: supers })
    }

    /// (super-group, element offset, numel) of `name`, if a member.
    pub fn span_of(&self, name: &str) -> Option<(usize, usize, usize)> {
        self.members
            .iter()
            .find(|m| m.name == name)
            .map(|m| (m.super_idx, m.offset, m.numel))
    }
}

/// Super-group optimizer state: the coalesced layout plus one
/// [`OptimState`] per super-group stream on the SSD.
pub struct CoalescedOptim {
    pub layout: CoalescedLayout,
    pub supers: Vec<OptimState>,
    /// Member-index range of each super-group (members are assigned in
    /// order, so each super-group owns a contiguous slice).
    super_members: Vec<Range<usize>>,
    /// Whether the packed per-super fp16 streams
    /// ([`fp16_stream_name`]) are maintained alongside the per-member
    /// scatter (the swapper's coalesced read path depends on them).
    fp16_streams: bool,
}

/// Plan the coalesced layout for `groups` (a pure function of the
/// member list), with the dtype sanity checks both constructors need.
fn plan_for(groups: &[OptimState], target_bytes: usize) -> anyhow::Result<CoalescedLayout> {
    anyhow::ensure!(!groups.is_empty(), "nothing to coalesce");
    let dtype = groups[0].dtype;
    anyhow::ensure!(
        groups.iter().all(|g| g.dtype == dtype),
        "mixed state dtypes cannot share a coalesced layout"
    );
    let members: Vec<(String, usize)> =
        groups.iter().map(|g| (g.group.clone(), g.numel)).collect();
    Ok(CoalescedLayout::plan(&members, dtype, target_bytes))
}

/// Validate the blob persisted under [`LAYOUT_KEY`] (and the target
/// that produced it) against the freshly-planned `layout`; returns
/// whether a persisted blob existed.  A run restarted against the same
/// storage must address the same offsets — divergence is a structured
/// error that names the knob actually responsible.
fn check_persisted_layout(
    engine: &dyn NvmeEngine,
    layout: &CoalescedLayout,
    target_bytes: usize,
) -> anyhow::Result<bool> {
    let Some(len) = engine.len_of(LAYOUT_KEY) else {
        return Ok(false);
    };
    let mut stored = vec![0u8; len];
    engine.read(LAYOUT_KEY, &mut stored)?;
    let parsed = Json::parse(std::str::from_utf8(&stored)?)
        .map_err(|e| anyhow::anyhow!("coalesce layout unreadable: {e:?}"))?;
    let stored_target = parsed
        .req("target_bytes")?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("coalesce layout: bad target_bytes"))?;
    anyhow::ensure!(
        stored_target == target_bytes,
        "coalesce target changed ({stored_target} -> {target_bytes} state \
         bytes); keep optim_coalesce_bytes stable for this storage, or \
         clear '{LAYOUT_KEY}' to re-lay the super-groups"
    );
    let stored = CoalescedLayout::from_json(parsed.req("layout")?)?;
    anyhow::ensure!(
        &stored == layout,
        "persisted coalesce layout diverged from the member inventory"
    );
    Ok(true)
}

/// Member-index range of each super-group (members are assigned in
/// order, so each super-group owns a contiguous slice).
fn member_ranges(layout: &CoalescedLayout) -> Vec<Range<usize>> {
    let mut super_members = vec![0..0; layout.super_numels.len()];
    for (mi, span) in layout.members.iter().enumerate() {
        let r = &mut super_members[span.super_idx];
        if r.start == r.end {
            *r = mi..mi + 1;
        } else {
            r.end = mi + 1;
        }
    }
    super_members
}

impl CoalescedOptim {
    /// Build the super-group streams from per-member states already
    /// initialized on `engine`: compute the layout (or verify the one
    /// persisted under [`LAYOUT_KEY`] against it), reserve the
    /// super-group streams, and gather each member's (master, m, v)
    /// into them with ranged writes.  Member streams are authoritative
    /// at build time — the trainer (re)initializes them immediately
    /// before building.
    pub fn build(
        engine: &dyn NvmeEngine,
        groups: &[OptimState],
        target_bytes: usize,
    ) -> anyhow::Result<Self> {
        let layout = plan_for(groups, target_bytes)?;
        let dtype = layout.dtype;
        // persist the mapping (and the target that produced it) once
        if !check_persisted_layout(engine, &layout, target_bytes)? {
            let blob = Json::obj(vec![
                ("target_bytes", Json::from(target_bytes)),
                ("layout", layout.to_json()),
            ]);
            engine.write(LAYOUT_KEY, blob.to_string().as_bytes())?;
        }
        let es = dtype.bytes_per_elem();
        let supers: Vec<OptimState> = layout
            .super_numels
            .iter()
            .enumerate()
            .map(|(i, &numel)| OptimState { group: super_group_name(i), numel, dtype })
            .collect();
        for st in &supers {
            for k in state_keys(&st.group) {
                engine.reserve(&k, st.numel * es)?;
            }
        }
        for (g, span) in groups.iter().zip(&layout.members) {
            let src = state_keys(&g.group);
            let dst = state_keys(&super_group_name(span.super_idx));
            let mut buf = vec![0u8; g.numel * es];
            for (s, d) in src.iter().zip(&dst) {
                engine.read(s, &mut buf)?;
                engine.write_at(d, span.offset * es, &buf)?;
            }
        }
        let super_members = member_ranges(&layout);
        Ok(Self { layout, supers, super_members, fp16_streams: false })
    }

    /// Reattach to super-group streams that already hold the *current*
    /// optimizer state — the checkpoint-resume constructor.  Recomputes
    /// the layout from the member inventory, requires the persisted
    /// [`LAYOUT_KEY`] blob to exist and agree, and validates every
    /// super-group stream's stored length; it never gathers from the
    /// per-member streams, which go stale the moment the coalesced
    /// streams are first stepped ([`Self::build`]'s gather here would
    /// silently roll the run back to initialization).  No state bytes
    /// move — resume costs metadata reads only.
    pub fn resume(
        engine: &dyn NvmeEngine,
        groups: &[OptimState],
        target_bytes: usize,
    ) -> anyhow::Result<Self> {
        let layout = plan_for(groups, target_bytes)?;
        let dtype = layout.dtype;
        anyhow::ensure!(
            check_persisted_layout(engine, &layout, target_bytes)?,
            "cannot resume a coalesced run: no layout persisted under '{LAYOUT_KEY}'"
        );
        let es = dtype.bytes_per_elem();
        let supers: Vec<OptimState> = layout
            .super_numels
            .iter()
            .enumerate()
            .map(|(i, &numel)| OptimState { group: super_group_name(i), numel, dtype })
            .collect();
        for st in &supers {
            let want = st.numel * es;
            for k in state_keys(&st.group) {
                match engine.len_of(&k) {
                    Some(l) => anyhow::ensure!(
                        l == want,
                        "resume: super-group stream '{k}' is {l} bytes, expected {want}"
                    ),
                    None => anyhow::bail!(
                        "resume: super-group stream '{k}' missing from storage"
                    ),
                }
            }
        }
        let super_members = member_ranges(&layout);
        Ok(Self { layout, supers, super_members, fp16_streams: false })
    }

    /// Turn on the packed per-super fp16 streams: reserve
    /// [`fp16_stream_name`] per super-group and gather every member's
    /// `{name}/fp16` bytes into it at the layout offsets.  The member
    /// keys are authoritative here — on a fresh build they were just
    /// initialized — so the gather is the correct way to *create* the
    /// streams.  Once created they join the checkpoint key set
    /// (shadow-paged like the state streams), and a resumed run
    /// reattaches with [`Self::attach_fp16_streams`] instead of
    /// re-gathering.  From then on every tile write-back mirrors its
    /// fp16 window into the stream, keeping it bit-identical to the
    /// member keys.
    pub fn enable_fp16_streams(
        &mut self,
        engine: &dyn NvmeEngine,
        fp16_keys: &[String],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            fp16_keys.len() == self.layout.members.len(),
            "members/keys length mismatch"
        );
        for (i, &numel) in self.layout.super_numels.iter().enumerate() {
            engine.reserve(&fp16_stream_name(i), numel * 2)?;
        }
        for (span, key) in self.layout.members.iter().zip(fp16_keys) {
            let mut buf = vec![0u8; span.numel * 2];
            engine.read(key, &mut buf)?;
            engine.write_at(&fp16_stream_name(span.super_idx), span.offset * 2, &buf)?;
        }
        self.fp16_streams = true;
        Ok(())
    }

    /// Reattach to packed fp16 streams that already hold the current
    /// weights — the checkpoint-resume twin of
    /// [`Self::enable_fp16_streams`].  The streams are part of the
    /// journaled key set (shadow-paged like the state streams), so at
    /// resume they already carry the committed epoch's bytes: this
    /// validates every stream's stored length and enables the
    /// coalesced fetch path *without* re-gathering.  A re-gather here
    /// would be wrong twice over — it would roll packed weights back
    /// to whatever the member keys hold, and under shadow paging its
    /// writes would land in the next epoch's write extent, invisible
    /// to reads until a step advances the map.
    pub fn attach_fp16_streams(&mut self, engine: &dyn NvmeEngine) -> anyhow::Result<()> {
        for (i, &numel) in self.layout.super_numels.iter().enumerate() {
            let key = fp16_stream_name(i);
            let want = numel * 2;
            match engine.len_of(&key) {
                Some(stored) => anyhow::ensure!(
                    stored == want,
                    "packed fp16 stream '{key}' stored {stored} bytes, expected \
                     {want} — storage was re-laid since the checkpoint"
                ),
                None => anyhow::bail!(
                    "packed fp16 stream '{key}' missing at resume — the \
                     checkpoint was taken without fetch coalescing"
                ),
            }
        }
        self.fp16_streams = true;
        Ok(())
    }

    /// Whether [`Self::enable_fp16_streams`] or
    /// [`Self::attach_fp16_streams`] has run (the swapper's coalesced
    /// fetch path requires it).
    pub fn fp16_streams_enabled(&self) -> bool {
        self.fp16_streams
    }

    /// Member overlaps of the tile `[start, start+cnt)` of super-group
    /// `g`: `(member index, overlap start, overlap end)` in super-group
    /// element coordinates.
    fn overlaps(&self, g: usize, start: usize, cnt: usize) -> Vec<(usize, usize, usize)> {
        let end = start + cnt;
        let mut out = Vec::new();
        for mi in self.super_members[g].clone() {
            let span = &self.layout.members[mi];
            if span.offset >= end {
                break;
            }
            let s = span.offset.max(start);
            let e = (span.offset + span.numel).min(end);
            if s < e {
                out.push((mi, s, e));
            }
        }
        out
    }

    /// Ranged read of one member's state stream (`master`, `adam_m`,
    /// or `adam_v`) out of its super-group — the per-member view the
    /// bit-identity tests and external checkpoint readers use.
    pub fn read_member_state(
        &self,
        engine: &dyn NvmeEngine,
        member: usize,
        suffix: &str,
        out: &mut [u8],
    ) -> anyhow::Result<()> {
        let span = &self.layout.members[member];
        let es = self.layout.dtype.bytes_per_elem();
        anyhow::ensure!(out.len() == span.numel * es, "member read size mismatch");
        let key = format!("{}/{suffix}", super_group_name(span.super_idx));
        engine.read_at(&key, span.offset * es, out)
    }

    /// One explicit durability point over every coalesced artifact:
    /// each super-group's three state streams plus every member's fp16
    /// compute copy (the coalesced analog of
    /// [`super::flush_groups`]).
    pub fn flush(
        &self,
        engine: &dyn NvmeEngine,
        fp16_keys: &[String],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            fp16_keys.len() == self.layout.members.len(),
            "members/keys length mismatch"
        );
        for (i, st) in self.supers.iter().enumerate() {
            for k in state_keys(&st.group) {
                engine.flush(&k)?;
            }
            if self.fp16_streams {
                engine.flush(&fp16_stream_name(i))?;
            }
        }
        for k in fp16_keys {
            engine.flush(k)?;
        }
        Ok(())
    }

    /// Tile-granular four-stage AdamW over the super-group streams —
    /// the same fetch → Adam → downconvert/write-back pipeline as
    /// [`super::step_groups_tiled`], but tiles run long contiguous
    /// ranges that span member boundaries.  `grads[i]` /
    /// `fp16_keys[i]` belong to `layout.members[i]`.  Bit-identical to
    /// the per-group drivers; submission count per step is
    /// `O(super-group bytes / tile_bytes)` instead of `O(members)`.
    #[allow(clippy::too_many_arguments)]
    pub fn step_tiled(
        &self,
        aio: &AsyncEngine,
        stage: &StageExecutor,
        arena: &Arc<PinnedArena>,
        grads: &[&[f32]],
        fp16_keys: &[String],
        step: u64,
        grad_scale: f32,
        hp: &AdamParams,
        threads: usize,
        tile_bytes: usize,
        depth: usize,
    ) -> anyhow::Result<PipelineStats> {
        anyhow::ensure!(tile_bytes > 0, "coalesced driver requires a tile size");
        anyhow::ensure!(
            grads.len() == self.layout.members.len()
                && fp16_keys.len() == self.layout.members.len(),
            "members/grads/keys length mismatch"
        );
        for (span, g) in self.layout.members.iter().zip(grads) {
            anyhow::ensure!(
                g.len() == span.numel,
                "grad size mismatch for '{}'",
                span.name
            );
        }
        for (span, key) in self.layout.members.iter().zip(fp16_keys) {
            aio.engine().reserve(key, span.numel * 2)?;
        }
        let dtype = self.layout.dtype;
        let es = dtype.bytes_per_elem();
        // fixed-byte tile plan across all super-groups, tails included
        let tile_elems = (tile_bytes / es).max(1);
        let mut plan: Vec<(usize, usize, usize)> = Vec::new();
        for (g, st) in self.supers.iter().enumerate() {
            let mut start = 0;
            while start < st.numel {
                let cnt = tile_elems.min(st.numel - start);
                plan.push((g, start, cnt));
                start += cnt;
            }
        }
        let depth = depth.max(1);
        let mut stats = PipelineStats { tiles: plan.len() as u64, ..Default::default() };
        let mut next = 0usize;
        let mut fetches: VecDeque<TileFetch> = VecDeque::new();
        let mut wbs: VecDeque<IoHandle<CoalescedWriteback>> = VecDeque::new();
        loop {
            // keep the fetch window full; a refused lease degrades that
            // one tile to the synchronous unpinned path
            while next < plan.len() && fetches.len() < depth {
                let (g, s, c) = plan[next];
                next += 1;
                match self.submit_tile_fetch(aio, arena, g, s, c) {
                    Ok(tf) => fetches.push_back(tf),
                    Err(_budget) => {
                        self.step_tile_sync(
                            aio.engine().as_ref(),
                            g,
                            s,
                            c,
                            grads,
                            step,
                            grad_scale,
                            hp,
                            threads,
                            fp16_keys,
                        )?;
                        stats.degraded_tiles += 1;
                    }
                }
            }
            let Some(tf) = fetches.pop_front() else { break };
            let t0 = Instant::now();
            let mut p = tf.p.wait()?;
            let mut m = tf.m.wait()?;
            let mut v = tf.v.wait()?;
            stats.wait_secs += t0.elapsed().as_secs_f64();
            // Adam per member overlap: elementwise kernels over
            // disjoint sub-windows — the exact arithmetic the
            // per-group drivers run, just batched into one tile
            for (mi, s, e) in self.overlaps(tf.g, tf.start, tf.cnt) {
                let span = &self.layout.members[mi];
                let gs = &grads[mi][s - span.offset..e - span.offset];
                let (ts, te) = (s - tf.start, e - tf.start);
                match dtype {
                    StateDtype::F32 => super::adam_step_f32(
                        &mut p.as_f32_mut()[ts..te],
                        gs,
                        &mut m.as_f32_mut()[ts..te],
                        &mut v.as_f32_mut()[ts..te],
                        step,
                        grad_scale,
                        hp,
                        threads,
                    ),
                    StateDtype::BF16 => super::adam_step_bf16(
                        &mut p.as_mut_slice()[2 * ts..2 * te],
                        gs,
                        &mut m.as_mut_slice()[2 * ts..2 * te],
                        &mut v.as_mut_slice()[2 * ts..2 * te],
                        step,
                        grad_scale,
                        hp,
                        threads,
                    ),
                }
            }
            while wbs.len() >= depth {
                let wb = wbs.pop_front().expect("non-empty window");
                let t0 = Instant::now();
                wb.wait()?.drain()?;
                stats.wait_secs += t0.elapsed().as_secs_f64();
            }
            match self.submit_tile_writeback(
                aio, stage, arena, tf.g, tf.start, tf.cnt, p, m, v, fp16_keys,
            ) {
                Ok(h) => wbs.push_back(h),
                Err((_budget, p, m, v)) => {
                    self.writeback_tile_sync(
                        aio.engine().as_ref(),
                        tf.g,
                        tf.start,
                        tf.cnt,
                        p,
                        m,
                        v,
                        fp16_keys,
                    )?;
                    stats.degraded_tiles += 1;
                }
            }
        }
        while let Some(wb) = wbs.pop_front() {
            let t0 = Instant::now();
            wb.wait()?.drain()?;
            stats.wait_secs += t0.elapsed().as_secs_f64();
        }
        Ok(stats)
    }

    fn submit_tile_fetch(
        &self,
        aio: &AsyncEngine,
        arena: &PinnedArena,
        g: usize,
        start: usize,
        cnt: usize,
    ) -> Result<TileFetch, crate::pinned::ArenaError> {
        let es = self.layout.dtype.bytes_per_elem();
        let [k_p, k_m, k_v] = state_keys(&self.supers[g].group);
        let off = start * es;
        let len = cnt * es;
        let lp = arena.lease(len, Cat::OptimBuf)?;
        let lm = arena.lease(len, Cat::OptimBuf)?;
        let lv = arena.lease(len, Cat::OptimBuf)?;
        Ok(TileFetch {
            g,
            start,
            cnt,
            p: aio.submit_read_at_lease(k_p, off, lp),
            m: aio.submit_read_at_lease(k_m, off, lm),
            v: aio.submit_read_at_lease(k_v, off, lv),
        })
    }

    /// Queue tile downconvert + write-back: the fp16 conversion runs
    /// once over the whole tile on the stage executor, then the stage
    /// job submits the three super-group ranged writes plus one ranged
    /// *view* write per member overlap, all sharing the frozen fp16
    /// lease.
    #[allow(clippy::too_many_arguments)]
    fn submit_tile_writeback(
        &self,
        aio: &AsyncEngine,
        stage: &StageExecutor,
        arena: &PinnedArena,
        g: usize,
        start: usize,
        cnt: usize,
        p: Lease,
        m: Lease,
        v: Lease,
        fp16_keys: &[String],
    ) -> Result<IoHandle<CoalescedWriteback>, (crate::pinned::ArenaError, Lease, Lease, Lease)>
    {
        let mut fp16 = match arena.lease(cnt * 2, Cat::SwapBuf) {
            Ok(l) => l,
            Err(e) => return Err((e, p, m, v)),
        };
        // (member fp16 key, member-side byte offset, tile-side byte
        // offset, byte length) per overlap — owned, so the stage job
        // borrows nothing
        let scatter: Vec<(String, usize, usize, usize)> = self
            .overlaps(g, start, cnt)
            .into_iter()
            .map(|(mi, s, e)| {
                let span = &self.layout.members[mi];
                (
                    fp16_keys[mi].clone(),
                    (s - span.offset) * 2,
                    (s - start) * 2,
                    (e - s) * 2,
                )
            })
            .collect();
        let (completer, handle) = IoHandle::pair();
        let aio = aio.clone();
        let [k_p, k_m, k_v] = state_keys(&self.supers[g].group);
        let dtype = self.layout.dtype;
        let off = start * dtype.bytes_per_elem();
        // the packed stream mirror is one more ranged view write off
        // the same shared fp16 lease: the tile is contiguous in
        // super-group coordinates, so the whole window lands at once
        let stream = self.fp16_streams.then(|| (fp16_stream_name(g), start * 2, cnt * 2));
        stage.submit(move || {
            master_to_fp16(dtype, p.as_slice(), fp16.as_mut_slice());
            let shared = fp16.into_shared();
            let mut wb = CoalescedWriteback {
                leases: vec![
                    aio.submit_write_at_lease(k_p, off, p),
                    aio.submit_write_at_lease(k_m, off, m),
                    aio.submit_write_at_lease(k_v, off, v),
                ],
                views: Vec::new(),
            };
            for (key, dst_off, src_off, len) in scatter {
                wb.views.push(aio.submit_write_at_lease_view(
                    key,
                    dst_off,
                    Arc::clone(&shared),
                    src_off,
                    len,
                ));
            }
            if let Some((key, dst_off, len)) = stream {
                wb.views.push(aio.submit_write_at_lease_view(
                    key,
                    dst_off,
                    Arc::clone(&shared),
                    0,
                    len,
                ));
            }
            completer.complete(Ok(wb));
        });
        Ok(handle)
    }

    /// Budget-degraded path for one whole tile: fetch, Adam per member
    /// overlap, downconvert, and write back synchronously through
    /// transient unpinned buffers — same kernels, same disjoint byte
    /// windows, bit-identical.
    #[allow(clippy::too_many_arguments)]
    fn step_tile_sync(
        &self,
        engine: &dyn NvmeEngine,
        g: usize,
        start: usize,
        cnt: usize,
        grads: &[&[f32]],
        step: u64,
        grad_scale: f32,
        hp: &AdamParams,
        threads: usize,
        fp16_keys: &[String],
    ) -> anyhow::Result<()> {
        let dtype = self.layout.dtype;
        let es = dtype.bytes_per_elem();
        let [k_p, k_m, k_v] = state_keys(&self.supers[g].group);
        let off = start * es;
        let mut fp16 = vec![0u8; cnt * 2];
        match dtype {
            StateDtype::F32 => {
                // typed buffers, read in place — same shape as the
                // per-group driver's sync path, no bounce copies
                let mut p = vec![0f32; cnt];
                let mut m = vec![0f32; cnt];
                let mut v = vec![0f32; cnt];
                engine.read_at(&k_p, off, crate::dtype::f32s_as_bytes_mut(&mut p))?;
                engine.read_at(&k_m, off, crate::dtype::f32s_as_bytes_mut(&mut m))?;
                engine.read_at(&k_v, off, crate::dtype::f32s_as_bytes_mut(&mut v))?;
                for (mi, s, e) in self.overlaps(g, start, cnt) {
                    let span = &self.layout.members[mi];
                    let gs = &grads[mi][s - span.offset..e - span.offset];
                    let (ts, te) = (s - start, e - start);
                    super::adam_step_f32(
                        &mut p[ts..te],
                        gs,
                        &mut m[ts..te],
                        &mut v[ts..te],
                        step,
                        grad_scale,
                        hp,
                        threads,
                    );
                }
                engine.write_at(&k_p, off, crate::dtype::f32s_as_bytes(&p))?;
                engine.write_at(&k_m, off, crate::dtype::f32s_as_bytes(&m))?;
                engine.write_at(&k_v, off, crate::dtype::f32s_as_bytes(&v))?;
                master_to_fp16(dtype, crate::dtype::f32s_as_bytes(&p), &mut fp16);
            }
            StateDtype::BF16 => {
                let mut p = vec![0u8; cnt * 2];
                let mut m = vec![0u8; cnt * 2];
                let mut v = vec![0u8; cnt * 2];
                engine.read_at(&k_p, off, &mut p)?;
                engine.read_at(&k_m, off, &mut m)?;
                engine.read_at(&k_v, off, &mut v)?;
                for (mi, s, e) in self.overlaps(g, start, cnt) {
                    let span = &self.layout.members[mi];
                    let gs = &grads[mi][s - span.offset..e - span.offset];
                    let (ts, te) = (s - start, e - start);
                    super::adam_step_bf16(
                        &mut p[2 * ts..2 * te],
                        gs,
                        &mut m[2 * ts..2 * te],
                        &mut v[2 * ts..2 * te],
                        step,
                        grad_scale,
                        hp,
                        threads,
                    );
                }
                engine.write_at(&k_p, off, &p)?;
                engine.write_at(&k_m, off, &m)?;
                engine.write_at(&k_v, off, &v)?;
                master_to_fp16(dtype, &p, &mut fp16);
            }
        }
        for (mi, s, e) in self.overlaps(g, start, cnt) {
            let span = &self.layout.members[mi];
            engine.write_at(
                &fp16_keys[mi],
                (s - span.offset) * 2,
                &fp16[(s - start) * 2..(e - start) * 2],
            )?;
        }
        if self.fp16_streams {
            engine.write_at(&fp16_stream_name(g), start * 2, &fp16)?;
        }
        Ok(())
    }

    /// [`Self::step_tile_sync`]'s write-back half, for a tile whose
    /// states are already updated in leases but whose fp16 window
    /// lease was refused.
    #[allow(clippy::too_many_arguments)]
    fn writeback_tile_sync(
        &self,
        engine: &dyn NvmeEngine,
        g: usize,
        start: usize,
        cnt: usize,
        p: Lease,
        m: Lease,
        v: Lease,
        fp16_keys: &[String],
    ) -> anyhow::Result<()> {
        let dtype = self.layout.dtype;
        let es = dtype.bytes_per_elem();
        let [k_p, k_m, k_v] = state_keys(&self.supers[g].group);
        let off = start * es;
        let mut fp16 = vec![0u8; cnt * 2];
        master_to_fp16(dtype, p.as_slice(), &mut fp16);
        engine.write_at(&k_p, off, p.as_slice())?;
        engine.write_at(&k_m, off, m.as_slice())?;
        engine.write_at(&k_v, off, v.as_slice())?;
        for (mi, s, e) in self.overlaps(g, start, cnt) {
            let span = &self.layout.members[mi];
            engine.write_at(
                &fp16_keys[mi],
                (s - span.offset) * 2,
                &fp16[(s - start) * 2..(e - start) * 2],
            )?;
        }
        if self.fp16_streams {
            engine.write_at(&fp16_stream_name(g), start * 2, &fp16)?;
        }
        Ok(())
    }
}

/// One tile's in-flight fetch off the super-group streams.
struct TileFetch {
    g: usize,
    start: usize,
    cnt: usize,
    p: IoHandle<Lease>,
    m: IoHandle<Lease>,
    v: IoHandle<Lease>,
}

/// One tile's in-flight write-back: three super-group ranged writes
/// plus the fp16 scatter's shared-lease view writes.
struct CoalescedWriteback {
    leases: Vec<IoHandle<Lease>>,
    views: Vec<IoHandle<Arc<Lease>>>,
}

impl CoalescedWriteback {
    fn drain(self) -> anyhow::Result<()> {
        for h in self.leases {
            h.wait()?;
        }
        for h in self.views {
            h.wait()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::pinned::Mode;
    use crate::ssd::DirectEngine;

    fn engine(tag: &str) -> (DirectEngine, std::path::PathBuf) {
        let dir =
            std::env::temp_dir().join(format!("ma-coal-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        (DirectEngine::new(&dir, 2, 1 << 26, 1).unwrap(), dir)
    }

    fn arena() -> Arc<PinnedArena> {
        test_arena(Mode::Real)
    }

    fn init_groups(
        eng: &dyn NvmeEngine,
        sizes: &[usize],
        dtype: StateDtype,
        rng: &mut crate::util::rng::Xoshiro256,
    ) -> (Vec<OptimState>, Vec<Vec<f32>>) {
        let mut states = Vec::new();
        let mut inits = Vec::new();
        for (g, n) in sizes.iter().enumerate() {
            let p0: Vec<f32> = (0..*n).map(|_| rng.normal() as f32).collect();
            states.push(OptimState::init(eng, &format!("g{g}"), &p0, dtype).unwrap());
            // fp16 compute keys exist per member, as the trainer's
            // init_weights guarantees
            let mut fp16 = vec![0u8; n * 2];
            crate::dtype::f32s_to_f16_bytes(&p0, &mut fp16);
            eng.write(&format!("g{g}/fp16"), &fp16).unwrap();
            inits.push(p0);
        }
        (states, inits)
    }

    #[test]
    fn plan_is_deterministic_bounded_and_order_preserving() {
        let members: Vec<(String, usize)> = [120usize, 4000, 8, 900, 1, 2048, 77]
            .iter()
            .enumerate()
            .map(|(i, n)| (format!("t{i}"), *n))
            .collect();
        for dtype in [StateDtype::F32, StateDtype::BF16] {
            let es = dtype.bytes_per_elem();
            let target = 4096usize;
            let a = CoalescedLayout::plan(&members, dtype, target);
            let b = CoalescedLayout::plan(&members, dtype, target);
            assert_eq!(a, b, "plan must be a pure function of the member list");
            // every member mapped, in order, with ascending offsets
            assert_eq!(a.members.len(), members.len());
            let mut expect_super = 0;
            let mut expect_off = 0;
            for (span, (name, numel)) in a.members.iter().zip(&members) {
                assert_eq!(&span.name, name);
                assert_eq!(span.numel, *numel);
                if span.super_idx != expect_super {
                    assert_eq!(span.super_idx, expect_super + 1, "supers must ascend");
                    expect_super = span.super_idx;
                    expect_off = 0;
                }
                assert_eq!(span.offset, expect_off);
                expect_off += span.numel;
            }
            // no super-group exceeds the target unless a single member
            // does; sizes agree with the member spans
            for (g, &numel) in a.super_numels.iter().enumerate() {
                let members_in: Vec<_> =
                    a.members.iter().filter(|m| m.super_idx == g).collect();
                assert_eq!(members_in.iter().map(|m| m.numel).sum::<usize>(), numel);
                assert!(
                    numel * es <= target || members_in.len() == 1,
                    "super {g} overflows the target with multiple members"
                );
            }
            // coalescing actually bounded the group count
            assert!(a.super_numels.len() < members.len());
            // json round-trip is exact
            let rt = CoalescedLayout::from_json(
                &Json::parse(&a.to_json().to_string()).unwrap(),
            )
            .unwrap();
            assert_eq!(rt, a);
            assert_eq!(a.span_of("t1"), Some((a.members[1].super_idx, a.members[1].offset, 4000)));
            assert_eq!(a.span_of("absent"), None);
        }
    }

    #[test]
    fn coalesced_bit_identical_to_sequential_and_per_group_tiled() {
        // sizes cover: sub-tile members, ragged tails, an exact
        // multiple, and a member larger than the whole target
        let sizes = [5usize, 700, 64, 300, 1100, 17, 512, 2048];
        for dtype in [StateDtype::F32, StateDtype::BF16] {
            let (eng_a, dir_a) = engine(&format!("id-seq-{dtype:?}"));
            let (eng_b, dir_b) = engine(&format!("id-tile-{dtype:?}"));
            let (eng_c, dir_c) = engine(&format!("id-coal-{dtype:?}"));
            let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
            let mut rng = crate::util::rng::Xoshiro256::new(31);
            let (states_a, _) = init_groups(&eng_a, &sizes, dtype, &mut rng);
            let mut rng = crate::util::rng::Xoshiro256::new(31);
            let (states_b, _) = init_groups(&eng_b, &sizes, dtype, &mut rng);
            let mut rng = crate::util::rng::Xoshiro256::new(31);
            let (states_c, _) = init_groups(&eng_c, &sizes, dtype, &mut rng);
            let eng_b: Arc<dyn NvmeEngine> = Arc::new(eng_b);
            let eng_c: Arc<dyn NvmeEngine> = Arc::new(eng_c);
            let aio_b = AsyncEngine::new(Arc::clone(&eng_b), 3);
            let aio_c = AsyncEngine::new(Arc::clone(&eng_c), 3);
            let stage = StageExecutor::new(2);
            let arena_b = arena();
            let arena_c = arena();
            // super-groups of ~4 KiB state bytes, tiles of 1 KiB: tiles
            // span member boundaries and members span tiles
            let co = CoalescedOptim::build(eng_c.as_ref(), &states_c, 4096).unwrap();
            assert!(co.supers.len() < sizes.len(), "nothing coalesced");
            let keys: Vec<String> =
                (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
            for t in 1..=3u64 {
                let grads: Vec<Vec<f32>> = sizes
                    .iter()
                    .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
                    .collect();
                let grad_refs: Vec<&[f32]> =
                    grads.iter().map(|g| g.as_slice()).collect();
                for (g, st) in states_a.iter().enumerate() {
                    st.step(&eng_a, &grads[g], t, 2.0, &hp, 1, &keys[g]).unwrap();
                }
                super::super::step_groups_tiled(
                    &aio_b, &stage, &arena_b, &states_b, &grad_refs, &keys, t, 2.0,
                    &hp, 1, 1024, 2,
                )
                .unwrap();
                let stats = co
                    .step_tiled(
                        &aio_c, &stage, &arena_c, &grad_refs, &keys, t, 2.0, &hp, 1,
                        1024, 2,
                    )
                    .unwrap();
                assert_eq!(stats.degraded_tiles, 0);
                // tile count follows the *super* streams, not members
                let es = dtype.bytes_per_elem();
                let tile_elems = 1024 / es;
                let want: usize = co
                    .layout
                    .super_numels
                    .iter()
                    .map(|n| n.div_ceil(tile_elems))
                    .sum();
                assert_eq!(stats.tiles as usize, want);
            }
            // every member's state + fp16 identical across all drivers
            let es = dtype.bytes_per_elem();
            for (g, n) in sizes.iter().enumerate() {
                for suffix in ["master", "adam_m", "adam_v"] {
                    let key = format!("g{g}/{suffix}");
                    let mut a = vec![0u8; n * es];
                    let mut b = vec![0u8; n * es];
                    let mut c = vec![0u8; n * es];
                    eng_a.read(&key, &mut a).unwrap();
                    eng_b.read(&key, &mut b).unwrap();
                    co.read_member_state(eng_c.as_ref(), g, suffix, &mut c).unwrap();
                    assert_eq!(a, b, "{dtype:?} per-group tiled {key} diverged");
                    assert_eq!(a, c, "{dtype:?} coalesced {key} diverged");
                }
                let key = format!("g{g}/fp16");
                let mut a = vec![0u8; n * 2];
                let mut c = vec![0u8; n * 2];
                eng_a.read(&key, &mut a).unwrap();
                eng_c.read(&key, &mut c).unwrap();
                assert_eq!(a, c, "{dtype:?} coalesced {key} diverged");
            }
            // all tile leases returned to the arena
            assert_eq!(arena_c.stats().requested_bytes, 0);
            std::fs::remove_dir_all(&dir_a).ok();
            std::fs::remove_dir_all(&dir_b).ok();
            std::fs::remove_dir_all(&dir_c).ok();
        }
    }

    #[test]
    fn coalescing_reduces_per_step_submissions_on_many_small_tensors() {
        // 48 sub-tile tensors: per-group tiling pays >= 7 submissions
        // per tensor, the coalesced stream pays ~6 per tile + 1 fp16
        // scatter per member
        let sizes: Vec<usize> = (0..48).map(|i| 64 + (i % 7) * 96).collect();
        let (eng_b, dir_b) = engine("sub-group");
        let (eng_c, dir_c) = engine("sub-coal");
        let hp = AdamParams::default();
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        let (states_b, _) = init_groups(&eng_b, &sizes, StateDtype::F32, &mut rng);
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        let (states_c, _) = init_groups(&eng_c, &sizes, StateDtype::F32, &mut rng);
        let eng_b: Arc<dyn NvmeEngine> = Arc::new(eng_b);
        let eng_c: Arc<dyn NvmeEngine> = Arc::new(eng_c);
        let aio_b = AsyncEngine::new(Arc::clone(&eng_b), 3);
        let aio_c = AsyncEngine::new(Arc::clone(&eng_c), 3);
        let stage = StageExecutor::new(2);
        let co = CoalescedOptim::build(eng_c.as_ref(), &states_c, 256 << 10).unwrap();
        let keys: Vec<String> = (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
        let grads: Vec<Vec<f32>> = sizes
            .iter()
            .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
            .collect();
        let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let tile = 64 << 10;
        let before_b = eng_b.stats().ops();
        super::super::step_groups_tiled(
            &aio_b,
            &stage,
            &arena(),
            &states_b,
            &grad_refs,
            &keys,
            1,
            1.0,
            &hp,
            1,
            tile,
            2,
        )
        .unwrap();
        let per_group_ops = eng_b.stats().ops() - before_b;
        let before_c = eng_c.stats().ops();
        co.step_tiled(
            &aio_c,
            &stage,
            &arena(),
            &grad_refs,
            &keys,
            1,
            1.0,
            &hp,
            1,
            tile,
            2,
        )
        .unwrap();
        let coalesced_ops = eng_c.stats().ops() - before_c;
        assert!(
            coalesced_ops * 2 <= per_group_ops,
            "coalescing saved too little: {coalesced_ops} vs {per_group_ops} submissions"
        );
    std::fs::remove_dir_all(&dir_b).ok();
        std::fs::remove_dir_all(&dir_c).ok();
    }

    #[test]
    fn layout_persists_and_rebuild_maps_identically() {
        let sizes = [100usize, 50, 800, 3];
        let (eng, dir) = engine("persist");
        let mut rng = crate::util::rng::Xoshiro256::new(9);
        let (states, _) = init_groups(&eng, &sizes, StateDtype::F32, &mut rng);
        let co1 = CoalescedOptim::build(&eng, &states, 2048).unwrap();
        assert!(eng.len_of(LAYOUT_KEY).is_some(), "layout never persisted");
        // a rebuild against the same storage loads + verifies the
        // persisted mapping and lands on identical offsets (restart
        // determinism)
        let co2 = CoalescedOptim::build(&eng, &states, 2048).unwrap();
        assert_eq!(co1.layout, co2.layout);
        // a fresh engine with the same member inventory maps the same
        let (eng2, dir2) = engine("persist2");
        let mut rng = crate::util::rng::Xoshiro256::new(9);
        let (states2, _) = init_groups(&eng2, &sizes, StateDtype::F32, &mut rng);
        let co3 = CoalescedOptim::build(&eng2, &states2, 2048).unwrap();
        assert_eq!(co1.layout, co3.layout);
        // a diverging inventory against persisted state is a
        // structured error, not silent relocation
        let bad = vec![
            OptimState { group: "g0".into(), numel: 100, dtype: StateDtype::F32 },
            OptimState { group: "gX".into(), numel: 50, dtype: StateDtype::F32 },
        ];
        assert!(CoalescedOptim::build(&eng, &bad, 2048).is_err());
        // a changed coalesce target is its own structured error,
        // naming the knob responsible rather than blaming the inventory
        let err = CoalescedOptim::build(&eng, &states, 4096).unwrap_err();
        assert!(
            err.to_string().contains("coalesce target changed"),
            "unexpected error: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn resume_reattaches_stepped_state_without_gathering() {
        // the resume constructor must preserve the *stepped* super-group
        // state: build()'s gather would silently roll the streams back
        // to the (now stale) member-stream contents
        let sizes = [300usize, 45, 1200, 7];
        let (eng_a, dir_a) = engine("res-seq");
        let (eng_c, dir_c) = engine("res-coal");
        let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
        let mut rng = crate::util::rng::Xoshiro256::new(21);
        let (states_a, _) = init_groups(&eng_a, &sizes, StateDtype::F32, &mut rng);
        let mut rng = crate::util::rng::Xoshiro256::new(21);
        let (states_c, _) = init_groups(&eng_c, &sizes, StateDtype::F32, &mut rng);
        let eng_c: Arc<dyn NvmeEngine> = Arc::new(eng_c);
        let aio = AsyncEngine::new(Arc::clone(&eng_c), 2);
        let stage = StageExecutor::new(1);
        let keys: Vec<String> = (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
        let co = CoalescedOptim::build(eng_c.as_ref(), &states_c, 4096).unwrap();
        let step_both = |co: &CoalescedOptim, t: u64, rng: &mut crate::util::rng::Xoshiro256| {
            let grads: Vec<Vec<f32>> = sizes
                .iter()
                .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
                .collect();
            let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            for (g, st) in states_a.iter().enumerate() {
                st.step(&eng_a, &grads[g], t, 1.0, &hp, 1, &keys[g]).unwrap();
            }
            co.step_tiled(&aio, &stage, &arena(), &grad_refs, &keys, t, 1.0, &hp, 1, 1024, 2)
                .unwrap();
        };
        step_both(&co, 1, &mut rng);
        step_both(&co, 2, &mut rng);
        drop(co);
        // "restart": reattach against the same storage and keep stepping
        let co = CoalescedOptim::resume(eng_c.as_ref(), &states_c, 4096).unwrap();
        step_both(&co, 3, &mut rng);
        for (g, n) in sizes.iter().enumerate() {
            for suffix in ["master", "adam_m", "adam_v"] {
                let mut a = vec![0u8; n * 4];
                let mut c = vec![0u8; n * 4];
                eng_a.read(&format!("g{g}/{suffix}"), &mut a).unwrap();
                co.read_member_state(eng_c.as_ref(), g, suffix, &mut c).unwrap();
                assert_eq!(a, c, "resumed g{g}/{suffix} diverged");
            }
        }
        // resume without a persisted layout is a structured error
        let (eng_f, dir_f) = engine("res-fresh");
        let mut rng = crate::util::rng::Xoshiro256::new(21);
        let (states_f, _) = init_groups(&eng_f, &sizes, StateDtype::F32, &mut rng);
        let err = CoalescedOptim::resume(&eng_f, &states_f, 4096).unwrap_err();
        assert!(err.to_string().contains("no layout persisted"), "got: {err}");
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_c).ok();
        std::fs::remove_dir_all(&dir_f).ok();
    }

    #[test]
    fn degraded_tiles_under_impossible_budget_stay_identical() {
        let sizes = [400usize, 2500, 31];
        let (eng_a, dir_a) = engine("deg-seq");
        let (eng_c, dir_c) = engine("deg-coal");
        let hp = AdamParams::default();
        let mut rng = crate::util::rng::Xoshiro256::new(13);
        let (states_a, _) = init_groups(&eng_a, &sizes, StateDtype::F32, &mut rng);
        let mut rng = crate::util::rng::Xoshiro256::new(13);
        let (states_c, _) = init_groups(&eng_c, &sizes, StateDtype::F32, &mut rng);
        let eng_c: Arc<dyn NvmeEngine> = Arc::new(eng_c);
        let aio = AsyncEngine::new(Arc::clone(&eng_c), 2);
        let stage = StageExecutor::new(1);
        let co = CoalescedOptim::build(eng_c.as_ref(), &states_c, 8192).unwrap();
        let tracker = Arc::new(crate::pinned::MemoryTracker::new());
        let starved = PinnedArena::new(
            Arc::new(crate::pinned::AlignedAllocator::new(Mode::Real, tracker)),
            crate::pinned::ArenaConfig {
                budget_bytes: Some(512),
                ..Default::default()
            },
        );
        let keys: Vec<String> = (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
        for t in 1..=2u64 {
            let grads: Vec<Vec<f32>> = sizes
                .iter()
                .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
                .collect();
            let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            for (g, st) in states_a.iter().enumerate() {
                st.step(&eng_a, &grads[g], t, 1.0, &hp, 1, &keys[g]).unwrap();
            }
            let stats = co
                .step_tiled(
                    &aio, &stage, &starved, &grad_refs, &keys, t, 1.0, &hp, 1, 4096, 2,
                )
                .unwrap();
            assert_eq!(
                stats.degraded_tiles, stats.tiles,
                "every tile must have degraded, none aborted"
            );
        }
        for (g, n) in sizes.iter().enumerate() {
            for suffix in ["master", "adam_m", "adam_v"] {
                let mut a = vec![0u8; n * 4];
                let mut c = vec![0u8; n * 4];
                eng_a.read(&format!("g{g}/{suffix}"), &mut a).unwrap();
                co.read_member_state(eng_c.as_ref(), g, suffix, &mut c).unwrap();
                assert_eq!(a, c, "degraded coalesced g{g}/{suffix} diverged");
            }
            let mut a = vec![0u8; n * 2];
            let mut c = vec![0u8; n * 2];
            eng_a.read(&format!("g{g}/fp16"), &mut a).unwrap();
            eng_c.read(&format!("g{g}/fp16"), &mut c).unwrap();
            assert_eq!(a, c, "degraded coalesced g{g}/fp16 diverged");
        }
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_c).ok();
    }

    #[test]
    fn fp16_streams_mirror_member_keys_bit_for_bit() {
        // the packed per-super fp16 streams must equal the member keys
        // laid out at the layout offsets after the initial gather and
        // after every step — on the async write-back path *and* the
        // budget-degraded sync path
        let sizes = [130usize, 7, 950, 64, 33];
        let (eng, dir) = engine("fp16s");
        let hp = AdamParams::default();
        let mut rng = crate::util::rng::Xoshiro256::new(17);
        let (states, _) = init_groups(&eng, &sizes, StateDtype::F32, &mut rng);
        let eng: Arc<dyn NvmeEngine> = Arc::new(eng);
        let aio = AsyncEngine::new(Arc::clone(&eng), 2);
        let stage = StageExecutor::new(1);
        let keys: Vec<String> = (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
        let mut co = CoalescedOptim::build(eng.as_ref(), &states, 2048).unwrap();
        assert!(!co.fp16_streams_enabled());
        co.enable_fp16_streams(eng.as_ref(), &keys).unwrap();
        assert!(co.fp16_streams_enabled());
        let check = |co: &CoalescedOptim, ctx: &str| {
            for (i, &numel) in co.layout.super_numels.iter().enumerate() {
                let key = fp16_stream_name(i);
                assert_eq!(eng.len_of(&key), Some(numel * 2), "{ctx}: stream {key}");
                let mut stream = vec![0u8; numel * 2];
                eng.read(&key, &mut stream).unwrap();
                for span in co.layout.members.iter().filter(|m| m.super_idx == i) {
                    let mut member = vec![0u8; span.numel * 2];
                    eng.read(&format!("{}/fp16", span.name), &mut member).unwrap();
                    assert_eq!(
                        &stream[span.offset * 2..(span.offset + span.numel) * 2],
                        &member[..],
                        "{ctx}: stream {key} diverged from '{}'",
                        span.name
                    );
                }
            }
        };
        check(&co, "after gather");
        let starved = PinnedArena::new(
            Arc::new(crate::pinned::AlignedAllocator::new(
                Mode::Real,
                Arc::new(crate::pinned::MemoryTracker::new()),
            )),
            crate::pinned::ArenaConfig { budget_bytes: Some(512), ..Default::default() },
        );
        for t in 1..=2u64 {
            let grads: Vec<Vec<f32>> = sizes
                .iter()
                .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
                .collect();
            let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            // t=1: pinned async write-back; t=2: every tile degraded
            let ar = if t == 1 { arena() } else { Arc::clone(&starved) };
            let stats = co
                .step_tiled(&aio, &stage, &ar, &grad_refs, &keys, t, 1.0, &hp, 1, 1024, 2)
                .unwrap();
            if t == 2 {
                assert_eq!(stats.degraded_tiles, stats.tiles);
            }
            check(&co, if t == 1 { "after async step" } else { "after degraded step" });
        }
        co.flush(eng.as_ref(), &keys).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn structured_errors_for_bad_inputs() {
        let (eng, dir) = engine("errs");
        let mut rng = crate::util::rng::Xoshiro256::new(3);
        let (states, _) = init_groups(&eng, &[64, 64], StateDtype::F32, &mut rng);
        let eng: Arc<dyn NvmeEngine> = Arc::new(eng);
        let co = CoalescedOptim::build(eng.as_ref(), &states, 4096).unwrap();
        let aio = AsyncEngine::new(Arc::clone(&eng), 1);
        let stage = StageExecutor::new(1);
        let hp = AdamParams::default();
        let good = vec![0.0f32; 64];
        let bad = vec![0.0f32; 7];
        let keys = vec!["g0/fp16".to_string(), "g1/fp16".to_string()];
        // wrong grad size
        assert!(co
            .step_tiled(
                &aio,
                &stage,
                &arena(),
                &[good.as_slice(), bad.as_slice()],
                &keys,
                1,
                1.0,
                &hp,
                1,
                1024,
                2
            )
            .is_err());
        // tile_bytes = 0 is a caller bug on this driver
        assert!(co
            .step_tiled(
                &aio,
                &stage,
                &arena(),
                &[good.as_slice(), good.as_slice()],
                &keys,
                1,
                1.0,
                &hp,
                1,
                0,
                2
            )
            .is_err());
        // empty build + mixed dtypes
        assert!(CoalescedOptim::build(eng.as_ref(), &[], 1024).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prop_coalesced_matches_step_across_random_shapes() {
        use crate::prop_assert;
        use crate::util::proptest::{check, Config};
        check("optim-coalesced", Config { cases: 8, ..Default::default() }, |rng, size| {
            let dtype = if rng.next_u64() % 2 == 0 {
                StateDtype::F32
            } else {
                StateDtype::BF16
            };
            let case = rng.next_u64();
            let (eng_a, dir_a) = engine(&format!("pa{case}"));
            let (eng_c, dir_c) = engine(&format!("pc{case}"));
            let hp = AdamParams { weight_decay: 0.005, ..Default::default() };
            let n_groups = rng.range(1, 6);
            let sizes: Vec<usize> = (0..n_groups)
                .map(|_| rng.range(1, (size * 4).max(3)))
                .collect();
            let target = [512usize, 2048, 16384][rng.below(3)];
            let tile = [256usize, 1000, 4096][rng.below(3)];
            let seed = rng.next_u64();
            let mut ra = crate::util::rng::Xoshiro256::new(seed);
            let (states_a, _) = init_groups(&eng_a, &sizes, dtype, &mut ra);
            let mut rc = crate::util::rng::Xoshiro256::new(seed);
            let (states_c, _) = init_groups(&eng_c, &sizes, dtype, &mut rc);
            let eng_c: Arc<dyn NvmeEngine> = Arc::new(eng_c);
            let aio = AsyncEngine::new(Arc::clone(&eng_c), 2);
            let stage = StageExecutor::new(1);
            let co = CoalescedOptim::build(eng_c.as_ref(), &states_c, target)
                .map_err(|e| e.to_string())?;
            let keys: Vec<String> =
                (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
            for t in 1..=2u64 {
                let grads: Vec<Vec<f32>> = sizes
                    .iter()
                    .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
                    .collect();
                let grad_refs: Vec<&[f32]> =
                    grads.iter().map(|g| g.as_slice()).collect();
                for (g, st) in states_a.iter().enumerate() {
                    st.step(&eng_a, &grads[g], t, 2.0, &hp, 1, &keys[g])
                        .map_err(|e| e.to_string())?;
                }
                co.step_tiled(
                    &aio, &stage, &arena(), &grad_refs, &keys, t, 2.0, &hp, 1, tile, 2,
                )
                .map_err(|e| e.to_string())?;
            }
            let es = dtype.bytes_per_elem();
            for (g, n) in sizes.iter().enumerate() {
                for suffix in ["master", "adam_m", "adam_v"] {
                    let mut a = vec![0u8; n * es];
                    let mut c = vec![0u8; n * es];
                    eng_a
                        .read(&format!("g{g}/{suffix}"), &mut a)
                        .map_err(|e| e.to_string())?;
                    co.read_member_state(eng_c.as_ref(), g, suffix, &mut c)
                        .map_err(|e| e.to_string())?;
                    prop_assert!(
                        a == c,
                        "{dtype:?} target={target} tile={tile} g{g}/{suffix} diverged (n={n})"
                    );
                }
                let mut a = vec![0u8; n * 2];
                let mut c = vec![0u8; n * 2];
                eng_a.read(&format!("g{g}/fp16"), &mut a).map_err(|e| e.to_string())?;
                eng_c.read(&format!("g{g}/fp16"), &mut c).map_err(|e| e.to_string())?;
                prop_assert!(a == c, "{dtype:?} g{g}/fp16 diverged (n={n})");
            }
            std::fs::remove_dir_all(&dir_a).ok();
            std::fs::remove_dir_all(&dir_c).ok();
            Ok(())
        });
    }
}
