//! CPU Adam/AdamW — the DeepSpeed host-optimizer analog.
//!
//! ZeRO-Infinity runs the optimizer on the CPU because its arithmetic
//! intensity never justifies moving optimizer states over PCIe
//! (§II-A).  This is the fused C++/AVX backend's Rust counterpart:
//! contiguous flat buffers, a chunked parallel loop, bias correction
//! and decoupled weight decay in one pass, with gradient unscaling
//! (the dynamic-loss-scale divide) folded in so gradients are never
//! rewritten.
//!
//! Two state layouts:
//! - fp32 states (baseline): `m`, `v`, master `p` all f32.
//! - bf16 states (§VI-B-3a "pure half-precision optimizer"): `m`, `v`,
//!   and master `p` stored as bf16 (direct truncation from f32), halving
//!   optimizer I/O volume — Fig. 20 / Table VI.
//!
//! Residency and streaming live in [`states`]: the sequential
//! reference loop, the whole-group double-buffered swap (its fetch
//! staging rides pinned `Cat::OptimBuf` leases, degrading to owned
//! vectors under budget refusal), and the staged-tile pipeline
//! (`step_groups_tiled`) that caps peak pinned DRAM at `O(tile_bytes ×
//! depth)` independent of group size.  [`coalesce`] adds the layout
//! layer above them: many small per-tensor groups concatenate into a
//! bounded number of *super-groups* (a stable, persisted key →
//! (super-group, offset) mapping), so the tile pipeline drives long
//! contiguous ranged I/O instead of one sub-tile submission burst per
//! tensor — the per-step NVMe submission count drops from
//! `O(members)` to `O(state bytes / tile_bytes)` plus one fp16
//! scatter write per member.  All drivers produce bit-identical state.
//!
//! The tile size and pipeline depth these drivers take are *policy*
//! inputs: static from `TrainSpec` by default, retuned each step by
//! [`crate::train::PipelineGovernor`] when the governor is enabled.

pub mod coalesce;
pub mod states;

pub use coalesce::{CoalescedLayout, CoalescedOptim, MemberSpan};
pub use states::{
    flush_groups, step_groups_pipelined, step_groups_tiled, Fp16Staging, OptimState,
    PipelineStats, StateBuf, StateBufs, StateDtype, StateFetch, StateScratch,
    StateWriteback, TILE_PIPELINE_DEPTH,
};

use crate::util::par;

#[derive(Debug, Clone, Copy)]
pub struct AdamParams {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
}

impl Default for AdamParams {
    fn default() -> Self {
        Self { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// One fused AdamW step over f32 flat buffers.
///
/// `grads` are *scaled* by `grad_scale` (dynamic loss scaling); the
/// unscale divide happens inline. `step` is 1-based.
#[allow(clippy::too_many_arguments)]
pub fn adam_step_f32(
    p: &mut [f32],
    grads: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    step: u64,
    grad_scale: f32,
    hp: &AdamParams,
    threads: usize,
) {
    let n = p.len();
    assert!(grads.len() == n && m.len() == n && v.len() == n);
    // fp32 arithmetic inside the loop — DeepSpeed's AVX backend
    // semantics, and ~1.8x faster than the f64 path on this core
    // (§Perf); bias corrections still come from f64 pow.
    let bc1 = (1.0 - hp.beta1.powi(step as i32)) as f32;
    let bc2 = (1.0 - hp.beta2.powi(step as i32)) as f32;
    let inv_scale = 1.0f32 / grad_scale;
    let (lr, b1, b2, eps, wd) = (
        hp.lr as f32,
        hp.beta1 as f32,
        hp.beta2 as f32,
        hp.eps as f32,
        hp.weight_decay as f32,
    );

    // Chunked loop: each chunk updates its disjoint spans of all four
    // buffers. Single pass, no temporaries (the fusion the paper's
    // AVX backend performs).
    let chunks = par::chunks(n, threads.max(1));
    std::thread::scope(|scope| {
        // SAFETY-free split: partition all slices identically.
        let mut p_rest = p;
        let mut m_rest = m;
        let mut v_rest = v;
        let mut handles = Vec::new();
        let mut offset = 0usize;
        for (s, e) in chunks {
            let take = e - s;
            let (p_c, pr) = p_rest.split_at_mut(take);
            let (m_c, mr) = m_rest.split_at_mut(take);
            let (v_c, vr) = v_rest.split_at_mut(take);
            p_rest = pr;
            m_rest = mr;
            v_rest = vr;
            let g_c = &grads[offset..offset + take];
            offset += take;
            handles.push(scope.spawn(move || {
                for i in 0..p_c.len() {
                    let g = g_c[i] * inv_scale;
                    let mi = b1 * m_c[i] + (1.0 - b1) * g;
                    let vi = b2 * v_c[i] + (1.0 - b2) * g * g;
                    let m_hat = mi / bc1;
                    let v_hat = vi / bc2;
                    let pi = p_c[i];
                    p_c[i] = pi - lr * (m_hat / (v_hat.sqrt() + eps) + wd * pi);
                    m_c[i] = mi;
                    v_c[i] = vi;
                }
            }));
        }
    });
}

/// AdamW step where `m`, `v`, and master `p` live as packed bf16
/// (loaded to f32 per chunk, updated, truncated back). `p_bf16`,
/// `m_bf16`, `v_bf16` are little-endian bf16 byte buffers.
#[allow(clippy::too_many_arguments)]
pub fn adam_step_bf16(
    p_bf16: &mut [u8],
    grads: &[f32],
    m_bf16: &mut [u8],
    v_bf16: &mut [u8],
    step: u64,
    grad_scale: f32,
    hp: &AdamParams,
    _threads: usize,
) {
    use crate::dtype::{bf16_to_f32, f32_to_bf16};
    let n = grads.len();
    assert!(p_bf16.len() == 2 * n && m_bf16.len() == 2 * n && v_bf16.len() == 2 * n);
    let bc1 = 1.0 - hp.beta1.powi(step as i32);
    let bc2 = 1.0 - hp.beta2.powi(step as i32);
    let inv_scale = 1.0 / grad_scale as f64;
    let rd = |b: &[u8], i: usize| bf16_to_f32(u16::from_le_bytes([b[2 * i], b[2 * i + 1]]));
    for i in 0..n {
        let g = grads[i] as f64 * inv_scale;
        let mi = hp.beta1 * rd(m_bf16, i) as f64 + (1.0 - hp.beta1) * g;
        let vi = hp.beta2 * rd(v_bf16, i) as f64 + (1.0 - hp.beta2) * g * g;
        let m_hat = mi / bc1;
        let v_hat = vi / bc2;
        let pi = rd(p_bf16, i) as f64;
        let pnew =
            pi - hp.lr * (m_hat / (v_hat.sqrt() + hp.eps) + hp.weight_decay * pi);
        p_bf16[2 * i..2 * i + 2].copy_from_slice(&f32_to_bf16(pnew as f32).to_le_bytes());
        m_bf16[2 * i..2 * i + 2].copy_from_slice(&f32_to_bf16(mi as f32).to_le_bytes());
        v_bf16[2 * i..2 * i + 2].copy_from_slice(&f32_to_bf16(vi as f32).to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scalar reference Adam (textbook form).
    fn reference(
        p: &mut Vec<f64>,
        g: &[f64],
        m: &mut Vec<f64>,
        v: &mut Vec<f64>,
        t: u64,
        hp: &AdamParams,
    ) {
        for i in 0..p.len() {
            m[i] = hp.beta1 * m[i] + (1.0 - hp.beta1) * g[i];
            v[i] = hp.beta2 * v[i] + (1.0 - hp.beta2) * g[i] * g[i];
            let mh = m[i] / (1.0 - hp.beta1.powi(t as i32));
            let vh = v[i] / (1.0 - hp.beta2.powi(t as i32));
            p[i] -= hp.lr * (mh / (vh.sqrt() + hp.eps) + hp.weight_decay * p[i]);
        }
    }

    #[test]
    fn matches_reference_over_steps() {
        let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
        let n = 1000;
        let mut rng = crate::util::rng::Xoshiro256::new(1);
        let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut m = vec![0f32; n];
        let mut v = vec![0f32; n];
        let mut pr: Vec<f64> = p.iter().map(|&x| x as f64).collect();
        let mut mr = vec![0f64; n];
        let mut vr = vec![0f64; n];
        for t in 1..=20 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let gr: Vec<f64> = g.iter().map(|&x| x as f64).collect();
            adam_step_f32(&mut p, &g, &mut m, &mut v, t, 1.0, &hp, 1);
            reference(&mut pr, &gr, &mut mr, &mut vr, t, &hp);
        }
        for i in 0..n {
            assert!(
                (p[i] as f64 - pr[i]).abs() < 1e-4,
                "param {i}: {} vs {}",
                p[i],
                pr[i]
            );
        }
    }

    #[test]
    fn grad_scale_is_unscaled() {
        let hp = AdamParams::default();
        let scale = 1024.0f32;
        let mut p1 = vec![1.0f32; 8];
        let (mut m1, mut v1) = (vec![0f32; 8], vec![0f32; 8]);
        let mut p2 = vec![1.0f32; 8];
        let (mut m2, mut v2) = (vec![0f32; 8], vec![0f32; 8]);
        let g = vec![0.5f32; 8];
        let g_scaled: Vec<f32> = g.iter().map(|x| x * scale).collect();
        adam_step_f32(&mut p1, &g, &mut m1, &mut v1, 1, 1.0, &hp, 1);
        adam_step_f32(&mut p2, &g_scaled, &mut m2, &mut v2, 1, scale, &hp, 1);
        for i in 0..8 {
            assert!((p1[i] - p2[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn multithreaded_matches_single() {
        let hp = AdamParams::default();
        let n = 10_007;
        let mut rng = crate::util::rng::Xoshiro256::new(3);
        let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p1 = p0.clone();
        let (mut m1, mut v1) = (vec![0f32; n], vec![0f32; n]);
        let mut p4 = p0;
        let (mut m4, mut v4) = (vec![0f32; n], vec![0f32; n]);
        adam_step_f32(&mut p1, &g, &mut m1, &mut v1, 1, 1.0, &hp, 1);
        adam_step_f32(&mut p4, &g, &mut m4, &mut v4, 1, 1.0, &hp, 4);
        assert_eq!(p1, p4);
        assert_eq!(m1, m4);
    }

    #[test]
    fn bf16_states_approximate_f32() {
        let hp = AdamParams::default();
        let n = 256;
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut pf = p0.clone();
        let (mut mf, mut vf) = (vec![0f32; n], vec![0f32; n]);
        let mut pb = vec![0u8; 2 * n];
        crate::dtype::f32s_to_bf16_bytes(&p0, &mut pb);
        let (mut mb, mut vb) = (vec![0u8; 2 * n], vec![0u8; 2 * n]);
        for t in 1..=10 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            adam_step_f32(&mut pf, &g, &mut mf, &mut vf, t, 1.0, &hp, 1);
            adam_step_bf16(&mut pb, &g, &mut mb, &mut vb, t, 1.0, &hp, 1);
        }
        let mut back = vec![0f32; n];
        crate::dtype::bf16_bytes_to_f32s(&pb, &mut back);
        for i in 0..n {
            // bf16 has ~3 decimal digits: loose tolerance, but the
            // trajectory must track
            assert!(
                (back[i] - pf[i]).abs() < 0.05,
                "{i}: {} vs {}",
                back[i],
                pf[i]
            );
        }
    }
}
