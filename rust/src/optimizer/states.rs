//! Optimizer state residency: SSD-backed subgroup swapping, tiled.
//!
//! ZeRO-Infinity updates optimizer states in *subgroups*: for each
//! contiguous span of parameters it reads (master, m, v) from SSD into
//! pinned buffers, updates on CPU, and writes them back — so host
//! memory holds only a subgroup at a time, not 12 bytes/param.  This
//! module owns that loop and its I/O-volume accounting (Fig. 20).
//!
//! Three drivers exist over the same arithmetic:
//!
//! - [`OptimState::step`] — the sequential reference: read m/v/master,
//!   Adam, write back, one group at a time.  Every byte of I/O is
//!   foreground stall.
//! - [`step_groups_pipelined`] — the double-buffered swap: group k+1's
//!   states are fetched over the async queue while Adam runs on group
//!   k and group k-1's write-back drains.  Peak pinned bytes scale
//!   with the *largest group* — one embedding or MoE-expert group sets
//!   the high-water mark regardless of the budget.
//! - [`step_groups_tiled`] — the staged-tile pipeline: every group's
//!   m/v/master streams are split into fixed-byte tiles
//!   (`TrainSpec::optim_tile_bytes`) and driven through four
//!   overlapping stages, with the dtype conversions on a compute-side
//!   [`StageExecutor`] instead of the NVMe queue workers:
//!
//! ```text
//!   fetch (NVMe queue):   [t0] [t1] [t2] [t3]
//!   adam  (caller):            [t0] [t1] [t2] [t3]
//!   convert (stage pool):           [t0] [t1] [t2] [t3]
//!   write (NVMe queue):              [t0]  [t1]  [t2]  [t3]
//! ```
//!
//!   In-flight state lives in real [`PinnedArena`] leases
//!   (`Cat::OptimBuf` for m/v/master tiles, `Cat::SwapBuf` for the
//!   fp16 window), bounded by the fetch and write-back windows — peak
//!   pinned optimizer memory is `O(tile_bytes × depth)`, *independent
//!   of group size* (ZeRO-Infinity's subgroup semantics at fixed byte
//!   granularity; SSDTrain's fixed-window overlapped transfers).
//!
//! All drivers produce bit-identical state: same bytes read, same
//! elementwise arithmetic over disjoint windows, same bytes written,
//! only reordered in time across distinct keys/ranges.  `tile_bytes =
//! 0` falls back to the whole-group double-buffer.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::dtype::DType;
use crate::pinned::{Cat, Lease, PinnedArena};
use crate::ssd::{AsyncEngine, IoHandle, NvmeEngine};
use crate::util::stage::StageExecutor;

/// Optimizer state storage precision (paper §VI-B-3a).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StateDtype {
    F32,
    BF16,
}

impl StateDtype {
    pub fn dtype(self) -> DType {
        match self {
            StateDtype::F32 => DType::F32,
            StateDtype::BF16 => DType::BF16,
        }
    }

    pub fn bytes_per_elem(self) -> usize {
        self.dtype().size()
    }
}

/// Produce the fp16 compute window from an updated master window (its
/// raw stored bytes: LE f32 or LE bf16).  **The** downconvert all
/// drivers share — sequential, whole-group pipelined, tiled, and the
/// tiled degradation paths — kept in one place so the bit-identity
/// guarantee has a single implementation.
pub(super) fn master_to_fp16(dtype: StateDtype, master: &[u8], fp16: &mut [u8]) {
    match dtype {
        StateDtype::F32 => crate::dtype::f32_le_bytes_to_f16_bytes(master, fp16),
        StateDtype::BF16 => {
            let mut pf = vec![0f32; master.len() / 2];
            crate::dtype::bf16_bytes_to_f32s(master, &mut pf);
            crate::dtype::f32s_to_f16_bytes(&pf, fp16);
        }
    }
}

/// Keys under which one flat group's states live on the SSD.
pub fn state_keys(group: &str) -> [String; 3] {
    [
        format!("{group}/master"),
        format!("{group}/adam_m"),
        format!("{group}/adam_v"),
    ]
}

/// SSD-resident optimizer state for one parameter group.
pub struct OptimState {
    pub group: String,
    pub numel: usize,
    pub dtype: StateDtype,
}

impl OptimState {
    /// Initialize states on the SSD: master = initial params, m = v = 0.
    pub fn init(
        engine: &dyn NvmeEngine,
        group: &str,
        params_f32: &[f32],
        dtype: StateDtype,
    ) -> anyhow::Result<Self> {
        let [k_p, k_m, k_v] = state_keys(group);
        let n = params_f32.len();
        match dtype {
            StateDtype::F32 => {
                engine.write(&k_p, crate::dtype::f32s_as_bytes(params_f32))?;
                let zeros = vec![0u8; n * 4];
                engine.write(&k_m, &zeros)?;
                engine.write(&k_v, &zeros)?;
            }
            StateDtype::BF16 => {
                let mut buf = vec![0u8; n * 2];
                crate::dtype::f32s_to_bf16_bytes(params_f32, &mut buf);
                engine.write(&k_p, &buf)?;
                let zeros = vec![0u8; n * 2];
                engine.write(&k_m, &zeros)?;
                engine.write(&k_v, &zeros)?;
            }
        }
        Ok(Self { group: group.to_string(), numel: n, dtype })
    }

    /// Bytes moved (read + write) by one full optimizer step over this
    /// group, including the fp16 compute-weight writeback.
    pub fn io_bytes_per_step(&self) -> u64 {
        let s = self.dtype.bytes_per_elem() as u64;
        let n = self.numel as u64;
        // read master+m+v, write master+m+v, write fp16 compute copy
        n * s * 6 + n * 2
    }

    /// Run one fused AdamW step with states streamed through `engine`.
    /// `grads` are the group's fp32 (scaled) gradients; returns the
    /// updated fp16 compute weights (LE bytes) written back to SSD.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        engine: &dyn NvmeEngine,
        grads: &[f32],
        step: u64,
        grad_scale: f32,
        hp: &super::AdamParams,
        threads: usize,
        fp16_key: &str,
    ) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(grads.len() == self.numel, "grad size mismatch");
        let [k_p, k_m, k_v] = state_keys(&self.group);
        let n = self.numel;
        let mut fp16 = vec![0u8; n * 2];
        match self.dtype {
            StateDtype::F32 => {
                let mut p = vec![0f32; n];
                let mut m = vec![0f32; n];
                let mut v = vec![0f32; n];
                engine.read(&k_p, crate::dtype::f32s_as_bytes_mut(&mut p))?;
                engine.read(&k_m, crate::dtype::f32s_as_bytes_mut(&mut m))?;
                engine.read(&k_v, crate::dtype::f32s_as_bytes_mut(&mut v))?;
                super::adam_step_f32(&mut p, grads, &mut m, &mut v, step, grad_scale, hp, threads);
                engine.write(&k_p, crate::dtype::f32s_as_bytes(&p))?;
                engine.write(&k_m, crate::dtype::f32s_as_bytes(&m))?;
                engine.write(&k_v, crate::dtype::f32s_as_bytes(&v))?;
                master_to_fp16(self.dtype, crate::dtype::f32s_as_bytes(&p), &mut fp16);
            }
            StateDtype::BF16 => {
                let mut p = vec![0u8; n * 2];
                let mut m = vec![0u8; n * 2];
                let mut v = vec![0u8; n * 2];
                engine.read(&k_p, &mut p)?;
                engine.read(&k_m, &mut m)?;
                engine.read(&k_v, &mut v)?;
                super::adam_step_bf16(&mut p, grads, &mut m, &mut v, step, grad_scale, hp, threads);
                engine.write(&k_p, &p)?;
                engine.write(&k_m, &m)?;
                engine.write(&k_v, &v)?;
                master_to_fp16(self.dtype, &p, &mut fp16);
            }
        }
        engine.write(fp16_key, &fp16)?;
        Ok(fp16)
    }

    // ---- split-phase surface for the double-buffered driver ----

    /// Queue async reads for this group's (master, m, v).  Each stream
    /// stages in a pinned `Cat::OptimBuf` lease when the arena grants
    /// one — so whole-group fetch staging sits on the pinned ledger and
    /// inside the budget, exactly like the tile driver's windows — and
    /// degrades to a recycled owned vector otherwise (bit-identical
    /// data either way, never an abort).
    pub fn submit_fetch(&self, aio: &AsyncEngine, scratch: &StateScratch) -> StateFetch {
        let [k_p, k_m, k_v] = state_keys(&self.group);
        let n = self.numel;
        let bytes = n * self.dtype.bytes_per_elem();
        let stream = |key: String| -> StateBufHandle {
            match scratch.lease(bytes) {
                // lease tier: a ranged read over the full span fills
                // the pinned window in place
                Some(l) => StateBufHandle::Lease(aio.submit_read_at_lease(key, 0, l)),
                // owned tier: recycled scratch vector, typed by dtype
                None => match self.dtype {
                    StateDtype::F32 => {
                        StateBufHandle::F32(aio.submit_read_f32(key, scratch.take_f32(n)))
                    }
                    StateDtype::BF16 => {
                        StateBufHandle::Bytes(aio.submit_read(key, scratch.take_bytes(bytes)))
                    }
                },
            }
        };
        StateFetch {
            dtype: self.dtype,
            p: stream(k_p),
            m: stream(k_m),
            v: stream(k_v),
        }
    }

    /// Run the AdamW arithmetic on fetched buffers in place and
    /// produce the fp16 compute copy into `fp16` (exactly `numel * 2`
    /// bytes — a pinned lease's span or an owned vector) — the exact
    /// same kernels [`Self::step`] uses, so the trajectories are
    /// bit-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn compute(
        &self,
        bufs: &mut StateBufs,
        grads: &[f32],
        step: u64,
        grad_scale: f32,
        hp: &super::AdamParams,
        threads: usize,
        fp16: &mut [u8],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(grads.len() == self.numel, "grad size mismatch");
        let n = self.numel;
        anyhow::ensure!(
            fp16.len() == n * 2,
            "fp16 window holds {} bytes, expected {}",
            fp16.len(),
            n * 2
        );
        anyhow::ensure!(bufs.dtype == self.dtype, "state dtype mismatch for '{}'", self.group);
        let want = n * self.dtype.bytes_per_elem();
        anyhow::ensure!(
            bufs.p.byte_len() == want && bufs.m.byte_len() == want && bufs.v.byte_len() == want,
            "state buffer size mismatch for '{}'",
            self.group
        );
        match self.dtype {
            StateDtype::F32 => {
                super::adam_step_f32(
                    bufs.p.as_f32_mut(),
                    grads,
                    bufs.m.as_f32_mut(),
                    bufs.v.as_f32_mut(),
                    step,
                    grad_scale,
                    hp,
                    threads,
                );
            }
            StateDtype::BF16 => {
                super::adam_step_bf16(
                    bufs.p.as_bytes_mut(),
                    grads,
                    bufs.m.as_bytes_mut(),
                    bufs.v.as_bytes_mut(),
                    step,
                    grad_scale,
                    hp,
                    threads,
                );
            }
        }
        master_to_fp16(self.dtype, bufs.p.as_bytes(), fp16);
        Ok(())
    }

    /// Queue async write-back of the updated states plus the fp16
    /// compute copy; vector buffers return to scratch when the handles
    /// drain, lease windows drop back to the arena.
    pub fn submit_writeback(
        &self,
        aio: &AsyncEngine,
        bufs: StateBufs,
        fp16: Fp16Staging,
        fp16_key: &str,
    ) -> StateWriteback {
        let [k_p, k_m, k_v] = state_keys(&self.group);
        let mut wb =
            StateWriteback { f32s: Vec::new(), bytes: Vec::new(), leases: Vec::new() };
        for (key, buf) in [(k_p, bufs.p), (k_m, bufs.m), (k_v, bufs.v)] {
            match buf {
                // lease tier: a ranged write over the full span,
                // straight out of the pinned window
                StateBuf::Lease(l) => wb.leases.push(aio.submit_write_at_lease(key, 0, l)),
                StateBuf::F32(v) => wb.f32s.push(aio.submit_write_f32(key, v)),
                StateBuf::Bytes(v) => wb.bytes.push(aio.submit_write(key, v)),
            }
        }
        match fp16 {
            // lease tier: a ranged write over the (reserved) full span,
            // straight out of pinned memory
            Fp16Staging::Lease(l) => {
                wb.leases.push(aio.submit_write_at_lease(fp16_key.to_string(), 0, l))
            }
            Fp16Staging::Owned(v) => {
                wb.bytes.push(aio.submit_write(fp16_key.to_string(), v))
            }
        }
        wb
    }
}

/// fp16 compute-window staging for the whole-group drivers: a pinned
/// lease (view tier — written back with a ranged lease write) when the
/// arena grants one, an owned scratch vector otherwise.  The
/// optimizer-side analog of the boundary's `F32Staging`.
pub enum Fp16Staging {
    Lease(Lease),
    Owned(Vec<u8>),
}

impl Fp16Staging {
    /// The byte-tier lease-else-owned policy in one place (mirrors
    /// `runtime::F32Staging::take`): a pinned `Cat::OptimBuf` lease
    /// when the arena grants one, else owned scratch.  Unmetered by
    /// design — this is optimizer-side fp16 staging, not an fp32 copy
    /// on the PJRT boundary path that `host_copy_bytes` accounts.
    pub fn take(scratch: &StateScratch, bytes: usize) -> Self {
        match scratch.lease(bytes) {
            Some(l) => Fp16Staging::Lease(l),
            None => Fp16Staging::Owned(scratch.take_bytes(bytes)),
        }
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        match self {
            Fp16Staging::Lease(l) => l.as_mut_slice(),
            Fp16Staging::Owned(v) => v,
        }
    }
}

/// One staged whole-group state stream (master, m, or v): a pinned
/// `Cat::OptimBuf` lease on the budget-ledgered tier, a recycled owned
/// vector (typed by storage dtype) when the arena degraded the fetch.
pub enum StateBuf {
    Lease(Lease),
    F32(Vec<f32>),
    Bytes(Vec<u8>),
}

impl StateBuf {
    fn byte_len(&self) -> usize {
        match self {
            StateBuf::Lease(l) => l.as_slice().len(),
            StateBuf::F32(v) => v.len() * 4,
            StateBuf::Bytes(v) => v.len(),
        }
    }

    fn as_bytes(&self) -> &[u8] {
        match self {
            StateBuf::Lease(l) => l.as_slice(),
            StateBuf::F32(v) => crate::dtype::f32s_as_bytes(v),
            StateBuf::Bytes(v) => v,
        }
    }

    fn as_bytes_mut(&mut self) -> &mut [u8] {
        match self {
            StateBuf::Lease(l) => l.as_mut_slice(),
            StateBuf::F32(v) => crate::dtype::f32s_as_bytes_mut(v),
            StateBuf::Bytes(v) => v,
        }
    }

    fn as_f32_mut(&mut self) -> &mut [f32] {
        match self {
            StateBuf::Lease(l) => l.as_f32_mut(),
            StateBuf::F32(v) => v,
            StateBuf::Bytes(_) => unreachable!("bf16 stream driven through the f32 kernel"),
        }
    }
}

/// One group's staged state buffers (master, m, v).
pub struct StateBufs {
    dtype: StateDtype,
    p: StateBuf,
    m: StateBuf,
    v: StateBuf,
}

enum StateBufHandle {
    Lease(IoHandle<Lease>),
    F32(IoHandle<Vec<f32>>),
    Bytes(IoHandle<Vec<u8>>),
}

impl StateBufHandle {
    fn wait(self) -> anyhow::Result<StateBuf> {
        Ok(match self {
            StateBufHandle::Lease(h) => StateBuf::Lease(h.wait()?),
            StateBufHandle::F32(h) => StateBuf::F32(h.wait()?),
            StateBufHandle::Bytes(h) => StateBuf::Bytes(h.wait()?),
        })
    }
}

/// In-flight prefetch of one group's three state tensors.
pub struct StateFetch {
    dtype: StateDtype,
    p: StateBufHandle,
    m: StateBufHandle,
    v: StateBufHandle,
}

impl StateFetch {
    pub fn wait(self) -> anyhow::Result<StateBufs> {
        Ok(StateBufs {
            dtype: self.dtype,
            p: self.p.wait()?,
            m: self.m.wait()?,
            v: self.v.wait()?,
        })
    }
}

/// In-flight write-back of one group (states + fp16 compute copy).
pub struct StateWriteback {
    f32s: Vec<IoHandle<Vec<f32>>>,
    bytes: Vec<IoHandle<Vec<u8>>>,
    leases: Vec<IoHandle<Lease>>,
}

impl StateWriteback {
    /// Drain all writes; vector buffers go back to `scratch` for the
    /// next generation, lease windows drop (their extents recycle in
    /// the arena).
    pub fn wait(self, scratch: &StateScratch) -> anyhow::Result<()> {
        for h in self.f32s {
            scratch.put_f32(h.wait()?);
        }
        for h in self.bytes {
            scratch.put_bytes(h.wait()?);
        }
        for h in self.leases {
            h.wait()?;
        }
        Ok(())
    }
}

/// Staging tier for the double-buffered swap, under `Cat::OptimBuf`:
/// vends pinned leases first (the two generations of (master, m, v)
/// windows alive in steady state are then real ledgered pinned bytes
/// inside the budget, like the tile driver's windows) and recycled
/// owned vectors on refusal — and survives across steps (the arena
/// pool and free extents outlive any one `step_groups_pipelined`
/// call).
pub struct StateScratch {
    arena: Arc<PinnedArena>,
}

impl StateScratch {
    pub fn new(arena: Arc<PinnedArena>) -> Self {
        Self { arena }
    }

    /// Vend a pinned view-tier buffer: lease `bytes` under
    /// `Cat::OptimBuf`.  `None` under budget refusal or a Virtual-mode
    /// arena — callers degrade to the owned vector tier below, exactly
    /// like the swapper's scratch.
    pub fn lease(&self, bytes: usize) -> Option<Lease> {
        let l = self.arena.lease(bytes, Cat::OptimBuf).ok()?;
        (!l.is_virtual()).then_some(l)
    }

    fn take_f32(&self, n: usize) -> Vec<f32> {
        self.arena.take_f32(n, Cat::OptimBuf)
    }

    fn take_bytes(&self, n: usize) -> Vec<u8> {
        self.arena.take_bytes(n, Cat::OptimBuf)
    }

    fn put_f32(&self, v: Vec<f32>) {
        self.arena.put_f32(v, Cat::OptimBuf)
    }

    fn put_bytes(&self, v: Vec<u8>) {
        self.arena.put_bytes(v, Cat::OptimBuf)
    }
}

/// Foreground-stall accounting for one pipelined optimizer pass.
#[derive(Debug, Default, Clone, Copy)]
pub struct PipelineStats {
    /// Seconds the driver thread blocked waiting on fetch/write-back
    /// completions (I/O *not* hidden behind the Adam compute).
    pub wait_secs: f64,
    /// Tiles streamed by [`step_groups_tiled`] (0 for the whole-group
    /// drivers).
    pub tiles: u64,
    /// Tiles the staged pipeline degraded to the synchronous unpinned
    /// path because the arena refused a lease (pinned budget pressure
    /// from other components).  Correctness is unaffected; a non-zero
    /// count means the budget is too tight for the tile window.
    pub degraded_tiles: u64,
}

/// Double-buffered SSD-swapped AdamW over `groups`: while Adam runs on
/// group k, group k+1's states stream in and group k-1's write-back
/// drains.  `grads[i]` / `fp16_keys[i]` belong to `groups[i]`.
/// Staging buffers lease-recycle through `arena` (`Cat::OptimBuf`).
#[allow(clippy::too_many_arguments)]
pub fn step_groups_pipelined(
    aio: &AsyncEngine,
    arena: &Arc<PinnedArena>,
    groups: &[OptimState],
    grads: &[&[f32]],
    fp16_keys: &[String],
    step: u64,
    grad_scale: f32,
    hp: &super::AdamParams,
    threads: usize,
) -> anyhow::Result<PipelineStats> {
    anyhow::ensure!(
        groups.len() == grads.len() && groups.len() == fp16_keys.len(),
        "groups/grads/keys length mismatch"
    );
    // validate up front, and make sure every fp16 destination exists
    // so the lease tier's ranged writes have a span to land in
    for (g, st) in groups.iter().enumerate() {
        anyhow::ensure!(
            grads[g].len() == st.numel,
            "grad size mismatch for '{}'",
            st.group
        );
        aio.engine().reserve(&fp16_keys[g], st.numel * 2)?;
    }
    let scratch = StateScratch::new(Arc::clone(arena));
    let mut stats = PipelineStats::default();
    let mut prev_wb: Option<StateWriteback> = None;
    let mut next_fetch = groups.first().map(|g| g.submit_fetch(aio, &scratch));
    for (k, st) in groups.iter().enumerate() {
        let fetch_k = next_fetch.take().expect("fetch scheduled for every group");
        // overlap: group k+1's reads start before we block on k's
        if let Some(nx) = groups.get(k + 1) {
            next_fetch = Some(nx.submit_fetch(aio, &scratch));
        }
        let t0 = std::time::Instant::now();
        let mut bufs = fetch_k.wait()?;
        stats.wait_secs += t0.elapsed().as_secs_f64();
        // Adam on the caller thread, overlapping k+1's fetch and
        // k-1's write-back.  The fp16 compute window prefers the
        // pinned view tier; budget refusal degrades to owned scratch.
        let mut fp16 = Fp16Staging::take(&scratch, st.numel * 2);
        st.compute(&mut bufs, grads[k], step, grad_scale, hp, threads, fp16.as_mut_slice())?;
        // drain k-1's write generation before queueing k's: bounds
        // in-flight state memory to two generations
        if let Some(wb) = prev_wb.take() {
            let t0 = std::time::Instant::now();
            wb.wait(&scratch)?;
            stats.wait_secs += t0.elapsed().as_secs_f64();
        }
        prev_wb = Some(st.submit_writeback(aio, bufs, fp16, &fp16_keys[k]));
    }
    if let Some(wb) = prev_wb {
        let t0 = std::time::Instant::now();
        wb.wait(&scratch)?;
        stats.wait_secs += t0.elapsed().as_secs_f64();
    }
    Ok(stats)
}

// ---------------------------------------------------------------------------
// The staged-tile driver.

/// Default tile-pipeline window: fetch generations kept in flight and
/// write-back generations allowed to drain behind compute.
pub const TILE_PIPELINE_DEPTH: usize = 2;

/// One tile's in-flight fetch: three pinned leases filling off the
/// NVMe queue.
struct TileFetch {
    g: usize,
    start: usize,
    cnt: usize,
    p: IoHandle<Lease>,
    m: IoHandle<Lease>,
    v: IoHandle<Lease>,
}

/// One tile's in-flight write-back (m/v/master windows + the fp16
/// compute window); waiting it drops the leases, recycling their
/// extents.
struct TileWriteback {
    handles: Vec<IoHandle<Lease>>,
}

impl TileWriteback {
    fn drain(self) -> anyhow::Result<()> {
        for h in self.handles {
            h.wait()?;
        }
        Ok(())
    }
}

/// Queue one tile's three ranged reads into fresh pinned leases.  The
/// only failure is a lease refusal (typed, so the driver can degrade
/// instead of aborting mid-step); submission itself cannot fail.
fn submit_tile_fetch(
    aio: &AsyncEngine,
    arena: &PinnedArena,
    st: &OptimState,
    g: usize,
    start: usize,
    cnt: usize,
) -> Result<TileFetch, crate::pinned::ArenaError> {
    let es = st.dtype.bytes_per_elem();
    let [k_p, k_m, k_v] = state_keys(&st.group);
    let off = start * es;
    let len = cnt * es;
    // leases are taken on the caller thread so a budget refusal
    // surfaces synchronously as a structured error
    let lp = arena.lease(len, Cat::OptimBuf)?;
    let lm = arena.lease(len, Cat::OptimBuf)?;
    let lv = arena.lease(len, Cat::OptimBuf)?;
    Ok(TileFetch {
        g,
        start,
        cnt,
        p: aio.submit_read_at_lease(k_p, off, lp),
        m: aio.submit_read_at_lease(k_m, off, lm),
        v: aio.submit_read_at_lease(k_v, off, lv),
    })
}

/// Budget-degraded path for one whole tile: fetch, Adam, downconvert,
/// and write back synchronously through transient unpinned buffers —
/// same kernels, same disjoint byte windows, so running a tile this
/// way (even out of order relative to in-flight pipelined tiles) is
/// bit-identical.  Slower, but the arena's "callers degrade, never
/// abort" contract holds: budget pressure can never tear a step.
#[allow(clippy::too_many_arguments)]
fn step_tile_sync(
    engine: &dyn NvmeEngine,
    st: &OptimState,
    grads: &[f32],
    start: usize,
    cnt: usize,
    step: u64,
    grad_scale: f32,
    hp: &super::AdamParams,
    threads: usize,
    fp16_key: &str,
) -> anyhow::Result<()> {
    let es = st.dtype.bytes_per_elem();
    let [k_p, k_m, k_v] = state_keys(&st.group);
    let off = start * es;
    let gslice = &grads[start..start + cnt];
    let mut fp16 = vec![0u8; cnt * 2];
    match st.dtype {
        StateDtype::F32 => {
            let mut p = vec![0f32; cnt];
            let mut m = vec![0f32; cnt];
            let mut v = vec![0f32; cnt];
            engine.read_at(&k_p, off, crate::dtype::f32s_as_bytes_mut(&mut p))?;
            engine.read_at(&k_m, off, crate::dtype::f32s_as_bytes_mut(&mut m))?;
            engine.read_at(&k_v, off, crate::dtype::f32s_as_bytes_mut(&mut v))?;
            super::adam_step_f32(&mut p, gslice, &mut m, &mut v, step, grad_scale, hp, threads);
            engine.write_at(&k_p, off, crate::dtype::f32s_as_bytes(&p))?;
            engine.write_at(&k_m, off, crate::dtype::f32s_as_bytes(&m))?;
            engine.write_at(&k_v, off, crate::dtype::f32s_as_bytes(&v))?;
            master_to_fp16(st.dtype, crate::dtype::f32s_as_bytes(&p), &mut fp16);
        }
        StateDtype::BF16 => {
            let mut p = vec![0u8; cnt * 2];
            let mut m = vec![0u8; cnt * 2];
            let mut v = vec![0u8; cnt * 2];
            engine.read_at(&k_p, off, &mut p)?;
            engine.read_at(&k_m, off, &mut m)?;
            engine.read_at(&k_v, off, &mut v)?;
            super::adam_step_bf16(&mut p, gslice, &mut m, &mut v, step, grad_scale, hp, threads);
            engine.write_at(&k_p, off, &p)?;
            engine.write_at(&k_m, off, &m)?;
            engine.write_at(&k_v, off, &v)?;
            master_to_fp16(st.dtype, &p, &mut fp16);
        }
    }
    engine.write_at(fp16_key, start * 2, &fp16)?;
    Ok(())
}

/// [`step_tile_sync`]'s write-back half, for a tile whose states are
/// already updated in leases but whose fp16 window lease was refused:
/// downconvert into a transient buffer and write everything back
/// synchronously (the leases drop on return, freeing their extents).
fn writeback_tile_sync(
    engine: &dyn NvmeEngine,
    st: &OptimState,
    p: Lease,
    m: Lease,
    v: Lease,
    start: usize,
    cnt: usize,
    fp16_key: &str,
) -> anyhow::Result<()> {
    let es = st.dtype.bytes_per_elem();
    let [k_p, k_m, k_v] = state_keys(&st.group);
    let off = start * es;
    let mut fp16 = vec![0u8; cnt * 2];
    master_to_fp16(st.dtype, p.as_slice(), &mut fp16);
    engine.write_at(&k_p, off, p.as_slice())?;
    engine.write_at(&k_m, off, m.as_slice())?;
    engine.write_at(&k_v, off, v.as_slice())?;
    engine.write_at(fp16_key, start * 2, &fp16)?;
    Ok(())
}

/// Queue tile downconvert + write-back: the fp16 conversion runs on
/// the compute-side stage executor (not an NVMe queue worker, not the
/// caller), then the stage job itself submits the four ranged writes.
/// The only failure is the fp16 window's lease refusal (typed; the
/// tile's state leases are handed back to the caller for the
/// synchronous fallback).
#[allow(clippy::too_many_arguments)]
fn submit_tile_writeback(
    aio: &AsyncEngine,
    stage: &StageExecutor,
    arena: &PinnedArena,
    st: &OptimState,
    p: Lease,
    m: Lease,
    v: Lease,
    start: usize,
    cnt: usize,
    fp16_key: &str,
) -> Result<IoHandle<TileWriteback>, (crate::pinned::ArenaError, Lease, Lease, Lease)> {
    let mut fp16 = match arena.lease(cnt * 2, Cat::SwapBuf) {
        Ok(l) => l,
        Err(e) => return Err((e, p, m, v)),
    };
    let (completer, handle) = IoHandle::pair();
    let aio = aio.clone();
    let [k_p, k_m, k_v] = state_keys(&st.group);
    let dtype = st.dtype;
    let off = start * dtype.bytes_per_elem();
    let fp16_off = start * 2;
    let fp16_key = fp16_key.to_string();
    stage.submit(move || {
        // downconvert the updated master window into the fp16 compute
        // window — the same shared conversion `OptimState::step` runs,
        // over an elementwise-disjoint range
        master_to_fp16(dtype, p.as_slice(), fp16.as_mut_slice());
        let wb = TileWriteback {
            handles: vec![
                aio.submit_write_at_lease(k_p, off, p),
                aio.submit_write_at_lease(k_m, off, m),
                aio.submit_write_at_lease(k_v, off, v),
                aio.submit_write_at_lease(fp16_key, fp16_off, fp16),
            ],
        };
        completer.complete(Ok(wb));
    });
    Ok(handle)
}

/// Tile-granular four-stage AdamW over `groups`: fetch → upconvert →
/// Adam → downconvert/write-back, overlapped across tiles of
/// `tile_bytes` state bytes.  Peak pinned optimizer memory is bounded
/// by the fetch window (`depth` tiles × 3 leases) plus the write-back
/// window (`depth` tiles × 4 leases) — independent of group size,
/// enforced through real arena leases.  Bit-identical to
/// [`OptimState::step`] and [`step_groups_pipelined`]; `tile_bytes =
/// 0` delegates to the whole-group double-buffer.
///
/// Real-mode arenas only (Virtual leases have no storage to stage
/// tiles in).
#[allow(clippy::too_many_arguments)]
pub fn step_groups_tiled(
    aio: &AsyncEngine,
    stage: &StageExecutor,
    arena: &Arc<PinnedArena>,
    groups: &[OptimState],
    grads: &[&[f32]],
    fp16_keys: &[String],
    step: u64,
    grad_scale: f32,
    hp: &super::AdamParams,
    threads: usize,
    tile_bytes: usize,
    depth: usize,
) -> anyhow::Result<PipelineStats> {
    anyhow::ensure!(
        groups.len() == grads.len() && groups.len() == fp16_keys.len(),
        "groups/grads/keys length mismatch"
    );
    if tile_bytes == 0 {
        return step_groups_pipelined(
            aio, arena, groups, grads, fp16_keys, step, grad_scale, hp, threads,
        );
    }
    // validate everything and reserve fp16 destinations before any
    // tile is in flight — errors surface before a byte moves
    for (g, st) in groups.iter().enumerate() {
        anyhow::ensure!(
            grads[g].len() == st.numel,
            "grad size mismatch for '{}'",
            st.group
        );
        aio.engine().reserve(&fp16_keys[g], st.numel * 2)?;
    }
    // fixed-byte tile plan across all groups, tails included
    let mut plan: Vec<(usize, usize, usize)> = Vec::new();
    for (g, st) in groups.iter().enumerate() {
        let tile_elems = (tile_bytes / st.dtype.bytes_per_elem()).max(1);
        let mut start = 0;
        while start < st.numel {
            let cnt = tile_elems.min(st.numel - start);
            plan.push((g, start, cnt));
            start += cnt;
        }
    }
    let depth = depth.max(1);
    let mut stats = PipelineStats { tiles: plan.len() as u64, ..Default::default() };
    let mut next = 0usize;
    let mut fetches: VecDeque<TileFetch> = VecDeque::new();
    let mut wbs: VecDeque<IoHandle<TileWriteback>> = VecDeque::new();
    loop {
        // keep the fetch window full; a refused lease degrades that
        // one tile to the synchronous unpinned path (disjoint windows
        // make out-of-order completion safe) instead of aborting a
        // step whose earlier tiles are already durable
        while next < plan.len() && fetches.len() < depth {
            let (g, s, c) = plan[next];
            next += 1;
            match submit_tile_fetch(aio, arena, &groups[g], g, s, c) {
                Ok(tf) => fetches.push_back(tf),
                Err(_budget) => {
                    step_tile_sync(
                        aio.engine().as_ref(),
                        &groups[g],
                        grads[g],
                        s,
                        c,
                        step,
                        grad_scale,
                        hp,
                        threads,
                        &fp16_keys[g],
                    )?;
                    stats.degraded_tiles += 1;
                }
            }
        }
        let Some(tf) = fetches.pop_front() else { break };
        let t0 = Instant::now();
        let mut p = tf.p.wait()?;
        let mut m = tf.m.wait()?;
        let mut v = tf.v.wait()?;
        stats.wait_secs += t0.elapsed().as_secs_f64();
        let st = &groups[tf.g];
        let gslice = &grads[tf.g][tf.start..tf.start + tf.cnt];
        // Adam on the caller thread, overlapping the next tile's fetch
        // and the previous tiles' conversion/write-back — the same
        // kernels `step` runs, over an elementwise-disjoint window
        match st.dtype {
            StateDtype::F32 => super::adam_step_f32(
                p.as_f32_mut(),
                gslice,
                m.as_f32_mut(),
                v.as_f32_mut(),
                step,
                grad_scale,
                hp,
                threads,
            ),
            StateDtype::BF16 => super::adam_step_bf16(
                p.as_mut_slice(),
                gslice,
                m.as_mut_slice(),
                v.as_mut_slice(),
                step,
                grad_scale,
                hp,
                threads,
            ),
        }
        // bound in-flight write-back generations before queueing ours
        while wbs.len() >= depth {
            let wb = wbs.pop_front().expect("non-empty window");
            let t0 = Instant::now();
            wb.wait()?.drain()?;
            stats.wait_secs += t0.elapsed().as_secs_f64();
        }
        match submit_tile_writeback(
            aio,
            stage,
            arena,
            st,
            p,
            m,
            v,
            tf.start,
            tf.cnt,
            &fp16_keys[tf.g],
        ) {
            Ok(h) => wbs.push_back(h),
            Err((_budget, p, m, v)) => {
                // fp16 window refused: finish this tile synchronously
                // from the leases we already hold
                writeback_tile_sync(
                    aio.engine().as_ref(),
                    st,
                    p,
                    m,
                    v,
                    tf.start,
                    tf.cnt,
                    &fp16_keys[tf.g],
                )?;
                stats.degraded_tiles += 1;
            }
        }
    }
    while let Some(wb) = wbs.pop_front() {
        let t0 = Instant::now();
        wb.wait()?.drain()?;
        stats.wait_secs += t0.elapsed().as_secs_f64();
    }
    // no fsync here: crash-consistency of a step is out of scope
    // (training state is rebuilt on restart — see ROADMAP), so paying
    // a per-step durability tax the whole-group paths don't pay would
    // buy nothing.  Callers that do need durability (e.g. a future
    // checkpoint path) get it explicitly via `NvmeEngine::flush`.
    Ok(stats)
}

/// One explicit durability point over every SSD artifact the optimizer
/// owns: flush each group's master/m/v streams plus its fp16 compute
/// copy.  Ranged tile writes deliberately never fsync per step (the
/// training loop pays no per-step durability tax; state is rebuilt on
/// restart) — this is where those buffered writes reach a defined
/// durable state.  The trainer's drain/shutdown path calls it once;
/// checkpoint-style callers can call it per key boundary.
pub fn flush_groups(
    engine: &dyn NvmeEngine,
    groups: &[OptimState],
    fp16_keys: &[String],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        groups.len() == fp16_keys.len(),
        "groups/keys length mismatch"
    );
    for (st, fk) in groups.iter().zip(fp16_keys) {
        for k in state_keys(&st.group) {
            engine.flush(&k)?;
        }
        engine.flush(fk)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::optimizer::AdamParams;
    use crate::pinned::Mode;
    use crate::ssd::DirectEngine;

    fn engine(tag: &str) -> (DirectEngine, std::path::PathBuf) {
        let dir =
            std::env::temp_dir().join(format!("ma-opt-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        (DirectEngine::new(&dir, 1, 1 << 26, 1).unwrap(), dir)
    }

    fn arena() -> Arc<PinnedArena> {
        test_arena(Mode::Real)
    }

    #[test]
    fn ssd_swapped_step_matches_in_memory() {
        let (eng, dir) = engine("par");
        let hp = AdamParams::default();
        let n = 500;
        let mut rng = crate::util::rng::Xoshiro256::new(2);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let st = OptimState::init(&eng, "g0", &p0, StateDtype::F32).unwrap();

        // in-memory reference trajectory
        let mut pr = p0.clone();
        let (mut mr, mut vr) = (vec![0f32; n], vec![0f32; n]);
        for t in 1..=5 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            crate::optimizer::adam_step_f32(&mut pr, &g, &mut mr, &mut vr, t, 1.0, &hp, 1);
            st.step(&eng, &g, t, 1.0, &hp, 1, "g0/fp16").unwrap();
        }
        let mut p_ssd = vec![0f32; n];
        eng.read("g0/master", crate::dtype::f32s_as_bytes_mut(&mut p_ssd)).unwrap();
        for i in 0..n {
            assert!((p_ssd[i] - pr[i]).abs() < 1e-6);
        }
        // fp16 compute copy exists and decodes near the master
        let mut fp16 = vec![0u8; n * 2];
        eng.read("g0/fp16", &mut fp16).unwrap();
        let mut back = vec![0f32; n];
        crate::dtype::f16_bytes_to_f32s(&fp16, &mut back);
        for i in 0..n {
            assert!((back[i] - pr[i]).abs() < 2e-3 * pr[i].abs().max(1.0));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bf16_io_volume_is_less_than_half_plus_const() {
        // Fig. 20: bf16 optimizer cuts state I/O by 2x
        let f32_state = OptimState {
            group: "g".into(),
            numel: 1_000_000,
            dtype: StateDtype::F32,
        };
        let bf16_state = OptimState {
            group: "g".into(),
            numel: 1_000_000,
            dtype: StateDtype::BF16,
        };
        let r = bf16_state.io_bytes_per_step() as f64
            / f32_state.io_bytes_per_step() as f64;
        assert!((0.5..0.6).contains(&r), "ratio {r}");
    }

    #[test]
    fn pipelined_groups_bit_identical_to_sequential() {
        for dtype in [StateDtype::F32, StateDtype::BF16] {
            let (eng_a, dir_a) = engine(&format!("seq-{dtype:?}"));
            let (eng_b, dir_b) = engine(&format!("pipe-{dtype:?}"));
            let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
            let mut rng = crate::util::rng::Xoshiro256::new(9);
            let sizes = [700usize, 300, 1100, 64];
            let mut states_a = Vec::new();
            let mut states_b = Vec::new();
            for (g, n) in sizes.iter().enumerate() {
                let p0: Vec<f32> = (0..*n).map(|_| rng.normal() as f32).collect();
                states_a
                    .push(OptimState::init(&eng_a, &format!("g{g}"), &p0, dtype).unwrap());
                states_b
                    .push(OptimState::init(&eng_b, &format!("g{g}"), &p0, dtype).unwrap());
            }
            let eng_b: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng_b);
            let aio = AsyncEngine::new(Arc::clone(&eng_b), 3);
            let arena = arena();
            for t in 1..=4u64 {
                let grads: Vec<Vec<f32>> = sizes
                    .iter()
                    .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
                    .collect();
                for (g, st) in states_a.iter().enumerate() {
                    st.step(&eng_a, &grads[g], t, 2.0, &hp, 1, &format!("g{g}/fp16"))
                        .unwrap();
                }
                let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
                let keys: Vec<String> =
                    (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
                step_groups_pipelined(
                    &aio, &arena, &states_b, &grad_refs, &keys, t, 2.0, &hp, 1,
                )
                .unwrap();
            }
            // fetch staging rode pinned leases (every byte on the
            // ledger while staged), every lease returned, and extents
            // recycled across generations — no owned vectors needed
            let st = arena.stats();
            assert_eq!(st.requested_bytes, 0, "{dtype:?}: staging leases leaked");
            assert!(st.leases > 0, "{dtype:?}: fetch staging never leased");
            assert!(st.recycled > 0, "{dtype:?}: staging extents never recycled");
            assert_eq!(
                arena.pooled_f32(Cat::OptimBuf) + arena.pooled_byte_vecs(Cat::OptimBuf),
                0,
                "{dtype:?}: unbounded arena degraded staging to owned vectors"
            );
            // every stored artifact must match byte-for-byte
            for (g, n) in sizes.iter().enumerate() {
                let es = dtype.bytes_per_elem();
                for suffix in ["master", "adam_m", "adam_v"] {
                    let key = format!("g{g}/{suffix}");
                    let mut a = vec![0u8; n * es];
                    let mut b = vec![0u8; n * es];
                    eng_a.read(&key, &mut a).unwrap();
                    eng_b.read(&key, &mut b).unwrap();
                    assert_eq!(a, b, "{dtype:?} {key} diverged");
                }
                let key = format!("g{g}/fp16");
                let mut a = vec![0u8; n * 2];
                let mut b = vec![0u8; n * 2];
                eng_a.read(&key, &mut a).unwrap();
                eng_b.read(&key, &mut b).unwrap();
                assert_eq!(a, b, "{dtype:?} {key} diverged");
            }
            std::fs::remove_dir_all(&dir_a).ok();
            std::fs::remove_dir_all(&dir_b).ok();
        }
    }

    #[test]
    fn whole_group_staging_degrades_to_owned_under_budget_and_stays_identical() {
        // a starved arena refuses every fetch-staging lease: the
        // whole-group driver must fall back to recycled owned vectors,
        // never abort, and the trajectory stays bit-identical
        for dtype in [StateDtype::F32, StateDtype::BF16] {
            let (eng_a, dir_a) = engine(&format!("degwg-seq-{dtype:?}"));
            let (eng_b, dir_b) = engine(&format!("degwg-pipe-{dtype:?}"));
            let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
            let mut rng = crate::util::rng::Xoshiro256::new(21);
            let n = 900usize;
            let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let st_a = OptimState::init(&eng_a, "g0", &p0, dtype).unwrap();
            let st_b = OptimState::init(&eng_b, "g0", &p0, dtype).unwrap();
            let eng_b: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng_b);
            let aio = AsyncEngine::new(Arc::clone(&eng_b), 2);
            let tracker = Arc::new(crate::pinned::MemoryTracker::new());
            // below one page-padded lease (n*es rounds up to >= 4096),
            // but big enough that the owned fallback vectors can still
            // pool-recycle through the arena afterwards
            let starved = PinnedArena::new(
                Arc::new(crate::pinned::AlignedAllocator::new(Mode::Real, tracker)),
                crate::pinned::ArenaConfig {
                    budget_bytes: Some(4000),
                    ..Default::default()
                },
            );
            for t in 1..=3u64 {
                let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                st_a.step(&eng_a, &g, t, 1.0, &hp, 1, "g0/fp16").unwrap();
                step_groups_pipelined(
                    &aio,
                    &starved,
                    std::slice::from_ref(&st_b),
                    &[g.as_slice()],
                    &["g0/fp16".to_string()],
                    t,
                    1.0,
                    &hp,
                    1,
                )
                .unwrap();
            }
            // the owned tier recycled its vectors through the arena pool
            let pooled = starved.pooled_f32(Cat::OptimBuf)
                + starved.pooled_byte_vecs(Cat::OptimBuf);
            assert!(pooled > 0, "{dtype:?}: degraded staging never pooled");
            let es = dtype.bytes_per_elem();
            assert_engines_identical(
                &eng_a,
                eng_b.as_ref(),
                &[n],
                es,
                &format!("{dtype:?} degraded whole-group"),
            );
            std::fs::remove_dir_all(&dir_a).ok();
            std::fs::remove_dir_all(&dir_b).ok();
        }
    }

    #[test]
    fn pipelined_write_errors_surface() {
        let (eng, dir) = engine("pipe-err");
        let hp = AdamParams::default();
        let st =
            OptimState::init(&eng, "g0", &[1.0f32; 128], StateDtype::F32).unwrap();
        let eng: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng);
        let aio = AsyncEngine::new(eng, 2);
        // wrong-size grads error cleanly out of the pipeline
        let bad: &[f32] = &[0.0; 4];
        let r = step_groups_pipelined(
            &aio,
            &arena(),
            std::slice::from_ref(&st),
            &[bad],
            &["g0/fp16".to_string()],
            1,
            1.0,
            &hp,
            1,
        );
        assert!(r.is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn state_keys_are_namespaced() {
        let [p, m, v] = state_keys("layers.0.wq");
        assert!(p.contains("master") && m.contains("adam_m") && v.contains("adam_v"));
    }

    #[test]
    fn pipelined_fp16_window_rides_the_lease_tier() {
        // with an unbounded real arena the fp16 compute copy must stage
        // in pinned leases (requested bytes return to 0, extents
        // recycle) — not owned vectors
        let (eng, dir) = engine("fp16-lease");
        let hp = AdamParams::default();
        let n = 2048usize;
        let st = OptimState::init(&eng, "g0", &vec![0.5f32; n], StateDtype::F32).unwrap();
        let eng: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng);
        let aio = AsyncEngine::new(eng, 2);
        let a = arena();
        let g = vec![0.1f32; n];
        step_groups_pipelined(
            &aio,
            &a,
            std::slice::from_ref(&st),
            &[g.as_slice()],
            &["g0/fp16".to_string()],
            1,
            1.0,
            &hp,
            1,
        )
        .unwrap();
        let stats = a.stats();
        assert_eq!(stats.requested_bytes, 0, "fp16 window lease leaked");
        assert!(stats.leases >= 1, "fp16 window never leased");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Engine wrapper recording which keys were flushed.
    struct FlushSpy {
        inner: DirectEngine,
        flushed: std::sync::Mutex<Vec<String>>,
        fail_on: Option<String>,
    }

    impl crate::ssd::NvmeEngine for FlushSpy {
        fn write(&self, key: &str, data: &[u8]) -> anyhow::Result<()> {
            self.inner.write(key, data)
        }
        fn read(&self, key: &str, out: &mut [u8]) -> anyhow::Result<()> {
            self.inner.read(key, out)
        }
        fn write_at(&self, key: &str, offset: usize, data: &[u8]) -> anyhow::Result<()> {
            self.inner.write_at(key, offset, data)
        }
        fn flush(&self, key: &str) -> anyhow::Result<()> {
            if self.fail_on.as_deref() == Some(key) {
                anyhow::bail!("injected flush failure on {key}");
            }
            self.flushed.lock().unwrap().push(key.to_string());
            self.inner.flush(key)
        }
        fn len_of(&self, key: &str) -> Option<usize> {
            self.inner.len_of(key)
        }
        fn stats(&self) -> crate::ssd::IoSnapshot {
            self.inner.stats()
        }
        fn label(&self) -> &'static str {
            "flush-spy"
        }
    }

    #[test]
    fn flush_groups_hits_every_state_and_fp16_key_once() {
        let (eng, dir) = engine("flush");
        let spy = FlushSpy { inner: eng, flushed: Default::default(), fail_on: None };
        let groups = vec![
            OptimState { group: "g0".into(), numel: 8, dtype: StateDtype::F32 },
            OptimState { group: "g1".into(), numel: 8, dtype: StateDtype::BF16 },
        ];
        let keys = vec!["g0/fp16".to_string(), "g1/fp16".to_string()];
        flush_groups(&spy, &groups, &keys).unwrap();
        let mut flushed = spy.flushed.lock().unwrap().clone();
        flushed.sort();
        assert_eq!(
            flushed,
            vec![
                "g0/adam_m", "g0/adam_v", "g0/fp16", "g0/master", "g1/adam_m",
                "g1/adam_v", "g1/fp16", "g1/master",
            ]
        );
        // length mismatch is a structured error
        assert!(flush_groups(&spy, &groups, &keys[..1]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flush_errors_surface_out_of_the_drain_path() {
        let (eng, dir) = engine("flush-err");
        let spy = FlushSpy {
            inner: eng,
            flushed: Default::default(),
            fail_on: Some("g0/adam_v".into()),
        };
        let groups =
            vec![OptimState { group: "g0".into(), numel: 8, dtype: StateDtype::F32 }];
        let err = flush_groups(&spy, &groups, &["g0/fp16".to_string()]).unwrap_err();
        assert!(err.to_string().contains("injected flush failure"));
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- staged-tile driver ------------------------------------------

    /// Compare every stored artifact of two engines byte-for-byte.
    fn assert_engines_identical(
        a: &dyn crate::ssd::NvmeEngine,
        b: &dyn crate::ssd::NvmeEngine,
        sizes: &[usize],
        es: usize,
        ctx: &str,
    ) {
        for (g, n) in sizes.iter().enumerate() {
            for (suffix, width) in
                [("master", es), ("adam_m", es), ("adam_v", es), ("fp16", 2)]
            {
                let key = format!("g{g}/{suffix}");
                let mut va = vec![0u8; n * width];
                let mut vb = vec![0u8; n * width];
                a.read(&key, &mut va).unwrap();
                b.read(&key, &mut vb).unwrap();
                assert_eq!(va, vb, "{ctx}: {key} diverged");
            }
        }
    }

    #[test]
    fn tiled_bit_identical_to_sequential_and_pipelined() {
        // covers: group smaller than one tile (64), exact tile
        // multiples (512), and ragged tails (700/300/1100)
        for dtype in [StateDtype::F32, StateDtype::BF16] {
            let (eng_a, dir_a) = engine(&format!("tseq-{dtype:?}"));
            let (eng_b, dir_b) = engine(&format!("tpipe-{dtype:?}"));
            let (eng_c, dir_c) = engine(&format!("ttile-{dtype:?}"));
            let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
            let mut rng = crate::util::rng::Xoshiro256::new(11);
            let sizes = [64usize, 700, 300, 1100, 512];
            let tile_bytes = 1024; // 256 f32 / 512 bf16 elems per tile
            let mut states_a = Vec::new();
            let mut states_b = Vec::new();
            let mut states_c = Vec::new();
            for (g, n) in sizes.iter().enumerate() {
                let p0: Vec<f32> = (0..*n).map(|_| rng.normal() as f32).collect();
                states_a
                    .push(OptimState::init(&eng_a, &format!("g{g}"), &p0, dtype).unwrap());
                states_b
                    .push(OptimState::init(&eng_b, &format!("g{g}"), &p0, dtype).unwrap());
                states_c
                    .push(OptimState::init(&eng_c, &format!("g{g}"), &p0, dtype).unwrap());
            }
            let eng_b: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng_b);
            let eng_c: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng_c);
            let aio_b = AsyncEngine::new(Arc::clone(&eng_b), 3);
            let aio_c = AsyncEngine::new(Arc::clone(&eng_c), 3);
            let stage = StageExecutor::new(2);
            let arena_b = arena();
            let arena_c = arena();
            let keys: Vec<String> =
                (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
            for t in 1..=3u64 {
                let grads: Vec<Vec<f32>> = sizes
                    .iter()
                    .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
                    .collect();
                for (g, st) in states_a.iter().enumerate() {
                    st.step(&eng_a, &grads[g], t, 2.0, &hp, 1, &keys[g]).unwrap();
                }
                let grad_refs: Vec<&[f32]> =
                    grads.iter().map(|g| g.as_slice()).collect();
                step_groups_pipelined(
                    &aio_b, &arena_b, &states_b, &grad_refs, &keys, t, 2.0, &hp, 1,
                )
                .unwrap();
                let stats = step_groups_tiled(
                    &aio_c,
                    &stage,
                    &arena_c,
                    &states_c,
                    &grad_refs,
                    &keys,
                    t,
                    2.0,
                    &hp,
                    1,
                    tile_bytes,
                    TILE_PIPELINE_DEPTH,
                )
                .unwrap();
                // one tile for the sub-tile group, ceil-div for tails
                let es = dtype.bytes_per_elem();
                let tile_elems = tile_bytes / es;
                let want: usize =
                    sizes.iter().map(|n| n.div_ceil(tile_elems)).sum();
                assert_eq!(stats.tiles as usize, want, "{dtype:?} tile count");
            }
            let es = dtype.bytes_per_elem();
            assert_engines_identical(
                &eng_a,
                eng_b.as_ref(),
                &sizes,
                es,
                &format!("{dtype:?} pipelined"),
            );
            assert_engines_identical(
                &eng_a,
                eng_c.as_ref(),
                &sizes,
                es,
                &format!("{dtype:?} tiled"),
            );
            // the staged tiles leased real pinned spans and returned
            // every one of them
            assert_eq!(arena_c.stats().requested_bytes, 0);
            assert!(arena_c.stats().recycled > 0, "tile leases never recycled");
            std::fs::remove_dir_all(&dir_a).ok();
            std::fs::remove_dir_all(&dir_b).ok();
            std::fs::remove_dir_all(&dir_c).ok();
        }
    }

    #[test]
    fn tile_zero_falls_back_to_whole_group_path() {
        let (eng_a, dir_a) = engine("tz-seq");
        let (eng_b, dir_b) = engine("tz-tile");
        let hp = AdamParams::default();
        let n = 900usize;
        let p0 = vec![0.5f32; n];
        let st_a = OptimState::init(&eng_a, "g0", &p0, StateDtype::F32).unwrap();
        let st_b = OptimState::init(&eng_b, "g0", &p0, StateDtype::F32).unwrap();
        let eng_b: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng_b);
        let aio = AsyncEngine::new(Arc::clone(&eng_b), 2);
        let stage = StageExecutor::new(1);
        let g = vec![0.25f32; n];
        st_a.step(&eng_a, &g, 1, 1.0, &hp, 1, "g0/fp16").unwrap();
        let stats = step_groups_tiled(
            &aio,
            &stage,
            &arena(),
            std::slice::from_ref(&st_b),
            &[g.as_slice()],
            &["g0/fp16".to_string()],
            1,
            1.0,
            &hp,
            1,
            0, // tile_bytes = 0: whole-group double-buffer
            TILE_PIPELINE_DEPTH,
        )
        .unwrap();
        assert_eq!(stats.tiles, 0, "fallback path must not tile");
        assert_engines_identical(&eng_a, eng_b.as_ref(), &[n], 4, "fallback");
        // wrong-size grads still error cleanly out of the tiled driver
        let bad: &[f32] = &[0.0; 4];
        assert!(step_groups_tiled(
            &aio,
            &stage,
            &arena(),
            std::slice::from_ref(&st_b),
            &[bad],
            &["g0/fp16".to_string()],
            2,
            1.0,
            &hp,
            1,
            4096,
            TILE_PIPELINE_DEPTH,
        )
        .is_err());
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    }

    #[test]
    fn tiled_peak_pinned_capped_independent_of_group_size() {
        // the tentpole claim: a group ~100x the tile updates under a
        // pinned budget a whole-group fetch could never satisfy, and
        // stays bit-identical to the sequential reference
        let (eng_a, dir_a) = engine("cap-seq");
        let (eng_b, dir_b) = engine("cap-tile");
        let hp = AdamParams::default();
        let n = 400_000usize; // 1.6 MiB per f32 stream, 4.8 MiB per fetch
        let tile_bytes = 16 << 10;
        let budget = 512 << 10;
        let mut rng = crate::util::rng::Xoshiro256::new(4);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let st_a = OptimState::init(&eng_a, "g0", &p0, StateDtype::F32).unwrap();
        let st_b = OptimState::init(&eng_b, "g0", &p0, StateDtype::F32).unwrap();
        let eng_b: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng_b);
        let aio = AsyncEngine::new(Arc::clone(&eng_b), 3);
        let stage = StageExecutor::new(2);
        let tracker = Arc::new(crate::pinned::MemoryTracker::new());
        let capped = PinnedArena::new(
            Arc::new(crate::pinned::AlignedAllocator::new(Mode::Real, tracker)),
            crate::pinned::ArenaConfig {
                budget_bytes: Some(budget),
                ..Default::default()
            },
        );
        for t in 1..=2u64 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            st_a.step(&eng_a, &g, t, 1.0, &hp, 1, "g0/fp16").unwrap();
            step_groups_tiled(
                &aio,
                &stage,
                &capped,
                std::slice::from_ref(&st_b),
                &[g.as_slice()],
                &["g0/fp16".to_string()],
                t,
                1.0,
                &hp,
                1,
                tile_bytes,
                TILE_PIPELINE_DEPTH,
            )
            .unwrap();
        }
        let st = capped.stats();
        assert!(
            st.peak_reserved <= budget,
            "peak pinned {} exceeded the {budget} B budget",
            st.peak_reserved
        );
        // the whole-group working set (3 x 1.6 MiB) never materialized
        assert!(
            capped.watermark(Cat::OptimBuf).charged_peak <= budget,
            "optimizer staging outgrew the budget"
        );
        assert_engines_identical(&eng_a, eng_b.as_ref(), &[n], 4, "capped tiled");
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    }

    #[test]
    fn tiled_degrades_to_sync_tiles_under_impossible_budget() {
        // a budget below one padded tile refuses every lease: the
        // driver must degrade each tile to the unpinned synchronous
        // path — never abort — and stay bit-identical
        let (eng_a, dir_a) = engine("deg-seq");
        let (eng_b, dir_b) = engine("deg-tile");
        let hp = AdamParams::default();
        let n = 5000usize;
        let tile_bytes = 4096usize;
        let mut rng = crate::util::rng::Xoshiro256::new(6);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let st_a = OptimState::init(&eng_a, "g0", &p0, StateDtype::F32).unwrap();
        let st_b = OptimState::init(&eng_b, "g0", &p0, StateDtype::F32).unwrap();
        let eng_b: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng_b);
        let aio = AsyncEngine::new(Arc::clone(&eng_b), 2);
        let stage = StageExecutor::new(1);
        let tracker = Arc::new(crate::pinned::MemoryTracker::new());
        let starved = PinnedArena::new(
            Arc::new(crate::pinned::AlignedAllocator::new(Mode::Real, tracker)),
            crate::pinned::ArenaConfig {
                budget_bytes: Some(1024), // below one padded tile
                ..Default::default()
            },
        );
        for t in 1..=2u64 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            st_a.step(&eng_a, &g, t, 1.0, &hp, 1, "g0/fp16").unwrap();
            let stats = step_groups_tiled(
                &aio,
                &stage,
                &starved,
                std::slice::from_ref(&st_b),
                &[g.as_slice()],
                &["g0/fp16".to_string()],
                t,
                1.0,
                &hp,
                1,
                tile_bytes,
                TILE_PIPELINE_DEPTH,
            )
            .unwrap();
            assert_eq!(
                stats.degraded_tiles, stats.tiles,
                "every tile must have degraded, none aborted"
            );
        }
        assert_eq!(starved.stats().requested_bytes, 0);
        assert_engines_identical(&eng_a, eng_b.as_ref(), &[n], 4, "degraded tiled");
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    }

    #[test]
    fn prop_tiled_matches_step_across_random_group_shapes() {
        use crate::prop_assert;
        use crate::util::proptest::{check, Config};
        check("optim-tiled", Config { cases: 10, ..Default::default() }, |rng, size| {
            let dtype = if rng.next_u64() % 2 == 0 {
                StateDtype::F32
            } else {
                StateDtype::BF16
            };
            let case = rng.next_u64();
            let (eng_a, dir_a) = engine(&format!("pa{case}"));
            let (eng_b, dir_b) = engine(&format!("pb{case}"));
            let hp = AdamParams { weight_decay: 0.005, ..Default::default() };
            let n_groups = rng.range(1, 4);
            let sizes: Vec<usize> = (0..n_groups)
                .map(|_| rng.range(1, (size * 4).max(3)))
                .collect();
            // deliberately odd tile sizes: unaligned ranged I/O + tails
            let tile_bytes = [256usize, 1000, 4096, 16384][rng.below(4)];
            let mut states_a = Vec::new();
            let mut states_b = Vec::new();
            for (g, n) in sizes.iter().enumerate() {
                let p0: Vec<f32> = (0..*n).map(|_| rng.normal() as f32).collect();
                states_a.push(
                    OptimState::init(&eng_a, &format!("g{g}"), &p0, dtype)
                        .map_err(|e| e.to_string())?,
                );
                states_b.push(
                    OptimState::init(&eng_b, &format!("g{g}"), &p0, dtype)
                        .map_err(|e| e.to_string())?,
                );
            }
            let eng_b: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng_b);
            let aio = AsyncEngine::new(Arc::clone(&eng_b), 2);
            let stage = StageExecutor::new(1);
            let tile_arena = arena();
            let keys: Vec<String> =
                (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
            for t in 1..=2u64 {
                let grads: Vec<Vec<f32>> = sizes
                    .iter()
                    .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
                    .collect();
                for (g, st) in states_a.iter().enumerate() {
                    st.step(&eng_a, &grads[g], t, 2.0, &hp, 1, &keys[g])
                        .map_err(|e| e.to_string())?;
                }
                let grad_refs: Vec<&[f32]> =
                    grads.iter().map(|g| g.as_slice()).collect();
                step_groups_tiled(
                    &aio, &stage, &tile_arena, &states_b, &grad_refs, &keys, t, 2.0,
                    &hp, 1, tile_bytes, 2,
                )
                .map_err(|e| e.to_string())?;
            }
            let es = dtype.bytes_per_elem();
            for (g, n) in sizes.iter().enumerate() {
                for (suffix, width) in
                    [("master", es), ("adam_m", es), ("adam_v", es), ("fp16", 2)]
                {
                    let key = format!("g{g}/{suffix}");
                    let mut a = vec![0u8; n * width];
                    let mut b = vec![0u8; n * width];
                    eng_a.read(&key, &mut a).map_err(|e| e.to_string())?;
                    eng_b.read(&key, &mut b).map_err(|e| e.to_string())?;
                    prop_assert!(
                        a == b,
                        "{dtype:?} tile={tile_bytes} {key} diverged (n={n})"
                    );
                }
            }
            std::fs::remove_dir_all(&dir_a).ok();
            std::fs::remove_dir_all(&dir_b).ok();
            Ok(())
        });
    }
}
