//! Optimizer state residency: SSD-backed subgroup swapping.
//!
//! ZeRO-Infinity updates optimizer states in *subgroups*: for each
//! contiguous span of parameters it reads (master, m, v) from SSD into
//! pinned buffers, updates on CPU, and writes them back — so host
//! memory holds only a subgroup at a time, not 12 bytes/param.  This
//! module owns that loop and its I/O-volume accounting (Fig. 20).
//!
//! Two drivers exist over the same arithmetic:
//!
//! - [`OptimState::step`] — the sequential reference: read m/v/master,
//!   Adam, write back, one group at a time.  Every byte of I/O is
//!   foreground stall.
//! - [`step_groups_pipelined`] — the double-buffered swap: group k+1's
//!   states are fetched over the async queue while Adam runs on group
//!   k and group k-1's write-back drains.
//!
//! ```text
//!   time ──►
//!   fetch:    [g0] [g1]  [g2]  [g3]
//!   adam:          [g0]  [g1]  [g2]  [g3]
//!   write:               [g0]  [g1]  [g2]  [g3]
//! ```
//!
//! At most two generations of (master, m, v) buffers are alive at a
//! time — the bounded double-buffer that also flattens the peak-DRAM
//! spike the paper attributes to optimizer bursts (§III-C).  Both
//! drivers produce bit-identical state: same reads, same arithmetic,
//! same writes, only reordered in time across distinct keys.

use std::sync::Arc;

use crate::dtype::DType;
use crate::pinned::{Cat, PinnedArena};
use crate::ssd::{AsyncEngine, IoHandle, NvmeEngine};

/// Optimizer state storage precision (paper §VI-B-3a).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StateDtype {
    F32,
    BF16,
}

impl StateDtype {
    pub fn dtype(self) -> DType {
        match self {
            StateDtype::F32 => DType::F32,
            StateDtype::BF16 => DType::BF16,
        }
    }

    pub fn bytes_per_elem(self) -> usize {
        self.dtype().size()
    }
}

/// Keys under which one flat group's states live on the SSD.
pub fn state_keys(group: &str) -> [String; 3] {
    [
        format!("{group}/master"),
        format!("{group}/adam_m"),
        format!("{group}/adam_v"),
    ]
}

/// SSD-resident optimizer state for one parameter group.
pub struct OptimState {
    pub group: String,
    pub numel: usize,
    pub dtype: StateDtype,
}

impl OptimState {
    /// Initialize states on the SSD: master = initial params, m = v = 0.
    pub fn init(
        engine: &dyn NvmeEngine,
        group: &str,
        params_f32: &[f32],
        dtype: StateDtype,
    ) -> anyhow::Result<Self> {
        let [k_p, k_m, k_v] = state_keys(group);
        let n = params_f32.len();
        match dtype {
            StateDtype::F32 => {
                engine.write(&k_p, crate::dtype::f32s_as_bytes(params_f32))?;
                let zeros = vec![0u8; n * 4];
                engine.write(&k_m, &zeros)?;
                engine.write(&k_v, &zeros)?;
            }
            StateDtype::BF16 => {
                let mut buf = vec![0u8; n * 2];
                crate::dtype::f32s_to_bf16_bytes(params_f32, &mut buf);
                engine.write(&k_p, &buf)?;
                let zeros = vec![0u8; n * 2];
                engine.write(&k_m, &zeros)?;
                engine.write(&k_v, &zeros)?;
            }
        }
        Ok(Self { group: group.to_string(), numel: n, dtype })
    }

    /// Bytes moved (read + write) by one full optimizer step over this
    /// group, including the fp16 compute-weight writeback.
    pub fn io_bytes_per_step(&self) -> u64 {
        let s = self.dtype.bytes_per_elem() as u64;
        let n = self.numel as u64;
        // read master+m+v, write master+m+v, write fp16 compute copy
        n * s * 6 + n * 2
    }

    /// Run one fused AdamW step with states streamed through `engine`.
    /// `grads` are the group's fp32 (scaled) gradients; returns the
    /// updated fp16 compute weights (LE bytes) written back to SSD.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        engine: &dyn NvmeEngine,
        grads: &[f32],
        step: u64,
        grad_scale: f32,
        hp: &super::AdamParams,
        threads: usize,
        fp16_key: &str,
    ) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(grads.len() == self.numel, "grad size mismatch");
        let [k_p, k_m, k_v] = state_keys(&self.group);
        let n = self.numel;
        let mut fp16 = vec![0u8; n * 2];
        match self.dtype {
            StateDtype::F32 => {
                let mut p = vec![0f32; n];
                let mut m = vec![0f32; n];
                let mut v = vec![0f32; n];
                engine.read(&k_p, crate::dtype::f32s_as_bytes_mut(&mut p))?;
                engine.read(&k_m, crate::dtype::f32s_as_bytes_mut(&mut m))?;
                engine.read(&k_v, crate::dtype::f32s_as_bytes_mut(&mut v))?;
                super::adam_step_f32(&mut p, grads, &mut m, &mut v, step, grad_scale, hp, threads);
                engine.write(&k_p, crate::dtype::f32s_as_bytes(&p))?;
                engine.write(&k_m, crate::dtype::f32s_as_bytes(&m))?;
                engine.write(&k_v, crate::dtype::f32s_as_bytes(&v))?;
                crate::dtype::f32s_to_f16_bytes(&p, &mut fp16);
            }
            StateDtype::BF16 => {
                let mut p = vec![0u8; n * 2];
                let mut m = vec![0u8; n * 2];
                let mut v = vec![0u8; n * 2];
                engine.read(&k_p, &mut p)?;
                engine.read(&k_m, &mut m)?;
                engine.read(&k_v, &mut v)?;
                super::adam_step_bf16(&mut p, grads, &mut m, &mut v, step, grad_scale, hp, threads);
                engine.write(&k_p, &p)?;
                engine.write(&k_m, &m)?;
                engine.write(&k_v, &v)?;
                // bf16 -> f32 -> f16 for the compute copy
                let mut pf = vec![0f32; n];
                crate::dtype::bf16_bytes_to_f32s(&p, &mut pf);
                crate::dtype::f32s_to_f16_bytes(&pf, &mut fp16);
            }
        }
        engine.write(fp16_key, &fp16)?;
        Ok(fp16)
    }

    // ---- split-phase surface for the double-buffered driver ----

    /// Queue async reads for this group's (master, m, v), reusing
    /// buffers from `scratch` when available.
    pub fn submit_fetch(&self, aio: &AsyncEngine, scratch: &StateScratch) -> StateFetch {
        let [k_p, k_m, k_v] = state_keys(&self.group);
        let n = self.numel;
        let inner = match self.dtype {
            StateDtype::F32 => StateFetchInner::F32([
                aio.submit_read_f32(k_p, scratch.take_f32(n)),
                aio.submit_read_f32(k_m, scratch.take_f32(n)),
                aio.submit_read_f32(k_v, scratch.take_f32(n)),
            ]),
            StateDtype::BF16 => StateFetchInner::Bf16([
                aio.submit_read(k_p, scratch.take_bytes(n * 2)),
                aio.submit_read(k_m, scratch.take_bytes(n * 2)),
                aio.submit_read(k_v, scratch.take_bytes(n * 2)),
            ]),
        };
        StateFetch { inner }
    }

    /// Run the AdamW arithmetic on fetched buffers in place and
    /// produce the fp16 compute copy into `fp16` — the exact same
    /// kernels [`Self::step`] uses, so the trajectories are
    /// bit-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn compute(
        &self,
        bufs: &mut StateBufs,
        grads: &[f32],
        step: u64,
        grad_scale: f32,
        hp: &super::AdamParams,
        threads: usize,
        fp16: &mut Vec<u8>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(grads.len() == self.numel, "grad size mismatch");
        let n = self.numel;
        fp16.clear();
        fp16.resize(n * 2, 0);
        match bufs {
            StateBufs::F32 { p, m, v } => {
                anyhow::ensure!(
                    p.len() == n && m.len() == n && v.len() == n,
                    "state buffer size mismatch for '{}'",
                    self.group
                );
                super::adam_step_f32(p, grads, m, v, step, grad_scale, hp, threads);
                crate::dtype::f32s_to_f16_bytes(p, fp16);
            }
            StateBufs::Bf16 { p, m, v } => {
                anyhow::ensure!(
                    p.len() == n * 2 && m.len() == n * 2 && v.len() == n * 2,
                    "state buffer size mismatch for '{}'",
                    self.group
                );
                super::adam_step_bf16(p, grads, m, v, step, grad_scale, hp, threads);
                let mut pf = vec![0f32; n];
                crate::dtype::bf16_bytes_to_f32s(p, &mut pf);
                crate::dtype::f32s_to_f16_bytes(&pf, fp16);
            }
        }
        Ok(())
    }

    /// Queue async write-back of the updated states plus the fp16
    /// compute copy; buffers return to scratch when the handles drain.
    pub fn submit_writeback(
        &self,
        aio: &AsyncEngine,
        bufs: StateBufs,
        fp16: Vec<u8>,
        fp16_key: &str,
    ) -> StateWriteback {
        let [k_p, k_m, k_v] = state_keys(&self.group);
        let mut wb = StateWriteback { f32s: Vec::new(), bytes: Vec::new() };
        match bufs {
            StateBufs::F32 { p, m, v } => {
                wb.f32s.push(aio.submit_write_f32(k_p, p));
                wb.f32s.push(aio.submit_write_f32(k_m, m));
                wb.f32s.push(aio.submit_write_f32(k_v, v));
            }
            StateBufs::Bf16 { p, m, v } => {
                wb.bytes.push(aio.submit_write(k_p, p));
                wb.bytes.push(aio.submit_write(k_m, m));
                wb.bytes.push(aio.submit_write(k_v, v));
            }
        }
        wb.bytes.push(aio.submit_write(fp16_key.to_string(), fp16));
        wb
    }
}

/// One group's state buffers, typed by storage precision.
pub enum StateBufs {
    F32 { p: Vec<f32>, m: Vec<f32>, v: Vec<f32> },
    Bf16 { p: Vec<u8>, m: Vec<u8>, v: Vec<u8> },
}

enum StateFetchInner {
    F32([IoHandle<Vec<f32>>; 3]),
    Bf16([IoHandle<Vec<u8>>; 3]),
}

/// In-flight prefetch of one group's three state tensors.
pub struct StateFetch {
    inner: StateFetchInner,
}

impl StateFetch {
    pub fn wait(self) -> anyhow::Result<StateBufs> {
        match self.inner {
            StateFetchInner::F32([hp, hm, hv]) => Ok(StateBufs::F32 {
                p: hp.wait()?,
                m: hm.wait()?,
                v: hv.wait()?,
            }),
            StateFetchInner::Bf16([hp, hm, hv]) => Ok(StateBufs::Bf16 {
                p: hp.wait()?,
                m: hm.wait()?,
                v: hv.wait()?,
            }),
        }
    }
}

/// In-flight write-back of one group (states + fp16 compute copy).
pub struct StateWriteback {
    f32s: Vec<IoHandle<Vec<f32>>>,
    bytes: Vec<IoHandle<Vec<u8>>>,
}

impl StateWriteback {
    /// Drain all writes; buffers go back to `scratch` for the next
    /// generation.
    pub fn wait(self, scratch: &StateScratch) -> anyhow::Result<()> {
        for h in self.f32s {
            scratch.put_f32(h.wait()?);
        }
        for h in self.bytes {
            scratch.put_bytes(h.wait()?);
        }
        Ok(())
    }
}

/// Staging-buffer recycler for the double-buffered swap: a facade over
/// the arena's scratch tier under `Cat::OptimBuf`, so the two
/// generations of (master, m, v) buffers alive in steady state sit on
/// the shared ledger and inside the pinned budget — and survive across
/// steps (the arena pool outlives any one `step_groups_pipelined`
/// call).
pub struct StateScratch {
    arena: Arc<PinnedArena>,
}

impl StateScratch {
    pub fn new(arena: Arc<PinnedArena>) -> Self {
        Self { arena }
    }

    fn take_f32(&self, n: usize) -> Vec<f32> {
        self.arena.take_f32(n, Cat::OptimBuf)
    }

    fn take_bytes(&self, n: usize) -> Vec<u8> {
        self.arena.take_bytes(n, Cat::OptimBuf)
    }

    fn put_f32(&self, v: Vec<f32>) {
        self.arena.put_f32(v, Cat::OptimBuf)
    }

    fn put_bytes(&self, v: Vec<u8>) {
        self.arena.put_bytes(v, Cat::OptimBuf)
    }
}

/// Foreground-stall accounting for one pipelined optimizer pass.
#[derive(Debug, Default, Clone, Copy)]
pub struct PipelineStats {
    /// Seconds the driver thread blocked waiting on fetch/write-back
    /// completions (I/O *not* hidden behind the Adam compute).
    pub wait_secs: f64,
}

/// Double-buffered SSD-swapped AdamW over `groups`: while Adam runs on
/// group k, group k+1's states stream in and group k-1's write-back
/// drains.  `grads[i]` / `fp16_keys[i]` belong to `groups[i]`.
/// Staging buffers lease-recycle through `arena` (`Cat::OptimBuf`).
#[allow(clippy::too_many_arguments)]
pub fn step_groups_pipelined(
    aio: &AsyncEngine,
    arena: &Arc<PinnedArena>,
    groups: &[OptimState],
    grads: &[&[f32]],
    fp16_keys: &[String],
    step: u64,
    grad_scale: f32,
    hp: &super::AdamParams,
    threads: usize,
) -> anyhow::Result<PipelineStats> {
    anyhow::ensure!(
        groups.len() == grads.len() && groups.len() == fp16_keys.len(),
        "groups/grads/keys length mismatch"
    );
    let scratch = StateScratch::new(Arc::clone(arena));
    let mut stats = PipelineStats::default();
    let mut prev_wb: Option<StateWriteback> = None;
    let mut next_fetch = groups.first().map(|g| g.submit_fetch(aio, &scratch));
    for (k, st) in groups.iter().enumerate() {
        let fetch_k = next_fetch.take().expect("fetch scheduled for every group");
        // overlap: group k+1's reads start before we block on k's
        if let Some(nx) = groups.get(k + 1) {
            next_fetch = Some(nx.submit_fetch(aio, &scratch));
        }
        let t0 = std::time::Instant::now();
        let mut bufs = fetch_k.wait()?;
        stats.wait_secs += t0.elapsed().as_secs_f64();
        // Adam on the caller thread, overlapping k+1's fetch and
        // k-1's write-back
        let mut fp16 = scratch.take_bytes(0);
        st.compute(&mut bufs, grads[k], step, grad_scale, hp, threads, &mut fp16)?;
        // drain k-1's write generation before queueing k's: bounds
        // in-flight state memory to two generations
        if let Some(wb) = prev_wb.take() {
            let t0 = std::time::Instant::now();
            wb.wait(&scratch)?;
            stats.wait_secs += t0.elapsed().as_secs_f64();
        }
        prev_wb = Some(st.submit_writeback(aio, bufs, fp16, &fp16_keys[k]));
    }
    if let Some(wb) = prev_wb {
        let t0 = std::time::Instant::now();
        wb.wait(&scratch)?;
        stats.wait_secs += t0.elapsed().as_secs_f64();
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bufpool::test_util::test_arena;
    use crate::optimizer::AdamParams;
    use crate::pinned::Mode;
    use crate::ssd::DirectEngine;

    fn engine(tag: &str) -> (DirectEngine, std::path::PathBuf) {
        let dir =
            std::env::temp_dir().join(format!("ma-opt-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        (DirectEngine::new(&dir, 1, 1 << 26, 1).unwrap(), dir)
    }

    fn arena() -> Arc<PinnedArena> {
        test_arena(Mode::Real)
    }

    #[test]
    fn ssd_swapped_step_matches_in_memory() {
        let (eng, dir) = engine("par");
        let hp = AdamParams::default();
        let n = 500;
        let mut rng = crate::util::rng::Xoshiro256::new(2);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let st = OptimState::init(&eng, "g0", &p0, StateDtype::F32).unwrap();

        // in-memory reference trajectory
        let mut pr = p0.clone();
        let (mut mr, mut vr) = (vec![0f32; n], vec![0f32; n]);
        for t in 1..=5 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            crate::optimizer::adam_step_f32(&mut pr, &g, &mut mr, &mut vr, t, 1.0, &hp, 1);
            st.step(&eng, &g, t, 1.0, &hp, 1, "g0/fp16").unwrap();
        }
        let mut p_ssd = vec![0f32; n];
        eng.read("g0/master", crate::dtype::f32s_as_bytes_mut(&mut p_ssd)).unwrap();
        for i in 0..n {
            assert!((p_ssd[i] - pr[i]).abs() < 1e-6);
        }
        // fp16 compute copy exists and decodes near the master
        let mut fp16 = vec![0u8; n * 2];
        eng.read("g0/fp16", &mut fp16).unwrap();
        let mut back = vec![0f32; n];
        crate::dtype::f16_bytes_to_f32s(&fp16, &mut back);
        for i in 0..n {
            assert!((back[i] - pr[i]).abs() < 2e-3 * pr[i].abs().max(1.0));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bf16_io_volume_is_less_than_half_plus_const() {
        // Fig. 20: bf16 optimizer cuts state I/O by 2x
        let f32_state = OptimState {
            group: "g".into(),
            numel: 1_000_000,
            dtype: StateDtype::F32,
        };
        let bf16_state = OptimState {
            group: "g".into(),
            numel: 1_000_000,
            dtype: StateDtype::BF16,
        };
        let r = bf16_state.io_bytes_per_step() as f64
            / f32_state.io_bytes_per_step() as f64;
        assert!((0.5..0.6).contains(&r), "ratio {r}");
    }

    #[test]
    fn pipelined_groups_bit_identical_to_sequential() {
        for dtype in [StateDtype::F32, StateDtype::BF16] {
            let (eng_a, dir_a) = engine(&format!("seq-{dtype:?}"));
            let (eng_b, dir_b) = engine(&format!("pipe-{dtype:?}"));
            let hp = AdamParams { weight_decay: 0.01, ..Default::default() };
            let mut rng = crate::util::rng::Xoshiro256::new(9);
            let sizes = [700usize, 300, 1100, 64];
            let mut states_a = Vec::new();
            let mut states_b = Vec::new();
            for (g, n) in sizes.iter().enumerate() {
                let p0: Vec<f32> = (0..*n).map(|_| rng.normal() as f32).collect();
                states_a
                    .push(OptimState::init(&eng_a, &format!("g{g}"), &p0, dtype).unwrap());
                states_b
                    .push(OptimState::init(&eng_b, &format!("g{g}"), &p0, dtype).unwrap());
            }
            let eng_b: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng_b);
            let aio = AsyncEngine::new(Arc::clone(&eng_b), 3);
            let arena = arena();
            for t in 1..=4u64 {
                let grads: Vec<Vec<f32>> = sizes
                    .iter()
                    .map(|n| (0..*n).map(|_| rng.normal() as f32).collect())
                    .collect();
                for (g, st) in states_a.iter().enumerate() {
                    st.step(&eng_a, &grads[g], t, 2.0, &hp, 1, &format!("g{g}/fp16"))
                        .unwrap();
                }
                let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
                let keys: Vec<String> =
                    (0..sizes.len()).map(|g| format!("g{g}/fp16")).collect();
                step_groups_pipelined(
                    &aio, &arena, &states_b, &grad_refs, &keys, t, 2.0, &hp, 1,
                )
                .unwrap();
            }
            // staging buffers recycled through the arena between
            // generations (and sit on its ledger while idle)
            match dtype {
                StateDtype::F32 => assert!(arena.pooled_f32(Cat::OptimBuf) > 0),
                StateDtype::BF16 => assert!(arena.pooled_byte_vecs(Cat::OptimBuf) > 0),
            }
            // every stored artifact must match byte-for-byte
            for (g, n) in sizes.iter().enumerate() {
                let es = dtype.bytes_per_elem();
                for suffix in ["master", "adam_m", "adam_v"] {
                    let key = format!("g{g}/{suffix}");
                    let mut a = vec![0u8; n * es];
                    let mut b = vec![0u8; n * es];
                    eng_a.read(&key, &mut a).unwrap();
                    eng_b.read(&key, &mut b).unwrap();
                    assert_eq!(a, b, "{dtype:?} {key} diverged");
                }
                let key = format!("g{g}/fp16");
                let mut a = vec![0u8; n * 2];
                let mut b = vec![0u8; n * 2];
                eng_a.read(&key, &mut a).unwrap();
                eng_b.read(&key, &mut b).unwrap();
                assert_eq!(a, b, "{dtype:?} {key} diverged");
            }
            std::fs::remove_dir_all(&dir_a).ok();
            std::fs::remove_dir_all(&dir_b).ok();
        }
    }

    #[test]
    fn pipelined_write_errors_surface() {
        let (eng, dir) = engine("pipe-err");
        let hp = AdamParams::default();
        let st =
            OptimState::init(&eng, "g0", &[1.0f32; 128], StateDtype::F32).unwrap();
        let eng: Arc<dyn crate::ssd::NvmeEngine> = Arc::new(eng);
        let aio = AsyncEngine::new(eng, 2);
        // wrong-size grads error cleanly out of the pipeline
        let bad: &[f32] = &[0.0; 4];
        let r = step_groups_pipelined(
            &aio,
            &arena(),
            std::slice::from_ref(&st),
            &[bad],
            &["g0/fp16".to_string()],
            1,
            1.0,
            &hp,
            1,
        );
        assert!(r.is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn state_keys_are_namespaced() {
        let [p, m, v] = state_keys("layers.0.wq");
        assert!(p.contains("master") && m.contains("adam_m") && v.contains("adam_v"));
    }
}
