//! Optimizer state residency: SSD-backed subgroup swapping.
//!
//! ZeRO-Infinity updates optimizer states in *subgroups*: for each
//! contiguous span of parameters it reads (master, m, v) from SSD into
//! pinned buffers, updates on CPU, and writes them back — so host
//! memory holds only a subgroup at a time, not 12 bytes/param.  This
//! module owns that loop and its I/O-volume accounting (Fig. 20).

use crate::dtype::DType;
use crate::ssd::NvmeEngine;

/// Optimizer state storage precision (paper §VI-B-3a).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StateDtype {
    F32,
    BF16,
}

impl StateDtype {
    pub fn dtype(self) -> DType {
        match self {
            StateDtype::F32 => DType::F32,
            StateDtype::BF16 => DType::BF16,
        }
    }

    pub fn bytes_per_elem(self) -> usize {
        self.dtype().size()
    }
}

/// Keys under which one flat group's states live on the SSD.
pub fn state_keys(group: &str) -> [String; 3] {
    [
        format!("{group}/master"),
        format!("{group}/adam_m"),
        format!("{group}/adam_v"),
    ]
}

/// SSD-resident optimizer state for one parameter group.
pub struct OptimState {
    pub group: String,
    pub numel: usize,
    pub dtype: StateDtype,
}

impl OptimState {
    /// Initialize states on the SSD: master = initial params, m = v = 0.
    pub fn init(
        engine: &dyn NvmeEngine,
        group: &str,
        params_f32: &[f32],
        dtype: StateDtype,
    ) -> anyhow::Result<Self> {
        let [k_p, k_m, k_v] = state_keys(group);
        let n = params_f32.len();
        match dtype {
            StateDtype::F32 => {
                engine.write(&k_p, crate::dtype::f32s_as_bytes(params_f32))?;
                let zeros = vec![0u8; n * 4];
                engine.write(&k_m, &zeros)?;
                engine.write(&k_v, &zeros)?;
            }
            StateDtype::BF16 => {
                let mut buf = vec![0u8; n * 2];
                crate::dtype::f32s_to_bf16_bytes(params_f32, &mut buf);
                engine.write(&k_p, &buf)?;
                let zeros = vec![0u8; n * 2];
                engine.write(&k_m, &zeros)?;
                engine.write(&k_v, &zeros)?;
            }
        }
        Ok(Self { group: group.to_string(), numel: n, dtype })
    }

    /// Bytes moved (read + write) by one full optimizer step over this
    /// group, including the fp16 compute-weight writeback.
    pub fn io_bytes_per_step(&self) -> u64 {
        let s = self.dtype.bytes_per_elem() as u64;
        let n = self.numel as u64;
        // read master+m+v, write master+m+v, write fp16 compute copy
        n * s * 6 + n * 2
    }

    /// Run one fused AdamW step with states streamed through `engine`.
    /// `grads` are the group's fp32 (scaled) gradients; returns the
    /// updated fp16 compute weights (LE bytes) written back to SSD.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        engine: &dyn NvmeEngine,
        grads: &[f32],
        step: u64,
        grad_scale: f32,
        hp: &super::AdamParams,
        threads: usize,
        fp16_key: &str,
    ) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(grads.len() == self.numel, "grad size mismatch");
        let [k_p, k_m, k_v] = state_keys(&self.group);
        let n = self.numel;
        let mut fp16 = vec![0u8; n * 2];
        match self.dtype {
            StateDtype::F32 => {
                let mut p = vec![0f32; n];
                let mut m = vec![0f32; n];
                let mut v = vec![0f32; n];
                engine.read(&k_p, crate::dtype::f32s_as_bytes_mut(&mut p))?;
                engine.read(&k_m, crate::dtype::f32s_as_bytes_mut(&mut m))?;
                engine.read(&k_v, crate::dtype::f32s_as_bytes_mut(&mut v))?;
                super::adam_step_f32(&mut p, grads, &mut m, &mut v, step, grad_scale, hp, threads);
                engine.write(&k_p, crate::dtype::f32s_as_bytes(&p))?;
                engine.write(&k_m, crate::dtype::f32s_as_bytes(&m))?;
                engine.write(&k_v, crate::dtype::f32s_as_bytes(&v))?;
                crate::dtype::f32s_to_f16_bytes(&p, &mut fp16);
            }
            StateDtype::BF16 => {
                let mut p = vec![0u8; n * 2];
                let mut m = vec![0u8; n * 2];
                let mut v = vec![0u8; n * 2];
                engine.read(&k_p, &mut p)?;
                engine.read(&k_m, &mut m)?;
                engine.read(&k_v, &mut v)?;
                super::adam_step_bf16(&mut p, grads, &mut m, &mut v, step, grad_scale, hp, threads);
                engine.write(&k_p, &p)?;
                engine.write(&k_m, &m)?;
                engine.write(&k_v, &v)?;
                // bf16 -> f32 -> f16 for the compute copy
                let mut pf = vec![0f32; n];
                crate::dtype::bf16_bytes_to_f32s(&p, &mut pf);
                crate::dtype::f32s_to_f16_bytes(&pf, &mut fp16);
            }
        }
        engine.write(fp16_key, &fp16)?;
        Ok(fp16)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::AdamParams;
    use crate::ssd::DirectEngine;

    fn engine(tag: &str) -> (DirectEngine, std::path::PathBuf) {
        let dir =
            std::env::temp_dir().join(format!("ma-opt-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        (DirectEngine::new(&dir, 1, 1 << 26, 1).unwrap(), dir)
    }

    #[test]
    fn ssd_swapped_step_matches_in_memory() {
        let (eng, dir) = engine("par");
        let hp = AdamParams::default();
        let n = 500;
        let mut rng = crate::util::rng::Xoshiro256::new(2);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let st = OptimState::init(&eng, "g0", &p0, StateDtype::F32).unwrap();

        // in-memory reference trajectory
        let mut pr = p0.clone();
        let (mut mr, mut vr) = (vec![0f32; n], vec![0f32; n]);
        for t in 1..=5 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            crate::optimizer::adam_step_f32(&mut pr, &g, &mut mr, &mut vr, t, 1.0, &hp, 1);
            st.step(&eng, &g, t, 1.0, &hp, 1, "g0/fp16").unwrap();
        }
        let mut p_ssd = vec![0f32; n];
        eng.read("g0/master", crate::dtype::f32s_as_bytes_mut(&mut p_ssd)).unwrap();
        for i in 0..n {
            assert!((p_ssd[i] - pr[i]).abs() < 1e-6);
        }
        // fp16 compute copy exists and decodes near the master
        let mut fp16 = vec![0u8; n * 2];
        eng.read("g0/fp16", &mut fp16).unwrap();
        let mut back = vec![0f32; n];
        crate::dtype::f16_bytes_to_f32s(&fp16, &mut back);
        for i in 0..n {
            assert!((back[i] - pr[i]).abs() < 2e-3 * pr[i].abs().max(1.0));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bf16_io_volume_is_less_than_half_plus_const() {
        // Fig. 20: bf16 optimizer cuts state I/O by 2x
        let f32_state = OptimState {
            group: "g".into(),
            numel: 1_000_000,
            dtype: StateDtype::F32,
        };
        let bf16_state = OptimState {
            group: "g".into(),
            numel: 1_000_000,
            dtype: StateDtype::BF16,
        };
        let r = bf16_state.io_bytes_per_step() as f64
            / f32_state.io_bytes_per_step() as f64;
        assert!((0.5..0.6).contains(&r), "ratio {r}");
    }

    #[test]
    fn state_keys_are_namespaced() {
        let [p, m, v] = state_keys("layers.0.wq");
        assert!(p.contains("master") && m.contains("adam_m") && v.contains("adam_v"));
    }
}
