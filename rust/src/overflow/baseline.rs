//! The ZeRO-Infinity overflow check, faithfully inefficient.
//!
//! PyTorch's path (paper Fig. 3): `isinf()` internally calls `abs()`
//! which **duplicates the tensor**, then compares against +inf into a
//! Boolean tensor, reduces with `any()`; `isnan()` produces another
//! Boolean tensor and reduction.  Five passes, two materialized
//! temporaries, and a 2.25× transient memory peak on the fp32 flat
//! buffer (1× abs copy + 0.25× bool tensor), then a further 1.25×
//! peak for the isnan bool tensor.
//!
//! Temporaries here are *real allocations* charged to the tracker so
//! the Fig. 13 bench measures the spike, not a model of it.

use std::sync::Arc;

use crate::pinned::{Cat, MemoryTracker};

/// Step 2-3: abs copy + isinf bool tensor + any reduce.
/// Step 4-5: isnan bool tensor + any reduce.
pub fn baseline_overflow_check(grads: &[f32], tracker: &Arc<MemoryTracker>) -> bool {
    let n = grads.len();
    let f32_bytes = (n * 4) as u64;
    let bool_bytes = n as u64; // torch bool = 1 byte/elem

    // ---- pass 1: abs() duplicates the tensor (the 1.0x copy) ----
    tracker.alloc(Cat::OverflowTemp, f32_bytes);
    let abs: Vec<f32> = grads.iter().map(|x| x.abs()).collect();

    // ---- pass 2: isinf() -> bool tensor (the 0.25x) ----
    tracker.alloc(Cat::OverflowTemp, bool_bytes);
    let isinf: Vec<u8> = abs.iter().map(|x| u8::from(x.is_infinite())).collect();

    // ---- pass 3: any() over the bool tensor ----
    let inf_any = isinf.iter().any(|&b| b != 0);

    // abs copy and isinf bool free before the isnan pass (Fig. 3:
    // the second peak is lower, 1.25x)
    drop(abs);
    tracker.free(Cat::OverflowTemp, f32_bytes);
    drop(isinf);
    tracker.free(Cat::OverflowTemp, bool_bytes);

    // ---- pass 4: isnan() -> bool tensor ----
    tracker.alloc(Cat::OverflowTemp, bool_bytes);
    let isnan: Vec<u8> = grads.iter().map(|x| u8::from(x.is_nan())).collect();

    // ---- pass 5: any() ----
    let nan_any = isnan.iter().any(|&b| b != 0);
    drop(isnan);
    tracker.free(Cat::OverflowTemp, bool_bytes);

    inf_any || nan_any
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detects_all_specials() {
        let tracker = Arc::new(MemoryTracker::new());
        assert!(!baseline_overflow_check(&[1.0, -2.0, 0.0], &tracker));
        assert!(baseline_overflow_check(&[1.0, f32::INFINITY], &tracker));
        assert!(baseline_overflow_check(&[f32::NEG_INFINITY], &tracker));
        assert!(baseline_overflow_check(&[0.0, f32::NAN], &tracker));
    }

    #[test]
    fn memory_spike_is_2_25x() {
        let n = 1_000_000usize;
        let grads = vec![0.5f32; n];
        let tracker = Arc::new(MemoryTracker::with_timeline());
        // charge the flat buffer itself so the ratio is visible
        tracker.alloc(Cat::GradFlat, (n * 4) as u64);
        baseline_overflow_check(&grads, &tracker);
        let flat = (n * 4) as u64;
        let peak = tracker.peak_total();
        // flat (1.0) + abs copy (1.0) + bool (0.25) = 2.25x
        let ratio = peak as f64 / flat as f64;
        assert!((2.24..2.26).contains(&ratio), "peak ratio {ratio}");
        // after the check, transients are gone
        assert_eq!(tracker.current(Cat::OverflowTemp), 0);
    }

    #[test]
    fn timeline_shows_double_peak() {
        let n = 1000usize;
        let grads = vec![0.5f32; n];
        let tracker = Arc::new(MemoryTracker::with_timeline());
        tracker.alloc(Cat::GradFlat, (n * 4) as u64);
        baseline_overflow_check(&grads, &tracker);
        let tl = tracker.timeline();
        // find the two local maxima of total_after
        let totals: Vec<u64> = tl.iter().map(|e| e.total_after).collect();
        let peak1 = *totals.iter().max().unwrap();
        // second peak: max after the first drop below peak1
        let first_peak_idx = totals.iter().position(|&t| t == peak1).unwrap();
        let after_drop: Vec<u64> = totals[first_peak_idx..]
            .iter()
            .copied()
            .skip_while(|&t| t == peak1)
            .collect();
        let peak2 = after_drop.iter().max().copied().unwrap_or(0);
        let flat = (n * 4) as u64;
        assert_eq!(peak1, flat * 9 / 4); // 2.25x
        assert_eq!(peak2, flat * 5 / 4); // 1.25x
    }
}
