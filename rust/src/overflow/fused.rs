//! MemAscend's fused overflow check — paper Algorithm 1.
//!
//! IEEE-754: a float is Inf or NaN **iff its exponent field is all
//! ones**.  So the check is: reinterpret bits, AND with the exponent
//! mask, compare — one pass, no temporaries, embarrassingly parallel,
//! with cooperative early exit across workers.
//!
//! This is the same computation as the L1 Pallas kernel
//! (`python/compile/kernels/overflow.py`); integration tests assert the
//! native path, the HLO-artifact path, and the baseline chain all
//! return identical verdicts.

use crate::util::par;

const EXP_MASK_F32: u32 = 0x7F80_0000;
const EXP_MASK_F16: u16 = 0x7C00;
const EXP_MASK_BF16: u16 = 0x7F80;

/// Tile size per early-exit poll. 64Ki elements = 256 KiB of f32 —
/// large enough to amortize the atomic poll, small enough to exit fast.
const TILE: usize = 1 << 16;

#[inline]
fn tile_has_overflow_f32(tile: &[f32]) -> bool {
    // Branch-free inner loop: OR-accumulate the masked compare so the
    // compiler can autovectorize; branch only once per tile.
    let mut acc = false;
    for &x in tile {
        acc |= (x.to_bits() & EXP_MASK_F32) == EXP_MASK_F32;
    }
    acc
}

/// Fused single-pass check over an fp32 buffer.
pub fn fused_overflow_check(grads: &[f32], threads: usize) -> bool {
    par::par_any(grads, threads, TILE, tile_has_overflow_f32)
}

/// Fused check over packed IEEE binary16 values.
pub fn fused_overflow_check_f16(bits: &[u16], threads: usize) -> bool {
    par::par_any(bits, threads, TILE * 2, |tile| {
        let mut acc = false;
        for &b in tile {
            acc |= (b & EXP_MASK_F16) == EXP_MASK_F16;
        }
        acc
    })
}

/// Fused check over packed bfloat16 values.
pub fn fused_overflow_check_bf16(bits: &[u16], threads: usize) -> bool {
    par::par_any(bits, threads, TILE * 2, |tile| {
        let mut acc = false;
        for &b in tile {
            acc |= (b & EXP_MASK_BF16) == EXP_MASK_BF16;
        }
        acc
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::{f32_to_bf16, f32_to_f16};

    #[test]
    fn exponent_mask_is_exact() {
        // all-ones exponent <=> inf or nan, never a finite value
        assert!(fused_overflow_check(&[f32::INFINITY], 1));
        assert!(fused_overflow_check(&[f32::NEG_INFINITY], 1));
        assert!(fused_overflow_check(&[f32::NAN], 1));
        assert!(!fused_overflow_check(&[f32::MAX, f32::MIN_POSITIVE, -0.0], 1));
    }

    #[test]
    fn finds_needle_in_any_position() {
        for pos in [0usize, 1, TILE - 1, TILE, TILE + 1, 3 * TILE - 1] {
            let mut v = vec![1.0f32; 3 * TILE];
            v[pos] = f32::NAN;
            assert!(fused_overflow_check(&v, 1), "pos {pos}");
            assert!(fused_overflow_check(&v, 4), "pos {pos} (mt)");
        }
    }

    #[test]
    fn f16_bf16_variants() {
        let inf16 = f32_to_f16(f32::INFINITY);
        let one16 = f32_to_f16(1.0);
        assert!(fused_overflow_check_f16(&[one16, inf16], 1));
        assert!(!fused_overflow_check_f16(&[one16; 64], 1));

        let nanb = f32_to_bf16(f32::NAN);
        let oneb = f32_to_bf16(1.0);
        assert!(fused_overflow_check_bf16(&[oneb, nanb], 1));
        assert!(!fused_overflow_check_bf16(&[oneb; 64], 1));
        // f16 max (65504) is finite in f16: must not flag
        assert!(!fused_overflow_check_f16(&[f32_to_f16(65504.0)], 1));
    }

    #[test]
    fn empty_buffer_is_clean() {
        assert!(!fused_overflow_check(&[], 1));
    }
}
