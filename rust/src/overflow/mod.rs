//! Gradient overflow detection — §III-C (problem) and §IV-D (fix).
//!
//! Mixed fp16 training must vet the fp32 gradient flat buffer for
//! Inf/NaN every iteration before the optimizer step.  The baseline
//! reproduces PyTorch's operator chain with its real temporaries (the
//! 2.25× memory spike); the fused check is paper Algorithm 1 — one
//! pass, bitwise exponent test, early exit, zero allocation.

pub mod baseline;
pub mod fused;

pub use baseline::baseline_overflow_check;
pub use fused::{fused_overflow_check, fused_overflow_check_bf16, fused_overflow_check_f16};

/// Which checker the engine runs (ablation switch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Checker {
    /// isabs→isinf→any→isnan→any with materialized temporaries.
    Baseline,
    /// Single-pass fused bitwise check (Algorithm 1).
    Fused,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pinned::MemoryTracker;
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};
    use crate::util::rng::Xoshiro256;
    use std::sync::Arc;

    /// Oracle: the straightforward scan.
    fn oracle(xs: &[f32]) -> bool {
        xs.iter().any(|x| x.is_infinite() || x.is_nan())
    }

    #[test]
    fn prop_baseline_fused_oracle_agree() {
        check("overflow-parity", Config { cases: 64, ..Default::default() }, |rng, size| {
            let n = rng.range(1, size.max(2) * 8);
            let mut xs: Vec<f32> = (0..n)
                .map(|_| (rng.normal() as f32) * 1000.0)
                .collect();
            // inject specials at random positions with 50% probability
            if rng.next_f64() < 0.5 {
                let k = rng.range(1, 4.min(n) + 1);
                for _ in 0..k {
                    let pos = rng.below(n);
                    xs[pos] = match rng.below(3) {
                        0 => f32::INFINITY,
                        1 => f32::NEG_INFINITY,
                        _ => f32::NAN,
                    };
                }
            }
            let want = oracle(&xs);
            let tracker = Arc::new(MemoryTracker::new());
            let got_base = baseline_overflow_check(&xs, &tracker);
            let got_fused = fused_overflow_check(&xs, 1);
            prop_assert!(got_base == want, "baseline {got_base} != oracle {want}");
            prop_assert!(got_fused == want, "fused {got_fused} != oracle {want}");
            Ok(())
        });
    }

    #[test]
    fn denormals_and_extremes_are_finite() {
        let xs = vec![
            f32::MIN_POSITIVE,
            f32::MAX,
            -f32::MAX,
            1e-45, // subnormal
            0.0,
            -0.0,
        ];
        let tracker = Arc::new(MemoryTracker::new());
        assert!(!baseline_overflow_check(&xs, &tracker));
        assert!(!fused_overflow_check(&xs, 1));
    }

    #[test]
    fn multithreaded_fused_matches() {
        let mut rng = Xoshiro256::new(9);
        let mut xs: Vec<f32> = (0..100_000).map(|_| rng.next_f32()).collect();
        assert!(!fused_overflow_check(&xs, 4));
        xs[99_999] = f32::NAN;
        assert!(fused_overflow_check(&xs, 4));
    }
}
