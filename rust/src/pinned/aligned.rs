//! MemAscend's alignment-free pinned allocation (§IV-C).
//!
//! Real mode mirrors the paper's C++ extension: `posix_memalign` with
//! 4096-byte alignment (the DMA requirement), size rounded only to the
//! 4 KiB page boundary — not to a power of two — then "page-locked and
//! registered" (a no-op here; the *policy* cost is what's measured),
//! wrapped with a release hook that frees exactly once (the
//! `torch::from_blob` custom-deleter lifecycle).  Freed memory returns
//! to the OS immediately: these buffers are allocated once at init and
//! live for the whole run, so caching buys nothing.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::{Cat, HostAllocator, HostRegion, MemoryTracker, Mode, RegionData};

/// DMA-required alignment (NVMe + pinned-transfer friendly).
pub const DMA_ALIGN: usize = 4096;

pub fn round_page(bytes: usize) -> usize {
    bytes.div_ceil(DMA_ALIGN) * DMA_ALIGN
}

pub struct AlignedAllocator {
    mode: Mode,
    tracker: Arc<MemoryTracker>,
    reserved: Arc<AtomicUsize>,
    requested: Arc<AtomicUsize>,
}

impl AlignedAllocator {
    pub fn new(mode: Mode, tracker: Arc<MemoryTracker>) -> Arc<Self> {
        Arc::new(Self {
            mode,
            tracker,
            reserved: Arc::new(AtomicUsize::new(0)),
            requested: Arc::new(AtomicUsize::new(0)),
        })
    }

    fn alloc_impl(&self, bytes: usize, cat: Cat) -> HostRegion {
        let reserved = round_page(bytes.max(1));
        self.reserved.fetch_add(reserved, Ordering::Relaxed);
        self.requested.fetch_add(bytes, Ordering::Relaxed);
        self.tracker.alloc(cat, bytes as u64);
        self.tracker
            .alloc(Cat::PinnedOverhead, (reserved - bytes) as u64);

        let data = match self.mode {
            Mode::Virtual => RegionData::Virtual,
            Mode::Real => RegionData::Aligned { ptr: super::memalign_zeroed(reserved) },
        };

        let tracker = Arc::clone(&self.tracker);
        let res_ctr = Arc::clone(&self.reserved);
        let req_ctr = Arc::clone(&self.requested);
        let req = bytes;
        HostRegion {
            data,
            bytes_requested: bytes,
            bytes_reserved: reserved,
            cat,
            release: Some(Box::new(move |data, reserved, cat| {
                // exactly-once free via the region's Drop (refcount
                // semantics are provided by Arc<HostRegion> users).
                if let RegionData::Aligned { ptr } = data {
                    // SAFETY: ptr came from posix_memalign above and is
                    // freed exactly once (release is take()n).
                    unsafe { libc::free(ptr.cast()) };
                }
                res_ctr.fetch_sub(reserved, Ordering::Relaxed);
                req_ctr.fetch_sub(req, Ordering::Relaxed);
                tracker.free(cat, req as u64);
                tracker.free(Cat::PinnedOverhead, (reserved - req) as u64);
            })),
        }
    }
}

impl HostAllocator for Arc<AlignedAllocator> {
    fn alloc(&self, bytes: usize, cat: Cat) -> HostRegion {
        self.alloc_impl(bytes, cat)
    }

    fn reserve_size(&self, bytes: usize) -> usize {
        round_page(bytes.max(1))
    }

    fn reclaimable(&self) -> bool {
        true // frees return to the OS immediately (§IV-C)
    }

    fn reserved_bytes(&self) -> usize {
        self.reserved.load(Ordering::Relaxed)
    }

    fn requested_bytes(&self) -> usize {
        self.requested.load(Ordering::Relaxed)
    }

    fn tracker(&self) -> &Arc<MemoryTracker> {
        &self.tracker
    }
}

// Convenience: allow calling alloc directly on AlignedAllocator too.
impl AlignedAllocator {
    pub fn alloc(&self, bytes: usize, cat: Cat) -> HostRegion {
        self.alloc_impl(bytes, cat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};

    #[test]
    fn overhead_is_subpage() {
        let a = AlignedAllocator::new(Mode::Virtual, Arc::new(MemoryTracker::new()));
        // the paper's 2.1 GiB example: overhead < 4 KiB, not ~2 GiB
        let r = a.alloc((21 << 30) / 10, Cat::GradFlat);
        assert!(r.overhead() < DMA_ALIGN);
    }

    #[test]
    fn real_alloc_is_dma_aligned_and_zeroed() {
        let a = AlignedAllocator::new(Mode::Real, Arc::new(MemoryTracker::new()));
        let mut r = a.alloc(10_000, Cat::Other);
        let ptr = r.as_mut_slice().as_ptr() as usize;
        assert_eq!(ptr % DMA_ALIGN, 0);
        assert!(r.as_slice().iter().all(|&b| b == 0));
        r.as_mut_slice()[0] = 7;
        assert_eq!(r.as_slice()[0], 7);
    }

    #[test]
    fn free_returns_to_os_ledger() {
        let tracker = Arc::new(MemoryTracker::new());
        let a = AlignedAllocator::new(Mode::Real, tracker.clone());
        let r = a.alloc(1 << 20, Cat::OptimBuf);
        assert!(Arc::clone(&a).reserved_bytes() >= 1 << 20);
        drop(r);
        assert_eq!(Arc::clone(&a).reserved_bytes(), 0);
        assert_eq!(tracker.current_total(), 0);
        assert!(tracker.peak_total() >= 1 << 20);
    }

    #[test]
    fn prop_fragmentation_is_negligible() {
        check("aligned-allocator", Config::default(), |rng, size| {
            let a =
                AlignedAllocator::new(Mode::Virtual, Arc::new(MemoryTracker::new()));
            let mut live = Vec::new();
            for _ in 0..rng.range(1, 30) {
                let bytes = rng.range(1, size.max(2) * 4096);
                let r = a.alloc(bytes, Cat::Other);
                prop_assert!(r.overhead() < DMA_ALIGN, "overhead >= page");
                live.push(r);
            }
            let frag = Arc::clone(&a).fragmentation();
            prop_assert!(frag < 0.5, "fragmentation {frag} too high");
            Ok(())
        });
    }
}
