//! The unified pinned-memory arena: one budget-enforced lease tier
//! under every host-memory consumer.
//!
//! MemAscend's §III-B diagnosis is that system-memory waste comes from
//! *scattered, policy-blind* pinned allocation — five independent call
//! sites each pinning its own buffers means no component ever sees
//! global pressure.  The arena turns the paper's memory policy into an
//! enforced invariant:
//!
//! ```text
//!   bufpool  gradbuf  spill  swapper-scratch  optimizer-staging
//!      │        │       │          │                │
//!      └────────┴───────┴────┬─────┴────────────────┘
//!                            ▼  lease(bytes, cat) / take_*/put_*
//!                     [ PinnedArena ]──── budget cap, per-Cat
//!                            │            watermarks, overlap-free
//!                            ▼            offset/len leases
//!                  HostAllocator policy (pow2-caching | aligned)
//! ```
//!
//! Two tiers:
//!
//! - **Leases** ([`PinnedArena::lease`]): long-lived, exactly-placed
//!   regions.  Each category owns a set of *segments* — exactly-sized
//!   backing regions obtained from the policy allocator — and a lease
//!   is an (offset, len) carve out of one, page-granular so every
//!   lease is DMA-aligned and viewable as `&[f32]`.  Releasing a lease
//!   (RAII `Drop`) returns its extent for reuse; repeated same-shape
//!   leases therefore recycle the same backing pages (the shape-class
//!   behaviour the adaptive pool relies on), and [`PinnedArena::trim`]
//!   drops fully-idle segments back to the allocator.
//! - **Scratch vectors** ([`PinnedArena::take_f32`] /
//!   [`PinnedArena::put_f32`] and byte variants): the bounded
//!   recycling pools behind the swapper's `F32Scratch` and the
//!   optimizer's staging buffers.  Pooled (idle) bytes are charged to
//!   the ledger and count against the budget; handing a vector out
//!   un-charges it (it becomes transient compute memory the kernel
//!   call owns).
//!
//! The budget is a cap on everything the arena holds reserved —
//! segment bytes *including allocator-policy overhead* plus pooled
//! scratch.  A lease that cannot fit first triggers an implicit trim;
//! if that is not enough the caller gets a structured
//! [`ArenaError::BudgetExceeded`], never an abort — callers degrade
//! (e.g. the activation store spills to SSD).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use super::{Cat, HostAllocator, HostRegion, MemoryTracker};

/// Carve granularity: every lease offset and padded length is a
/// multiple of this, so leases inherit the segment base's DMA
/// alignment (and f32 alignment) for free.
pub const LEASE_ALIGN: usize = 4096;

fn pad(bytes: usize) -> usize {
    bytes.max(1).div_ceil(LEASE_ALIGN) * LEASE_ALIGN
}

/// Structured arena failures — returned, never panicked, so callers
/// can degrade (spill, fall back, surface the error).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArenaError {
    /// Granting the lease would push total reserved bytes past the cap
    /// (after an implicit trim of idle segments and pooled scratch).
    BudgetExceeded {
        cat: Cat,
        /// Bytes the caller asked for.
        requested: usize,
        /// Bytes a fresh backing region would reserve under the policy.
        would_reserve: usize,
        /// Bytes the arena currently holds reserved.
        in_use: usize,
        budget: usize,
    },
}

impl fmt::Display for ArenaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArenaError::BudgetExceeded { cat, requested, would_reserve, in_use, budget } => {
                write!(
                    f,
                    "pinned budget exceeded: lease of {requested} B ({would_reserve} B \
                     reserved) under '{}' with {in_use} of {budget} B in use",
                    cat.name()
                )
            }
        }
    }
}

impl std::error::Error for ArenaError {}

#[derive(Debug, Clone)]
pub struct ArenaConfig {
    /// Cap on total arena-reserved bytes (segments incl. policy
    /// overhead + pooled scratch). `None` = unbounded.
    pub budget_bytes: Option<usize>,
    /// Scratch-pool bounds, per category: max vectors kept idle…
    pub max_pooled_vecs: usize,
    /// …max idle bytes…
    pub max_pooled_vec_bytes: usize,
    /// …and the floor below which a vector is not worth a slot
    /// (without it, tiny returns — e.g. a 1-element loss-scale vec —
    /// would fill the count bound and disable recycling of real
    /// buffers).
    pub min_pooled_vec_bytes: usize,
}

impl Default for ArenaConfig {
    fn default() -> Self {
        Self {
            budget_bytes: None,
            max_pooled_vecs: 64,
            max_pooled_vec_bytes: 64 << 20,
            min_pooled_vec_bytes: 256,
        }
    }
}

/// One exactly-sized backing region of a category.
struct Segment {
    /// Kept alive for the ledger + the release hook; never sliced
    /// directly once `base` is taken (leases own disjoint views).
    region: HostRegion,
    base: *mut u8,
    len: usize,
    /// Sorted, coalesced free extents (offset, len).
    free: Vec<(usize, usize)>,
    live: usize,
}

// SAFETY: `base` points into `region`'s uniquely-owned allocation and
// is only dereferenced through non-overlapping leases.
unsafe impl Send for Segment {}

#[derive(Default)]
struct VecPool {
    f32s: Vec<Vec<f32>>,
    bytes: Vec<Vec<u8>>,
    pooled_bytes: usize,
}

/// Per-category watermarks. `charged` mirrors what the arena put on
/// the [`MemoryTracker`] ledger under this category (segment sizes +
/// pooled scratch); `requested` is the live leased demand.  When the
/// arena is the category's sole ledger client, `charged_peak` matches
/// `MemoryTracker::peak(cat)` bit-for-bit — the invariant
/// `accounting::sysmem` asserts.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CatWatermark {
    pub charged: usize,
    pub charged_peak: usize,
    pub requested: usize,
    pub requested_peak: usize,
}

/// Whole-arena utilization snapshot (Fig. 11-style reporting).
#[derive(Debug, Default, Clone, Copy)]
pub struct ArenaStats {
    /// Bytes currently reserved (segments incl. policy overhead +
    /// pooled scratch).
    pub reserved_bytes: usize,
    pub peak_reserved: usize,
    /// Live leased bytes (the actual need).
    pub requested_bytes: usize,
    pub peak_requested: usize,
    pub leases: u64,
    pub releases: u64,
    /// Leases served from an existing free extent (no fresh pin).
    pub recycled: u64,
    pub fresh_segments: u64,
}

impl ArenaStats {
    /// 1 − actual-need / reserved (internal fragmentation right now).
    pub fn fragmentation(&self) -> f64 {
        if self.reserved_bytes == 0 {
            return 0.0;
        }
        1.0 - self.requested_bytes as f64 / self.reserved_bytes as f64
    }

    /// 1 − peak-need / peak-reserved.
    pub fn peak_fragmentation(&self) -> f64 {
        if self.peak_reserved == 0 {
            return 0.0;
        }
        1.0 - self.peak_requested as f64 / self.peak_reserved as f64
    }
}

#[derive(Default)]
struct State {
    /// Segment slots per category (index-stable: trim leaves `None`).
    segments: BTreeMap<Cat, Vec<Option<Segment>>>,
    pools: BTreeMap<Cat, VecPool>,
    cats: BTreeMap<Cat, CatWatermark>,
    stats: ArenaStats,
}

struct Inner {
    alloc: Arc<dyn HostAllocator>,
    tracker: Arc<MemoryTracker>,
    cfg: ArenaConfig,
    state: Mutex<State>,
}

/// The budget-enforced lease layer. Cheap to share as `Arc<PinnedArena>`.
pub struct PinnedArena {
    inner: Arc<Inner>,
}

/// RAII view of an (offset, len) span inside one arena segment.
/// Dropping it returns the extent for reuse.
pub struct Lease {
    inner: Arc<Inner>,
    cat: Cat,
    seg: usize,
    offset: usize,
    padded: usize,
    requested: usize,
    /// Segment base (null in Virtual mode).
    base: *mut u8,
}

// SAFETY: a lease has exclusive ownership of its [offset, offset+padded)
// span — the extent allocator never hands out overlapping ranges — and
// the backing segment outlives it (`inner` is kept alive and segments
// with `live > 0` are never trimmed).  `&self` access is read-only.
unsafe impl Send for Lease {}
unsafe impl Sync for Lease {}

impl Lease {
    pub fn cat(&self) -> Cat {
        self.cat
    }

    /// Bytes the caller asked for (the visible span).
    pub fn bytes_requested(&self) -> usize {
        self.requested
    }

    /// Page-padded bytes the lease occupies inside its segment.
    pub fn bytes_padded(&self) -> usize {
        self.padded
    }

    pub fn is_virtual(&self) -> bool {
        self.base.is_null()
    }

    pub fn as_slice(&self) -> &[u8] {
        if self.base.is_null() {
            return &[];
        }
        // SAFETY: see the Send/Sync justification above.
        unsafe { std::slice::from_raw_parts(self.base.add(self.offset), self.requested) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        if self.base.is_null() {
            return &mut [];
        }
        // SAFETY: exclusive (&mut self) access to an exclusive span.
        unsafe { std::slice::from_raw_parts_mut(self.base.add(self.offset), self.requested) }
    }

    /// f32 view of the span (requires a multiple-of-4 request; the
    /// 4096-aligned base + page-aligned offset guarantee alignment).
    pub fn as_f32(&self) -> &[f32] {
        if self.base.is_null() {
            return &[];
        }
        debug_assert_eq!(self.requested % 4, 0, "f32 view of a non-f32-sized lease");
        // SAFETY: aligned (base and offset are 4096-multiples), in
        // bounds, exclusive span.
        unsafe {
            std::slice::from_raw_parts(
                self.base.add(self.offset).cast::<f32>(),
                self.requested / 4,
            )
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        if self.base.is_null() {
            return &mut [];
        }
        debug_assert_eq!(self.requested % 4, 0, "f32 view of a non-f32-sized lease");
        // SAFETY: as above, plus &mut self exclusivity.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.base.add(self.offset).cast::<f32>(),
                self.requested / 4,
            )
        }
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        let mut st = self.inner.state.lock().unwrap();
        {
            let seg = st
                .segments
                .get_mut(&self.cat)
                .and_then(|v| v[self.seg].as_mut())
                .expect("lease outlived its segment");
            seg.live -= 1;
            insert_extent(&mut seg.free, self.offset, self.padded);
        }
        let cw = st.cats.get_mut(&self.cat).expect("category accounted");
        cw.requested -= self.requested;
        st.stats.requested_bytes -= self.requested;
        st.stats.releases += 1;
    }
}

/// Insert (off, len) into a sorted free list, coalescing neighbours.
fn insert_extent(free: &mut Vec<(usize, usize)>, off: usize, len: usize) {
    let i = free.partition_point(|&(o, _)| o < off);
    free.insert(i, (off, len));
    if i + 1 < free.len() && free[i].0 + free[i].1 == free[i + 1].0 {
        let next = free.remove(i + 1);
        free[i].1 += next.1;
    }
    if i > 0 && free[i - 1].0 + free[i - 1].1 == free[i].0 {
        let cur = free.remove(i);
        free[i - 1].1 += cur.1;
    }
}

impl PinnedArena {
    pub fn new(alloc: Arc<dyn HostAllocator>, cfg: ArenaConfig) -> Arc<Self> {
        let tracker = Arc::clone(alloc.tracker());
        Arc::new(Self {
            inner: Arc::new(Inner { alloc, tracker, cfg, state: Mutex::new(State::default()) }),
        })
    }

    /// Lease `bytes` under `cat`.  Served from a recycled extent when
    /// one fits (best-fit), else from a fresh exactly-sized segment —
    /// which is where the budget is enforced.
    pub fn lease(&self, bytes: usize, cat: Cat) -> Result<Lease, ArenaError> {
        let padded = pad(bytes);
        let inner = &self.inner;
        let mut st = inner.state.lock().unwrap();

        // best-fit over this category's free extents
        let mut best: Option<(usize, usize, usize)> = None; // (seg, ext, ext_len)
        if let Some(segs) = st.segments.get(&cat) {
            for (si, slot) in segs.iter().enumerate() {
                let Some(seg) = slot else { continue };
                for (ei, &(_, elen)) in seg.free.iter().enumerate() {
                    if elen >= padded && best.is_none_or(|(_, _, bl)| elen < bl) {
                        best = Some((si, ei, elen));
                    }
                }
            }
        }
        if let Some((si, ei, _)) = best {
            let (offset, base) = {
                let seg = st.segments.get_mut(&cat).unwrap()[si]
                    .as_mut()
                    .expect("best-fit segment present");
                let (eoff, elen) = seg.free[ei];
                if elen == padded {
                    seg.free.remove(ei);
                } else {
                    seg.free[ei] = (eoff + padded, elen - padded);
                }
                seg.live += 1;
                (eoff, seg.base)
            };
            st.stats.recycled += 1;
            note_lease(&mut st, cat, bytes);
            return Ok(Lease {
                inner: Arc::clone(inner),
                cat,
                seg: si,
                offset,
                padded,
                requested: bytes,
                base,
            });
        }

        // fresh segment, exactly sized to this request
        let would_reserve = inner.alloc.reserve_size(padded);
        if let Some(budget) = inner.cfg.budget_bytes {
            // a request that can never fit must not wipe warm caches
            if would_reserve > budget {
                return Err(ArenaError::BudgetExceeded {
                    cat,
                    requested: bytes,
                    would_reserve,
                    in_use: st.stats.reserved_bytes,
                    budget,
                });
            }
            if st.stats.reserved_bytes + would_reserve > budget {
                // targeted: free idle capacity only until this fits
                trim_until(inner, &mut st, budget - would_reserve);
                if st.stats.reserved_bytes + would_reserve > budget {
                    return Err(ArenaError::BudgetExceeded {
                        cat,
                        requested: bytes,
                        would_reserve,
                        in_use: st.stats.reserved_bytes,
                        budget,
                    });
                }
            }
        }
        let region = inner.alloc.alloc(padded, cat);
        let base = region.raw_base();
        let reserved = region.bytes_reserved;
        let seg = Segment { region, base, len: padded, free: Vec::new(), live: 1 };
        let segs = st.segments.entry(cat).or_default();
        let si = match segs.iter().position(|s| s.is_none()) {
            Some(i) => i,
            None => {
                segs.push(None);
                segs.len() - 1
            }
        };
        segs[si] = Some(seg);
        st.stats.fresh_segments += 1;
        st.stats.reserved_bytes += reserved;
        st.stats.peak_reserved = st.stats.peak_reserved.max(st.stats.reserved_bytes);
        {
            let cw = st.cats.entry(cat).or_default();
            cw.charged += padded;
            cw.charged_peak = cw.charged_peak.max(cw.charged);
        }
        note_lease(&mut st, cat, bytes);
        Ok(Lease {
            inner: Arc::clone(inner),
            cat,
            seg: si,
            offset: 0,
            padded,
            requested: bytes,
            base,
        })
    }

    /// Drop all idle capacity: fully-free segments go back to the
    /// allocator (when the policy reclaims frees) and pooled scratch
    /// vectors are released.
    pub fn trim(&self) {
        let mut st = self.inner.state.lock().unwrap();
        trim_until(&self.inner, &mut st, 0);
    }

    // ---- scratch-vector tier -------------------------------------------

    /// Take an f32 vector of exactly `n` elements, recycled best-fit
    /// from the category's pool when possible.  Handing a vector out
    /// un-charges it from the ledger (it becomes transient compute
    /// memory until [`Self::put_f32`] returns it).
    pub fn take_f32(&self, n: usize, cat: Cat) -> Vec<f32> {
        let inner = &self.inner;
        let mut st = inner.state.lock().unwrap();
        let taken = {
            let pool = st.pools.entry(cat).or_default();
            let mut best: Option<(usize, usize)> = None; // (index, capacity)
            for (i, v) in pool.f32s.iter().enumerate() {
                let c = v.capacity();
                if c >= n && best.is_none_or(|(_, bc)| c < bc) {
                    best = Some((i, c));
                }
            }
            best.map(|(i, c)| (pool.f32s.swap_remove(i), c * 4))
        };
        match taken {
            Some((mut v, bytes)) => {
                uncharge_pooled(inner, &mut st, cat, bytes);
                drop(st);
                v.clear();
                v.resize(n, 0.0);
                v
            }
            None => {
                drop(st);
                vec![0f32; n]
            }
        }
    }

    /// Return a spent f32 vector to the category's pool.  Dropped
    /// (not pooled) when below the size floor, past the pool bounds,
    /// or when pooling it would exceed the budget.
    pub fn put_f32(&self, v: Vec<f32>, cat: Cat) {
        let bytes = v.capacity() * 4;
        let inner = &self.inner;
        if bytes < inner.cfg.min_pooled_vec_bytes {
            return;
        }
        let mut st = inner.state.lock().unwrap();
        if !pool_admits(inner, &st, cat, bytes) {
            return;
        }
        st.pools.entry(cat).or_default().f32s.push(v);
        charge_pooled(inner, &mut st, cat, bytes);
    }

    /// [`Self::take_f32`] for byte buffers.
    pub fn take_bytes(&self, n: usize, cat: Cat) -> Vec<u8> {
        let inner = &self.inner;
        let mut st = inner.state.lock().unwrap();
        let taken = {
            let pool = st.pools.entry(cat).or_default();
            let mut best: Option<(usize, usize)> = None;
            for (i, v) in pool.bytes.iter().enumerate() {
                let c = v.capacity();
                if c >= n && best.is_none_or(|(_, bc)| c < bc) {
                    best = Some((i, c));
                }
            }
            best.map(|(i, c)| (pool.bytes.swap_remove(i), c))
        };
        match taken {
            Some((mut v, bytes)) => {
                uncharge_pooled(inner, &mut st, cat, bytes);
                drop(st);
                v.clear();
                v.resize(n, 0);
                v
            }
            None => {
                drop(st);
                vec![0u8; n]
            }
        }
    }

    /// [`Self::put_f32`] for byte buffers.
    pub fn put_bytes(&self, v: Vec<u8>, cat: Cat) {
        let bytes = v.capacity();
        let inner = &self.inner;
        if bytes < inner.cfg.min_pooled_vec_bytes {
            return;
        }
        let mut st = inner.state.lock().unwrap();
        if !pool_admits(inner, &st, cat, bytes) {
            return;
        }
        st.pools.entry(cat).or_default().bytes.push(v);
        charge_pooled(inner, &mut st, cat, bytes);
    }

    /// Idle f32 vectors pooled under `cat` (test/introspection hook).
    pub fn pooled_f32(&self, cat: Cat) -> usize {
        self.inner
            .state
            .lock()
            .unwrap()
            .pools
            .get(&cat)
            .map_or(0, |p| p.f32s.len())
    }

    /// Idle byte vectors pooled under `cat`.
    pub fn pooled_byte_vecs(&self, cat: Cat) -> usize {
        self.inner
            .state
            .lock()
            .unwrap()
            .pools
            .get(&cat)
            .map_or(0, |p| p.bytes.len())
    }

    // ---- introspection -------------------------------------------------

    pub fn stats(&self) -> ArenaStats {
        self.inner.state.lock().unwrap().stats
    }

    pub fn watermark(&self, cat: Cat) -> CatWatermark {
        self.inner
            .state
            .lock()
            .unwrap()
            .cats
            .get(&cat)
            .copied()
            .unwrap_or_default()
    }

    /// Per-category watermarks for every category the arena touched.
    pub fn watermarks(&self) -> Vec<(Cat, CatWatermark)> {
        let st = self.inner.state.lock().unwrap();
        Cat::ALL
            .iter()
            .filter_map(|c| st.cats.get(c).map(|w| (*c, *w)))
            .collect()
    }

    pub fn budget_bytes(&self) -> Option<usize> {
        self.inner.cfg.budget_bytes
    }

    pub fn tracker(&self) -> &Arc<MemoryTracker> {
        &self.inner.tracker
    }
}

fn note_lease(st: &mut State, cat: Cat, bytes: usize) {
    st.stats.leases += 1;
    st.stats.requested_bytes += bytes;
    st.stats.peak_requested = st.stats.peak_requested.max(st.stats.requested_bytes);
    let cw = st.cats.entry(cat).or_default();
    cw.requested += bytes;
    cw.requested_peak = cw.requested_peak.max(cw.requested);
}

fn pool_admits(inner: &Inner, st: &State, cat: Cat, bytes: usize) -> bool {
    if let Some(pool) = st.pools.get(&cat) {
        if pool.f32s.len() + pool.bytes.len() >= inner.cfg.max_pooled_vecs
            || pool.pooled_bytes + bytes > inner.cfg.max_pooled_vec_bytes
        {
            return false;
        }
    } else if bytes > inner.cfg.max_pooled_vec_bytes {
        return false;
    }
    match inner.cfg.budget_bytes {
        Some(budget) => st.stats.reserved_bytes + bytes <= budget,
        None => true,
    }
}

fn charge_pooled(inner: &Inner, st: &mut State, cat: Cat, bytes: usize) {
    st.pools.get_mut(&cat).unwrap().pooled_bytes += bytes;
    st.stats.reserved_bytes += bytes;
    st.stats.peak_reserved = st.stats.peak_reserved.max(st.stats.reserved_bytes);
    let cw = st.cats.entry(cat).or_default();
    cw.charged += bytes;
    cw.charged_peak = cw.charged_peak.max(cw.charged);
    inner.tracker.alloc(cat, bytes as u64);
}

fn uncharge_pooled(inner: &Inner, st: &mut State, cat: Cat, bytes: usize) {
    st.pools.get_mut(&cat).unwrap().pooled_bytes -= bytes;
    st.stats.reserved_bytes -= bytes;
    st.cats.get_mut(&cat).unwrap().charged -= bytes;
    inner.tracker.free(cat, bytes as u64);
}

/// Free idle capacity until `reserved_bytes <= target`, stopping as
/// soon as the target is met (pass 0 for a full trim).  Fully-idle
/// segments go first — but only when the allocator actually reclaims
/// frees; under the pow2-caching policy freed blocks would just move
/// to the allocator's cache while staying on the ledger, so segments
/// are kept and the arena's watermarks remain an exact ledger mirror
/// (and the budget correctly reflects that the reserve is monotone
/// there).  Pooled scratch vectors (arena-charged, always reversible)
/// go second.
fn trim_until(inner: &Inner, st: &mut State, target: usize) {
    if inner.alloc.reclaimable() {
        let seg_cats: Vec<Cat> = st.segments.keys().copied().collect();
        for cat in seg_cats {
            let n_slots = st.segments.get(&cat).map_or(0, |v| v.len());
            for i in 0..n_slots {
                if st.stats.reserved_bytes <= target {
                    return;
                }
                let taken = {
                    let slot = &mut st.segments.get_mut(&cat).unwrap()[i];
                    if matches!(slot, Some(s) if s.live == 0) {
                        slot.take()
                    } else {
                        None
                    }
                };
                if let Some(seg) = taken {
                    st.stats.reserved_bytes -= seg.region.bytes_reserved;
                    st.cats.get_mut(&cat).unwrap().charged -= seg.len;
                    // seg drops here: the region's release hook
                    // un-charges the ledger
                }
            }
        }
    }
    let pool_cats: Vec<Cat> = st.pools.keys().copied().collect();
    for cat in pool_cats {
        loop {
            if st.stats.reserved_bytes <= target {
                return;
            }
            // evict one vector at a time, largest first, so a small
            // overshoot does not wipe a warm pool
            let freed = {
                let pool = st.pools.get_mut(&cat).unwrap();
                let f = pool
                    .f32s
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, v)| v.capacity())
                    .map(|(i, v)| (i, v.capacity() * 4));
                let b = pool
                    .bytes
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, v)| v.capacity())
                    .map(|(i, v)| (i, v.capacity()));
                match (f, b) {
                    (Some((i, fb)), Some((j, bb))) => {
                        if fb >= bb {
                            pool.f32s.swap_remove(i);
                            fb
                        } else {
                            pool.bytes.swap_remove(j);
                            bb
                        }
                    }
                    (Some((i, fb)), None) => {
                        pool.f32s.swap_remove(i);
                        fb
                    }
                    (None, Some((j, bb))) => {
                        pool.bytes.swap_remove(j);
                        bb
                    }
                    (None, None) => break,
                }
            };
            st.pools.get_mut(&cat).unwrap().pooled_bytes -= freed;
            st.stats.reserved_bytes -= freed;
            st.cats.get_mut(&cat).unwrap().charged -= freed;
            inner.tracker.free(cat, freed as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pinned::{AlignedAllocator, CachingAllocator, Mode};
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};

    fn arena(mode: Mode, budget: Option<usize>) -> Arc<PinnedArena> {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(mode, tracker);
        PinnedArena::new(
            Arc::new(alloc),
            ArenaConfig { budget_bytes: budget, ..Default::default() },
        )
    }

    #[test]
    fn lease_roundtrip_and_release() {
        let a = arena(Mode::Real, None);
        let mut l = a.lease(10_000, Cat::GradFlat).unwrap();
        assert_eq!(l.bytes_requested(), 10_000);
        assert_eq!(l.as_slice().len(), 10_000);
        l.as_mut_slice()[9_999] = 7;
        assert_eq!(l.as_slice()[9_999], 7);
        let st = a.stats();
        assert_eq!(st.requested_bytes, 10_000);
        assert_eq!(st.fresh_segments, 1);
        drop(l);
        let st = a.stats();
        assert_eq!(st.requested_bytes, 0);
        // the segment stays cached for recycling until trim
        assert!(st.reserved_bytes >= 10_000);
        a.trim();
        assert_eq!(a.stats().reserved_bytes, 0);
        assert_eq!(a.tracker().current_total(), 0);
    }

    #[test]
    fn freed_extents_recycle_without_fresh_pins() {
        let a = arena(Mode::Real, None);
        let l1 = a.lease(8192, Cat::ParamPool).unwrap();
        drop(l1);
        let _l2 = a.lease(4096, Cat::ParamPool).unwrap();
        let _l3 = a.lease(4096, Cat::ParamPool).unwrap();
        let st = a.stats();
        assert_eq!(st.fresh_segments, 1, "both re-leases must carve the freed segment");
        assert_eq!(st.recycled, 2);
    }

    #[test]
    fn f32_view_is_aligned_and_writable() {
        let a = arena(Mode::Real, None);
        let mut l = a.lease(1024 * 4, Cat::OptimBuf).unwrap();
        assert_eq!(l.as_f32().len(), 1024);
        assert_eq!(l.as_f32().as_ptr() as usize % 4, 0);
        l.as_f32_mut()[1023] = 1.5;
        assert_eq!(l.as_f32()[1023], 1.5);
        // the raw-byte view sees the same memory
        assert_eq!(&l.as_slice()[1023 * 4..1024 * 4], 1.5f32.to_le_bytes());
    }

    #[test]
    fn budget_cap_returns_structured_error() {
        let a = arena(Mode::Virtual, Some(1 << 20));
        let l1 = a.lease(512 << 10, Cat::ActCkpt).unwrap();
        let err = a.lease(1 << 20, Cat::ActCkpt).unwrap_err();
        match err {
            ArenaError::BudgetExceeded { cat, requested, budget, .. } => {
                assert_eq!(cat, Cat::ActCkpt);
                assert_eq!(requested, 1 << 20);
                assert_eq!(budget, 1 << 20);
            }
        }
        // releasing + implicit trim makes room again
        drop(l1);
        assert!(a.lease(1 << 20, Cat::ActCkpt).is_ok());
    }

    #[test]
    fn budget_counts_policy_overhead_under_pow2_allocator() {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = CachingAllocator::new(Mode::Virtual, tracker);
        let a = PinnedArena::new(
            Arc::new(alloc),
            ArenaConfig { budget_bytes: Some(3 << 20), ..Default::default() },
        );
        // 1.5 MiB request reserves 2 MiB under pow2; a second one would
        // need 4 MiB total — over the 3 MiB cap.
        let _l = a.lease((3 << 20) / 2, Cat::ParamPool).unwrap();
        assert!(matches!(
            a.lease((3 << 20) / 2, Cat::ParamPool),
            Err(ArenaError::BudgetExceeded { .. })
        ));
    }

    #[test]
    fn pow2_policy_segments_survive_trim_keeping_ledger_mirror() {
        // the caching policy's reserve is monotone: trimming must keep
        // segments (freeing them would only move bytes into the
        // allocator cache while the ledger stays charged — the
        // watermark/ledger mirror would silently break)
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = CachingAllocator::new(Mode::Virtual, tracker.clone());
        let a = PinnedArena::new(Arc::new(alloc), ArenaConfig::default());
        drop(a.lease(10_000, Cat::OptimBuf).unwrap());
        a.trim();
        assert!(a.stats().reserved_bytes > 0, "pow2 segment must be kept");
        assert_eq!(
            a.watermark(Cat::OptimBuf).charged as u64,
            tracker.current(Cat::OptimBuf)
        );
        // a re-lease recycles the kept segment — no fresh pin, and the
        // mirror still holds
        let _l2 = a.lease(8_000, Cat::OptimBuf).unwrap();
        assert_eq!(a.stats().fresh_segments, 1);
        assert_eq!(
            a.watermark(Cat::OptimBuf).charged as u64,
            tracker.current(Cat::OptimBuf)
        );
    }

    #[test]
    fn watermarks_match_ledger_bit_for_bit() {
        let a = arena(Mode::Virtual, None);
        let l1 = a.lease(123_456, Cat::GradFlat).unwrap();
        let l2 = a.lease(77_000, Cat::OptimBuf).unwrap();
        let l3 = a.lease(50_000, Cat::GradFlat).unwrap();
        drop(l3);
        drop(l2);
        for (cat, w) in a.watermarks() {
            assert_eq!(
                w.charged_peak as u64,
                a.tracker().peak(cat),
                "{cat:?} watermark diverged from the ledger"
            );
        }
        drop(l1);
    }

    #[test]
    fn concurrent_leases_never_overlap_in_memory() {
        // byte-pattern proof: every thread writes its own tag through
        // its lease and must read it back intact.
        let a = arena(Mode::Real, None);
        std::thread::scope(|s| {
            for tag in 0..8u8 {
                let a = Arc::clone(&a);
                s.spawn(move || {
                    for round in 0..50usize {
                        let n = 1000 + (tag as usize * 977 + round * 131) % 9000;
                        let mut l = a.lease(n, Cat::SwapBuf).unwrap();
                        l.as_mut_slice().fill(tag);
                        std::thread::yield_now();
                        assert!(
                            l.as_slice().iter().all(|&b| b == tag),
                            "lease memory trampled by a concurrent lease"
                        );
                    }
                });
            }
        });
        assert_eq!(a.stats().requested_bytes, 0);
    }

    #[test]
    fn prop_lease_release_matches_reference_model() {
        check("pinned-arena", Config { cases: 48, ..Default::default() }, |rng, size| {
            let budget = 64 * 4096;
            let a = arena(Mode::Virtual, Some(budget));
            // reference model: live (requested, padded) pairs
            let mut live: Vec<(Lease, usize)> = Vec::new();
            let mut model_requested = 0usize;
            for _ in 0..120 {
                if !live.is_empty() && rng.next_f64() < 0.45 {
                    let i = rng.below(live.len());
                    let (_, req) = live.swap_remove(i);
                    model_requested -= req;
                } else {
                    let bytes = rng.range(1, (size.max(2) * 16).min(budget));
                    match a.lease(bytes, Cat::Other) {
                        Ok(l) => {
                            live.push((l, bytes));
                            model_requested += bytes;
                        }
                        Err(ArenaError::BudgetExceeded { .. }) => {
                            // the refusal must be justified: even after
                            // the implicit trim, reserved state plus the
                            // new lease really exceeds the cap
                            let reserved = a.stats().reserved_bytes;
                            prop_assert!(
                                reserved + pad(bytes) > budget,
                                "budget refusal with only {reserved} B reserved \
                                 (+{bytes} B) under {budget} B cap"
                            );
                        }
                    }
                }
                let st = a.stats();
                prop_assert!(
                    st.requested_bytes == model_requested,
                    "requested ledger drift: {} vs model {}",
                    st.requested_bytes,
                    model_requested
                );
                prop_assert!(
                    st.reserved_bytes <= budget,
                    "reserved {} exceeds budget {}",
                    st.reserved_bytes,
                    budget
                );
                prop_assert!(
                    st.leases == st.releases + live.len() as u64,
                    "lease/release count drift"
                );
                // no overlap between live leases (same-cat, same-segment
                // spans must be disjoint)
                for (i, (l1, _)) in live.iter().enumerate() {
                    for (l2, _) in live.iter().skip(i + 1) {
                        if l1.seg != l2.seg {
                            continue;
                        }
                        let disjoint = l1.offset + l1.padded <= l2.offset
                            || l2.offset + l2.padded <= l1.offset;
                        prop_assert!(
                            disjoint,
                            "leases overlap: [{}, {}) vs [{}, {})",
                            l1.offset,
                            l1.offset + l1.padded,
                            l2.offset,
                            l2.offset + l2.padded
                        );
                    }
                }
            }
            drop(live);
            prop_assert!(a.stats().requested_bytes == 0, "leak after drop");
            Ok(())
        });
    }

    #[test]
    fn scratch_recycles_best_fit() {
        let a = arena(Mode::Real, None);
        let v = a.take_f32(100, Cat::SwapBuf);
        a.put_f32(v, Cat::SwapBuf);
        assert_eq!(a.pooled_f32(Cat::SwapBuf), 1);
        // best-fit: a huge reclaimed buffer must not be pinned by a
        // small request when a smaller one fits
        a.put_f32(Vec::with_capacity(1_000_000), Cat::SwapBuf);
        let small = a.take_f32(80, Cat::SwapBuf);
        assert!(small.capacity() < 1_000_000);
        assert_eq!(small.len(), 80);
        assert_eq!(a.pooled_f32(Cat::SwapBuf), 1);
    }

    #[test]
    fn scratch_floor_and_byte_bound() {
        let a = arena(Mode::Real, None);
        for _ in 0..100 {
            a.put_f32(vec![0f32; 1], Cat::SwapBuf); // sub-floor: dropped
        }
        assert_eq!(a.pooled_f32(Cat::SwapBuf), 0);
        // 4 MiB each against the 64 MiB per-cat byte bound: ≤ 16 kept
        for _ in 0..20 {
            a.put_f32(Vec::with_capacity(1 << 20), Cat::SwapBuf);
        }
        assert!(a.pooled_f32(Cat::SwapBuf) <= 16);
    }

    #[test]
    fn scratch_pool_charges_ledger_and_respects_budget() {
        let a = arena(Mode::Real, Some(1 << 20));
        a.put_bytes(vec![0u8; 512 << 10], Cat::OptimBuf);
        assert_eq!(a.tracker().current(Cat::OptimBuf), 512 << 10);
        // pooling another 768 KiB would break the 1 MiB budget: dropped
        a.put_bytes(vec![0u8; 768 << 10], Cat::OptimBuf);
        assert_eq!(a.pooled_byte_vecs(Cat::OptimBuf), 1);
        // taking the pooled vector un-charges it
        let v = a.take_bytes(512 << 10, Cat::OptimBuf);
        assert_eq!(a.tracker().current(Cat::OptimBuf), 0);
        assert_eq!(v.len(), 512 << 10);
    }

    #[test]
    fn virtual_mode_leases_have_no_storage() {
        let a = arena(Mode::Virtual, None);
        let mut l = a.lease(1 << 30, Cat::ParamPool).unwrap();
        assert!(l.is_virtual());
        assert!(l.as_slice().is_empty());
        assert!(l.as_mut_slice().is_empty());
        assert!(l.as_f32().is_empty());
        assert_eq!(a.stats().requested_bytes, 1 << 30);
    }
}
