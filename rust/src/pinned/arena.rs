//! The unified pinned-memory arena: one budget-enforced lease tier
//! under every host-memory consumer.
//!
//! MemAscend's §III-B diagnosis is that system-memory waste comes from
//! *scattered, policy-blind* pinned allocation — five independent call
//! sites each pinning its own buffers means no component ever sees
//! global pressure.  The arena turns the paper's memory policy into an
//! enforced invariant:
//!
//! ```text
//!   bufpool  gradbuf  spill  swapper-scratch  optimizer-tiles
//!      │        │       │          │                │
//!      └────────┴───────┴────┬─────┴────────────────┘
//!                            ▼  lease(bytes, cat) / take_*/put_*
//!                     [ PinnedArena ]──── per-Cat sharded state,
//!                            │            atomic global budget,
//!                            ▼            overlap-free offset/len leases
//!                  HostAllocator policy (pow2-caching | aligned)
//! ```
//!
//! Two tiers:
//!
//! - **Leases** ([`PinnedArena::lease`]): long-lived, exactly-placed
//!   regions.  Each category owns a set of *segments* — exactly-sized
//!   backing regions obtained from the policy allocator — and a lease
//!   is an (offset, len) carve out of one, page-granular so every
//!   lease is DMA-aligned and viewable as `&[f32]`.  Releasing a lease
//!   (RAII `Drop`) returns its extent for reuse, and
//!   [`PinnedArena::trim`] drops fully-idle segments back to the
//!   allocator.
//! - **Scratch vectors** ([`PinnedArena::take_f32`] /
//!   [`PinnedArena::put_f32`] and byte variants): the bounded
//!   recycling pools behind the swapper's `F32Scratch` and the
//!   optimizer's staging buffers.  Pooled (idle) bytes are charged to
//!   the ledger and count against the budget; handing a vector out
//!   un-charges it (it becomes transient compute memory the kernel
//!   call owns).
//!
//! Concurrency: all mutable state is **sharded per category** — one
//! lock per [`Cat`], so tile-heavy optimizer lease traffic never
//! contends with the swapper's scratch recycling or the activation
//! store's slot churn.  The cross-category invariants (global budget,
//! whole-arena stats) live on atomics; the budget is enforced by a
//! compare-and-swap reservation, so the cap can never be exceeded even
//! under concurrent leases from different shards.
//!
//! Extent recycling is **size-class bucketed**: each shard indexes its
//! free extents by power-of-two class, so a mixed stream of tile and
//! tail leases finds a fitting extent in O(log) instead of scanning
//! every segment — and near-best-fit is preserved (the smallest
//! fitting extent of the first non-empty class is taken, splitting the
//! remainder back into its class).  [`ArenaStats::recycled`] /
//! [`ArenaStats::recycle_misses`] count free-list hits vs fresh
//! segment pins.
//!
//! The budget is a cap on everything the arena holds reserved —
//! segment bytes *including allocator-policy overhead* plus pooled
//! scratch.  A lease that cannot fit first triggers an implicit trim;
//! if that is not enough the caller gets a structured
//! [`ArenaError::BudgetExceeded`], never an abort — callers degrade
//! (e.g. the activation store spills to SSD).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::events::MAX_JOB_LANES;

use super::{Cat, HostAllocator, HostRegion, MemoryTracker};

/// Carve granularity: every lease offset and padded length is a
/// multiple of this, so leases inherit the segment base's DMA
/// alignment (and f32 alignment) for free.
pub const LEASE_ALIGN: usize = 4096;

/// Job-scoped namespaces one arena can carry (aligned with the I/O
/// layer's per-job lanes so `JobId::lane` indexes both).  Namespace 0
/// is the host default: no quota, the identity of every pre-tenancy
/// code path.
pub const MAX_NAMESPACES: usize = MAX_JOB_LANES;

const N_CATS: usize = Cat::ALL.len();

/// Shard index of a category: `Cat` is unit-only and `Cat::ALL` is in
/// declaration order, so the discriminant *is* the index (constant
/// time on the hot lease/release path).
fn cat_index(cat: Cat) -> usize {
    let i = cat as usize;
    debug_assert_eq!(Cat::ALL[i], cat, "Cat::ALL out of declaration order");
    i
}

fn pad(bytes: usize) -> usize {
    bytes.max(1).div_ceil(LEASE_ALIGN) * LEASE_ALIGN
}

/// Size class of an extent: floor(log2(len)).  Extents in class `c`
/// have lengths in `[2^c, 2^(c+1))`, so any extent in a class above a
/// request's class is guaranteed to fit.
fn class_of(len: usize) -> u32 {
    debug_assert!(len > 0);
    usize::BITS - 1 - len.leading_zeros()
}

/// Structured arena failures — returned, never panicked, so callers
/// can degrade (spill, fall back, surface the error).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArenaError {
    /// Granting the lease would push total reserved bytes past the cap
    /// (after an implicit trim of idle segments and pooled scratch).
    BudgetExceeded {
        cat: Cat,
        /// Bytes the caller asked for.
        requested: usize,
        /// Bytes a fresh backing region would reserve under the policy.
        would_reserve: usize,
        /// Bytes the arena currently holds reserved.
        in_use: usize,
        budget: usize,
    },
}

impl fmt::Display for ArenaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArenaError::BudgetExceeded { cat, requested, would_reserve, in_use, budget } => {
                write!(
                    f,
                    "pinned budget exceeded: lease of {requested} B ({would_reserve} B \
                     reserved) under '{}' with {in_use} of {budget} B in use",
                    cat.name()
                )
            }
        }
    }
}

impl std::error::Error for ArenaError {}

#[derive(Debug, Clone)]
pub struct ArenaConfig {
    /// Cap on total arena-reserved bytes (segments incl. policy
    /// overhead + pooled scratch). `None` = unbounded.
    pub budget_bytes: Option<usize>,
    /// Scratch-pool bounds, per category: max vectors kept idle…
    pub max_pooled_vecs: usize,
    /// …max idle bytes…
    pub max_pooled_vec_bytes: usize,
    /// …and the floor below which a vector is not worth a slot
    /// (without it, tiny returns — e.g. a 1-element loss-scale vec —
    /// would fill the count bound and disable recycling of real
    /// buffers).
    pub min_pooled_vec_bytes: usize,
}

impl Default for ArenaConfig {
    fn default() -> Self {
        Self {
            budget_bytes: None,
            max_pooled_vecs: 64,
            max_pooled_vec_bytes: 64 << 20,
            min_pooled_vec_bytes: 256,
        }
    }
}

/// One exactly-sized backing region of a category.
struct Segment {
    /// Kept alive for the ledger + the release hook; never sliced
    /// directly once `base` is taken (leases own disjoint views).
    region: HostRegion,
    base: *mut u8,
    len: usize,
    /// Free extents, offset -> len (coalesced; mirrored in the shard's
    /// size-class buckets).
    free: BTreeMap<usize, usize>,
    live: usize,
    /// Namespace whose lease pinned this segment — its reserved bytes
    /// stay attributed here until trim (free extents are shared across
    /// the whole category, so recycling by another namespace does not
    /// move the charge).
    ns: usize,
}

// SAFETY: `base` points into `region`'s uniquely-owned allocation and
// is only dereferenced through non-overlapping leases.
unsafe impl Send for Segment {}

/// Pooled scratch, each entry tagged with the namespace whose `put_*`
/// charged it (the reserved-byte attribution follows the putter until
/// a take or eviction un-charges it, mirroring segment attribution).
#[derive(Default)]
struct VecPool {
    f32s: Vec<(Vec<f32>, usize)>,
    bytes: Vec<(Vec<u8>, usize)>,
    pooled_bytes: usize,
}

/// Per-category watermarks. `charged` mirrors what the arena put on
/// the [`MemoryTracker`] ledger under this category (segment sizes +
/// pooled scratch); `requested` is the live leased demand.  When the
/// arena is the category's sole ledger client, `charged_peak` matches
/// `MemoryTracker::peak(cat)` bit-for-bit — the invariant
/// `accounting::sysmem` asserts.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CatWatermark {
    pub charged: usize,
    pub charged_peak: usize,
    pub requested: usize,
    pub requested_peak: usize,
}

/// Whole-arena utilization snapshot (Fig. 11-style reporting).
#[derive(Debug, Default, Clone, Copy)]
pub struct ArenaStats {
    /// Bytes currently reserved (segments incl. policy overhead +
    /// pooled scratch).
    pub reserved_bytes: usize,
    pub peak_reserved: usize,
    /// Live leased bytes (the actual need).
    pub requested_bytes: usize,
    pub peak_requested: usize,
    pub leases: u64,
    pub releases: u64,
    /// Free-list hits: leases served from a recycled extent (no fresh
    /// pin).
    pub recycled: u64,
    /// Free-list misses: lease attempts no bucketed extent could serve
    /// (they pinned a fresh segment, or were refused by the budget).
    pub recycle_misses: u64,
    pub fresh_segments: u64,
}

impl ArenaStats {
    /// 1 − actual-need / reserved (internal fragmentation right now).
    pub fn fragmentation(&self) -> f64 {
        if self.reserved_bytes == 0 {
            return 0.0;
        }
        1.0 - self.requested_bytes as f64 / self.reserved_bytes as f64
    }

    /// 1 − peak-need / peak-reserved.
    pub fn peak_fragmentation(&self) -> f64 {
        if self.peak_reserved == 0 {
            return 0.0;
        }
        1.0 - self.peak_requested as f64 / self.peak_reserved as f64
    }

    /// Fraction of leases served from the free list.
    pub fn recycle_hit_rate(&self) -> f64 {
        let total = self.recycled + self.recycle_misses;
        if total == 0 {
            return 0.0;
        }
        self.recycled as f64 / total as f64
    }
}

/// Size-class index over free extents: class -> ordered
/// (padded len, segment, offset) candidates.
type Buckets = BTreeMap<u32, BTreeSet<(usize, usize, usize)>>;

/// All mutable state of one category, behind its own lock.
struct CatShard {
    cat: Cat,
    /// Segment slots (index-stable: trim leaves `None`).
    segments: Vec<Option<Segment>>,
    buckets: Buckets,
    pool: VecPool,
    wm: CatWatermark,
    /// Whether this category ever held arena state (gates
    /// [`PinnedArena::watermarks`], which reports touched cats only).
    touched: bool,
}

impl CatShard {
    fn new(cat: Cat) -> Self {
        Self {
            cat,
            segments: Vec::new(),
            buckets: BTreeMap::new(),
            pool: VecPool::default(),
            wm: CatWatermark::default(),
            touched: false,
        }
    }
}

fn bucket_insert(shard: &mut CatShard, len: usize, seg: usize, off: usize) {
    shard.buckets.entry(class_of(len)).or_default().insert((len, seg, off));
}

fn bucket_remove(shard: &mut CatShard, len: usize, seg: usize, off: usize) {
    let cls = class_of(len);
    if let Some(set) = shard.buckets.get_mut(&cls) {
        set.remove(&(len, seg, off));
        if set.is_empty() {
            shard.buckets.remove(&cls);
        }
    }
}

/// Return extent `[off, off+len)` of segment `seg_idx` to the free
/// state, coalescing with adjacent free extents (bucket entries of
/// merged neighbours are replaced by the merged extent's).
fn insert_free_extent(shard: &mut CatShard, seg_idx: usize, off: usize, len: usize) {
    let mut off = off;
    let mut len = len;
    let (pred, succ) = {
        let seg = shard.segments[seg_idx].as_ref().expect("segment present");
        let pred = seg.free.range(..off).next_back().map(|(&o, &l)| (o, l));
        let succ = seg.free.range(off..).next().map(|(&o, &l)| (o, l));
        (pred, succ)
    };
    if let Some((po, pl)) = pred {
        if po + pl == off {
            shard.segments[seg_idx].as_mut().unwrap().free.remove(&po);
            bucket_remove(shard, pl, seg_idx, po);
            off = po;
            len += pl;
        }
    }
    if let Some((so, sl)) = succ {
        if off + len == so {
            shard.segments[seg_idx].as_mut().unwrap().free.remove(&so);
            bucket_remove(shard, sl, seg_idx, so);
            len += sl;
        }
    }
    shard.segments[seg_idx].as_mut().unwrap().free.insert(off, len);
    bucket_insert(shard, len, seg_idx, off);
}

/// Take a free extent that fits `padded` bytes via the size-class
/// buckets: smallest fitting extent of the request's own class, else
/// the smallest extent of the next non-empty class up.  Splits the
/// remainder back into its class.  Returns (segment, offset).
fn take_fit(shard: &mut CatShard, padded: usize) -> Option<(usize, usize)> {
    let want = class_of(padded);
    let mut found: Option<(usize, usize, usize)> = None; // (len, seg, off)
    for (&cls, set) in shard.buckets.range(want..) {
        let cand = if cls == want {
            // same class: lengths straddle `padded`; take the smallest
            // that still fits
            set.range((padded, 0, 0)..).next()
        } else {
            // higher class: everything fits; smallest is best-fit
            set.iter().next()
        };
        if let Some(&(len, seg, off)) = cand {
            found = Some((len, seg, off));
            break;
        }
    }
    let (elen, seg_idx, eoff) = found?;
    bucket_remove(shard, elen, seg_idx, eoff);
    {
        let seg = shard.segments[seg_idx].as_mut().expect("bucketed segment present");
        seg.free.remove(&eoff);
        seg.live += 1;
    }
    if elen > padded {
        // the remainder cannot touch another free extent (it was part
        // of one coalesced extent), so no coalescing pass is needed
        shard.segments[seg_idx]
            .as_mut()
            .unwrap()
            .free
            .insert(eoff + padded, elen - padded);
        bucket_insert(shard, elen - padded, seg_idx, eoff + padded);
    }
    Some((seg_idx, eoff))
}

/// Quota/borrow state of one namespace (admission control).  `used`
/// is the live *padded* lease demand admitted against the quota; it
/// falls on every release, unlike the reserved-byte attribution in
/// [`NsCounters`] which mirrors the global cache-retaining ledger.
#[derive(Default)]
struct NsQuota {
    /// Fair-share byte cap on live leased demand (`None` = unlimited —
    /// the host default, and bit-for-bit the pre-tenancy behavior).
    quota: Option<usize>,
    used: usize,
    /// Bytes currently taken from the shared headroom pool beyond the
    /// quota.  Repaid automatically as `used` falls back under quota.
    borrowed: usize,
    /// Revoked namespaces may not take *new* headroom; existing
    /// borrows drain as leases release (a revocation never aborts
    /// in-flight work — refusal degrades like any `BudgetExceeded`).
    revoked: bool,
}

/// The shared borrowable headroom pool namespaces may burst into.
#[derive(Default)]
struct Headroom {
    total: usize,
    borrowed: usize,
}

/// Per-namespace mirror of the global service counters, all atomic
/// (updated next to their global twins, same quantities), so a noisy
/// or leaky tenant is identifiable without locks.
#[derive(Default)]
struct NsCounters {
    /// Reserved-byte attribution: fresh-segment reserves + pooled
    /// scratch charged by this namespace, minus trims/evictions of
    /// state it pinned.  Summed over namespaces this equals
    /// [`ArenaStats::reserved_bytes`] bit-for-bit.
    charged: AtomicUsize,
    charged_peak: AtomicUsize,
    requested: AtomicUsize,
    requested_peak: AtomicUsize,
    leases: AtomicU64,
    releases: AtomicU64,
    recycled: AtomicU64,
    recycle_misses: AtomicU64,
    fresh_segments: AtomicU64,
}

/// Snapshot of one namespace: admission state + service counters
/// ([`PinnedArena::ns_stats`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct NsStats {
    pub quota: Option<usize>,
    /// Live padded lease demand admitted against the quota.
    pub used: usize,
    pub borrowed: usize,
    pub revoked: bool,
    /// Reserved-byte attribution (see [`ArenaStats::reserved_bytes`]:
    /// the per-namespace shares sum to it exactly).
    pub charged: usize,
    pub charged_peak: usize,
    pub requested: usize,
    pub requested_peak: usize,
    pub leases: u64,
    pub releases: u64,
    pub recycled: u64,
    pub recycle_misses: u64,
    pub fresh_segments: u64,
}

impl NsStats {
    /// 1 − live-need / charged attribution (a per-tenant
    /// [`ArenaStats::fragmentation`]).
    pub fn fragmentation(&self) -> f64 {
        if self.charged == 0 {
            return 0.0;
        }
        1.0 - self.requested as f64 / self.charged as f64
    }

    /// Fraction of this namespace's leases served from the free list.
    pub fn recycle_hit_rate(&self) -> f64 {
        let total = self.recycled + self.recycle_misses;
        if total == 0 {
            return 0.0;
        }
        self.recycled as f64 / total as f64
    }
}

/// Why a namespace refused a lease (mapped to
/// [`ArenaError::BudgetExceeded`] at the public surface).
struct NsRefusal {
    used: usize,
    allowed: usize,
}

struct Inner {
    alloc: Arc<dyn HostAllocator>,
    tracker: Arc<MemoryTracker>,
    cfg: ArenaConfig,
    /// Global reserve ledger: the budget is enforced here by CAS
    /// reservation, so shards never serialize on each other.
    reserved: AtomicUsize,
    peak_reserved: AtomicUsize,
    requested: AtomicUsize,
    peak_requested: AtomicUsize,
    leases: AtomicU64,
    releases: AtomicU64,
    recycled: AtomicU64,
    recycle_misses: AtomicU64,
    fresh_segments: AtomicU64,
    shards: [Mutex<CatShard>; N_CATS],
    /// Per-namespace admission state (lock order: ns_quota before
    /// headroom; never held across a shard lock acquisition).
    ns_quota: [Mutex<NsQuota>; MAX_NAMESPACES],
    headroom: Mutex<Headroom>,
    ns_counters: [NsCounters; MAX_NAMESPACES],
}

impl Inner {
    fn shard(&self, cat: Cat) -> &Mutex<CatShard> {
        &self.shards[cat_index(cat)]
    }

    /// Atomically reserve `bytes` against the budget; false when the
    /// cap would be exceeded (caller trims and retries, or refuses).
    fn try_reserve(&self, bytes: usize) -> bool {
        loop {
            let cur = self.reserved.load(Ordering::Relaxed);
            if let Some(budget) = self.cfg.budget_bytes {
                if cur + bytes > budget {
                    return false;
                }
            }
            if self
                .reserved
                .compare_exchange(cur, cur + bytes, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                self.peak_reserved.fetch_max(cur + bytes, Ordering::Relaxed);
                return true;
            }
        }
    }

    fn note_lease(&self, shard: &mut CatShard, bytes: usize, ns: usize) {
        shard.touched = true;
        self.leases.fetch_add(1, Ordering::Relaxed);
        let now = self.requested.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak_requested.fetch_max(now, Ordering::Relaxed);
        shard.wm.requested += bytes;
        shard.wm.requested_peak = shard.wm.requested_peak.max(shard.wm.requested);
        let nc = &self.ns_counters[ns];
        nc.leases.fetch_add(1, Ordering::Relaxed);
        let now = nc.requested.fetch_add(bytes, Ordering::Relaxed) + bytes;
        nc.requested_peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Attribute `bytes` of fresh reserve to namespace `ns` (the
    /// per-namespace twin of the global `reserved` bookkeeping).
    fn ns_charge(&self, ns: usize, bytes: usize) {
        let nc = &self.ns_counters[ns];
        let now = nc.charged.fetch_add(bytes, Ordering::Relaxed) + bytes;
        nc.charged_peak.fetch_max(now, Ordering::Relaxed);
    }

    fn ns_uncharge(&self, ns: usize, bytes: usize) {
        self.ns_counters[ns].charged.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Admit `padded` bytes of new lease demand against namespace
    /// `ns`'s quota, borrowing from the shared headroom pool when the
    /// quota alone does not cover it.  `Err` carries the refusal
    /// context; quota-less namespaces (the host default) always admit.
    fn ns_admit(&self, ns: usize, padded: usize) -> Result<(), NsRefusal> {
        let mut q = self.ns_quota[ns].lock().unwrap();
        let new_used = q.used + padded;
        if let Some(quota) = q.quota {
            let need = new_used.saturating_sub(quota);
            if need > q.borrowed {
                let delta = need - q.borrowed;
                let granted = !q.revoked && {
                    let mut h = self.headroom.lock().unwrap();
                    if h.borrowed + delta <= h.total {
                        h.borrowed += delta;
                        true
                    } else {
                        false
                    }
                };
                if !granted {
                    let avail = if q.revoked {
                        0
                    } else {
                        let h = self.headroom.lock().unwrap();
                        h.total.saturating_sub(h.borrowed)
                    };
                    return Err(NsRefusal {
                        used: q.used,
                        allowed: quota + q.borrowed + avail,
                    });
                }
                q.borrowed = need;
            }
        }
        q.used = new_used;
        Ok(())
    }

    /// Return `padded` bytes of lease demand to namespace `ns`,
    /// repaying any headroom borrow the lower demand no longer needs.
    fn ns_release_demand(&self, ns: usize, padded: usize) {
        let mut q = self.ns_quota[ns].lock().unwrap();
        q.used = q.used.saturating_sub(padded);
        repay_excess_borrow(&mut q, &self.headroom);
    }
}

/// Repay whatever part of `q.borrowed` the current demand no longer
/// justifies (all of it when the quota was lifted).
fn repay_excess_borrow(q: &mut NsQuota, headroom: &Mutex<Headroom>) {
    let need = match q.quota {
        Some(quota) => q.used.saturating_sub(quota),
        None => 0,
    };
    if q.borrowed > need {
        let repay = q.borrowed - need;
        q.borrowed = need;
        let mut h = headroom.lock().unwrap();
        h.borrowed = h.borrowed.saturating_sub(repay);
    }
}

/// The budget-enforced lease layer. Cheap to share as `Arc<PinnedArena>`.
///
/// A `PinnedArena` value is a *namespace view*: all views made by
/// [`PinnedArena::namespace`] share one `Inner` (one budget, one free
/// list, one ledger), but leases and pooled scratch taken through a
/// view are admitted against — and attributed to — that view's
/// namespace.  The root view is namespace 0 (no quota).
pub struct PinnedArena {
    inner: Arc<Inner>,
    ns: usize,
}

/// RAII view of an (offset, len) span inside one arena segment.
/// Dropping it returns the extent for reuse.
pub struct Lease {
    inner: Arc<Inner>,
    cat: Cat,
    ns: usize,
    seg: usize,
    offset: usize,
    padded: usize,
    requested: usize,
    /// Segment base (null in Virtual mode).
    base: *mut u8,
}

// SAFETY: a lease has exclusive ownership of its [offset, offset+padded)
// span — the extent allocator never hands out overlapping ranges — and
// the backing segment outlives it (`inner` is kept alive and segments
// with `live > 0` are never trimmed).  `&self` access is read-only.
unsafe impl Send for Lease {}
unsafe impl Sync for Lease {}

impl Lease {
    pub fn cat(&self) -> Cat {
        self.cat
    }

    /// Bytes the caller asked for (the visible span).
    pub fn bytes_requested(&self) -> usize {
        self.requested
    }

    /// Page-padded bytes the lease occupies inside its segment.
    pub fn bytes_padded(&self) -> usize {
        self.padded
    }

    pub fn is_virtual(&self) -> bool {
        self.base.is_null()
    }

    pub fn as_slice(&self) -> &[u8] {
        if self.base.is_null() {
            return &[];
        }
        // SAFETY: see the Send/Sync justification above.
        unsafe { std::slice::from_raw_parts(self.base.add(self.offset), self.requested) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        if self.base.is_null() {
            return &mut [];
        }
        // SAFETY: exclusive (&mut self) access to an exclusive span.
        unsafe { std::slice::from_raw_parts_mut(self.base.add(self.offset), self.requested) }
    }

    /// Raw base of the leased span (null in Virtual mode), for owners
    /// that carve the span into *disjoint* sub-buffers with their own
    /// exclusivity discipline (the parameter pools' slot free-lists).
    /// Deliberately not a `&mut` borrow: concurrent writers of
    /// disjoint sub-ranges must not require aliasing whole-span
    /// borrows.  Every write through it must stay inside a sub-range
    /// the caller exclusively owns.
    pub(crate) fn span_base(&self) -> *mut u8 {
        if self.base.is_null() {
            return std::ptr::null_mut();
        }
        // SAFETY: offset is in bounds of the segment (established at
        // lease time); only pointer arithmetic happens here.
        unsafe { self.base.add(self.offset) }
    }

    /// f32 view of the span (requires a multiple-of-4 request; the
    /// 4096-aligned base + page-aligned offset guarantee alignment).
    pub fn as_f32(&self) -> &[f32] {
        if self.base.is_null() {
            return &[];
        }
        debug_assert_eq!(self.requested % 4, 0, "f32 view of a non-f32-sized lease");
        // SAFETY: aligned (base and offset are 4096-multiples), in
        // bounds, exclusive span.
        unsafe {
            std::slice::from_raw_parts(
                self.base.add(self.offset).cast::<f32>(),
                self.requested / 4,
            )
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        if self.base.is_null() {
            return &mut [];
        }
        debug_assert_eq!(self.requested % 4, 0, "f32 view of a non-f32-sized lease");
        // SAFETY: as above, plus &mut self exclusivity.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.base.add(self.offset).cast::<f32>(),
                self.requested / 4,
            )
        }
    }

    /// f32 elements the span holds (0 in Virtual mode).
    pub fn len_f32(&self) -> usize {
        if self.base.is_null() {
            return 0;
        }
        self.requested / 4
    }

    /// Freeze the lease into a shared **read-only** handle.
    ///
    /// This is the fill-then-freeze contract of the zero-copy PJRT
    /// boundary: a producer fills the span through `as_f32_mut`
    /// (unique ownership), then freezes it so any number of
    /// [`crate::runtime::TensorBuf`] views can alias disjoint or
    /// overlapping sub-ranges concurrently.  Mutation is impossible
    /// while views exist — `as_mut_slice`/`as_f32_mut` need `&mut
    /// Lease`, which an `Arc` only yields back to a sole owner — and
    /// the extent returns to the free list when the last clone drops.
    pub fn into_shared(self) -> Arc<Lease> {
        Arc::new(self)
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        let mut shard = self.inner.shards[cat_index(self.cat)].lock().unwrap();
        {
            let seg = shard.segments[self.seg]
                .as_mut()
                .expect("lease outlived its segment");
            seg.live -= 1;
        }
        insert_free_extent(&mut shard, self.seg, self.offset, self.padded);
        shard.wm.requested -= self.requested;
        drop(shard);
        self.inner.requested.fetch_sub(self.requested, Ordering::Relaxed);
        self.inner.releases.fetch_add(1, Ordering::Relaxed);
        let nc = &self.inner.ns_counters[self.ns];
        nc.requested.fetch_sub(self.requested, Ordering::Relaxed);
        nc.releases.fetch_add(1, Ordering::Relaxed);
        self.inner.ns_release_demand(self.ns, self.padded);
    }
}

impl PinnedArena {
    pub fn new(alloc: Arc<dyn HostAllocator>, cfg: ArenaConfig) -> Arc<Self> {
        let tracker = Arc::clone(alloc.tracker());
        Arc::new(Self {
            inner: Arc::new(Inner {
                alloc,
                tracker,
                cfg,
                reserved: AtomicUsize::new(0),
                peak_reserved: AtomicUsize::new(0),
                requested: AtomicUsize::new(0),
                peak_requested: AtomicUsize::new(0),
                leases: AtomicU64::new(0),
                releases: AtomicU64::new(0),
                recycled: AtomicU64::new(0),
                recycle_misses: AtomicU64::new(0),
                fresh_segments: AtomicU64::new(0),
                shards: std::array::from_fn(|i| Mutex::new(CatShard::new(Cat::ALL[i]))),
                ns_quota: Default::default(),
                headroom: Mutex::new(Headroom::default()),
                ns_counters: Default::default(),
            }),
            ns: 0,
        })
    }

    // ---- namespaces ----------------------------------------------------

    /// A view of this arena scoped to namespace `ns` (clamped to
    /// [`MAX_NAMESPACES`]`- 1`).  Views share everything — budget, free
    /// lists, pools, ledger — but leases and scratch taken through a
    /// view are admitted against the namespace's quota and attributed
    /// to it in [`Self::ns_stats`].
    pub fn namespace(self: &Arc<Self>, ns: u32) -> Arc<PinnedArena> {
        Arc::new(PinnedArena {
            inner: Arc::clone(&self.inner),
            ns: (ns as usize).min(MAX_NAMESPACES - 1),
        })
    }

    /// The namespace this view admits against (0 = host default).
    pub fn ns(&self) -> usize {
        self.ns
    }

    /// Set (or lift, with `None`) a namespace's fair-share quota on
    /// live padded lease bytes.  Lowering a quota never aborts live
    /// leases; demand above the new quota is treated as a headroom
    /// borrow (repaid as leases release) and new demand is refused
    /// until the namespace drains back under its share.
    pub fn set_ns_quota(&self, ns: usize, quota: Option<usize>) {
        let ns = ns.min(MAX_NAMESPACES - 1);
        let mut q = self.inner.ns_quota[ns].lock().unwrap();
        q.quota = quota;
        // raising/lifting the quota may free borrows; lowering it does
        // NOT retroactively borrow (live demand above quota is simply
        // already admitted — only new demand needs headroom)
        repay_excess_borrow(&mut q, &self.inner.headroom);
    }

    /// Size the shared borrowable headroom pool.  Shrinking below the
    /// currently-borrowed amount blocks *new* borrows until existing
    /// ones drain; nothing is revoked retroactively.
    pub fn set_shared_headroom(&self, bytes: usize) {
        self.inner.headroom.lock().unwrap().total = bytes;
    }

    /// Revoke (or restore) a namespace's access to shared headroom.
    /// Existing borrows drain as leases release; only *new* borrows are
    /// refused — revocation degrades a tenant, never aborts it.
    pub fn set_ns_revoked(&self, ns: usize, revoked: bool) {
        let ns = ns.min(MAX_NAMESPACES - 1);
        self.inner.ns_quota[ns].lock().unwrap().revoked = revoked;
    }

    /// Snapshot one namespace's admission state and service counters.
    pub fn ns_stats(&self, ns: usize) -> NsStats {
        let ns = ns.min(MAX_NAMESPACES - 1);
        let inner = &self.inner;
        let (quota, used, borrowed, revoked) = {
            let q = inner.ns_quota[ns].lock().unwrap();
            (q.quota, q.used, q.borrowed, q.revoked)
        };
        let nc = &inner.ns_counters[ns];
        NsStats {
            quota,
            used,
            borrowed,
            revoked,
            charged: nc.charged.load(Ordering::Relaxed),
            charged_peak: nc.charged_peak.load(Ordering::Relaxed),
            requested: nc.requested.load(Ordering::Relaxed),
            requested_peak: nc.requested_peak.load(Ordering::Relaxed),
            leases: nc.leases.load(Ordering::Relaxed),
            releases: nc.releases.load(Ordering::Relaxed),
            recycled: nc.recycled.load(Ordering::Relaxed),
            recycle_misses: nc.recycle_misses.load(Ordering::Relaxed),
            fresh_segments: nc.fresh_segments.load(Ordering::Relaxed),
        }
    }

    /// Lease `bytes` under `cat`.  Served from the category's bucketed
    /// free list when an extent fits, else from a fresh exactly-sized
    /// segment — which is where the budget is enforced (atomic CAS
    /// reservation; only the category's own shard lock is held).
    ///
    /// Under a namespaced view the request is first admitted against
    /// the namespace's quota (+ borrowable headroom); a quota refusal
    /// surfaces as the same [`ArenaError::BudgetExceeded`] every caller
    /// already degrades on, with the namespace's own used/allowed
    /// figures in the `in_use`/`budget` slots.
    pub fn lease(&self, bytes: usize, cat: Cat) -> Result<Lease, ArenaError> {
        let padded = pad(bytes);
        if let Err(r) = self.inner.ns_admit(self.ns, padded) {
            return Err(ArenaError::BudgetExceeded {
                cat,
                requested: bytes,
                would_reserve: padded,
                in_use: r.used,
                budget: r.allowed,
            });
        }
        let out = self.lease_admitted(bytes, padded, cat);
        if out.is_err() {
            // global-budget refusal: hand the admitted demand back
            self.inner.ns_release_demand(self.ns, padded);
        }
        out
    }

    /// The pre-tenancy lease body; namespace demand is already admitted.
    fn lease_admitted(&self, bytes: usize, padded: usize, cat: Cat) -> Result<Lease, ArenaError> {
        let inner = &self.inner;

        // fast path: bucketed recycle inside this category's shard
        {
            let mut shard = inner.shard(cat).lock().unwrap();
            if let Some((seg, offset)) = take_fit(&mut shard, padded) {
                let base = shard.segments[seg].as_ref().unwrap().base;
                inner.recycled.fetch_add(1, Ordering::Relaxed);
                inner.ns_counters[self.ns].recycled.fetch_add(1, Ordering::Relaxed);
                inner.note_lease(&mut shard, bytes, self.ns);
                return Ok(Lease {
                    inner: Arc::clone(inner),
                    cat,
                    ns: self.ns,
                    seg,
                    offset,
                    padded,
                    requested: bytes,
                    base,
                });
            }
        }

        // miss: fresh segment, exactly sized to this request
        inner.recycle_misses.fetch_add(1, Ordering::Relaxed);
        inner.ns_counters[self.ns].recycle_misses.fetch_add(1, Ordering::Relaxed);
        let would_reserve = inner.alloc.reserve_size(padded);
        if let Some(budget) = inner.cfg.budget_bytes {
            // a request that can never fit must not wipe warm caches
            if would_reserve > budget {
                return Err(ArenaError::BudgetExceeded {
                    cat,
                    requested: bytes,
                    would_reserve,
                    in_use: inner.reserved.load(Ordering::Relaxed),
                    budget,
                });
            }
        }
        if !inner.try_reserve(would_reserve) {
            let budget = inner.cfg.budget_bytes.expect("reserve only fails under a budget");
            // targeted: free idle capacity only until this fits
            trim_until(inner, budget.saturating_sub(would_reserve));
            if !inner.try_reserve(would_reserve) {
                return Err(ArenaError::BudgetExceeded {
                    cat,
                    requested: bytes,
                    would_reserve,
                    in_use: inner.reserved.load(Ordering::Relaxed),
                    budget,
                });
            }
        }
        // the pin itself runs outside every lock
        let region = inner.alloc.alloc(padded, cat);
        let actual = region.bytes_reserved;
        // `reserve_size` is the policy's declared worst case and the
        // budget CAS reserved exactly that; an allocator reserving
        // *more* than its own prediction would silently pierce the cap,
        // so that is a policy bug, not something to book after the fact
        assert!(
            actual <= would_reserve,
            "allocator reserved {actual} B for a {padded} B segment, above its \
             own reserve_size prediction of {would_reserve} B"
        );
        if actual < would_reserve {
            inner.reserved.fetch_sub(would_reserve - actual, Ordering::Relaxed);
        }
        let base = region.raw_base();
        inner.fresh_segments.fetch_add(1, Ordering::Relaxed);
        inner.ns_counters[self.ns].fresh_segments.fetch_add(1, Ordering::Relaxed);
        inner.ns_charge(self.ns, actual);

        let mut shard = inner.shard(cat).lock().unwrap();
        let seg =
            Segment { region, base, len: padded, free: BTreeMap::new(), live: 1, ns: self.ns };
        let si = match shard.segments.iter().position(|s| s.is_none()) {
            Some(i) => i,
            None => {
                shard.segments.push(None);
                shard.segments.len() - 1
            }
        };
        shard.segments[si] = Some(seg);
        shard.wm.charged += padded;
        shard.wm.charged_peak = shard.wm.charged_peak.max(shard.wm.charged);
        inner.note_lease(&mut shard, bytes, self.ns);
        Ok(Lease {
            inner: Arc::clone(inner),
            cat,
            ns: self.ns,
            seg: si,
            offset: 0,
            padded,
            requested: bytes,
            base,
        })
    }

    /// Drop all idle capacity: fully-free segments go back to the
    /// allocator (when the policy reclaims frees) and pooled scratch
    /// vectors are released.
    pub fn trim(&self) {
        trim_until(&self.inner, 0);
    }

    // ---- scratch-vector tier -------------------------------------------

    /// Take an f32 vector of exactly `n` elements, recycled best-fit
    /// from the category's pool when possible.  Handing a vector out
    /// un-charges it from the ledger (it becomes transient compute
    /// memory until [`Self::put_f32`] returns it).
    pub fn take_f32(&self, n: usize, cat: Cat) -> Vec<f32> {
        let inner = &self.inner;
        let mut shard = inner.shard(cat).lock().unwrap();
        let taken = {
            let pool = &mut shard.pool;
            let mut best: Option<(usize, usize)> = None; // (index, capacity)
            for (i, (v, _)) in pool.f32s.iter().enumerate() {
                let c = v.capacity();
                if c >= n && best.is_none_or(|(_, bc)| c < bc) {
                    best = Some((i, c));
                }
            }
            best.map(|(i, c)| {
                let (v, ns) = pool.f32s.swap_remove(i);
                (v, c * 4, ns)
            })
        };
        match taken {
            Some((mut v, bytes, ns)) => {
                uncharge_pooled(inner, &mut shard, bytes, ns);
                drop(shard);
                v.clear();
                v.resize(n, 0.0);
                v
            }
            None => {
                drop(shard);
                vec![0f32; n]
            }
        }
    }

    /// Return a spent f32 vector to the category's pool.  Dropped
    /// (not pooled) when below the size floor, past the pool bounds,
    /// or when pooling it would exceed the budget.
    pub fn put_f32(&self, v: Vec<f32>, cat: Cat) {
        let bytes = v.capacity() * 4;
        let inner = &self.inner;
        if bytes < inner.cfg.min_pooled_vec_bytes {
            return;
        }
        let mut shard = inner.shard(cat).lock().unwrap();
        if !pool_admits(inner, &shard, bytes) || !inner.try_reserve(bytes) {
            return; // bounds or budget: the vector is simply dropped
        }
        shard.pool.f32s.push((v, self.ns));
        charge_pooled(inner, &mut shard, bytes, self.ns);
    }

    /// [`Self::take_f32`] for byte buffers.
    pub fn take_bytes(&self, n: usize, cat: Cat) -> Vec<u8> {
        let inner = &self.inner;
        let mut shard = inner.shard(cat).lock().unwrap();
        let taken = {
            let pool = &mut shard.pool;
            let mut best: Option<(usize, usize)> = None;
            for (i, (v, _)) in pool.bytes.iter().enumerate() {
                let c = v.capacity();
                if c >= n && best.is_none_or(|(_, bc)| c < bc) {
                    best = Some((i, c));
                }
            }
            best.map(|(i, c)| {
                let (v, ns) = pool.bytes.swap_remove(i);
                (v, c, ns)
            })
        };
        match taken {
            Some((mut v, bytes, ns)) => {
                uncharge_pooled(inner, &mut shard, bytes, ns);
                drop(shard);
                v.clear();
                v.resize(n, 0);
                v
            }
            None => {
                drop(shard);
                vec![0u8; n]
            }
        }
    }

    /// [`Self::put_f32`] for byte buffers.
    pub fn put_bytes(&self, v: Vec<u8>, cat: Cat) {
        let bytes = v.capacity();
        let inner = &self.inner;
        if bytes < inner.cfg.min_pooled_vec_bytes {
            return;
        }
        let mut shard = inner.shard(cat).lock().unwrap();
        if !pool_admits(inner, &shard, bytes) || !inner.try_reserve(bytes) {
            return;
        }
        shard.pool.bytes.push((v, self.ns));
        charge_pooled(inner, &mut shard, bytes, self.ns);
    }

    /// Idle f32 vectors pooled under `cat` (test/introspection hook).
    pub fn pooled_f32(&self, cat: Cat) -> usize {
        self.inner.shard(cat).lock().unwrap().pool.f32s.len()
    }

    /// Idle byte vectors pooled under `cat`.
    pub fn pooled_byte_vecs(&self, cat: Cat) -> usize {
        self.inner.shard(cat).lock().unwrap().pool.bytes.len()
    }

    // ---- introspection -------------------------------------------------

    pub fn stats(&self) -> ArenaStats {
        let inner = &self.inner;
        ArenaStats {
            reserved_bytes: inner.reserved.load(Ordering::Relaxed),
            peak_reserved: inner.peak_reserved.load(Ordering::Relaxed),
            requested_bytes: inner.requested.load(Ordering::Relaxed),
            peak_requested: inner.peak_requested.load(Ordering::Relaxed),
            leases: inner.leases.load(Ordering::Relaxed),
            releases: inner.releases.load(Ordering::Relaxed),
            recycled: inner.recycled.load(Ordering::Relaxed),
            recycle_misses: inner.recycle_misses.load(Ordering::Relaxed),
            fresh_segments: inner.fresh_segments.load(Ordering::Relaxed),
        }
    }

    pub fn watermark(&self, cat: Cat) -> CatWatermark {
        self.inner.shard(cat).lock().unwrap().wm
    }

    /// Per-category watermarks for every category the arena touched.
    pub fn watermarks(&self) -> Vec<(Cat, CatWatermark)> {
        Cat::ALL
            .iter()
            .filter_map(|c| {
                let shard = self.inner.shard(*c).lock().unwrap();
                shard.touched.then_some((*c, shard.wm))
            })
            .collect()
    }

    pub fn budget_bytes(&self) -> Option<usize> {
        self.inner.cfg.budget_bytes
    }

    pub fn tracker(&self) -> &Arc<MemoryTracker> {
        &self.inner.tracker
    }
}

/// Per-cat pool bounds (count + idle bytes).  The budget itself is
/// enforced separately by the caller's `try_reserve`.
fn pool_admits(inner: &Inner, shard: &CatShard, bytes: usize) -> bool {
    let pool = &shard.pool;
    pool.f32s.len() + pool.bytes.len() < inner.cfg.max_pooled_vecs
        && pool.pooled_bytes + bytes <= inner.cfg.max_pooled_vec_bytes
}

/// Book a freshly-pooled vector (budget already reserved by the
/// caller's `try_reserve`) and attribute it to namespace `ns`.
fn charge_pooled(inner: &Inner, shard: &mut CatShard, bytes: usize, ns: usize) {
    shard.touched = true;
    shard.pool.pooled_bytes += bytes;
    shard.wm.charged += bytes;
    shard.wm.charged_peak = shard.wm.charged_peak.max(shard.wm.charged);
    inner.tracker.alloc(shard.cat, bytes as u64);
    inner.ns_charge(ns, bytes);
}

fn uncharge_pooled(inner: &Inner, shard: &mut CatShard, bytes: usize, ns: usize) {
    shard.pool.pooled_bytes -= bytes;
    shard.wm.charged -= bytes;
    inner.tracker.free(shard.cat, bytes as u64);
    inner.reserved.fetch_sub(bytes, Ordering::Relaxed);
    inner.ns_uncharge(ns, bytes);
}

/// Free idle capacity until `reserved <= target`, stopping as soon as
/// the target is met (pass 0 for a full trim).  Fully-idle segments go
/// first — but only when the allocator actually reclaims frees; under
/// the pow2-caching policy freed blocks would just move to the
/// allocator's cache while staying on the ledger, so segments are kept
/// and the arena's watermarks remain an exact ledger mirror (and the
/// budget correctly reflects that the reserve is monotone there).
/// Pooled scratch vectors (arena-charged, always reversible) go
/// second.  Shard locks are taken one category at a time — callers
/// hold no shard lock while trimming.
fn trim_until(inner: &Inner, target: usize) {
    if inner.alloc.reclaimable() {
        for shard_mx in &inner.shards {
            if inner.reserved.load(Ordering::Relaxed) <= target {
                return;
            }
            let mut shard = shard_mx.lock().unwrap();
            for i in 0..shard.segments.len() {
                if inner.reserved.load(Ordering::Relaxed) <= target {
                    return;
                }
                let idle = matches!(&shard.segments[i], Some(s) if s.live == 0);
                if !idle {
                    continue;
                }
                let seg = shard.segments[i].take().expect("idle segment present");
                let frees: Vec<(usize, usize)> =
                    seg.free.iter().map(|(&o, &l)| (o, l)).collect();
                for (o, l) in frees {
                    bucket_remove(&mut shard, l, i, o);
                }
                inner.reserved.fetch_sub(seg.region.bytes_reserved, Ordering::Relaxed);
                inner.ns_uncharge(seg.ns, seg.region.bytes_reserved);
                shard.wm.charged -= seg.len;
                // seg drops here: the region's release hook un-charges
                // the ledger
            }
        }
    }
    for shard_mx in &inner.shards {
        if inner.reserved.load(Ordering::Relaxed) <= target {
            return;
        }
        let mut shard = shard_mx.lock().unwrap();
        loop {
            if inner.reserved.load(Ordering::Relaxed) <= target {
                return;
            }
            // evict one vector at a time, largest first, so a small
            // overshoot does not wipe a warm pool
            let freed = {
                let pool = &mut shard.pool;
                let f = pool
                    .f32s
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, (v, _))| v.capacity())
                    .map(|(i, (v, ns))| (i, v.capacity() * 4, *ns));
                let b = pool
                    .bytes
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, (v, _))| v.capacity())
                    .map(|(i, (v, ns))| (i, v.capacity(), *ns));
                match (f, b) {
                    (Some((i, fb, fns)), Some((j, bb, bns))) => {
                        if fb >= bb {
                            pool.f32s.swap_remove(i);
                            (fb, fns)
                        } else {
                            pool.bytes.swap_remove(j);
                            (bb, bns)
                        }
                    }
                    (Some((i, fb, fns)), None) => {
                        pool.f32s.swap_remove(i);
                        (fb, fns)
                    }
                    (None, Some((j, bb, bns))) => {
                        pool.bytes.swap_remove(j);
                        (bb, bns)
                    }
                    (None, None) => break,
                }
            };
            uncharge_pooled(inner, &mut shard, freed.0, freed.1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pinned::{AlignedAllocator, CachingAllocator, Mode};
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};

    fn arena(mode: Mode, budget: Option<usize>) -> Arc<PinnedArena> {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(mode, tracker);
        PinnedArena::new(
            Arc::new(alloc),
            ArenaConfig { budget_bytes: budget, ..Default::default() },
        )
    }

    #[test]
    fn lease_roundtrip_and_release() {
        let a = arena(Mode::Real, None);
        let mut l = a.lease(10_000, Cat::GradFlat).unwrap();
        assert_eq!(l.bytes_requested(), 10_000);
        assert_eq!(l.as_slice().len(), 10_000);
        l.as_mut_slice()[9_999] = 7;
        assert_eq!(l.as_slice()[9_999], 7);
        let st = a.stats();
        assert_eq!(st.requested_bytes, 10_000);
        assert_eq!(st.fresh_segments, 1);
        drop(l);
        let st = a.stats();
        assert_eq!(st.requested_bytes, 0);
        // the segment stays cached for recycling until trim
        assert!(st.reserved_bytes >= 10_000);
        a.trim();
        assert_eq!(a.stats().reserved_bytes, 0);
        assert_eq!(a.tracker().current_total(), 0);
    }

    #[test]
    fn freed_extents_recycle_without_fresh_pins() {
        let a = arena(Mode::Real, None);
        let l1 = a.lease(8192, Cat::ParamPool).unwrap();
        drop(l1);
        let _l2 = a.lease(4096, Cat::ParamPool).unwrap();
        let _l3 = a.lease(4096, Cat::ParamPool).unwrap();
        let st = a.stats();
        assert_eq!(st.fresh_segments, 1, "both re-leases must carve the freed segment");
        assert_eq!(st.recycled, 2);
        assert_eq!(st.recycle_misses, 1, "only the first lease missed the free list");
    }

    #[test]
    fn size_class_buckets_serve_mixed_tile_and_tail_leases() {
        // tile-pipeline shape: one big freed region, then a mixed
        // stream of tile + tail sizes — every one must hit the free
        // list (no fresh pins), across classes
        let a = arena(Mode::Real, None);
        drop(a.lease(1 << 20, Cat::OptimBuf).unwrap());
        let sizes = [64 << 10, 17_000, 64 << 10, 4096, 120_000, 300, 64 << 10];
        let mut live = Vec::new();
        for (i, n) in sizes.iter().enumerate() {
            live.push(a.lease(*n, Cat::OptimBuf).unwrap());
            if i % 3 == 2 {
                live.remove(0); // interleave releases
            }
        }
        let st = a.stats();
        assert_eq!(st.fresh_segments, 1, "bucketed free list missed");
        assert_eq!(st.recycle_misses, 1);
        assert_eq!(st.recycled, sizes.len() as u64);
        assert!(st.recycle_hit_rate() > 0.8);
        drop(live);
        // coalescing restored one whole free extent: a full-size lease
        // still fits without a fresh pin
        let _big = a.lease(1 << 20, Cat::OptimBuf).unwrap();
        assert_eq!(a.stats().fresh_segments, 1, "coalescing failed");
    }

    #[test]
    fn shards_keep_categories_independent_under_concurrency() {
        // different categories on different threads: stats must stay
        // exact (the global ledger is atomic, shards never share locks)
        let a = arena(Mode::Real, None);
        let cats = [Cat::ParamPool, Cat::OptimBuf, Cat::SwapBuf, Cat::GradFlat];
        std::thread::scope(|s| {
            for (t, cat) in cats.into_iter().enumerate() {
                let a = Arc::clone(&a);
                s.spawn(move || {
                    for round in 0..60usize {
                        let n = 2048 + (t * 977 + round * 131) % 9000;
                        let mut l = a.lease(n, cat).unwrap();
                        l.as_mut_slice().fill(t as u8);
                        assert!(l.as_slice().iter().all(|&b| b == t as u8));
                        drop(l);
                        let v = a.take_f32(n / 4, cat);
                        a.put_f32(v, cat);
                    }
                });
            }
        });
        let st = a.stats();
        assert_eq!(st.requested_bytes, 0);
        assert_eq!(st.leases, st.releases);
        assert_eq!(st.leases, (cats.len() * 60) as u64);
        for cat in cats {
            let wm = a.watermark(cat);
            assert_eq!(wm.requested, 0, "{cat:?} leaked requested bytes");
        }
    }

    #[test]
    fn f32_view_is_aligned_and_writable() {
        let a = arena(Mode::Real, None);
        let mut l = a.lease(1024 * 4, Cat::OptimBuf).unwrap();
        assert_eq!(l.as_f32().len(), 1024);
        assert_eq!(l.as_f32().as_ptr() as usize % 4, 0);
        l.as_f32_mut()[1023] = 1.5;
        assert_eq!(l.as_f32()[1023], 1.5);
        // the raw-byte view sees the same memory
        assert_eq!(&l.as_slice()[1023 * 4..1024 * 4], 1.5f32.to_le_bytes());
    }

    #[test]
    fn budget_cap_returns_structured_error() {
        let a = arena(Mode::Virtual, Some(1 << 20));
        let l1 = a.lease(512 << 10, Cat::ActCkpt).unwrap();
        let err = a.lease(1 << 20, Cat::ActCkpt).unwrap_err();
        match err {
            ArenaError::BudgetExceeded { cat, requested, budget, .. } => {
                assert_eq!(cat, Cat::ActCkpt);
                assert_eq!(requested, 1 << 20);
                assert_eq!(budget, 1 << 20);
            }
        }
        // releasing + implicit trim makes room again
        drop(l1);
        assert!(a.lease(1 << 20, Cat::ActCkpt).is_ok());
    }

    #[test]
    fn budget_counts_policy_overhead_under_pow2_allocator() {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = CachingAllocator::new(Mode::Virtual, tracker);
        let a = PinnedArena::new(
            Arc::new(alloc),
            ArenaConfig { budget_bytes: Some(3 << 20), ..Default::default() },
        );
        // 1.5 MiB request reserves 2 MiB under pow2; a second one would
        // need 4 MiB total — over the 3 MiB cap.
        let _l = a.lease((3 << 20) / 2, Cat::ParamPool).unwrap();
        assert!(matches!(
            a.lease((3 << 20) / 2, Cat::ParamPool),
            Err(ArenaError::BudgetExceeded { .. })
        ));
    }

    #[test]
    fn pow2_policy_segments_survive_trim_keeping_ledger_mirror() {
        // the caching policy's reserve is monotone: trimming must keep
        // segments (freeing them would only move bytes into the
        // allocator cache while the ledger stays charged — the
        // watermark/ledger mirror would silently break)
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = CachingAllocator::new(Mode::Virtual, tracker.clone());
        let a = PinnedArena::new(Arc::new(alloc), ArenaConfig::default());
        drop(a.lease(10_000, Cat::OptimBuf).unwrap());
        a.trim();
        assert!(a.stats().reserved_bytes > 0, "pow2 segment must be kept");
        assert_eq!(
            a.watermark(Cat::OptimBuf).charged as u64,
            tracker.current(Cat::OptimBuf)
        );
        // a re-lease recycles the kept segment — no fresh pin, and the
        // mirror still holds
        let _l2 = a.lease(8_000, Cat::OptimBuf).unwrap();
        assert_eq!(a.stats().fresh_segments, 1);
        assert_eq!(
            a.watermark(Cat::OptimBuf).charged as u64,
            tracker.current(Cat::OptimBuf)
        );
    }

    #[test]
    fn watermarks_match_ledger_bit_for_bit() {
        let a = arena(Mode::Virtual, None);
        let l1 = a.lease(123_456, Cat::GradFlat).unwrap();
        let l2 = a.lease(77_000, Cat::OptimBuf).unwrap();
        let l3 = a.lease(50_000, Cat::GradFlat).unwrap();
        drop(l3);
        drop(l2);
        for (cat, w) in a.watermarks() {
            assert_eq!(
                w.charged_peak as u64,
                a.tracker().peak(cat),
                "{cat:?} watermark diverged from the ledger"
            );
        }
        drop(l1);
    }

    #[test]
    fn concurrent_leases_never_overlap_in_memory() {
        // byte-pattern proof: every thread writes its own tag through
        // its lease and must read it back intact.
        let a = arena(Mode::Real, None);
        std::thread::scope(|s| {
            for tag in 0..8u8 {
                let a = Arc::clone(&a);
                s.spawn(move || {
                    for round in 0..50usize {
                        let n = 1000 + (tag as usize * 977 + round * 131) % 9000;
                        let mut l = a.lease(n, Cat::SwapBuf).unwrap();
                        l.as_mut_slice().fill(tag);
                        std::thread::yield_now();
                        assert!(
                            l.as_slice().iter().all(|&b| b == tag),
                            "lease memory trampled by a concurrent lease"
                        );
                    }
                });
            }
        });
        assert_eq!(a.stats().requested_bytes, 0);
    }

    #[test]
    fn prop_lease_release_matches_reference_model() {
        check("pinned-arena", Config { cases: 48, ..Default::default() }, |rng, size| {
            let budget = 64 * 4096;
            let a = arena(Mode::Virtual, Some(budget));
            // reference model: live (requested, padded) pairs
            let mut live: Vec<(Lease, usize)> = Vec::new();
            let mut model_requested = 0usize;
            for _ in 0..120 {
                if !live.is_empty() && rng.next_f64() < 0.45 {
                    let i = rng.below(live.len());
                    let (_, req) = live.swap_remove(i);
                    model_requested -= req;
                } else {
                    let bytes = rng.range(1, (size.max(2) * 16).min(budget));
                    match a.lease(bytes, Cat::Other) {
                        Ok(l) => {
                            live.push((l, bytes));
                            model_requested += bytes;
                        }
                        Err(ArenaError::BudgetExceeded { .. }) => {
                            // the refusal must be justified: even after
                            // the implicit trim, reserved state plus the
                            // new lease really exceeds the cap
                            let reserved = a.stats().reserved_bytes;
                            prop_assert!(
                                reserved + pad(bytes) > budget,
                                "budget refusal with only {reserved} B reserved \
                                 (+{bytes} B) under {budget} B cap"
                            );
                        }
                    }
                }
                let st = a.stats();
                prop_assert!(
                    st.requested_bytes == model_requested,
                    "requested ledger drift: {} vs model {}",
                    st.requested_bytes,
                    model_requested
                );
                prop_assert!(
                    st.reserved_bytes <= budget,
                    "reserved {} exceeds budget {}",
                    st.reserved_bytes,
                    budget
                );
                prop_assert!(
                    st.leases == st.releases + live.len() as u64,
                    "lease/release count drift"
                );
                // every granted lease was a free-list hit or a miss
                // (misses also count budget-refused attempts)
                prop_assert!(
                    st.recycled + st.recycle_misses >= st.leases,
                    "hit/miss counters lost a lease"
                );
                // no overlap between live leases (same-cat, same-segment
                // spans must be disjoint)
                for (i, (l1, _)) in live.iter().enumerate() {
                    for (l2, _) in live.iter().skip(i + 1) {
                        if l1.seg != l2.seg {
                            continue;
                        }
                        let disjoint = l1.offset + l1.padded <= l2.offset
                            || l2.offset + l2.padded <= l1.offset;
                        prop_assert!(
                            disjoint,
                            "leases overlap: [{}, {}) vs [{}, {})",
                            l1.offset,
                            l1.offset + l1.padded,
                            l2.offset,
                            l2.offset + l2.padded
                        );
                    }
                }
            }
            drop(live);
            prop_assert!(a.stats().requested_bytes == 0, "leak after drop");
            Ok(())
        });
    }

    #[test]
    fn scratch_recycles_best_fit() {
        let a = arena(Mode::Real, None);
        let v = a.take_f32(100, Cat::SwapBuf);
        a.put_f32(v, Cat::SwapBuf);
        assert_eq!(a.pooled_f32(Cat::SwapBuf), 1);
        // best-fit: a huge reclaimed buffer must not be pinned by a
        // small request when a smaller one fits
        a.put_f32(Vec::with_capacity(1_000_000), Cat::SwapBuf);
        let small = a.take_f32(80, Cat::SwapBuf);
        assert!(small.capacity() < 1_000_000);
        assert_eq!(small.len(), 80);
        assert_eq!(a.pooled_f32(Cat::SwapBuf), 1);
    }

    #[test]
    fn scratch_floor_and_byte_bound() {
        let a = arena(Mode::Real, None);
        for _ in 0..100 {
            a.put_f32(vec![0f32; 1], Cat::SwapBuf); // sub-floor: dropped
        }
        assert_eq!(a.pooled_f32(Cat::SwapBuf), 0);
        // 4 MiB each against the 64 MiB per-cat byte bound: ≤ 16 kept
        for _ in 0..20 {
            a.put_f32(Vec::with_capacity(1 << 20), Cat::SwapBuf);
        }
        assert!(a.pooled_f32(Cat::SwapBuf) <= 16);
    }

    #[test]
    fn scratch_pool_charges_ledger_and_respects_budget() {
        let a = arena(Mode::Real, Some(1 << 20));
        a.put_bytes(vec![0u8; 512 << 10], Cat::OptimBuf);
        assert_eq!(a.tracker().current(Cat::OptimBuf), 512 << 10);
        // pooling another 768 KiB would break the 1 MiB budget: dropped
        a.put_bytes(vec![0u8; 768 << 10], Cat::OptimBuf);
        assert_eq!(a.pooled_byte_vecs(Cat::OptimBuf), 1);
        // taking the pooled vector un-charges it
        let v = a.take_bytes(512 << 10, Cat::OptimBuf);
        assert_eq!(a.tracker().current(Cat::OptimBuf), 0);
        assert_eq!(v.len(), 512 << 10);
    }

    #[test]
    fn shared_lease_views_read_concurrently_and_recycle_on_last_drop() {
        // the zero-copy boundary's aliasing model: fill exclusively,
        // freeze, fan out read-only clones across threads, and only the
        // last drop returns the extent
        let a = arena(Mode::Real, None);
        let mut l = a.lease(4096 * 4, Cat::SwapBuf).unwrap();
        for (i, x) in l.as_f32_mut().iter_mut().enumerate() {
            *x = i as f32;
        }
        let shared = l.into_shared();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let view = Arc::clone(&shared);
                s.spawn(move || {
                    assert!(view.as_f32().iter().enumerate().all(|(i, &x)| x == i as f32));
                });
            }
        });
        assert_eq!(shared.len_f32(), 4096);
        let clone = Arc::clone(&shared);
        drop(shared);
        // still leased while any clone lives
        assert_eq!(a.stats().requested_bytes, 4096 * 4);
        drop(clone);
        assert_eq!(a.stats().requested_bytes, 0);
        // and the freed extent recycles without a fresh pin
        let _l2 = a.lease(4096 * 4, Cat::SwapBuf).unwrap();
        assert_eq!(a.stats().fresh_segments, 1);
    }

    /// Satellite: per-namespace reserved-byte attribution must mirror
    /// the global ledger exactly — Σ over namespaces of `charged` ==
    /// `ArenaStats::reserved_bytes`, bit-for-bit, through leases,
    /// cross-namespace recycling, pooled scratch, and trim.
    #[test]
    fn namespace_charges_sum_to_global_ledger_bit_for_bit() {
        let a = arena(Mode::Virtual, None);
        let j1 = a.namespace(1);
        let j2 = a.namespace(2);
        let check_sum = |tag: &str| {
            let sum: usize = (0..MAX_NAMESPACES).map(|n| a.ns_stats(n).charged).sum();
            assert_eq!(
                sum,
                a.stats().reserved_bytes,
                "{tag}: ns attribution drifted from the global ledger"
            );
        };
        let l1 = j1.lease(100_000, Cat::GradFlat).unwrap();
        let l2 = j2.lease(50_000, Cat::GradFlat).unwrap();
        let l0 = a.lease(10_000, Cat::OptimBuf).unwrap();
        check_sum("after leases");
        assert_eq!(a.ns_stats(1).leases, 1);
        assert_eq!(a.ns_stats(2).leases, 1);
        // pooled scratch is charged to its putter...
        j1.put_f32(vec![0f32; 4096], Cat::SwapBuf);
        j2.put_bytes(vec![0u8; 8192], Cat::SwapBuf);
        check_sum("after pool puts");
        let j1_charged = a.ns_stats(1).charged;
        // ...and un-charged from the *tagged* namespace even when a
        // different tenant takes it
        let v = j2.take_f32(4096, Cat::SwapBuf);
        assert_eq!(a.ns_stats(1).charged, j1_charged - 4096 * 4);
        check_sum("after cross-ns take");
        drop(v);
        // cross-namespace extent recycling: the reserve charge stays
        // with the namespace whose lease pinned the segment
        drop(l1);
        let j1_charged = a.ns_stats(1).charged;
        let l3 = j2.lease(60_000, Cat::GradFlat).unwrap();
        assert_eq!(a.ns_stats(2).recycled, 1, "must carve j1's freed segment");
        assert_eq!(a.ns_stats(1).charged, j1_charged, "charge moved with recycling");
        check_sum("after cross-ns recycle");
        drop(l2);
        drop(l3);
        drop(l0);
        a.trim();
        check_sum("after trim");
        assert_eq!(a.stats().reserved_bytes, 0);
        for n in 0..MAX_NAMESPACES {
            assert_eq!(a.ns_stats(n).charged, 0, "ns {n} kept charge after full trim");
        }
    }

    #[test]
    fn quota_refusal_borrow_and_revocation_degrade_without_abort() {
        const P: usize = 4096;
        let a = arena(Mode::Virtual, None);
        let j1 = a.namespace(1);
        a.set_ns_quota(1, Some(64 * P));
        a.set_shared_headroom(32 * P);
        // within quota: admitted
        let l1 = j1.lease(60 * P, Cat::ActCkpt).unwrap();
        // beyond quota: bursts into shared headroom
        let l2 = j1.lease(20 * P, Cat::ActCkpt).unwrap();
        assert_eq!(a.ns_stats(1).borrowed, 16 * P);
        // beyond quota + remaining headroom: the structured refusal
        // carries the namespace's own used/allowed figures
        match j1.lease(40 * P, Cat::ActCkpt).unwrap_err() {
            ArenaError::BudgetExceeded { in_use, budget, .. } => {
                assert_eq!(in_use, 80 * P);
                assert_eq!(budget, (64 + 16 + 16) * P);
            }
        }
        // the refusal degrades j1 only: the host namespace is untouched
        let _h = a.lease(100 * P, Cat::ActCkpt).unwrap();
        // scratch is transient compute memory — not quota-admitted
        j1.put_f32(vec![0f32; 2048], Cat::SwapBuf);
        assert_eq!(a.pooled_f32(Cat::SwapBuf), 1);
        // revocation blocks NEW borrows only; nothing aborts
        a.set_ns_revoked(1, true);
        assert!(j1.lease(20 * P, Cat::ActCkpt).is_err());
        assert_eq!(l1.bytes_requested(), 60 * P, "live lease survived revocation");
        // borrows drain as leases release
        drop(l2);
        assert_eq!(a.ns_stats(1).borrowed, 0);
        // back under quota, new leases admit even while revoked
        let _l3 = j1.lease(4 * P, Cat::ActCkpt).unwrap();
        a.set_ns_revoked(1, false);
        assert!(!a.ns_stats(1).revoked);
    }

    #[test]
    fn virtual_mode_leases_have_no_storage() {
        let a = arena(Mode::Virtual, None);
        let mut l = a.lease(1 << 30, Cat::ParamPool).unwrap();
        assert!(l.is_virtual());
        assert!(l.as_slice().is_empty());
        assert!(l.as_mut_slice().is_empty());
        assert!(l.as_f32().is_empty());
        assert_eq!(a.stats().requested_bytes, 1 << 30);
    }
}
