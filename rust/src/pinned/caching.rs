//! PyTorch `CachingHostAllocator` policy reproduction (the baseline).
//!
//! Policy, per the PyTorch source the paper analyzes:
//! 1. every request is rounded **up to the next power of two**;
//! 2. freed blocks are *cached* in per-size free lists, not returned to
//!    the OS (pinning/unpinning is expensive), so reserved memory is
//!    monotone non-decreasing;
//! 3. an allocation is served from the smallest cached block whose
//!    rounded size matches, else fresh memory is pinned.
//!
//! For the huge, long-lived, exactly-sized buffers of SSD offloading,
//! (1) turns into *permanent* internal fragmentation — the paper's
//! §III-B: "aligning a 2.1 GiB request to 4 GiB needlessly wastes
//! almost 2 GiB".

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::{Cat, HostAllocator, HostRegion, MemoryTracker, Mode, RegionData};

/// Requests below this are not rounded (matches the small-block slab
/// behaviour; irrelevant for offload buffers but keeps policy honest).
const MIN_ROUND: usize = 4096;

pub fn round_pow2(bytes: usize) -> usize {
    if bytes <= MIN_ROUND {
        return MIN_ROUND;
    }
    bytes.next_power_of_two()
}

struct FreeLists {
    /// rounded size -> number of cached blocks of that size.
    lists: BTreeMap<usize, usize>,
}

pub struct CachingAllocator {
    mode: Mode,
    tracker: Arc<MemoryTracker>,
    free: Mutex<FreeLists>,
    reserved: AtomicUsize,
    requested: AtomicUsize,
    /// Fresh pins vs cache hits (reuse-rate metric).
    pub fresh_allocs: AtomicUsize,
    pub cache_hits: AtomicUsize,
}

impl CachingAllocator {
    pub fn new(mode: Mode, tracker: Arc<MemoryTracker>) -> Arc<Self> {
        Arc::new(Self {
            mode,
            tracker,
            free: Mutex::new(FreeLists { lists: BTreeMap::new() }),
            reserved: AtomicUsize::new(0),
            requested: AtomicUsize::new(0),
            fresh_allocs: AtomicUsize::new(0),
            cache_hits: AtomicUsize::new(0),
        })
    }

    pub fn alloc_arc(self: &Arc<Self>, bytes: usize, cat: Cat) -> HostRegion {
        let rounded = round_pow2(bytes);
        let hit = {
            let mut free = self.free.lock().unwrap();
            match free.lists.get_mut(&rounded) {
                Some(n) if *n > 0 => {
                    *n -= 1;
                    true
                }
                _ => false,
            }
        };
        if hit {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
            self.reserved.fetch_add(rounded, Ordering::Relaxed);
            // Reserved growth is what the paper charges as pinned
            // memory: the full rounded size, forever.
            self.tracker.alloc(cat, bytes as u64);
            self.tracker
                .alloc(Cat::PinnedOverhead, (rounded - bytes) as u64);
        }
        self.requested.fetch_add(bytes, Ordering::Relaxed);

        let data = match self.mode {
            Mode::Virtual => RegionData::Virtual,
            Mode::Real => RegionData::Aligned { ptr: super::memalign_zeroed(rounded) },
        };
        let me = Arc::clone(self);
        let req = bytes;
        HostRegion {
            data,
            bytes_requested: bytes,
            bytes_reserved: rounded,
            cat,
            release: Some(Box::new(move |data, reserved, _cat| {
                // The *policy* keeps the block cached — reserved stays
                // monotone and the ledger never shrinks.  The backing
                // pages themselves are returned (a cache hit re-pins
                // fresh memory); only the accounting is PyTorch's.
                if let RegionData::Aligned { ptr } = data {
                    // SAFETY: ptr came from posix_memalign above and is
                    // freed exactly once (release is take()n).
                    unsafe { libc::free(ptr.cast()) };
                }
                me.requested.fetch_sub(req, Ordering::Relaxed);
                let mut free = me.free.lock().unwrap();
                *free.lists.entry(reserved).or_insert(0) += 1;
            })),
        }
    }

    /// Bytes sitting in the free cache (reserved, unused, unreturned).
    pub fn cached_bytes(&self) -> usize {
        let free = self.free.lock().unwrap();
        free.lists.iter().map(|(sz, n)| sz * n).sum()
    }
}

impl HostAllocator for Arc<CachingAllocator> {
    fn alloc(&self, bytes: usize, cat: Cat) -> HostRegion {
        self.alloc_arc(bytes, cat)
    }

    fn reserve_size(&self, bytes: usize) -> usize {
        // worst case: no cached block matches and a fresh pow2 pin grows
        // the reserve (a cache hit reserves nothing new).
        round_pow2(bytes)
    }

    fn reclaimable(&self) -> bool {
        false // freed blocks go to the cache, never back to the ledger
    }

    fn reserved_bytes(&self) -> usize {
        self.reserved.load(Ordering::Relaxed)
    }

    fn requested_bytes(&self) -> usize {
        self.requested.load(Ordering::Relaxed)
    }

    fn tracker(&self) -> &Arc<MemoryTracker> {
        &self.tracker
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::{check, Config};

    fn mk() -> Arc<CachingAllocator> {
        CachingAllocator::new(Mode::Virtual, Arc::new(MemoryTracker::new()))
    }

    #[test]
    fn pow2_rounding() {
        assert_eq!(round_pow2(1), MIN_ROUND);
        assert_eq!(round_pow2(4096), 4096);
        assert_eq!(round_pow2(4097), 8192);
        // the paper's example: 2.1 GiB -> 4 GiB
        let gib = 1usize << 30;
        assert_eq!(round_pow2(gib * 21 / 10), 4 * gib);
    }

    #[test]
    fn paper_example_wastes_half() {
        let a = mk();
        let r = a.alloc_arc((21 << 30) / 10, Cat::GradFlat);
        assert!(r.overhead() as f64 > 1.89 * (1u64 << 30) as f64);
    }

    #[test]
    fn freed_blocks_are_cached_not_released() {
        let a = mk();
        let r = a.alloc_arc(10_000, Cat::Other);
        let reserved = a.reserved_bytes();
        drop(r);
        assert_eq!(a.reserved_bytes(), reserved, "reserve is monotone");
        assert_eq!(a.cached_bytes(), round_pow2(10_000));
        // same-size realloc must hit the cache
        let _r2 = a.alloc_arc(9_000, Cat::Other); // rounds to same bucket
        assert_eq!(a.cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(a.reserved_bytes(), reserved);
    }

    #[test]
    fn different_size_misses_cache() {
        let a = mk();
        drop(a.alloc_arc(10_000, Cat::Other)); // 16384 bucket
        let _r = a.alloc_arc(20_000, Cat::Other); // 32768 bucket
        assert_eq!(a.cache_hits.load(Ordering::Relaxed), 0);
        assert_eq!(a.fresh_allocs.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn prop_reserved_geq_requested_and_pow2() {
        check("caching-allocator", Config::default(), |rng, size| {
            let a = mk();
            let mut live = Vec::new();
            for _ in 0..rng.range(1, 40) {
                if !live.is_empty() && rng.next_f64() < 0.4 {
                    let i = rng.below(live.len());
                    live.swap_remove(i);
                } else {
                    let bytes = rng.range(1, size.max(2) * 1000);
                    let r = a.alloc_arc(bytes, Cat::Other);
                    prop_assert!(
                        r.bytes_reserved >= r.bytes_requested,
                        "reserved < requested"
                    );
                    prop_assert!(
                        r.bytes_reserved.is_power_of_two()
                            || r.bytes_reserved == MIN_ROUND,
                        "not pow2: {}",
                        r.bytes_reserved
                    );
                    live.push(r);
                }
                let live_req: usize = live.iter().map(|r| r.bytes_requested).sum();
                prop_assert!(
                    a.requested_bytes() == live_req,
                    "requested ledger drift"
                );
                prop_assert!(
                    a.reserved_bytes() >= live_req,
                    "reserved below live requested"
                );
            }
            Ok(())
        });
    }
}
