//! Pinned host memory: the allocation policies at the center of §III-B
//! and §IV-C.
//!
//! CUDA pinned memory itself cannot exist here (no GPU); what the paper
//! measures, though, is *policy* waste — PyTorch's CachingHostAllocator
//! rounds every request to the next power of two and caches freed
//! blocks, so a 2.1 GiB long-lived buffer reserves 4 GiB forever.  The
//! policies are reproduced bit-for-bit over real host memory (or over
//! pure accounting for full-scale models):
//!
//! - [`caching::CachingAllocator`] — pow2 rounding + size-bucket reuse
//!   (the ZeRO-Infinity baseline behaviour).
//! - [`aligned::AlignedAllocator`] — MemAscend's alignment-free path:
//!   `posix_memalign(4096)` exact-size allocation, refcounted free
//!   (the `cudaHostRegister`/`torch::from_blob` lifecycle analog).

pub mod aligned;
pub mod caching;
pub mod tracker;

pub use aligned::AlignedAllocator;
pub use caching::CachingAllocator;
pub use tracker::{Cat, MemoryTracker};

use std::sync::Arc;

/// Real allocations back tiny-model training; Virtual allocations run
/// the identical policy logic while only charging the tracker — that is
/// how 322 GiB peaks are measured inside a 35 GiB container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Real,
    Virtual,
}

/// A pinned host region. `bytes_requested <= bytes_reserved`; the
/// difference is the allocator-policy overhead the paper attacks.
pub struct HostRegion {
    pub(crate) data: RegionData,
    pub bytes_requested: usize,
    pub bytes_reserved: usize,
    pub(crate) cat: Cat,
    pub(crate) release: Option<Box<dyn FnOnce(RegionData, usize, Cat) + Send>>,
}

pub(crate) enum RegionData {
    Real(Box<[u8]>),
    /// posix_memalign'd pointer (freed via libc::free in release hook).
    Aligned { ptr: *mut u8 },
    Virtual,
}

// SAFETY: the Aligned pointer is uniquely owned by this region.
unsafe impl Send for RegionData {}

impl HostRegion {
    /// Mutable view of the *requested* span (Real/Aligned modes only).
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        match &mut self.data {
            RegionData::Real(b) => &mut b[..self.bytes_requested],
            RegionData::Aligned { ptr } => unsafe {
                std::slice::from_raw_parts_mut(*ptr, self.bytes_requested)
            },
            RegionData::Virtual => &mut [],
        }
    }

    pub fn as_slice(&self) -> &[u8] {
        match &self.data {
            RegionData::Real(b) => &b[..self.bytes_requested],
            RegionData::Aligned { ptr } => unsafe {
                std::slice::from_raw_parts(*ptr, self.bytes_requested)
            },
            RegionData::Virtual => &[],
        }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self.data, RegionData::Virtual)
    }

    /// Policy overhead of this allocation in bytes.
    pub fn overhead(&self) -> usize {
        self.bytes_reserved - self.bytes_requested
    }
}

impl Drop for HostRegion {
    fn drop(&mut self) {
        if let Some(release) = self.release.take() {
            let data = std::mem::replace(&mut self.data, RegionData::Virtual);
            release(data, self.bytes_reserved, self.cat);
        }
    }
}

/// Common allocator interface for both policies.
pub trait HostAllocator: Send + Sync {
    /// Allocate `bytes` under category `cat`.
    fn alloc(&self, bytes: usize, cat: Cat) -> HostRegion;

    /// Total bytes currently reserved by the allocator (incl. cached
    /// free blocks that the OS never got back — PyTorch semantics).
    fn reserved_bytes(&self) -> usize;

    /// Sum of currently-live requested bytes.
    fn requested_bytes(&self) -> usize;

    fn tracker(&self) -> &Arc<MemoryTracker>;

    /// Reserved-but-not-requested fraction (internal fragmentation).
    fn fragmentation(&self) -> f64 {
        let res = self.reserved_bytes();
        if res == 0 {
            return 0.0;
        }
        1.0 - self.requested_bytes() as f64 / res as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_real_rw() {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(Mode::Real, tracker);
        let mut r = alloc.alloc(100, Cat::Other);
        r.as_mut_slice()[99] = 42;
        assert_eq!(r.as_slice()[99], 42);
        assert!(!r.is_virtual());
    }

    #[test]
    fn virtual_region_has_no_storage() {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(Mode::Virtual, tracker);
        let r = alloc.alloc(1 << 40, Cat::Other); // 1 TiB "allocated"
        assert!(r.is_virtual());
        assert_eq!(r.as_slice().len(), 0);
        assert!(r.bytes_reserved >= 1 << 40);
    }
}
