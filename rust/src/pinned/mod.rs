//! Pinned host memory: the allocation policies at the center of §III-B
//! and §IV-C, plus the unified arena every consumer leases from.
//!
//! CUDA pinned memory itself cannot exist here (no GPU); what the paper
//! measures, though, is *policy* waste — PyTorch's CachingHostAllocator
//! rounds every request to the next power of two and caches freed
//! blocks, so a 2.1 GiB long-lived buffer reserves 4 GiB forever.  The
//! policies are reproduced bit-for-bit over real host memory (or over
//! pure accounting for full-scale models):
//!
//! - [`caching::CachingAllocator`] — pow2 rounding + size-bucket reuse
//!   (the ZeRO-Infinity baseline behaviour).
//! - [`aligned::AlignedAllocator`] — MemAscend's alignment-free path:
//!   `posix_memalign(4096)` exact-size allocation, refcounted free
//!   (the `cudaHostRegister`/`torch::from_blob` lifecycle analog).
//!
//! Layered on top sits [`arena::PinnedArena`] — the single
//! budget-enforced lease tier this crate's host-memory consumers
//! (buffer pools, gradient flat buffer, activation spill slots,
//! swapper/optimizer scratch) allocate through.  The allocators above
//! supply the *policy* (how a request is rounded and whether frees
//! return to the OS); the arena supplies the *system invariant*: one
//! global byte budget, per-category watermarks, offset/len leases that
//! can never overlap, and exact fragmentation stats.  Direct
//! [`HostAllocator::alloc`] calls are reserved to this module — every
//! other subsystem goes through the arena.

pub mod aligned;
pub mod arena;
pub mod caching;
pub mod tracker;

pub use aligned::AlignedAllocator;
pub use arena::{
    ArenaConfig, ArenaError, ArenaStats, CatWatermark, Lease, NsStats, PinnedArena,
    MAX_NAMESPACES,
};
pub use caching::CachingAllocator;
pub use tracker::{Cat, MemoryTracker};

use std::sync::Arc;

/// Real allocations back tiny-model training; Virtual allocations run
/// the identical policy logic while only charging the tracker — that is
/// how 322 GiB peaks are measured inside a 35 GiB container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Real,
    Virtual,
}

/// A pinned host region. `bytes_requested <= bytes_reserved`; the
/// difference is the allocator-policy overhead the paper attacks.
pub struct HostRegion {
    pub(crate) data: RegionData,
    pub bytes_requested: usize,
    pub bytes_reserved: usize,
    pub(crate) cat: Cat,
    pub(crate) release: Option<Box<dyn FnOnce(RegionData, usize, Cat) + Send>>,
}

pub(crate) enum RegionData {
    /// posix_memalign'd pointer (freed via libc::free in the release
    /// hook).  Both allocators back real regions this way, so every
    /// region base — and every page-aligned arena lease carved from
    /// one — is DMA-aligned and safely viewable as `&[f32]`.
    Aligned { ptr: *mut u8 },
    Virtual,
}

// SAFETY: the Aligned pointer is uniquely owned by this region.
unsafe impl Send for RegionData {}

impl HostRegion {
    /// Mutable view of the *requested* span (Real mode only).
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        match &mut self.data {
            RegionData::Aligned { ptr } => unsafe {
                std::slice::from_raw_parts_mut(*ptr, self.bytes_requested)
            },
            RegionData::Virtual => &mut [],
        }
    }

    pub fn as_slice(&self) -> &[u8] {
        match &self.data {
            RegionData::Aligned { ptr } => unsafe {
                std::slice::from_raw_parts(*ptr, self.bytes_requested)
            },
            RegionData::Virtual => &[],
        }
    }

    /// Raw base pointer (null in Virtual mode).  The arena carves
    /// disjoint lease views from it without materializing a whole-region
    /// `&mut` that would alias them.
    pub(crate) fn raw_base(&self) -> *mut u8 {
        match &self.data {
            RegionData::Aligned { ptr } => *ptr,
            RegionData::Virtual => std::ptr::null_mut(),
        }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self.data, RegionData::Virtual)
    }

    /// Policy overhead of this allocation in bytes.
    pub fn overhead(&self) -> usize {
        self.bytes_reserved - self.bytes_requested
    }
}

impl Drop for HostRegion {
    fn drop(&mut self) {
        if let Some(release) = self.release.take() {
            let data = std::mem::replace(&mut self.data, RegionData::Virtual);
            release(data, self.bytes_reserved, self.cat);
        }
    }
}

/// Common allocator interface for both policies.
pub trait HostAllocator: Send + Sync {
    /// Allocate `bytes` under category `cat`.
    fn alloc(&self, bytes: usize, cat: Cat) -> HostRegion;

    /// Worst-case bytes a fresh `alloc(bytes, _)` would reserve under
    /// this policy (the arena's budget precheck).
    fn reserve_size(&self, bytes: usize) -> usize;

    /// Whether freeing a region actually returns its bytes to the OS
    /// and the ledger.  False for the pow2-caching policy (freed
    /// blocks are cached forever; the reserve is monotone), in which
    /// case the arena never trims segments — keeping its watermarks
    /// an exact mirror of the ledger.
    fn reclaimable(&self) -> bool;

    /// Total bytes currently reserved by the allocator (incl. cached
    /// free blocks that the OS never got back — PyTorch semantics).
    fn reserved_bytes(&self) -> usize;

    /// Sum of currently-live requested bytes.
    fn requested_bytes(&self) -> usize;

    fn tracker(&self) -> &Arc<MemoryTracker>;

    /// Reserved-but-not-requested fraction (internal fragmentation).
    fn fragmentation(&self) -> f64 {
        let res = self.reserved_bytes();
        if res == 0 {
            return 0.0;
        }
        1.0 - self.requested_bytes() as f64 / res as f64
    }
}

/// posix_memalign a zeroed, DMA-aligned block of `bytes` (shared by
/// both allocators' Real mode).
pub(crate) fn memalign_zeroed(bytes: usize) -> *mut u8 {
    let mut ptr: *mut libc::c_void = std::ptr::null_mut();
    // SAFETY: standard posix_memalign call; checked result.
    let rc = unsafe { libc::posix_memalign(&mut ptr, aligned::DMA_ALIGN, bytes) };
    assert_eq!(rc, 0, "posix_memalign failed for {bytes} bytes");
    // zero-init (pinned buffers are staging space; make reads
    // deterministic)
    unsafe { std::ptr::write_bytes(ptr.cast::<u8>(), 0, bytes) };
    ptr.cast()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_real_rw() {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(Mode::Real, tracker);
        let mut r = alloc.alloc(100, Cat::Other);
        r.as_mut_slice()[99] = 42;
        assert_eq!(r.as_slice()[99], 42);
        assert!(!r.is_virtual());
    }

    #[test]
    fn virtual_region_has_no_storage() {
        let tracker = Arc::new(MemoryTracker::new());
        let alloc = AlignedAllocator::new(Mode::Virtual, tracker);
        let r = alloc.alloc(1 << 40, Cat::Other); // 1 TiB "allocated"
        assert!(r.is_virtual());
        assert_eq!(r.as_slice().len(), 0);
        assert!(r.bytes_reserved >= 1 << 40);
    }

    #[test]
    fn real_regions_are_dma_aligned_under_both_policies() {
        let t = Arc::new(MemoryTracker::new());
        let a = AlignedAllocator::new(Mode::Real, t.clone());
        let c = CachingAllocator::new(Mode::Real, t);
        for r in [a.alloc(100, Cat::Other), c.alloc_arc(100, Cat::Other)] {
            assert_eq!(r.as_slice().as_ptr() as usize % aligned::DMA_ALIGN, 0);
        }
    }
}
