//! System-memory ledger: per-category current/peak accounting plus an
//! event timeline (the instrument behind Figs. 3, 8, 13, 15, 16, 17).
//!
//! Every allocator, buffer pool, and engine charges its bytes here, in
//! both *real* runs (tiny models, actual buffers) and *virtual* runs
//! (full-scale accounting — same allocator logic, no backing pages).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Memory categories matching the paper's Fig. 8 breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Cat {
    /// Parameter buffer pool (prefetch staging).
    ParamPool,
    /// Power-of-two / alignment overhead on pinned allocations.
    PinnedOverhead,
    /// fp32 gradient partition flat buffer.
    GradFlat,
    /// Transients of the overflow check (abs copy, bool tensors).
    OverflowTemp,
    /// Optimizer state fetch/update buffers.
    OptimBuf,
    /// Swap-out staging buffer.
    SwapBuf,
    /// Offloaded activation checkpoints (Eq. 1).
    ActCkpt,
    /// Small resident tensors (norms, router) + misc framework.
    Resident,
    Other,
}

impl Cat {
    pub const ALL: [Cat; 9] = [
        Cat::ParamPool,
        Cat::PinnedOverhead,
        Cat::GradFlat,
        Cat::OverflowTemp,
        Cat::OptimBuf,
        Cat::SwapBuf,
        Cat::ActCkpt,
        Cat::Resident,
        Cat::Other,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Cat::ParamPool => "param_pool",
            Cat::PinnedOverhead => "pinned_overhead",
            Cat::GradFlat => "grad_flat",
            Cat::OverflowTemp => "overflow_temp",
            Cat::OptimBuf => "optim_buf",
            Cat::SwapBuf => "swap_buf",
            Cat::ActCkpt => "act_ckpt",
            Cat::Resident => "resident",
            Cat::Other => "other",
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Logical time (monotone event counter).
    pub t: u64,
    pub cat: Cat,
    /// Signed delta in bytes (+alloc / -free).
    pub delta: i64,
    /// Global current total *after* this event.
    pub total_after: u64,
}

#[derive(Default)]
struct Inner {
    current: BTreeMap<Cat, u64>,
    peak: BTreeMap<Cat, u64>,
    timeline: Vec<Event>,
    record_timeline: bool,
}

/// Thread-safe memory ledger.
pub struct MemoryTracker {
    inner: Mutex<Inner>,
    total: AtomicU64,
    peak_total: AtomicU64,
    clock: AtomicU64,
}

impl Default for MemoryTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoryTracker {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner::default()),
            total: AtomicU64::new(0),
            peak_total: AtomicU64::new(0),
            clock: AtomicU64::new(0),
        }
    }

    /// Enable the event timeline (Fig. 3 reproduction); off by default
    /// to keep long runs cheap.
    pub fn with_timeline() -> Self {
        let t = Self::new();
        t.inner.lock().unwrap().record_timeline = true;
        t
    }

    pub fn alloc(&self, cat: Cat, bytes: u64) {
        self.apply(cat, bytes as i64);
    }

    pub fn free(&self, cat: Cat, bytes: u64) {
        self.apply(cat, -(bytes as i64));
    }

    fn apply(&self, cat: Cat, delta: i64) {
        let t = self.clock.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let cur = inner.current.entry(cat).or_insert(0);
        if delta >= 0 {
            *cur += delta as u64;
        } else {
            let d = (-delta) as u64;
            debug_assert!(*cur >= d, "free exceeds current for {:?}", cat);
            *cur = cur.saturating_sub(d);
        }
        let cur_v = *cur;
        let pk = inner.peak.entry(cat).or_insert(0);
        *pk = (*pk).max(cur_v);

        let new_total = if delta >= 0 {
            self.total.fetch_add(delta as u64, Ordering::Relaxed) + delta as u64
        } else {
            let d = (-delta) as u64;
            self.total.fetch_sub(d, Ordering::Relaxed) - d
        };
        self.peak_total.fetch_max(new_total, Ordering::Relaxed);
        if inner.record_timeline {
            inner.timeline.push(Event { t, cat, delta, total_after: new_total });
        }
    }

    pub fn current_total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn peak_total(&self) -> u64 {
        self.peak_total.load(Ordering::Relaxed)
    }

    pub fn current(&self, cat: Cat) -> u64 {
        *self.inner.lock().unwrap().current.get(&cat).unwrap_or(&0)
    }

    pub fn peak(&self, cat: Cat) -> u64 {
        *self.inner.lock().unwrap().peak.get(&cat).unwrap_or(&0)
    }

    pub fn timeline(&self) -> Vec<Event> {
        self.inner.lock().unwrap().timeline.clone()
    }

    /// Per-category peak snapshot (Fig. 8 bars).
    pub fn peak_breakdown(&self) -> Vec<(Cat, u64)> {
        let inner = self.inner.lock().unwrap();
        Cat::ALL
            .iter()
            .filter_map(|c| inner.peak.get(c).map(|v| (*c, *v)))
            .filter(|(_, v)| *v > 0)
            .collect()
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (c, v) in self.peak_breakdown() {
            s.push_str(&format!(
                "  {:<16} peak {:>12}  current {:>12}\n",
                c.name(),
                crate::util::human::bytes(v),
                crate::util::human::bytes(self.current(c)),
            ));
        }
        s.push_str(&format!(
            "  {:<16} peak {:>12}  current {:>12}\n",
            "TOTAL",
            crate::util::human::bytes(self.peak_total()),
            crate::util::human::bytes(self.current_total()),
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_spike() {
        let t = MemoryTracker::new();
        t.alloc(Cat::GradFlat, 100);
        t.alloc(Cat::OverflowTemp, 125); // 2.25x spike analog
        t.free(Cat::OverflowTemp, 125);
        assert_eq!(t.current_total(), 100);
        assert_eq!(t.peak_total(), 225);
        assert_eq!(t.peak(Cat::OverflowTemp), 125);
        assert_eq!(t.current(Cat::OverflowTemp), 0);
    }

    #[test]
    fn timeline_records_order() {
        let t = MemoryTracker::with_timeline();
        t.alloc(Cat::ParamPool, 10);
        t.alloc(Cat::GradFlat, 20);
        t.free(Cat::ParamPool, 10);
        let tl = t.timeline();
        assert_eq!(tl.len(), 3);
        assert!(tl.windows(2).all(|w| w[0].t < w[1].t));
        assert_eq!(tl[2].total_after, 20);
    }

    #[test]
    fn concurrent_updates_balance() {
        let t = std::sync::Arc::new(MemoryTracker::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let t = t.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        t.alloc(Cat::Other, 7);
                        t.free(Cat::Other, 7);
                    }
                });
            }
        });
        assert_eq!(t.current_total(), 0);
        assert!(t.peak_total() >= 7);
    }
}
