//! AOT manifest: the shape/dtype contract between `python/compile` and
//! the Rust runtime (written by `aot.py`, one per exported config).

use std::path::Path;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub results: Vec<ArgSpec>,
}

/// Model hyper-parameters baked into the artifacts.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub seq: usize,
    pub batch: usize,
    pub chunk: usize,
    pub param_count: u64,
}

/// Adam constants baked into the `adam_step` artifact.
#[derive(Debug, Clone)]
pub struct AdamMeta {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelMeta,
    pub adam: AdamMeta,
    pub block_weight_names: Vec<String>,
    pub stages: Vec<StageSpec>,
}

fn arg_from_json(j: &Json) -> anyhow::Result<ArgSpec> {
    Ok(ArgSpec {
        name: j.req("name")?.as_str().unwrap_or_default().to_string(),
        shape: j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("shape not array"))?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect(),
        dtype: j.req("dtype")?.as_str().unwrap_or("f32").to_string(),
    })
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest json: {e}"))?;
        let c = j.req("config")?;
        let num = |k: &str| -> anyhow::Result<usize> {
            c.req(k)?.as_usize().ok_or_else(|| anyhow::anyhow!("config.{k} not a number"))
        };
        let config = ModelMeta {
            name: c.req("name")?.as_str().unwrap_or_default().to_string(),
            vocab: num("vocab")?,
            hidden: num("hidden")?,
            intermediate: num("intermediate")?,
            layers: num("layers")?,
            heads: num("heads")?,
            kv_heads: num("kv_heads")?,
            seq: num("seq")?,
            batch: num("batch")?,
            chunk: num("chunk")?,
            param_count: c.req("param_count")?.as_u64().unwrap_or(0),
        };
        let a = j.req("adam")?;
        let anum = |k: &str| -> anyhow::Result<f64> {
            a.req(k)?.as_f64().ok_or_else(|| anyhow::anyhow!("adam.{k} not a number"))
        };
        let adam = AdamMeta {
            lr: anum("lr")?,
            beta1: anum("beta1")?,
            beta2: anum("beta2")?,
            eps: anum("eps")?,
            weight_decay: anum("weight_decay")?,
        };
        let block_weight_names = j
            .req("block_weight_names")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("block_weight_names not array"))?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let mut stages = Vec::new();
        for (name, st) in j
            .req("stages")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("stages not object"))?
        {
            let args = st
                .req("args")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("args not array"))?
                .iter()
                .map(arg_from_json)
                .collect::<anyhow::Result<_>>()?;
            let results = st
                .req("results")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("results not array"))?
                .iter()
                .map(arg_from_json)
                .collect::<anyhow::Result<_>>()?;
            stages.push(StageSpec {
                name: name.clone(),
                file: st.req("file")?.as_str().unwrap_or_default().to_string(),
                args,
                results,
            });
        }
        Ok(Self { config, adam, block_weight_names, stages })
    }

    pub fn stage(&self, name: &str) -> anyhow::Result<&StageSpec> {
        self.stages.iter().find(|s| s.name == name).ok_or_else(|| {
            anyhow::anyhow!(
                "no stage '{name}' in manifest (have: {})",
                self.stage_names().join(", ")
            )
        })
    }

    pub fn stage_names(&self) -> Vec<String> {
        self.stages.iter().map(|s| s.name.clone()).collect()
    }

    /// The matching Rust-side ModelSpec preset, verified dimensionally.
    pub fn model_spec(&self) -> anyhow::Result<&'static crate::config::ModelSpec> {
        let spec = crate::config::ModelSpec::by_name(&self.config.name)?;
        anyhow::ensure!(
            spec.vocab == self.config.vocab
                && spec.hidden == self.config.hidden
                && spec.layers == self.config.layers
                && spec.param_count() == self.config.param_count,
            "manifest/preset divergence for '{}': re-run `make artifacts`",
            self.config.name
        );
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"name": "smoke", "vocab": 64, "hidden": 32,
                 "intermediate": 64, "layers": 2, "heads": 2,
                 "kv_heads": 2, "seq": 16, "batch": 2, "chunk": 1024,
                 "param_count": 23680, "norm_eps": 1e-6,
                 "rope_theta": 10000.0},
      "adam": {"lr": 0.001, "beta1": 0.9, "beta2": 0.999,
               "eps": 1e-8, "weight_decay": 0.0},
      "block_weight_names": ["attn_norm", "wq"],
      "stages": {
        "embed_fwd": {
          "file": "embed_fwd.hlo.txt",
          "args": [{"name": "tokens", "shape": [2, 16], "dtype": "i32"},
                    {"name": "table", "shape": [64, 32], "dtype": "f32"}],
          "results": [{"name": "h", "shape": [2, 16, 32], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.name, "smoke");
        assert_eq!(m.config.hidden, 32);
        let st = m.stage("embed_fwd").unwrap();
        assert_eq!(st.args[0].dtype, "i32");
        assert_eq!(st.args[0].numel(), 32);
        assert_eq!(st.results[0].numel(), 2 * 16 * 32);
        assert!(m.stage("nope").is_err());
        assert!((m.adam.lr - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn model_spec_divergence_detected() {
        // param_count 23680 is wrong for the smoke preset -> must error
        let m = Manifest::parse(SAMPLE).unwrap();
        if m.config.param_count != crate::config::presets::SMOKE.param_count() {
            assert!(m.model_spec().is_err());
        }
    }
}
